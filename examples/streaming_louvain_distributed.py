"""Distributed streaming community detection: sharded warm-start Louvain.

The multi-device version of ``streaming_louvain.py``: the graph is
partitioned ONCE over 8 forced host devices (1-D vertex partition), then a
community-structured graph evolves one edge batch at a time.  Each update

  1. applies the batch directly to the per-shard edge arrays inside
     shard_map (one sort-reduce per shard; compiled shapes never change),
  2. delta-screens the changed endpoints + their communities into a seed
     frontier (one all_gather of touched-owned slices), and
  3. resumes the sharded move rounds from the previous replicated
     membership,

so the cluster serves fresh membership between queries without ever
re-running from singletons.  A deliberately undersized partition at the end
shows the capacity-growth policy: the stream overflows e_per_shard,
re-buckets into doubled capacity, and keeps going.

    PYTHONPATH=src python examples/streaming_louvain_distributed.py
"""

import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import jax
import numpy as np

from repro.compat import make_mesh
from repro.core.delta import make_edge_batch
from repro.core.distributed import distributed_louvain
from repro.core.distributed_dynamic import louvain_dynamic_sharded
from repro.core.graph import build_csr
from repro.core.louvain import membership_modularity
from repro.data import sbm_graph

# 1. The "final" graph: 32 communities of 16 vertices.  Hold out 120
#    intra-community edges and stream them back in batches of 6.
full, _truth = sbm_graph(n_communities=32, size=16, p_in=0.4, p_out=0.003,
                         seed=3)
e = int(full.e_valid)
src, dst = np.asarray(full.src)[:e], np.asarray(full.indices)[:e]
w = np.asarray(full.weights)[:e]
und = src < dst
us, ud, uw = src[und], dst[und], w[und]

rng = np.random.default_rng(0)
hold = rng.choice(len(us), 120, replace=False)
keep = np.ones(len(us), bool)
keep[hold] = False
initial = build_csr(np.concatenate([us[keep], ud[keep]]),
                    np.concatenate([ud[keep], us[keep]]),
                    np.concatenate([uw[keep], uw[keep]]),
                    int(full.n_valid), e_cap=e + 8)

batches = [make_edge_batch(us[hold[i::20]], ud[hold[i::20]],
                           uw[hold[i::20]], initial.n_cap, b_cap=8)
           for i in range(20)]

mesh = make_mesh((2, 4), ("data", "model"))
axes = ("data", "model")
print(f"devices: {jax.device_count()}, mesh {dict(mesh.shape)}")
print(f"initial graph     : {int(initial.n_valid)} vertices, "
      f"{int(initial.e_valid)} directed edges")

# 2. One cold sharded run gives the starting membership (e_per_shard head-
#    room because aggregation concentrates coarse edges — community skew)...
prev, ncomm0, _ = distributed_louvain(initial, mesh, axes, e_per_shard=e)
print(f"cold sharded start: {ncomm0} communities, "
      f"Q = {membership_modularity(initial, prev):.4f}")

# 3. ...then every batch is an incremental warm-started sharded update.
dyn = louvain_dynamic_sharded(initial, mesh, axes, batches, prev=prev,
                              track_modularity=True)
print(f"\nstreamed {len(batches)} batches "
      f"({sum(s.batch_size for s in dyn.batch_stats)} edge updates) "
      f"in {dyn.total_seconds:.2f}s "
      f"({dyn.updates_per_second:.0f} updates/s), "
      f"layout {dyn.spec.n_shards} shards x {dyn.spec.e_per_shard} slots")
for i, s in enumerate(dyn.batch_stats):
    print(f"  batch {i:2d}: +{s.batch_size} edges, touched {s.n_touched:3d} "
          f"vertices, frontier {s.frontier_size:3d}/{s.n_vertices} "
          f"({100 * s.frontier_fraction:4.1f}%), "
          f"{s.n_communities} communities, Q = {s.modularity:.4f}")

# 4. Sanity: a cold sharded recompute on the final graph agrees.
cold_mem, cold_ncomm, _ = distributed_louvain(full, mesh, axes,
                                              e_per_shard=e)
print(f"\nfinal dynamic     : {dyn.n_communities} communities, "
      f"Q = {membership_modularity(full, dyn.membership):.4f}")
print(f"cold recompute    : {cold_ncomm} communities, "
      f"Q = {membership_modularity(full, cold_mem):.4f}")

# 5. Capacity growth: a partition with almost no edge headroom survives the
#    same stream by re-bucketing into doubled capacity (one recompile each).
tight = louvain_dynamic_sharded(initial, mesh, axes, batches, prev=prev,
                                e_per_shard=1)
print(f"\ntight partition   : {tight.n_regrows} capacity regrow(s), "
      f"e_per_shard -> {tight.spec.e_per_shard}, "
      f"Q = {membership_modularity(full, tight.membership):.4f}")
