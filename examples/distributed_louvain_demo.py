"""Multi-device GVE-Louvain via shard_map (the Vite-style distributed layer).

Forces 8 host devices (must run as its own process), partitions an R-MAT
graph 1-D over a (2, 4) data x model mesh, and runs the distributed
local-moving + aggregation phases end to end, comparing quality against the
single-device implementation.

    PYTHONPATH=src python examples/distributed_louvain_demo.py
"""

import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import make_mesh
from repro.core.distributed import distributed_louvain, partition_graph_host
from repro.core.louvain import LouvainConfig, louvain, louvain_modularity
from repro.core.modularity import modularity
from repro.data import rmat_graph

graph = rmat_graph(11, edge_factor=8, seed=0)
n, e = int(graph.n_valid), int(graph.e_valid)
print(f"R-MAT graph: {n} vertices, {e} directed edges")
print(f"devices: {jax.device_count()}")

mesh = make_mesh((2, 4), ("data", "model"))

# Show the layout the distributed phases consume.
src_g, dst_g, w_g, spec = partition_graph_host(graph, 8)
print(f"1-D vertex partition: {spec.n_shards} shards x "
      f"{spec.v_per_shard} vertices, {spec.e_per_shard} edge slots/shard")

t0 = time.perf_counter()
mem, ncomm, stats = distributed_louvain(graph, mesh, ("data", "model"))
t_dist = time.perf_counter() - t0

comm = jnp.concatenate([
    jnp.asarray(mem, jnp.int32),
    jnp.full((graph.n_cap + 1 - len(mem),), graph.n_cap, jnp.int32)])
q_dist = float(modularity(graph, comm))

t0 = time.perf_counter()
res = louvain(graph, LouvainConfig())
t_single = time.perf_counter() - t0
q_single = louvain_modularity(graph, res)

print(f"\ndistributed : {ncomm} communities, Q = {q_dist:.4f}, "
      f"{t_dist:.2f}s, {len(stats)} passes")
for i, s in enumerate(stats):
    print(f"  pass {i}: {s['n_vertices']} -> {s['n_communities']} "
          f"({s['iterations']} iters)")
print(f"single      : {res.n_communities} communities, "
      f"Q = {q_single:.4f}, {t_single:.2f}s")
print(f"quality gap : {100 * (q_single - q_dist) / max(q_single, 1e-9):.2f}%")
