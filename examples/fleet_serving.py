"""Multi-tenant serving fleet: shard every tenant, batch tenants per step.

Four tenant graphs — three regular SBM streams and one "whale" whose insert
stream outgrows its capacity envelope — are served through ONE
``FleetRouter``: each tenant's graph is sharded across the device mesh,
tenants sharing a capacity envelope ride the same ``jit(vmap(shard_map))``
dispatch, every dispatch's convergence fetch is deferred one step, and the
whale migrates to a bigger bucket mid-stream without recompiling anyone
else.  The same streams are then re-served one tenant at a time through
``louvain_dynamic_sharded`` to show the fleet speedup and the bit-for-bit
per-tenant equality.

    PYTHONPATH=src python examples/fleet_serving.py
"""

import time

import numpy as np

from repro.compat import make_mesh
from repro.core.delta import make_edge_batch
from repro.core.distributed_dynamic import louvain_dynamic_sharded
from repro.core.fleet import serve_fleet
from repro.core.graph import build_csr
from repro.core.louvain import louvain
from repro.data import sbm_holdout_stream

AXES = ("shard",)


def make_stream(seed, n_steps=8, b_cap=4):
    """One tenant: an SBM graph with held-out edges streamed back in."""
    init, batches, _ = sbm_holdout_stream(
        seed, n_cap=128, e_cap=4600, n_hold=32, n_steps=n_steps,
        b_cap=b_cap)
    return init, batches


def make_whale(n=64, n_batches=8, k=12):
    """A sparse ring with dense insert batches: its envelope overflows
    mid-stream and the router migrates it to a bigger bucket."""
    s = np.arange(n, dtype=np.int64)
    d = (s + 1) % n
    g = build_csr(np.concatenate([s, d]), np.concatenate([d, s]),
                  np.ones(2 * n, np.float32), n, e_cap=2 * n + 4 * k)
    rng = np.random.default_rng(7)
    batches = []
    for _ in range(n_batches):
        bs = rng.integers(0, n, k)
        bd = (bs + 2 + rng.integers(0, n - 3, k)) % n
        batches.append(make_edge_batch(bs, bd, np.ones(k, np.float32),
                                       g.n_cap, b_cap=k))
    return g, batches


def main():
    mesh = make_mesh((1,), AXES)
    graphs, streams = {}, {}
    for t in range(3):
        graphs[f"t{t}"], streams[f"t{t}"] = make_stream(100 + t)
    graphs["whale"], streams["whale"] = make_whale()
    prevs = {tid: louvain(g).membership for tid, g in graphs.items()}

    print(f"fleet: {len(graphs)} tenants "
          f"(3 SBM streams + 1 overflowing whale)")

    # Warm both paths once (compile), then time.
    serve_fleet(graphs, streams, mesh, AXES, prevs=prevs,
                screening="community")
    for tid in graphs:
        louvain_dynamic_sharded(graphs[tid], mesh, AXES, streams[tid],
                                prev=prevs[tid], screening="community")

    t0 = time.perf_counter()
    flt = serve_fleet(graphs, streams, mesh, AXES, prevs=prevs,
                      screening="community")
    t_fleet = time.perf_counter() - t0

    t0 = time.perf_counter()
    solo = {tid: louvain_dynamic_sharded(graphs[tid], mesh, AXES,
                                         streams[tid], prev=prevs[tid],
                                         screening="community")
            for tid in graphs}
    t_seq = time.perf_counter() - t0

    print(f"\nfleet     : {t_fleet:.3f}s "
          f"({flt.n_dispatches} fused dispatches, "
          f"{flt.bytes_per_dispatch:.0f} B/dispatch, "
          f"{flt.n_migrations} migration(s), backend={flt.comm_backend})")
    # With 3 buckets for 4 tenants plus a migration replay, the fleet's
    # dispatch win here is modest — BENCH_fleet.json holds the scaled
    # head-to-head (one shared bucket, 8 devices, 2-3x).
    print(f"sequential: {t_seq:.3f}s "
          f"({t_seq / t_fleet:.2f}x the fleet's wall time)")

    print("\nbucket layout after the serve:")
    # Each bucket also resolves its own STATE layout (LouvainConfig.
    # state_layout, default "replicated"): under "auto"/"hybrid" the
    # router keeps working state owner-partitioned when the worst
    # admitted tenant's measured boundary fraction is small enough,
    # trading dense per-round psums for boundary-mover halo lanes.
    for env, tids in flt.buckets.items():
        lay = flt.bucket_layouts.get(env, flt.state_layout)
        print(f"  v/shard={env.v_per_shard:4d} e/shard={env.e_per_shard:5d} "
              f"b_cap={env.b_cap} state={lay}: {', '.join(tids)}")
    frac = ("n/a" if flt.boundary_frac is None
            else f"{flt.boundary_frac:.2f}")
    print(f"  summary layout={flt.state_layout}  halo bytes="
          f"{flt.halo_bytes}  worst boundary frac={frac}")

    print("\nper-tenant results (fleet == solo sharded, bit-for-bit):")
    for tid in graphs:
        same = np.array_equal(flt.membership[tid], solo[tid].membership)
        print(f"  {tid:6s}: {flt.n_communities[tid]:2d} communities, "
              f"equal = {same}")


if __name__ == "__main__":
    main()
