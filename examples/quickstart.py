"""Quickstart: community detection with GVE-Louvain (JAX) in ~20 lines.

    PYTHONPATH=src python examples/quickstart.py
"""

import networkx as nx

from repro.core.graph import from_networkx
from repro.core.louvain import LouvainConfig, louvain, louvain_modularity

# 1. Any undirected graph -> the framework's padded CSR container.
nxg = nx.les_miserables_graph()
graph = from_networkx(nxg)

# 2. Run with the paper's parameters (tolerance 0.01, drop 10, cap 20 iters,
#    aggregation tolerance 0.8, vertex pruning on).
result = louvain(graph, LouvainConfig())

print(f"vertices          : {int(graph.n_valid)}")
print(f"edges (directed)  : {int(graph.e_valid)}")
print(f"communities found : {result.n_communities}")
print(f"passes            : {result.n_passes}")
print(f"modularity Q      : {louvain_modularity(graph, result):.4f}")
print(f"total time        : {result.total_seconds * 1e3:.1f} ms")

# 3. Per-pass details (the paper's Fig. 6 phase split, per run).
for i, p in enumerate(result.passes):
    print(f"  pass {i}: {p.n_vertices} vertices -> {p.n_communities} "
          f"communities in {p.iterations} iterations "
          f"({p.seconds * 1e3:.1f} ms)")

# 4. Who's with whom (first 10 vertices).
names = list(nxg.nodes())[:10]
for name, c in zip(names, result.membership[:10]):
    print(f"  {name:24s} -> community {c}")

# 5. The same run through the Pallas ELL-kernel path (Far-KV analogue).
result_ell = louvain(graph, LouvainConfig(use_ell_kernel=True))
print(f"ELL-kernel path Q : {louvain_modularity(graph, result_ell):.4f}")
