"""The paper's technique as a framework feature: Louvain-driven graph
partitioning for distributed GNN training.

Detects communities on a modular graph, packs them onto N devices
(community-balanced bin packing), and compares the edge-cut — the proxy for
cross-device gather traffic in full-graph GNN training — against random
placement.  Then trains a GIN on the reordered graph for a few steps.

    PYTHONPATH=src python examples/community_partition_gnn.py
"""

import time

import jax
import jax.numpy as jnp
import networkx as nx
import numpy as np

from repro.core.graph import from_networkx
from repro.core.partition import louvain_partition, random_partition
from repro.models.gnn import gin
from repro.models.gnn.common import GraphBatch
from repro.optim import AdamWConfig, adamw_init, adamw_update

N_DEVICES = 8

# A social-like modular graph.
nxg = nx.connected_caveman_graph(24, 12)
graph = from_networkx(nxg)
n = int(graph.n_valid)
print(f"graph: {n} vertices, {int(graph.e_valid)} directed edges")

# --- partition quality: Louvain vs random ----------------------------------
t0 = time.perf_counter()
lp = louvain_partition(graph, N_DEVICES)
t_louvain = time.perf_counter() - t0
rp = random_partition(graph, N_DEVICES)
print(f"louvain partition : cut {lp.cut_edges}/{lp.total_edges} "
      f"({100 * lp.cut_fraction:.1f}%), balance {lp.balance:.2f}, "
      f"{t_louvain * 1e3:.0f} ms")
print(f"random partition  : cut {rp.cut_edges}/{rp.total_edges} "
      f"({100 * rp.cut_fraction:.1f}%), balance {rp.balance:.2f}")
print(f"gather-traffic reduction: "
      f"{rp.cut_fraction / max(lp.cut_fraction, 1e-9):.1f}x")

# --- train a GIN node classifier on the community-reordered graph ----------
# Labels: the communities themselves (self-supervised sanity task).
perm = lp.order                       # community-contiguous vertex order
inv = np.argsort(perm)
src = inv[np.asarray(graph.src)[: int(graph.e_valid)]]
dst = inv[np.asarray(graph.indices)[: int(graph.e_valid)]]
labels = lp.assignment[perm]

cfg = gin.GINConfig(n_layers=3, d_hidden=32, d_feat=8,
                    n_classes=N_DEVICES)
key = jax.random.PRNGKey(0)
batch = GraphBatch(
    node_feat=jax.random.normal(key, (n, 8)),
    edge_src=jnp.asarray(src, jnp.int32),
    edge_dst=jnp.asarray(dst, jnp.int32),
    n_nodes=jnp.int32(n),
    labels=jnp.asarray(labels, jnp.int32),
    graph_id=jnp.zeros((n,), jnp.int32), n_graphs=jnp.int32(1))

params = gin.init_params(cfg, key)
opt = adamw_init(params)
ocfg = AdamWConfig(lr=5e-3)


@jax.jit
def step(p, o):
    loss, g = jax.value_and_grad(
        lambda q: gin.loss_fn(cfg, q, batch))(p)
    p, o, _ = adamw_update(ocfg, p, g, o)
    return p, o, loss


print("\ntraining GIN on the partitioned graph:")
for s in range(60):
    params, opt, loss = step(params, opt)
    if s % 10 == 0 or s == 59:
        print(f"  step {s:3d}  loss {float(loss):.4f}")
