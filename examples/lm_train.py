"""End-to-end LM training driver: a ~100M-param qwen2-style model for a few
hundred steps on synthetic data, through the fault-tolerant training loop
(checkpoint/resume + straggler detection + optional gradient compression).

    PYTHONPATH=src python examples/lm_train.py [--steps 200] [--params 100]
"""

import argparse
import tempfile

import jax

from repro.data.tokens import synthetic_token_batches
from repro.models import transformer as tf
from repro.optim import AdamWConfig, CompressionConfig
from repro.train.loop import TrainLoopConfig, train


def make_config(target_m_params: int) -> tf.TransformerConfig:
    """A qwen2-shaped config scaled to ~target_m_params million params."""
    if target_m_params >= 100:
        d, L, v = 640, 10, 48000           # ~92M (+biases/norms ~ 100M tier)
    elif target_m_params >= 20:
        d, L, v = 256, 6, 16000
    else:
        d, L, v = 128, 4, 2000
    return tf.TransformerConfig(
        name=f"lm-{target_m_params}m", n_layers=L, d_model=d,
        n_heads=max(d // 64, 2), n_kv_heads=max(d // 128, 1), d_head=64,
        d_ff=d * 4, vocab=v, qkv_bias=True, tie_embeddings=True,
        dtype="float32", remat=False)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--params", type=int, default=100,
                    help="target size in millions (100 -> ~100M)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--compression", default="none",
                    choices=["none", "topk", "int8"])
    args = ap.parse_args()

    cfg = make_config(args.params)
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {cfg.name}  {n_params / 1e6:.1f}M params "
          f"(L={cfg.n_layers} d={cfg.d_model} v={cfg.vocab})")

    batches = synthetic_token_batches(cfg.vocab, args.batch, args.seq)
    with tempfile.TemporaryDirectory() as ckpt_dir:
        params, metrics = train(
            lambda p, b: tf.loss_fn(cfg, p, b), params, iter(batches),
            AdamWConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps),
            TrainLoopConfig(total_steps=args.steps,
                            log_every=max(args.steps // 20, 1),
                            ckpt_every=max(args.steps // 4, 1),
                            ckpt_dir=ckpt_dir),
            comp_cfg=CompressionConfig(scheme=args.compression))

    hist = metrics["history"]
    print(f"\nloss: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} "
          f"over {args.steps} steps  "
          f"(stragglers flagged: {metrics['n_stragglers']})")
    for h in hist:
        print(f"  step {h['step']:4d}  loss {h['loss']:.4f}  "
              f"{h['sec'] * 1e3:6.0f} ms/step")
    assert hist[-1]["loss"] < hist[0]["loss"], "loss must decrease"
    print("OK: loss decreased.")


if __name__ == "__main__":
    main()
