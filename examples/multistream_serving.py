"""Batched multi-stream serving: one jitted program, a fleet of tenants.

Four independent SBM graphs each stream small edge-batch deltas; the batched
driver serves all four through ONE compiled program per step (vmapped engine
rounds), then the same streams are re-served sequentially to show the
fleet-level speedup and per-stream equality.

    PYTHONPATH=src python examples/multistream_serving.py
"""

import time

import numpy as np

from repro.core.dynamic import louvain_dynamic
from repro.core.louvain import louvain, membership_modularity
from repro.core.multistream import louvain_dynamic_batched
from repro.data import sbm_holdout_stream


def make_stream(seed, n_cap=128, e_cap=4600, n_hold=32, n_steps=8, b_cap=4):
    """One tenant: an SBM graph with held-out edges streamed back in."""
    init, batches, _ = sbm_holdout_stream(
        seed, n_cap=n_cap, e_cap=e_cap, n_hold=n_hold, n_steps=n_steps,
        b_cap=b_cap)
    return init, batches


def main():
    S = 4
    cases = [make_stream(100 + s) for s in range(S)]
    graphs = [c[0] for c in cases]
    streams = [c[1] for c in cases]

    print(f"fleet: {S} tenants, {len(streams[0])} serving steps each")
    prevs = [louvain(g).membership for g in graphs]

    # Warm both paths once (compile), then time.  Neither timed call
    # tracks modularity — Q is recomputed from the results afterwards, so
    # the head-to-head is symmetric.
    louvain_dynamic_batched(graphs, streams, prevs=prevs)
    for s in range(S):
        louvain_dynamic(graphs[s], streams[s], prev=prevs[s])

    t0 = time.perf_counter()
    batched = louvain_dynamic_batched(graphs, streams, prevs=prevs)
    t_batched = time.perf_counter() - t0

    t0 = time.perf_counter()
    solo = [louvain_dynamic(graphs[s], streams[s], prev=prevs[s])
            for s in range(S)]
    t_seq = time.perf_counter() - t0

    print(f"\nbatched   : {t_batched:.3f}s for the fleet")
    print(f"sequential: {t_seq:.3f}s ({t_seq / t_batched:.2f}x slower)")
    print("\nper-stream results (batched == sequential, bit-for-bit):")
    for s in range(S):
        same = np.array_equal(batched.stream_membership(s),
                              solo[s].membership)
        q = membership_modularity(solo[s].graph, solo[s].membership)
        print(f"  tenant {s}: {batched.n_communities[s]:2d} communities, "
              f"Q = {q:.4f}, equal = {same}")


if __name__ == "__main__":
    main()
