"""FM serving example: batched CTR scoring + single-query retrieval against
a candidate set, with latency stats — the recsys arch's serve shapes at
laptop scale.

    PYTHONPATH=src python examples/fm_serving.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.fm import smoke_config
from repro.models import recsys

cfg = smoke_config()
params = recsys.init_params(cfg, jax.random.PRNGKey(0))
rng = np.random.default_rng(0)


def sample_ids(batch: int) -> jnp.ndarray:
    cols = [rng.integers(0, v, batch) for v in cfg.vocab_sizes]
    return jnp.asarray(np.stack(cols, 1), jnp.int32)


# --- batched online scoring (serve_p99 analogue) ----------------------------
serve = jax.jit(lambda ids: recsys.forward(cfg, params, ids))
ids = sample_ids(512)
serve(ids).block_until_ready()            # compile
lat = []
for _ in range(20):
    ids = sample_ids(512)
    t0 = time.perf_counter()
    serve(ids).block_until_ready()
    lat.append(time.perf_counter() - t0)
lat_ms = np.asarray(lat) * 1e3
print(f"online scoring B=512 : p50 {np.percentile(lat_ms, 50):.2f} ms  "
      f"p99 {np.percentile(lat_ms, 99):.2f} ms")

# --- bulk offline scoring (serve_bulk analogue) ------------------------------
bulk_ids = sample_ids(16384)
t0 = time.perf_counter()
scores = jax.jit(lambda i: recsys.forward(cfg, params, i))(bulk_ids)
scores.block_until_ready()
dt = time.perf_counter() - t0
print(f"bulk scoring B=16384 : {dt * 1e3:.1f} ms "
      f"({16384 / dt:,.0f} items/s)")

# --- retrieval: one user vs many candidates ---------------------------------
user = sample_ids(1)
cand = jnp.asarray(rng.integers(0, cfg.total_vocab, 100_000), jnp.int32)
retrieve = jax.jit(
    lambda u, c: recsys.retrieval_scores(cfg, params, u, c))
retrieve(user, cand).block_until_ready()
t0 = time.perf_counter()
scores = retrieve(user, cand)
top = jax.lax.top_k(scores, 10)
jax.block_until_ready(top)
dt = time.perf_counter() - t0
print(f"retrieval 1 x 100k   : {dt * 1e3:.2f} ms (single batched matvec)")
print(f"top-3 candidate rows : {np.asarray(top[1])[:3].tolist()}")
