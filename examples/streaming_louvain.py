"""Streaming community detection: warm-start Louvain over edge-batch deltas.

A community-structured graph evolves one small edge batch at a time (the
serving scenario: millions of users, graph changes continuously, membership
must stay fresh between queries).  Instead of re-running Louvain from
singletons after every change, ``louvain_dynamic``

  1. applies the batch in place of capacity (``repro.core.delta`` — no
     reallocation, every jit stays compiled),
  2. seeds the move phase with the PREVIOUS membership (naive-dynamic), and
  3. restricts the first-pass frontier to the changed edges' endpoints plus
     their communities' members (delta screening),

so each update touches a small fraction of the graph.

    PYTHONPATH=src python examples/streaming_louvain.py
"""

import numpy as np

from repro.core.delta import make_edge_batch
from repro.core.dynamic import louvain_dynamic
from repro.core.graph import build_csr
from repro.core.louvain import louvain, louvain_modularity
from repro.data import sbm_graph

# 1. The "final" graph: 32 communities of 16 vertices.  Hold out 120
#    intra-community edges and stream them back in batches of 6.
full, _truth = sbm_graph(n_communities=32, size=16, p_in=0.4, p_out=0.003,
                         seed=3)
e = int(full.e_valid)
src, dst = np.asarray(full.src)[:e], np.asarray(full.indices)[:e]
w = np.asarray(full.weights)[:e]
und = src < dst
us, ud, uw = src[und], dst[und], w[und]

rng = np.random.default_rng(0)
hold = rng.choice(len(us), 120, replace=False)
keep = np.ones(len(us), bool)
keep[hold] = False
initial = build_csr(np.concatenate([us[keep], ud[keep]]),
                    np.concatenate([ud[keep], us[keep]]),
                    np.concatenate([uw[keep], uw[keep]]),
                    int(full.n_valid), e_cap=e + 8)   # capacity for stream

batches = [make_edge_batch(us[hold[i::20]], ud[hold[i::20]],
                           uw[hold[i::20]], initial.n_cap, b_cap=8)
           for i in range(20)]

# 2. One cold run on the initial graph gives the starting membership...
cold = louvain(initial)
print(f"initial graph     : {int(initial.n_valid)} vertices, "
      f"{int(initial.e_valid)} directed edges")
print(f"cold start        : {cold.n_communities} communities, "
      f"Q = {louvain_modularity(initial, cold):.4f}")

# 3. ...then every batch is an incremental warm-started update.
dyn = louvain_dynamic(initial, batches, prev=cold.membership,
                      track_modularity=True)
print(f"\nstreamed {len(batches)} batches "
      f"({sum(s.batch_size for s in dyn.batch_stats)} edge updates) "
      f"in {dyn.total_seconds:.2f}s "
      f"({dyn.updates_per_second:.0f} updates/s)")
for i, s in enumerate(dyn.batch_stats):
    print(f"  batch {i:2d}: +{s.batch_size} edges, touched {s.n_touched:3d} "
          f"vertices, frontier {s.frontier_size:3d}/{s.n_vertices} "
          f"({100 * s.frontier_fraction:4.1f}%), "
          f"{s.n_communities} communities, Q = {s.modularity:.4f}")

# 4. Sanity: a cold recompute on the final graph agrees.
static = louvain(dyn.graph)
print(f"\nfinal dynamic     : {dyn.n_communities} communities, "
      f"Q = {dyn.batch_stats[-1].modularity:.4f}")
print(f"cold recompute    : {static.n_communities} communities, "
      f"Q = {louvain_modularity(dyn.graph, static):.4f}")
