"""Modularity (Eq. 1) and delta-modularity (Eq. 2) of GVE-Louvain.

All functions are jit-friendly and operate on the padded containers from
``graph.py``.  Community arrays have shape (n_cap + 1,) with the trailing
sentinel slot pointing at itself.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.graph import CSRGraph


def community_weights(graph: CSRGraph, comm: jax.Array) -> jax.Array:
    """Sigma_c: (n_cap + 1,) total weighted degree of each community.

    Community ids index into the same (n_cap + 1) space as vertices; the
    sentinel community accumulates only padding (= 0 weight).
    """
    k = graph.vertex_weights()  # (n_cap + 1,)
    return jax.ops.segment_sum(k[: graph.n_cap], comm[: graph.n_cap],
                               num_segments=graph.n_cap + 1)


def modularity(graph: CSRGraph, comm: jax.Array) -> jax.Array:
    """Q (Eq. 1) = sum_c [ sigma_c / 2m  - (Sigma_c / 2m)^2 ].

    ``sigma_c`` counts directed slots with both endpoints in c (undirected
    internal edges twice, self-loop slots once) — consistent with m = sum(w)/2.
    """
    m = graph.total_weight()
    c_src = comm[graph.src]
    c_dst = comm[graph.indices]
    internal = jnp.sum(jnp.where(c_src == c_dst, graph.weights, 0.0))
    sig = community_weights(graph, comm)
    # A zero-edge graph (empty, single vertex, or a deletion stream that
    # drained every edge) has m == 0; every vertex is trivially its own
    # community and Q is 0 by convention, not NaN.
    m_safe = jnp.where(m > 0, m, 1.0)
    q = internal / (2.0 * m_safe) - jnp.sum((sig / (2.0 * m_safe)) ** 2)
    return jnp.where(m > 0, q, 0.0)


def delta_modularity(
    k_i_to_c: jax.Array,
    k_i_to_d: jax.Array,
    k_i: jax.Array,
    sigma_c: jax.Array,
    sigma_d: jax.Array,
    m: jax.Array,
) -> jax.Array:
    """Eq. 2: dQ of moving vertex i from its community d to community c.

    ``sigma_d`` is the total weight of d *with i still inside*; ``sigma_c`` is
    the target community total *without* i.  ``k_i_to_*`` exclude self-loops.
    Broadcasts over any leading shape.  With m == 0 there are no edges, hence
    no move can improve anything — dQ is 0 by convention, not NaN.
    """
    m_safe = jnp.where(m > 0, m, 1.0)
    dq = ((k_i_to_c - k_i_to_d) / m_safe
          - k_i * (k_i + sigma_c - sigma_d) / (2.0 * m_safe * m_safe))
    return jnp.where(m > 0, dq, 0.0)
