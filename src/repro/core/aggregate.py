"""Aggregation phase (Algorithm 3): community coarsening into super-vertices.

The paper's opts 7+8 (parallel prefix sum + preallocated/holey CSR, per-thread
hashtable merge) are realized TPU-natively as one sort-reduce over relabeled
edge slots:

    (i, j, w)  ->  (C[i], C[j], w)  --lexsort--> groups --segment_sum--> G''

**Why sort-reduce instead of the paper's holey CSR.**  GVE-Louvain cannot
know a super-vertex's degree before merging its members' adjacency lists, so
it over-allocates each coarse row (sum of member degrees), writes into the
holes via per-thread hashtables, and lives with a "holey" CSR whose rows are
padded internally.  Under XLA, dynamic per-row hashing is hostile and padded
holes would poison every downstream ``segment_*`` with garbage slots.  The
sort-reduce reverses the order of discovery: lexsorting the relabeled slots
makes duplicate coarse edges adjacent, so one pass yields *exact* per-super-
vertex degrees and the coarse CSR is written dense — the paper's
over-estimation is unnecessary because the sort IS the merge.  The coarse
graph lands in a preallocated buffer of at most the input's capacity
(coarsening never grows |E|), giving the paper's two-buffer ping-pong; the
capacity ladder (``repro.configs.louvain_arch.resolve_coarse_capacity``)
then re-buckets it down so later passes pay coarse-graph cost.

Two interchangeable backends resolve the post-sort groups
(``LouvainConfig.agg_backend``): the XLA chain (global cumsum group ids ->
``segment_sum`` weights -> three scatters) and the fused Pallas sweep
(``repro.kernels.aggregate``, one carry-chained kernel trip over the sorted
slots).  Group keys/positions agree exactly; weight sums agree bit-for-bit
for integer-valued weights (exact float32 sums — all golden corpora) and to
float32 rounding otherwise.

**Refinement interaction.**  Under ``LouvainConfig.refine="leiden"`` the
partition handed here is the REFINED one (strictly finer than the reported
outer partition), so the coarse graph has more super-vertices than the
outer community count.  The capacity ladder keys off the refined
``n_comms`` — the finer granularity is what the next pass scans — while the
pass loop's aggregation-tolerance early stop keys off the OUTER shrink
ratio, so refinement (which always coarsens less) does not trigger a
spurious early exit.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.graph import CSRGraph


def renumber_communities(
    comm: jax.Array, n_valid: jax.Array, n_cap: int
) -> Tuple[jax.Array, jax.Array]:
    """Dense relabel of community ids to [0, n_comms); sentinel -> n_cap.

    Returns (comm_new, n_comms).  Invalid vertex slots map to the sentinel.
    """
    idx = jnp.arange(n_cap + 1)
    valid = idx < n_valid
    cs = jnp.where(valid, comm, n_cap)
    present = jnp.zeros((n_cap + 1,), jnp.int32).at[cs].set(1)
    present = present.at[n_cap].set(0)
    new_id = jnp.cumsum(present) - present  # exclusive scan
    n_comms = jnp.sum(present)
    new_id = new_id.at[n_cap].set(n_cap)  # sentinel maps to sentinel
    return jnp.where(valid, new_id[cs], n_cap), n_comms


def community_vertices_csr(
    comm: jax.Array, n_valid: jax.Array, n_cap: int
) -> Tuple[jax.Array, jax.Array]:
    """Opt. 7: vertices grouped by community via prefix sum + stable sort.

    Returns (offsets, vertex_list): offsets (n_cap + 1,) int32 exclusive scan
    of community sizes; vertex_list (n_cap,) vertex ids grouped by community
    (invalid slots at the tail).  Used by the Louvain partitioner.
    """
    idx = jnp.arange(n_cap + 1)
    valid = idx < n_valid
    cs = jnp.where(valid, comm, n_cap)[:n_cap]
    counts = jax.ops.segment_sum(
        jnp.where(valid[:n_cap], 1, 0), cs, num_segments=n_cap + 1
    )
    offsets = jnp.cumsum(counts) - counts
    order = jnp.argsort(cs, stable=True)
    return offsets.astype(jnp.int32), order.astype(jnp.int32)


def aggregate_graph(graph: CSRGraph, comm: jax.Array, n_comms: jax.Array,
                    backend: str = "sort") -> CSRGraph:
    """Algorithm 3 as sort-reduce; returns the coarse graph at equal capacity.

    ``comm`` must be renumbered (dense ids in [0, n_comms), sentinel n_cap).
    ``backend`` resolves the post-sort groups: ``"sort"`` (XLA cumsum +
    segment_sum + scatters) or ``"pallas"`` (one fused carry-chained kernel
    sweep, ``repro.kernels.aggregate``) — see the module docstring for the
    exactness contract.
    """
    n_cap, e_cap = graph.n_cap, graph.e_cap
    ci = comm[graph.src]       # padding slots -> sentinel
    cj = comm[graph.indices]
    w = graph.weights

    order = jnp.lexsort((cj, ci))
    s_ci, s_cj, s_w = ci[order], cj[order], w[order]

    if backend == "pallas":
        from repro.kernels.aggregate import coarsen_groups_pallas
        emit, gpos, g_src, g_dst, g_w = coarsen_groups_pallas(
            s_ci, s_cj, s_w, sent=n_cap)
        # One record per live group, at the same dense position the sort
        # path uses (live groups precede sentinel padding in sort order).
        pos = jnp.where(emit, gpos, e_cap)
        coarse_src = jnp.full((e_cap + 1,), n_cap, jnp.int32).at[pos].set(
            jnp.where(emit, g_src, n_cap))[:e_cap]
        coarse_dst = jnp.full((e_cap + 1,), n_cap, jnp.int32).at[pos].set(
            jnp.where(emit, g_dst, n_cap))[:e_cap]
        coarse_w = jnp.zeros((e_cap + 1,), jnp.float32).at[pos].set(
            jnp.where(emit, g_w, 0.0))[:e_cap]
    elif backend == "sort":
        prev_i = jnp.concatenate([jnp.full((1,), -1, jnp.int32), s_ci[:-1]])
        prev_j = jnp.concatenate([jnp.full((1,), -1, jnp.int32), s_cj[:-1]])
        new_group = (s_ci != prev_i) | (s_cj != prev_j)
        gid = jnp.cumsum(new_group.astype(jnp.int32)) - 1
        group_w = jax.ops.segment_sum(s_w, gid, num_segments=e_cap)

        # First slot of each group scatters the coarse edge to position gid.
        # Sentinel-src groups (padding) are redirected to a scratch slot.
        live = new_group & (s_ci != n_cap)
        pos = jnp.where(live, gid, e_cap)
        group_total = group_w[gid]  # per-slot view of its group's sum
        coarse_src = jnp.full((e_cap + 1,), n_cap, jnp.int32).at[pos].set(
            s_ci)[:e_cap]
        coarse_dst = jnp.full((e_cap + 1,), n_cap, jnp.int32).at[pos].set(
            s_cj)[:e_cap]
        coarse_w = jnp.zeros((e_cap + 1,), jnp.float32).at[pos].set(
            group_total)[:e_cap]
    else:
        raise ValueError(f"unknown aggregation backend: {backend!r}")

    live_rows = coarse_src < n_cap
    counts = jax.ops.segment_sum(
        jnp.where(live_rows, 1, 0), jnp.where(live_rows, coarse_src, n_cap),
        num_segments=n_cap + 1,
    )
    indptr = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(counts[:n_cap]).astype(jnp.int32)]
    )
    e_valid = jnp.sum(jnp.where(live_rows, 1, 0)).astype(jnp.int32)
    return CSRGraph(
        indptr=indptr,
        indices=coarse_dst,
        weights=coarse_w,
        src=coarse_src,
        n_valid=n_comms.astype(jnp.int32),
        e_valid=e_valid,
    )
