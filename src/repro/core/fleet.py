"""Multi-tenant serving fleet: shard every tenant graph AND batch tenants.

The repo's two scaling axes were separate: ``core.multistream`` vmaps many
SMALL streams over one device, ``core.distributed_dynamic`` shard_maps one
BIG stream over many devices.  This layer fuses them into the serving stack
of the ROADMAP's "millions of users" item: each tenant's graph is 1-D
vertex-partitioned across the mesh (every lane is a full sharded layout) and
tenants are *batched per dispatch* with ``jax.vmap`` OVER the shard_map'd
step, so one XLA program advances a whole capacity bucket of tenants by one
stream step — the partition-then-pipeline layout of the parallel-heuristics
literature (Lu et al.; Staudt & Meyerhenke), with JAX collectives instead of
MPI ranks.

Three pieces:

  * **Bucketed capacity fleets** — tenants are admitted into power-of-two
    ``(v_per_shard, e_per_shard, b_cap)`` envelopes via
    ``configs.louvain_arch.plan_fleet``; lanes sharing an envelope share ONE
    compiled fused step.  A whale tenant that overflows its envelope
    *migrates buckets* (``migrate_envelope``) instead of forcing a
    fleet-wide recompile: its pre-apply lane is re-bucketed host-side, the
    overflowing step is replayed solo exactly once, and the lane joins (or
    founds) the bucket of the grown envelope while its old lane is frozen.
  * **Admission/routing** — ``FleetRouter.admit`` partitions a tenant into
    its envelope layout (cold pass loop when no previous membership is
    given) and ``FleetRouter.serve`` routes per-step ``EdgeBatch``es to
    lanes, exposing per-tenant ``PassStats`` (including the host-resolved
    screening mode, see below).
  * **Pipelined stepping** — the serve loop generalizes the pass loop's
    ``pipeline_fetch``: every bucket's step ``t`` is dispatched BEFORE step
    ``t - 1``'s convergence scalars are fetched (one stacked ``device_get``
    across all buckets), so device work overlaps host control.  A lane
    whose deferred scalars violate the fused fast path is repaired and its
    bucket's speculative dispatch is replaced.

**Correctness bar** (pinned in tests + the golden matrix): per-tenant
memberships are bit-for-bit identical to running each tenant alone through
``louvain_dynamic_sharded`` on the same mesh.  The fused step IS the solo
driver's pass 0 (same apply, same screening, same warm start, same move
phase, same renumber fold); a lane is accepted only when solo would have
stopped after pass 0 (converged, low shrink, or ``max_passes == 1``) —
otherwise the full solo pass loop replays that lane from its pre-step
membership, which reproduces the fused pass 0 exactly and continues.

Screening ``"auto"`` is resolved HOST-SIDE per bucket
(``engine.resolve_screening_host``) from the previous validated dispatch's
worst touched fraction: the on-device auto select evaluates both
granularities under vmap, which silently costs the full community-expansion
bill — the downgrade the satellite bugfix makes explicit via
``PassStats.downgraded``.  Because each dispatch's fetch is deferred one
step, the measurement the resolver sees is up to TWO steps stale (step 1
dispatches before step 0 validates); the mode actually run is recorded in
the step's ``PassStats``, and replaying the recorded modes through the solo
driver reproduces the fleet bit-for-bit.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.configs.louvain_arch import (FleetEnvelope, fleet_envelope,
                                        fleet_v_per_shard, migrate_envelope,
                                        resolve_comm_backend,
                                        resolve_state_layout)
from repro.core.delta import EdgeBatch
from repro.core.distributed import (ShardedGraphSpec, _rebucket_live_host,
                                    _vertex_k, _warm_comm_sigma,
                                    make_distributed_move, make_tier_phases,
                                    measure_boundary_frac,
                                    partition_graph_host, replicated_renumber,
                                    sentinel_forced_membership,
                                    sharded_comm_plan, sharded_louvain_passes)
from repro.core.comm import phase_bytes
from repro.core.distributed_dynamic import make_sharded_batch_apply
from repro.core.engine import (affected_frontier, normalize_screening,
                               resolve_screening_host)
from repro.core.graph import CSRGraph
from repro.core.louvain import LouvainConfig, PassStats, pad_membership


def _fleet_spec(env: FleetEnvelope, n_shards: int) -> ShardedGraphSpec:
    return ShardedGraphSpec(n_shards, env.v_per_shard, env.e_per_shard,
                            env.v_per_shard * n_shards)


@functools.lru_cache(maxsize=None)
def _make_fleet_step(mesh: Mesh, axes: Tuple[str, ...],
                     spec: ShardedGraphSpec, b_cap: int,
                     screen_mode: Optional[str], tolerance: float,
                     max_iterations: int, gate_fraction: int,
                     use_pruning: bool, comm_backend: str,
                     apply_backend: str, state_layout: str = "replicated"):
    """Build the fused per-bucket step: ``jit(vmap(`` solo pass 0 ``))``.

    Lane signature (vmapped over axis 0 of every operand)::

        (src_g, dst_g, w_g, mem, n_valid, n_limit,
         b_src, b_dst, b_w, b_valid)
        -> ((src', dst', w', mem', n_valid'), frontier,
            e_max, iters, n_comms, dq_sum, rounds, fallbacks,
            touched_n, frontier_n)

    The body is EXACTLY the solo streaming step's fast path: sharded batch
    apply (traced ``n_limit`` so lanes of different logical ``n_cap`` share
    the program), delta screening at the host-resolved ``screen_mode``,
    warm-started move phase at ``tolerance`` (= pass 0's
    ``initial_tolerance``), replicated renumber, sentinel-forced
    membership.  Lanes with an empty batch (``b_valid == 0``) keep their
    state bit-for-bit via a where-select on every output.  The scalars are
    returned UNFETCHED — the serve loop defers their ``device_get`` one
    dispatch (the ``pipeline_fetch`` generalization).
    """
    n_pad, sent = spec.n_pad, spec.sentinel
    apply_fn = make_sharded_batch_apply(mesh, axes, spec, None,
                                        apply_backend, True)
    move = make_distributed_move(
        mesh, axes, spec, max_iterations=max_iterations,
        gate_fraction=gate_fraction, use_pruning=use_pruning,
        comm_backend=comm_backend, state_layout=state_layout)
    tol = jnp.float32(tolerance)

    def lane(src_g, dst_g, w_g, mem, n_valid, n_limit,
             b_src, b_dst, b_w, b_valid):
        src2, dst2, w2, touched, e_max, nv2 = apply_fn(
            src_g, dst_g, w_g, b_src, b_dst, b_w, b_valid, n_valid,
            n_limit)
        if screen_mode is not None:
            frontier = affected_frontier(touched, mem, nv2, screen_mode)
        else:
            frontier = jnp.ones((n_pad + 1,), bool)
        k = _vertex_k(w2, src2, jnp.zeros((n_pad + 1,), jnp.float32))
        m = jnp.sum(w2) * 0.5
        comm0, sigma0 = _warm_comm_sigma(mem, k, nv2)
        comm, _sigma, iters, dq_sum, rounds, fallbacks = move(
            src2, dst2, w2, comm0, sigma0, k, frontier, m, tol)
        comm_ren, n_comms = replicated_renumber(comm)
        mem2 = sentinel_forced_membership(comm_ren[:n_pad], nv2, n_pad)

        active = b_valid > 0
        sel = lambda new, old: jnp.where(active, new, old)
        state = (sel(src2, src_g), sel(dst2, dst_g), sel(w2, w_g),
                 sel(mem2, mem), sel(nv2, n_valid))
        zero = jnp.int32(0)
        frontier_n = (jnp.sum(frontier.astype(jnp.int32))
                      if screen_mode is not None else nv2)
        scalars = (sel(e_max, zero), sel(iters, zero),
                   sel(n_comms, zero), sel(dq_sum, jnp.float32(0.0)),
                   sel(rounds, zero), sel(fallbacks, zero),
                   sel(jnp.sum(touched.astype(jnp.int32)), zero),
                   sel(frontier_n, zero))
        return state, frontier, scalars

    return jax.jit(jax.vmap(lane))


@dataclasses.dataclass
class _Tenant:
    """Host-side tenant record; device state lives in envelope layout."""
    tid: str
    n_cap: int                  # logical vertex capacity (CSR n_cap)
    env: FleetEnvelope
    src: jax.Array              # (n_shards * e_per_shard,) slot arrays
    dst: jax.Array
    w: jax.Array
    mem: jax.Array              # (n_pad + 1,) replicated membership
    n_valid: int
    stats: List[PassStats] = dataclasses.field(default_factory=list)
    migrations: List[dict] = dataclasses.field(default_factory=list)
    n_fallbacks: int = 0
    #: Boundary fraction of the admitted partition — drives the per-bucket
    #: state_layout="auto" resolution (worst lane wins).
    boundary_frac: Optional[float] = None


class _Bucket:
    """One capacity envelope's stacked lanes during a serve call."""

    def __init__(self, env: FleetEnvelope, spec: ShardedGraphSpec,
                 tenants: List[_Tenant],
                 state_layout: str = "replicated"):
        self.env = env
        self.spec = spec
        self.lanes: List[_Tenant] = list(tenants)
        self.frozen: set = set()     # lane indices migrated away
        self.touched_frac: Optional[float] = None   # last validated max
        self.state_layout = state_layout   # resolved for this bucket
        self.state = (
            jnp.stack([t.src for t in self.lanes]),
            jnp.stack([t.dst for t in self.lanes]),
            jnp.stack([t.w for t in self.lanes]),
            jnp.stack([t.mem for t in self.lanes]),
            jnp.asarray([t.n_valid for t in self.lanes], jnp.int32),
        )
        self.n_lim = jnp.asarray([t.n_cap for t in self.lanes], jnp.int32)

    def append_lane(self, tenant: _Tenant, lane_state):
        """Join a migrated lane: widen every stacked array by one row."""
        self.lanes.append(tenant)
        src, dst, w, mem, nv = self.state
        s2, d2, w2, m2, nv2 = lane_state
        self.state = (
            jnp.concatenate([src, s2[None]]),
            jnp.concatenate([dst, d2[None]]),
            jnp.concatenate([w, w2[None]]),
            jnp.concatenate([mem, m2[None]]),
            jnp.concatenate([nv, jnp.asarray([nv2], jnp.int32)]),
        )
        self.n_lim = jnp.concatenate(
            [self.n_lim, jnp.asarray([tenant.n_cap], jnp.int32)])


@dataclasses.dataclass
class _Pending:
    """One bucket dispatch awaiting its deferred convergence fetch."""
    bucket: _Bucket
    t: int
    pre: tuple                  # stacked state BEFORE the dispatch
    post: tuple                 # stacked state after (speculatively kept)
    frontier: jax.Array         # (T, n_pad + 1) seed frontiers
    scalars: tuple              # (T,) device arrays, unfetched
    batches: tuple              # (bs, bd, bw, bv) np arrays as dispatched
    active: np.ndarray          # (T,) bool, b_valid > 0 at dispatch
    mode: Optional[str]         # screening mode this dispatch ran with
    downgraded: bool
    seconds: float


@dataclasses.dataclass
class FleetResult:
    """Per-tenant results of one ``FleetRouter.serve`` call."""
    membership: Dict[str, np.ndarray]
    n_communities: Dict[str, int]
    pass_stats: Dict[str, List[PassStats]]
    total_seconds: float
    n_dispatches: int = 0
    n_fallbacks: int = 0        # lanes replayed through the solo pass loop
    n_migrations: int = 0       # whale bucket migrations
    bytes_on_wire: int = 0      # plan-priced move-phase exchange bytes
    comm_rounds: int = 0
    comm_backend: str = "gather"
    #: Envelope -> tenant ids, the bucket layout at the END of the serve.
    buckets: Dict[FleetEnvelope, List[str]] = dataclasses.field(
        default_factory=dict)
    #: Per-bucket resolved working-state layout; ``state_layout`` is the
    #: fleet-level summary ("mixed" when buckets disagree under "auto").
    bucket_layouts: Dict[FleetEnvelope, str] = dataclasses.field(
        default_factory=dict)
    state_layout: str = "replicated"
    halo_bytes: int = 0         # boundary-mover share of bytes_on_wire
    #: Worst admitted boundary fraction across the served tenants.
    boundary_frac: Optional[float] = None

    @property
    def bytes_per_dispatch(self) -> float:
        return self.bytes_on_wire / max(self.n_dispatches, 1)

    @property
    def halo_bytes_per_round(self) -> float:
        return self.halo_bytes / max(self.comm_rounds, 1)


class FleetRouter:
    """Admission + routing for the multi-tenant sharded serving fleet.

    ``admit`` places each tenant in its ``plan_fleet`` envelope (one
    compiled fused step per envelope); ``serve`` advances every tenant's
    stream with one vmapped dispatch per bucket per step, deferring each
    dispatch's convergence fetch one step.  See the module docstring for
    the parity contract.

    ``screening`` accepts the usual modes; ``"auto"`` (the default) is
    resolved host-side per bucket and recorded (with its downgrade flag)
    in the per-tenant ``PassStats``.  ``config.refine`` must stay
    ``"none"``: refinement runs inside every solo pass INCLUDING pass 0,
    which the fused fast path does not reproduce.
    """

    def __init__(self, mesh: Mesh, axes: Tuple[str, ...],
                 config: LouvainConfig = LouvainConfig(), *,
                 screening="auto", apply_backend: str = "xla"):
        if config.refine != "none":
            raise ValueError("FleetRouter requires config.refine='none' "
                             "(refinement changes pass 0, which the fused "
                             "fleet step must reproduce bit-for-bit)")
        self.mesh = mesh
        self.axes = tuple(axes)
        self.config = config
        self.n_shards = int(np.prod([mesh.shape[a] for a in self.axes]))
        self.screen_req = normalize_screening(screening)
        self.comm_backend = resolve_comm_backend(config.comm_backend,
                                                 self.n_shards)
        self.apply_backend = apply_backend
        self.tenants: Dict[str, _Tenant] = {}

        # Tier factories per working-state layout: layouts resolve PER
        # BUCKET (config "auto" + each bucket's worst admitted boundary
        # fraction), and make_tier_phases is cached, so asking for both
        # layouts costs nothing until a bucket actually uses one.
        def _tiers(state_layout: str):
            return make_tier_phases(
                mesh, self.axes, max_iterations=config.max_iterations,
                gate_fraction=config.gate_fraction,
                use_pruning=config.use_pruning,
                comm_backend=self.comm_backend,
                state_layout=state_layout, refine="none")
        self._tiers = _tiers
        self._pass_kw = dict(
            max_passes=config.max_passes,
            initial_tolerance=config.initial_tolerance,
            tolerance_drop=config.tolerance_drop,
            aggregation_tolerance=config.aggregation_tolerance,
        )
        self._buckets: List[_Bucket] = []

    # -- admission ---------------------------------------------------------

    def admit(self, tid: str, graph: CSRGraph,
              prev: Optional[np.ndarray] = None,
              b_cap: int = 1) -> FleetEnvelope:
        """Admit a tenant: partition into its envelope layout and warm up.

        ``b_cap`` is the largest per-step batch capacity the tenant's
        streams will carry (rounded up to the envelope's power of two).
        ``prev=None`` runs one cold solo pass loop to produce the resident
        membership — the same machinery ``louvain_dynamic_sharded`` uses,
        so a later solo run from the same ``prev`` matches bit-for-bit.
        """
        if tid in self.tenants:
            raise ValueError(f"tenant {tid!r} already admitted")
        v_per = fleet_v_per_shard(graph.n_cap, self.n_shards)
        n_pad = v_per * self.n_shards
        # First partition measures the worst owned-edge count; the second
        # lands directly in the envelope's slot layout.
        _, _, _, spec0 = partition_graph_host(graph, self.n_shards,
                                              n_target=n_pad)
        env = fleet_envelope(graph.n_cap, spec0.e_per_shard, b_cap,
                             self.n_shards)
        spec = _fleet_spec(env, self.n_shards)
        src_g, dst_g, w_g, spec2 = partition_graph_host(
            graph, self.n_shards, n_target=n_pad,
            e_per_shard=env.e_per_shard)
        assert spec2 == spec, (spec2, spec)
        n_live = int(graph.n_valid)
        bfrac = measure_boundary_frac(src_g, dst_g, spec, n_live)
        if prev is None:
            with self.mesh:
                mem, _, _ = self._run_solo_passes(
                    spec, src_g, dst_g, w_g, n_live,
                    state_layout=resolve_state_layout(
                        self.config.state_layout, self.n_shards, bfrac))
        else:
            mem = jnp.asarray(pad_membership(
                np.asarray(prev, np.int32)[: spec.n_pad], spec.n_pad))
        self.tenants[tid] = _Tenant(tid=tid, n_cap=graph.n_cap, env=env,
                                    src=src_g, dst=dst_g, w=w_g, mem=mem,
                                    n_valid=n_live, boundary_frac=bfrac)
        return env

    def _run_solo_passes(self, spec, src_g, dst_g, w_g, n_live,
                         init_membership=None, init_frontier=None,
                         state_layout: Optional[str] = None):
        """The solo pass loop at this router's knobs — admission cold
        starts, non-converged-lane fallbacks and migration replays all go
        through here so they are the SAME computation the solo driver
        runs.  ``state_layout`` is the caller's resolved per-bucket (or
        per-admission) layout; memberships are invariant to it."""
        layout = (state_layout if state_layout is not None
                  else resolve_state_layout(self.config.state_layout,
                                            self.n_shards))
        tiers = self._tiers(layout)
        move, agg, _ = tiers(spec)
        gc, nc, pstats = sharded_louvain_passes(
            src_g, dst_g, w_g, spec, move, agg, n_live,
            init_membership=init_membership, init_frontier=init_frontier,
            phases_for=tiers, use_ladder=self.config.use_ladder,
            comm_backend=self.comm_backend, state_layout=layout,
            refine="none", reshard=self.config.reshard,
            pipeline_fetch=self.config.pipeline_fetch, **self._pass_kw)
        return sentinel_forced_membership(gc, n_live, spec.n_pad), nc, pstats

    # -- serving -----------------------------------------------------------

    def serve(self, streams: Dict[str, Sequence[EdgeBatch]]) -> FleetResult:
        """Advance every tenant's stream; one fused dispatch per bucket per
        step, convergence fetches deferred one dispatch."""
        t_start = time.perf_counter()
        for tid in streams:
            if tid not in self.tenants:
                raise ValueError(f"tenant {tid!r} not admitted")
        n_steps = max((len(s) for s in streams.values()), default=0)

        self._n_dispatches = self._n_fallbacks = self._n_migrations = 0
        self._bytes = self._rounds = self._halo = 0
        by_env: Dict[FleetEnvelope, List[_Tenant]] = {}
        for tid in streams:
            ten = self.tenants[tid]
            by_env.setdefault(ten.env, []).append(ten)
        # Layout per bucket: "auto" takes the WORST admitted boundary
        # fraction over the bucket's lanes, so hybrid engages only when
        # every cohabitant tenant is interior-dominated.
        self._buckets = [
            _Bucket(env, _fleet_spec(env, self.n_shards), tenants,
                    resolve_state_layout(
                        self.config.state_layout, self.n_shards,
                        max((t.boundary_frac for t in tenants
                             if t.boundary_frac is not None),
                            default=None)))
            for env, tenants in by_env.items()]

        with self.mesh:
            pending: Dict[int, _Pending] = {}
            for t in range(n_steps):
                fresh = {id(B): self._dispatch(B, t, streams)
                         for B in list(self._buckets)}
                if pending:
                    for B in self._validate(pending):
                        fresh[id(B)] = self._dispatch(B, t, streams)
                pending = fresh
            if pending:
                self._validate(pending)

        # Unstack bucket lanes back into tenant records.
        membership: Dict[str, np.ndarray] = {}
        n_comms: Dict[str, int] = {}
        for B in self._buckets:
            src, dst, w, mem, nv = B.state
            nv_host = np.asarray(nv)
            for i, ten in enumerate(B.lanes):
                if i in B.frozen:
                    continue
                ten.src, ten.dst, ten.w = src[i], dst[i], w[i]
                ten.mem = mem[i]
                ten.n_valid = int(nv_host[i])
                m = np.asarray(ten.mem[: ten.n_valid])
                membership[ten.tid] = m
                n_comms[ten.tid] = int(len(np.unique(m))) if len(m) else 0
        buckets_out = {B.env: [t.tid for i, t in enumerate(B.lanes)
                               if i not in B.frozen]
                       for B in self._buckets}
        layouts_out = {B.env: B.state_layout for B in self._buckets
                       if buckets_out.get(B.env)}
        layout_set = set(layouts_out.values())
        summary_layout = (layout_set.pop() if len(layout_set) == 1
                          else "mixed" if layout_set
                          else resolve_state_layout(
                              self.config.state_layout, self.n_shards))
        fracs = [self.tenants[tid].boundary_frac for tid in streams
                 if self.tenants[tid].boundary_frac is not None]
        self._buckets = []
        return FleetResult(
            membership=membership,
            n_communities=n_comms,
            pass_stats={tid: self.tenants[tid].stats for tid in streams},
            total_seconds=time.perf_counter() - t_start,
            n_dispatches=self._n_dispatches,
            n_fallbacks=self._n_fallbacks,
            n_migrations=self._n_migrations,
            bytes_on_wire=self._bytes,
            comm_rounds=self._rounds,
            comm_backend=self.comm_backend,
            buckets={env: tids for env, tids in buckets_out.items() if tids},
            bucket_layouts=layouts_out,
            state_layout=summary_layout,
            halo_bytes=self._halo,
            boundary_frac=max(fracs) if fracs else None,
        )

    def _dispatch(self, B: _Bucket, t: int, streams) -> _Pending:
        """Dispatch one bucket's step ``t``; returns without any host sync
        on the result (the convergence scalars stay on device)."""
        T = len(B.lanes)
        bc = B.env.b_cap
        sent = B.spec.sentinel
        bs = np.full((T, bc), sent, np.int32)
        bd = np.full((T, bc), sent, np.int32)
        bw = np.zeros((T, bc), np.float32)
        bv = np.zeros((T,), np.int32)
        for i, ten in enumerate(B.lanes):
            if i in B.frozen:
                continue
            st = streams.get(ten.tid, ())
            if t < len(st):
                b = st[t]
                if b.b_cap > bc:
                    raise ValueError(
                        f"tenant {ten.tid!r} batch b_cap={b.b_cap} exceeds "
                        f"its admitted envelope b_cap={bc}")
                bs[i, : b.b_cap] = np.asarray(b.src)
                bd[i, : b.b_cap] = np.asarray(b.dst)
                bw[i, : b.b_cap] = np.asarray(b.weight)
                bv[i] = int(b.b_valid)
        mode, downgraded = resolve_screening_host(self.screen_req,
                                                  B.touched_frac)
        cfg = self.config
        fused = _make_fleet_step(
            self.mesh, self.axes, B.spec, bc, mode,
            float(cfg.initial_tolerance), cfg.max_iterations,
            cfg.gate_fraction, cfg.use_pruning, self.comm_backend,
            self.apply_backend, B.state_layout)
        t0 = time.perf_counter()
        pre = B.state
        state, frontier, scalars = fused(
            *pre, B.n_lim, jnp.asarray(bs), jnp.asarray(bd),
            jnp.asarray(bw), jnp.asarray(bv))
        B.state = state
        self._n_dispatches += 1
        return _Pending(bucket=B, t=t, pre=pre, post=state,
                        frontier=frontier, scalars=scalars,
                        batches=(bs, bd, bw, bv), active=bv > 0, mode=mode,
                        downgraded=downgraded,
                        seconds=time.perf_counter() - t0)

    def _validate(self, pending: Dict[int, _Pending]) -> List[_Bucket]:
        """Fetch + check the deferred scalars of every pending dispatch.

        ONE stacked ``device_get`` across all buckets (the deferred
        convergence fetch).  Returns the buckets whose post-step state
        changed (fallback repairs, migration joins) and therefore need
        their speculative next-step dispatch replaced.
        """
        plist = list(pending.values())
        fetched = jax.device_get([(p.scalars, p.post[4]) for p in plist])
        redo: List[_Bucket] = []
        migrations = []
        for p, (sc, nv_post) in zip(plist, fetched):
            B = p.bucket
            spec = B.spec
            e_max, iters, n_comms, dq_sum, rounds, fallbacks, touched_n, \
                frontier_n = sc
            active = [i for i in range(len(p.active))
                      if p.active[i] and i not in B.frozen]
            if not active:
                continue
            # Comm accounting: the batched collectives ship EVERY lane's
            # payload for the max rounds any lane ran (converged lanes ride
            # along) — price the true wire cost, not the per-lane solo sum.
            plan = sharded_comm_plan(spec, self.comm_backend,
                                     B.state_layout)
            r_exec = max(int(rounds[i]) for i in active)
            fb_exec = max(int(fallbacks[i]) for i in active)
            self._bytes += len(B.lanes) * phase_bytes(plan, r_exec, fb_exec)
            self._halo += len(B.lanes) * plan.halo_round_bytes * r_exec
            self._rounds += r_exec
            # Worst touched fraction over the bucket: drives the NEXT
            # dispatch's host-side "auto" screening resolution.
            B.touched_frac = max(
                int(touched_n[i]) / max(int(nv_post[i]), 1) for i in active)

            patched = None
            agg_tol = self.config.aggregation_tolerance
            max_passes = self.config.max_passes
            for i in active:
                ten = B.lanes[i]
                nv_i = int(nv_post[i])
                overflow = int(e_max[i]) > spec.e_per_shard
                accepted = (not overflow) and (
                    int(iters[i]) <= 1
                    or int(n_comms[i]) / max(nv_i, 1) > agg_tol
                    or max_passes <= 1)
                stat = PassStats(
                    iterations=int(iters[i]),
                    n_communities=int(n_comms[i]),
                    n_vertices=nv_i,
                    dq_sum=float(dq_sum[i]),
                    seconds=p.seconds,
                    phase_seconds={},
                    frontier_size=int(frontier_n[i]),
                    n_cap=spec.n_pad, e_cap=spec.e_per_shard * spec.n_shards,
                    screening=p.mode, scan_backend="sharded",
                    downgraded=p.downgraded)
                if overflow:
                    migrations.append((p, i, int(e_max[i])))
                    continue
                if accepted:
                    ten.stats.append(stat)
                    continue
                # Fused pass 0 is not where solo stops: replay this lane
                # through the full solo pass loop from its PRE-step
                # membership (it reproduces the fused pass 0 bit-for-bit
                # and continues through aggregation).
                if patched is None:
                    patched = list(p.post)
                frontier_i = (p.frontier[i] if p.mode is not None else None)
                mem_i, nc_i, pstats = self._run_solo_passes(
                    spec, p.post[0][i], p.post[1][i], p.post[2][i], nv_i,
                    init_membership=p.pre[3][i], init_frontier=frontier_i,
                    state_layout=B.state_layout)
                patched[3] = patched[3].at[i].set(mem_i)
                ten.n_fallbacks += 1
                self._n_fallbacks += 1
                self._rounds += sum(r["comm_rounds"] for r in pstats[1:])
                self._bytes += sum(r["comm_bytes"] for r in pstats[1:])
                self._halo += sum(r.get("halo_bytes", 0)
                                  for r in pstats[1:])
                stat = dataclasses.replace(
                    stat, iterations=sum(r["iterations"] for r in pstats),
                    n_communities=nc_i)
                ten.stats.append(stat)
            if patched is not None:
                # B.state currently holds the NEXT step's speculative
                # result — discard it; the caller redispatches from the
                # repaired post-step state.  p.post is updated too so a
                # migration joining this bucket sees the repaired base.
                p.post = tuple(patched)
                B.state = p.post
                redo.append(B)
        for p, i, e_need in migrations:
            dest = self._migrate(p, i, e_need, pending)
            if dest is not None and dest not in redo:
                redo.append(dest)
        return redo

    def _migrate(self, p: _Pending, lane: int, e_need: int,
                 pending) -> Optional[_Bucket]:
        """Whale migration: re-bucket the lane's PRE-apply state into the
        grown envelope, replay the overflowing step solo EXACTLY ONCE, and
        join the destination bucket.  The source lane is frozen (its
        speculative garbage is never read), so cohabitant tenants keep
        their compiled program and their speculative next step.
        """
        B = p.bucket
        ten = B.lanes[lane]
        env = migrate_envelope(ten.env, e_need)
        spec_new = _fleet_spec(env, self.n_shards)
        src, dst, w, spec_got = _rebucket_live_host(
            p.pre[0][lane], p.pre[1][lane], p.pre[2][lane],
            B.spec.sentinel, spec_new)
        if spec_got != spec_new:      # pathological skew grew further
            spec_new = spec_got
            env = env._replace(e_per_shard=spec_got.e_per_shard)
        mem_pre = p.pre[3][lane]
        nv_pre = jnp.asarray(np.asarray(p.pre[4][lane]), jnp.int32)
        bs, bd, bw, bv = p.batches

        apply_fn = make_sharded_batch_apply(self.mesh, self.axes, spec_new,
                                            ten.n_cap, self.apply_backend)
        while True:
            out = apply_fn(src, dst, w, jnp.asarray(bs[lane]),
                           jnp.asarray(bd[lane]), jnp.asarray(bw[lane]),
                           jnp.asarray(bv[lane]), nv_pre)
            if int(out[4]) <= spec_new.e_per_shard:
                break
            env = migrate_envelope(env, int(out[4]))
            spec_new = _fleet_spec(env, self.n_shards)
            src, dst, w, _ = _rebucket_live_host(src, dst, w,
                                                 spec_new.sentinel, spec_new)
            apply_fn = make_sharded_batch_apply(self.mesh, self.axes,
                                                spec_new, ten.n_cap,
                                                self.apply_backend)
        src2, dst2, w2, touched, _, nv2 = out
        frontier = (affected_frontier(touched, mem_pre, nv2, p.mode)
                    if p.mode is not None else None)
        n_live = int(nv2)
        mem2, nc, pstats = self._run_solo_passes(
            spec_new, src2, dst2, w2, n_live,
            init_membership=mem_pre, init_frontier=frontier,
            state_layout=B.state_layout)
        self._rounds += sum(r["comm_rounds"] for r in pstats)
        self._bytes += sum(r["comm_bytes"] for r in pstats)
        self._halo += sum(r.get("halo_bytes", 0) for r in pstats)
        ten.stats.append(PassStats(
            iterations=sum(r["iterations"] for r in pstats),
            n_communities=nc, n_vertices=n_live,
            dq_sum=sum(r["dq_sum"] for r in pstats),
            seconds=0.0, phase_seconds={},
            frontier_size=int(np.asarray(jnp.sum(frontier)))
            if frontier is not None else n_live,
            n_cap=spec_new.n_pad,
            e_cap=spec_new.e_per_shard * spec_new.n_shards,
            screening=p.mode, scan_backend="sharded",
            downgraded=p.downgraded))
        ten.env = env
        ten.migrations.append(dict(step=p.t, e_need=e_need,
                                   e_per_shard=env.e_per_shard))
        self._n_migrations += 1
        B.frozen.add(lane)

        lane_state = (src2, dst2, w2, mem2, n_live)
        for dest in self._buckets:
            if dest is not B and dest.env == env:
                # Join at the destination's VALIDATED post-step state.  If
                # dest dispatched this step too, its resident state is the
                # NEXT step's speculative result — rewind to its pending
                # entry's post (already repaired if it had fallbacks); the
                # caller redispatches dest with the extra lane.
                dp = pending.get(id(dest))
                if dp is not None:
                    dest.state = dp.post
                dest.append_lane(ten, lane_state)
                return dest
        dest = _Bucket.__new__(_Bucket)
        dest.env = env
        dest.spec = spec_new
        dest.lanes = [ten]
        dest.frozen = set()
        dest.touched_frac = B.touched_frac
        dest.state_layout = B.state_layout
        dest.state = (jnp.stack([src2]), jnp.stack([dst2]),
                      jnp.stack([w2]), jnp.stack([mem2]),
                      jnp.asarray([n_live], jnp.int32))
        dest.n_lim = jnp.asarray([ten.n_cap], jnp.int32)
        self._buckets.append(dest)
        return dest


def serve_fleet(graphs: Dict[str, CSRGraph],
                streams: Dict[str, Sequence[EdgeBatch]],
                mesh: Mesh, axes: Tuple[str, ...],
                prevs: Optional[Dict[str, np.ndarray]] = None,
                config: LouvainConfig = LouvainConfig(), *,
                screening="auto", apply_backend: str = "xla") -> FleetResult:
    """One-shot convenience: admit every tenant, serve every stream.

    ``prevs`` maps tenant id -> previous membership (tenants absent from it
    get a cold solo pass loop at admission).  Batch capacity per tenant is
    taken from the largest batch in its stream.
    """
    router = FleetRouter(mesh, axes, config, screening=screening,
                         apply_backend=apply_backend)
    prevs = prevs or {}
    for tid, graph in graphs.items():
        b_cap = max((b.b_cap for b in streams.get(tid, ())), default=1)
        router.admit(tid, graph, prev=prevs.get(tid), b_cap=b_cap)
    return router.serve(streams)
