"""GVE-Louvain main loop (Algorithm 1) — passes of local-moving + aggregation.

The pass loop runs on the host (graph capacities are static, so every phase is
jit-compiled exactly once and reused across passes — the JAX realization of
the paper's preallocated ping-pong buffers).  All paper parameters are exposed
with the paper's defaults:

    MAX_PASSES=10, MAX_ITERATIONS=20, initial tolerance 0.01,
    TOLERANCE_DROP=10, aggregation tolerance 0.8, vertex pruning on.

The move phase accepts an arbitrary initial membership + community-weight
snapshot (plus an optional seed frontier), which is what the dynamic
warm-start driver in ``repro.core.dynamic`` builds on: ``louvain()`` with
``init_membership=`` resumes from a previous partition instead of the
singleton start, and ``init_frontier=`` restricts the first pass to a
delta-screened vertex set.  All jit signatures stay static — warm and cold
starts share one compiled ``_move_phase``.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.louvain_arch import (COMPACT_WORK_FRAC, compact_work_cap,
                                        resolve_agg_backend,
                                        resolve_coarse_capacity,
                                        resolve_scan_backend)
from repro.core.aggregate import aggregate_graph, renumber_communities
from repro.core.engine import affected_frontier
from repro.core.graph import CSRGraph, count_trace, rebucket_capacity
from repro.core.local_move import louvain_move
from repro.core.modularity import community_weights, modularity


@dataclasses.dataclass(frozen=True)
class LouvainConfig:
    """Paper §4.1 parameter set (defaults = paper's chosen values)."""

    max_passes: int = 10
    max_iterations: int = 20          # opt. 4.1.2
    initial_tolerance: float = 0.01   # opt. 4.1.4
    tolerance_drop: float = 10.0      # opt. 4.1.3 (threshold scaling)
    aggregation_tolerance: float = 0.8  # opt. 4.1.5
    use_pruning: bool = True          # opt. 4.1.6
    gate_fraction: int = 2            # stochastic round gating (see local_move)
    use_ell_kernel: bool = False      # Pallas scan kernel for the move phase
    ell_widths: tuple = (16, 64, 256)
    track_modularity: bool = False    # record Q after every pass (debugging)
    #: Scanner backend for the move phase (configs.louvain_arch policy):
    #: "auto" (frontier-compacted sort-reduce when a small seed frontier is
    #: active; the fused kernel on the ELL family), "full", "compact",
    #: "ell", "ell_fused".  All backends are bit-identical in results —
    #: this knob trades work, never memberships.
    scan_backend: str = "auto"
    #: Compact work-buffer capacity as a fraction of e_cap (default: the
    #: configs.louvain_arch.COMPACT_WORK_FRAC policy — ONE home).
    compact_cap_frac: float = COMPACT_WORK_FRAC
    #: Aggregation backend ("sort" | "pallas" | "auto"): the XLA
    #: lexsort -> segment_sum -> scatter chain, or the fused Pallas
    #: group-detect + accumulate + emit kernel (repro.kernels.aggregate).
    #: Bit-identical memberships across backends — policy in
    #: configs.louvain_arch.resolve_agg_backend.
    agg_backend: str = "auto"
    #: Coarse-pass capacity ladder: after aggregation, re-bucket the coarse
    #: graph down to the smallest power-of-two tier fitting (n_comms,
    #: e_valid), so later passes' scans/renumbers/sorts run at coarse
    #: capacity instead of the original e_cap.  Memberships are invariant
    #: to capacity, so this trades work, never results (pinned bit-for-bit
    #: in tests/test_engine_equiv.py).  Tier policy:
    #: configs.louvain_arch.resolve_coarse_capacity.
    use_ladder: bool = True
    #: Sharded per-round exchange backend ("gather" | "delta" | "auto"):
    #: dense Vite-style all_gather/psum of the whole replicated state, or
    #: compacted bit-packed owned CHANGES with a measured-overflow dense
    #: fallback (repro.core.distributed.DeltaShardedScanner).  "auto"
    #: resolves per mesh (delta on multi-shard meshes).  Single-device
    #: drivers ignore it; memberships are invariant to it (pinned
    #: bit-for-bit in tests/test_engine_equiv.py).  Policy + caps:
    #: configs.louvain_arch.resolve_comm_backend / delta_move_cap.
    comm_backend: str = "auto"
    #: Leiden-style refinement ("none" | "leiden"): after each local-moving
    #: phase, re-seed vertices as singletons and run a CONSTRAINED engine
    #: sweep (moves only within the outer community, singleton movers only
    #: — ``engine.ConstrainedScanner``), then aggregate the REFINED
    #: partition while the reported membership / warm start stay at the
    #: outer partition.  Fixes Louvain's badly-connected-community
    #: pathology: every refined community is connected by construction,
    #: so aggregation never glues disconnected pieces into one coarse
    #: vertex.  All scanner/agg/comm backends inherit the constrained
    #: sweep through the one wrapper — pinned bit-for-bit in
    #: tests/test_engine_equiv.py.
    refine: str = "none"
    #: Skew-aware coarse re-sharding on the sharded paths ("none" |
    #: "auto"): after each aggregation, measure per-coarse-vertex edge
    #: load and, past configs.louvain_arch.RESHARD_IMBALANCE_THRESHOLD,
    #: relabel the coarse ids onto contiguous load-balanced owner ranges
    #: instead of inheriting the seed owner map (policy:
    #: configs.louvain_arch.plan_reshard).  A no-op on one shard and on
    #: balanced graphs; single-device drivers ignore it.  Default "none"
    #: keeps every committed golden's layout history bit-for-bit.
    reshard: str = "none"
    #: Pipeline the sharded pass loop's host convergence fetch: dispatch
    #: the next aggregation speculatively before reading this pass's
    #: convergence scalars, overlapping device work with host control.
    #: Dispatch order only — memberships are identical (pinned in
    #: tests/test_engine_equiv.py); single-device drivers ignore it.
    pipeline_fetch: bool = False
    #: Sharded working-state placement ("replicated" | "hybrid" | "auto"):
    #: replicated keeps the full (n_pad + 1,) membership/Sigma/sizes on
    #: every shard; hybrid keeps per-vertex state OWNER-PARTITIONED and
    #: exchanges only boundary-mover labels + touched-community deltas
    #: per round (repro.core.distributed.HybridShardedScanner), with one
    #: membership resync per phase.  "auto" measures the partitioned
    #: layout's boundary fraction and engages hybrid below the
    #: configs.louvain_arch.HYBRID_BOUNDARY_FRAC_MAX threshold on
    #: multi-shard meshes.  Single-device drivers ignore it; memberships
    #: are invariant to it (pinned bit-for-bit in
    #: tests/test_engine_equiv.py).  Default "replicated" keeps every
    #: committed golden/bench artifact's comm history bit-for-bit.
    #: Policy: configs.louvain_arch.resolve_state_layout.
    state_layout: str = "replicated"


@dataclasses.dataclass
class PassStats:
    iterations: int
    n_communities: int
    n_vertices: int
    dq_sum: float
    seconds: float
    phase_seconds: dict
    modularity: Optional[float] = None
    frontier_size: Optional[int] = None  # seed-frontier size (delta screening)
    n_cap: Optional[int] = None          # capacities the pass ran at
    e_cap: Optional[int] = None          # (ladder tier when use_ladder)
    refine_iterations: Optional[int] = None  # constrained-sweep iterations
    n_refined: Optional[int] = None      # refined (aggregation) communities
    #: Screening granularity the step actually ran with ("community" |
    #: "vertex" | "auto" | None) — batched/fleet drivers resolve "auto"
    #: host-side and record the concrete choice here.
    screening: Optional[str] = None
    #: Scanner backend the step actually ran with ("full" | "compact" |
    #: "sharded") — the batched driver cannot honor scan_backend="auto"
    #: under vmap and records the resolved backend here.
    scan_backend: Optional[str] = None
    #: True when a requested "auto" knob could not be honored as such and
    #: was downgraded to a safe concrete choice (the explicit record the
    #: batched drivers emit instead of silently staying on the full path).
    downgraded: Optional[bool] = None


@dataclasses.dataclass
class LouvainResult:
    membership: np.ndarray       # (n,) community id per original vertex
    n_communities: int
    passes: List[PassStats]
    total_seconds: float
    #: Per-level memberships of the dendrogram: ``levels[p]`` is the (n,)
    #: membership of the ORIGINAL vertices after pass p (the fold of every
    #: renumbered pass partition up to p); ``levels[-1] == membership``.
    #: With ``refine="none"`` each level is a coarsening of the previous
    #: one (nested dendrogram); with ``refine="leiden"`` the levels hold
    #: the OUTER partitions (what the pass reports) while aggregation
    #: follows the refined chain, so consecutive levels need not nest —
    #: only the refined fold chain does.
    levels: List[np.ndarray] = dataclasses.field(default_factory=list)

    @property
    def n_passes(self) -> int:
        return len(self.passes)


def pad_membership(mem, n_cap: int) -> np.ndarray:
    """Pad a flat (n,) membership to the (n_cap + 1,) sentinel layout shared
    by the warm-start paths (single-device and sharded)."""
    out = np.full(n_cap + 1, n_cap, np.int32)
    mem = np.asarray(mem, np.int32)
    out[: len(mem)] = mem
    return out


def screened_frontier(touched: jax.Array, membership: jax.Array,
                      n_valid: jax.Array, mode: str = "community") -> jax.Array:
    """Delta-screened seed frontier from a touched-vertex mask.

    (cap + 1,) bool; works for both the single-device capacity layout
    (cap = n_cap) and the replicated sharded layout (cap = n_pad).  Thin
    alias of the engine-level ``repro.core.engine.affected_frontier`` —
    ``mode="community"`` (default) expands to whole affected communities,
    ``mode="vertex"`` is the DF-Louvain-style per-vertex flag set.
    """
    return affected_frontier(touched, membership, n_valid, mode)


@jax.jit
def singleton_init(graph: CSRGraph):
    """(comm0, sigma0, frontier0) of the cold singleton start."""
    n_cap = graph.n_cap
    comm0 = jnp.arange(n_cap + 1, dtype=jnp.int32)
    sigma0 = graph.vertex_weights()   # every vertex its own community
    frontier0 = jnp.arange(n_cap + 1) < graph.n_valid
    return comm0, sigma0, frontier0


@jax.jit
def warm_init(graph: CSRGraph, membership: jax.Array,
              frontier: jax.Array | None = None):
    """(comm0, sigma0, frontier0) resuming from ``membership``.

    ``membership`` is (n_cap,) or (n_cap + 1,) int32 community ids in vertex-id
    space (what ``LouvainResult.membership`` holds, padded to capacity);
    invalid vertex slots are remapped to the sentinel, and valid vertices
    WITHOUT a previous assignment (id >= n_cap — e.g. vertices that entered
    via an edge insert) fall back to their own singleton.  ``sigma0`` is
    recomputed from the CURRENT graph weights, so a warm start stays exact
    after edge-batch updates.  ``frontier`` optionally seeds delta screening.
    """
    n_cap = graph.n_cap
    idx = jnp.arange(n_cap + 1)
    valid = idx < graph.n_valid
    mem = jnp.concatenate([
        membership[:n_cap].astype(jnp.int32),
        jnp.full((1,), n_cap, jnp.int32),
    ])
    assigned = jnp.where(mem < n_cap, mem, idx.astype(jnp.int32))
    comm0 = jnp.where(valid, assigned, n_cap)
    sigma0 = community_weights(graph, comm0)
    frontier0 = valid if frontier is None else (frontier[: n_cap + 1] & valid)
    return comm0, sigma0, frontier0


@functools.partial(jax.jit, static_argnames=("max_iterations", "use_pruning",
                                             "gate_fraction", "work_cap"))
def _move_phase(graph: CSRGraph, comm0, sigma0, frontier0, tolerance, *,
                max_iterations: int, use_pruning: bool,
                gate_fraction: int = 2, work_cap: int = 0):
    """One local-moving phase from an arbitrary (C, Sigma, frontier) start.

    ``work_cap > 0`` runs the frontier-compacted scanner with that static
    work-buffer capacity (bit-identical results, frontier-proportional
    work); 0 is the full e_cap scan.
    """
    count_trace("move_phase")
    k = graph.vertex_weights()
    m = graph.total_weight()
    st = louvain_move(
        graph, comm0, sigma0, k, m,
        tolerance=tolerance, max_iterations=max_iterations,
        use_pruning=use_pruning, gate_fraction=gate_fraction,
        frontier0=frontier0, work_cap=work_cap,
    )
    return st.comm, st.iters, st.dq_sum


@functools.partial(jax.jit, static_argnames=("max_iterations", "use_pruning",
                                             "gate_fraction"))
def _refine_phase(graph: CSRGraph, outer, tolerance, *,
                  max_iterations: int, use_pruning: bool,
                  gate_fraction: int = 2):
    """Leiden refinement sweep: singletons under the outer-community constraint.

    Re-seeds every vertex as its own community and runs the CONSTRAINED
    engine sweep (``local_move.louvain_move(refine_outer=...)``): cross-outer
    edges are masked out of the candidate topology and only still-singleton
    vertices may move, so the result is a partition that (a) refines
    ``outer`` and (b) contains only CONNECTED communities.  ``k``/``m`` are
    the full graph's — the constraint restricts candidates, not the
    objective.
    """
    count_trace("refine_phase")
    k = graph.vertex_weights()
    m = graph.total_weight()
    n_cap = graph.n_cap
    comm0 = jnp.arange(n_cap + 1, dtype=jnp.int32)
    frontier0 = jnp.arange(n_cap + 1) < graph.n_valid
    st = louvain_move(
        graph, comm0, k, k, m,
        tolerance=tolerance, max_iterations=max_iterations,
        use_pruning=use_pruning, gate_fraction=gate_fraction,
        frontier0=frontier0, refine_outer=outer,
    )
    return st.comm, st.iters, st.dq_sum


@jax.jit
def _leiden_warm_membership(comm_ren, outer_ren, n_valid, n_agg):
    """Next-pass warm start after aggregating the REFINED partition.

    The coarse graph's vertices are the refined communities; the next pass
    must start from the OUTER partition expressed on them (Leiden's pass
    semantics — Q of the warm start equals Q of the reported outer
    partition).  For each live coarse vertex r (< ``n_agg``) the outer
    label is constant over its members, so a scatter of ``outer_ren``
    through ``comm_ren`` is well defined; the returned membership labels
    each coarse vertex with the SMALLEST coarse id sharing its outer
    community (labels must live in coarse vertex-id space).

    ``n_valid`` is the scalar live count for dense-prefix layouts or a
    ``(cap + 1,)`` bool live mask for gappy (skew-resharded) sharded
    layouts.
    """
    cap = comm_ren.shape[0] - 1
    idx = jnp.arange(cap + 1, dtype=jnp.int32)
    nv = jnp.asarray(n_valid)
    valid = (nv & (idx < cap)) if nv.ndim else (idx < nv)
    tgt = jnp.where(valid, jnp.minimum(comm_ren, cap), cap)
    oc = jnp.full((cap + 1,), cap, jnp.int32).at[tgt].set(
        jnp.where(valid, outer_ren.astype(jnp.int32), cap))
    live = idx < n_agg
    oc = jnp.where(live, jnp.minimum(oc, cap), cap)
    rep = jax.ops.segment_min(jnp.where(live, idx, cap), oc,
                              num_segments=cap + 1)
    rep = jnp.minimum(rep, cap)
    return jnp.where(live, rep[oc], cap).astype(jnp.int32)


@jax.jit
def _renumber_and_fold(comm, n_valid, n_cap_arr, global_comm):
    """Renumber pass-level communities and fold into the dendrogram lookup.

    ``comm`` may live at a laddered (shrunk) capacity while ``global_comm``
    stays at the ORIGINAL vertex capacity; invalid original slots carry
    stale sentinel values that clamp on the gather — they are sliced off
    before the membership is returned.
    """
    n_cap = global_comm.shape[0]  # == original n_cap (static via shape)
    del n_cap_arr
    count_trace("renumber_and_fold")
    comm_new, n_comms = renumber_communities(comm, n_valid, comm.shape[0] - 1)
    folded = comm_new[global_comm]
    return comm_new, n_comms, folded


@functools.partial(jax.jit, static_argnames=("backend",))
def _aggregate_phase(graph: CSRGraph, comm_renumbered, n_comms,
                     backend: str = "sort"):
    count_trace("aggregate_phase")
    return aggregate_graph(graph, comm_renumbered, n_comms, backend=backend)


def louvain(
    graph: CSRGraph,
    config: LouvainConfig = LouvainConfig(),
    *,
    init_membership: Optional[np.ndarray] = None,
    init_frontier: Optional[np.ndarray] = None,
) -> LouvainResult:
    """Run GVE-Louvain; returns the flat membership for the original vertices.

    ``init_membership`` warm-starts the FIRST pass from a previous partition
    ((n,), (n_cap,) or (n_cap + 1,) community ids) instead of singletons;
    ``init_frontier`` restricts that pass's seed frontier to a boolean
    vertex mask (delta screening — see ``repro.core.dynamic``), with or
    without a warm membership.  Later passes (after aggregation) always
    restart from singletons on the coarse graph, as in static Louvain.

    ``config.scan_backend`` picks the move-phase scanner per pass
    (``configs.louvain_arch.resolve_scan_backend``): with an active seed
    frontier the compacted sort-reduce scanner makes scan work proportional
    to |F| instead of e_cap; on the ELL family the fused Pallas kernel makes
    the whole round one kernel trip.  Memberships are bit-identical across
    backends.

    With ``config.use_ladder`` (the default), every aggregation is followed
    by a capacity re-bucket down to the smallest power-of-two tier that
    fits the coarse graph (``resolve_coarse_capacity``), so later passes'
    scans, renumbering and sorts run at coarse capacity; per-tier phases
    are jit-cached by shape, bounding recompiles at log2(e_cap) per phase.
    ``config.agg_backend`` picks the aggregation implementation (the XLA
    sort-reduce chain or the fused Pallas kernel) — memberships are
    bit-identical across ladder tiers and aggregation backends.
    """
    t_start = time.perf_counter()
    n_cap = graph.n_cap
    n = int(graph.n_valid)
    global_comm = jnp.arange(n_cap, dtype=jnp.int32)

    g = graph
    tol = float(config.initial_tolerance)
    passes: List[PassStats] = []
    n_comms_final = n
    agg_backend = resolve_agg_backend(config.agg_backend)
    if config.refine not in ("none", "leiden"):
        raise ValueError(f"refine must be 'none' or 'leiden', "
                         f"got {config.refine!r}")
    refine_on = config.refine == "leiden"
    levels: List[np.ndarray] = []
    leiden_warm = None   # outer-on-coarse membership for the next pass

    ell_family = (config.use_ell_kernel
                  or config.scan_backend in ("ell", "ell_fused"))
    if ell_family:
        from repro.core import ell_move  # lazy: pulls in Pallas

    warm_comm0 = warm_sigma0 = warm_frontier0 = None
    frontier_size0 = None
    fr = None
    if init_frontier is not None:
        # jnp-native: device-resident frontiers (delta screening) stay on
        # device — no host round-trip between batch apply and warm start.
        fr = jnp.asarray(init_frontier).astype(bool)
        if fr.shape[0] < n_cap + 1:
            fr = jnp.concatenate(
                [fr, jnp.zeros(n_cap + 1 - fr.shape[0], bool)])
    if init_membership is not None:
        mem = np.asarray(init_membership, dtype=np.int32)
        if len(mem) < n_cap + 1:   # pad (n,) / (n_cap,) inputs to capacity
            mem = np.concatenate(
                [mem, np.full(n_cap + 1 - len(mem), n_cap, np.int32)])
        warm_comm0, warm_sigma0, warm_frontier0 = warm_init(
            g, jnp.asarray(mem), fr)
        frontier_size0 = int(jnp.sum(warm_frontier0))
    elif fr is not None:
        # Screened frontier over a cold singleton start: still honored.
        warm_comm0, warm_sigma0, frontier0_all = singleton_init(g)
        warm_frontier0 = fr & frontier0_all
        frontier_size0 = int(jnp.sum(warm_frontier0))

    for p in range(config.max_passes):
        t0 = time.perf_counter()
        if p == 0 and warm_comm0 is not None:
            comm0, sigma0, frontier0 = warm_comm0, warm_sigma0, warm_frontier0
            pass_frontier = frontier_size0
        elif leiden_warm is not None:
            # Leiden pass semantics: the coarse graph's vertices are the
            # REFINED communities, so the next pass resumes from the outer
            # partition expressed on them (Q matches the reported outer Q).
            comm0, sigma0, frontier0 = warm_init(g, jnp.asarray(leiden_warm))
            pass_frontier = None
        else:
            comm0, sigma0, frontier0 = singleton_init(g)
            pass_frontier = None
        # A *screened* frontier is active only on pass 0 with init_frontier;
        # warm-only starts re-scan all vertices, so compaction buys nothing.
        frontier_frac = (frontier_size0 / max(n, 1)
                         if p == 0 and fr is not None else None)
        backend = resolve_scan_backend(
            config.scan_backend, use_ell_kernel=config.use_ell_kernel,
            frontier_frac=frontier_frac)
        if ell_family:
            comm, iters, dq_sum = ell_move.move_phase_ell(
                g, jnp.float32(tol), max_iterations=config.max_iterations,
                use_pruning=config.use_pruning,
                gate_fraction=config.gate_fraction, widths=config.ell_widths,
                comm0=comm0, sigma0=sigma0, frontier0=frontier0,
                fused=backend == "ell_fused")
        else:
            comm, iters, dq_sum = _move_phase(
                g, comm0, sigma0, frontier0, jnp.float32(tol),
                max_iterations=config.max_iterations,
                use_pruning=config.use_pruning,
                gate_fraction=config.gate_fraction,
                work_cap=(compact_work_cap(g.e_cap, config.compact_cap_frac)
                          if backend == "compact" else 0))
        iters = int(iters)
        t1a = time.perf_counter()

        refine_iters = None
        outer_ren = None
        if refine_on:
            if ell_family:
                refined, r_it, _r_dq = ell_move.move_phase_ell(
                    g, jnp.float32(tol),
                    max_iterations=config.max_iterations,
                    use_pruning=config.use_pruning,
                    gate_fraction=config.gate_fraction,
                    widths=config.ell_widths,
                    fused=backend == "ell_fused", refine_outer=comm)
            else:
                refined, r_it, _r_dq = _refine_phase(
                    g, comm, jnp.float32(tol),
                    max_iterations=config.max_iterations,
                    use_pruning=config.use_pruning,
                    gate_fraction=config.gate_fraction)
            refine_iters = int(r_it)
        t1 = time.perf_counter()

        if refine_on:
            # Two folds off the SAME pre-pass global_comm: the outer fold is
            # what this pass reports, the refined fold is what aggregation
            # (and the dendrogram chain) follows.
            outer_ren, n_outer, outer_fold = _renumber_and_fold(
                comm, g.n_valid, jnp.int32(g.n_cap), global_comm)
            comm_ren, n_comms, folded = _renumber_and_fold(
                refined, g.n_valid, jnp.int32(g.n_cap), global_comm)
            level = outer_fold
            n_report = int(n_outer)
        else:
            comm_ren, n_comms, folded = _renumber_and_fold(
                comm, g.n_valid, jnp.int32(g.n_cap), global_comm)
            level = folded
            n_report = int(n_comms)
        global_comm = folded
        n_comms_i = int(n_comms)        # aggregation granularity (refined)
        n_verts_i = int(g.n_valid)
        levels.append(np.asarray(level[:n]))
        t2 = time.perf_counter()

        q_now = float(modularity(graph, jnp.concatenate(
            [level, jnp.asarray([n_cap], jnp.int32)]))) \
            if config.track_modularity else None

        converged = iters <= 1                       # Alg. 1 line 7
        low_shrink = n_report / max(n_verts_i, 1) > config.aggregation_tolerance  # line 9

        pass_caps = (g.n_cap, g.e_cap)
        if not (converged or low_shrink or p == config.max_passes - 1):
            g = _aggregate_phase(g, comm_ren, n_comms, backend=agg_backend)
            if config.use_ladder:
                # Ladder: re-bucket the coarse graph down to the smallest
                # power-of-two tier that fits it, so the NEXT pass's phases
                # run (and jit-cache) at coarse capacity.
                n_cap_new, e_cap_new = resolve_coarse_capacity(
                    n_comms_i, int(g.e_valid), g.n_cap, g.e_cap)
                if (n_cap_new, e_cap_new) != (g.n_cap, g.e_cap):
                    g = rebucket_capacity(g, n_cap_new=n_cap_new,
                                          e_cap_new=e_cap_new)
            if refine_on:
                warm_flat = np.asarray(_leiden_warm_membership(
                    comm_ren, outer_ren, jnp.int32(n_verts_i),
                    n_comms))[:n_comms_i]
                leiden_warm = pad_membership(warm_flat, g.n_cap)
            t3 = time.perf_counter()
            agg_s = t3 - t2
        else:
            agg_s = 0.0

        passes.append(PassStats(
            iterations=iters, n_communities=n_report, n_vertices=n_verts_i,
            dq_sum=float(dq_sum), seconds=time.perf_counter() - t0,
            phase_seconds={"local_move": t1a - t0,
                           "other": t2 - t1, "aggregate": agg_s,
                           **({"refine": t1 - t1a} if refine_on else {})},
            modularity=q_now,
            frontier_size=pass_frontier if pass_frontier is not None
            else n_verts_i,
            n_cap=pass_caps[0], e_cap=pass_caps[1],
            refine_iterations=refine_iters,
            n_refined=n_comms_i if refine_on else None,
        ))
        n_comms_final = n_report
        if converged or low_shrink:
            break
        tol = tol / config.tolerance_drop            # line 13 threshold scaling

    # With refinement the dendrogram chain (global_comm) follows the REFINED
    # partitions; the reported membership is the last pass's OUTER level.
    membership = levels[-1] if levels else np.asarray(global_comm[:n])
    return LouvainResult(
        membership=membership,
        n_communities=int(len(np.unique(membership))),
        passes=passes,
        total_seconds=time.perf_counter() - t_start,
        levels=levels,
    )


def membership_modularity(graph: CSRGraph, membership) -> float:
    """Q of a flat (n,) membership array on ``graph`` (sentinel-padded)."""
    membership = np.asarray(membership)
    comm = jnp.concatenate([
        jnp.asarray(membership, jnp.int32),
        jnp.full((graph.n_cap + 1 - len(membership),), graph.n_cap,
                 jnp.int32),
    ])
    return float(modularity(graph, comm))


def louvain_modularity(graph: CSRGraph, result: LouvainResult) -> float:
    """Q of a result on the original graph."""
    return membership_modularity(graph, result.membership)
