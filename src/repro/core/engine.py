"""Unified BSP move engine: ONE round loop behind pluggable scanner backends.

GVE-Louvain's speed lives in a single tight local-moving loop (Algorithm 2);
this repo used to carry three divergent copies of it — the single-device
sort-reduce loop, the Pallas-ELL loop, and the shard_map ``_round_body`` —
each re-implementing the gate hash, frontier pruning, singleton-swap guard,
and sweep/tolerance semantics.  Following the PLM/Grappolo observation that
parallel Louvain variants differ only in their *heuristic knobs* (pruning,
gating, ordering), the loop now exists exactly once:

  * ``MoveEngine`` owns the bulk-synchronous sweep (``lax.while_loop`` over
    sweeps of ``gate_fraction`` gated rounds), the Weyl gate hash, tolerance
    and iteration-cap semantics, vertex pruning, the singleton-swap guard,
    and the warm-start/``frontier0`` plumbing.
  * A **scanner backend** supplies only what is backend-specific: the
    per-vertex best-move scan ``(best_c, best_dq)`` from a (C, Sigma)
    snapshot, plus a thin topology surface (how to slice local state, sum
    across shards, gather replicated state, and mark movers' neighbors).
    ``repro.core.local_move.SortReduceScanner`` (CSR sort-reduce),
    ``repro.core.ell_move.ELLScanner`` (Pallas ELL kernel), and
    ``repro.core.distributed.ShardedScanner`` (shard_map + collectives) are
    the three backends; every execution path — static, dynamic, sharded,
    sharded-dynamic, batched multi-stream — routes through this engine.

The engine is shape-polymorphic over the backend's *local* vertex axis
(``n_cap + 1`` replicated slots on a single device, ``v_per_shard`` owned
slots inside ``shard_map``) while community state (C, Sigma) is always the
replicated ``(sentinel + 1,)`` layout.

Delta screening also lives here (``affected_frontier``): the seed-frontier
policy for streaming updates, at community granularity (touched endpoints +
every member of their communities, the PR-1 behavior) or DF-Louvain-style
per-vertex granularity (touched endpoints only — finer, relying on pruning
to grow the frontier outward from actual movers).  All streaming drivers
(CSR, sharded, batched) share this one implementation.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Weyl gate hash — the one home of the constants formerly pasted per-loop.
# ---------------------------------------------------------------------------

#: Knuth's multiplicative constant 2654435761 reinterpreted as int32.
GATE_MUL = jnp.int32(-1640531535)
#: Odd per-round Weyl increment (low bits of 2654435769).
GATE_INC = jnp.int32(40503)


def gate_hash(ids: jax.Array, round_ix: jax.Array) -> jax.Array:
    """Cheap per-(vertex, round) hash — Weyl sequence on odd constants."""
    return ids.astype(jnp.int32) * GATE_MUL + round_ix.astype(jnp.int32) * GATE_INC


def round_gate(ids: jax.Array, round_ix: jax.Array,
               gate_fraction: int) -> jax.Array:
    """Boolean mask selecting ~1/gate_fraction of ``ids`` this round.

    Deterministic, and decorrelated across rounds: a vertex not selected in
    round r is (approximately uniformly) likely to be selected in r + 1, so
    over a sweep of ``gate_fraction`` rounds nearly all vertices get a turn.
    """
    h = gate_hash(ids, round_ix)
    return jnp.abs(h >> 13) % gate_fraction == 0


# ---------------------------------------------------------------------------
# Engine state and configuration.
# ---------------------------------------------------------------------------


class MoveState(NamedTuple):
    """Loop state of one local-moving phase.

    ``comm``/``sigma`` are replicated community state ((sentinel + 1,));
    ``frontier`` is in the backend's LOCAL vertex layout (equal to the
    replicated layout on a single device, ``(v_per_shard,)`` per shard).
    """

    comm: jax.Array      # (sent + 1,) int32, sentinel slot = sent
    sigma: jax.Array     # (sent + 1,) float32 community total weights
    sizes: jax.Array     # (sent + 1,) int32 community sizes, maintained
    #                      incrementally by backends with exchange_round;
    #                      scalar 0 placeholder on the per-round-recompute
    #                      backends
    frontier: jax.Array  # (L,) bool — local layout
    iters: jax.Array     # () int32 — sweeps performed
    dq: jax.Array        # () float32 — total dQ of the last sweep
    dq_sum: jax.Array    # () float32 — accumulated dQ over the phase
    comm_fb: jax.Array   # () int32 — rounds the delta exchange fell back
    #                      to the dense path (0 on backends without one)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Static knobs of the round loop (jit-static everywhere)."""

    max_iterations: int = 20
    use_pruning: bool = True
    gate_fraction: int = 2


def gated_move_mask(best_c: jax.Array, best_dq: jax.Array, comm_l: jax.Array,
                    sizes: jax.Array, frontier: jax.Array, sent: int,
                    move_valid: Optional[jax.Array] = None,
                    gate: Optional[jax.Array] = None) -> jax.Array:
    """The engine's move decision from a scan result — ONE home.

    Combines the improvement test, the singleton-swap guard (Vite lineage:
    two singleton communities may only merge towards the smaller id, breaking
    A<->B oscillation), the frontier/validity masks and the round gate.
    Scanner backends that fuse the decision into their kernel (the fused ELL
    round) must reproduce exactly this boolean, and reuse this function for
    any rows their kernel does not cover.
    """
    own_single = sizes[comm_l] == 1
    tgt_single = sizes[jnp.minimum(best_c, sent)] == 1
    swap_blocked = own_single & tgt_single & (best_c > comm_l)
    do_move = ((best_dq > 0.0) & (best_c != comm_l) & (best_c < sent)
               & frontier & ~swap_blocked)
    if move_valid is not None:
        do_move = do_move & move_valid
    if gate is not None:
        do_move = do_move & gate
    return do_move


class MoveEngine:
    """The one BSP round loop.  ``scanner`` supplies the backend surface:

    required attributes
      ``sentinel``    int — sentinel id (n_cap single-device, n_pad sharded)
      ``local_ids``   (L,) int32 — global vertex id per local slot
      ``k_local``     (L,) f32 — vertex weights K_i in local layout
      ``move_valid``  (L,) bool or None — structural validity gate on moves
      ``frontier_valid`` (L,) bool — mask applied to the grown frontier

    required methods
      ``scan(comm, sigma, frontier)`` -> (best_c (L,), best_dq (L,))
      ``comm_local(comm)``            -> (L,) current community per local slot
      ``count_ones(comm_l)``          -> (L,) 0/1 contribution to |community|
      ``psum(x)``                     -> cross-shard sum (identity locally)
      ``combine_sigma(sigma, add, sub)`` -> replicated Sigma'
      ``gather_comm(comm_l)``         -> (sent + 1,) replicated membership
      ``gather_mask(mask_l)``         -> (sent + 1,) replicated bool
      ``mark_neighbors(moved)``       -> (L,) bool neighbors-of-movers

    optional methods
      ``decide_moves(comm, sigma, frontier, comm_l, sizes, round_ix)``
          -> (do_move (L,) bool, best_c (L,), best_dq (L,)) — a backend that
          fuses scan + gate + guard into one kernel (the fused Pallas ELL
          round) supplies the whole decision; it must equal what
          ``scan`` + ``gated_move_mask`` would produce, bit for bit.
      ``community_sizes(comm, comm_l)`` -> (sent + 1,) int32 — replaces the
          engine's psum'd size reduction (the delta backend recomputes sizes
          locally from the replicated membership: integer-exact, zero
          collective).  Must equal the psum path element for element.
      ``exchange_round(comm, sigma, sizes, comm_l, do_move, best_c,
                       dq_local)``
          -> (comm', sigma', sizes', moved (sent + 1,) bool,
              fallback () int32, dq () f32) —
          replaces the combine_sigma / gather_comm / gather_mask round-trip
          AND the dq psum with the backend's own state exchange (the delta
          backend ships compacted, bit-packed movers and the local dq in
          one fused collective and reconstructs everything else locally).
          ``dq_local`` is the shard's summed accepted gain.  The engine
          does NOT pre-reduce the per-community Sigma segment sums for
          this path — a backend that needs them (e.g. inside an overflow
          fallback branch) computes them itself, so the reduction only
          runs where it is consumed.  A backend with ``exchange_round``
          also maintains the community-size array incrementally: the
          engine threads ``sizes`` through ``MoveState`` (seeded once per
          phase via ``community_sizes``, required in this case) instead of
          re-reducing it every round.  Results must equal the default path
          bit for bit on one shard.
    """

    def __init__(self, scanner, config: EngineConfig):
        self.scanner = scanner
        self.config = config

    # -- one synchronous round: scan -> gate -> guard -> apply ------------
    def one_round(self, st: MoveState, frontier0: jax.Array,
                  round_ix: jax.Array) -> MoveState:
        sc, cfg = self.scanner, self.config
        sent = sc.sentinel
        frontier = st.frontier if cfg.use_pruning else frontier0
        comm_l = sc.comm_local(st.comm)

        gate = (round_gate(sc.local_ids, round_ix, cfg.gate_fraction)
                if cfg.gate_fraction > 1 else None)
        exchange = getattr(sc, "exchange_round", None)
        sizes_fn = getattr(sc, "community_sizes", None)
        if exchange is not None:
            sizes = st.sizes        # maintained by the backend's exchange
        elif sizes_fn is not None:
            sizes = sizes_fn(st.comm, comm_l)
        else:
            sizes = sc.psum(jax.ops.segment_sum(
                sc.count_ones(comm_l), comm_l, num_segments=sent + 1))

        decide = getattr(sc, "decide_moves", None)
        if decide is not None:
            do_move, best_c, best_dq = decide(st.comm, st.sigma, frontier,
                                              comm_l, sizes, round_ix)
        else:
            best_c, best_dq = sc.scan(st.comm, st.sigma, frontier)
            do_move = gated_move_mask(best_c, best_dq, comm_l, sizes,
                                      frontier, sent, sc.move_valid, gate)

        dq_local = jnp.sum(jnp.where(do_move, best_dq, 0.0))
        if exchange is not None:
            comm, sigma, sizes_new, moved_g, fb, dq = exchange(
                st.comm, st.sigma, sizes, comm_l, do_move, best_c, dq_local)
        else:
            moved_k = jnp.where(do_move, sc.k_local, 0.0)
            add = jax.ops.segment_sum(
                moved_k, jnp.where(do_move, best_c, sent),
                num_segments=sent + 1)
            sub = jax.ops.segment_sum(
                moved_k, jnp.where(do_move, comm_l, sent),
                num_segments=sent + 1)
            sigma = sc.combine_sigma(st.sigma, add, sub)
            comm = sc.gather_comm(jnp.where(do_move, best_c, comm_l))
            moved_g = sc.gather_mask(do_move)
            fb = jnp.asarray(0, jnp.int32)
            dq = sc.psum(dq_local)
            sizes_new = st.sizes

        # Vertex pruning: processed vertices leave the frontier; neighbors
        # of movers re-enter it.  Gated-out frontier vertices were never
        # processed this round — keep them hot.
        frontier_new = sc.mark_neighbors(moved_g) & sc.frontier_valid
        if gate is not None:
            frontier_new = frontier_new | (frontier & ~gate)

        return MoveState(comm, sigma, sizes_new, frontier_new, st.iters,
                         st.dq + dq, st.dq_sum + dq, st.comm_fb + fb)

    # -- the sweep loop ---------------------------------------------------
    def run(self, comm0: jax.Array, sigma0: jax.Array, frontier0: jax.Array,
            tolerance: jax.Array) -> MoveState:
        """Algorithm 2: sweeps until total dQ <= tolerance or the cap.

        ``comm0``/``sigma0`` may be ANY consistent membership + community-
        weight snapshot (warm starts pass the previous membership);
        ``frontier0`` restricts the first round to a seed set (delta
        screening) and is the re-scan set when pruning is disabled.
        """
        cfg = self.config

        def cond(st: MoveState):
            return (st.iters < cfg.max_iterations) & (st.dq > tolerance)

        def body(st: MoveState) -> MoveState:
            # One paper-"iteration" = one sweep = gate_fraction gated rounds,
            # so tolerance/cap semantics match the paper's full sweeps.
            st = st._replace(dq=jnp.asarray(0.0, jnp.float32))
            base = st.iters * cfg.gate_fraction
            for r in range(cfg.gate_fraction):
                st = self.one_round(st, frontier0, base + r)
            return st._replace(iters=st.iters + 1)

        # Backends with their own exchange maintain sizes incrementally —
        # seed them once per phase; everyone else recomputes per round and
        # carries a scalar placeholder through the loop state.
        sc = self.scanner
        if getattr(sc, "exchange_round", None) is not None:
            sizes0 = sc.community_sizes(comm0, sc.comm_local(comm0))
        else:
            sizes0 = jnp.asarray(0, jnp.int32)

        # Prime with dq = +inf so the loop always runs at least one sweep.
        st0 = MoveState(comm0, sigma0, sizes0, frontier0,
                        jnp.asarray(0, jnp.int32),
                        jnp.asarray(jnp.inf, jnp.float32),
                        jnp.asarray(0.0, jnp.float32),
                        jnp.asarray(0, jnp.int32))
        return jax.lax.while_loop(cond, body, st0)


def sanitize_outer(outer: jax.Array, n_valid: jax.Array,
                   sentinel: int) -> jax.Array:
    """Sanitize an outer-community membership before a constrained sweep.

    Refinement re-seeds vertices as singletons and constrains moves to the
    OUTER community from the preceding local-moving phase; ``outer`` arrives
    from arbitrary earlier state (a previous ladder tier's sentinel space, a
    streamed warm-start snapshot), so — exactly like the PR-5 ladder
    warm-start sanitisation — any label that does not denote a live
    community in the CURRENT sentinel space must be neutralised before it
    can leak into the constrained sweep's seed:

      * invalid vertex slots (id >= n_valid) pin to the sentinel;
      * a stale out-of-range label (< 0 or >= n_valid, e.g. a smaller
        tier's sentinel) on a VALID slot falls back to the vertex's own
        singleton — never to another community's id.

    ``n_valid`` is either the usual scalar (valid ids are the dense prefix
    ``[0, n_valid)``) or a ``(cap,)`` bool LIVE MASK for gappy layouts (the
    skew-resharded owner ranges, where valid ids are scattered blocks);
    community labels are representative vertex ids, so label validity is
    the same mask lookup.  The slot at ``sentinel`` is never valid.

    ``ConstrainedScanner`` applies this unconditionally, so the guarantee
    is engine-level, not per-driver.  ``assert_outer_sane`` is the eager
    companion for driver boundaries.
    """
    ids = jnp.arange(outer.shape[0], dtype=jnp.int32)
    lab = outer.astype(jnp.int32)
    nv = jnp.asarray(n_valid)
    if nv.ndim == 0:
        valid_slot = ids < nv
        in_range = (lab >= 0) & (lab < nv)
    else:
        valid_slot = nv & (ids < sentinel)
        safe_lab = jnp.clip(lab, 0, sentinel)
        in_range = ((lab >= 0) & (lab < sentinel)
                    & nv[safe_lab] & (safe_lab < sentinel))
    out = jnp.where(valid_slot & in_range, lab, ids)
    return jnp.where(valid_slot, out, sentinel)


def assert_outer_sane(outer, n_valid, sentinel: int) -> None:
    """Eager-mode guard: raise if a stale outer id would reach a constrained
    sweep.  No-op under tracing (jit), where ``sanitize_outer`` provides the
    in-graph guarantee; on concrete arrays this surfaces the driver bug
    loudly instead of silently re-labelling.  ``n_valid`` accepts the same
    scalar-or-live-mask forms as ``sanitize_outer``."""
    if isinstance(outer, jax.core.Tracer) or isinstance(n_valid, jax.core.Tracer):
        return
    import numpy as np
    outer = np.asarray(outer)
    ids = np.arange(outer.shape[0])
    nv_arr = np.asarray(n_valid)
    if nv_arr.ndim > 0:
        live = nv_arr.astype(bool) & (ids < sentinel)
        safe = np.clip(outer, 0, sentinel)
        lab_ok = (outer >= 0) & (outer < sentinel) & live[safe]
        bad_valid = live & ~lab_ok
        bad_pad = ~live & (outer != sentinel)
        if bad_valid.any() or bad_pad.any():
            where = np.flatnonzero(bad_valid | bad_pad)[:8]
            raise ValueError(
                f"stale outer-community ids in refinement seed: slots "
                f"{where.tolist()} hold {outer[where].tolist()} "
                f"(live mask, sentinel={sentinel})")
        return
    nv = int(n_valid)
    bad_valid = (ids < nv) & ((outer < 0) | (outer >= nv))
    bad_pad = (ids >= nv) & (outer != sentinel)
    if bad_valid.any() or bad_pad.any():
        where = np.flatnonzero(bad_valid | bad_pad)[:8]
        raise ValueError(
            f"stale outer-community ids in refinement seed: slots "
            f"{where.tolist()} hold {outer[where].tolist()} "
            f"(n_valid={nv}, sentinel={sentinel})")


def mask_cross_outer_slots(src: jax.Array, dst: jax.Array, w: jax.Array,
                           outer: jax.Array, sentinel: int):
    """Mask directed edge slots that cross outer-community boundaries.

    The refinement constraint is an EDGE property: a sub-community never
    spans an outer boundary, so "candidate target lies inside my outer
    community" is exactly "this slot's endpoints share an outer label".
    Cross-outer slots take ``dst = sentinel`` and ``w = 0`` — the sentinel
    destination makes the whole candidate group vanish in every backend's
    existing validity check (``s_c != sentinel``), which is essential:
    zeroing the weight alone would NOT be safe, because dQ can be positive
    with ``k_i_to_c == 0`` through the degree term of Eq. 2.

    Returns (dst', w').  Padding slots (already at the sentinel on both
    endpoints) pass through unchanged.
    """
    src_o = outer[jnp.minimum(src, sentinel)]
    dst_o = outer[jnp.minimum(dst, sentinel)]
    cross = src_o != dst_o
    return (jnp.where(cross, sentinel, dst).astype(dst.dtype),
            jnp.where(cross, 0.0, w).astype(w.dtype))


class ConstrainedScanner:
    """Leiden-style refinement as a wrapper over ANY scanner backend.

    Wraps an inner scanner that was built over the cross-outer-MASKED
    topology (``mask_cross_outer_slots``) and layers the two refinement
    rules on top of the engine's move decision:

      1. **intra-outer target** — the chosen community's label must share
         the mover's outer label (a safety net: the masked topology already
         makes cross-outer candidates unreachable);
      2. **singleton-only movers** (Leiden's refinement rule) — a vertex
         may move only while it is still a singleton in the refined
         partition.  Together with rule 1 and the fact that a singleton's
         positive-dQ move requires an actual edge into the target
         (``k_i_to_c > 0``; with ``sigma_d == k_i`` the degree term of
         Eq. 2 is non-positive), this guarantees every refined community
         is CONNECTED — the badly-connected-community fix.

    The wrapper delegates the whole scanner protocol to the inner backend
    (so SortReduce / compact / ELL / fused-ELL / sharded gather / sharded
    delta all inherit refinement with zero per-backend forks) and supplies
    ``decide_moves`` so the size-dependent singleton rule composes with the
    engine's gate + guard exactly once, for fused and unfused inners alike.
    """

    def __init__(self, inner, outer: jax.Array, n_valid,
                 gate_fraction: int = 2):
        assert_outer_sane(outer, n_valid, inner.sentinel)
        self.inner = inner
        self.sentinel = inner.sentinel
        self.local_ids = inner.local_ids
        self.k_local = inner.k_local
        self.move_valid = inner.move_valid
        self.frontier_valid = inner.frontier_valid
        self.gate_fraction = int(gate_fraction)
        self.outer = sanitize_outer(outer, n_valid, inner.sentinel)
        # Outer label per LOCAL slot (replicated == local on one device).
        self._outer_l = self.outer[jnp.minimum(self.local_ids, self.sentinel)]
        # Backends with their own exchange keep it: the engine probes via
        # getattr, so only mirror the hooks the inner actually has.
        for hook in ("community_sizes", "exchange_round", "resync_comm"):
            fn = getattr(inner, hook, None)
            if fn is not None:
                setattr(self, hook, fn)

    # -- delegated topology surface ---------------------------------------
    def comm_local(self, comm):
        return self.inner.comm_local(comm)

    def count_ones(self, comm_l):
        return self.inner.count_ones(comm_l)

    def psum(self, x):
        return self.inner.psum(x)

    def combine_sigma(self, sigma, add, sub):
        return self.inner.combine_sigma(sigma, add, sub)

    def gather_comm(self, comm_l):
        return self.inner.gather_comm(comm_l)

    def gather_mask(self, mask_l):
        return self.inner.gather_mask(mask_l)

    def mark_neighbors(self, moved):
        return self.inner.mark_neighbors(moved)

    def scan(self, comm, sigma, frontier):
        return self.inner.scan(comm, sigma, frontier)

    # -- the constrained decision -----------------------------------------
    def decide_moves(self, comm, sigma, frontier, comm_l, sizes, round_ix):
        sent = self.sentinel
        inner_decide = getattr(self.inner, "decide_moves", None)
        if inner_decide is not None:
            do_move, best_c, best_dq = inner_decide(
                comm, sigma, frontier, comm_l, sizes, round_ix)
        else:
            best_c, best_dq = self.inner.scan(comm, sigma, frontier)
            gate = (round_gate(self.local_ids, round_ix, self.gate_fraction)
                    if self.gate_fraction > 1 else None)
            do_move = gated_move_mask(best_c, best_dq, comm_l, sizes,
                                      frontier, sent, self.move_valid, gate)
        intra_outer = self.outer[jnp.minimum(best_c, sent)] == self._outer_l
        still_singleton = sizes[jnp.minimum(comm_l, sent)] == 1
        return do_move & intra_outer & still_singleton, best_c, best_dq


class ReplicatedScannerBase:
    """Topology surface shared by the single-device backends (sort-reduce
    and ELL): local layout == replicated layout, all collectives identity."""

    def __init__(self, sentinel: int, n_valid: jax.Array, k: jax.Array):
        self.sentinel = sentinel
        self.local_ids = jnp.arange(sentinel + 1)
        self.k_local = k
        valid = self.local_ids < n_valid
        self.move_valid: Optional[jax.Array] = valid
        self.frontier_valid = valid
        self._valid = valid

    def comm_local(self, comm: jax.Array) -> jax.Array:
        return comm

    def count_ones(self, comm_l: jax.Array) -> jax.Array:
        return jnp.where(self._valid, 1, 0)

    def psum(self, x: jax.Array) -> jax.Array:
        return x

    def combine_sigma(self, sigma, add, sub):
        return sigma + add - sub

    def gather_comm(self, comm_l: jax.Array) -> jax.Array:
        return comm_l

    def gather_mask(self, mask_l: jax.Array) -> jax.Array:
        return mask_l

    def scan(self, comm, sigma, frontier) -> Tuple[jax.Array, jax.Array]:
        raise NotImplementedError

    def mark_neighbors(self, moved: jax.Array) -> jax.Array:
        raise NotImplementedError


# ---------------------------------------------------------------------------
# Delta screening — the streaming seed-frontier policy, shared by every path.
# ---------------------------------------------------------------------------


#: ``screening="auto"`` uses DF-style per-vertex flags while the touched set
#: stays at or below n_valid / AUTO_SCREEN_TOUCHED_DENOM, and falls back to
#: the community-granular set for bulkier batches.  Small deltas are where
#: the ~8x-smaller vertex frontiers pay off (pruning re-grows them from
#: actual movers); a batch that perturbs a sizable fraction of the graph
#: shifts whole communities, where the coarser, safer set converges in
#: fewer sweeps for the same scan bill.
AUTO_SCREEN_TOUCHED_DENOM = 16


@functools.partial(jax.jit, static_argnames=("mode",))
def affected_frontier(touched: jax.Array, membership: jax.Array,
                      n_valid: jax.Array, mode: str = "community") -> jax.Array:
    """Seed frontier from a touched-vertex mask, in the replicated layout.

    ``membership`` is (cap + 1,) community ids with the sentinel slot = cap
    (cap = n_cap single-device, n_pad sharded).  Modes:

    ``"community"`` — touched endpoints plus ALL members of their current
        communities (the delta-screening set of Zarayeneh et al.; safe and
        the historical default).
    ``"vertex"`` — DF-Louvain-style per-vertex affected flags: ONLY the
        touched endpoints seed the frontier; with vertex pruning on, the
        frontier then grows outward from actual movers, so the engine
        re-scans strictly less of the graph per update.
    ``"auto"`` — pick per batch from the touched-set size (an on-device
        select, so streaming drivers stay free of per-batch host syncs):
        vertex granularity when |touched| <= n_valid /
        ``AUTO_SCREEN_TOUCHED_DENOM``, community granularity above.
    """
    cap = membership.shape[0] - 1
    idx = jnp.arange(cap + 1)
    valid = idx < n_valid
    fv = touched & valid
    if mode == "vertex":
        return fv
    if mode not in ("community", "auto"):
        raise ValueError(f"unknown screening mode: {mode!r}")
    comm = jnp.where(valid, jnp.minimum(membership, cap), cap)
    # Mark affected communities, then pull every member of a marked one.
    mark = jnp.zeros((cap + 1,), bool)
    mark = mark.at[jnp.where(fv, comm, cap)].set(True)
    mark = mark.at[cap].set(False)
    fc = (touched | mark[comm]) & valid
    if mode == "community":
        return fc
    small = (jnp.sum(fv.astype(jnp.int32)) * AUTO_SCREEN_TOUCHED_DENOM
             <= n_valid.astype(jnp.int32))
    return jnp.where(small, fv, fc)


def normalize_screening(screening) -> Optional[str]:
    """Map the drivers' ``screening`` argument to a frontier mode.

    ``True`` -> ``"community"`` (back-compat), ``False``/``None`` -> ``None``
    (pure naive-dynamic: warm start over all vertices), strings
    (``"community"``, ``"vertex"``, ``"auto"``) pass through.
    """
    if screening is True:
        return "community"
    if screening in (False, None):
        return None
    if screening in ("community", "vertex", "auto"):
        return screening
    raise ValueError(f"screening must be bool, 'community', 'vertex' or "
                     f"'auto'; got {screening!r}")


def resolve_screening_host(mode: Optional[str],
                           touched_frac: Optional[float]) -> Tuple[Optional[str], bool]:
    """Host-side ``"auto"`` screening resolution for BATCHED (vmapped) traces.

    ``affected_frontier``'s on-device ``"auto"`` is a ``jnp.where`` select:
    correct under ``vmap``, but it EVALUATES BOTH granularities for every
    lane every step — the community expansion's scatter/gather over the full
    capacity is exactly the work the vertex mode exists to avoid, so inside
    a combined vmap+shard_map program "auto" silently costs the full bill.
    Batched drivers therefore resolve the mode HOST-SIDE from the last
    validated dispatch's worst touched fraction (max over the lanes sharing
    the compiled program, one step stale — no extra device syncs) and record
    the choice in their ``PassStats``.

    Returns ``(mode, downgraded)``: non-"auto" modes pass through
    unchanged; ``"auto"`` resolves by the same |touched| <= n /
    ``AUTO_SCREEN_TOUCHED_DENOM`` threshold the on-device select uses, and
    falls back to the safe community granularity — flagged as a downgrade —
    when no measurement exists yet (the first dispatch).
    """
    if mode != "auto":
        return mode, False
    if touched_frac is None:
        return "community", True
    if touched_frac * AUTO_SCREEN_TOUCHED_DENOM <= 1.0:
        return "vertex", False
    return "community", False
