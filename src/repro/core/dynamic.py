"""Dynamic streaming Louvain: naive-dynamic warm start + delta screening.

Static GVE-Louvain restarts every pass from singleton communities.  Serving
workloads see small edge-batch deltas between queries, so re-running from
scratch wastes nearly all of its work.  This driver implements the two
standard dynamic strategies on top of the (now warm-startable) static
machinery in ``repro.core.louvain``:

  * **Naive-dynamic (ND)**: resume the move phase from the previous
    membership; community weights Sigma are recomputed from the updated
    graph so the warm snapshot is exact.
  * **Delta screening (DS)**: seed the first pass's frontier ONLY with the
    endpoints of changed edges plus every member of the communities those
    endpoints currently belong to (community membership lists come from
    ``community_vertices_csr``-style grouping — realized here as the
    equivalent O(n) mask ``member_of_affected = mark[comm]``).  With vertex
    pruning on, the frontier then grows outward from actual movers, so
    unaffected regions of the graph are never re-scanned.

``louvain_dynamic(graph, batches, prev=...)`` streams a sequence of
``EdgeBatch`` updates, applying each with ``repro.core.delta`` and
re-optimizing incrementally; per-batch ``PassStats.frontier_size`` reports
how many vertices delta screening re-processed (the streaming win is that
this stays a small fraction of n).
"""

from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.delta import EdgeBatch, apply_edge_batch
from repro.core.engine import affected_frontier, normalize_screening
from repro.core.graph import CSRGraph
from repro.core.louvain import (LouvainConfig, LouvainResult, louvain,
                                louvain_modularity, pad_membership,
                                screened_frontier)

# The frontier math is shared with the sharded layout — see
# ``repro.core.engine.affected_frontier``; this name is the historical
# single-device entry point.
delta_frontier = screened_frontier


@dataclasses.dataclass
class BatchUpdateStats:
    """One streamed batch: what changed and what it cost."""

    batch_size: int              # live entries in the batch
    n_touched: int               # endpoints whose incident weights changed
    frontier_size: int           # delta-screened seed frontier (|F| <= n)
    n_vertices: int              # n_valid after the update
    n_communities: int
    apply_seconds: float         # CSR edge-batch apply
    update_seconds: float        # warm-started Louvain
    modularity: Optional[float] = None

    @property
    def frontier_fraction(self) -> float:
        return self.frontier_size / max(self.n_vertices, 1)


@dataclasses.dataclass
class DynamicResult:
    graph: CSRGraph              # graph after all batches
    membership: np.ndarray       # (n_valid,) final community per vertex
    n_communities: int
    batch_stats: List[BatchUpdateStats]
    total_seconds: float

    @property
    def updates_per_second(self) -> float:
        edges = sum(s.batch_size for s in self.batch_stats)
        return edges / max(self.total_seconds, 1e-12)


_pad_membership = pad_membership


def louvain_dynamic(
    graph: CSRGraph,
    batches: Sequence[EdgeBatch],
    prev: Optional[np.ndarray] = None,
    config: LouvainConfig = LouvainConfig(),
    *,
    screening=True,
    track_modularity: bool = False,
    grow_capacity: bool = True,
    apply_backend: str = "xla",
) -> DynamicResult:
    """Stream edge batches through warm-started (ND + DS) Louvain.

    ``prev`` is the membership of ``graph`` BEFORE the stream ((n,) ints, as
    in ``LouvainResult.membership``); if ``None``, a cold static run on the
    initial graph produces it.  Each batch is applied in capacity
    (``apply_edge_batch``), then ``louvain`` resumes from the running
    membership with the delta-screened frontier.  ``screening`` picks the
    seed-frontier policy: ``True``/``"community"`` (touched endpoints plus
    their whole communities), ``"vertex"`` (DF-Louvain-style per-vertex
    affected flags — finer; pruning grows the frontier from actual movers),
    ``"auto"`` (per-batch granularity from the touched-set size — vertex
    for small deltas, community for bulky ones; an on-device select, no
    per-batch host sync), or ``False`` (pure naive-dynamic: warm start over
    ALL vertices).  ``config.scan_backend`` additionally routes the move
    phase through the frontier-compacted scanner when the screened frontier
    is small (``"auto"``/``"compact"`` — bit-identical results, scan work
    proportional to |F|).  With
    ``grow_capacity`` (the default) a batch that would overflow ``e_cap``
    re-buckets host-side into doubled capacity instead of raising — one
    recompile per growth step, then the stream continues in capacity.
    ``apply_backend`` selects the batch-apply group-resolve (``"xla"`` or
    the ``"pallas"`` kernel — bit-identical results).

    With ``config.use_ladder`` the warm re-optimizations ride the coarse-
    pass capacity ladder INSIDE each ``louvain`` call; the ladder never
    touches the resident stream graph — ``louvain`` re-buckets only its
    internal coarse graphs, so the next batch always applies at stream
    capacity (the driver is "un-laddered" by construction) and the
    compiled apply/screen programs never change shape across the stream.

    Returns the final graph/membership plus per-batch stats; the acceptance
    property is that modularity tracks a cold recompute while
    ``frontier_size`` stays a small fraction of n.
    """
    t_start = time.perf_counter()
    n_cap = graph.n_cap
    screen_mode = normalize_screening(screening)

    if prev is None:
        cold = louvain(graph, config)
        prev = cold.membership
    membership = _pad_membership(np.asarray(prev, np.int32), n_cap)

    stats: List[BatchUpdateStats] = []
    # n_touched is a device reduction; materializing it per batch would force
    # a sync inside the stream loop, so collect the lazy scalars and fill the
    # stats in one host transfer after the stream.
    touched_counts: List[jax.Array] = []
    n_comms = int(len(np.unique(membership[: int(graph.n_valid)])))
    for batch in batches:
        t0 = time.perf_counter()
        graph, touched = apply_edge_batch(graph, batch, grow=grow_capacity,
                                          backend=apply_backend)
        t1 = time.perf_counter()

        frontier = None
        if screen_mode is not None:
            frontier = affected_frontier(
                touched, jnp.asarray(membership), graph.n_valid,
                screen_mode)
        res: LouvainResult = louvain(
            graph, config, init_membership=membership,
            init_frontier=frontier)
        t2 = time.perf_counter()

        n = int(graph.n_valid)
        membership = _pad_membership(res.membership, n_cap)
        n_comms = res.n_communities
        touched_counts.append(jnp.sum(touched))
        stats.append(BatchUpdateStats(
            batch_size=int(batch.b_valid),
            n_touched=-1,  # filled from touched_counts after the stream
            frontier_size=res.passes[0].frontier_size if res.passes else 0,
            n_vertices=n,
            n_communities=n_comms,
            apply_seconds=t1 - t0,
            update_seconds=t2 - t1,
            modularity=louvain_modularity(graph, res)
            if track_modularity else None,
        ))
    for s, cnt in zip(stats, touched_counts):
        s.n_touched = int(cnt)

    n = int(graph.n_valid)
    return DynamicResult(
        graph=graph,
        membership=membership[:n].copy(),
        n_communities=n_comms,
        batch_stats=stats,
        total_seconds=time.perf_counter() - t_start,
    )
