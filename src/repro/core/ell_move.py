"""Pallas-ELL scanner backend + its local-moving adapter.

Vertices are degree-bucketed into fixed-width ELL tiles (graph.to_ell_blocks)
— the TPU analogue of the paper's dynamic load-balanced schedule — and each
tile's best-move scan runs in the fused Pallas kernel.  Hub vertices whose
degree exceeds the largest ELL width fall back to the sort-reduce scan.

The round/sweep loop lives in ``repro.core.engine.MoveEngine``; this module
contributes only the ELL **scanner** and the host-side wrapper.  The compiled
loop is cached per static configuration (``_ell_runner``) — blocks and
leftover ids are passed as jit *arguments*, so repeated calls with the same
shapes reuse one executable instead of re-jitting per invocation (the old
``jax.jit(lambda s: ...)``-per-call bug).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.engine import (ConstrainedScanner, EngineConfig, MoveEngine,
                               MoveState, gated_move_mask,
                               mask_cross_outer_slots, round_gate,
                               sanitize_outer)
from repro.core.graph import CSRGraph, ELLBlock, to_ell_blocks
from repro.core.local_move import SortReduceScanner, best_moves
from repro.core.modularity import community_weights
from repro.kernels.louvain_scan import ops as scan_ops


class ELLScanner(SortReduceScanner):
    """Engine backend: Pallas ELL scan tiles + sort-reduce hub fallback.

    Topology hooks (identity) and ``mark_neighbors`` come from the
    sort-reduce scanner; only the best-move scan differs.
    """

    def __init__(self, graph: CSRGraph, blocks, leftover, k, m, *,
                 use_pallas: bool, interpret: bool):
        super().__init__(graph, k, m)
        self.blocks = blocks
        self.leftover = leftover        # (n_leftover,) int32; may be empty
        self.use_pallas = use_pallas
        self.interpret = interpret

    def scan(self, comm, sigma, frontier) -> Tuple[jax.Array, jax.Array]:
        graph, k, m = self.graph, self.k_local, self.m
        n_cap = graph.n_cap
        best_c = jnp.full((n_cap + 1,), n_cap, jnp.int32)
        best_dq = jnp.full((n_cap + 1,), -jnp.inf, jnp.float32)

        for block in self.blocks:
            ins = scan_ops.prepare_ell_inputs(block, comm, sigma, k, n_cap)
            bc, bdq = scan_ops.louvain_scan(
                *ins, m, use_pallas=self.use_pallas, interpret=self.interpret
            )
            bc = jnp.where(bc < 0, n_cap, bc)
            # Pad rows carry vertex id n_cap -> land in the sentinel slot.
            best_c = best_c.at[block.rows].set(bc)
            best_dq = best_dq.at[block.rows].set(bdq)

        if self.leftover.shape[0]:
            sc, sdq = best_moves(graph, comm, sigma, k, frontier, m)
            best_c = best_c.at[self.leftover].set(sc[self.leftover])
            best_dq = best_dq.at[self.leftover].set(sdq[self.leftover])

        # Frontier-gate: non-frontier vertices must not move.
        best_dq = jnp.where(frontier, best_dq, -jnp.inf)
        best_c = best_c.at[n_cap].set(n_cap)
        return best_c, best_dq


class FusedELLScanner(ELLScanner):
    """Engine backend: the FUSED Pallas scan+apply round on ELL tiles.

    Supplies the engine's optional ``decide_moves`` hook: each tile leaves
    the fused kernel with its whole move decision made (scan + improvement
    test + in-kernel round gate + singleton guard + frontier mask), so the
    engine skips its generic gate/guard recompute — one kernel trip per tile
    instead of scan kernel + XLA apply round-trip.  Hub vertices beyond the
    widest ELL tile take the sort-reduce scan + the engine's own
    ``gated_move_mask`` — the same boolean the kernel computes, so the two
    halves compose bit-identically with the scan-only path.
    """

    def __init__(self, graph: CSRGraph, blocks, leftover, k, m, *,
                 use_pallas: bool, interpret: bool, gate_fraction: int):
        super().__init__(graph, blocks, leftover, k, m,
                         use_pallas=use_pallas, interpret=interpret)
        self.gate_fraction = gate_fraction

    def decide_moves(self, comm, sigma, frontier, comm_l, sizes, round_ix):
        graph, k, m = self.graph, self.k_local, self.m
        n_cap = graph.n_cap
        front = frontier & self._valid          # frontier & move-valid
        best_c = jnp.full((n_cap + 1,), n_cap, jnp.int32)
        best_dq = jnp.full((n_cap + 1,), -jnp.inf, jnp.float32)
        do_move = jnp.zeros((n_cap + 1,), bool)

        for block in self.blocks:
            ins = scan_ops.prepare_fused_inputs(block, comm, sigma, sizes,
                                                k, front, n_cap)
            bc, bdq, mv = scan_ops.louvain_fused(
                *ins, m, round_ix, gate_fraction=self.gate_fraction,
                sentinel=n_cap, use_pallas=self.use_pallas,
                interpret=self.interpret)
            # Pad rows carry vertex id n_cap -> land in the sentinel slot.
            best_c = best_c.at[block.rows].set(bc)
            best_dq = best_dq.at[block.rows].set(bdq)
            do_move = do_move.at[block.rows].set(mv > 0)

        if self.leftover.shape[0]:
            sc, sdq = best_moves(graph, comm, sigma, k, frontier, m)
            gate = (round_gate(self.local_ids, round_ix, self.gate_fraction)
                    if self.gate_fraction > 1 else None)
            mv_all = gated_move_mask(sc, sdq, comm_l, sizes, frontier, n_cap,
                                     self.move_valid, gate)
            best_c = best_c.at[self.leftover].set(sc[self.leftover])
            best_dq = best_dq.at[self.leftover].set(
                jnp.where(front[self.leftover], sdq[self.leftover],
                          -jnp.inf))
            do_move = do_move.at[self.leftover].set(mv_all[self.leftover])

        best_c = best_c.at[n_cap].set(n_cap)
        do_move = do_move.at[n_cap].set(False)
        return do_move, best_c, best_dq


def _mask_blocks_cross_outer(blocks, outer, n_cap: int):
    """On-device ELL analogue of ``engine.mask_cross_outer_slots``: slots
    whose endpoints disagree on the outer label become padding (col = n_cap,
    w = 0), which ``prepare_ell_inputs`` already treats as dead."""
    masked = []
    for b in blocks:
        row_o = outer[jnp.minimum(b.rows, n_cap)][:, None]
        col_o = outer[jnp.minimum(b.cols, n_cap)]
        cross = row_o != col_o
        masked.append(ELLBlock(b.rows,
                               jnp.where(cross, n_cap, b.cols),
                               jnp.where(cross, 0.0, b.w)))
    return tuple(masked)


@functools.lru_cache(maxsize=None)
def _ell_runner(n_blocks: int, use_pallas: bool, interpret: bool,
                max_iterations: int, use_pruning: bool, gate_fraction: int,
                fused: bool = False, refine: bool = False):
    """One jit'd engine loop per static config; graph/blocks are arguments
    (not closure constants), so calls with equal shapes share the executable."""
    config = EngineConfig(max_iterations=max_iterations,
                          use_pruning=use_pruning,
                          gate_fraction=gate_fraction)

    @jax.jit
    def run(graph, blocks, leftover, k, m, comm0, sigma0, frontier0,
            tolerance, outer=None):
        if refine:
            outer_s = sanitize_outer(outer, graph.n_valid, graph.n_cap)
            dst, w = mask_cross_outer_slots(
                graph.src, graph.indices, graph.weights, outer_s,
                graph.n_cap)
            graph = graph._replace(indices=dst, weights=w)
            blocks = _mask_blocks_cross_outer(blocks, outer_s, graph.n_cap)
        if fused:
            scanner = FusedELLScanner(graph, blocks, leftover, k, m,
                                      use_pallas=use_pallas,
                                      interpret=interpret,
                                      gate_fraction=gate_fraction)
        else:
            scanner = ELLScanner(graph, blocks, leftover, k, m,
                                 use_pallas=use_pallas, interpret=interpret)
        if refine:
            scanner = ConstrainedScanner(scanner, outer_s, graph.n_valid,
                                         gate_fraction=gate_fraction)
        st = MoveEngine(scanner, config).run(comm0, sigma0, frontier0,
                                             tolerance)
        return st.comm, st.iters, st.dq_sum

    return run


def move_phase_ell(
    graph: CSRGraph,
    tolerance: jax.Array,
    *,
    max_iterations: int = 20,
    use_pruning: bool = True,
    gate_fraction: int = 2,
    widths: Tuple[int, ...] = (16, 64, 256),
    use_pallas: bool = True,
    interpret: bool | None = None,
    comm0: jax.Array | None = None,
    sigma0: jax.Array | None = None,
    frontier0: jax.Array | None = None,
    fused: bool = False,
    refine_outer: jax.Array | None = None,
):
    """ELL-kernel local-moving phase: returns (comm, iters, dq_sum).

    Host-side wrapper: buckets the graph once, then runs the cached jit'd
    engine loop.  ``comm0``/``sigma0``/``frontier0`` warm-start the sweep
    from an arbitrary membership snapshot (defaults: singleton start over
    all valid vertices), mirroring the sort-reduce ``_move_phase``.
    ``fused=True`` runs the fused scan+apply kernel (``FusedELLScanner``)
    instead of the scan-only kernel + engine apply — same memberships, bit
    for bit.  ``refine_outer`` runs the Leiden-style constrained sweep
    instead (see ``local_move.louvain_move``): blocks and leftover slots
    are masked on device, so the host-side bucketing is reused as-is.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    blocks, leftover_np = to_ell_blocks(graph, widths)
    leftover = jnp.asarray(leftover_np)

    n_cap = graph.n_cap
    k = graph.vertex_weights()
    m = graph.total_weight()
    valid = jnp.arange(n_cap + 1) < graph.n_valid
    if comm0 is None:
        comm0 = jnp.arange(n_cap + 1, dtype=jnp.int32)
        if sigma0 is None:
            sigma0 = k               # singleton start: Sigma_c == K_i
    elif sigma0 is None:
        # Derive Sigma from the warm membership — defaulting to k here
        # would silently pair a non-singleton C with singleton weights.
        sigma0 = community_weights(graph, comm0)
    frontier0 = valid if frontier0 is None else (frontier0 & valid)

    run = _ell_runner(len(blocks), use_pallas, interpret,
                      max_iterations, use_pruning, gate_fraction, fused,
                      refine_outer is not None)
    if refine_outer is not None:
        return run(graph, tuple(blocks), leftover, k, m, comm0, sigma0,
                   frontier0, jnp.float32(tolerance), refine_outer)
    return run(graph, tuple(blocks), leftover, k, m, comm0, sigma0,
               frontier0, jnp.float32(tolerance))
