"""Local-moving phase backed by the Pallas ELL scan kernel.

Vertices are degree-bucketed into fixed-width ELL tiles (graph.to_ell_blocks)
— the TPU analogue of the paper's dynamic load-balanced schedule — and each
tile's best-move scan runs in the fused Pallas kernel.  Hub vertices whose
degree exceeds the largest ELL width fall back to the sort-reduce path.

The bucketing happens host-side once per pass (the graph is static within a
pass); the round loop itself is a single jit with `lax.while_loop`.
"""

from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import CSRGraph, ELLBlock, to_ell_blocks
from repro.core.local_move import MoveState, apply_moves, best_moves
from repro.core.modularity import community_weights
from repro.kernels.louvain_scan import ops as scan_ops


def _ell_best_moves(
    blocks: List[ELLBlock],
    leftover: jax.Array | None,
    graph: CSRGraph,
    comm: jax.Array,
    sigma: jax.Array,
    k: jax.Array,
    frontier: jax.Array,
    m: jax.Array,
    *,
    use_pallas: bool,
    interpret: bool,
) -> Tuple[jax.Array, jax.Array]:
    """Best (community, dQ) per vertex, assembled from all ELL tiles."""
    n_cap = graph.n_cap
    best_c = jnp.full((n_cap + 1,), n_cap, jnp.int32)
    best_dq = jnp.full((n_cap + 1,), -jnp.inf, jnp.float32)

    for block in blocks:
        ins = scan_ops.prepare_ell_inputs(block, comm, sigma, k, n_cap)
        bc, bdq = scan_ops.louvain_scan(
            *ins, m, use_pallas=use_pallas, interpret=interpret
        )
        bc = jnp.where(bc < 0, n_cap, bc)
        # Pad rows carry vertex id n_cap -> land in the sentinel slot.
        best_c = best_c.at[block.rows].set(bc)
        best_dq = best_dq.at[block.rows].set(bdq)

    if leftover is not None and leftover.size:
        sc, sdq = best_moves(graph, comm, sigma, k, frontier, m)
        best_c = best_c.at[leftover].set(sc[leftover])
        best_dq = best_dq.at[leftover].set(sdq[leftover])

    # Frontier-gate: non-frontier vertices must not move.
    best_dq = jnp.where(frontier, best_dq, -jnp.inf)
    best_c = best_c.at[n_cap].set(n_cap)
    return best_c, best_dq


def move_phase_ell(
    graph: CSRGraph,
    tolerance: jax.Array,
    *,
    max_iterations: int = 20,
    use_pruning: bool = True,
    gate_fraction: int = 2,
    widths: Tuple[int, ...] = (16, 64, 256),
    use_pallas: bool = True,
    interpret: bool | None = None,
    comm0: jax.Array | None = None,
    sigma0: jax.Array | None = None,
    frontier0: jax.Array | None = None,
):
    """ELL-kernel local-moving phase: returns (comm, iters, dq_sum).

    Host-side wrapper: buckets the graph once, then runs the jit'd sweep loop.
    ``comm0``/``sigma0``/``frontier0`` warm-start the sweep from an arbitrary
    membership snapshot (defaults: singleton start over all valid vertices),
    mirroring the sort-reduce ``_move_phase``.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    blocks, leftover_np = to_ell_blocks(graph, widths)
    leftover = jnp.asarray(leftover_np) if len(leftover_np) else None

    n_cap = graph.n_cap
    k = graph.vertex_weights()
    m = graph.total_weight()
    idx = jnp.arange(n_cap + 1)
    valid = idx < graph.n_valid
    if comm0 is None:
        comm0 = jnp.arange(n_cap + 1, dtype=jnp.int32)
        if sigma0 is None:
            sigma0 = k               # singleton start: Sigma_c == K_i
    elif sigma0 is None:
        # Derive Sigma from the warm membership — defaulting to k here
        # would silently pair a non-singleton C with singleton weights.
        sigma0 = community_weights(graph, comm0)
    frontier0 = valid if frontier0 is None else (frontier0 & valid)

    def cond(st: MoveState):
        return (st.iters < max_iterations) & (st.dq > tolerance)

    def one_round(st: MoveState, round_ix):
        frontier = st.frontier if use_pruning else frontier0
        bc, bdq = _ell_best_moves(
            blocks, leftover, graph, st.comm, st.sigma, k, frontier, m,
            use_pallas=use_pallas, interpret=interpret,
        )
        if gate_fraction > 1:
            h = (idx.astype(jnp.int32) * jnp.int32(-1640531535)
                 + round_ix.astype(jnp.int32) * jnp.int32(40503))
            gate = jnp.abs(h >> 13) % gate_fraction == 0
        else:
            gate = None
        comm, sigma, frontier_new, dq = apply_moves(
            graph, st.comm, st.sigma, k, frontier, bc, bdq, gate
        )
        if gate is not None:
            frontier_new = frontier_new | (frontier & ~gate)
        return MoveState(comm, sigma, frontier_new, st.iters, st.dq + dq,
                         st.dq_sum + dq)

    def body(st: MoveState) -> MoveState:
        st = st._replace(dq=jnp.asarray(0.0, jnp.float32))
        base = st.iters * gate_fraction
        for r in range(gate_fraction):
            st = one_round(st, base + r)
        return st._replace(iters=st.iters + 1)

    st0 = MoveState(comm0, sigma0, frontier0, jnp.asarray(0, jnp.int32),
                    jnp.asarray(jnp.inf, jnp.float32),
                    jnp.asarray(0.0, jnp.float32))

    run = jax.jit(lambda s: jax.lax.while_loop(cond, body, s))
    st = run(st0)
    return st.comm, st.iters, st.dq_sum
