"""Louvain-driven graph partitioning — the paper technique as a framework
feature for distributed GNN training.

Communities from GVE-Louvain are packed onto devices with a greedy
bin-packing, keeping each community's vertices device-local.  Compared to
random/hashed vertex assignment this minimizes cut edges, i.e. the cross-
device gathers a full-graph GNN layer must all-to-all.  Also provides the
community-contiguous reordering (locality for segment ops).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from repro.core.graph import CSRGraph
from repro.core.louvain import LouvainConfig, louvain


@dataclasses.dataclass
class PartitionResult:
    assignment: np.ndarray       # (n,) device id per vertex
    order: np.ndarray            # (n,) community-contiguous permutation
    cut_edges: int
    total_edges: int
    balance: float               # max device load / mean load

    @property
    def cut_fraction(self) -> float:
        return self.cut_edges / max(self.total_edges, 1)


def edge_cut(graph: CSRGraph, assignment: np.ndarray) -> int:
    src = np.asarray(graph.src)
    dst = np.asarray(graph.indices)
    live = src < graph.n_cap
    return int(np.sum(assignment[src[live]] != assignment[dst[live]]))


def louvain_partition(
    graph: CSRGraph,
    n_devices: int,
    config: LouvainConfig = LouvainConfig(),
) -> PartitionResult:
    """Detect communities, then greedily pack them onto devices (LPT)."""
    n = int(graph.n_valid)
    res = louvain(graph, config)
    membership = res.membership

    # Community sizes -> largest-first bin packing onto devices.
    comms, counts = np.unique(membership, return_counts=True)
    order_c = np.argsort(-counts)
    loads = np.zeros(n_devices, np.int64)
    comm_dev = np.zeros(comms.max() + 1, np.int32)
    for cix in order_c:
        d = int(np.argmin(loads))
        comm_dev[comms[cix]] = d
        loads[d] += counts[cix]

    assignment = comm_dev[membership]
    order = np.argsort(assignment * (membership.max() + 1) + membership,
                       kind="stable").astype(np.int32)
    cut = edge_cut(graph, assignment)
    src = np.asarray(graph.src)
    total = int((src < graph.n_cap).sum())
    return PartitionResult(
        assignment=assignment.astype(np.int32), order=order,
        cut_edges=cut, total_edges=total,
        balance=float(loads.max() / max(loads.mean(), 1e-9)))


def random_partition(graph: CSRGraph, n_devices: int,
                     seed: int = 0) -> PartitionResult:
    """Baseline: hashed assignment (what you get without the technique)."""
    n = int(graph.n_valid)
    rng = np.random.default_rng(seed)
    assignment = rng.integers(0, n_devices, n).astype(np.int32)
    cut = edge_cut(graph, assignment)
    src = np.asarray(graph.src)
    total = int((src < graph.n_cap).sum())
    loads = np.bincount(assignment, minlength=n_devices)
    return PartitionResult(
        assignment=assignment, order=np.argsort(assignment).astype(np.int32),
        cut_edges=cut, total_edges=total,
        balance=float(loads.max() / max(loads.mean(), 1e-9)))
