"""Sort-reduce scanner backend + single-device local-moving adapter.

The paper's asynchronous per-thread moves (OpenMP atomics) have no efficient
analogue in a bulk-synchronous XLA program, so GVE-Louvain's local-moving is
recast as rounds: every frontier vertex computes its best move against the
*same* snapshot of (C, Sigma), then all moves are applied at once (cf. the GPU
adaptations the paper cites, Naim et al. / Cheong et al.).

The round/sweep loop itself lives in ``repro.core.engine.MoveEngine`` — this
module contributes only the **scanner**: the per-thread collision-free Far-KV
hashtable of scanCommunities() becomes a sort-reduce, grouping edges by
(src, C[dst]) with a lexicographic sort and segment-summing the per-community
weights K_{i->c}.  A Pallas ELL kernel implementing the same scan as a dense
pairwise compare lives in ``repro.core.ell_move`` / ``repro.kernels``.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.engine import (ConstrainedScanner, EngineConfig, MoveEngine,
                               MoveState, ReplicatedScannerBase,
                               mask_cross_outer_slots, sanitize_outer)
from repro.core.graph import CSRGraph
from repro.core.modularity import delta_modularity

_NEG_INF = -jnp.inf

__all__ = ["CompactSortReduceScanner", "MoveState", "SortReduceScanner",
           "best_moves", "best_moves_slots", "compact_best_moves",
           "gather_frontier_slots", "louvain_move",
           "scan_communities_sorted"]


def scan_communities_sorted(
    graph: CSRGraph, comm: jax.Array
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Group edge slots by (src, C[dst]) and compute K_{i->c} per slot.

    Returns (order, s_src, s_c, k_i_to_c) where arrays are in sorted slot
    order.  Self-loop slots contribute 0 (K_{i->c} excludes self edges).
    """
    return _scan_communities_slots(graph.src, graph.indices, graph.weights,
                                   comm)


def _scan_communities_slots(src, dst, w, comm):
    """``scan_communities_sorted`` over arbitrary directed-slot arrays."""
    cdst = comm[dst]
    order = jnp.lexsort((cdst, src))  # primary: src, secondary: community
    s_src = src[order]
    s_dst = dst[order]
    s_c = cdst[order]
    s_w = jnp.where(s_src == s_dst, 0.0, w[order])

    prev_src = jnp.concatenate([jnp.full((1,), -1, jnp.int32), s_src[:-1]])
    prev_c = jnp.concatenate([jnp.full((1,), -1, jnp.int32), s_c[:-1]])
    new_group = (s_src != prev_src) | (s_c != prev_c)
    gid = jnp.cumsum(new_group.astype(jnp.int32)) - 1
    group_w = jax.ops.segment_sum(s_w, gid, num_segments=src.shape[0])
    return order, s_src, s_c, group_w[gid]


def best_moves_slots(
    src: jax.Array,
    dst: jax.Array,
    w: jax.Array,
    comm: jax.Array,
    sigma: jax.Array,
    k: jax.Array,
    frontier: jax.Array,
    m: jax.Array,
    n_cap: int,
) -> Tuple[jax.Array, jax.Array]:
    """Per-vertex (best community, best dQ) from a directed-slot list.

    The slot arrays may be the graph's full ``e_cap`` layout or any
    compacted subset of it (dead slots hold the sentinel ``n_cap``); a
    vertex whose live slots are ALL present gets exactly the full-scan
    answer — compaction preserves slot order, the lexsort is stable, and
    the per-group reductions therefore add the same weights in the same
    order, so the result is bit-identical, not just numerically close.
    """
    # K_{i -> own community} — direct segment-sum, no sort needed.
    own = (comm[dst] == comm[src]) & (dst != src)
    k_to_own = jax.ops.segment_sum(
        jnp.where(own, w, 0.0), src, num_segments=n_cap + 1
    )

    _, s_src, s_c, k_i_to_c = _scan_communities_slots(src, dst, w, comm)
    c_own = comm[s_src]
    dq = delta_modularity(
        k_i_to_c, k_to_own[s_src], k[s_src], sigma[s_c], sigma[c_own], m
    )
    valid = (s_c != c_own) & (s_src != n_cap) & (s_c != n_cap) & frontier[s_src]
    dq = jnp.where(valid, dq, _NEG_INF)

    best_dq = jax.ops.segment_max(dq, s_src, num_segments=n_cap + 1)
    best_dq = jnp.where(jnp.isfinite(best_dq), best_dq, _NEG_INF)
    is_best = (dq == best_dq[s_src]) & valid
    best_c = jax.ops.segment_min(
        jnp.where(is_best, s_c, n_cap), s_src, num_segments=n_cap + 1
    )
    # Empty segments yield iinfo.max — clamp into the sentinel slot.
    best_c = jnp.minimum(best_c, n_cap)
    return best_c, best_dq


def best_moves(
    graph: CSRGraph,
    comm: jax.Array,
    sigma: jax.Array,
    k: jax.Array,
    frontier: jax.Array,
    m: jax.Array,
) -> Tuple[jax.Array, jax.Array]:
    """Per-vertex (best community, best dQ) from one snapshot (sort-reduce path)."""
    return best_moves_slots(graph.src, graph.indices, graph.weights, comm,
                            sigma, k, frontier, m, graph.n_cap)


def gather_frontier_slots(
    graph: CSRGraph, frontier: jax.Array, work_cap: int
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Compact the frontier vertices' edge slots into a (work_cap,) buffer.

    Order-preserving: slot i of the output is the i-th edge slot (in CSR
    order) whose src is in the frontier, so downstream sort-reduce results
    are bit-identical to the full scan.  Slots past ``work_cap`` are dropped
    — ``overflow`` reports whether any were, in which case the caller must
    fall back to the full scan (the compact result would be missing edges).

    Returns (src, dst, w, overflow) with dead slots = (n_cap, n_cap, 0).
    """
    n_cap = graph.n_cap
    src, dst, w = graph.src, graph.indices, graph.weights
    in_f = frontier[src]                       # pad slots: frontier[n_cap]=F
    rank = jnp.cumsum(in_f.astype(jnp.int32)) - 1
    keep = in_f & (rank < work_cap)
    slot = jnp.where(keep, rank, work_cap)
    out_src = jnp.full((work_cap + 1,), n_cap, jnp.int32).at[slot].set(
        jnp.where(keep, src, n_cap))[:work_cap]
    out_dst = jnp.full((work_cap + 1,), n_cap, jnp.int32).at[slot].set(
        jnp.where(keep, dst, n_cap))[:work_cap]
    out_w = jnp.zeros((work_cap + 1,), jnp.float32).at[slot].set(
        jnp.where(keep, w, 0.0))[:work_cap]
    overflow = jnp.sum(in_f.astype(jnp.int32)) > work_cap
    return out_src, out_dst, out_w, overflow


def compact_best_moves(
    graph: CSRGraph,
    comm: jax.Array,
    sigma: jax.Array,
    k: jax.Array,
    frontier: jax.Array,
    m: jax.Array,
    work_cap: int,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Frontier-proportional best-move scan with measured-overflow fallback.

    Gathers only the frontier vertices' edge slots into a static
    ``(work_cap,)`` buffer and scans that, so per-round scan cost is
    O(work_cap log work_cap) instead of O(e_cap log e_cap) — the
    DF-Louvain-style payoff when |F| << n.  When the frontier's slots
    exceed the cap, ``lax.cond`` dispatches the full e_cap scan instead
    (shapes stay static; one compiled program handles both regimes).

    Returns (best_c, best_dq, overflowed); the first two are bit-identical
    to ``best_moves`` either way.
    """
    c_src, c_dst, c_w, overflow = gather_frontier_slots(graph, frontier,
                                                        work_cap)

    def full_scan(_):
        return best_moves(graph, comm, sigma, k, frontier, m)

    def compact_scan(_):
        return best_moves_slots(c_src, c_dst, c_w, comm, sigma, k, frontier,
                                m, graph.n_cap)

    best_c, best_dq = jax.lax.cond(overflow, full_scan, compact_scan,
                                   operand=None)
    return best_c, best_dq, overflow


class SortReduceScanner(ReplicatedScannerBase):
    """Engine backend: CSR sort-reduce scan on a single device.

    Local layout == replicated layout ((n_cap + 1,) with the sentinel slot);
    all topology hooks are the identities from ``ReplicatedScannerBase``.
    """

    def __init__(self, graph: CSRGraph, k: jax.Array, m: jax.Array):
        super().__init__(graph.n_cap, graph.n_valid, k)
        self.graph = graph
        self.m = m

    def scan(self, comm, sigma, frontier):
        return best_moves(self.graph, comm, sigma, self.k_local, frontier,
                          self.m)

    def mark_neighbors(self, moved: jax.Array) -> jax.Array:
        g = self.graph
        marked = jax.ops.segment_max(
            moved[g.src].astype(jnp.int32), g.indices,
            num_segments=g.n_cap + 1)
        return marked > 0


class CompactSortReduceScanner(SortReduceScanner):
    """Engine backend: frontier-compacted CSR sort-reduce scan.

    Same topology surface as ``SortReduceScanner`` — only the scan differs:
    per round it gathers the CURRENT frontier's edge slots into a static
    ``(work_cap,)`` buffer and sort-reduces that, falling back to the full
    ``e_cap`` scan inside the same compiled program when the frontier's
    slots overflow the cap.  Results are bit-identical to the full scan;
    only the work is frontier-proportional (ROADMAP "Unified move engine ->
    Next": scan ONLY frontier vertices' edge slots).
    """

    def __init__(self, graph: CSRGraph, k: jax.Array, m: jax.Array,
                 work_cap: int):
        super().__init__(graph, k, m)
        if not 0 < work_cap:
            raise ValueError(f"work_cap must be positive, got {work_cap}")
        self.work_cap = int(min(work_cap, graph.e_cap))

    def scan(self, comm, sigma, frontier):
        best_c, best_dq, _ = compact_best_moves(
            self.graph, comm, sigma, self.k_local, frontier, self.m,
            self.work_cap)
        return best_c, best_dq


def louvain_move(
    graph: CSRGraph,
    comm: jax.Array,
    sigma: jax.Array,
    k: jax.Array,
    m: jax.Array,
    *,
    tolerance: jax.Array,
    max_iterations: int = 20,
    use_pruning: bool = True,
    gate_fraction: int = 2,
    frontier0: jax.Array | None = None,
    work_cap: int = 0,
    refine_outer: jax.Array | None = None,
) -> MoveState:
    """Algorithm 2 on the sort-reduce backend — a thin engine adapter.

    ``comm``/``sigma`` may be ANY consistent membership + community-weight
    snapshot, not just the singleton start — warm starts (dynamic Louvain)
    pass the previous membership here.  ``frontier0`` optionally restricts
    the first round to a seed set (delta screening); ``None`` means all
    valid vertices.  ``work_cap > 0`` selects the frontier-compacted
    scanner with that (static) work-buffer capacity; 0 keeps the full-scan
    backend.  Sweep/tolerance/gating semantics are the engine's — see
    ``repro.core.engine.MoveEngine``.

    ``refine_outer`` switches the sweep into the Leiden-style CONSTRAINED
    mode: cross-outer edge slots are masked (dst -> sentinel, w -> 0) so a
    vertex only ever sees candidates inside its outer community, and the
    scanner is wrapped in ``engine.ConstrainedScanner`` (intra-outer target
    + singleton-only movers).  ``k``/``m``/``sigma`` stay the FULL graph's
    quantities — only the candidate topology is restricted.
    """
    valid = jnp.arange(graph.n_cap + 1) < graph.n_valid
    frontier0 = valid if frontier0 is None else (frontier0 & valid)
    if refine_outer is not None:
        outer = sanitize_outer(refine_outer, graph.n_valid, graph.n_cap)
        dst, w = mask_cross_outer_slots(graph.src, graph.indices,
                                        graph.weights, outer, graph.n_cap)
        graph = graph._replace(indices=dst, weights=w)
    scanner = (CompactSortReduceScanner(graph, k, m, work_cap) if work_cap
               else SortReduceScanner(graph, k, m))
    if refine_outer is not None:
        scanner = ConstrainedScanner(scanner, outer, graph.n_valid,
                                     gate_fraction=gate_fraction)
    engine = MoveEngine(
        scanner,
        EngineConfig(max_iterations=max_iterations, use_pruning=use_pruning,
                     gate_fraction=gate_fraction))
    return engine.run(comm, sigma, frontier0, tolerance)
