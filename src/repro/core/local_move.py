"""Sort-reduce scanner backend + single-device local-moving adapter.

The paper's asynchronous per-thread moves (OpenMP atomics) have no efficient
analogue in a bulk-synchronous XLA program, so GVE-Louvain's local-moving is
recast as rounds: every frontier vertex computes its best move against the
*same* snapshot of (C, Sigma), then all moves are applied at once (cf. the GPU
adaptations the paper cites, Naim et al. / Cheong et al.).

The round/sweep loop itself lives in ``repro.core.engine.MoveEngine`` — this
module contributes only the **scanner**: the per-thread collision-free Far-KV
hashtable of scanCommunities() becomes a sort-reduce, grouping edges by
(src, C[dst]) with a lexicographic sort and segment-summing the per-community
weights K_{i->c}.  A Pallas ELL kernel implementing the same scan as a dense
pairwise compare lives in ``repro.core.ell_move`` / ``repro.kernels``.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.engine import (EngineConfig, MoveEngine, MoveState,
                               ReplicatedScannerBase)
from repro.core.graph import CSRGraph
from repro.core.modularity import delta_modularity

_NEG_INF = -jnp.inf

__all__ = ["MoveState", "SortReduceScanner", "best_moves", "louvain_move",
           "scan_communities_sorted"]


def scan_communities_sorted(
    graph: CSRGraph, comm: jax.Array
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Group edge slots by (src, C[dst]) and compute K_{i->c} per slot.

    Returns (order, s_src, s_c, k_i_to_c) where arrays are in sorted slot
    order.  Self-loop slots contribute 0 (K_{i->c} excludes self edges).
    """
    src, dst, w = graph.src, graph.indices, graph.weights
    cdst = comm[dst]
    order = jnp.lexsort((cdst, src))  # primary: src, secondary: community
    s_src = src[order]
    s_dst = dst[order]
    s_c = cdst[order]
    s_w = jnp.where(s_src == s_dst, 0.0, w[order])

    prev_src = jnp.concatenate([jnp.full((1,), -1, jnp.int32), s_src[:-1]])
    prev_c = jnp.concatenate([jnp.full((1,), -1, jnp.int32), s_c[:-1]])
    new_group = (s_src != prev_src) | (s_c != prev_c)
    gid = jnp.cumsum(new_group.astype(jnp.int32)) - 1
    group_w = jax.ops.segment_sum(s_w, gid, num_segments=graph.e_cap)
    return order, s_src, s_c, group_w[gid]


def best_moves(
    graph: CSRGraph,
    comm: jax.Array,
    sigma: jax.Array,
    k: jax.Array,
    frontier: jax.Array,
    m: jax.Array,
) -> Tuple[jax.Array, jax.Array]:
    """Per-vertex (best community, best dQ) from one snapshot (sort-reduce path)."""
    n_cap = graph.n_cap
    src, dst, w = graph.src, graph.indices, graph.weights

    # K_{i -> own community} — direct segment-sum, no sort needed.
    own = (comm[dst] == comm[src]) & (dst != src)
    k_to_own = jax.ops.segment_sum(
        jnp.where(own, w, 0.0), src, num_segments=n_cap + 1
    )

    order, s_src, s_c, k_i_to_c = scan_communities_sorted(graph, comm)
    c_own = comm[s_src]
    dq = delta_modularity(
        k_i_to_c, k_to_own[s_src], k[s_src], sigma[s_c], sigma[c_own], m
    )
    valid = (s_c != c_own) & (s_src != n_cap) & (s_c != n_cap) & frontier[s_src]
    dq = jnp.where(valid, dq, _NEG_INF)

    best_dq = jax.ops.segment_max(dq, s_src, num_segments=n_cap + 1)
    best_dq = jnp.where(jnp.isfinite(best_dq), best_dq, _NEG_INF)
    is_best = (dq == best_dq[s_src]) & valid
    best_c = jax.ops.segment_min(
        jnp.where(is_best, s_c, n_cap), s_src, num_segments=n_cap + 1
    )
    # Empty segments yield iinfo.max — clamp into the sentinel slot.
    best_c = jnp.minimum(best_c, n_cap)
    return best_c, best_dq


class SortReduceScanner(ReplicatedScannerBase):
    """Engine backend: CSR sort-reduce scan on a single device.

    Local layout == replicated layout ((n_cap + 1,) with the sentinel slot);
    all topology hooks are the identities from ``ReplicatedScannerBase``.
    """

    def __init__(self, graph: CSRGraph, k: jax.Array, m: jax.Array):
        super().__init__(graph.n_cap, graph.n_valid, k)
        self.graph = graph
        self.m = m

    def scan(self, comm, sigma, frontier):
        return best_moves(self.graph, comm, sigma, self.k_local, frontier,
                          self.m)

    def mark_neighbors(self, moved: jax.Array) -> jax.Array:
        g = self.graph
        marked = jax.ops.segment_max(
            moved[g.src].astype(jnp.int32), g.indices,
            num_segments=g.n_cap + 1)
        return marked > 0


def louvain_move(
    graph: CSRGraph,
    comm: jax.Array,
    sigma: jax.Array,
    k: jax.Array,
    m: jax.Array,
    *,
    tolerance: jax.Array,
    max_iterations: int = 20,
    use_pruning: bool = True,
    gate_fraction: int = 2,
    frontier0: jax.Array | None = None,
) -> MoveState:
    """Algorithm 2 on the sort-reduce backend — a thin engine adapter.

    ``comm``/``sigma`` may be ANY consistent membership + community-weight
    snapshot, not just the singleton start — warm starts (dynamic Louvain)
    pass the previous membership here.  ``frontier0`` optionally restricts
    the first round to a seed set (delta screening); ``None`` means all
    valid vertices.  Sweep/tolerance/gating semantics are the engine's — see
    ``repro.core.engine.MoveEngine``.
    """
    valid = jnp.arange(graph.n_cap + 1) < graph.n_valid
    frontier0 = valid if frontier0 is None else (frontier0 & valid)
    engine = MoveEngine(
        SortReduceScanner(graph, k, m),
        EngineConfig(max_iterations=max_iterations, use_pruning=use_pruning,
                     gate_fraction=gate_fraction))
    return engine.run(comm, sigma, frontier0, tolerance)
