"""Local-moving phase (Algorithm 2) as synchronous data-parallel rounds.

The paper's asynchronous per-thread moves (OpenMP atomics) have no efficient
analogue in a bulk-synchronous XLA program, so GVE-Louvain's local-moving is
recast as rounds: every frontier vertex computes its best move against the
*same* snapshot of (C, Sigma), then all moves are applied at once (cf. the GPU
adaptations the paper cites, Naim et al. / Cheong et al.).

The per-thread collision-free Far-KV hashtable of scanCommunities() becomes a
sort-reduce: edges are grouped by (src, C[dst]) with a lexicographic sort and
the per-community weights K_{i->c} are segment-sums over the groups.  A Pallas
ELL kernel implementing the same scan as a dense pairwise compare lives in
``repro.kernels.louvain_scan`` and is used via the `use_ell_kernel` path.

Safeguards against synchronous oscillation (Vite lineage):
  - deterministic tie-break to the lowest community id,
  - the singleton-swap guard: two singleton communities may only merge in the
    direction of the smaller id.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.graph import CSRGraph
from repro.core.modularity import delta_modularity

_NEG_INF = -jnp.inf


class MoveState(NamedTuple):
    comm: jax.Array      # (n_cap + 1,) int32, sentinel slot = n_cap
    sigma: jax.Array     # (n_cap + 1,) float32 community total weights
    frontier: jax.Array  # (n_cap + 1,) bool
    iters: jax.Array     # () int32 — iterations performed
    dq: jax.Array        # () float32 — total dQ of the last round
    dq_sum: jax.Array    # () float32 — accumulated dQ over the pass


def scan_communities_sorted(
    graph: CSRGraph, comm: jax.Array
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Group edge slots by (src, C[dst]) and compute K_{i->c} per slot.

    Returns (order, s_src, s_c, k_i_to_c) where arrays are in sorted slot
    order.  Self-loop slots contribute 0 (K_{i->c} excludes self edges).
    """
    src, dst, w = graph.src, graph.indices, graph.weights
    cdst = comm[dst]
    order = jnp.lexsort((cdst, src))  # primary: src, secondary: community
    s_src = src[order]
    s_dst = dst[order]
    s_c = cdst[order]
    s_w = jnp.where(s_src == s_dst, 0.0, w[order])

    prev_src = jnp.concatenate([jnp.full((1,), -1, jnp.int32), s_src[:-1]])
    prev_c = jnp.concatenate([jnp.full((1,), -1, jnp.int32), s_c[:-1]])
    new_group = (s_src != prev_src) | (s_c != prev_c)
    gid = jnp.cumsum(new_group.astype(jnp.int32)) - 1
    group_w = jax.ops.segment_sum(s_w, gid, num_segments=graph.e_cap)
    return order, s_src, s_c, group_w[gid]


def best_moves(
    graph: CSRGraph,
    comm: jax.Array,
    sigma: jax.Array,
    k: jax.Array,
    frontier: jax.Array,
    m: jax.Array,
) -> Tuple[jax.Array, jax.Array]:
    """Per-vertex (best community, best dQ) from one snapshot (sort-reduce path)."""
    n_cap = graph.n_cap
    src, dst, w = graph.src, graph.indices, graph.weights

    # K_{i -> own community} — direct segment-sum, no sort needed.
    own = (comm[dst] == comm[src]) & (dst != src)
    k_to_own = jax.ops.segment_sum(
        jnp.where(own, w, 0.0), src, num_segments=n_cap + 1
    )

    order, s_src, s_c, k_i_to_c = scan_communities_sorted(graph, comm)
    c_own = comm[s_src]
    dq = delta_modularity(
        k_i_to_c, k_to_own[s_src], k[s_src], sigma[s_c], sigma[c_own], m
    )
    valid = (s_c != c_own) & (s_src != n_cap) & (s_c != n_cap) & frontier[s_src]
    dq = jnp.where(valid, dq, _NEG_INF)

    best_dq = jax.ops.segment_max(dq, s_src, num_segments=n_cap + 1)
    best_dq = jnp.where(jnp.isfinite(best_dq), best_dq, _NEG_INF)
    is_best = (dq == best_dq[s_src]) & valid
    best_c = jax.ops.segment_min(
        jnp.where(is_best, s_c, n_cap), s_src, num_segments=n_cap + 1
    )
    # Empty segments yield iinfo.max — clamp into the sentinel slot.
    best_c = jnp.minimum(best_c, n_cap)
    return best_c, best_dq


def apply_moves(
    graph: CSRGraph,
    comm: jax.Array,
    sigma: jax.Array,
    k: jax.Array,
    frontier: jax.Array,
    best_c: jax.Array,
    best_dq: jax.Array,
    move_gate: jax.Array | None = None,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Apply all positive-gain moves at once; returns (C', Sigma', frontier', dQ)."""
    n_cap = graph.n_cap
    idx = jnp.arange(n_cap + 1)
    vertex_valid = idx < graph.n_valid

    # Singleton-swap guard (Vite): two singleton communities merge only
    # towards the smaller id, breaking symmetric A<->B oscillation.
    comm_size = jax.ops.segment_sum(
        jnp.where(vertex_valid, 1, 0), comm, num_segments=n_cap + 1
    )
    own_singleton = comm_size[comm] == 1
    tgt_singleton = comm_size[best_c] == 1
    swap_blocked = own_singleton & tgt_singleton & (best_c > comm)

    do_move = (
        (best_dq > 0.0)
        & (best_c != comm)
        & (best_c < n_cap)
        & frontier
        & vertex_valid
        & ~swap_blocked
    )
    if move_gate is not None:
        do_move = do_move & move_gate

    moved_k = jnp.where(do_move, k, 0.0)
    sigma_new = (
        sigma
        + jax.ops.segment_sum(moved_k, jnp.where(do_move, best_c, n_cap),
                              num_segments=n_cap + 1)
        - jax.ops.segment_sum(moved_k, jnp.where(do_move, comm, n_cap),
                              num_segments=n_cap + 1)
    )
    comm_new = jnp.where(do_move, best_c, comm)
    dq_total = jnp.sum(jnp.where(do_move, best_dq, 0.0))

    # Vertex pruning: processed vertices leave the frontier; neighbors of
    # movers re-enter it.
    moved_src = do_move[graph.src]
    marked = jax.ops.segment_max(
        moved_src.astype(jnp.int32), graph.indices, num_segments=n_cap + 1
    )
    frontier_new = (marked > 0) & vertex_valid
    return comm_new, sigma_new, frontier_new, dq_total


def louvain_move(
    graph: CSRGraph,
    comm: jax.Array,
    sigma: jax.Array,
    k: jax.Array,
    m: jax.Array,
    *,
    tolerance: jax.Array,
    max_iterations: int = 20,
    use_pruning: bool = True,
    gate_fraction: int = 2,
    frontier0: jax.Array | None = None,
) -> MoveState:
    """Algorithm 2: iterate rounds until total dQ <= tolerance or the cap.

    ``comm``/``sigma`` may be ANY consistent membership + community-weight
    snapshot, not just the singleton start — warm starts (dynamic Louvain)
    pass the previous membership here.  ``frontier0`` optionally restricts
    the first round to a seed set (delta screening); ``None`` means all
    valid vertices.  With ``use_pruning`` the frontier then grows outward
    from movers exactly as in the static pruned phase.

    ``gate_fraction > 1`` enables stochastic round gating: each round only a
    pseudo-random 1/gate_fraction of vertices may move.  This damps the
    synchronous pile-on/oscillation pathology of bulk-synchronous Louvain at
    the cost of more (cheaper-converging) rounds; vertices not selected stay
    in the frontier.  ``gate_fraction=1`` disables the gate (pure greedy).
    """
    n_cap = graph.n_cap
    idx = jnp.arange(n_cap + 1)
    valid = idx < graph.n_valid
    frontier0 = valid if frontier0 is None else (frontier0 & valid)

    def cond(st: MoveState):
        return (st.iters < max_iterations) & (st.dq > tolerance)

    def one_round(st: MoveState, round_ix: jax.Array) -> MoveState:
        frontier = st.frontier if use_pruning else frontier0
        best_c, best_dq = best_moves(graph, st.comm, st.sigma, k, frontier, m)
        if gate_fraction > 1:
            # Cheap per-(vertex, round) hash — Weyl sequence on odd constants.
            h = (idx.astype(jnp.int32) * jnp.int32(-1640531535)  # 2654435761 as i32
                 + round_ix.astype(jnp.int32) * jnp.int32(40503))
            gate = jnp.abs(h >> 13) % gate_fraction == 0
        else:
            gate = None
        comm, sigma, frontier_new, dq = apply_moves(
            graph, st.comm, st.sigma, k, frontier, best_c, best_dq, gate
        )
        if gate is not None:
            # Unselected frontier vertices were not processed — keep them hot.
            frontier_new = frontier_new | (frontier & ~gate)
        return MoveState(comm, sigma, frontier_new, st.iters, st.dq + dq,
                         st.dq_sum + dq)

    def body(st: MoveState) -> MoveState:
        # One paper-"iteration" = one sweep = gate_fraction gated rounds, so
        # that tolerance/iteration-cap semantics match the paper's full sweeps.
        st = st._replace(dq=jnp.asarray(0.0, jnp.float32))
        base = st.iters * gate_fraction
        for r in range(gate_fraction):
            st = one_round(st, base + r)
        return st._replace(iters=st.iters + 1)

    # Prime with dq = +inf so the loop always runs at least one sweep.
    st0 = MoveState(comm, sigma, frontier0, jnp.asarray(0, jnp.int32),
                    jnp.asarray(jnp.inf, jnp.float32),
                    jnp.asarray(0.0, jnp.float32))
    return jax.lax.while_loop(cond, body, st0)
