"""Communication-lean exchange primitives for the sharded paths.

GVE-Louvain's per-iteration cost model assumes data movement proportional to
the TOUCHED work; the sharded baseline instead ships dense O(n_pad) state
every round (the Vite-style ghost exchange as whole-array collectives).  The
delta backend (``repro.core.distributed.DeltaShardedScanner``) ships only
what changed, built from the pure, mesh-free primitives in this module:

  * ``pack_bits`` / ``unpack_bits`` — bit-pack integer labels into dense
    uint32 lanes at the minimum width for the layout (a moved-vertex label
    needs ceil(log2(n_pad + 1)) bits, not 32), the gnn_compress-style lane
    packing from the ROADMAP.
  * ``compact_movers`` — rank-compact the (local index, new label) pairs of
    vertices that actually moved into a static-capacity buffer.  Movers are
    all the delta backend ships: Sigma deltas and community sizes are
    reconstructed on the receiver from the replicated vertex weights and
    membership.
  * ``topk_touched_deltas`` — the per-shard top-k touched communities and
    their delta values, mask-deduplicated and rank-compacted: the general
    shipping primitive for per-community payloads a receiver CANNOT
    reconstruct (e.g. Sigma deltas on topologies that do not replicate
    vertex weights).
  * ``boundary_mask`` — the halo-set constructor of the HYBRID state
    layout: which owned vertices have a live remote neighbour and must
    therefore publish their membership label each round.  Everything else
    an owned vertex does stays shard-local under hybrid.
  * ``comm_plan`` / ``phase_bytes`` — host-side bytes-on-wire accounting
    from static shapes + measured round counts (the ``BENCH_distdyn.json``
    ``bytes_per_round`` column), including the hybrid layout's
    boundary-mover and touched-community lanes and its one-per-phase
    membership resync fold.

Everything here is plain jnp on one shard's arrays — no collectives — so the
whole layer is property-testable without a mesh (tests/test_comm_delta.py).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


def label_bits(n_values: int) -> int:
    """Minimum lane width (bits) encoding values in ``[0, n_values)``."""
    if n_values <= 1:
        return 1
    return int(n_values - 1).bit_length()


def packed_lanes(count: int, width: int) -> int:
    """uint32 lanes holding ``count`` values of ``width`` bits each."""
    return -(-(count * width) // 32)


def pack_bits(values: jax.Array, width: int) -> jax.Array:
    """Bit-pack ``(k,)`` integers in ``[0, 2**width)`` into uint32 lanes.

    Little-endian bit order: value i occupies global bits
    ``[i * width, (i + 1) * width)``; a value may straddle two lanes.
    Values are masked to ``width`` bits (callers encode their sentinel
    within the width).  Inverse: ``unpack_bits(lanes, width, k)``.
    """
    if not 1 <= width <= 32:
        raise ValueError(f"width must be in [1, 32]; got {width}")
    k = values.shape[0]
    lanes = packed_lanes(k, width)
    mask = jnp.uint32((1 << width) - 1)
    vals = values.astype(jnp.uint32) & mask
    start = jnp.arange(k, dtype=jnp.uint32) * jnp.uint32(width)
    lane0 = (start // 32).astype(jnp.int32)
    off = start % 32
    lo = (vals << off).astype(jnp.uint32)
    # A shift by 32 is undefined; guard the straddle part (off == 0 means
    # the value is wholly inside lane0 and contributes nothing upward).
    hi_shift = jnp.where(off > 0, jnp.uint32(32) - off, jnp.uint32(0))
    hi = jnp.where(off > 0, vals >> hi_shift, jnp.uint32(0))
    # Disjoint bit ranges per lane, so scatter-add assembles without carries.
    buf = jnp.zeros((lanes + 1,), jnp.uint32)
    buf = buf.at[lane0].add(lo).at[lane0 + 1].add(hi)
    return buf[:lanes]


def unpack_bits(lanes: jax.Array, width: int, count: int) -> jax.Array:
    """Inverse of ``pack_bits``: ``(L,)`` uint32 lanes -> ``(count,)`` int32."""
    if not 1 <= width <= 32:
        raise ValueError(f"width must be in [1, 32]; got {width}")
    start = jnp.arange(count, dtype=jnp.uint32) * jnp.uint32(width)
    lane0 = (start // 32).astype(jnp.int32)
    off = start % 32
    ext = jnp.concatenate([lanes.astype(jnp.uint32),
                           jnp.zeros((1,), jnp.uint32)])
    lo = ext[lane0] >> off
    hi_shift = jnp.where(off > 0, jnp.uint32(32) - off, jnp.uint32(0))
    hi = jnp.where(off > 0, ext[lane0 + 1] << hi_shift, jnp.uint32(0))
    mask = jnp.uint32((1 << width) - 1)
    return ((lo | hi) & mask).astype(jnp.int32)


def compact_movers(flag: jax.Array, values: jax.Array, cap: int, fill):
    """Rank-compact flagged slots' (local index, value) into static buffers.

    Returns ``(idx_buf (cap,), val_buf (cap,), n_flagged)``: ``idx_buf``
    holds LOCAL slot indices of the first ``cap`` flagged entries (empty
    slots carry ``L = len(flag)``, the local sentinel), ``val_buf`` their
    values (empty slots carry ``fill``).  Entries beyond ``cap`` are
    dropped — ``n_flagged`` is the TRUE uncapped count, so callers detect
    ``n_flagged > cap`` and take a dense fallback.
    """
    L = flag.shape[0]
    rank = jnp.cumsum(flag.astype(jnp.int32)) - 1
    keep = flag & (rank < cap)
    slot = jnp.where(keep, rank, cap)
    idx = jnp.arange(L, dtype=jnp.int32)
    idx_buf = jnp.full((cap + 1,), L, jnp.int32).at[slot].set(
        jnp.where(keep, idx, L))[:cap]
    val_buf = jnp.full((cap + 1,), fill, values.dtype).at[slot].set(
        jnp.where(keep, values, fill))[:cap]
    return idx_buf, val_buf, jnp.sum(flag.astype(jnp.int32))


def topk_touched_deltas(delta: jax.Array, touched: jax.Array, cap: int,
                        sent: int):
    """Touched communities and their delta values, rank-compacted.

    ``touched`` is a dense ``(sent + 1,)`` bool mask of communities whose
    value changed (slot ``sent`` is ignored); ``delta`` the dense
    per-community value to ship.  Returns ``(c_buf (cap,), d_buf (cap,),
    n_touched)`` with the first ``cap`` touched ids in ascending order
    (empty slots: ``sent`` / 0); ``n_touched`` is the TRUE count, so
    ``n_touched > cap`` flags overflow for the dense fallback.  Mask-based
    on purpose: the caller already holds dense add/sub reductions, so
    deduplicated ascending ids fall out of a cumsum — no sort.
    """
    ids = jnp.arange(touched.shape[0], dtype=jnp.int32)
    live = touched & (ids < sent)
    rank = jnp.cumsum(live.astype(jnp.int32)) - 1
    keep = live & (rank < cap)
    slot = jnp.where(keep, rank, cap)
    c_buf = jnp.full((cap + 1,), sent, jnp.int32).at[slot].set(
        jnp.where(keep, ids, sent))[:cap]
    d_buf = jnp.zeros((cap + 1,), delta.dtype).at[slot].set(
        jnp.where(keep, delta, 0))[:cap]
    return c_buf, d_buf, jnp.sum(live.astype(jnp.int32))


def boundary_mask(src_l: jax.Array, dst_l: jax.Array, v0, v_per: int,
                  sent: int) -> jax.Array:
    """Owned vertices with at least one live remote neighbour — the halo
    publishers of the hybrid state layout.

    ``src_l`` / ``dst_l`` are ONE shard's directed slot arrays (sentinel =
    ``sent`` marks dead slots), ``v0`` its first owned vertex id.  Returns a
    ``(v_per,)`` bool mask: ``mask[i]`` iff vertex ``v0 + i`` owns a live
    slot whose dst lies outside ``[v0, v0 + v_per)``.  Because the
    partition places slot ``(u, v)`` on owner(u) AND ``(v, u)`` on
    owner(v), any remote dst a shard reads is, by this same construction
    on its owner, a boundary vertex there — so per-round label exchange
    restricted to boundary movers keeps every cross-shard read fresh.
    Pure jnp on one shard's arrays; property-tested without a mesh.
    """
    live = (src_l < sent) & (dst_l < sent)
    remote = live & ((dst_l < v0) | (dst_l >= v0 + v_per))
    loc = jnp.clip(jnp.where(remote, src_l - v0, v_per), 0, v_per)
    return (jnp.zeros((v_per + 1,), bool).at[loc].set(True)[:v_per]
            & (jnp.arange(v_per) + v0 < sent))


def size_delta_width(v_per: int) -> int:
    """Lane width for a per-community SIZE delta under the hybrid layout.

    One round's size delta at a community is bounded by the shard's owned
    movers, so it lives in ``[-v_per, v_per]`` and ships offset-encoded as
    ``delta + v_per`` in ``label_bits(2 * v_per + 1)`` bits.
    """
    return label_bits(2 * int(v_per) + 1)


class CommPlan(NamedTuple):
    """Static bytes-on-wire accounting for ONE engine round.

    Host-side arithmetic over the layout's static shapes (each shard's
    contribution to every collective, summed over shards); combined with
    the MEASURED per-phase round/fallback counters it yields the
    ``bytes_per_round`` column of ``BENCH_distdyn.json``.  ``round_bytes``
    prices a regular round of the backend; ``fallback_bytes`` a delta
    round that overflowed its caps and took the dense-exchange branch
    (== ``round_bytes`` for the gather backend, which has no fallback).
    """

    backend: str
    n_shards: int
    move_cap: int
    idx_width: int
    lab_width: int
    round_bytes: int
    fallback_bytes: int
    #: State layout the plan prices ("replicated" | "hybrid").  Hybrid
    #: replaces the dense per-round state exchange with boundary-mover
    #: label pairs plus aggregated touched-community (Sigma, size) delta
    #: lanes, and adds ONE owned-membership resync fold per phase.
    state_layout: str = "replicated"
    #: Touched-community lane capacity of a hybrid round (0 otherwise).
    touched_cap: int = 0
    #: Per-round share spent on the boundary-mover label lanes (all
    #: shards) — the BENCH ``halo_bytes_per_round`` column.
    halo_round_bytes: int = 0
    #: One-per-phase fixed cost (the hybrid end-of-phase membership
    #: resync all_gather); ``phase_bytes`` adds it once per phase.
    phase_fixed_bytes: int = 0


def comm_plan(backend: str, n_shards: int, v_per: int, n_pad: int,
              move_cap: int = 0, *, state_layout: str = "replicated",
              touched_cap: int = 0) -> CommPlan:
    """Price one engine round for a layout under ``backend``.

    REPLICATED layout: per shard per round the gather backend ships its
    owned membership slice (int32) + moved mask (bool) + two dense O(n_pad)
    psums (Sigma f32 and community sizes int32) + the dq scalar; the delta
    backend replaces all of that with ONE fused wire word — the mover count
    + the local dq + the bit-packed mover lanes (fused (index, label) pairs
    when they fit an int32).  Sigma and community sizes are reconstructed
    locally from the replicated vertex weights and membership, and the
    moved mask is a label compare, so no per-community payload travels at
    all.  On overflow the wire has already travelled, then the dense comm
    + Sigma exchange runs on top.

    HYBRID layout (``state_layout="hybrid"``): per-vertex working state
    stays owner-partitioned (K_i is never replicated), so every round ships
    exactly one fused word of (a) bit-packed BOUNDARY-mover (index, label)
    pairs — capacity ``move_cap`` — and (b) aggregated touched-community
    Sigma/size delta lanes — capacity ``touched_cap`` — plus a 12-byte
    header (two counts + dq).  Under the gather backend the caps are the
    worst case (``v_per`` / ``2 * v_per``) so a hybrid-gather round can
    never overflow; under delta they are the policy caps and overflow takes
    a dense resync fallback (owned comm slice + moved mask + two dense
    psums on top of the wire).  ``phase_fixed_bytes`` prices the one
    end-of-phase owned-membership all_gather that re-replicates the phase
    output for the (unchanged) renumber/aggregation consumers.
    """
    rep = n_pad + 1
    if state_layout not in ("replicated", "hybrid"):
        raise ValueError(f"comm_plan state_layout must be 'replicated' or "
                         f"'hybrid'; got {state_layout!r}")
    if backend not in ("gather", "delta"):
        raise ValueError(f"comm_plan backend must be 'gather' or 'delta'; "
                         f"got {backend!r}")
    if state_layout == "hybrid":
        iw = label_bits(v_per + 1)
        lw = label_bits(n_pad + 1)
        if backend == "gather":      # worst-case caps: overflow-free
            move_cap, touched_cap = v_per, 2 * v_per
        if iw + lw <= 31:
            mover_lanes = packed_lanes(move_cap, iw + lw)
        else:
            mover_lanes = (packed_lanes(move_cap, iw)
                           + packed_lanes(move_cap, lw))
        tid_lanes = packed_lanes(touched_cap, lw)
        siz_lanes = packed_lanes(touched_cap, size_delta_width(v_per))
        round_b = 12 + 4 * (mover_lanes + tid_lanes + touched_cap
                            + siz_lanes)
        if backend == "gather":
            fallback = round_b
        else:
            fallback = round_b + v_per * 4 + v_per + 2 * rep * 4
        return CommPlan(backend, n_shards, move_cap, iw, lw,
                        n_shards * round_b, n_shards * fallback,
                        state_layout="hybrid", touched_cap=touched_cap,
                        halo_round_bytes=n_shards * 4 * mover_lanes,
                        phase_fixed_bytes=n_shards * v_per * 4)
    if backend == "gather":
        per_shard = (v_per * 4 + v_per * 1 + rep * 4 + 4   # comm+moved+
                     + rep * 4)                            # Sigma+dq+sizes
        return CommPlan("gather", n_shards, 0, 0, 0,
                        n_shards * per_shard, n_shards * per_shard)
    iw = label_bits(v_per + 1)
    lw = label_bits(n_pad + 1)
    if iw + lw <= 31:
        mover_lanes = packed_lanes(move_cap, iw + lw)
    else:
        mover_lanes = packed_lanes(move_cap, iw) + packed_lanes(move_cap, lw)
    delta = mover_lanes * 4 + 8                   # lanes + count + dq
    fallback = delta + v_per * 4 + rep * 4        # wire, then comm + Sigma
    return CommPlan("delta", n_shards, move_cap, iw, lw,
                    n_shards * delta, n_shards * fallback)


def reshard_bytes(e_slots_old: int, e_slots_new: int) -> int:
    """One-time cost of a pass-boundary coarse re-shard, in bytes.

    A re-shard pulls the padded coarse edge arrays out of the OLD layout
    (src, dst int32 + weight f32 = 12 B per slot) and pushes the relabelled
    arrays back in the NEW layout — every slot crosses the wire exactly
    once in each direction, so the price is 12 B over both layouts' total
    edge slots (``n_shards * e_per_shard`` each).  Host arithmetic only;
    pairs with the measured ``reshard_passes`` counter the same way
    ``round_bytes`` pairs with the round counters.
    """
    return 12 * (int(e_slots_old) + int(e_slots_new))


def phase_bytes(plan: CommPlan, rounds: int, fallback_rounds: int = 0,
                reshard_cost: int = 0) -> int:
    """Total bytes on the wire for a move phase of ``rounds`` rounds, of
    which ``fallback_rounds`` overflowed the delta caps.  ``reshard_cost``
    adds the one-time pass-boundary re-shard bytes (``reshard_bytes``)
    when the pass re-balanced its owner ranges.  A hybrid plan's
    ``phase_fixed_bytes`` (the end-of-phase membership resync fold) is
    added once whenever the phase ran at least one round."""
    fb = min(int(fallback_rounds), int(rounds))
    fixed = plan.phase_fixed_bytes if int(rounds) > 0 else 0
    return ((int(rounds) - fb) * plan.round_bytes + fb * plan.fallback_bytes
            + int(reshard_cost) + fixed)
