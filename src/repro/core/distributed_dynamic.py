"""Sharded streaming Louvain: distributed warm-start + delta screening.

The distributed layer (``repro.core.distributed``) ran batch-only: every
stream update meant a fresh partition and a cold singleton start.  This
module turns it into the serving-shaped streaming system of the ROADMAP by
porting the dynamic machinery (``repro.core.dynamic``) to the 1-D vertex
partition, the same way Vite/Ghosh-style distributed Louvain keeps ghost and
community state resident across rounds instead of rebuilding it:

  * **Sharded batch apply** — an ``EdgeBatch`` of undirected ``{u, v} -> w``
    assignments is applied directly to the partitioned per-shard edge arrays
    inside ``shard_map``.  Each shard materializes the batch's directed slots
    it owns (slot (u, v) lives on owner(u)) and resolves them against its
    existing slots with the same key/rank sort-reduce as the single-device
    CSR apply (``repro.core.delta.sort_reduce_apply_slots``) — compiled
    shapes never change across the stream.
  * **Warm start + delta screening** — the move phase resumes from the
    previous replicated membership; the seed frontier is the touched
    endpoints plus their communities' members.  Touched ownership is local
    (every changed directed slot's src is owned), so the global mask is one
    ``all_gather`` of touched-owned slices; the frontier math itself is the
    shared ``repro.core.louvain.screened_frontier``.
  * **Capacity growth** — a batch that would overflow ``e_per_shard``
    re-buckets host-side into doubled capacity (``bucket_slots_host``),
    rebuilds the jit'd phases once, and re-applies, instead of raising —
    unbounded streams keep running.
  * **Skew-aware re-sharding** — coarse-graph ownership skew inside the
    pass loop is no longer absorbed by capacity growth alone: with
    ``config.reshard="auto"`` the pass loop re-balances the coarse owner
    ranges by measured edge load after each aggregation
    (``distributed.sharded_louvain_passes``), so one hot shard stops
    setting the fleet's capacity tier; the one-time relabel traffic is
    priced into the stream's bytes accounting and surfaced as the
    ``reshard_*`` result fields.  Capacity doubling remains the backstop
    for residual skew (e.g. a single dominant coarse vertex).

``louvain_dynamic_sharded`` is the multi-device analogue of
``louvain_dynamic`` and reports the same ``BatchUpdateStats`` per batch.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.delta import EdgeBatch, sort_reduce_apply_slots
from repro.core.distributed import (ShardedGraphSpec,
                                    _rebucket_live_host, _shard_index,
                                    make_distributed_aggregate,
                                    make_distributed_move,
                                    make_tier_phases,
                                    partition_graph_host,
                                    sentinel_forced_membership,
                                    sharded_louvain_passes,
                                    sharded_modularity)
from repro.core.dynamic import BatchUpdateStats
from repro.core.engine import affected_frontier, normalize_screening
from repro.core.graph import CSRGraph
from repro.core.louvain import LouvainConfig, pad_membership


def apply_batch_shard(spec: ShardedGraphSpec, shard_ix,
                      src_l, dst_l, w_l, b_src, b_dst, b_w, b_valid,
                      n_limit: Optional[int] = None,
                      backend: str = "xla"):
    """Per-shard batch apply: resolve the owned directed batch slots against
    this shard's (e_per_shard,) slot arrays via the shared sort-reduce.

    Pure jnp (no collectives), so it is property-testable shard-by-shard
    without a mesh.  An undirected assignment {u, v} -> w materializes as
    slot (u, v) on owner(u) and (v, u) on owner(v); a self loop u == v gets
    one slot on owner(u) — matching the CSR convention, so the union of all
    shards' slots equals the single-device ``apply_edge_batch`` result.
    ``n_limit`` is the logical vertex capacity (the CSR ``n_cap``); entries
    with an endpoint >= n_limit are dropped exactly like the single-device
    apply drops them (n_pad can exceed n_cap when n_cap % n_shards != 0).

    Returns (src', dst', w', touched_own (v_per,), e_new) where ``e_new`` is
    the uncapped owned live-slot count (> e_per_shard signals overflow) and
    ``touched_own`` marks owned vertices whose incident weights changed.
    """
    sent = spec.sentinel
    lim = sent if n_limit is None else n_limit
    v_per, e_per = spec.v_per_shard, spec.e_per_shard
    v0 = shard_ix * v_per
    b_cap = b_src.shape[0]

    b_idx = jnp.arange(b_cap)
    u = b_src.astype(jnp.int32)
    v = b_dst.astype(jnp.int32)
    b_live = (b_idx < b_valid) & (u < lim) & (v < lim)
    own_u = (u >= v0) & (u < v0 + v_per)
    own_v = (v >= v0) & (v < v0 + v_per)
    live_fwd = b_live & own_u
    live_rev = b_live & own_v & (u != v)
    d_src = jnp.concatenate([jnp.where(live_fwd, u, sent),
                             jnp.where(live_rev, v, sent)])
    d_dst = jnp.concatenate([jnp.where(live_fwd, v, sent),
                             jnp.where(live_rev, u, sent)])
    d_w = jnp.concatenate([jnp.where(live_fwd, b_w, 0.0),
                           jnp.where(live_rev, b_w, 0.0)])

    # Unified slot list: existing first (rank 0), batch after (rank = 1 + i
    # so later batch entries win ties — last-write-wins within one batch).
    all_src = jnp.concatenate([src_l, d_src])
    all_dst = jnp.concatenate([dst_l, d_dst])
    all_w = jnp.concatenate([w_l, d_w]).astype(jnp.float32)
    is_batch = jnp.concatenate([jnp.zeros(e_per, bool),
                                jnp.ones(2 * b_cap, bool)])
    rank = jnp.concatenate([
        jnp.zeros(e_per, jnp.int32),
        1 + (jnp.arange(2 * b_cap, dtype=jnp.int32) % b_cap),
    ])
    out_src, out_dst, out_w, e_new, chg_src, _ = sort_reduce_apply_slots(
        all_src, all_dst, all_w, rank, is_batch, sent, e_per, backend)

    # Every changed slot's src is owned here; the mirror shard marks the dst
    # endpoint via its own (v, u) slot — no cross-shard scatter needed.
    loc = jnp.clip(jnp.where(chg_src < sent, chg_src - v0, v_per), 0, v_per)
    touched_own = jnp.zeros((v_per + 1,), bool).at[loc].set(True)[:v_per]
    return out_src, out_dst, out_w, touched_own, e_new


@functools.lru_cache(maxsize=None)
def make_sharded_batch_apply(mesh: Mesh, axes: Tuple[str, ...],
                             spec: ShardedGraphSpec,
                             n_limit: Optional[int] = None,
                             backend: str = "xla",
                             traced_n_limit: bool = False):
    """Build the jit'd sharded batch apply for a fixed mesh/layout.

    Returns fn(src_g, dst_g, w_g, b_src, b_dst, b_w, b_valid, n_valid)
        -> (src_g', dst_g', w_g', touched (n_pad + 1,), e_max, n_valid')
    with edge arrays in the partitioned layout, the touched mask replicated
    (ONE all_gather of touched-owned slices), and ``e_max`` the worst
    shard's uncapped slot count (overflow signal).  ``backend`` picks the
    group-resolve implementation (``"xla"`` / ``"pallas"``).

    With ``traced_n_limit`` the returned fn takes the logical vertex
    capacity as one extra TRACED replicated operand (after ``n_valid``)
    instead of baking it into the compiled body — ``apply_batch_shard``
    only ever compares against it, so the math is identical.  The serving
    fleet uses this to share one compiled apply across tenants whose
    logical ``n_cap`` differ within a capacity bucket (and to vmap the
    apply over tenant lanes with per-lane capacities).
    """
    edge_spec = P(axes)
    rep = P()

    def apply_fn(src_g, dst_g, w_g, b_src, b_dst, b_w, b_valid, n_valid,
                 n_limit_op=None):
        def body(src_l, dst_l, w_l, b_src, b_dst, b_w, b_valid, n_valid,
                 *lim_rest):
            shard_ix = _shard_index(axes)
            lim = lim_rest[0] if lim_rest else n_limit
            src2, dst2, w2, touched_own, e_new = apply_batch_shard(
                spec, shard_ix, src_l, dst_l, w_l, b_src, b_dst, b_w,
                b_valid, lim, backend)
            touched = jax.lax.all_gather(touched_own, axes, tiled=True)
            touched = jnp.concatenate([touched, jnp.zeros((1,), bool)])
            e_max = jax.lax.pmax(e_new, axes)
            # Batch endpoints may extend the valid-vertex prefix.
            mx = jnp.max(jnp.where(touched, jnp.arange(spec.n_pad + 1), -1))
            n_valid_new = jnp.maximum(n_valid, (mx + 1).astype(jnp.int32))
            return src2, dst2, w2, touched, e_max, n_valid_new

        operands = (src_g, dst_g, w_g, b_src, b_dst, b_w, b_valid, n_valid)
        in_specs = (edge_spec, edge_spec, edge_spec, rep, rep, rep, rep, rep)
        if traced_n_limit:
            operands = operands + (n_limit_op,)
            in_specs = in_specs + (rep,)
        fn = shard_map(
            body, mesh=mesh,
            in_specs=in_specs,
            out_specs=(edge_spec, edge_spec, edge_spec, rep, rep, rep),
            check_rep=False,
        )
        return fn(*operands)

    if not traced_n_limit:
        def apply_static(src_g, dst_g, w_g, b_src, b_dst, b_w, b_valid,
                         n_valid):
            return apply_fn(src_g, dst_g, w_g, b_src, b_dst, b_w, b_valid,
                            n_valid)
        return jax.jit(apply_static)
    return apply_fn


def _rebucket_host(src_g, dst_g, w_g, spec: ShardedGraphSpec):
    """Pull live slots to the host and re-bucket into ``spec``'s layout
    (the shared ``distributed._rebucket_live_host`` body; growth callers
    size ``spec`` so the ownership always fits — a layout the slots don't
    fit is a caller bug, not a retry case)."""
    src2, dst2, w2, spec2 = _rebucket_live_host(src_g, dst_g, w_g,
                                                spec.sentinel, spec)
    if spec2 != spec:
        raise ValueError(
            f"slots do not fit the caller-sized layout: needed "
            f"e_per_shard={spec2.e_per_shard} > {spec.e_per_shard}")
    return src2, dst2, w2


def _build_phases(mesh, axes, spec, config: LouvainConfig,
                  n_limit: Optional[int] = None, backend: str = "xla",
                  comm_backend: str = "gather",
                  state_layout: str = "replicated"):
    move = make_distributed_move(
        mesh, axes, spec, max_iterations=config.max_iterations,
        gate_fraction=config.gate_fraction, use_pruning=config.use_pruning,
        comm_backend=comm_backend, state_layout=state_layout)
    agg = make_distributed_aggregate(mesh, axes, spec)
    apply_fn = make_sharded_batch_apply(mesh, axes, spec, n_limit, backend)
    return move, agg, apply_fn


@dataclasses.dataclass
class ShardedDynamicResult:
    membership: np.ndarray       # (n_valid,) final community per vertex
    n_communities: int
    batch_stats: List[BatchUpdateStats]
    total_seconds: float
    n_regrows: int               # capacity-growth re-bucketing events
    spec: ShardedGraphSpec       # final layout (e_per_shard may have grown)
    comm_backend: str = "gather"          # resolved exchange backend
    comm_rounds: int = 0                  # engine rounds across the stream
    comm_fallback_rounds: int = 0         # rounds the delta caps overflowed
    bytes_on_wire: int = 0                # total move-phase exchange bytes
    reshard_passes: int = 0               # skew-aware owner re-shards
    reshard_bytes: int = 0                # one-time relabel bytes (priced)
    #: Worst pre-/post-re-shard shard load fraction observed across the
    #: stream (None when no pass re-sharded).
    max_shard_load_frac_before: Optional[float] = None
    max_shard_load_frac_after: Optional[float] = None
    #: Largest per-shard COARSE edge tier any pass ran at — the capacity
    #: tier the skew check is trying to keep down.
    coarse_e_per_max: int = 0
    #: Resolved working-state layout ("replicated" | "hybrid") and its
    #: accounting: boundary-mover bytes across the stream and the measured
    #: boundary fraction of the fine partition (None under replicated).
    state_layout: str = "replicated"
    halo_bytes: int = 0
    boundary_frac: Optional[float] = None
    #: Summed per-pass wall-clock across every batch's pass loop (the
    #: measured-time signal the reshard="auto" policy is validated
    #: against; aggregation and re-buckets included).
    pass_seconds_total: float = 0.0

    @property
    def updates_per_second(self) -> float:
        edges = sum(s.batch_size for s in self.batch_stats)
        return edges / max(self.total_seconds, 1e-12)

    @property
    def bytes_per_round(self) -> float:
        return self.bytes_on_wire / max(self.comm_rounds, 1)

    @property
    def halo_bytes_per_round(self) -> float:
        return self.halo_bytes / max(self.comm_rounds, 1)


def louvain_dynamic_sharded(
    graph: CSRGraph,
    mesh: Mesh,
    axes: Tuple[str, ...],
    batches: Sequence[EdgeBatch],
    prev: Optional[np.ndarray] = None,
    config: LouvainConfig = LouvainConfig(),
    *,
    screening=True,
    track_modularity: bool = False,
    grow_capacity: bool = True,
    e_per_shard: Optional[int] = None,
    apply_backend: str = "xla",
) -> ShardedDynamicResult:
    """Stream edge batches through warm-started sharded Louvain.

    The distributed counterpart of ``louvain_dynamic``: the graph is
    partitioned ONCE (1-D vertex partition over all ``axes``, with vertex
    capacity ``graph.n_cap`` and edge headroom ``e_per_shard``), then every
    batch is (a) applied in-layout inside ``shard_map``, (b) delta-screened
    into a seed frontier, and (c) re-optimized from the previous replicated
    membership via the shared sharded pass loop.  A batch overflowing
    ``e_per_shard`` triggers host-side re-bucketing into doubled capacity
    (one recompile) when ``grow_capacity`` is set, else raises.

    ``prev`` is the membership of ``graph`` before the stream; ``None`` runs
    one cold sharded pass loop to produce it.  Batches of equal ``b_cap``
    reuse one compiled apply; mixed capacities recompile per distinct size.
    ``screening`` picks the seed-frontier policy (``True``/``"community"``,
    ``"vertex"`` for DF-style per-vertex flags, ``"auto"`` to pick per
    batch from the touched-set size, ``False`` for pure naive-dynamic);
    ``apply_backend`` the batch-apply group-resolve;
    ``config.comm_backend`` the per-round exchange ("gather" | "delta" |
    "auto") — memberships are invariant to it, and the result carries the
    stream's bytes-on-wire accounting (``bytes_per_round``).
    ``config.state_layout`` picks the working-state placement
    ("replicated" | "hybrid" | "auto"; auto measures the fine partition's
    boundary fraction once — memberships are invariant to this too, and
    the result carries ``state_layout`` / ``halo_bytes_per_round`` /
    ``boundary_frac``).
    ``config.refine="leiden"`` runs the constrained refinement sweep inside
    every batch's pass loop (see ``sharded_louvain_passes``).
    ``config.reshard="auto"`` re-balances the coarse owner ranges by
    measured load after each aggregation and ``config.pipeline_fetch``
    overlaps the pass loop's host convergence decision with the next
    aggregation — both change work placement, never memberships.
    """
    from repro.configs.louvain_arch import (resolve_comm_backend,
                                            resolve_state_layout)
    from repro.core.distributed import measure_boundary_frac

    t_start = time.perf_counter()
    screen_mode = normalize_screening(screening)
    n_shards = int(np.prod([mesh.shape[a] for a in axes]))
    cb = resolve_comm_backend(config.comm_backend, n_shards)
    src_g, dst_g, w_g, spec = partition_graph_host(
        graph, n_shards, n_target=graph.n_cap)
    bfrac = (measure_boundary_frac(src_g, dst_g, spec, int(graph.n_valid))
             if n_shards > 1 and config.state_layout != "replicated"
             else None)
    sl = resolve_state_layout(config.state_layout, n_shards, bfrac)
    if e_per_shard is None:
        # Default headroom: 25% slack + room for one worst-case batch (each
        # batch adds at most 2 * b_cap directed slots to a single shard).
        b_max = max((b.b_cap for b in batches), default=1)
        e_per_shard = spec.e_per_shard + spec.e_per_shard // 4 + 2 * b_max
    if int(e_per_shard) > spec.e_per_shard:
        spec = spec._replace(e_per_shard=int(e_per_shard))
        src_g, dst_g, w_g = _rebucket_host(src_g, dst_g, w_g, spec)
    n_limit = graph.n_cap   # logical vertex capacity (n_pad may exceed it)
    move, agg, apply_fn = _build_phases(mesh, axes, spec, config, n_limit,
                                        apply_backend, cb, sl)
    sent = spec.sentinel

    # Coarse-pass ladder phases: one (move, agg) per tier layout, cached so
    # every batch's pass loop reuses the compiled phases.  The ladder only
    # touches the COARSE graphs inside the pass loop — the resident fine
    # arrays stay at stream capacity (the driver "un-ladders" by
    # construction: the next batch applies to ``src_g``/``dst_g``/``w_g``,
    # which the pass loop never mutates).
    phases_for = make_tier_phases(
        mesh, axes, max_iterations=config.max_iterations,
        gate_fraction=config.gate_fraction,
        use_pruning=config.use_pruning, comm_backend=cb,
        state_layout=sl, refine=config.refine)

    pass_kw = dict(
        max_passes=config.max_passes,
        initial_tolerance=config.initial_tolerance,
        tolerance_drop=config.tolerance_drop,
        aggregation_tolerance=config.aggregation_tolerance,
    )
    n_live = int(graph.n_valid)
    stats: List[BatchUpdateStats] = []
    touched_counts: List[jax.Array] = []
    frontier_sizes: List[jax.Array] = []
    n_regrows = 0
    comm_rounds = comm_fb = comm_bytes = halo_bytes = 0
    reshard_passes = reshard_bytes_total = coarse_e_max = 0
    load_frac_before = load_frac_after = None
    pass_seconds = 0.0

    def _grow_to(e_per_new: int):
        """Re-bucket the resident fine arrays into grown capacity and
        rebuild the jit'd phases (one recompile per growth step)."""
        nonlocal spec, src_g, dst_g, w_g, move, agg, apply_fn, n_regrows
        spec = spec._replace(e_per_shard=int(e_per_new))
        src_g, dst_g, w_g = _rebucket_host(src_g, dst_g, w_g, spec)
        move, agg, apply_fn = _build_phases(mesh, axes, spec, config,
                                            n_limit, apply_backend, cb, sl)
        n_regrows += 1

    def _run_passes(n_live_, **kw):
        """Pass loop + comm accounting.  Coarse-edge ownership skew no
        longer raises here: with ``phases_for`` supplied the pass loop
        re-shards the owner map (skew-aware with ``config.reshard="auto"``,
        ladder-tight otherwise) and grows coarse edge capacity pass-
        locally in-flight — the resident fine arrays are untouched."""
        nonlocal comm_rounds, comm_fb, comm_bytes, reshard_passes, \
            reshard_bytes_total, coarse_e_max, load_frac_before, \
            load_frac_after, halo_bytes, pass_seconds
        gc, nc, pstats = sharded_louvain_passes(
            src_g, dst_g, w_g, spec, move, agg, n_live_,
            phases_for=phases_for, use_ladder=config.use_ladder,
            comm_backend=cb, state_layout=sl, refine=config.refine,
            reshard=config.reshard, pipeline_fetch=config.pipeline_fetch,
            **kw, **pass_kw)
        comm_rounds += sum(r["comm_rounds"] for r in pstats)
        comm_fb += sum(r["comm_fallback_rounds"] for r in pstats)
        comm_bytes += sum(r["comm_bytes"] for r in pstats)
        halo_bytes += sum(r.get("halo_bytes", 0) for r in pstats)
        pass_seconds += sum(r.get("seconds", 0.0) for r in pstats)
        for r in pstats[1:]:   # coarse tiers only (row 0 is the fine pass)
            coarse_e_max = max(coarse_e_max, r["e_per_shard"])
        for r in pstats:
            if r.get("reshard"):
                reshard_passes += 1
                reshard_bytes_total += r["reshard_bytes"]
                b, a = (r["max_shard_load_frac_before"],
                        r["max_shard_load_frac_after"])
                load_frac_before = max(load_frac_before or 0.0, b)
                load_frac_after = max(load_frac_after or 0.0, a)
        return gc, nc, pstats

    def _mem_from(global_comm, n_valid):
        """Replicated membership from a pass-loop result (shared with the
        serving fleet — see ``distributed.sentinel_forced_membership``)."""
        return sentinel_forced_membership(global_comm, n_valid, spec.n_pad)

    with mesh:
        if prev is None:
            global_comm, n_comms, _ = _run_passes(n_live)
            mem = _mem_from(global_comm, n_live)
        else:
            mem = jnp.asarray(pad_membership(
                np.asarray(prev, np.int32)[: spec.n_pad], spec.n_pad))
            n_comms = int(len(np.unique(np.asarray(prev)[:n_live])))
        n_valid_dev = jnp.asarray(n_live, jnp.int32)

        for batch in batches:
            t0 = time.perf_counter()
            out = apply_fn(src_g, dst_g, w_g, batch.src, batch.dst,
                           batch.weight, batch.b_valid, n_valid_dev)
            if int(out[4]) > spec.e_per_shard:   # e_max: worst shard count
                if not grow_capacity:
                    raise ValueError(
                        f"sharded edge batch overflows capacity: a shard "
                        f"needs {int(out[4])} slots > e_per_shard="
                        f"{spec.e_per_shard}")
                # Re-bucket the PRE-apply arrays into doubled capacity,
                # rebuild the jit'd phases once, and re-apply the batch.
                _grow_to(max(2 * spec.e_per_shard, int(out[4])))
                out = apply_fn(src_g, dst_g, w_g, batch.src, batch.dst,
                               batch.weight, batch.b_valid, n_valid_dev)
            src_g, dst_g, w_g, touched, _, n_valid_dev = out
            t1 = time.perf_counter()

            frontier = None
            if screen_mode is not None:
                frontier = affected_frontier(touched, mem, n_valid_dev,
                                             screen_mode)
            n_live = int(n_valid_dev)
            global_comm, n_comms, _ = _run_passes(
                n_live, init_membership=mem, init_frontier=frontier)
            mem = _mem_from(global_comm, n_live)
            t2 = time.perf_counter()

            touched_counts.append(jnp.sum(touched))
            frontier_sizes.append(jnp.sum(frontier) if frontier is not None
                                  else jnp.asarray(n_live, jnp.int32))
            stats.append(BatchUpdateStats(
                batch_size=int(batch.b_valid),
                n_touched=-1,      # filled lazily after the stream
                frontier_size=-1,  # filled lazily after the stream
                n_vertices=n_live,
                n_communities=n_comms,
                apply_seconds=t1 - t0,
                update_seconds=t2 - t1,
                modularity=float(sharded_modularity(
                    src_g, dst_g, w_g, mem)) if track_modularity else None,
            ))
        for s, tc, fs in zip(stats, touched_counts, frontier_sizes):
            s.n_touched = int(tc)
            s.frontier_size = int(fs)

    membership = np.asarray(mem[:n_live])
    return ShardedDynamicResult(
        membership=membership,
        n_communities=int(len(np.unique(membership))),
        batch_stats=stats,
        total_seconds=time.perf_counter() - t_start,
        n_regrows=n_regrows,
        spec=spec,
        comm_backend=cb,
        comm_rounds=comm_rounds,
        comm_fallback_rounds=comm_fb,
        bytes_on_wire=comm_bytes,
        reshard_passes=reshard_passes,
        reshard_bytes=reshard_bytes_total,
        max_shard_load_frac_before=load_frac_before,
        max_shard_load_frac_after=load_frac_after,
        coarse_e_per_max=coarse_e_max,
        state_layout=sl,
        halo_bytes=halo_bytes,
        boundary_frac=bfrac,
        pass_seconds_total=pass_seconds,
    )
