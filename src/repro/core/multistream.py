"""Batched multi-stream serving: one jitted program, many edge streams.

Serving workloads rarely carry ONE stream: a fleet of tenants (per-user
interaction graphs, per-region topologies, A/B shadow graphs) each emits
small edge-batch deltas and wants fresh communities.  Running
``louvain_dynamic`` per stream pays the full dispatch + host-control-flow
cost S times; here the engine's move rounds are ``vmap``-ed over a leading
stream axis instead, so S independent streams ride ONE compiled program:

  * ``stack_graphs`` / ``stack_batches`` stack equal-capacity ``CSRGraph`` /
    ``EdgeBatch`` pytrees along axis 0 (capacities are the compiled shape,
    so serving fleets provision one shared (n_cap, e_cap) envelope).
  * ``louvain_batched`` is the batched pass loop: vmapped warm/singleton
    init, vmapped engine move phase (the ``lax.while_loop`` batches to a
    run-until-all-converge loop with masked updates), vmapped renumber +
    aggregation.  Pass-level decisions stay host-side but are taken ONCE
    for the fleet: converged streams get ``tolerance = +inf`` (their loop
    exits immediately) and their state is frozen via an active-mask select,
    while the rest keep optimizing in lockstep.
  * ``louvain_dynamic_batched`` is the streaming driver: per step, the
    edge batches of all streams apply in one vmapped sort-reduce, delta
    screening (``repro.core.engine.affected_frontier``, community- or
    vertex-granularity) seeds per-stream frontiers, and the batched pass
    loop resumes from the per-stream memberships.

Capacity growth is a FLEET-level event: one whale stream overflowing
``e_cap`` re-buckets every stream into the next power-of-two tier (one
recompile for the fleet, same as the capacity ladder's shrink) and replays
the step, instead of killing the whole serving step mid-fleet.  Callers
that would rather fail fast pass ``grow_capacity=False`` and catch the
typed ``FleetCapacityOverflow``.  The scanner is the sort-reduce backend
(ELL bucketing is per-graph host work that does not batch).
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.louvain_arch import (_pow2_at_least, compact_work_cap,
                                        resolve_agg_backend,
                                        resolve_coarse_capacity)
from repro.core.aggregate import renumber_communities
from repro.core.delta import EdgeBatch, _apply_edge_batch
from repro.core.engine import (affected_frontier, normalize_screening,
                               resolve_screening_host)
from repro.core.graph import CSRGraph, rebucket_capacity
from repro.core.louvain import (LouvainConfig, PassStats, _aggregate_phase,
                                _leiden_warm_membership, _move_phase,
                                _refine_phase, _renumber_and_fold,
                                pad_membership, singleton_init, warm_init)
from repro.core.modularity import modularity


class FleetCapacityOverflow(ValueError):
    """A serving step overflows the fleet's shared ``e_cap`` envelope.

    Raised only under ``grow_capacity=False`` (the default driver re-buckets
    the fleet and replays).  Carries the offending ``step``, the worst
    stream's required slot count ``e_need``, and the envelope ``e_cap``."""

    def __init__(self, step: int, e_need: int, e_cap: int):
        super().__init__(
            f"batched step {step} overflows capacity: a stream needs "
            f"{e_need} live directed slots > e_cap={e_cap}")
        self.step, self.e_need, self.e_cap = step, e_need, e_cap


def stack_graphs(graphs: Sequence[CSRGraph]) -> CSRGraph:
    """Stack equal-capacity graphs along a new leading stream axis."""
    g0 = graphs[0]
    for g in graphs[1:]:
        if g.n_cap != g0.n_cap or g.e_cap != g0.e_cap:
            raise ValueError(
                f"stream capacities differ: ({g.n_cap}, {g.e_cap}) vs "
                f"({g0.n_cap}, {g0.e_cap}) — provision one shared envelope")
    return jax.tree.map(lambda *xs: jnp.stack(xs), *graphs)


def stack_batches(batches: Sequence[EdgeBatch]) -> EdgeBatch:
    """Stack equal-capacity edge batches along a new leading stream axis."""
    b0 = batches[0]
    for b in batches[1:]:
        if b.b_cap != b0.b_cap:
            raise ValueError(
                f"batch capacities differ: {b.b_cap} vs {b0.b_cap}")
    return jax.tree.map(lambda *xs: jnp.stack(xs), *batches)


@dataclasses.dataclass
class BatchedLouvainResult:
    membership: jax.Array        # (S, n_cap) padded per-stream membership
    n_communities: np.ndarray    # (S,) int
    n_passes: int                # lockstep passes run (max over streams)


@dataclasses.dataclass
class BatchedDynamicResult:
    graphs: CSRGraph             # stacked graphs after all steps
    membership: np.ndarray       # (S, n_cap) final padded membership
    n_communities: np.ndarray    # (S,) int
    frontier_sizes: np.ndarray   # (n_steps, S) delta-screened seed sizes
    modularity: Optional[np.ndarray]  # (S,) final Q per stream (if tracked)
    total_seconds: float
    n_regrows: int = 0           # fleet-level capacity-growth re-buckets
    #: One row per serving step with the knobs the step ACTUALLY ran with
    #: (fleet-level maxima; ``screening``/``scan_backend`` record the
    #: host-resolved choices, ``downgraded`` flags an "auto" request the
    #: vmapped program could not honor as such).
    pass_stats: List[PassStats] = dataclasses.field(default_factory=list)

    def stream_membership(self, s: int) -> np.ndarray:
        n = int(np.asarray(self.graphs.n_valid)[s])
        return np.asarray(self.membership[s, :n])


@functools.lru_cache(maxsize=None)
def _fused_step(max_iterations: int, use_pruning: bool, gate_fraction: int,
                tolerance: float, screen_mode: Optional[str], backend: str,
                work_cap: int = 0):
    """ONE jitted vmapped program for a whole serving step: batch apply ->
    delta screen -> warm init -> engine move -> renumber.

    This is the fast path of ``louvain_dynamic_batched``: warm streaming
    updates almost always converge in a single pass (``iters <= 1``), so the
    per-step host cost collapses to one dispatch + one scalar fetch for the
    fleet.  The returned ``iters``/``e_new`` let the host detect the rare
    step that needs the general pass loop (or overflowed capacity) and
    redo it off the fast path — results stay exactly equal to the
    sequential drivers either way.  ``work_cap > 0`` routes the move phase
    through the frontier-compacted scanner (bit-identical; note that under
    ``vmap`` its overflow ``cond`` lowers to a select that evaluates both
    scans, so this is a correctness-preserving knob here, not a speedup —
    which is why ``scan_backend="auto"`` resolves to the full scan for the
    batched driver).
    """

    def one(g: CSRGraph, mem_row: jax.Array, b: EdgeBatch):
        n_cap = g.n_cap
        g2, touched, e_new = _apply_edge_batch(g, b, backend=backend)
        mem_pad = jnp.concatenate(
            [mem_row[:n_cap], jnp.full((1,), n_cap, jnp.int32)])
        if screen_mode is not None:
            frontier = affected_frontier(touched, mem_pad, g2.n_valid,
                                         screen_mode)
        else:
            frontier = jnp.arange(n_cap + 1) < g2.n_valid
        comm0, sigma0, frontier0 = warm_init(g2, mem_pad, frontier)
        comm, iters, _ = _move_phase(
            g2, comm0, sigma0, frontier0, jnp.float32(tolerance),
            max_iterations=max_iterations, use_pruning=use_pruning,
            gate_fraction=gate_fraction, work_cap=work_cap)
        comm_ren, _ = renumber_communities(comm, g2.n_valid, n_cap)
        return (g2, comm_ren[:n_cap], frontier, iters, e_new,
                jnp.sum(frontier), jnp.sum(touched.astype(jnp.int32)))

    return jax.jit(jax.vmap(one))


@functools.lru_cache(maxsize=None)
def _batched_phases(max_iterations: int, use_pruning: bool,
                    gate_fraction: int, work_cap: int = 0,
                    agg_backend: str = "sort"):
    """vmapped jit'd phases for one static move configuration."""
    move = jax.vmap(functools.partial(
        _move_phase, max_iterations=max_iterations, use_pruning=use_pruning,
        gate_fraction=gate_fraction, work_cap=work_cap))
    return (move, jax.vmap(singleton_init), jax.vmap(warm_init),
            jax.vmap(_renumber_and_fold),
            jax.vmap(functools.partial(_aggregate_phase,
                                       backend=agg_backend)))


def louvain_batched(
    gb: CSRGraph,
    config: LouvainConfig = LouvainConfig(),
    *,
    init_membership: Optional[jax.Array] = None,
    init_frontier: Optional[jax.Array] = None,
) -> BatchedLouvainResult:
    """Batched pass loop over stacked graphs; see the module docstring.

    ``init_membership`` ((S, n_cap) or (S, n_cap + 1)) warm-starts pass 0
    per stream; ``init_frontier`` ((S, n_cap + 1) bool) seeds delta
    screening.  Streams converge independently: a finished stream's
    tolerance flips to +inf (its batched while_loop lane exits immediately)
    and its membership is frozen while the fleet finishes.

    With ``config.use_ladder`` the coarse passes ride the capacity ladder
    at FLEET granularity: one tier per pass, resolved from the max coarse
    size over the still-active streams, so the whole fleet keeps a single
    compiled shape per tier (per-stream tiers would shatter the vmap).

    ``config.refine="leiden"`` vmaps the constrained refinement sweep
    (``repro.core.louvain._refine_phase``) over the fleet: aggregation
    follows each stream's REFINED partition while the reported membership
    and the next pass's warm start stay at the outer partition — the same
    Leiden pass semantics as the single-device driver, one compiled
    program for all streams.
    """
    if config.use_ell_kernel or config.scan_backend in ("ell", "ell_fused"):
        raise ValueError("louvain_batched uses the sort-reduce scanner; "
                         "ELL bucketing is per-graph host work")
    if config.refine not in ("none", "leiden"):
        raise ValueError(
            f"refine must be 'none' or 'leiden', got {config.refine!r}")
    refine_on = config.refine == "leiden"
    S, n_cap = gb.indptr.shape[0], gb.indptr.shape[1] - 1
    # Aggregation backend under vmap mirrors the scanner policy: an
    # EXPLICIT "pallas" is honored (bit-identical, tested in interpret
    # mode), but "auto" stays the sort chain — the vmapped kernel is not a
    # tuned fleet path, so auto never routes production fleets through it.
    agg_backend = (resolve_agg_backend(config.agg_backend)
                   if config.agg_backend != "auto" else "sort")
    move, v_singleton, v_warm, v_renumber, v_aggregate = _batched_phases(
        config.max_iterations, config.use_pruning, config.gate_fraction,
        0, agg_backend)
    # Pass 0 with a seed frontier may use the compacted scanner (explicit
    # "compact" only — "auto" keeps the full scan under vmap, where the
    # overflow cond lowers to a both-branches select).
    move0 = move
    if config.scan_backend == "compact" and init_frontier is not None:
        move0 = _batched_phases(
            config.max_iterations, config.use_pruning, config.gate_fraction,
            compact_work_cap(gb.indices.shape[1],
                             config.compact_cap_frac))[0]
    if refine_on:
        v_refine = jax.vmap(functools.partial(
            _refine_phase, max_iterations=config.max_iterations,
            use_pruning=config.use_pruning,
            gate_fraction=config.gate_fraction))
        v_leiden_warm = jax.vmap(_leiden_warm_membership)

    global_comm = jnp.tile(jnp.arange(n_cap, dtype=jnp.int32)[None], (S, 1))
    report_comm = global_comm
    leiden_mem = None
    n_valid0 = gb.n_valid           # per-stream vertex counts of the INPUT
    active = np.ones(S, bool)       # (gb becomes the coarse graph below)
    tol = float(config.initial_tolerance)
    n_comms_final = np.asarray(gb.n_valid).copy()
    warm = init_membership is not None
    if warm:
        mem = jnp.asarray(init_membership, jnp.int32)
        if mem.shape[1] < n_cap + 1:
            mem = jnp.concatenate(
                [mem, jnp.full((S, n_cap + 1 - mem.shape[1]), n_cap,
                               jnp.int32)], axis=1)
    fr = (jnp.ones((S, n_cap + 1), bool) if init_frontier is None
          else jnp.asarray(init_frontier, bool))

    passes = 0
    for p in range(config.max_passes):
        if p == 0 and warm:
            comm0, sigma0, frontier0 = v_warm(gb, mem, fr)
        elif leiden_mem is not None:
            # Leiden pass semantics: resume from the outer partition
            # expressed on the refined coarse vertices.
            comm0, sigma0, frontier0 = v_warm(
                gb, leiden_mem, jnp.ones_like(leiden_mem, bool))
        else:
            comm0, sigma0, frontier0 = v_singleton(gb)
            if p == 0 and init_frontier is not None:
                frontier0 = frontier0 & fr
        tols = jnp.where(jnp.asarray(active), jnp.float32(tol), jnp.inf)
        comm, iters, _ = (move0 if p == 0 else move)(
            gb, comm0, sigma0, frontier0, tols)
        if refine_on:
            refined, _r_iters, _r_dq = v_refine(gb, comm, tols)
            outer_ren, n_outer, outer_fold = v_renumber(
                comm, gb.n_valid, jnp.zeros((S,), jnp.int32), global_comm)
            comm_ren, n_comms, folded = v_renumber(
                refined, gb.n_valid, jnp.zeros((S,), jnp.int32), global_comm)
            report_fold, n_report = outer_fold, n_outer
        else:
            comm_ren, n_comms, folded = v_renumber(
                comm, gb.n_valid, jnp.zeros((S,), jnp.int32), global_comm)
            report_fold, n_report = folded, n_comms
        mask = jnp.asarray(active)
        global_comm = jnp.where(mask[:, None], folded, global_comm)
        report_comm = jnp.where(mask[:, None], report_fold, report_comm)
        passes = p + 1

        iters_np = np.asarray(iters)
        n_comms_np = np.asarray(n_comms)
        n_report_np = np.asarray(n_report)
        n_valid_np = np.asarray(gb.n_valid)
        n_comms_final = np.where(active, n_report_np, n_comms_final)
        converged = iters_np <= 1
        low_shrink = (n_report_np / np.maximum(n_valid_np, 1)
                      > config.aggregation_tolerance)
        next_active = active & ~converged & ~low_shrink
        if p == config.max_passes - 1 or not next_active.any():
            break
        if refine_on:
            # Outer-on-coarse warm start at the FINE pass capacity; resized
            # below once the coarse layout (ladder tier) is known — values
            # are coarse ids [0, n_comms), invariant to the layout.
            warm_c = v_leiden_warm(comm_ren, outer_ren, gb.n_valid, n_comms)
        gb_new = v_aggregate(gb, comm_ren, n_comms)
        sel = jnp.asarray(next_active)
        gb = jax.tree.map(
            lambda new, old: jnp.where(
                sel.reshape((S,) + (1,) * (new.ndim - 1)), new, old),
            gb_new, gb)
        if config.use_ladder:
            # Fleet-level tier decision: the capacity ladder must keep ONE
            # jit shape for the whole fleet, so the tier is resolved from
            # the max coarse size over the streams that keep optimizing.
            # Frozen lanes' graphs may be truncated by the shrink — they
            # are never read again (membership is already folded and their
            # aggregation output is masked off).
            n_cap_cur = gb.indptr.shape[1] - 1
            e_cap_cur = gb.indices.shape[1]
            e_valid_np = np.asarray(gb.e_valid)
            n_need = int(n_comms_np[next_active].max())
            e_need = int(e_valid_np[next_active].max())
            n_new, e_new = resolve_coarse_capacity(
                n_need, e_need, n_cap_cur, e_cap_cur)
            if (n_new, e_new) != (n_cap_cur, e_cap_cur):
                gb = jax.vmap(lambda g: rebucket_capacity(
                    g, n_cap_new=n_new, e_cap_new=e_new))(gb)
        if refine_on:
            # Resize the warm rows to the (possibly laddered) coarse
            # capacity: live entries (< n_comms) hold valid coarse ids,
            # everything else becomes the new sentinel.
            cap2 = gb.indptr.shape[1] - 1
            idx2 = jnp.arange(cap2 + 1)
            if warm_c.shape[1] >= cap2 + 1:
                body = warm_c[:, : cap2 + 1]
            else:
                body = jnp.concatenate(
                    [warm_c, jnp.full((S, cap2 + 1 - warm_c.shape[1]),
                                      cap2, jnp.int32)], axis=1)
            leiden_mem = jnp.where(idx2[None, :] < n_comms[:, None],
                                   body, jnp.int32(cap2))
        active = next_active
        tol /= config.tolerance_drop

    # Invalid slots (idx >= n_valid) are forced to the ORIGINAL sentinel:
    # folding through a laddered (shrunk) pass leaves them holding the small
    # tier's sentinel, which a later warm start would misread as a real
    # community assignment (matches the un-laddered fold, where they hold
    # n_cap after the first renumber).  With refinement the reported
    # membership is the OUTER fold, not the refined dendrogram chain.
    idx = jnp.arange(n_cap)
    report_comm = jnp.where(idx[None, :] < n_valid0[:, None],
                            report_comm, jnp.int32(n_cap))
    return BatchedLouvainResult(membership=report_comm,
                                n_communities=n_comms_final.astype(int),
                                n_passes=passes)


def louvain_dynamic_batched(
    graphs: Sequence[CSRGraph],
    streams: Sequence[Sequence[EdgeBatch]],
    prevs: Optional[Sequence[np.ndarray]] = None,
    config: LouvainConfig = LouvainConfig(),
    *,
    screening=True,
    track_modularity: bool = False,
    apply_backend: str = "xla",
    grow_capacity: bool = True,
) -> BatchedDynamicResult:
    """Serve S independent edge streams through ONE batched dynamic program.

    ``streams[s]`` is stream s's batch sequence; all streams must have the
    same number of steps and per-step ``b_cap`` (serving fleets share one
    compiled envelope — pad short streams with empty batches).  ``prevs``
    are the per-stream memberships before the stream; ``None`` runs one
    batched cold start.  Per step: one vmapped batch apply, one vmapped
    delta screen (``screening`` as in ``louvain_dynamic``, including
    ``"auto"``), one batched warm pass loop.  ``config.scan_backend=
    "compact"`` routes the vmapped move phase through the frontier-
    compacted scanner (bit-identical; under vmap the overflow cond lowers
    to a both-branches select, so ``"auto"`` keeps the full scan here).
    A step overflowing the fleet's ``e_cap`` re-buckets every stream into
    the next power-of-two edge tier and replays it (``grow_capacity``,
    default; one recompile per growth, counted in ``n_regrows``) — with
    ``grow_capacity=False`` it raises ``FleetCapacityOverflow`` instead.
    Memberships are invariant to capacity either way.
    """
    t_start = time.perf_counter()
    S = len(graphs)
    if len(streams) != S:
        raise ValueError(f"{S} graphs but {len(streams)} streams")
    n_steps = len(streams[0])
    if any(len(s) != n_steps for s in streams):
        raise ValueError("all streams must have the same number of steps")
    screen_mode = normalize_screening(screening)
    gb = stack_graphs(list(graphs))
    n_cap, e_cap = gb.indptr.shape[1] - 1, gb.indices.shape[1]

    if config.use_ell_kernel or config.scan_backend in ("ell", "ell_fused"):
        raise ValueError("louvain_dynamic_batched uses the sort-reduce "
                         "scanner; ELL bucketing is per-graph host work")
    # Scanner selection under vmap: "compact" is honored (bit-identical,
    # though its overflow cond lowers to a both-branches select), but
    # "auto" CANNOT be — the per-batch frontier-fraction resolution is a
    # host decision the one-program-many-streams driver has no per-stream
    # hook for, so it downgrades to the full scan and RECORDS the
    # downgrade in ``pass_stats`` instead of silently staying full.
    compact_on = (config.scan_backend == "compact"
                  and screen_mode is not None)
    scan_used = "compact" if compact_on else "full"
    # (Without screening the auto resolution would pick the full scan
    # anyway — only flag the downgrade when it could have differed.)
    scan_down = config.scan_backend == "auto" and screen_mode is not None
    # Screening "auto" is likewise resolved HOST-side, per fleet step, from
    # the previous step's worst touched fraction (the on-device auto select
    # evaluates BOTH granularities for every lane under vmap): the driver
    # takes the per-step validated path, whose scalar fetch carries the
    # touched counts for free.
    auto_screen = screen_mode == "auto"

    def _fused_for(mode: Optional[str]):
        wc = (compact_work_cap(e_cap, config.compact_cap_frac)
              if compact_on else 0)
        return _fused_step(config.max_iterations, config.use_pruning,
                           config.gate_fraction,
                           float(config.initial_tolerance), mode,
                           apply_backend, wc)

    fused = _fused_for("community" if auto_screen else screen_mode)

    if prevs is None:
        mem = louvain_batched(gb, config).membership
    else:
        # pad_membership accepts (n,), (n_cap,) and sentinel-padded
        # (n_cap + 1,) inputs alike — same contract as louvain_dynamic.
        mem = jnp.stack([
            jnp.asarray(pad_membership(
                np.asarray(p, np.int32)[:n_cap], n_cap)[:n_cap])
            for p in prevs])

    bbs = [stack_batches([streams[s][step] for s in range(S)])
           for step in range(n_steps)]

    n_regrows = 0
    stats: List[PassStats] = []

    def _step_stat(mode, mode_down, iters_max, fsize_max, nv_max):
        return PassStats(
            iterations=int(iters_max), n_communities=0, n_vertices=nv_max,
            dq_sum=0.0, seconds=0.0, phase_seconds={},
            frontier_size=int(fsize_max), n_cap=n_cap, e_cap=e_cap,
            screening=mode, scan_backend=scan_used,
            downgraded=bool(mode_down or scan_down))

    def serve_carefully(gb, mem):
        """Per-step validated loop: check overflow/convergence every step,
        routing overflowed steps through a fleet re-bucket + replay and
        non-converged steps through the general batched pass loop —
        results stay exactly equal to the sequential driver.  With
        ``screening="auto"`` this is the ONLY path: the step's scalar
        fetch carries the touched counts the next step's host-side mode
        resolution needs."""
        nonlocal e_cap, n_regrows
        frontier_sizes: List[jax.Array] = []
        stats.clear()
        touched_frac = None
        for step in range(n_steps):
            mode, mode_down = resolve_screening_host(screen_mode,
                                                     touched_frac)
            fused_t = _fused_for(mode)
            while True:
                gb_new, mem_new, frontier, iters, e_new, fsize, tch = \
                    fused_t(gb, mem, bbs[step])
                e_max, iters_max, fsz_max, nv_max, frac = jax.device_get((
                    jnp.max(e_new), jnp.max(iters), jnp.max(fsize),
                    jnp.max(gb_new.n_valid),
                    jnp.max(tch / jnp.maximum(gb_new.n_valid, 1)
                            .astype(jnp.float32))))
                if int(e_max) <= e_cap:
                    break
                if not grow_capacity:
                    raise FleetCapacityOverflow(step, int(e_max), e_cap)
                # One whale stream outgrew the envelope: re-bucket the
                # WHOLE fleet into the next power-of-two tier (one shared
                # compiled shape, like the ladder's shrink) and replay
                # this step against the pre-apply state.
                e_cap = _pow2_at_least(int(e_max))
                gb = jax.vmap(lambda g: rebucket_capacity(
                    g, n_cap_new=n_cap, e_cap_new=e_cap))(gb)
                fused_t = _fused_for(mode)
                n_regrows += 1
            touched_frac = float(frac)
            if int(iters_max) > 1:
                res = louvain_batched(
                    gb_new, config, init_membership=mem,
                    init_frontier=(frontier if mode is not None else None))
                mem_new = res.membership
            gb, mem = gb_new, mem_new
            frontier_sizes.append(fsize if mode is not None else gb.n_valid)
            stats.append(_step_stat(mode, mode_down, iters_max,
                                    fsz_max if mode is not None else nv_max,
                                    int(nv_max)))
        return gb, mem, frontier_sizes

    # Optimistic pipelined pass: enqueue every fused step back-to-back with
    # NO host round-trip, then validate the collected per-step scalars
    # once.  Warm serving updates virtually always satisfy both checks; a
    # violation redoes the stream through the per-step validated loop (so
    # overflow raises with its step index and non-converged steps get the
    # full pass loop) — results are identical either way.  Host-resolved
    # "auto" screening needs the per-step fetch, so it always takes the
    # validated loop.
    if auto_screen:
        gb, mem, frontier_sizes = serve_carefully(gb, mem)
    else:
        gb_t, mem_t = gb, mem
        fsz_t: List[jax.Array] = []
        its_t: List[jax.Array] = []
        enew_t: List[jax.Array] = []
        nv_t: List[jax.Array] = []
        for step in range(n_steps):
            gb_t, mem_t, _, iters, e_new, fsize, _tch = fused(
                gb_t, mem_t, bbs[step])
            fsz_t.append(fsize if screen_mode is not None else gb_t.n_valid)
            its_t.append(iters)
            enew_t.append(e_new)
            nv_t.append(gb_t.n_valid)
        if n_steps == 0:
            frontier_sizes = []      # idle fleet: warm membership unchanged
        else:
            e_max, iters_max, its_all, fsz_all, nv_all = jax.device_get(
                (jnp.max(jnp.stack(enew_t)), jnp.max(jnp.stack(its_t)),
                 jnp.stack(its_t), jnp.stack(fsz_t), jnp.stack(nv_t)))
            if int(e_max) > e_cap or int(iters_max) > 1:
                gb, mem, frontier_sizes = serve_carefully(gb, mem)
            else:
                gb, mem, frontier_sizes = gb_t, mem_t, fsz_t
                for step in range(n_steps):
                    stats.append(_step_stat(
                        screen_mode, False, its_all[step].max(),
                        fsz_all[step].max(), int(nv_all[step].max())))

    q = None
    if track_modularity:
        q = np.asarray(jax.vmap(modularity)(gb, _pad_sentinel(mem)))
    return BatchedDynamicResult(
        graphs=gb,
        membership=np.asarray(mem),
        n_communities=np.asarray(
            [len(np.unique(np.asarray(mem[s, :int(np.asarray(gb.n_valid)[s])])))
             for s in range(S)]),
        frontier_sizes=(np.asarray(jnp.stack(frontier_sizes))
                        if frontier_sizes else np.zeros((0, S), int)),
        modularity=q,
        total_seconds=time.perf_counter() - t_start,
        n_regrows=n_regrows,
        pass_stats=list(stats),
    )


@jax.jit
def _pad_sentinel(mem: jax.Array) -> jax.Array:
    """(S, n_cap) membership -> (S, n_cap + 1) with the sentinel column."""
    S, n_cap = mem.shape[0], mem.shape[1]
    return jnp.concatenate(
        [mem, jnp.full((S, 1), n_cap, jnp.int32)], axis=1)
