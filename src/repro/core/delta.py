"""In-capacity CSR edge-batch updates (the streaming half of dynamic Louvain).

A batch is a set of undirected ``{u, v} -> w`` assignments applied to the
padded ``CSRGraph`` buffers *in place of capacity* (shapes never change, so
every downstream jit — move phase, aggregation, modularity — reuses its
compiled form across the stream):

    w > 0, edge absent   -> insert
    w > 0, edge present  -> reweight (set, not add)
    w == 0               -> delete (no-op if absent)

The update is one sort-reduce over ``e_cap + 2 * b_cap`` slots: existing
directed slots and the batch's directed slots are keyed by
``src * (n_cap + 1) + dst``, lexsorted by (key, rank) with batch slots
outranking existing ones (and later batch entries outranking earlier — last
write wins), then per-key groups resolve to their highest-rank weight and
compact back into CSR order.  Because the key order IS the (src, dst) CSR
order, ``indptr`` rebuilds from a segment-count + cumsum.

Invariants preserved exactly (tested property-style in tests/test_dynamic.py):
  - undirected {i,j}, i != j   -> two directed slots; self loop -> one slot
  - K_i = row sum, m = sum(w)/2, padding slots hold (sentinel, 0)
so ``vertex_weights`` / ``total_weight`` stay consistent by construction.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import CSRGraph


class EdgeBatch(NamedTuple):
    """A padded batch of undirected edge assignments.

    src, dst : (b_cap,) int32 endpoints; padding slots hold ``n_cap``.
    weight   : (b_cap,) float32 new weight (0 = delete); padding slots 0.
    b_valid  : () int32 number of live entries.
    """

    src: jax.Array
    dst: jax.Array
    weight: jax.Array
    b_valid: jax.Array

    @property
    def b_cap(self) -> int:
        return self.src.shape[0]


def make_edge_batch(src, dst, weight, n_cap: int,
                    b_cap: int | None = None) -> EdgeBatch:
    """Host-side batch builder; pads to ``b_cap`` with sentinel entries."""
    src = np.asarray(src, dtype=np.int32)
    dst = np.asarray(dst, dtype=np.int32)
    weight = np.asarray(weight, dtype=np.float32)
    b = len(src)
    b_cap = int(b_cap if b_cap is not None else max(b, 1))
    assert b_cap >= b, "batch capacity below batch size"
    pad = np.full(b_cap - b, n_cap, np.int32)
    return EdgeBatch(
        src=jnp.asarray(np.concatenate([src, pad])),
        dst=jnp.asarray(np.concatenate([dst, pad])),
        weight=jnp.asarray(np.concatenate([weight,
                                           np.zeros(b_cap - b, np.float32)])),
        b_valid=jnp.asarray(b, dtype=np.int32),
    )


def sort_reduce_apply_slots(all_src, all_dst, all_w, rank, is_batch,
                            sent: int, out_cap: int, backend: str = "xla"):
    """The shared batch-apply sort-reduce over a unified directed-slot list.

    ``all_*`` concatenate the existing slots (rank 0) and the batch's directed
    slots (rank 1 + batch position, so later batch entries win ties); dead
    slots must already carry an endpoint >= ``sent``.  Groups of equal
    (src, dst) resolve to their highest-rank weight and compact back into
    (src, dst)-sorted order in ``out_cap`` slots (overflow rows land in a
    scratch slot and are reported via the uncapped ``e_new``).

    Returns ``(out_src, out_dst, out_w, e_new, chg_src, chg_dst)`` where
    ``chg_src``/``chg_dst`` hold the endpoints of every group whose resolved
    weight actually changed (``sent`` elsewhere) — callers scatter these into
    their own touched-vertex structures.  Used by both the single-device CSR
    apply below and the per-shard apply in ``repro.core.distributed_dynamic``.

    ``backend`` selects the post-sort group-resolve: ``"xla"`` (segment_*
    reductions, the reference) or ``"pallas"`` (the fused carry-chained scan
    kernel in ``repro.kernels.batch_apply`` — interpret mode off-TPU).  Both
    produce bit-identical graphs and touched sets; only the internal
    ``chg_*`` encoding differs (all group slots vs one record per group),
    which scatters to the same mask.
    """
    total = all_src.shape[0]
    dead = (all_src >= sent) | (all_dst >= sent)
    k_src = jnp.where(dead, sent, all_src)
    k_dst = jnp.where(dead, sent, all_dst)
    order = jnp.lexsort((rank, k_dst, k_src))
    s_src, s_dst = k_src[order], k_dst[order]
    s_w, s_batch = all_w[order], is_batch[order]

    if backend == "pallas":
        from repro.kernels.batch_apply import resolve_groups_pallas
        keep, pos, f_src, f_dst, f_w, chg = resolve_groups_pallas(
            s_src, s_dst, s_w, s_batch, sent=sent)
        e_new = jnp.sum(keep.astype(jnp.int32))
        pos = jnp.where(keep & (pos < out_cap), pos, out_cap)
        out_src = jnp.full((out_cap + 1,), sent, jnp.int32).at[pos].set(
            jnp.where(keep, f_src, sent))[:out_cap]
        out_dst = jnp.full((out_cap + 1,), sent, jnp.int32).at[pos].set(
            jnp.where(keep, f_dst, sent))[:out_cap]
        out_w = jnp.zeros((out_cap + 1,), jnp.float32).at[pos].set(
            jnp.where(keep, f_w, 0.0))[:out_cap]
        chg_src = jnp.where(chg, f_src, sent)
        chg_dst = jnp.where(chg, f_dst, sent)
        return out_src, out_dst, out_w, e_new, chg_src, chg_dst
    if backend != "xla":
        raise ValueError(f"unknown batch-apply backend: {backend!r}")

    s_sent = s_src == sent
    nxt_same = (s_src[:-1] == s_src[1:]) & (s_dst[:-1] == s_dst[1:])
    is_last = jnp.concatenate([~nxt_same, jnp.ones((1,), bool)])
    is_first = jnp.concatenate([jnp.ones((1,), bool), ~nxt_same])
    gid = jnp.cumsum(is_first.astype(jnp.int32)) - 1

    # Per-group old weight (0 if the first slot is a batch slot, i.e. insert)
    # and new weight (the last slot's weight — batch overrides existing).
    old_w = jax.ops.segment_sum(
        jnp.where(is_first & ~s_batch, s_w, 0.0), gid, num_segments=total)
    new_w = jax.ops.segment_sum(
        jnp.where(is_last, s_w, 0.0), gid, num_segments=total)
    changed_group = jax.ops.segment_max(
        (s_batch & (old_w[gid] != new_w[gid])).astype(jnp.int32),
        gid, num_segments=total)

    # Compact live groups (w > 0, real key) back into sorted slot order.
    keep = is_last & ~s_sent & (new_w[gid] > 0.0)
    e_new = jnp.sum(keep.astype(jnp.int32))
    pos = jnp.cumsum(keep.astype(jnp.int32)) - 1
    pos = jnp.where(keep & (pos < out_cap), pos, out_cap)  # overflow -> scratch
    out_src = jnp.full((out_cap + 1,), sent, jnp.int32).at[pos].set(
        jnp.where(keep, s_src, sent))[:out_cap]
    out_dst = jnp.full((out_cap + 1,), sent, jnp.int32).at[pos].set(
        jnp.where(keep, s_dst, sent))[:out_cap]
    out_w = jnp.zeros((out_cap + 1,), jnp.float32).at[pos].set(
        jnp.where(keep, new_w[gid], 0.0))[:out_cap]

    hit = changed_group[gid] > 0
    chg_src = jnp.where(hit, s_src, sent)
    chg_dst = jnp.where(hit, s_dst, sent)
    return out_src, out_dst, out_w, e_new, chg_src, chg_dst


@functools.partial(jax.jit, static_argnames=("backend",))
def _apply_edge_batch(graph: CSRGraph, batch: EdgeBatch,
                      backend: str = "xla"):
    """Jit core: returns (graph', touched_mask, e_new_uncapped)."""
    n_cap, e_cap = graph.n_cap, graph.e_cap
    b_cap = batch.b_cap

    # Directed batch slots: {u,v} -> (u,v) and (v,u); self loops get ONE slot
    # (the reverse collapses to a sentinel), matching the CSR convention.
    b_idx = jnp.arange(b_cap)
    b_live = (b_idx < batch.b_valid) & (batch.src < n_cap) & (batch.dst < n_cap)
    u = jnp.where(b_live, batch.src, n_cap)
    v = jnp.where(b_live, batch.dst, n_cap)
    rev_live = b_live & (u != v)
    d_src = jnp.concatenate([u, jnp.where(rev_live, v, n_cap)])
    d_dst = jnp.concatenate([v, jnp.where(rev_live, u, n_cap)])
    d_w = jnp.concatenate([batch.weight, jnp.where(rev_live, batch.weight, 0.0)])

    # Unified slot list: existing first (rank 0), batch after (rank = 1 + i so
    # later batch entries win ties — last-write-wins within one batch).
    all_src = jnp.concatenate([graph.src, d_src])
    all_dst = jnp.concatenate([graph.indices, d_dst])
    all_w = jnp.concatenate([graph.weights, d_w]).astype(jnp.float32)
    e_idx = jnp.arange(e_cap)
    exist_live = (e_idx < graph.e_valid) & (graph.src < n_cap)
    slot_live = jnp.concatenate([exist_live,
                                 (d_src < n_cap) | (d_dst < n_cap)])
    is_batch = jnp.concatenate([jnp.zeros(e_cap, bool), jnp.ones(2 * b_cap, bool)])
    rank = jnp.concatenate([
        jnp.zeros(e_cap, jnp.int32),
        1 + (jnp.arange(2 * b_cap, dtype=jnp.int32) % b_cap),
    ])

    # Dead slots collapse to the (n_cap, n_cap) sentinel pair so they sort
    # last; the (src, dst) sort order IS the CSR order — no combined int64
    # key (x64 is usually disabled), the lexsort carries both columns.
    # The group-resolve + compaction itself is the shared sort-reduce core.
    dead = ~(slot_live & (all_src < n_cap) & (all_dst < n_cap))
    out_src, out_dst, out_w, e_new, chg_src, chg_dst = sort_reduce_apply_slots(
        jnp.where(dead, n_cap, all_src), jnp.where(dead, n_cap, all_dst),
        all_w, rank, is_batch, n_cap, e_cap, backend)

    live_rows = out_src < n_cap
    counts = jax.ops.segment_sum(
        jnp.where(live_rows, 1, 0), jnp.where(live_rows, out_src, n_cap),
        num_segments=n_cap + 1)
    indptr = jnp.concatenate([
        jnp.zeros((1,), jnp.int32),
        jnp.cumsum(counts[:n_cap]).astype(jnp.int32),
    ])

    # Touched vertices: endpoints of groups whose weight actually changed.
    touched = jnp.zeros((n_cap + 1,), bool)
    touched = touched.at[chg_src].set(True)
    touched = touched.at[chg_dst].set(True)
    touched = touched.at[n_cap].set(False)

    # Batch endpoints may extend the valid-vertex prefix (still < n_cap).
    max_end = jnp.max(jnp.where(touched, jnp.arange(n_cap + 1), -1))
    n_valid = jnp.maximum(graph.n_valid, (max_end + 1).astype(jnp.int32))

    out = CSRGraph(
        indptr=indptr, indices=out_dst, weights=out_w, src=out_src,
        n_valid=n_valid, e_valid=jnp.minimum(e_new, e_cap).astype(jnp.int32),
    )
    return out, touched, e_new


def grow_graph_capacity(graph: CSRGraph, e_cap_new: int) -> CSRGraph:
    """Host-side re-bucketing: copy a graph into buffers with more edge slots.

    Vertex capacity (and so every (n_cap + 1,)-shaped consumer) is unchanged;
    only the edge arrays grow, so downstream jits recompile once per growth
    step and are reused for the rest of the stream.
    """
    e_cap_new = int(e_cap_new)
    if e_cap_new < graph.e_cap:
        raise ValueError(f"cannot shrink e_cap {graph.e_cap} -> {e_cap_new}")
    n_cap = graph.n_cap
    e = int(graph.e_valid)
    pad_i = np.full(e_cap_new - e, n_cap, np.int32)
    pad_w = np.zeros(e_cap_new - e, np.float32)
    return CSRGraph(
        indptr=graph.indptr,
        indices=jnp.asarray(np.concatenate(
            [np.asarray(graph.indices)[:e], pad_i])),
        weights=jnp.asarray(np.concatenate(
            [np.asarray(graph.weights)[:e], pad_w])),
        src=jnp.asarray(np.concatenate([np.asarray(graph.src)[:e], pad_i])),
        n_valid=graph.n_valid,
        e_valid=graph.e_valid,
    )


def apply_edge_batch(graph: CSRGraph, batch: EdgeBatch, *,
                     grow: bool = False,
                     backend: str = "xla") -> Tuple[CSRGraph, jax.Array]:
    """Apply one edge batch; returns (graph', touched_vertex_mask).

    Raises if the resulting edge count exceeds the preallocated ``e_cap``
    (streaming callers size capacities for the expected insert volume up
    front — growing buffers would retrigger every downstream jit).  With
    ``grow=True`` an overflowing batch instead re-buckets host-side into
    doubled capacity (at least the required count) and re-applies — the
    unbounded-stream policy used by ``louvain_dynamic``.  ``backend``
    selects the group-resolve implementation (see
    ``sort_reduce_apply_slots``).
    """
    out, touched, e_new = _apply_edge_batch(graph, batch, backend=backend)
    if int(e_new) > graph.e_cap:
        if not grow:
            raise ValueError(
                f"edge batch overflows capacity: {int(e_new)} live directed "
                f"slots > e_cap={graph.e_cap}")
        grown = grow_graph_capacity(
            graph, max(2 * graph.e_cap, int(e_new)))
        out, touched, e_new = _apply_edge_batch(grown, batch, backend=backend)
    return out, touched
