"""Multi-pod distributed GVE-Louvain via shard_map + jax.lax collectives.

The paper is single-node shared-memory; this layer extends it along the lines
of the distributed implementations it benchmarks (Vite / Ghosh et al.):

  - 1-D **vertex partition**: every vertex's full adjacency lives on exactly
    one shard.  Louvain's parallelism is vertex-wise, so the partition flattens
    ALL mesh axes (pod x data x model) into one vertex axis — each of the 512
    chips of the production mesh owns |V|/512 vertices.
  - **Replicated community state**: C, Sigma, K (O(|V|) each) are replicated;
    per-round updates travel as one `all_gather` (the owned C slice + moved
    flags) and one `psum` (Sigma deltas) — the same ghost-exchange pattern as
    Vite, expressed as XLA collectives.  That is the "gather" communication
    backend; the "delta" backend (``DeltaShardedScanner``) replaces the dense
    exchange with compacted, bit-packed owned CHANGES (moved labels + top-k
    Sigma deltas, with a measured-overflow fallback) — replication still
    forces an all_gather, but of O(moved) lanes instead of O(n_pad) arrays.
    Policy and caps live in ``repro.configs.louvain_arch``
    (``resolve_comm_backend``); bytes accounting in ``repro.core.comm``.
  - **Distributed aggregation**: local sort-reduce partially deduplicates each
    shard's relabeled edges, an `all_gather` shares the partials, and each
    shard re-reduces the rows it owns in the coarse partition.  (The gather is
    the faithful baseline; the all_to_all variant lives in
    ``repro.configs.louvain_arch`` as a dry-run cell.)

Everything here is shape-static and lowers AOT on the production meshes — see
launch/dryrun.py.
"""

from __future__ import annotations

import functools
import time
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro import compat

from repro.core.comm import (CommPlan, boundary_mask, comm_plan,
                             compact_movers, label_bits, pack_bits,
                             packed_lanes, phase_bytes, size_delta_width,
                             topk_touched_deltas, unpack_bits)
from repro.core.engine import (ConstrainedScanner, EngineConfig, MoveEngine,
                               MoveState, mask_cross_outer_slots,
                               sanitize_outer)
from repro.core.graph import CSRGraph
from repro.core.modularity import delta_modularity


class AggregationOverflow(RuntimeError):
    """A shard owns more coarse edges than ``e_per_shard`` (community-
    ownership skew).  Carries ``owned_max`` so streaming callers can
    re-bucket into grown capacity and retry instead of dying."""

    def __init__(self, owned_max: int, e_per_shard: int):
        super().__init__(
            f"aggregation overflow: a shard owns {owned_max} coarse edges "
            f"> capacity {e_per_shard}; re-partition with more headroom "
            "(community skew)")
        self.owned_max = owned_max


class ShardedGraphSpec(NamedTuple):
    """Static layout facts for a vertex-partitioned edge list."""

    n_shards: int
    v_per_shard: int     # owned vertices per shard
    e_per_shard: int     # padded edge slots per shard
    n_pad: int           # n_shards * v_per_shard  (global padded vertex count)

    @property
    def sentinel(self) -> int:
        return self.n_pad


def bucket_slots_host(
    src: np.ndarray, dst: np.ndarray, w: np.ndarray, spec: ShardedGraphSpec
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Host-side owner bucketing of live directed slots into the padded
    per-shard edge layout described by ``spec`` (the re-bucketing primitive
    shared by initial partitioning and capacity growth)."""
    n_shards, v_per, e_per = spec.n_shards, spec.v_per_shard, spec.e_per_shard
    n_pad = spec.n_pad
    owner = src // v_per
    counts = np.bincount(owner, minlength=n_shards)
    if counts.size > n_shards or (counts.max(initial=0) > e_per):
        raise ValueError(
            f"slots do not fit the shard layout: max owned "
            f"{int(counts.max(initial=0))} > e_per_shard={e_per}")
    s_out = np.full((n_shards, e_per), n_pad, np.int32)
    d_out = np.full((n_shards, e_per), n_pad, np.int32)
    w_out = np.zeros((n_shards, e_per), np.float32)
    order = np.argsort(owner, kind="stable")
    src, dst, w, owner = src[order], dst[order], w[order], owner[order]
    starts = np.searchsorted(owner, np.arange(n_shards))
    ends = np.searchsorted(owner, np.arange(n_shards), side="right")
    for s in range(n_shards):
        cnt = ends[s] - starts[s]
        s_out[s, :cnt] = src[starts[s]:ends[s]]
        d_out[s, :cnt] = dst[starts[s]:ends[s]]
        w_out[s, :cnt] = w[starts[s]:ends[s]]
    return (jnp.asarray(s_out.reshape(-1)), jnp.asarray(d_out.reshape(-1)),
            jnp.asarray(w_out.reshape(-1)))


def partition_graph_host(
    graph: CSRGraph, n_shards: int, *,
    n_target: int | None = None, e_per_shard: int | None = None,
) -> Tuple[jax.Array, jax.Array, jax.Array, ShardedGraphSpec]:
    """Host-side 1-D vertex partition -> globally laid-out padded edge arrays.

    Shard s owns vertices [s*v, (s+1)*v) and the slice [s*E_l, (s+1)*E_l) of
    each edge array.  Padding slots carry src = dst = sentinel, w = 0.

    ``n_target``/``e_per_shard`` reserve headroom beyond the current live
    graph (streaming callers partition for ``graph.n_cap`` vertices and an
    expected insert volume so the layout survives edge batches in capacity).
    """
    n = int(n_target if n_target is not None else graph.n_valid)
    v_per = -(-n // n_shards)
    n_pad = v_per * n_shards
    src = np.asarray(graph.src)
    dst = np.asarray(graph.indices)
    w = np.asarray(graph.weights)
    live = src < graph.n_cap
    src, dst, w = src[live], dst[live], w[live]

    owner = src // v_per
    e_per = max(int(np.bincount(owner, minlength=n_shards).max()), 1,
                int(e_per_shard or 0))
    spec = ShardedGraphSpec(n_shards, v_per, e_per, n_pad)
    src_g, dst_g, w_g = bucket_slots_host(src, dst, w, spec)
    return src_g, dst_g, w_g, spec


# ---------------------------------------------------------------------------
# shard_map bodies.  ``axes`` is the tuple of mesh axis names the vertex
# partition flattens over, e.g. ("data", "model") or ("pod", "data", "model").
# ---------------------------------------------------------------------------

def _shard_index(axes):
    shard_ix = jax.lax.axis_index(axes[0])
    for ax in axes[1:]:
        shard_ix = shard_ix * compat.axis_size(ax) + jax.lax.axis_index(ax)
    return shard_ix


def _best_moves_shard(axes, spec, src_l, dst_l, w_l, comm, sigma, k,
                      frontier_l, m):
    """Per-shard best (community, dQ) for owned vertices — the sort-reduce
    scanCommunities.  Returns (best_c (v_per,), best_dq (v_per,), v0)."""
    v_per, sent = spec.v_per_shard, spec.sentinel
    v0 = _shard_index(axes) * v_per

    # Local segment space: owned vertices -> [0, v_per), everything else -> v_per.
    src_loc = jnp.where(src_l >= sent, v_per, src_l - v0)
    cdst = comm[dst_l]

    own_comm_l = jax.lax.dynamic_slice_in_dim(comm, v0, v_per)  # (v_per,)
    c_own_e = comm[src_l]                                        # per-edge own community
    own_edge = (cdst == c_own_e) & (dst_l != src_l)
    k_to_own = jax.ops.segment_sum(
        jnp.where(own_edge, w_l, 0.0), src_loc, num_segments=v_per + 1)

    order = jnp.lexsort((cdst, src_loc))
    s_src = src_loc[order]
    s_c = cdst[order]
    s_w = jnp.where(dst_l[order] == src_l[order], 0.0, w_l[order])
    prev_src = jnp.concatenate([jnp.full((1,), -1, jnp.int32), s_src[:-1]])
    prev_c = jnp.concatenate([jnp.full((1,), -1, jnp.int32), s_c[:-1]])
    new_group = (s_src != prev_src) | (s_c != prev_c)
    gid = jnp.cumsum(new_group.astype(jnp.int32)) - 1
    k_i_to_c = jax.ops.segment_sum(s_w, gid, num_segments=s_w.shape[0])[gid]

    k_l = jax.lax.dynamic_slice_in_dim(k, v0, v_per)
    sig_own_l = sigma[own_comm_l]
    valid_row = s_src < v_per
    dq = delta_modularity(
        k_i_to_c,
        jnp.where(valid_row, k_to_own[s_src], 0.0),
        jnp.where(valid_row, k_l[jnp.minimum(s_src, v_per - 1)], 0.0),
        sigma[jnp.minimum(s_c, sent)],
        jnp.where(valid_row, sig_own_l[jnp.minimum(s_src, v_per - 1)], 0.0),
        m,
    )
    c_own_sorted = comm[src_l[order]]
    valid = valid_row & (s_c != c_own_sorted) & (s_c < sent) & frontier_l[
        jnp.minimum(s_src, v_per - 1)]
    dq = jnp.where(valid, dq, -jnp.inf)
    best_dq = jax.ops.segment_max(dq, s_src, num_segments=v_per + 1)[:v_per]
    best_dq = jnp.where(jnp.isfinite(best_dq), best_dq, -jnp.inf)
    is_best = valid & (dq == jnp.pad(best_dq, (0, 1), constant_values=-jnp.inf)[
        jnp.minimum(s_src, v_per)])
    best_c = jax.ops.segment_min(
        jnp.where(is_best, s_c, sent), s_src, num_segments=v_per + 1)[:v_per]
    best_c = jnp.minimum(best_c, sent)
    return best_c, best_dq, v0


class ShardedScanner:
    """Engine backend: per-shard sort-reduce scan + collective topology.

    Lives inside ``shard_map``: local layout is the shard's ``v_per_shard``
    owned vertices; community state (C, Sigma) is replicated ``(n_pad + 1,)``
    and updated with one ``all_gather`` (owned C slices + moved flags) and
    one ``psum`` (Sigma deltas) per round — the Vite-style ghost exchange,
    expressed as XLA collectives.  See ``repro.core.engine.MoveEngine`` for
    the protocol.
    """

    def __init__(self, axes, spec: ShardedGraphSpec, src_l, dst_l, w_l,
                 k, m):
        v_per, sent = spec.v_per_shard, spec.sentinel
        self.axes, self.spec = axes, spec
        self.src_l, self.dst_l, self.w_l = src_l, dst_l, w_l
        self.k, self.m = k, m
        self.sentinel = sent
        self.v0 = _shard_index(axes) * v_per
        self.local_ids = self.v0 + jnp.arange(v_per)
        self.k_local = jax.lax.dynamic_slice_in_dim(k, self.v0, v_per)
        self.src_loc = jnp.where(src_l >= sent, v_per, src_l - self.v0)
        self.move_valid = None           # invalid slots carry comm == sent
        self.frontier_valid = self.local_ids < spec.n_pad

    def scan(self, comm, sigma, frontier):
        best_c, best_dq, _ = _best_moves_shard(
            self.axes, self.spec, self.src_l, self.dst_l, self.w_l,
            comm, sigma, self.k, frontier, self.m)
        return best_c, best_dq

    def comm_local(self, comm):
        return jax.lax.dynamic_slice_in_dim(comm, self.v0,
                                            self.spec.v_per_shard)

    def count_ones(self, comm_l):
        return jnp.where(comm_l < self.sentinel, 1, 0)  # ghosts excluded

    def psum(self, x):
        return jax.lax.psum(x, self.axes)

    def combine_sigma(self, sigma, add, sub):
        return sigma + self.psum(add - sub)

    def gather_comm(self, comm_l):
        full = jax.lax.all_gather(comm_l, self.axes, tiled=True)
        return jnp.concatenate(
            [full, jnp.asarray([self.sentinel], jnp.int32)])

    def gather_mask(self, mask_l):
        full = jax.lax.all_gather(mask_l, self.axes, tiled=True)
        return jnp.concatenate([full, jnp.zeros((1,), bool)])

    def mark_neighbors(self, moved):
        v_per = self.spec.v_per_shard
        marked = jax.ops.segment_max(
            moved[self.dst_l].astype(jnp.int32), self.src_loc,
            num_segments=v_per + 1)[:v_per]
        return marked > 0


class DeltaShardedScanner(ShardedScanner):
    """Communication-lean engine backend: same scan, movers-only exchange.

    Per round the gather backend ships two dense O(n_pad) psums (Sigma,
    community sizes) plus the owned membership slice and moved mask.  This
    backend ships ONLY the movers — each as a (local index, new label)
    pair bit-packed to the minimum lane width for the layout
    (``repro.core.comm.pack_bits``) — and reconstructs every other array
    locally, because each receiver already replicates the state the deltas
    derive from:

      * Sigma updates: a mover shifts exactly its vertex weight ``K_i``
        from its old to its new community, and both ``k`` and the previous
        membership are replicated, so each shard rebuilds every shard's
        dense (add - sub) from the gathered movers — zero Sigma bytes;
      * community sizes: +1 / -1 at the movers' new / old labels,
        maintained incrementally across rounds (integer-exact in any
        order), seeded once per phase from ``community_sizes``;
      * the moved mask: a move always changes the label, so it is the
        compare ``comm' != comm``.

    The movers ride ONE fused ``all_gather`` per round — the mover count,
    the local dq, and the packed lanes concatenated into a single uint32
    wire word per shard — because collective rendezvous, not payload
    bytes, dominates small-round latency (the gather backend pays five
    collectives per round; this backend pays one).  The gathered counts
    are replicated by construction, so every shard decides the overflow
    branch locally: a round whose movers exceed the static cap runs the
    dense exchange inside ``lax.cond`` — the cap bounds compile shapes,
    never correctness.  Reconstruction mirrors the gather backend's
    arithmetic per shard (identical segment-sum orders, then one dense
    apply), so on one shard the result matches the default path bit for
    bit and every committed sharded golden is reproduced (pinned in
    tests/test_engine_equiv.py).  Cap policy:
    ``repro.configs.louvain_arch.delta_move_cap``.
    """

    def __init__(self, axes, spec: ShardedGraphSpec, src_l, dst_l, w_l,
                 k, m):
        super().__init__(axes, spec, src_l, dst_l, w_l, k, m)
        from repro.configs.louvain_arch import delta_move_cap
        self.move_cap = delta_move_cap(spec.v_per_shard)
        self.idx_width = label_bits(spec.v_per_shard + 1)
        self.lab_width = label_bits(spec.n_pad + 1)
        # Movers ship as ONE fused (index, label) pair per entry when the
        # pair fits an int32 — one pack/unpack instead of two.  Layouts too
        # wide for that (v_per * n_pad ~ 2^31) fall back to separate lanes.
        self.pair_width = self.idx_width + self.lab_width
        if self.pair_width <= 31:
            self.mover_lanes = packed_lanes(self.move_cap, self.pair_width)
        else:
            self.pair_width = None
            self.mover_lanes = (packed_lanes(self.move_cap, self.idx_width)
                                + packed_lanes(self.move_cap, self.lab_width))

    def community_sizes(self, comm, comm_l):
        # The replicated membership already holds every shard's slice, so
        # the psum'd per-shard size reduction collapses to one local
        # segment_sum — integer addition reorders exactly.
        sent = self.sentinel
        body = comm[:sent]
        return jax.ops.segment_sum(
            jnp.where(body < sent, 1, 0), jnp.minimum(body, sent),
            num_segments=sent + 1)

    def exchange_round(self, comm, sigma, sizes, comm_l, do_move, best_c,
                       dq_local):
        axes, spec = self.axes, self.spec
        v_per, sent = spec.v_per_shard, self.sentinel
        S, mcap = spec.n_shards, self.move_cap

        if self.pair_width is not None:
            # Fused (index, label) pairs: one compaction, one pack.  The
            # empty-slot fill decodes to index == v_per -> dropped below.
            pv = (jnp.arange(v_per, dtype=jnp.int32)
                  | (best_c << self.idx_width))
            _, pair_buf, n_moved = compact_movers(
                do_move, pv, mcap, jnp.int32(v_per))
            mover_lanes = pack_bits(pair_buf, self.pair_width)
        else:
            idx_buf, lab_buf, n_moved = compact_movers(
                do_move, best_c, mcap, jnp.int32(sent))
            mover_lanes = jnp.concatenate([
                pack_bits(idx_buf, self.idx_width),
                pack_bits(lab_buf, self.lab_width)])

        # ONE fused collective: mover count + local dq + packed mover
        # lanes, concatenated into a single uint32 word per shard.
        wire = jnp.concatenate([
            jnp.stack([n_moved.astype(jnp.uint32),
                       jax.lax.bitcast_convert_type(
                           dq_local.astype(jnp.float32), jnp.uint32)]),
            mover_lanes,
        ])
        g = jax.lax.all_gather(wire, axes)                 # (S, W)
        dq = jnp.sum(jax.lax.bitcast_convert_type(g[:, 1], jnp.float32))
        # Every shard sees every shard's counts, so the branch choice below
        # is replicated by construction — no extra pmax round-trip.
        over = jnp.max(g[:, 0].astype(jnp.int32)) > mcap
        g_mov = g[:, 2:]                                   # packed lanes

        def dense(_):
            # The per-community segment sums live HERE, not in the engine:
            # lax.cond operands are computed eagerly, so reducing them in
            # the branch means regular rounds never pay for them.
            moved_k = jnp.where(do_move, self.k_local, 0.0)
            add = jax.ops.segment_sum(
                moved_k, jnp.where(do_move, best_c, sent),
                num_segments=sent + 1)
            sub = jax.ops.segment_sum(
                moved_k, jnp.where(do_move, comm_l, sent),
                num_segments=sent + 1)
            comm_full = self.gather_comm(jnp.where(do_move, best_c, comm_l))
            return (comm_full, self.combine_sigma(sigma, add, sub),
                    self.community_sizes(comm_full, comm_l))

        def delta(_):
            if self.pair_width is not None:
                pairs = jax.vmap(
                    lambda r: unpack_bits(r, self.pair_width, mcap))(g_mov)
                idxs = pairs & ((1 << self.idx_width) - 1)
                labs = pairs >> self.idx_width
            else:
                li = packed_lanes(mcap, self.idx_width)
                idxs = jax.vmap(lambda r: unpack_bits(
                    r, self.idx_width, mcap))(g_mov[:, :li])
                labs = jax.vmap(lambda r: unpack_bits(
                    r, self.lab_width, mcap))(g_mov[:, li:])
            live = idxs < v_per                            # (S, mcap)
            base = jnp.arange(S, dtype=jnp.int32)[:, None] * v_per
            # Dead buffer slots route out of bounds -> the scatter drops
            # them (jnp default), leaving the sentinel slots alone.
            gid = jnp.where(live, base + idxs, sent + 1)
            lab = jnp.minimum(labs, sent)
            comm_new = comm.at[gid.reshape(-1)].set(lab.reshape(-1))

            # Sigma reconstruction: k and the pre-move membership are
            # replicated, so each mover's weight and old community are
            # local lookups.  Rebuild the dense mover-weight add / sub
            # arrays in the sender's segment-sum order (movers ascend by
            # vertex index in the buffer), subtract, then apply in ONE
            # dense add — on one shard that is exactly ``combine_sigma``'s
            # sigma + psum(add - sub) arithmetic, bit for bit.
            safe = jnp.where(live, gid, 0)
            kv = jnp.where(live, self.k[safe], 0.0).reshape(-1)
            old = jnp.where(live, comm[safe], sent + 1).reshape(-1)
            new = jnp.where(live, lab, sent + 1).reshape(-1)
            radd = jnp.zeros((sent + 2,), jnp.float32).at[new].add(kv)
            rsub = jnp.zeros((sent + 2,), jnp.float32).at[old].add(kv)
            sigma_new = sigma + (radd - rsub)[:sent + 1]

            # Sizes shift by +-1 at the movers' labels — integer adds
            # reorder exactly, so the running array equals a recompute.
            sizes_new = sizes.at[new].add(1).at[old].add(-1)
            return comm_new, sigma_new, sizes_new

        comm_new, sigma_new, sizes_new = jax.lax.cond(over, dense, delta, 0)
        # Movers are exactly the label changes (a move always changes the
        # label), so the moved mask is a compare, not another collective.
        moved_g = comm_new != comm
        return (comm_new, sigma_new, sizes_new, moved_g,
                over.astype(jnp.int32), dq)


class HybridShardedScanner(DeltaShardedScanner):
    """Owner-partitioned working state, replicated topology (P3 hybrid).

    The replicated-state scanners above rebuild the FULL ``(n_pad + 1,)``
    membership on every shard every round, so per-round payload and
    per-lane working state both scale with n.  This backend keeps
    membership fresh only where scanning actually reads it —

      * the shard's OWN ``v_per_shard`` block (``comm_local`` slices), and
      * each remote shard's BOUNDARY set: vertices with at least one
        cross-shard edge (``repro.core.comm.boundary_mask``).  Symmetric
        slot placement guarantees every remote ``dst_l`` a shard reads is
        in its owner's boundary, so publishing boundary movers keeps all
        cross-shard reads fresh; remote INTERIOR labels go stale and are
        provably never read mid-phase.

    K_i stays fully partitioned (only ``k_local`` is ever indexed), so
    receivers cannot rebuild Sigma from mover ids the way the delta
    backend does.  Instead each sender folds its OWN movers (interior and
    boundary alike) into per-community (Sigma, size) deltas locally and
    ships the compacted touched-community triples — ids, f32 Sigma deltas,
    offset-encoded size deltas (``size_delta_width``) — alongside the
    boundary movers, all fused into the backend's single uint32 wire word
    per round.  Replicated Sigma/sizes then advance by scatter-adds of the
    gathered deltas: identical f32 ops at touched communities and
    untouched slots left byte-identical (the dense paths add +0.0 there),
    so one-shard runs reproduce the committed goldens bit for bit and
    multi-shard runs match on integer-weight graphs.

    The phase ends with ONE owned-slice ``all_gather`` (``resync_comm``,
    priced as the plan's ``phase_fixed_bytes``) that re-replicates the
    final membership, so renumbering, aggregation, refinement's outer
    fold, warm restarts and fleet replay all run unchanged downstream —
    hybrid joins the golden matrix, never forks it.

    Flavors: the delta flavor (this class) keeps the policy mover cap and
    ``hybrid_touched_cap`` with the dense-resync ``lax.cond`` fallback;
    the gather flavor (`HybridGatherScanner`) prices worst-case caps
    (every vertex a boundary mover, every community touched) so it is
    overflow-free and skips the branch entirely — still far below the
    replicated gather backend's five dense O(n_pad) collectives.
    """

    can_overflow = True     # delta flavor: policy caps + dense fallback

    def __init__(self, axes, spec: ShardedGraphSpec, src_l, dst_l, w_l,
                 k, m):
        super().__init__(axes, spec, src_l, dst_l, w_l, k, m)
        from repro.configs.louvain_arch import hybrid_touched_cap
        v_per, sent = spec.v_per_shard, spec.sentinel
        if self.can_overflow:
            self.touched_cap = hybrid_touched_cap(v_per)
        else:
            # Worst-case caps: every owned vertex a boundary mover, each
            # touching a distinct old + new community.  Mover lane widths
            # recomputed to match (DeltaShardedScanner sized them for the
            # policy cap).
            self.move_cap = v_per
            self.touched_cap = 2 * v_per
            if self.pair_width is not None:
                self.mover_lanes = packed_lanes(self.move_cap,
                                                self.pair_width)
            else:
                self.mover_lanes = (
                    packed_lanes(self.move_cap, self.idx_width)
                    + packed_lanes(self.move_cap, self.lab_width))
        self.siz_width = size_delta_width(v_per)
        # The halo set: computed ONCE per phase from the sharded topology
        # (scanner construction), which also re-derives it at aggregation
        # and re-shard boundaries for free — those rebuild the scanner.
        self.bnd_own = boundary_mask(src_l, dst_l, self.v0, v_per, sent)

    def resync_comm(self, comm):
        """Phase-end re-replication: ONE all_gather of the fresh owned
        slices (stale remote-interior labels overwritten), so everything
        downstream of the move phase sees replicated state again."""
        return self.gather_comm(self.comm_local(comm))

    def exchange_round(self, comm, sigma, sizes, comm_l, do_move, best_c,
                       dq_local):
        axes, spec = self.axes, self.spec
        v_per, sent = spec.v_per_shard, self.sentinel
        S, mcap, tcap = spec.n_shards, self.move_cap, self.touched_cap
        lab_w = self.lab_width

        # Own movers — ALL of them, interior included — apply locally.
        comm_own_new = jnp.where(do_move, best_c, comm_l)

        # Sender-side per-community fold from the PARTITIONED K_i: only
        # k_local is read, in the same segment-sum order the dense paths
        # use, so the shipped deltas reproduce their arithmetic exactly.
        moved_k = jnp.where(do_move, self.k_local, 0.0)
        tgt = jnp.where(do_move, best_c, sent)
        old = jnp.where(do_move, comm_l, sent)
        add = jax.ops.segment_sum(moved_k, tgt, num_segments=sent + 1)
        sub = jax.ops.segment_sum(moved_k, old, num_segments=sent + 1)
        cnt_add = jax.ops.segment_sum(do_move.astype(jnp.int32), tgt,
                                      num_segments=sent + 1)
        cnt_sub = jax.ops.segment_sum(do_move.astype(jnp.int32), old,
                                      num_segments=sent + 1)
        touched = (cnt_add > 0) | (cnt_sub > 0)
        # Same compaction for both delta kinds (identical c_buf order).
        c_buf, ds_buf, n_t = topk_touched_deltas(add - sub, touched, tcap,
                                                 sent)
        _, dz_buf, _ = topk_touched_deltas(cnt_add - cnt_sub, touched,
                                           tcap, sent)

        # Only BOUNDARY movers travel; receivers rebuild global ids from
        # the sender's row index, so no id lists ever cross the wire.
        bnd_move = do_move & self.bnd_own
        if self.pair_width is not None:
            pv = (jnp.arange(v_per, dtype=jnp.int32)
                  | (best_c << self.idx_width))
            _, pair_buf, n_bnd = compact_movers(
                bnd_move, pv, mcap, jnp.int32(v_per))
            mover_lanes = pack_bits(pair_buf, self.pair_width)
        else:
            idx_buf, lab_buf, n_bnd = compact_movers(
                bnd_move, best_c, mcap, jnp.int32(sent))
            mover_lanes = jnp.concatenate([
                pack_bits(idx_buf, self.idx_width),
                pack_bits(lab_buf, self.lab_width)])

        # ONE fused collective: [boundary-mover count, touched count, dq]
        # header + boundary movers + touched ids + Sigma f32 deltas +
        # offset-encoded size deltas (delta + v_per, always nonnegative).
        wire = jnp.concatenate([
            jnp.stack([n_bnd.astype(jnp.uint32), n_t.astype(jnp.uint32),
                       jax.lax.bitcast_convert_type(
                           dq_local.astype(jnp.float32), jnp.uint32)]),
            mover_lanes,
            pack_bits(c_buf, lab_w),
            jax.lax.bitcast_convert_type(ds_buf, jnp.uint32),
            pack_bits(dz_buf + v_per, self.siz_width),
        ])
        g = jax.lax.all_gather(wire, axes)                  # (S, W)
        dq = jnp.sum(jax.lax.bitcast_convert_type(g[:, 2], jnp.float32))
        L_m, L_t = self.mover_lanes, packed_lanes(tcap, lab_w)
        g_mov = g[:, 3:3 + L_m]
        g_tid = g[:, 3 + L_m:3 + L_m + L_t]
        g_sig = g[:, 3 + L_m + L_t:3 + L_m + L_t + tcap]
        g_siz = g[:, 3 + L_m + L_t + tcap:]

        def apply_deltas(_):
            # Membership: own block from the local update, remote boundary
            # movers scattered in (dead buffer slots route out of bounds
            # and drop); remote interior stays stale — never read.
            comm_base = jax.lax.dynamic_update_slice(comm, comm_own_new,
                                                     (self.v0,))
            if self.pair_width is not None:
                pairs = jax.vmap(
                    lambda r: unpack_bits(r, self.pair_width, mcap))(g_mov)
                idxs = pairs & ((1 << self.idx_width) - 1)
                labs = pairs >> self.idx_width
            else:
                li = packed_lanes(mcap, self.idx_width)
                idxs = jax.vmap(lambda r: unpack_bits(
                    r, self.idx_width, mcap))(g_mov[:, :li])
                labs = jax.vmap(lambda r: unpack_bits(
                    r, self.lab_width, mcap))(g_mov[:, li:])
            live = idxs < v_per
            base = jnp.arange(S, dtype=jnp.int32)[:, None] * v_per
            gid = jnp.where(live, base + idxs, sent + 1)
            lab = jnp.minimum(labs, sent)
            comm_new = comm_base.at[gid.reshape(-1)].set(lab.reshape(-1))

            # Sigma / sizes: scatter-add every shard's touched deltas.
            # Empty buffer slots carry (id = sent, delta = 0) — adding
            # +0.0 / +0 there is byte-safe (Sigma holds sums of
            # nonnegative K_i, never -0.0).
            cs = jnp.minimum(jax.vmap(
                lambda r: unpack_bits(r, lab_w, tcap))(g_tid),
                sent).reshape(-1)
            sig_d = jax.lax.bitcast_convert_type(
                g_sig, jnp.float32).reshape(-1)
            siz_d = (jax.vmap(lambda r: unpack_bits(
                r, self.siz_width, tcap))(g_siz) - v_per).reshape(-1)
            sigma_new = sigma.at[cs].add(sig_d)
            sizes_new = sizes.at[cs].add(siz_d)

            # Fresh everywhere this mask is read: own block and remote
            # boundary (symmetric placement routes every ``dst_l`` there);
            # stale interior compares equal -> False, which is correct
            # for the LOCAL frontier mark.
            moved_g = comm_new != comm
            return comm_new, sigma_new, sizes_new, moved_g

        if not self.can_overflow:
            # Gather flavor: worst-case caps -> no overflow branch at all.
            comm_new, sigma_new, sizes_new, moved_g = apply_deltas(0)
            over = jnp.zeros((), bool)
        else:
            # Counts are gathered, so the branch choice is replicated.
            over = ((jnp.max(g[:, 0].astype(jnp.int32)) > mcap)
                    | (jnp.max(g[:, 1].astype(jnp.int32)) > tcap))

            def dense(_):
                # Full resync: owned slices are fresh by construction, so
                # one gather rebuilds replicated state exactly.  The moved
                # mask must come from do_move — the stale pre-round comm
                # makes the label compare unsound here.
                comm_full = self.gather_comm(comm_own_new)
                sigma_full = self.combine_sigma(sigma, add, sub)
                sizes_full = sizes + self.psum(cnt_add - cnt_sub)
                return (comm_full, sigma_full, sizes_full,
                        self.gather_mask(do_move))

            comm_new, sigma_new, sizes_new, moved_g = jax.lax.cond(
                over, dense, apply_deltas, 0)
        return (comm_new, sigma_new, sizes_new, moved_g,
                over.astype(jnp.int32), dq)


class HybridGatherScanner(HybridShardedScanner):
    """Hybrid state layout over the gather comm backend: worst-case caps
    (every owned vertex a boundary mover, ``2 * v_per`` touched
    communities) make the exchange overflow-free, so the round is still
    ONE fused collective — ~4-5x fewer bytes than replicated gather's
    five dense O(n_pad) collectives at the bench layout."""

    can_overflow = False


#: comm_backend -> engine scanner class (concrete backends only; "auto"
#: resolves through repro.configs.louvain_arch.resolve_comm_backend).
COMM_SCANNERS = {"gather": ShardedScanner, "delta": DeltaShardedScanner}

#: comm_backend -> scanner under the HYBRID state layout ("auto" resolves
#: through repro.configs.louvain_arch.resolve_state_layout).
HYBRID_SCANNERS = {"gather": HybridGatherScanner,
                   "delta": HybridShardedScanner}


def _scanner_cls(backend: str, state_layout: str):
    """Engine scanner for a (comm backend, state layout) pair — concrete
    values only; policies resolve in the drivers."""
    table = HYBRID_SCANNERS if state_layout == "hybrid" else COMM_SCANNERS
    return table[backend]


def sharded_comm_plan(spec: ShardedGraphSpec, backend: str,
                      state_layout: str = "replicated") -> CommPlan:
    """Bytes-on-wire plan for one engine round of ``spec`` under
    ``backend`` x ``state_layout`` (policy caps applied — ONE home for the
    accounting the pass-loop stats and the distdyn benchmark report)."""
    from repro.configs.louvain_arch import (delta_move_cap,
                                            hybrid_touched_cap)
    return comm_plan(backend, spec.n_shards, spec.v_per_shard, spec.n_pad,
                     delta_move_cap(spec.v_per_shard),
                     state_layout=state_layout,
                     touched_cap=hybrid_touched_cap(spec.v_per_shard))


def measure_boundary_frac(src_g, dst_g, spec: ShardedGraphSpec,
                          n_live: int | None = None) -> float:
    """Host-side boundary fraction of a partitioned layout: the share of
    live (edge-owning) vertices with at least one cross-shard slot.

    This is the measurement the ``state_layout="auto"`` policy consumes
    (``repro.configs.louvain_arch.resolve_state_layout``), mirroring how
    the measured mesh size drives ``resolve_comm_backend`` — hybrid only
    engages when the halo the layout would replicate is small.  ``n_live``
    overrides the denominator with the caller's live-vertex count;
    otherwise vertices owning no live slot are excluded from both sides.
    """
    src = np.asarray(src_g).ravel()
    dst = np.asarray(dst_g).ravel()
    sent, v_per = spec.sentinel, spec.v_per_shard
    live = (src < sent) & (dst < sent)
    if n_live is None:
        n_live = int(np.unique(src[live]).size)
    remote = live & (src // v_per != dst // v_per)
    n_bnd = int(np.unique(src[remote]).size)
    return n_bnd / max(int(n_live), 1)


def _round_body(axes, spec, src_l, dst_l, w_l, comm, sigma, k,
                frontier_l, round_ix, gate_fraction, m):
    """One synchronous local-move round for one shard; returns updates.

    Compatibility adapter over ``MoveEngine.one_round`` (the analysis
    harness in ``repro.configs.louvain_arch`` drives single rounds).
    """
    engine = MoveEngine(ShardedScanner(axes, spec, src_l, dst_l, w_l, k, m),
                        EngineConfig(gate_fraction=gate_fraction))
    zero = jnp.asarray(0.0, jnp.float32)
    st = MoveState(comm, sigma, jnp.asarray(0, jnp.int32), frontier_l,
                   jnp.asarray(0, jnp.int32), zero, zero,
                   jnp.asarray(0, jnp.int32))
    st = engine.one_round(st, frontier_l, round_ix)
    return st.comm, st.sigma, st.frontier, st.dq


@functools.lru_cache(maxsize=None)
def make_distributed_move(
    mesh: Mesh,
    axes: Tuple[str, ...],
    spec: ShardedGraphSpec,
    *,
    max_iterations: int = 20,
    gate_fraction: int = 2,
    use_pruning: bool = True,
    comm_backend: str = "gather",
    state_layout: str = "replicated",
):
    """Build the jit'd distributed local-moving phase for a fixed mesh/layout.

    Returns fn(src_g, dst_g, w_g, comm, sigma, k, frontier_g, m, tolerance)
        -> (comm, sigma, iters, dq_sum, rounds, fallbacks);
    comm/sigma replicated outputs, ``rounds`` the synchronous rounds run
    (sweeps x gate_fraction) and ``fallbacks`` how many of them the delta
    exchange overflowed to the dense path (0 under "gather").

    ``frontier_g`` is a replicated (n_pad + 1,) seed-frontier mask — all-ones
    for the static start, the delta-screened set for warm streaming starts
    (each shard slices its owned v_per entries).  ``comm_backend`` picks the
    per-round exchange (``COMM_SCANNERS``; "auto" resolves per mesh) and
    ``state_layout`` the working-state placement (``HYBRID_SCANNERS`` under
    "hybrid"; "auto" without a measured boundary fraction stays
    replicated).  Hybrid phases resync the membership once before
    returning, so outputs are replicated under every layout.
    """
    from repro.configs.louvain_arch import (resolve_comm_backend,
                                            resolve_state_layout)

    edge_spec = P(axes)      # edge arrays: sharded along dim 0 over all axes
    rep = P()                # replicated state

    scanner_cls = _scanner_cls(
        resolve_comm_backend(comm_backend, spec.n_shards),
        resolve_state_layout(state_layout, spec.n_shards))
    config = EngineConfig(max_iterations=max_iterations,
                          use_pruning=use_pruning,
                          gate_fraction=gate_fraction)

    def phase(src_g, dst_g, w_g, comm, sigma, k, frontier_g, m, tolerance):
        def body_shard(src_l, dst_l, w_l, comm, sigma, k, frontier_g, m,
                       tolerance):
            scanner = scanner_cls(axes, spec, src_l, dst_l, w_l, k, m)
            frontier0 = jax.lax.dynamic_slice_in_dim(
                frontier_g, scanner.v0, spec.v_per_shard
            ) & scanner.frontier_valid
            st = MoveEngine(scanner, config).run(comm, sigma, frontier0,
                                                 tolerance)
            resync = getattr(scanner, "resync_comm", None)
            comm_out = st.comm if resync is None else resync(st.comm)
            return (comm_out, st.sigma, st.iters, st.dq_sum,
                    st.iters * jnp.int32(gate_fraction), st.comm_fb)

        fn = shard_map(
            body_shard, mesh=mesh,
            in_specs=(edge_spec, edge_spec, edge_spec, rep, rep, rep, rep,
                      rep, rep),
            out_specs=(rep, rep, rep, rep, rep, rep),
            check_rep=False,
        )
        return fn(src_g, dst_g, w_g, comm, sigma, k, frontier_g, m, tolerance)

    return jax.jit(phase)


@functools.lru_cache(maxsize=None)
def make_distributed_refine(
    mesh: Mesh,
    axes: Tuple[str, ...],
    spec: ShardedGraphSpec,
    *,
    max_iterations: int = 20,
    gate_fraction: int = 2,
    use_pruning: bool = True,
    comm_backend: str = "gather",
    state_layout: str = "replicated",
):
    """Build the jit'd distributed Leiden REFINEMENT phase.

    Returns fn(src_g, dst_g, w_g, outer, k, n_live, m, tolerance)
        -> (comm, iters, dq_sum, rounds, fallbacks)
    — the constrained engine sweep: every vertex re-seeds as a singleton and
    may only join communities inside its outer community (``outer``, the
    replicated membership from the preceding move phase).  Per shard the
    cross-outer edge slots are masked (dst -> sentinel, w -> 0) and the same
    exchange scanner the move phase uses is wrapped in
    ``engine.ConstrainedScanner`` — so the gather and delta comm backends
    both inherit refinement with zero forks.  ``k``/``m`` stay the FULL
    graph's quantities.

    ``n_live`` is the scalar live count for dense-prefix layouts or a
    replicated ``(n_pad + 1,)`` bool live mask for gappy (skew-resharded)
    layouts — ``sanitize_outer`` and the singleton seed accept both.
    """
    from repro.configs.louvain_arch import (resolve_comm_backend,
                                            resolve_state_layout)

    edge_spec = P(axes)
    rep = P()
    sent = spec.sentinel
    scanner_cls = _scanner_cls(
        resolve_comm_backend(comm_backend, spec.n_shards),
        resolve_state_layout(state_layout, spec.n_shards))
    config = EngineConfig(max_iterations=max_iterations,
                          use_pruning=use_pruning,
                          gate_fraction=gate_fraction)

    def phase(src_g, dst_g, w_g, outer, k, n_live, m, tolerance):
        def body_shard(src_l, dst_l, w_l, outer, k, n_live, m, tolerance):
            outer_s = sanitize_outer(outer, n_live, sent)
            dst_m, w_m = mask_cross_outer_slots(src_l, dst_l, w_l, outer_s,
                                                sent)
            scanner = ConstrainedScanner(
                scanner_cls(axes, spec, src_l, dst_m, w_m, k, m),
                outer_s, n_live, gate_fraction=gate_fraction)
            ids = jnp.arange(sent + 1)
            nv = jnp.asarray(n_live)
            live_v = (nv & (ids < sent)) if nv.ndim else (ids < nv)
            comm0 = jnp.where(live_v, ids, sent).astype(jnp.int32)
            frontier0 = scanner.frontier_valid & live_v[
                jnp.minimum(scanner.local_ids, sent)]
            st = MoveEngine(scanner, config).run(comm0, k, frontier0,
                                                 tolerance)
            resync = getattr(scanner, "resync_comm", None)
            comm_out = st.comm if resync is None else resync(st.comm)
            return (comm_out, st.iters, st.dq_sum,
                    st.iters * jnp.int32(gate_fraction), st.comm_fb)

        fn = shard_map(
            body_shard, mesh=mesh,
            in_specs=(edge_spec, edge_spec, edge_spec, rep, rep, rep, rep,
                      rep),
            out_specs=(rep, rep, rep, rep, rep),
            check_rep=False,
        )
        return fn(src_g, dst_g, w_g, outer, k, n_live, m, tolerance)

    return jax.jit(phase)


@functools.lru_cache(maxsize=None)
def make_tier_phases(mesh: Mesh, axes: Tuple[str, ...], *,
                     max_iterations: int = 20, gate_fraction: int = 2,
                     use_pruning: bool = True, comm_backend: str = "gather",
                     state_layout: str = "replicated", refine: str = "none"):
    """The capacity-ladder phase factory: ``spec -> (move, agg, refine_move)``,
    cached so every tier's phases compile once and are reused across
    passes/batches (static and streaming drivers share this ONE builder).
    ``refine_move`` is ``None`` unless ``refine="leiden"`` — then it is the
    constrained-sweep phase from ``make_distributed_refine``.  The factory
    itself is cached on (mesh, axes, knobs) too — REPEATED driver calls on
    the same mesh (benchmarks, streaming restarts) must reuse the compiled
    phases instead of paying the XLA compile per call, which otherwise
    dominates small-graph wall time."""

    @functools.lru_cache(maxsize=None)
    def phases_for(spec_: ShardedGraphSpec):
        return (make_distributed_move(
                    mesh, axes, spec_, max_iterations=max_iterations,
                    gate_fraction=gate_fraction, use_pruning=use_pruning,
                    comm_backend=comm_backend, state_layout=state_layout),
                make_distributed_aggregate(mesh, axes, spec_),
                (make_distributed_refine(
                     mesh, axes, spec_, max_iterations=max_iterations,
                     gate_fraction=gate_fraction, use_pruning=use_pruning,
                     comm_backend=comm_backend, state_layout=state_layout)
                 if refine == "leiden" else None))

    return phases_for


@functools.lru_cache(maxsize=None)
def make_distributed_aggregate(mesh: Mesh, axes: Tuple[str, ...],
                               spec: ShardedGraphSpec):
    """Distributed coarsening: local sort-reduce, all_gather partials,
    owner-side re-reduce.  Returns fn(src_g, dst_g, w_g, comm_renumbered)
    -> (src_g', dst_g', w_g', e_valid) in the same edge layout for the coarse
    graph (coarse vertex v owned by shard v // v_per_shard)."""
    edge_spec = P(axes)
    rep = P()
    n_shards = spec.n_shards

    def body(src_l, dst_l, w_l, comm):
        v_per, sent = spec.v_per_shard, spec.sentinel
        e_l = src_l.shape[0]
        ci = comm[src_l]
        cj = comm[dst_l]

        # Local partial reduce.
        order = jnp.lexsort((cj, ci))
        s_ci, s_cj, s_w = ci[order], cj[order], w_l[order]
        prev_i = jnp.concatenate([jnp.full((1,), -1, jnp.int32), s_ci[:-1]])
        prev_j = jnp.concatenate([jnp.full((1,), -1, jnp.int32), s_cj[:-1]])
        new_group = (s_ci != prev_i) | (s_cj != prev_j)
        gidl = jnp.cumsum(new_group.astype(jnp.int32)) - 1
        gw = jax.ops.segment_sum(s_w, gidl, num_segments=e_l)[gidl]
        live = new_group & (s_ci != sent)
        pos = jnp.where(live, gidl, e_l)
        p_ci = jnp.full((e_l + 1,), sent, jnp.int32).at[pos].set(s_ci)[:e_l]
        p_cj = jnp.full((e_l + 1,), sent, jnp.int32).at[pos].set(s_cj)[:e_l]
        p_w = jnp.zeros((e_l + 1,), jnp.float32).at[pos].set(gw)[:e_l]

        # Share partials; each shard re-reduces and keeps its owned rows.
        g_ci = jax.lax.all_gather(p_ci, axes, tiled=True)   # (S * e_l,)
        g_cj = jax.lax.all_gather(p_cj, axes, tiled=True)
        g_w = jax.lax.all_gather(p_w, axes, tiled=True)

        shard_ix = _shard_index(axes)
        v0 = shard_ix * v_per
        mine = (g_ci >= v0) & (g_ci < v0 + v_per)
        m_ci = jnp.where(mine, g_ci, sent)
        m_cj = jnp.where(mine, g_cj, sent)
        m_w = jnp.where(mine, g_w, 0.0)

        order2 = jnp.lexsort((m_cj, m_ci))
        t_ci, t_cj, t_w = m_ci[order2], m_cj[order2], m_w[order2]
        prev_i = jnp.concatenate([jnp.full((1,), -1, jnp.int32), t_ci[:-1]])
        prev_j = jnp.concatenate([jnp.full((1,), -1, jnp.int32), t_cj[:-1]])
        ng2 = (t_ci != prev_i) | (t_cj != prev_j)
        gid2 = jnp.cumsum(ng2.astype(jnp.int32)) - 1
        gw2 = jax.ops.segment_sum(t_w, gid2, num_segments=t_w.shape[0])[gid2]
        live2 = ng2 & (t_ci != sent)
        pos2 = jnp.where(live2, gid2, e_l)  # per-shard capacity: e_l rows
        o_ci = jnp.full((e_l + 1,), sent, jnp.int32).at[pos2].set(
            jnp.where(live2, t_ci, sent))[:e_l]
        o_cj = jnp.full((e_l + 1,), sent, jnp.int32).at[pos2].set(
            jnp.where(live2, t_cj, sent))[:e_l]
        o_w = jnp.zeros((e_l + 1,), jnp.float32).at[pos2].set(
            jnp.where(live2, gw2, 0.0))[:e_l]
        e_valid = jax.lax.psum(jnp.sum(jnp.where(live2, 1, 0)), axes)
        # Overflow detection: a shard owning more than e_l coarse edges
        # (extreme community-ownership skew) would silently drop rows —
        # surface the max owned count so callers can fail loudly.
        owned_max = jax.lax.pmax(jnp.sum(jnp.where(live2, 1, 0)), axes)
        return o_ci, o_cj, o_w, e_valid, owned_max

    fn = shard_map(body, mesh=mesh, in_specs=(edge_spec, edge_spec, edge_spec, rep),
                   out_specs=(edge_spec, edge_spec, edge_spec, rep, rep),
                   check_rep=False)
    return jax.jit(fn)


@jax.jit
def _vertex_k(w_g, src_g, n_pad_plus_1_zeros):
    """K_i over the partitioned slot arrays (shape token carries n_pad + 1)."""
    return jax.ops.segment_sum(
        w_g, src_g,
        num_segments=n_pad_plus_1_zeros.shape[0]).astype(jnp.float32)


@jax.jit
def _warm_comm_sigma(mem, k, n_valid):
    """(comm0, sigma0) resuming the sharded move phase from ``mem``.

    The replicated analogue of ``repro.core.louvain.warm_init``: valid
    vertices without a previous assignment (id >= n_pad, e.g. entered via an
    edge insert) fall back to their own singleton; sigma is recomputed from
    the CURRENT vertex weights so the snapshot stays exact after updates.

    ``n_valid`` is either the usual scalar (valid ids are the dense prefix
    ``[0, n_valid)``) or a ``(n_pad + 1,)`` bool LIVE MASK — the gappy
    layouts produced by skew-aware re-sharding, where valid ids sit in
    per-shard blocks with padding gaps between them.
    """
    n_pad = mem.shape[0] - 1
    idx = jnp.arange(n_pad + 1)
    nv = jnp.asarray(n_valid)
    valid = (nv & (idx < n_pad)) if nv.ndim else (idx < nv)
    assigned = jnp.where(mem < n_pad, mem.astype(jnp.int32),
                         idx.astype(jnp.int32))
    comm0 = jnp.where(valid, assigned, n_pad).astype(jnp.int32)
    sigma0 = jax.ops.segment_sum(k[:n_pad], comm0[:n_pad],
                                 num_segments=n_pad + 1)
    return comm0, sigma0.astype(jnp.float32)


@jax.jit
def sharded_modularity(src_g, dst_g, w_g, comm):
    """Q of a replicated (n_pad + 1,) membership on partitioned edge arrays."""
    sent = comm.shape[0] - 1
    m = jnp.sum(w_g) * 0.5
    internal = jnp.sum(jnp.where(comm[src_g] == comm[dst_g], w_g, 0.0))
    k = jax.ops.segment_sum(w_g, src_g, num_segments=sent + 1)
    sig = jax.ops.segment_sum(k[:sent], jnp.minimum(comm[:sent], sent),
                              num_segments=sent + 1).at[sent].set(0.0)
    return internal / (2.0 * m) - jnp.sum((sig / (2.0 * m)) ** 2)


def _rebucket_live_host(src_g, dst_g, w_g, old_sent: int,
                        spec_new: ShardedGraphSpec):
    """Pull live slots host-side and re-bucket them into ``spec_new``'s
    layout, doubling ``e_per_shard`` until the ownership fits (the ladder's
    shrink can concentrate coarse edges on few shards).  A VERTEX id beyond
    the layout is a caller bug doubling can never fix — checked up front so
    the retry loop only ever sees edge-capacity overflow (and terminates:
    ``e_per_shard >= len(src)`` always fits)."""
    src = np.asarray(src_g)
    dst = np.asarray(dst_g)
    w = np.asarray(w_g)
    live = src < old_sent
    src, dst, w = src[live], dst[live], w[live]
    if len(src) and int(src.max()) >= spec_new.n_pad:
        raise ValueError(
            f"live vertex id {int(src.max())} does not fit the target "
            f"layout (n_pad={spec_new.n_pad})")
    while True:
        try:
            return (*bucket_slots_host(src, dst, w, spec_new), spec_new)
        except ValueError:
            spec_new = spec_new._replace(
                e_per_shard=2 * spec_new.e_per_shard)


def _reshard_relabel(bounds: np.ndarray, v_per: int, n_pad_new: int,
                     old_cap: int) -> np.ndarray:
    """Monotone relabel LUT for a skew-aware owner split.

    ``bounds`` partitions the dense coarse ids ``[0, bounds[-1])`` into
    contiguous owner ranges; range ``s`` lands at the uniform device block
    ``[s * v_per, s * v_per + width_s)``, so ``owner = id // v_per`` stays
    the layout law and only the id values move.  Returns an
    ``(old_cap + 1,)`` int32 LUT: dense id -> relabelled id, everything
    else (incl. the old sentinel) -> ``n_pad_new`` (the new sentinel).
    The map is strictly increasing on the live ids — relative order (and
    hence every ordered reduction downstream) is preserved.
    """
    n_live = int(bounds[-1])
    lut = np.full(old_cap + 1, n_pad_new, np.int64)
    ids = np.arange(n_live)
    owner = np.searchsorted(bounds, ids, side="right") - 1
    lut[:n_live] = owner * v_per + (ids - bounds[owner])
    return lut.astype(np.int32)


def _reshard_coarse_host(src_g, dst_g, w_g, old_sent: int, plan):
    """Apply a ``configs.louvain_arch.ReshardPlan`` to a coarse graph.

    Pulls the live coarse slots host-side (they are already host-bound for
    the ladder re-bucket), relabels both endpoints through the monotone
    LUT, and re-buckets into the balanced layout.  Returns
    ``(src', dst', w', spec', lut, live_mask)`` — ``live_mask`` is the
    ``(n_pad' + 1,)`` bool mask of live vertex ids in the gappy layout
    (the ``n_valid`` operand of the mask-aware warm/refine paths).
    """
    n_shards = len(plan.bounds) - 1
    spec_new = ShardedGraphSpec(n_shards, plan.v_per_shard, plan.e_per_shard,
                                n_shards * plan.v_per_shard)
    lut = _reshard_relabel(plan.bounds, plan.v_per_shard, spec_new.n_pad,
                           old_sent)
    src = np.asarray(src_g)
    dst = np.asarray(dst_g)
    w = np.asarray(w_g)
    live = src < old_sent
    src, dst, w = lut[src[live]], lut[dst[live]], w[live]
    out = bucket_slots_host(src, dst, w, spec_new)
    n_live = int(plan.bounds[-1])
    live_mask = np.zeros(spec_new.n_pad + 1, bool)
    live_mask[lut[:n_live]] = True
    return (*out, spec_new, lut, live_mask)


def sharded_louvain_passes(
    src_g, dst_g, w_g,
    spec: ShardedGraphSpec,
    move, agg,
    n_live: int,
    *,
    init_membership=None,
    init_frontier=None,
    max_passes: int = 10,
    initial_tolerance: float = 0.01,
    tolerance_drop: float = 10.0,
    aggregation_tolerance: float = 0.8,
    phases_for=None,
    use_ladder: bool = False,
    comm_backend: str = "gather",
    state_layout: str = "replicated",
    refine: str = "none",
    refine_move=None,
    reshard: str = "none",
    pipeline_fetch: bool = False,
):
    """Host pass loop over prebuilt jit'd phases on partitioned edge arrays.

    The shared engine of the static and streaming sharded drivers:
    ``init_membership``/``init_frontier`` warm-start pass 0 ((n_pad + 1,)
    replicated arrays, mirroring ``repro.core.louvain.louvain``); later
    passes restart from singletons on the coarse graph.  The fine edge
    arrays are never mutated (aggregation emits fresh coarse arrays), so
    streaming callers can keep them resident across calls.

    With ``use_ladder`` (requires ``phases_for``, a ``spec -> (move, agg)``
    factory — callers cache it so tiers reuse compiled phases), coarse
    graphs are re-bucketed down through the same host-side machinery the
    streaming driver uses to GROW capacity (``bucket_slots_host``): after
    each aggregation the layout shrinks to the power-of-two tier fitting
    the coarse graph, so later passes' collectives and per-shard sorts run
    at coarse capacity.  Memberships are invariant to the layout.

    An aggregation whose coarse-edge ownership overflows ``e_per_shard``
    (community skew: renumbered coarse ids form a dense prefix that an
    owner map sized for the ORIGINAL vertex range parks on the first
    shards) is retried through the same machinery whenever ``phases_for``
    is available: first the OWNER MAP is laddered — ``v_per_shard``
    re-buckets to the tier fitting the live vertex count, spreading
    ownership across all shards — and only then does ``e_per_shard`` grow.
    Without a phase factory the overflow raises ``AggregationOverflow``.

    ``comm_backend`` / ``state_layout`` must be the CONCRETE exchange
    backend ("gather" | "delta") and state layout ("replicated" |
    "hybrid") matching what ``move``/``phases_for`` were built with —
    they are used for the per-pass bytes-on-wire stats, not for routing.

    With ``refine="leiden"`` every pass runs the constrained refinement
    sweep (``refine_move``, from ``make_distributed_refine`` /
    ``phases_for``) after local-moving: aggregation follows the REFINED
    partition while the reported membership and next-pass warm start stay
    at the OUTER partition — the same Leiden pass semantics as the
    single-device ``repro.core.louvain.louvain``.

    With ``reshard="auto"`` (requires ``phases_for``) every aggregation on
    a multi-shard mesh is followed by a skew check: per-coarse-vertex edge
    counts are measured host-side and, when the worst shard's load exceeds
    ``configs.louvain_arch.RESHARD_IMBALANCE_THRESHOLD`` times the mean
    under the uniform owner map, the coarse ids are monotonically
    relabelled onto contiguous load-balanced owner blocks
    (``plan_reshard`` / ``_reshard_coarse_host``) instead of taking the
    ladder tier.  The relabelled layout is GAPPY — live ids sit in
    per-shard blocks — so the pass threads a live mask through the warm
    start, the refinement sweep and the Leiden fold; the global fold and
    warm membership are remapped through the same LUT.  Balanced graphs
    skip the shuffle entirely, and the one-time relabel traffic is priced
    into the pass's ``comm_bytes`` via ``comm.reshard_bytes``.

    ``pipeline_fetch=True`` dispatches the next aggregation speculatively
    BEFORE the host fetches this pass's convergence scalars, so device
    work overlaps the host control decision; a pass that then breaks
    simply discards the speculative result.  Dispatch order is the only
    change — final memberships are identical (pinned in the golden
    matrix).

    Returns (membership (n_pad,) device array, n_communities, stats);
    the membership stays at the ORIGINAL ``spec.n_pad`` length (with
    refinement it is the outer fold, not the refined dendrogram chain).
    Each stats row carries the comm-plan columns (``comm_backend``,
    ``comm_rounds``, ``comm_fallback_rounds``, ``comm_bytes``) from the
    measured round counters + static shapes, the state-layout columns
    (``state_layout``, ``halo_bytes`` — the boundary-mover share of the
    wire, ``boundary_frac`` — measured under hybrid, else None), the
    measured pass wall-clock ``seconds`` (aggregation and re-bucket
    included), plus the re-shard columns (``reshard``, ``reshard_bytes``,
    ``max_shard_load_frac_before`` / ``_after``) when the pass boundary
    re-balanced ownership.
    """
    from repro.configs.louvain_arch import (LADDER_SLACK, _pow2_at_least,
                                            plan_reshard,
                                            resolve_coarse_capacity,
                                            resolve_reshard)
    from repro.core.comm import reshard_bytes as _reshard_cost
    from repro.core.louvain import _leiden_warm_membership, pad_membership

    if refine not in ("none", "leiden"):
        raise ValueError(f"refine must be 'none' or 'leiden', got {refine!r}")
    reshard_on = resolve_reshard(reshard) == "auto"
    refine_on = refine == "leiden"
    if refine_on and refine_move is None:
        if phases_for is None:
            raise ValueError("refine='leiden' needs refine_move or "
                             "phases_for")
        refine_move = phases_for(spec)[2]
        if refine_move is None:
            raise ValueError("refine='leiden' but the phase factory was "
                             "built with refine='none'")

    n_pad, sent = spec.n_pad, spec.sentinel
    idx = np.arange(n_pad + 1)
    shape_token = jnp.zeros((n_pad + 1,), jnp.float32)
    global_comm = jnp.arange(n_pad, dtype=jnp.int32)
    report_comm = global_comm
    ones_frontier = jnp.ones((n_pad + 1,), bool)
    tol = float(initial_tolerance)
    stats = []
    n_report = n_live
    leiden_warm = None
    live_np = None       # None = dense prefix [0, n_live); ndarray = gappy
    for p in range(max_passes):
        t_pass0 = time.perf_counter()
        # The live-vertex operand of the mask-aware paths: the scalar count
        # for dense-prefix layouts, the replicated bool mask after a
        # skew-aware re-shard made the layout gappy.
        nv_op = (jnp.int32(n_live) if live_np is None
                 else jnp.asarray(live_np))
        k = _vertex_k(w_g, src_g, shape_token)
        m = jnp.sum(w_g) * 0.5
        if p == 0 and init_membership is not None:
            comm0, sigma0 = _warm_comm_sigma(init_membership, k, nv_op)
            frontier0 = (ones_frontier if init_frontier is None
                         else init_frontier)
        elif leiden_warm is not None:
            # Leiden pass semantics: resume from the outer partition
            # expressed on the refined coarse vertices.
            comm0, sigma0 = _warm_comm_sigma(leiden_warm, k, nv_op)
            frontier0 = ones_frontier
        else:
            live_host = (idx < n_live) if live_np is None else live_np
            comm0 = jnp.asarray(
                np.where(live_host, idx, sent).astype(np.int32))
            sigma0 = k
            frontier0 = ones_frontier
        comm, sigma, iters, dq_sum, rounds, fallbacks = move(
            src_g, dst_g, w_g, comm0, sigma0, k, frontier0, m,
            jnp.float32(tol))
        refine_iters_i = None
        outer_ren = None
        rounds_extra = fb_extra = 0
        if refine_on:
            refined, r_iters, _r_dq, r_rounds, r_fb = refine_move(
                src_g, dst_g, w_g, comm, k, nv_op, m, jnp.float32(tol))
            outer_ren, n_outer = replicated_renumber(comm)
            comm_ren, n_comms = replicated_renumber(refined)
        else:
            comm_ren, n_comms = replicated_renumber(comm)
        # Pipelined convergence fetch: enqueue the aggregation BEFORE any
        # host sync below, so the device works through it while the host
        # reads the convergence scalars and decides.  Never on the last
        # pass (its result could only be discarded).  Dispatch order is
        # the only difference from the default path.
        pending_agg = None
        if pipeline_fetch and p < max_passes - 1:
            pending_agg = agg(src_g, dst_g, w_g, comm_ren)
        if refine_on:
            # Outer fold off the PRE-pass chain: what this pass reports.
            report_comm = outer_ren[jnp.minimum(global_comm, sent)]
            n_report = int(n_outer)
            refine_iters_i = int(r_iters)
            rounds_extra, fb_extra = int(r_rounds), int(r_fb)
        global_comm = comm_ren[jnp.minimum(global_comm, sent)]
        if not refine_on:
            report_comm = global_comm
            n_report = int(n_comms)
        iters_i, n_comms_i = int(iters), int(n_comms)
        rounds_i = int(rounds) + rounds_extra
        fb_i = int(fallbacks) + fb_extra
        plan = sharded_comm_plan(spec, comm_backend, state_layout)
        stats.append({"iterations": iters_i, "n_communities": n_report,
                      "n_vertices": n_live, "n_pad": sent,
                      "e_per_shard": spec.e_per_shard,
                      "dq_sum": float(dq_sum),
                      "comm_backend": comm_backend,
                      "comm_rounds": rounds_i,
                      "comm_fallback_rounds": fb_i,
                      "comm_bytes": phase_bytes(plan, rounds_i, fb_i),
                      "state_layout": state_layout,
                      "halo_bytes": plan.halo_round_bytes * rounds_i,
                      "boundary_frac": (
                          measure_boundary_frac(src_g, dst_g, spec, n_live)
                          if state_layout == "hybrid" else None),
                      "seconds": time.perf_counter() - t_pass0,
                      "refine_iterations": refine_iters_i,
                      "n_refined": n_comms_i if refine_on else None,
                      "reshard": False, "reshard_bytes": 0,
                      "max_shard_load_frac_before": None,
                      "max_shard_load_frac_after": None})
        converged = iters_i <= 1
        low_shrink = n_report / max(n_live, 1) > aggregation_tolerance
        if converged or low_shrink or p == max_passes - 1:
            break
        if refine_on:
            # Outer-on-coarse warm start, computed BEFORE aggregation so
            # skew retiers (which rewrite comm_ren's slot space) cannot
            # touch it: values are coarse ids [0, n_comms) regardless of
            # later layout changes.
            warm_flat = np.asarray(_leiden_warm_membership(
                comm_ren, outer_ren, nv_op, n_comms))[:n_comms_i]
        while True:
            if pending_agg is not None:
                a_src, a_dst, a_w, e_valid, owned_max = pending_agg
                pending_agg = None
            else:
                a_src, a_dst, a_w, e_valid, owned_max = agg(
                    src_g, dst_g, w_g, comm_ren)
            owned = int(owned_max)
            if owned <= spec.e_per_shard:
                src_g, dst_g, w_g = a_src, a_dst, a_w
                break
            if phases_for is None:
                # No phase factory: cannot re-bucket into a new layout.
                raise AggregationOverflow(owned, spec.e_per_shard)
            # Community-ownership skew.  After renumbering, coarse ids form
            # a dense [0, n_comms) prefix, so an owner map whose v_per
            # spans the ORIGINAL vertex range parks every coarse edge on
            # the first shards.  Ladder the OWNER MAP first — re-shard to
            # the tier fitting the live vertex count, spreading ownership
            # across all shards for free — and only grow e_per_shard (a
            # real memory cost, pass-local: the coarse arrays never touch
            # the caller's resident buffers) for the residual skew.
            old_sent = spec.sentinel
            v_tight = _pow2_at_least(-(-n_live // spec.n_shards))
            # The owner-map shrink assumes live FINE ids form a dense
            # prefix; a gappy (resharded) layout scatters them across the
            # full range, so only the edge capacity may grow there.
            if live_np is None and v_tight < spec.v_per_shard:
                tier = ShardedGraphSpec(spec.n_shards, v_tight,
                                        spec.e_per_shard,
                                        spec.n_shards * v_tight)
            else:
                tier = spec._replace(e_per_shard=_pow2_at_least(
                    max(owned, 2 * spec.e_per_shard)))
            src_g, dst_g, w_g, spec = _rebucket_live_host(
                src_g, dst_g, w_g, old_sent, tier)
            move, agg, _rmv = phases_for(spec)
            if refine_on and _rmv is not None:
                refine_move = _rmv
            if spec.sentinel != old_sent:
                # The owner map changed: rewrite the renumbered membership
                # (which feeds the retried aggregation) and the loop-level
                # layout trackers into the new sentinel space.  Live
                # entries hold coarse ids < n_live <= new n_pad; stale
                # slots held the OLD sentinel and are forced to the new.
                sent = spec.sentinel
                body = comm_ren[:spec.n_pad]
                comm_ren = jnp.concatenate([
                    jnp.where(jnp.arange(spec.n_pad) < n_live,
                              jnp.minimum(body, sent),
                              sent).astype(jnp.int32),
                    jnp.full((1,), sent, jnp.int32)])
                idx = np.arange(spec.n_pad + 1)
                shape_token = jnp.zeros((spec.n_pad + 1,), jnp.float32)
                ones_frontier = jnp.ones((spec.n_pad + 1,), bool)
        # --- skew-aware re-sharding (reshard="auto") -----------------------
        # The coarse graph is on the device in the CURRENT owner map; pull
        # the per-coarse-vertex edge counts host-side (the ladder re-bucket
        # pulls the same arrays anyway) and measure the skew the next pass
        # would inherit under the uniform layout.  When it clears the
        # threshold, relabel the dense coarse ids onto balanced contiguous
        # owner blocks and thread the remap through every replicated
        # consumer: the dendrogram fold, the Leiden warm start, and the
        # live mask the warm/refine paths read.  A re-shard replaces the
        # ladder tier for this boundary (it already picked the capacity).
        resharded = False
        if reshard_on and phases_for is not None and spec.n_shards > 1:
            src_np = np.asarray(src_g)
            counts = np.bincount(src_np[src_np < spec.sentinel],
                                 minlength=max(n_comms_i, 1))
            if use_ladder:
                n_new, _e_new = resolve_coarse_capacity(
                    n_comms_i, int(e_valid), spec.n_pad,
                    spec.e_per_shard * spec.n_shards)
                v_uniform = -(-n_new // spec.n_shards)
            else:
                v_uniform = spec.v_per_shard
            rplan = plan_reshard(counts, spec.n_shards, v_uniform)
            if rplan is not None:
                old_sent_r = spec.sentinel
                cost = _reshard_cost(spec.n_shards * spec.e_per_shard,
                                     spec.n_shards * rplan.e_per_shard)
                src_g, dst_g, w_g, spec, lut, live_mask = \
                    _reshard_coarse_host(src_g, dst_g, w_g, old_sent_r,
                                         rplan)
                move, agg, _rmv = phases_for(spec)
                if refine_on and _rmv is not None:
                    refine_move = _rmv
                sent = spec.sentinel
                idx = np.arange(spec.n_pad + 1)
                shape_token = jnp.zeros((spec.n_pad + 1,), jnp.float32)
                ones_frontier = jnp.ones((spec.n_pad + 1,), bool)
                # Fold and warm start live in coarse-id VALUE space (and,
                # for the warm start, coarse-id INDEX space) — both sides
                # go through the same monotone LUT.
                global_comm = jnp.asarray(lut)[
                    jnp.minimum(global_comm, old_sent_r)]
                if refine_on:
                    warm_new = np.full(spec.n_pad + 1, sent, np.int32)
                    warm_new[lut[:n_comms_i]] = lut[warm_flat]
                    leiden_warm = jnp.asarray(warm_new)
                live_np = live_mask
                resharded = True
                stats[-1].update(
                    reshard=True, reshard_bytes=cost,
                    max_shard_load_frac_before=rplan.load_frac_before,
                    max_shard_load_frac_after=rplan.load_frac_after,
                    comm_bytes=phase_bytes(plan, rounds_i, fb_i,
                                           reshard_cost=cost))
        if not resharded:
            # Aggregation emits dense coarse ids, so any non-resharded next
            # layout is a dense prefix again.
            live_np = None
        if not resharded and use_ladder and phases_for is not None:
            n_new, e_new = resolve_coarse_capacity(
                n_comms_i, int(e_valid), spec.n_pad,
                spec.e_per_shard * spec.n_shards)
            if (n_new, e_new) != (spec.n_pad,
                                  spec.e_per_shard * spec.n_shards):
                old_sent = spec.sentinel
                # Per-shard edge tier: fair share of the global tier,
                # floored at the MEASURED worst-shard ownership (plus
                # slack) — coarse edges concentrate on few shards, and
                # sizing only by the total would make the re-bucket fail
                # and walk a doubling retry.  Power-of-two quantized so
                # data-dependent skew cannot mint a fresh spec (and a
                # recompile) per pass.  (The bucket retry below stays as
                # the net: a changed v_per shifts ownership.)
                e_tier = _pow2_at_least(max(
                    -(-e_new // spec.n_shards),
                    int(owned * LADDER_SLACK), 1))
                tier = ShardedGraphSpec(
                    spec.n_shards, -(-n_new // spec.n_shards), e_tier,
                    spec.n_shards * (-(-n_new // spec.n_shards)))
                if tier != spec:
                    src_g, dst_g, w_g, spec = _rebucket_live_host(
                        src_g, dst_g, w_g, old_sent, tier)
                    move, agg, _rmv = phases_for(spec)
                    if refine_on and _rmv is not None:
                        refine_move = _rmv
                    sent = spec.sentinel
                    idx = np.arange(spec.n_pad + 1)
                    shape_token = jnp.zeros((spec.n_pad + 1,), jnp.float32)
                    ones_frontier = jnp.ones((spec.n_pad + 1,), bool)
        if refine_on and not resharded:
            # Express the outer-on-coarse warm start in the FINAL next-pass
            # layout (skew retiers / ladder tiers may have changed n_pad);
            # a re-shard already wrote the LUT-remapped warm start above.
            leiden_warm = jnp.asarray(pad_membership(warm_flat, spec.n_pad))
        n_live = n_comms_i
        # Restamp with the FULL pass wall-clock — aggregation, ladder
        # re-buckets and skew re-shards included — so reshard="auto" can
        # be judged against measured time, not slot counts alone.
        stats[-1]["seconds"] = time.perf_counter() - t_pass0
        tol /= tolerance_drop
    return report_comm, n_report, stats


def distributed_louvain(
    graph: CSRGraph,
    mesh: Mesh,
    axes: Tuple[str, ...],
    *,
    max_passes: int = 10,
    max_iterations: int = 20,
    initial_tolerance: float = 0.01,
    tolerance_drop: float = 10.0,
    aggregation_tolerance: float = 0.8,
    gate_fraction: int = 2,
    use_pruning: bool = True,
    init_membership=None,
    init_frontier=None,
    e_per_shard: int | None = None,
    use_ladder: bool = True,
    comm_backend: str = "auto",
    state_layout: str = "replicated",
    refine: str = "none",
    reshard: str = "none",
    pipeline_fetch: bool = False,
):
    """End-to-end multi-device GVE-Louvain (host pass loop, jit'd phases).

    ``init_membership``/``init_frontier`` warm-start the first pass like the
    single-device ``louvain`` (the streaming driver in
    ``repro.core.distributed_dynamic`` builds on this).  ``e_per_shard``
    reserves per-shard slot headroom — community skew can concentrate
    coarse edges on few shards; the pass loop re-shards the owner map and
    grows edge capacity in-flight when that happens.  ``use_ladder``
    re-buckets coarse graphs down the capacity ladder between passes
    (memberships unchanged; per-tier phases are built once and cached for
    the call).  ``comm_backend`` picks the per-round exchange ("gather" |
    "delta" | "auto"; auto resolves per mesh) — memberships are invariant
    to it.  So is ``state_layout`` ("replicated" | "hybrid" | "auto"):
    auto measures the partitioned layout's boundary fraction
    (``measure_boundary_frac``) and engages the hybrid
    partitioned-state scanners when it clears the
    ``configs.louvain_arch`` threshold on a multi-shard mesh.
    ``refine="leiden"`` enables the constrained refinement sweep
    between local-moving and aggregation (see ``sharded_louvain_passes``).
    ``reshard="auto"`` re-balances the coarse owner ranges by measured load
    after each aggregation (skew-aware re-sharding; a no-op on one shard
    and on balanced graphs), and ``pipeline_fetch=True`` overlaps the host
    convergence decision with the speculatively dispatched aggregation —
    both knobs change work placement, never memberships.

    Returns (membership (n,), n_communities, pass_stats list).
    """
    from repro.configs.louvain_arch import (resolve_comm_backend,
                                            resolve_state_layout)

    n_shards = int(np.prod([mesh.shape[a] for a in axes]))
    cb = resolve_comm_backend(comm_backend, n_shards)
    src_g, dst_g, w_g, spec = partition_graph_host(
        graph, n_shards, e_per_shard=e_per_shard)
    n = int(graph.n_valid)
    sl = resolve_state_layout(
        state_layout, n_shards,
        boundary_frac=(measure_boundary_frac(src_g, dst_g, spec, n)
                       if state_layout == "auto" and n_shards > 1
                       else None))

    phases_for = make_tier_phases(
        mesh, axes, max_iterations=max_iterations,
        gate_fraction=gate_fraction, use_pruning=use_pruning,
        comm_backend=cb, state_layout=sl, refine=refine)
    move, agg, _ = phases_for(spec)

    from repro.core.louvain import pad_membership
    mem0 = fr0 = None
    if init_membership is not None:
        mem0 = jnp.asarray(pad_membership(
            np.minimum(np.asarray(init_membership, np.int64),
                       spec.n_pad).astype(np.int32)[:spec.n_pad],
            spec.n_pad))
    if init_frontier is not None:
        fr = np.zeros(spec.n_pad + 1, bool)
        src_fr = np.asarray(init_frontier, bool)
        fr[: min(len(src_fr), spec.n_pad)] = src_fr[: spec.n_pad]
        fr0 = jnp.asarray(fr)

    with mesh:
        global_comm, _, stats = sharded_louvain_passes(
            src_g, dst_g, w_g, spec, move, agg, n,
            init_membership=mem0, init_frontier=fr0,
            max_passes=max_passes, initial_tolerance=initial_tolerance,
            tolerance_drop=tolerance_drop,
            aggregation_tolerance=aggregation_tolerance,
            phases_for=phases_for, use_ladder=use_ladder, comm_backend=cb,
            state_layout=sl, refine=refine, reshard=reshard,
            pipeline_fetch=pipeline_fetch)
    membership = np.asarray(global_comm[:n])
    return membership, int(len(np.unique(membership))), stats


@jax.jit
def replicated_renumber(comm: jax.Array, n_pad: int | None = None):
    """Renumber a replicated community array (n_pad + 1,) -> dense ids."""
    n_pad = comm.shape[0] - 1
    idx = jnp.arange(n_pad + 1)
    valid = (comm < n_pad) & (idx < n_pad)
    cs = jnp.where(valid, comm, n_pad)
    present = jnp.zeros((n_pad + 1,), jnp.int32).at[cs].set(1)
    present = present.at[n_pad].set(0)
    new_id = jnp.cumsum(present) - present
    n_comms = jnp.sum(present)
    new_id = new_id.at[n_pad].set(n_pad)
    return jnp.where(valid, new_id[cs], n_pad), n_comms


def sentinel_forced_membership(global_comm, n_valid, n_pad: int):
    """Replicated (n_pad + 1,) membership from a pass-loop fold.

    Invalid slots are forced to the layout sentinel: with the coarse-pass
    ladder they can carry stale SMALL sentinel values (a shrunk tier's
    n_pad) which a later warm start would misread as real assignments.
    Shared by the streaming driver (``distributed_dynamic``) and the
    serving fleet (``repro.core.fleet``) so both produce bit-identical
    resident state.  Works eagerly or inside a trace (``n_valid`` may be a
    traced scalar).
    """
    gc = jnp.where(jnp.arange(n_pad) < n_valid, global_comm[:n_pad],
                   jnp.int32(n_pad))
    return jnp.concatenate([gc, jnp.full((1,), n_pad, jnp.int32)])
