"""Multi-pod distributed GVE-Louvain via shard_map + jax.lax collectives.

The paper is single-node shared-memory; this layer extends it along the lines
of the distributed implementations it benchmarks (Vite / Ghosh et al.):

  - 1-D **vertex partition**: every vertex's full adjacency lives on exactly
    one shard.  Louvain's parallelism is vertex-wise, so the partition flattens
    ALL mesh axes (pod x data x model) into one vertex axis — each of the 512
    chips of the production mesh owns |V|/512 vertices.
  - **Replicated community state**: C, Sigma, K (O(|V|) each) are replicated;
    per-round updates travel as one `all_gather` (the owned C slice + moved
    flags) and one `psum` (Sigma deltas) — the same ghost-exchange pattern as
    Vite, expressed as XLA collectives.
  - **Distributed aggregation**: local sort-reduce partially deduplicates each
    shard's relabeled edges, an `all_gather` shares the partials, and each
    shard re-reduces the rows it owns in the coarse partition.  (The gather is
    the faithful baseline; EXPERIMENTS.md §Perf explores the all_to_all
    variant.)

Everything here is shape-static and lowers AOT on the production meshes — see
launch/dryrun.py.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro import compat

from repro.core.graph import CSRGraph
from repro.core.modularity import delta_modularity


class ShardedGraphSpec(NamedTuple):
    """Static layout facts for a vertex-partitioned edge list."""

    n_shards: int
    v_per_shard: int     # owned vertices per shard
    e_per_shard: int     # padded edge slots per shard
    n_pad: int           # n_shards * v_per_shard  (global padded vertex count)

    @property
    def sentinel(self) -> int:
        return self.n_pad


def partition_graph_host(
    graph: CSRGraph, n_shards: int
) -> Tuple[jax.Array, jax.Array, jax.Array, ShardedGraphSpec]:
    """Host-side 1-D vertex partition -> globally laid-out padded edge arrays.

    Shard s owns vertices [s*v, (s+1)*v) and the slice [s*E_l, (s+1)*E_l) of
    each edge array.  Padding slots carry src = dst = sentinel, w = 0.
    """
    n = int(graph.n_valid)
    v_per = -(-n // n_shards)
    n_pad = v_per * n_shards
    src = np.asarray(graph.src)
    dst = np.asarray(graph.indices)
    w = np.asarray(graph.weights)
    live = src < graph.n_cap
    src, dst, w = src[live], dst[live], w[live]

    owner = src // v_per
    e_per = max(int(np.bincount(owner, minlength=n_shards).max()), 1)
    s_out = np.full((n_shards, e_per), n_pad, np.int32)
    d_out = np.full((n_shards, e_per), n_pad, np.int32)
    w_out = np.zeros((n_shards, e_per), np.float32)
    order = np.argsort(owner, kind="stable")
    src, dst, w, owner = src[order], dst[order], w[order], owner[order]
    starts = np.searchsorted(owner, np.arange(n_shards))
    ends = np.searchsorted(owner, np.arange(n_shards), side="right")
    for s in range(n_shards):
        cnt = ends[s] - starts[s]
        s_out[s, :cnt] = src[starts[s]:ends[s]]
        d_out[s, :cnt] = dst[starts[s]:ends[s]]
        w_out[s, :cnt] = w[starts[s]:ends[s]]
    spec = ShardedGraphSpec(n_shards, v_per, e_per, n_pad)
    return (jnp.asarray(s_out.reshape(-1)), jnp.asarray(d_out.reshape(-1)),
            jnp.asarray(w_out.reshape(-1)), spec)


# ---------------------------------------------------------------------------
# shard_map bodies.  ``axes`` is the tuple of mesh axis names the vertex
# partition flattens over, e.g. ("data", "model") or ("pod", "data", "model").
# ---------------------------------------------------------------------------

def _shard_index(axes):
    shard_ix = jax.lax.axis_index(axes[0])
    for ax in axes[1:]:
        shard_ix = shard_ix * compat.axis_size(ax) + jax.lax.axis_index(ax)
    return shard_ix


def _best_moves_shard(axes, spec, src_l, dst_l, w_l, comm, sigma, k,
                      frontier_l, m):
    """Per-shard best (community, dQ) for owned vertices — the sort-reduce
    scanCommunities.  Returns (best_c (v_per,), best_dq (v_per,), v0)."""
    v_per, sent = spec.v_per_shard, spec.sentinel
    v0 = _shard_index(axes) * v_per

    # Local segment space: owned vertices -> [0, v_per), everything else -> v_per.
    src_loc = jnp.where(src_l >= sent, v_per, src_l - v0)
    cdst = comm[dst_l]

    own_comm_l = jax.lax.dynamic_slice_in_dim(comm, v0, v_per)  # (v_per,)
    c_own_e = comm[src_l]                                        # per-edge own community
    own_edge = (cdst == c_own_e) & (dst_l != src_l)
    k_to_own = jax.ops.segment_sum(
        jnp.where(own_edge, w_l, 0.0), src_loc, num_segments=v_per + 1)

    order = jnp.lexsort((cdst, src_loc))
    s_src = src_loc[order]
    s_c = cdst[order]
    s_w = jnp.where(dst_l[order] == src_l[order], 0.0, w_l[order])
    prev_src = jnp.concatenate([jnp.full((1,), -1, jnp.int32), s_src[:-1]])
    prev_c = jnp.concatenate([jnp.full((1,), -1, jnp.int32), s_c[:-1]])
    new_group = (s_src != prev_src) | (s_c != prev_c)
    gid = jnp.cumsum(new_group.astype(jnp.int32)) - 1
    k_i_to_c = jax.ops.segment_sum(s_w, gid, num_segments=s_w.shape[0])[gid]

    k_l = jax.lax.dynamic_slice_in_dim(k, v0, v_per)
    sig_own_l = sigma[own_comm_l]
    valid_row = s_src < v_per
    dq = delta_modularity(
        k_i_to_c,
        jnp.where(valid_row, k_to_own[s_src], 0.0),
        jnp.where(valid_row, k_l[jnp.minimum(s_src, v_per - 1)], 0.0),
        sigma[jnp.minimum(s_c, sent)],
        jnp.where(valid_row, sig_own_l[jnp.minimum(s_src, v_per - 1)], 0.0),
        m,
    )
    c_own_sorted = comm[src_l[order]]
    valid = valid_row & (s_c != c_own_sorted) & (s_c < sent) & frontier_l[
        jnp.minimum(s_src, v_per - 1)]
    dq = jnp.where(valid, dq, -jnp.inf)
    best_dq = jax.ops.segment_max(dq, s_src, num_segments=v_per + 1)[:v_per]
    best_dq = jnp.where(jnp.isfinite(best_dq), best_dq, -jnp.inf)
    is_best = valid & (dq == jnp.pad(best_dq, (0, 1), constant_values=-jnp.inf)[
        jnp.minimum(s_src, v_per)])
    best_c = jax.ops.segment_min(
        jnp.where(is_best, s_c, sent), s_src, num_segments=v_per + 1)[:v_per]
    best_c = jnp.minimum(best_c, sent)
    return best_c, best_dq, v0


def _round_body(axes, spec, src_l, dst_l, w_l, comm, sigma, k,
                frontier_l, round_ix, gate_fraction, m):
    """One synchronous local-move round for one shard; returns updates."""
    v_per, sent = spec.v_per_shard, spec.sentinel
    best_c, best_dq, v0 = _best_moves_shard(
        axes, spec, src_l, dst_l, w_l, comm, sigma, k, frontier_l, m)
    own_comm_l = jax.lax.dynamic_slice_in_dim(comm, v0, v_per)
    k_l = jax.lax.dynamic_slice_in_dim(k, v0, v_per)
    src_loc = jnp.where(src_l >= sent, v_per, src_l - v0)

    # --- gating + singleton guard (global semantics, computed locally) ---
    gidx = v0 + jnp.arange(v_per)
    if gate_fraction > 1:
        h = (gidx.astype(jnp.int32) * jnp.int32(-1640531535)
             + round_ix.astype(jnp.int32) * jnp.int32(40503))
        gate = jnp.abs(h >> 13) % gate_fraction == 0
    else:
        gate = jnp.ones((v_per,), bool)

    ones_l = jnp.where(own_comm_l < sent, 1, 0)  # ghost vertices excluded
    size_local = jax.ops.segment_sum(ones_l, own_comm_l, num_segments=sent + 1)
    comm_size = jax.lax.psum(size_local, axes)
    own_single = comm_size[own_comm_l] == 1
    tgt_single = comm_size[jnp.minimum(best_c, sent)] == 1
    swap_blocked = own_single & tgt_single & (best_c > own_comm_l)

    do_move = ((best_dq > 0.0) & (best_c != own_comm_l) & (best_c < sent)
               & frontier_l & gate & ~swap_blocked)

    moved_k = jnp.where(do_move, k_l, 0.0)
    delta = (jax.ops.segment_sum(moved_k, jnp.where(do_move, best_c, sent),
                                 num_segments=sent + 1)
             - jax.ops.segment_sum(moved_k, jnp.where(do_move, own_comm_l, sent),
                                   num_segments=sent + 1))
    sigma_new = sigma + jax.lax.psum(delta, axes)
    comm_l_new = jnp.where(do_move, best_c, own_comm_l)
    dq_round = jax.lax.psum(jnp.sum(jnp.where(do_move, best_dq, 0.0)), axes)

    comm_new = jax.lax.all_gather(comm_l_new, axes, tiled=True)
    comm_new = jnp.concatenate([comm_new, jnp.asarray([sent], jnp.int32)])
    moved_g = jax.lax.all_gather(do_move, axes, tiled=True)
    moved_g = jnp.concatenate([moved_g, jnp.zeros((1,), bool)])

    # Frontier: neighbors of movers (dst side lives locally).
    marked = jax.ops.segment_max(
        moved_g[dst_l].astype(jnp.int32), src_loc, num_segments=v_per + 1)[:v_per]
    frontier_new = (marked > 0) & (gidx < spec.n_pad)
    frontier_new = frontier_new | (frontier_l & ~gate)
    return comm_new, sigma_new, frontier_new, dq_round


def make_distributed_move(
    mesh: Mesh,
    axes: Tuple[str, ...],
    spec: ShardedGraphSpec,
    *,
    max_iterations: int = 20,
    gate_fraction: int = 2,
    use_pruning: bool = True,
):
    """Build the jit'd distributed local-moving phase for a fixed mesh/layout.

    Returns fn(src_g, dst_g, w_g, comm, sigma, k, m, tolerance)
        -> (comm, sigma, iters, dq_sum); comm/sigma replicated outputs.
    """
    edge_spec = P(axes)      # edge arrays: sharded along dim 0 over all axes
    rep = P()                # replicated state

    def phase(src_g, dst_g, w_g, comm, sigma, k, m, tolerance):
        def body_shard(src_l, dst_l, w_l, comm, sigma, k, m, tolerance):
            v_per, sent = spec.v_per_shard, spec.sentinel
            shard_ix = _shard_index(axes)
            gidx = shard_ix * v_per + jnp.arange(v_per)
            frontier0 = gidx < spec.n_pad

            def cond(st):
                comm_, sigma_, frontier_, it, dq, dq_sum = st
                return (it < max_iterations) & (dq > tolerance)

            def body(st):
                comm_, sigma_, frontier_, it, _, dq_sum = st
                dq_acc = jnp.asarray(0.0, jnp.float32)
                for r in range(gate_fraction):
                    fr = frontier_ if use_pruning else frontier0
                    comm_, sigma_, frontier_, dq_r = _round_body(
                        axes, spec, src_l, dst_l, w_l, comm_, sigma_, k,
                        fr, it * gate_fraction + r, gate_fraction, m)
                    dq_acc = dq_acc + dq_r
                return (comm_, sigma_, frontier_, it + 1, dq_acc,
                        dq_sum + dq_acc)

            st0 = (comm, sigma, frontier0, jnp.asarray(0, jnp.int32),
                   jnp.asarray(jnp.inf, jnp.float32),
                   jnp.asarray(0.0, jnp.float32))
            comm_f, sigma_f, _, iters, _, dq_sum = jax.lax.while_loop(
                cond, body, st0)
            return comm_f, sigma_f, iters, dq_sum

        fn = shard_map(
            body_shard, mesh=mesh,
            in_specs=(edge_spec, edge_spec, edge_spec, rep, rep, rep, rep, rep),
            out_specs=(rep, rep, rep, rep),
            check_rep=False,
        )
        return fn(src_g, dst_g, w_g, comm, sigma, k, m, tolerance)

    return jax.jit(phase)


def make_distributed_aggregate(mesh: Mesh, axes: Tuple[str, ...],
                               spec: ShardedGraphSpec):
    """Distributed coarsening: local sort-reduce, all_gather partials,
    owner-side re-reduce.  Returns fn(src_g, dst_g, w_g, comm_renumbered)
    -> (src_g', dst_g', w_g', e_valid) in the same edge layout for the coarse
    graph (coarse vertex v owned by shard v // v_per_shard)."""
    edge_spec = P(axes)
    rep = P()
    n_shards = spec.n_shards

    def body(src_l, dst_l, w_l, comm):
        v_per, sent = spec.v_per_shard, spec.sentinel
        e_l = src_l.shape[0]
        ci = comm[src_l]
        cj = comm[dst_l]

        # Local partial reduce.
        order = jnp.lexsort((cj, ci))
        s_ci, s_cj, s_w = ci[order], cj[order], w_l[order]
        prev_i = jnp.concatenate([jnp.full((1,), -1, jnp.int32), s_ci[:-1]])
        prev_j = jnp.concatenate([jnp.full((1,), -1, jnp.int32), s_cj[:-1]])
        new_group = (s_ci != prev_i) | (s_cj != prev_j)
        gidl = jnp.cumsum(new_group.astype(jnp.int32)) - 1
        gw = jax.ops.segment_sum(s_w, gidl, num_segments=e_l)[gidl]
        live = new_group & (s_ci != sent)
        pos = jnp.where(live, gidl, e_l)
        p_ci = jnp.full((e_l + 1,), sent, jnp.int32).at[pos].set(s_ci)[:e_l]
        p_cj = jnp.full((e_l + 1,), sent, jnp.int32).at[pos].set(s_cj)[:e_l]
        p_w = jnp.zeros((e_l + 1,), jnp.float32).at[pos].set(gw)[:e_l]

        # Share partials; each shard re-reduces and keeps its owned rows.
        g_ci = jax.lax.all_gather(p_ci, axes, tiled=True)   # (S * e_l,)
        g_cj = jax.lax.all_gather(p_cj, axes, tiled=True)
        g_w = jax.lax.all_gather(p_w, axes, tiled=True)

        shard_ix = _shard_index(axes)
        v0 = shard_ix * v_per
        mine = (g_ci >= v0) & (g_ci < v0 + v_per)
        m_ci = jnp.where(mine, g_ci, sent)
        m_cj = jnp.where(mine, g_cj, sent)
        m_w = jnp.where(mine, g_w, 0.0)

        order2 = jnp.lexsort((m_cj, m_ci))
        t_ci, t_cj, t_w = m_ci[order2], m_cj[order2], m_w[order2]
        prev_i = jnp.concatenate([jnp.full((1,), -1, jnp.int32), t_ci[:-1]])
        prev_j = jnp.concatenate([jnp.full((1,), -1, jnp.int32), t_cj[:-1]])
        ng2 = (t_ci != prev_i) | (t_cj != prev_j)
        gid2 = jnp.cumsum(ng2.astype(jnp.int32)) - 1
        gw2 = jax.ops.segment_sum(t_w, gid2, num_segments=t_w.shape[0])[gid2]
        live2 = ng2 & (t_ci != sent)
        pos2 = jnp.where(live2, gid2, e_l)  # per-shard capacity: e_l rows
        o_ci = jnp.full((e_l + 1,), sent, jnp.int32).at[pos2].set(
            jnp.where(live2, t_ci, sent))[:e_l]
        o_cj = jnp.full((e_l + 1,), sent, jnp.int32).at[pos2].set(
            jnp.where(live2, t_cj, sent))[:e_l]
        o_w = jnp.zeros((e_l + 1,), jnp.float32).at[pos2].set(
            jnp.where(live2, gw2, 0.0))[:e_l]
        e_valid = jax.lax.psum(jnp.sum(jnp.where(live2, 1, 0)), axes)
        # Overflow detection: a shard owning more than e_l coarse edges
        # (extreme community-ownership skew) would silently drop rows —
        # surface the max owned count so callers can fail loudly.
        owned_max = jax.lax.pmax(jnp.sum(jnp.where(live2, 1, 0)), axes)
        return o_ci, o_cj, o_w, e_valid, owned_max

    fn = shard_map(body, mesh=mesh, in_specs=(edge_spec, edge_spec, edge_spec, rep),
                   out_specs=(edge_spec, edge_spec, edge_spec, rep, rep),
                   check_rep=False)
    return jax.jit(fn)


def distributed_louvain(
    graph: CSRGraph,
    mesh: Mesh,
    axes: Tuple[str, ...],
    *,
    max_passes: int = 10,
    max_iterations: int = 20,
    initial_tolerance: float = 0.01,
    tolerance_drop: float = 10.0,
    aggregation_tolerance: float = 0.8,
    gate_fraction: int = 2,
    use_pruning: bool = True,
):
    """End-to-end multi-device GVE-Louvain (host pass loop, jit'd phases).

    Returns (membership (n,), n_communities, pass_stats list).
    """
    n_shards = int(np.prod([mesh.shape[a] for a in axes]))
    src_g, dst_g, w_g, spec = partition_graph_host(graph, n_shards)
    n_pad, sent = spec.n_pad, spec.sentinel
    n = int(graph.n_valid)

    move = make_distributed_move(
        mesh, axes, spec, max_iterations=max_iterations,
        gate_fraction=gate_fraction, use_pruning=use_pruning)
    agg = make_distributed_aggregate(mesh, axes, spec)
    vertex_k = jax.jit(functools.partial(
        jax.ops.segment_sum, num_segments=n_pad + 1))

    idx = np.arange(n_pad + 1)
    n_live = n
    global_comm = jnp.arange(n_pad, dtype=jnp.int32)
    tol = float(initial_tolerance)
    stats = []
    with mesh:
        for p in range(max_passes):
            k = vertex_k(w_g, src_g).astype(jnp.float32)
            m = jnp.sum(w_g) * 0.5
            comm0 = jnp.where(idx < n_live, idx, sent).astype(jnp.int32)
            comm, sigma, iters, dq_sum = move(
                src_g, dst_g, w_g, comm0, k, k, m, jnp.float32(tol))
            comm_ren, n_comms = replicated_renumber(comm)
            global_comm = comm_ren[global_comm]
            iters_i, n_comms_i = int(iters), int(n_comms)
            stats.append({"iterations": iters_i, "n_communities": n_comms_i,
                          "n_vertices": n_live, "dq_sum": float(dq_sum)})
            converged = iters_i <= 1
            low_shrink = n_comms_i / max(n_live, 1) > aggregation_tolerance
            if converged or low_shrink or p == max_passes - 1:
                break
            src_g, dst_g, w_g, _, owned_max = agg(src_g, dst_g, w_g, comm_ren)
            if int(owned_max) > spec.e_per_shard:
                raise RuntimeError(
                    f"aggregation overflow: a shard owns {int(owned_max)} "
                    f"coarse edges > capacity {spec.e_per_shard}; "
                    "re-partition with more headroom (community skew)")
            n_live = n_comms_i
            tol /= tolerance_drop
    membership = np.asarray(global_comm[:n])
    return membership, int(len(np.unique(membership))), stats


@jax.jit
def replicated_renumber(comm: jax.Array, n_pad: int | None = None):
    """Renumber a replicated community array (n_pad + 1,) -> dense ids."""
    n_pad = comm.shape[0] - 1
    idx = jnp.arange(n_pad + 1)
    valid = (comm < n_pad) & (idx < n_pad)
    cs = jnp.where(valid, comm, n_pad)
    present = jnp.zeros((n_pad + 1,), jnp.int32).at[cs].set(1)
    present = present.at[n_pad].set(0)
    new_id = jnp.cumsum(present) - present
    n_comms = jnp.sum(present)
    new_id = new_id.at[n_pad].set(n_pad)
    return jnp.where(valid, new_id[cs], n_pad), n_comms
