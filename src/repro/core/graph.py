"""Static-shape graph containers for JAX Louvain.

The paper (GVE-Louvain §4.1.7/4.1.8) preallocates CSR buffers once and reuses
them across passes; under jit static shapes make this mandatory, so the same
design falls out naturally.  A graph lives in buffers of fixed capacity
(``n_cap`` vertex slots, ``e_cap`` directed edge slots); the *valid* prefix is
tracked with dynamic scalars.  Invalid slots use the sentinel vertex ``n_cap``
(all index arrays are addressable up to ``n_cap`` inclusive, so sentinel
scatters land in a scratch slot).

Conventions (the slot contract every module in ``repro.core`` assumes):
  - undirected edge {i,j}, i != j   -> two directed slots (i,j,w) and (j,i,w)
  - self loop {i,i}                 -> ONE slot (i,i,w)
  - K_i  = sum of slot weights out of i          (row sum of adjacency)
  - m    = (sum of all slot weights) / 2
These are conserved exactly under community coarsening.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class CSRGraph(NamedTuple):
    """Padded CSR graph.  All fields are jax arrays unless noted.

    indptr  : (n_cap + 1,) int32 — offsets; rows >= n_valid are empty.
    indices : (e_cap,) int32 — neighbor ids; padding slots hold ``n_cap``.
    weights : (e_cap,) float32 — edge weights; padding slots hold 0.
    src     : (e_cap,) int32 — row id of each slot (CSR expanded); pad = n_cap.
    n_valid : () int32 — number of valid vertices (dynamic).
    e_valid : () int32 — number of valid edge slots (dynamic).
    """

    indptr: jax.Array
    indices: jax.Array
    weights: jax.Array
    src: jax.Array
    n_valid: jax.Array
    e_valid: jax.Array

    @property
    def n_cap(self) -> int:
        return self.indptr.shape[0] - 1

    @property
    def e_cap(self) -> int:
        return self.indices.shape[0]

    def degrees(self) -> jax.Array:
        """(n_cap,) int32 out-degree (slot count) per vertex."""
        return self.indptr[1:] - self.indptr[:-1]

    def vertex_weights(self) -> jax.Array:
        """(n_cap + 1,) float32 — K_i, with a trailing sentinel slot (=0)."""
        k = jax.ops.segment_sum(self.weights, self.src, num_segments=self.n_cap + 1)
        return k.astype(jnp.float32)

    def total_weight(self) -> jax.Array:
        """Scalar m = sum(w)/2 (float32)."""
        return jnp.sum(self.weights) * 0.5


def _np_int32(x) -> np.ndarray:
    return np.asarray(x, dtype=np.int32)


def build_csr(
    src: np.ndarray,
    dst: np.ndarray,
    weight: np.ndarray,
    n: int,
    *,
    n_cap: int | None = None,
    e_cap: int | None = None,
    symmetrize: bool = False,
    dedup: bool = True,
) -> CSRGraph:
    """Host-side CSR builder from a directed slot list.

    ``symmetrize=True`` adds reverse slots for every i != j pair (the paper adds
    reverse edges to directed inputs, Table 1).  ``dedup`` merges parallel slots
    by summing weights.
    """
    src = _np_int32(src)
    dst = _np_int32(dst)
    weight = np.asarray(weight, dtype=np.float32)
    if symmetrize:
        off = src != dst
        src = np.concatenate([src, dst[off]])
        dst = np.concatenate([dst, src[: len(off)][off]])  # original src
        weight = np.concatenate([weight, weight[: len(off)][off]])
    if dedup and len(src):
        key = src.astype(np.int64) * (n + 1) + dst.astype(np.int64)
        order = np.argsort(key, kind="stable")
        key, src, dst, weight = key[order], src[order], dst[order], weight[order]
        first = np.ones(len(key), dtype=bool)
        first[1:] = key[1:] != key[:-1]
        gid = np.cumsum(first) - 1
        wsum = np.zeros(gid[-1] + 1, dtype=np.float64)
        np.add.at(wsum, gid, weight)
        src, dst, weight = src[first], dst[first], wsum.astype(np.float32)

    # CSR order.
    order = np.argsort(src.astype(np.int64) * (n + 1) + dst, kind="stable")
    src, dst, weight = src[order], dst[order], weight[order]

    e = len(src)
    n_cap = int(n_cap if n_cap is not None else n)
    e_cap = int(e_cap if e_cap is not None else e)
    assert n_cap >= n and e_cap >= e, "capacity below graph size"

    counts = np.zeros(n_cap + 1, dtype=np.int64)
    np.add.at(counts[1:], src, 1)
    indptr = np.cumsum(counts).astype(np.int32)

    pad_i = np.full(e_cap - e, n_cap, dtype=np.int32)
    pad_w = np.zeros(e_cap - e, dtype=np.float32)
    return CSRGraph(
        indptr=jnp.asarray(indptr),
        indices=jnp.asarray(np.concatenate([dst, pad_i])),
        weights=jnp.asarray(np.concatenate([weight, pad_w])),
        src=jnp.asarray(np.concatenate([src, pad_i])),
        n_valid=jnp.asarray(n, dtype=jnp.int32),
        e_valid=jnp.asarray(e, dtype=jnp.int32),
    )


def from_networkx(g, *, n_cap: int | None = None, e_cap: int | None = None) -> CSRGraph:
    """Build from an undirected networkx graph (unit weights by default)."""
    n = g.number_of_nodes()
    nodes = {v: i for i, v in enumerate(g.nodes())}
    src, dst, w = [], [], []
    for u, v, data in g.edges(data=True):
        wt = float(data.get("weight", 1.0))
        iu, iv = nodes[u], nodes[v]
        src.append(iu)
        dst.append(iv)
        w.append(wt)
        if iu != iv:
            src.append(iv)
            dst.append(iu)
            w.append(wt)
    return build_csr(np.array(src or [0][:0]), np.array(dst or [0][:0]),
                     np.array(w or [0.0][:0]), n, n_cap=n_cap, e_cap=e_cap)


# Trace-time side-effect counters: jitted phases bump their key ONCE per
# trace (Python bodies run only while tracing), so tests can assert a
# bounded compile count across ladder tiers without poking jit internals.
TRACE_COUNTS: dict = {}


def count_trace(name: str) -> None:
    """Bump a trace counter (call from inside a jitted function body)."""
    TRACE_COUNTS[name] = TRACE_COUNTS.get(name, 0) + 1


@functools.partial(jax.jit, static_argnames=("n_cap_new", "e_cap_new"))
def rebucket_capacity(graph: CSRGraph, *, n_cap_new: int,
                      e_cap_new: int) -> CSRGraph:
    """Copy a graph into buffers of different capacity (shrink OR grow).

    The capacity-ladder primitive: valid data must fit the target
    (``n_valid <= n_cap_new``, ``e_valid <= e_cap_new``, live edge slots in
    a compact prefix — all true for ``aggregate_graph`` outputs and
    ``build_csr``/``apply_edge_batch`` graphs).  Vertex-id arrays rewrite
    the sentinel (old ``n_cap`` -> new); valid ids are < ``n_valid`` so
    they survive either direction unchanged.  Callers check fit host-side;
    see ``repro.configs.louvain_arch.resolve_coarse_capacity`` for the
    tier policy.
    """
    count_trace("rebucket_capacity")
    n_cap, e_cap = graph.n_cap, graph.e_cap

    def remap(x):
        # Valid ids < n_valid <= n_cap_new; everything >= min(n_cap,
        # n_cap_new) is sentinel/padding in either direction.
        return jnp.where(x >= jnp.int32(min(n_cap, n_cap_new)),
                         jnp.int32(n_cap_new), x)

    def resize_e(x, fill):
        if e_cap_new <= e_cap:
            return x[:e_cap_new]
        return jnp.concatenate(
            [x, jnp.full((e_cap_new - e_cap,), fill, x.dtype)])

    if n_cap_new <= n_cap:
        indptr = graph.indptr[: n_cap_new + 1]
    else:
        indptr = jnp.pad(graph.indptr, (0, n_cap_new - n_cap), mode="edge")
    return CSRGraph(
        indptr=indptr,
        indices=remap(resize_e(graph.indices, jnp.int32(n_cap))),
        weights=resize_e(graph.weights, jnp.float32(0.0)),
        src=remap(resize_e(graph.src, jnp.int32(n_cap))),
        n_valid=graph.n_valid,
        e_valid=graph.e_valid,
    )


def rebucket_graph(graph: CSRGraph, n_cap_new: int,
                   e_cap_new: int) -> CSRGraph:
    """Host-checked wrapper over ``rebucket_capacity``: validates that the
    live data fits the target capacity before re-bucketing (one device
    sync; the ladder hot path calls the jitted core directly with counts
    it already fetched)."""
    n_valid, e_valid = int(graph.n_valid), int(graph.e_valid)
    if n_valid > n_cap_new or e_valid > e_cap_new:
        raise ValueError(
            f"graph does not fit target capacity: n_valid={n_valid} > "
            f"n_cap_new={n_cap_new} or e_valid={e_valid} > "
            f"e_cap_new={e_cap_new}")
    return rebucket_capacity(graph, n_cap_new=int(n_cap_new),
                             e_cap_new=int(e_cap_new))


def empty_like_caps(n_cap: int, e_cap: int) -> CSRGraph:
    """An all-padding graph buffer (used as the coarse-graph target)."""
    return CSRGraph(
        indptr=jnp.zeros(n_cap + 1, dtype=jnp.int32),
        indices=jnp.full((e_cap,), n_cap, dtype=jnp.int32),
        weights=jnp.zeros((e_cap,), dtype=jnp.float32),
        src=jnp.full((e_cap,), n_cap, dtype=jnp.int32),
        n_valid=jnp.asarray(0, dtype=jnp.int32),
        e_valid=jnp.asarray(0, dtype=jnp.int32),
    )


# ---------------------------------------------------------------------------
# Degree-bucketed ELL view (the TPU tiling of the paper's "dynamic schedule").
# ---------------------------------------------------------------------------

class ELLBlock(NamedTuple):
    """A fixed-width padded adjacency block for vertices of bounded degree.

    rows     : (n_rows,) int32 — vertex id per row (pad rows = n_cap).
    cols     : (n_rows, width) int32 — neighbors (pad = n_cap).
    w        : (n_rows, width) float32 — weights (pad = 0).
    """

    rows: jax.Array
    cols: jax.Array
    w: jax.Array

    @property
    def width(self) -> int:
        return self.cols.shape[1]


def to_ell_blocks(
    graph: CSRGraph,
    widths: Tuple[int, ...] = (16, 64, 256, 1024),
    *,
    row_align: int = 8,
) -> Tuple[list, np.ndarray]:
    """Host-side degree bucketing: vertices with degree <= widths[k] (and >
    widths[k-1]) go to block k.  Returns (blocks, leftover_vertex_ids) where
    leftover vertices exceed the largest width (handled by the sorted path).

    Rows are padded to a multiple of ``row_align`` for kernel-friendly grids.
    """
    indptr = np.asarray(graph.indptr)
    indices = np.asarray(graph.indices)
    weights = np.asarray(graph.weights)
    n = int(graph.n_valid)
    n_cap = graph.n_cap
    deg = indptr[1 : n + 1] - indptr[:n]

    blocks = []
    lo = 0
    assigned = np.zeros(n, dtype=bool)
    for width in widths:
        sel = np.where((deg > lo) & (deg <= width))[0]
        if width == widths[0]:
            sel = np.where(deg <= width)[0]  # include isolated vertices
        lo = width
        n_rows = int(np.ceil(max(len(sel), 1) / row_align) * row_align)
        rows = np.full(n_rows, n_cap, dtype=np.int32)
        cols = np.full((n_rows, width), n_cap, dtype=np.int32)
        wmat = np.zeros((n_rows, width), dtype=np.float32)
        rows[: len(sel)] = sel
        for r, v in enumerate(sel):
            s, e = indptr[v], indptr[v + 1]
            cols[r, : e - s] = indices[s:e]
            wmat[r, : e - s] = weights[s:e]
        assigned[sel] = True
        blocks.append(ELLBlock(jnp.asarray(rows), jnp.asarray(cols), jnp.asarray(wmat)))
    leftover = np.where(~assigned)[0].astype(np.int32)
    return blocks, leftover


def connected_total_weight_check(graph: CSRGraph) -> float:
    """Debug helper: host-side 2m."""
    return float(np.asarray(graph.weights).sum())
