"""Louvain-partition-aware distributed GNN training: halo exchange.

The GSPMD baseline for full-graph training all-gathers the node-feature
array to every chip for each layer's gather/scatter — O(N·d) collective
traffic per chip per layer.  With the graph in Louvain order (core/partition
.louvain_partition: community-contiguous vertices, each chip owning a
contiguous community-aligned slice) most edges are intra-shard, and only the
*halo* — features of remote source vertices of cut edges — must move, via a
single static-shape all_to_all per layer:

    traffic/chip/layer = 2 · P · S · d  ·  4B      (S = per-peer halo cap)

which with Louvain-grade locality (cut fraction << 1) is orders of magnitude
below the all-gather.  This is the paper's technique operating as the
framework's distribution strategy — the quantified §Perf win for the
gin-tu x ogb_products and equiformer-v2 x ogb_products cells.

Layout (host-side, from the partitioner):
  - vertices in Louvain order; shard p owns the contiguous slice
    [p·V_l, (p+1)·V_l);
  - edges partitioned by OWNER OF DST (so per-dst softmax/scatter is local);
    per-shard edge arrays use LOCAL indices: dst in [0, V_l), src in
    [0, V_l + P·S] where indices >= V_l point into the received halo buffer
    (sentinel = V_l + P·S -> zero row);
  - send_idx[p, q, s]: the s-th local vertex shard p sends to shard q.

``build_halo_inputs`` produces this layout for a REAL graph + membership
(used by tests/examples); the dry-run uses ShapeDtypeStruct stand-ins.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.distributed import _shard_index

F32, I32 = jnp.float32, jnp.int32


@dataclasses.dataclass(frozen=True)
class HaloSpec:
    n_shards: int       # P
    v_per_shard: int    # V_l
    e_per_shard: int    # E_l
    send_cap: int       # S (per peer pair)

    @property
    def halo_size(self) -> int:
        return self.n_shards * self.send_cap

    @property
    def sentinel(self) -> int:          # local index of the zero row
        return self.v_per_shard + self.halo_size


def make_halo_spec(n_nodes_pad: int, n_edges_pad: int, n_shards: int,
                   halo_frac: float = 0.25) -> HaloSpec:
    v_l = n_nodes_pad // n_shards
    e_l = n_edges_pad // n_shards
    s = max(-(-int(halo_frac * v_l) // n_shards), 1)
    return HaloSpec(n_shards, v_l, e_l, s)


def halo_exchange(x_l: jax.Array, send_idx_l: jax.Array,
                  axes: Tuple[str, ...]) -> jax.Array:
    """One halo exchange inside shard_map.

    x_l: (V_l, ...) owned features; send_idx_l: (P, S) local ids to send.
    Returns (P·S, ...) received features (block q = sent by shard q).
    """
    send = x_l[send_idx_l]                         # (P, S, ...)
    recv = jax.lax.all_to_all(send, axes, split_axis=0, concat_axis=0,
                              tiled=True)
    return recv.reshape((-1,) + recv.shape[2:])


def _with_halo(x_l: jax.Array, send_idx_l, axes) -> jax.Array:
    """x_full = [owned | halo | zero-sentinel-row]."""
    halo = halo_exchange(x_l, send_idx_l, axes)
    zero = jnp.zeros((1,) + x_l.shape[1:], x_l.dtype)
    return jnp.concatenate([x_l, halo, zero], axis=0)


# ---------------------------------------------------------------------------
# GIN halo-distributed loss (per-shard body)
# ---------------------------------------------------------------------------

def gin_halo_loss_shard(cfg, params, x_l, src_l, dst_l, labels_l,
                        send_idx_l, n_valid, spec: HaloSpec,
                        axes: Tuple[str, ...], bf16_msgs: bool = False):
    """Per-shard GIN forward + CE over owned vertices; psum'd mean loss.

    bf16_msgs: exchange + gather messages at bf16, accumulate the scatter in
    f32 (halves the edge-side HBM/ICI traffic; MLPs stay f32)."""
    from repro.models.gnn.common import mlp
    v_l = spec.v_per_shard
    shard_ix = _shard_index(axes)
    gidx = shard_ix * v_l + jnp.arange(v_l)

    x = x_l
    for lp in params["layers"]:
        xm = x.astype(jnp.bfloat16) if bf16_msgs else x
        x_full = _with_halo(xm, send_idx_l, axes)
        msgs = x_full[src_l]                               # (E_l, d)
        # build_halo_inputs emits edges dst-sorted per shard.
        agg = jax.ops.segment_sum(msgs.astype(jnp.float32), dst_l,
                                  num_segments=v_l + 1,
                                  indices_are_sorted=True)[:v_l]
        x = mlp((1.0 + lp["eps"]) * x + agg, lp["mlp"])
    logits = mlp(x, params["head"]).astype(jnp.float32)    # (V_l, n_classes)

    mask = (gidx < n_valid).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, jnp.maximum(labels_l, 0)[:, None],
                             1)[:, 0]
    nll = jnp.sum((lse - ll) * mask)
    total = jax.lax.psum(nll, axes)
    count = jax.lax.psum(jnp.sum(mask), axes)
    return total / jnp.maximum(count, 1.0)


def _so2_conv_truncated(cfg, lp, feat_t: jax.Array, sel: np.ndarray,
                        inv_sel: Dict[int, int]):
    """eSCN SO(2) conv operating directly in the truncated |m| <= m_max row
    space (feat_t: (E, n_rows, 2C)) — no zero-padded full-coefficient edge
    tensors.  Exactly equivalent to models.gnn.equiformer._so2_conv followed
    by selecting the sel rows (the rest are zero there by construction)."""
    from repro.models.gnn.equiformer import _m_indices
    e = feat_t.shape[0]
    c = feat_t.shape[-1] // 2
    lm = cfg.l_max
    dt = feat_t.dtype                      # bf16 edge path keeps bf16 here
    out = jnp.zeros((e, len(sel), c), dt)

    idx0 = np.asarray([inv_sel[l * l + l] for l in range(lm + 1)])
    x0 = feat_t[:, idx0].reshape(e, -1)
    y0 = (x0 @ lp["w_m0"].astype(dt)).reshape(e, lm + 1, c)
    out = out.at[:, idx0].set(y0)

    for m in range(1, cfg.m_max + 1):
        pos, neg = _m_indices(lm, m)
        pos_t = np.asarray([inv_sel[i] for i in pos])
        neg_t = np.asarray([inv_sel[i] for i in neg])
        xp = feat_t[:, pos_t].reshape(e, -1)
        xn = feat_t[:, neg_t].reshape(e, -1)
        w1 = lp[f"w1_m{m}"].astype(dt)
        w2 = lp[f"w2_m{m}"].astype(dt)
        yp = (xp @ w1 - xn @ w2).reshape(e, lm + 1 - m, c)
        yn = (xp @ w2 + xn @ w1).reshape(e, lm + 1 - m, c)
        out = out.at[:, pos_t].set(yp)
        out = out.at[:, neg_t].set(yn)
    return out, y0.reshape(e, -1)


# ---------------------------------------------------------------------------
# Equiformer halo-distributed loss (per-shard body)
# ---------------------------------------------------------------------------

def equiformer_halo_loss_shard(cfg, params, feat_l, pos_l, src_l, dst_l,
                               labels_l, send_idx_l, n_valid,
                               spec: HaloSpec, axes: Tuple[str, ...],
                               m_truncate: bool = True,
                               bf16_edges: bool = False):
    """Per-shard eSCN forward.  Geometry (positions) is exchanged once;
    irrep features are exchanged per layer.  m_truncate computes only the
    |m| <= m_max Wigner rows actually consumed by the SO(2) conv."""
    from repro.models.gnn.common import mlp, segment_softmax
    from repro.models.gnn.equiformer import _irrep_norm, _so2_conv
    from repro.models.gnn.wigner import (block_diag_apply, rotation_to_z,
                                         wigner_d_stack)

    v_l, lm, c = spec.v_per_shard, cfg.l_max, cfg.d_hidden
    shard_ix = _shard_index(axes)
    gidx = shard_ix * v_l + jnp.arange(v_l)

    # --- edge geometry (positions exchanged once) ---------------------------
    pos_full = _with_halo(pos_l, send_idx_l, axes)          # (V_l+H+1, 3)
    live_e = src_l < spec.sentinel
    s_ix = jnp.minimum(src_l, spec.sentinel)
    d_ix = jnp.minimum(dst_l, v_l - 1)
    vec = pos_l[d_ix] - pos_full[s_ix]
    dist = jnp.linalg.norm(vec + 1e-12, axis=-1)
    nvec = vec / jnp.maximum(dist[:, None], 1e-8)
    ds = wigner_d_stack(rotation_to_z(nvec), lm)            # per-edge blocks

    if m_truncate:
        # Rows with |m| <= m_max are the only coefficients _so2_conv reads;
        # slice the rotation blocks to those rows (and transpose-apply the
        # same slices on the way back) — the eSCN O(L^3) trick.
        mm = cfg.m_max
        ds_fwd = [d[:, (slice(None) if l <= mm
                        else slice(l - mm, l + mm + 1))]
                  for l, d in enumerate(ds)]
    else:
        ds_fwd = ds

    n_rbf = cfg.n_radial
    mu = jnp.linspace(0.0, cfg.cutoff, n_rbf)
    rbf = jnp.exp(-((dist[:, None] - mu) ** 2) * (n_rbf / cfg.cutoff))

    feat0 = mlp(feat_l, params["embed"])                    # (V_l, C)
    x = jnp.zeros((v_l, cfg.n_coef, c))
    x = x.at[:, 0].set(feat0)

    def rotate_rows(blocks, h_e):
        """Apply (possibly row-sliced) Wigner blocks: (E, rows_l, 2l+1)."""
        outs, off = [], 0
        for l, d in enumerate(blocks):
            blk = h_e[:, off:off + 2 * l + 1]
            outs.append(jnp.einsum("eij,ejc->eic", d, blk))
            off += 2 * l + 1
        return jnp.concatenate(outs, axis=1)

    def unrotate_rows(blocks, m_e):
        """Transpose-apply row-sliced blocks back to full coefficients."""
        outs, off = [], 0
        for l, d in enumerate(blocks):
            rows = d.shape[1]
            blk = m_e[:, off:off + rows]
            outs.append(jnp.einsum("eij,eic->ejc", d, blk))
            off += rows
        return jnp.concatenate(outs, axis=1)

    # Index maps between truncated edge-frame rows and full coefficients:
    # every computation on edge tensors stays in the (n_rows < n_coef)
    # truncated space — the |m| > m_max coefficients are provably unused.
    if m_truncate:
        sel = []
        for l in range(lm + 1):
            base = l * l
            lo = 0 if l <= cfg.m_max else l - cfg.m_max
            hi = 2 * l + 1 if l <= cfg.m_max else l + cfg.m_max + 1
            sel.extend(range(base + lo, base + hi))
        sel = np.asarray(sel)
        inv_sel = {int(f): r for r, f in enumerate(sel)}

    ds_e = ([d.astype(jnp.bfloat16) for d in ds_fwd] if bf16_edges
            else ds_fwd)

    for lp in params["layers"]:
        h = _irrep_norm(x, lp["ln_scale"], lm)
        if bf16_edges:
            # Edge-frame tensors (the E-sized memory hot spot) at bf16; the
            # SO(2)-conv matmuls accumulate f32, node state stays f32.
            h = h.astype(jnp.bfloat16)
        h_full = _with_halo(h, send_idx_l, axes)            # per-layer halo
        h_src = h_full[s_ix]
        h_dst = h_full[jnp.minimum(d_ix, v_l - 1)]

        if m_truncate:
            f_src = rotate_rows(ds_e, h_src)                # (E, n_rows, C)
            f_dst = rotate_rows(ds_e, h_dst)
            feat = jnp.concatenate([f_src, f_dst], axis=-1)
            msg, m0_flat = _so2_conv_truncated(cfg, lp, feat, sel, inv_sel)
            n_rows = len(sel)
        else:
            f_src = block_diag_apply(ds_e if bf16_edges else ds, h_src)
            f_dst = block_diag_apply(ds_e if bf16_edges else ds, h_dst)
            feat = jnp.concatenate([f_src, f_dst], axis=-1)
            msg, m0_flat = _so2_conv(cfg, lp, feat)
            n_rows = cfg.n_coef

        gate_d = mlp(rbf, lp["rbf_mlp"])
        msg = msg * gate_d[:, None, :].astype(msg.dtype)
        logits = mlp(m0_flat.astype(jnp.float32), lp["attn_mlp"])
        logits = jax.nn.leaky_relu(logits, 0.2)
        logits = jnp.where(live_e[:, None], logits, -jnp.inf)
        alpha = segment_softmax(logits, dst_l, v_l + 1)
        msg = msg.reshape(*msg.shape[:2], cfg.n_heads, c // cfg.n_heads)
        msg = (msg * alpha[:, None, :, None].astype(msg.dtype)).reshape(
            msg.shape[0], n_rows, c)

        if m_truncate:
            msg = unrotate_rows(ds_e, msg)
        else:
            msg = block_diag_apply(ds_e if bf16_edges else ds, msg,
                                   transpose=True)
        msg = jnp.where(live_e[:, None, None], msg, 0.0)
        # scatter-accumulate in f32 regardless of the edge dtype
        agg = jax.ops.segment_sum(msg.astype(jnp.float32), dst_l,
                                  num_segments=v_l + 1)[:v_l]
        x = x + agg @ lp["out_proj"]

        h2 = _irrep_norm(x, lp["ln_scale"], lm)
        scalar = h2[:, 0]
        gates = jax.nn.sigmoid(mlp(scalar, lp["ffn_gate"]))
        outs = [jax.nn.silu(scalar @ lp["ffn_l"][0])]
        for l in range(1, lm + 1):
            blk = h2[:, l * l:(l + 1) * (l + 1)] @ lp["ffn_l"][l]
            outs.append(blk * gates[:, None, (l - 1) * c:l * c])
        x = x + jnp.concatenate([outs[0][:, None]] + outs[1:], axis=1)

    logits = mlp(x[:, 0], params["head"]).astype(jnp.float32)
    mask = (gidx < n_valid).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, jnp.maximum(labels_l, 0)[:, None],
                             1)[:, 0]
    total = jax.lax.psum(jnp.sum((lse - ll) * mask), axes)
    count = jax.lax.psum(jnp.sum(mask), axes)
    return total / jnp.maximum(count, 1.0)


# ---------------------------------------------------------------------------
# Step builder (shard_map wrapped in jit, AOT-lowerable)
# ---------------------------------------------------------------------------

def build_halo_step(arch_id: str, shape_name: str, mesh: Mesh, *,
                    n_valid: int, cfg, param_specs, opt_cfg=None,
                    halo_frac: float = 0.25, m_truncate: bool = True,
                    bf16_msgs: bool = False,
                    needs_positions: bool = False):
    """(train_step, arg_specs, in_shardings) for the halo-distributed
    full-graph variant of gin-tu / equiformer-v2."""
    from jax.experimental.shard_map import shard_map

    from repro.configs.gnn_common import GNN_SHAPES, pad512
    from repro.optim import AdamWConfig, adamw_update
    from repro.optim.adamw import AdamWState

    sh = GNN_SHAPES[shape_name]
    n_pad, e_pad = pad512(sh.n_nodes), pad512(sh.n_edges)
    axes = tuple(mesh.axis_names)
    n_shards = int(mesh.devices.size)
    spec = make_halo_spec(n_pad, e_pad, n_shards, halo_frac)

    S = jax.ShapeDtypeStruct
    batch_specs = {
        "node_feat": S((n_pad, sh.d_feat), F32),
        "edge_src": S((e_pad,), I32),        # LOCAL indices (see module doc)
        "edge_dst": S((e_pad,), I32),
        "labels": S((n_pad,), I32),
        "send_idx": S((n_shards * n_shards, spec.send_cap), I32),
    }
    if needs_positions:
        batch_specs["positions"] = S((n_pad, 3), F32)

    shard1 = P(axes)
    b_pspecs = {"node_feat": P(axes, None), "edge_src": shard1,
                "edge_dst": shard1, "labels": shard1,
                "send_idx": P(axes, None)}
    if needs_positions:
        b_pspecs["positions"] = P(axes, None)

    opt_cfg = opt_cfg or AdamWConfig()
    f32s = lambda s: S(s.shape, jnp.float32)
    o_specs = AdamWState(step=S((), jnp.int32),
                         mu=jax.tree.map(f32s, param_specs),
                         nu=jax.tree.map(f32s, param_specs))
    rep = P()

    if arch_id == "gin-tu":
        def shard_loss(params, nf, es, ed, lab, sidx):
            return gin_halo_loss_shard(cfg, params, nf, es, ed, lab, sidx,
                                       n_valid, spec, axes,
                                       bf16_msgs=bf16_msgs)
        in_specs = (jax.tree.map(lambda _: rep, param_specs),
                    b_pspecs["node_feat"], shard1, shard1, shard1,
                    b_pspecs["send_idx"])
        batch_order = ("node_feat", "edge_src", "edge_dst", "labels",
                       "send_idx")
    else:  # equiformer-v2
        def shard_loss(params, nf, pos, es, ed, lab, sidx):
            return equiformer_halo_loss_shard(
                cfg, params, nf, pos, es, ed, lab, sidx, n_valid, spec,
                axes, m_truncate=m_truncate, bf16_edges=bf16_msgs)
        in_specs = (jax.tree.map(lambda _: rep, param_specs),
                    b_pspecs["node_feat"], b_pspecs["positions"], shard1,
                    shard1, shard1, b_pspecs["send_idx"])
        batch_order = ("node_feat", "positions", "edge_src", "edge_dst",
                       "labels", "send_idx")

    loss_sharded = shard_map(shard_loss, mesh=mesh, in_specs=in_specs,
                             out_specs=rep, check_rep=False)

    def train_step(params, opt_state, batch):
        args = tuple(batch[k] for k in batch_order)
        loss, grads = jax.value_and_grad(
            lambda p: loss_sharded(p, *args))(params)
        params, opt_state, _ = adamw_update(opt_cfg, params, grads,
                                            opt_state)
        return params, opt_state, loss

    train_step.donate_argnums = (0, 1)
    ns = lambda tree: jax.tree.map(
        lambda p_: NamedSharding(mesh, p_), tree,
        is_leaf=lambda x: isinstance(x, P))
    rep_tree = lambda tree: jax.tree.map(
        lambda _: NamedSharding(mesh, P()), tree,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    shardings = (rep_tree(param_specs), rep_tree(o_specs),
                 {k: NamedSharding(mesh, b_pspecs[k]) for k in batch_specs})
    return train_step, (param_specs, o_specs, batch_specs), shardings


# ---------------------------------------------------------------------------
# Host-side layout builder for REAL graphs (tests + examples)
# ---------------------------------------------------------------------------

def build_halo_inputs(edge_src: np.ndarray, edge_dst: np.ndarray,
                      membership_order: np.ndarray, n_shards: int,
                      n_pad: int, e_pad: int, spec: HaloSpec) -> Dict:
    """Reorder a real graph into the halo layout.

    membership_order: permutation placing vertices in Louvain order (vertex
    order[i] becomes new id i).  Returns dict of numpy arrays matching
    build_halo_step's batch layout, or raises if a halo/edge cap overflows
    (caps are sized from the partition's measured cut; callers pick
    halo_frac accordingly).
    """
    v_l, s_cap = spec.v_per_shard, spec.send_cap
    inv = np.empty_like(membership_order)
    inv[membership_order] = np.arange(len(membership_order))
    src = inv[edge_src]
    dst = inv[edge_dst]

    owner = dst // v_l
    send_sets = [[set() for _ in range(n_shards)] for _ in range(n_shards)]
    for s, d in zip(src, dst):
        p, q = d // v_l, s // v_l
        if p != q:
            send_sets[q][p].add(int(s))   # shard q sends vertex s to shard p

    send_idx = np.zeros((n_shards, n_shards, s_cap), np.int32)
    halo_pos: Dict[Tuple[int, int], int] = {}
    for q in range(n_shards):
        for p in range(n_shards):
            verts = sorted(send_sets[q][p])
            if len(verts) > s_cap:
                raise ValueError(
                    f"halo cap {s_cap} exceeded ({len(verts)}) for "
                    f"{q}->{p}; increase halo_frac")
            for i, v in enumerate(verts):
                send_idx[q, p, i] = v - q * v_l     # local id on sender
                halo_pos[(p, v)] = q * s_cap + i    # recv slot on shard p
            for i in range(len(verts), s_cap):
                send_idx[q, p, i] = 0               # padding (dup send ok)

    e_l = spec.e_per_shard
    es_out = np.full((n_shards, e_l), spec.sentinel, np.int32)
    ed_out = np.full((n_shards, e_l), v_l, np.int32)
    fill = np.zeros(n_shards, np.int64)
    order_e = np.argsort(dst, kind="stable")   # dst-sorted per shard
    for s, d in zip(src[order_e], dst[order_e]):
        p = d // v_l
        if fill[p] >= e_l:
            raise ValueError(f"edge cap {e_l} exceeded on shard {p}")
        if s // v_l == p:
            local_s = s - p * v_l
        else:
            local_s = v_l + halo_pos[(p, int(s))]
        es_out[p, fill[p]] = local_s
        ed_out[p, fill[p]] = d - p * v_l
        fill[p] += 1

    return {"edge_src": es_out.reshape(-1), "edge_dst": ed_out.reshape(-1),
            "send_idx": send_idx.reshape(n_shards * n_shards, s_cap),
            "perm": membership_order}
