"""Name-based sharding rules mapping model params/inputs to PartitionSpecs.

Conventions (MaxText-style FSDP x TP):

  - `model` axis: Megatron tensor parallelism — wq/wk/wv/gate/up
    column-parallel, wo/down row-parallel, embedding vocab-sharded,
    MoE expert-sharded (EP) when n_experts >= model-axis size.
  - dp axes (`data`, and `pod` on the multi-pod mesh): FSDP — every
    remaining large dimension is sharded over the dp axes so that params +
    optimizer state scale 1/512 on the production mesh (a 236B-param model
    at bf16 + f32 Adam moments is ~2.4 TB — replication over dp would be
    ~100 GB/chip; fully sharded it is ~4.6 GB/chip).  GSPMD inserts the
    FSDP all-gathers / reduce-scatters.
  - KV caches: batch over dp, heads (or MLA latent) over model; the
    batch=1 `long_500k` shape seq-shards the cache instead (sequence
    parallelism — a 512k-token cache cannot live on one chip).
"""

from __future__ import annotations

from typing import Tuple

import jax
from jax.sharding import Mesh, PartitionSpec as P


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a != "model")


def _layer_pspec(name: str, cfg, shard_experts: bool, F) -> P:
    """PartitionSpec for one (unstacked) layer param by name.

    F is the FSDP axis group (tuple of dp axis names).
    """
    if name in ("ln1", "ln2", "q_ln", "kv_ln"):
        return P(None)
    # --- attention ---
    if name in ("wq", "wk", "wv"):
        return P(F, "model")
    if name in ("bq", "bk", "bv"):
        return P("model")
    if name == "wo":
        return P("model", F)
    # --- MLA ---
    if name in ("w_dq", "w_dkv", "w_kr"):
        return P(F, None)
    if name in ("w_uq", "w_uk", "w_uv"):
        return P(F, "model")
    if name == "w_o":
        return P("model", F)
    # --- dense FFN ---
    if name in ("w_gate", "w_up"):
        return P(F, "model")
    if name == "w_down":
        return P("model", F)
    # --- MoE ---
    if name == "router":
        return P(F, None)
    if name in ("w_gate_e", "w_up_e"):
        return P("model", F, None) if shard_experts else P(None, F, "model")
    if name == "w_down_e":
        return P("model", None, F) if shard_experts else P(None, "model", F)
    if name in ("w_gate_s", "w_up_s"):
        return P(F, "model")
    if name == "w_down_s":
        return P("model", F)
    raise ValueError(f"no sharding rule for param {name!r}")


def lm_param_pspecs(cfg, mesh: Mesh, *, fsdp: bool = True) -> dict:
    """Pytree of PartitionSpec matching transformer.param_shapes(cfg).

    fsdp=False: tensor-parallel only — params replicated over the dp axes
    (decode-serving layout for models whose TP shard fits HBM; removes the
    per-layer FSDP weight all-gathers)."""
    shard_experts = (cfg.moe is not None
                     and cfg.moe.n_experts >= mesh.shape["model"])
    F = dp_axes(mesh) if fsdp else None
    from repro.models.transformer import _layer_param_shapes
    per_layer_names = _layer_param_shapes(cfg).keys()
    layer_specs = {
        name: P(*((None,) + tuple(_layer_pspec(name, cfg, shard_experts, F))))
        for name in per_layer_names
    }
    out = {
        "embed": P("model", F),
        "final_ln": P(None),
        "layers": [dict(layer_specs) for _ in cfg.layer_windows],
    }
    if not cfg.tie_embeddings:
        out["lm_head"] = P(F, "model")
    return out


def lm_batch_pspecs(mesh: Mesh) -> dict:
    dp = dp_axes(mesh)
    return {"tokens": P(dp, None), "labels": P(dp, None)}


def lm_cache_pspecs(cfg, mesh: Mesh, *, seq_shard: bool = False,
                    model_seq_shard: bool = True) -> dict:
    """KV caches: batch over dp; the cache SEQUENCE dim over `model`
    (flash-decoding layout: every model-group chip owns a slice of history,
    attention partials are psum'd — tiny (b,h,1) collectives).

    model_seq_shard=False is the naive baseline layout kept for the §Perf
    A/B: heads over model when the GQA KV heads divide the axis, else the
    head_dim — which forces SPMD to fully rematerialize (all-gather) the
    cache every layer (the dominant collective in the decode baselines).

    seq_shard=True (the batch=1 long_500k shape): the sequence dim is
    sharded over dp as well — the 512k-token cache cannot live on one chip.
    """
    dp = dp_axes(mesh)
    if seq_shard:
        b_ax, s_ax = None, (dp + ("model",) if model_seq_shard else dp)
    elif model_seq_shard:
        b_ax, s_ax = dp, "model"
    else:
        b_ax, s_ax = dp, None
    if cfg.mla is not None:
        per = {"c_kv": P(None, b_ax, s_ax, None),
               "k_rope": P(None, b_ax, s_ax, None)}
    else:
        if model_seq_shard:
            h_ax, d_ax = None, None
        elif cfg.n_kv_heads % mesh.shape["model"] == 0:
            h_ax, d_ax = "model", None
        else:
            h_ax, d_ax = None, "model"
        if cfg.kv_cache_dtype == "int8":
            per = {"k_q": P(None, b_ax, s_ax, h_ax, d_ax),
                   "v_q": P(None, b_ax, s_ax, h_ax, d_ax),
                   "k_s": P(None, b_ax, s_ax, h_ax),
                   "v_s": P(None, b_ax, s_ax, h_ax)}
        else:
            per = {"k": P(None, b_ax, s_ax, h_ax, d_ax),
                   "v": P(None, b_ax, s_ax, h_ax, d_ax)}
    return {"slots": [dict(per) for _ in cfg.layer_windows]}


def gnn_batch_pspecs(mesh: Mesh, *, node_sharded: bool, leading_batch: bool,
                     has_positions: bool = True) -> dict:
    """GraphBatch pspecs.  node_sharded: full-graph training with nodes/edges
    split across every axis.  leading_batch: a (n_blocks, ...) batch of
    sampled blocks / molecule graphs, data-parallel over dp."""
    dp = dp_axes(mesh)
    if node_sharded:
        allax = tuple(mesh.axis_names)
        node, edge = P(allax), P(allax)
        return dict(node_feat=P(allax, None), edge_src=edge, edge_dst=edge,
                    n_nodes=P(), labels=node, graph_id=node, n_graphs=P(),
                    positions=P(allax, None) if has_positions else None)
    if leading_batch:
        return dict(node_feat=P(dp, None, None), edge_src=P(dp, None),
                    edge_dst=P(dp, None), n_nodes=P(dp), labels=P(dp, None),
                    graph_id=P(dp, None), n_graphs=P(dp),
                    positions=P(dp, None, None) if has_positions else None)
    rep = P()
    return dict(node_feat=P(None, None), edge_src=P(None), edge_dst=P(None),
                n_nodes=rep, labels=P(None), graph_id=P(None), n_graphs=rep,
                positions=P(None, None) if has_positions else None)


def fm_param_pspecs(mesh: Mesh) -> dict:
    return {"w0": P(), "w": P("model"), "v": P("model", None)}
