from repro.sharding.rules import (dp_axes, fm_param_pspecs, gnn_batch_pspecs,
                                  lm_batch_pspecs, lm_param_pspecs)

__all__ = ["dp_axes", "lm_param_pspecs", "lm_batch_pspecs",
           "gnn_batch_pspecs", "fm_param_pspecs"]
