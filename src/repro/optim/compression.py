"""Gradient compression with error feedback (distributed-optimization trick).

Two schemes, both with EF-SGD-style residual accumulation so compression error
is fed back rather than lost (Karimireddy et al. 2019):

  - ``topk``: keep the largest-|g| fraction per tensor (sparsification); the
    dense all-reduce then moves ~rho of the bytes (with index metadata this
    maps to gather/all-to-all on a real fabric; in-graph we model it as a
    masked dense reduce, which XLA still shrinks via sparsity of values).
  - ``int8``: per-tensor affine quantization of the gradient to int8 before
    the reduce (8x fewer collective bytes), dequantized after.

Applied between loss.grad and the optimizer in train/loop.py; the collective
savings show up in the §Perf collective-bytes term.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    scheme: str = "none"        # none | topk | int8
    topk_fraction: float = 0.01


def compression_init(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _topk_mask(g: jax.Array, frac: float) -> jax.Array:
    k = max(int(g.size * frac), 1)
    flat = jnp.abs(g.reshape(-1))
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return (jnp.abs(g) >= thresh).astype(g.dtype)


def compress_grads(cfg: CompressionConfig, grads, residual):
    """Returns (compressed_grads, new_residual)."""
    if cfg.scheme == "none":
        return grads, residual

    def one(g, r):
        g = g.astype(jnp.float32) + r
        if cfg.scheme == "topk":
            mask = _topk_mask(g, cfg.topk_fraction)
            sent = g * mask
        elif cfg.scheme == "int8":
            scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
            q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
            sent = q.astype(jnp.float32) * scale
        else:
            raise ValueError(cfg.scheme)
        return sent, g - sent

    flat_g, td = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (jax.tree.unflatten(td, [o[0] for o in outs]),
            jax.tree.unflatten(td, [o[1] for o in outs]))
