"""AdamW with decoupled weight decay, global-norm clipping and a linear
warmup + cosine decay schedule.  Pure pytree functions — no optax dependency.
Optimizer state is kept in float32 regardless of param dtype (mixed-precision
training keeps master statistics in full precision)."""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class AdamWState(NamedTuple):
    step: jax.Array
    mu: dict
    nu: dict


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros))


def _schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, params, grads, state: AdamWState):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = _schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * g * g
        step_v = (mu / bc1) / (jnp.sqrt(nu / bc2) + cfg.eps)
        new_p = p.astype(jnp.float32) - lr * (step_v + cfg.weight_decay
                                              * p.astype(jnp.float32))
        return new_p.astype(p.dtype), mu, nu

    flat_p, td = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state.mu)
    flat_nu = jax.tree.leaves(state.nu)
    out = [upd(p, g, m, n) for p, g, m, n in
           zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = jax.tree.unflatten(td, [o[0] for o in out])
    new_mu = jax.tree.unflatten(td, [o[1] for o in out])
    new_nu = jax.tree.unflatten(td, [o[2] for o in out])
    return new_p, AdamWState(step, new_mu, new_nu), {
        "grad_norm": gnorm, "lr": lr}
