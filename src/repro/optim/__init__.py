from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.compression import (CompressionConfig, compress_grads,
                                     compression_init)

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "CompressionConfig",
           "compress_grads", "compression_init"]
