import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: AOT lower + compile every (arch x shape) on the
production meshes, dump memory/cost/roofline artifacts.

The two lines above MUST run before any other import (jax locks the device
count at first init).  Smoke tests and benchmarks do NOT import this module —
they see the real single CPU device.

Usage:
    python -m repro.launch.dryrun --arch gemma3-12b --shape train_4k
    python -m repro.launch.dryrun --arch fm --shape retrieval_cand --multipod
    python -m repro.launch.dryrun --all [--multipod] [--out results/dryrun]

Per cell, emits JSON with: lower/compile seconds, per-chip HLO flops/bytes,
collective bytes by kind (parsed from optimized HLO), memory analysis, the
three roofline terms, and MODEL_FLOPS (analytic useful work).
"""

import argparse
import json
import time
import traceback


_COST_KEYS = ("flops", "bytes accessed", "transcendentals")


def _compile_and_cost(arch, shape, mesh, *, n_repeats=None,
                      scan_layers=True, variant=()):
    """(compiled, costs-dict) for one lower+compile."""
    import jax
    from repro.launch import analysis

    kw = {} if n_repeats is None else {"n_repeats": n_repeats,
                                       "scan_layers": scan_layers}
    if variant:
        kw["variant"] = tuple(variant)
    fn, arg_specs, in_shardings = arch.build_step(shape, mesh, **kw)
    donate = getattr(fn, "donate_argnums", ())
    with mesh:
        lowered = jax.jit(fn, in_shardings=in_shardings,
                          donate_argnums=donate).lower(*arg_specs)
        compiled = lowered.compile()
    roof = analysis.roofline_from_compiled(compiled)
    ca = analysis.cost_dict(compiled)
    return compiled, {
        "flops": roof.flops_per_chip,
        "bytes": roof.bytes_per_chip,
        "coll": roof.coll_by_kind,
        "transcendentals": float(ca.get("transcendentals", 0.0)),
    }


def run_cell(arch_id: str, shape: str, *, multi_pod: bool,
             out_dir: str | None = None, verbose: bool = True,
             with_cost: bool = True, variant: tuple = ()) -> dict:
    import jax
    from repro.configs.registry import get_arch
    from repro.launch import analysis
    from repro.launch.mesh import make_production_mesh

    arch = get_arch(arch_id)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec = {"arch": arch_id, "shape": shape, "mesh": mesh_name,
           "n_devices": int(mesh.devices.size), "ok": False,
           "variant": list(variant)}
    vkw = {"variant": tuple(variant)} if variant else {}
    t0 = time.perf_counter()
    try:
        fn, arg_specs, in_shardings = arch.build_step(shape, mesh, **vkw)
        donate = getattr(fn, "donate_argnums", ())
        with mesh:
            jitted = jax.jit(fn, in_shardings=in_shardings,
                             donate_argnums=donate)
            lowered = jitted.lower(*arg_specs)
            rec["lower_s"] = time.perf_counter() - t0
            t1 = time.perf_counter()
            compiled = lowered.compile()
            rec["compile_s"] = time.perf_counter() - t1

            rec["memory"] = analysis.memory_stats(compiled)
            roof = analysis.roofline_from_compiled(compiled)
            rec["cost"] = {k: v for k, v in analysis.cost_dict(compiled).items()
                           if k in _COST_KEYS}

        # XLA cost_analysis counts while-loop (scan-over-layers) bodies ONCE.
        # For LM archs, compile UNROLLED r=1 and r=2 variants (layer costs
        # inline, so they are counted) and extrapolate:
        # cost(R) = cost(1) + (R-1) * [cost(2) - cost(1)].
        if (with_cost and getattr(arch, "family", "lm") == "lm"
                and hasattr(arch, "config")):
            R = arch.config().n_repeats
            _, c1 = _compile_and_cost(arch, shape, mesh, n_repeats=1,
                                      scan_layers=False, variant=variant)
            _, c2 = _compile_and_cost(arch, shape, mesh, n_repeats=2,
                                      scan_layers=False, variant=variant)
            lin = lambda a, b: a + (R - 1) * (b - a)
            coll = {k: lin(c1["coll"][k], c2["coll"][k]) for k in c1["coll"]}
            roof = analysis.Roofline(
                flops_per_chip=lin(c1["flops"], c2["flops"]),
                bytes_per_chip=lin(c1["bytes"], c2["bytes"]),
                coll_bytes_per_chip=float(sum(coll.values())),
                coll_by_kind=coll)
            rec["scan_extrapolated"] = {"n_repeats": R, "r1": c1, "r2": c2}

        rec["roofline"] = roof.as_dict()
        mf = analysis.model_flops(arch, shape)
        rec["model_flops"] = mf
        if mf and roof.flops_per_chip:
            # cost_analysis flops are per-chip; model flops are global.
            hlo_global = roof.flops_per_chip * mesh.devices.size
            rec["useful_flops_ratio"] = mf / hlo_global
        rec["ok"] = True
    except Exception as e:  # noqa: BLE001 — record, don't crash the sweep
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]

    if verbose:
        if rec["ok"]:
            r = rec["roofline"]
            print(f"[OK] {arch_id} x {shape} @ {mesh_name}: "
                  f"lower {rec['lower_s']:.1f}s compile {rec['compile_s']:.1f}s "
                  f"| t_comp {r['t_compute_s']:.2e} t_mem {r['t_memory_s']:.2e} "
                  f"t_coll {r['t_collective_s']:.2e} -> {r['bottleneck']}")
        else:
            print(f"[FAIL] {arch_id} x {shape} @ {mesh_name}: {rec['error']}")

    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        suffix = ("_" + "-".join(variant)) if variant else ""
        fname = f"{arch_id}_{shape}_{mesh_name}{suffix}.json".replace("/", "-")
        with open(os.path.join(out_dir, fname), "w") as f:
            json.dump(rec, f, indent=1, default=str)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every assigned (arch x shape) cell")
    ap.add_argument("--no-cost", action="store_true",
                    help="skip the cost-extrapolation compiles (multi-pod "
                         "pass: compile success + memory only; the roofline "
                         "table is single-pod)")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--variant", default="",
                    help="comma-separated perf A/B switches "
                         "(see EXPERIMENTS.md §Perf)")
    args = ap.parse_args()
    variant = tuple(v for v in args.variant.split(",") if v)

    from repro.configs.registry import all_cells

    if args.all:
        cells = all_cells()
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    n_fail = 0
    for arch_id, shape in cells:
        rec = run_cell(arch_id, shape, multi_pod=args.multipod,
                       out_dir=args.out, with_cost=not args.no_cost,
                       variant=variant)
        n_fail += 0 if rec["ok"] else 1
    print(f"dry-run complete: {len(cells) - n_fail}/{len(cells)} cells green")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
