"""End-to-end training launcher.

Runs REAL training at smoke/laptop scale on the local devices (the production
meshes exist only for the AOT dry-run — this container has one CPU device):

    python -m repro.launch.train --arch qwen2-1.5b --steps 100
    python -m repro.launch.train --arch gin-tu --shape molecule --steps 50
    python -m repro.launch.train --arch fm --steps 50
    python -m repro.launch.train --arch louvain --graph rmat --scale 12

The LM path drives the full fault-tolerant loop (checkpoint/resume,
straggler counters, gradient compression) from repro.train.loop.
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np


def train_lm(arch_id: str, steps: int, ckpt_dir: str | None,
             compression: str) -> dict:
    from repro.configs.registry import get_arch
    from repro.data.tokens import synthetic_token_batches
    from repro.models import transformer as tf
    from repro.optim import AdamWConfig, CompressionConfig
    from repro.train.loop import TrainLoopConfig, train

    arch = get_arch(arch_id)
    cfg = arch.smoke_config()
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    batches = synthetic_token_batches(cfg.vocab, batch=8, seq_len=128)
    t0 = time.perf_counter()
    params, metrics = train(
        lambda p, b: tf.loss_fn(cfg, p, b), params, iter(batches),
        AdamWConfig(lr=3e-4),
        TrainLoopConfig(total_steps=steps, log_every=max(steps // 10, 1),
                        ckpt_every=max(steps // 2, 1), ckpt_dir=ckpt_dir),
        comp_cfg=CompressionConfig(scheme=compression))
    hist = metrics["history"]
    return {"arch": arch_id, "steps": steps,
            "loss_first": hist[0]["loss"], "loss_last": hist[-1]["loss"],
            "seconds": time.perf_counter() - t0,
            "n_stragglers": metrics["n_stragglers"]}


def train_gnn(arch_id: str, shape: str, steps: int) -> dict:
    from repro.configs.gnn_common import GNN_SMOKE_SHAPES
    from repro.configs.registry import get_arch
    from repro.optim import AdamWConfig, adamw_init, adamw_update

    arch = get_arch(arch_id)
    sh = GNN_SMOKE_SHAPES[shape]
    cfg = arch.make_config(sh, True)
    loss_fn = arch.make_loss(cfg, sh, shape)
    key = jax.random.PRNGKey(0)
    params = arch.init_params(shape, key, smoke=True)
    opt = adamw_init(params)
    opt_cfg = AdamWConfig(lr=1e-3)

    @jax.jit
    def step(params, opt, batch):
        loss, grads = jax.value_and_grad(lambda p: loss_fn(p, batch))(params)
        params, opt, _ = adamw_update(opt_cfg, params, grads, opt)
        return params, opt, loss

    batch = arch.make_batch(shape, key, smoke=True)
    t0 = time.perf_counter()
    first = last = None
    for s in range(steps):
        params, opt, loss = step(params, opt, batch)
        if s == 0:
            first = float(loss)
        last = float(loss)
    return {"arch": arch_id, "shape": shape, "steps": steps,
            "loss_first": first, "loss_last": last,
            "seconds": time.perf_counter() - t0}


def train_fm(steps: int) -> dict:
    from repro.configs.fm import smoke_config
    from repro.data.recsys import synthetic_click_batches
    from repro.models import recsys
    from repro.optim import AdamWConfig, adamw_init, adamw_update

    cfg = smoke_config()
    params = recsys.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    opt_cfg = AdamWConfig(lr=1e-2)

    @jax.jit
    def step(params, opt, batch):
        loss, grads = jax.value_and_grad(
            lambda p: recsys.loss_fn(cfg, p, batch))(params)
        params, opt, _ = adamw_update(opt_cfg, params, grads, opt)
        return params, opt, loss

    batches = synthetic_click_batches(cfg.vocab_sizes, batch=256)
    t0 = time.perf_counter()
    first = last = None
    for s in range(steps):
        b = next(batches)
        b = {"field_ids": jnp.asarray(b["field_ids"]),
             "labels": jnp.asarray(b["labels"])}
        params, opt, loss = step(params, opt, b)
        if s == 0:
            first = float(loss)
        last = float(loss)
    return {"arch": "fm", "steps": steps, "loss_first": first,
            "loss_last": last, "seconds": time.perf_counter() - t0}


def run_louvain(graph: str, scale: int) -> dict:
    from repro.core.louvain import LouvainConfig, louvain, louvain_modularity
    from repro.data import rmat_graph, sbm_graph

    if graph == "rmat":
        G = rmat_graph(scale, edge_factor=8)
    else:
        G, _ = sbm_graph(n_communities=1 << max(scale - 6, 1), size=64,
                         p_in=0.2, p_out=0.002)
    t0 = time.perf_counter()
    res = louvain(G, LouvainConfig())
    dt = time.perf_counter() - t0
    return {"graph": graph, "n": int(G.n_valid), "e": int(G.e_valid),
            "n_communities": res.n_communities,
            "modularity": louvain_modularity(G, res),
            "passes": res.n_passes, "seconds": dt,
            "edges_per_s": int(G.e_valid) / dt}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True,
                    help="arch id from the registry, or 'louvain'")
    ap.add_argument("--shape", default=None)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--compression", default="none",
                    choices=["none", "topk", "int8"])
    ap.add_argument("--graph", default="rmat")
    ap.add_argument("--scale", type=int, default=12)
    args = ap.parse_args()

    if args.arch == "louvain":
        out = run_louvain(args.graph, args.scale)
    else:
        from repro.configs.registry import get_arch
        arch = get_arch(args.arch)
        fam = getattr(arch, "family", "lm")
        if fam == "lm":
            out = train_lm(args.arch, args.steps, args.ckpt_dir,
                           args.compression)
        elif fam == "gnn":
            out = train_gnn(args.arch, args.shape or "molecule", args.steps)
        else:
            out = train_fm(args.steps)
    print(json.dumps(out, indent=1))


if __name__ == "__main__":
    main()
