"""Production mesh construction.

A *function*, not a module-level constant, so importing this module never
touches jax device state (device count is locked at first jax init — the
dry-run sets XLA_FLAGS before any import for exactly this reason).

Production topology (TPU v5e target):
  single-pod:  16 x 16 = 256 chips,  axes (data, model)
  multi-pod:    2 x 16 x 16 = 512 chips, axes (pod, data, model)
"""

from __future__ import annotations

import jax

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist locally, as a 1-D (data,) mesh (examples)."""
    n = jax.device_count()
    return make_mesh((n,), ("data",))
