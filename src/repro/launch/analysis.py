"""Roofline bookkeeping over compiled dry-run artifacts.

Terms (per §Roofline; TPU v5e constants):
    compute    = HLO_FLOPs_per_chip   / peak_FLOP/s
    memory     = HLO_bytes_per_chip   / HBM_bw
    collective = coll_bytes_per_chip  / link_bw

``compiled.cost_analysis()`` reports per-chip (post-SPMD-partition) flops and
bytes.  Collective bytes are NOT in cost_analysis — we parse the optimized
HLO text and sum the output-shape bytes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute (async ``-start`` forms
counted once, ``-done`` skipped).  Post-SPMD shapes are per-chip, so the sums
are already per-chip quantities; the global volume is x n_chips, which cancels
in the roofline ratio — equivalent to the global formula in the assignment.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

# ---- TPU v5e hardware constants (per chip) --------------------------------
PEAK_FLOPS = 197e12          # bf16 FLOP/s
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# One HLO shape literal: dtype[d0,d1,...] — dims may be empty (scalar).
_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]+(?:e[0-9]+m[0-9]+(?:fn)?)?|pred)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output-shape bytes of collective ops in optimized HLO, by kind."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        # `%op.N = <shape or tuple> collective-kind(...)`
        m = re.match(r"%?[\w.\-]+\s*=\s*(.*?)\s+([a-z\-]+)\(", line)
        if not m:
            continue
        shapes_part, op = m.group(1), m.group(2)
        kind = None
        for c in _COLLECTIVES:
            if op == c or op == c + "-start":
                kind = c
                break
        if kind is None:
            continue
        total = sum(_shape_bytes(d, dims)
                    for d, dims in _SHAPE_RE.findall(shapes_part))
        out[kind] += total
    return out


@dataclasses.dataclass
class Roofline:
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes_per_chip: float
    coll_by_kind: Dict[str, int]

    @property
    def t_compute(self) -> float:
        return self.flops_per_chip / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_chip / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_chip / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def bound_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    def as_dict(self) -> dict:
        return {
            "flops_per_chip": self.flops_per_chip,
            "bytes_per_chip": self.bytes_per_chip,
            "coll_bytes_per_chip": self.coll_bytes_per_chip,
            "coll_by_kind": self.coll_by_kind,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
        }


def cost_dict(compiled) -> dict:
    """Normalize compiled.cost_analysis() across jax versions."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca) if ca else {}


def roofline_from_compiled(compiled) -> Roofline:
    ca = cost_dict(compiled)
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    coll = collective_bytes(compiled.as_text())
    return Roofline(flops_per_chip=flops, bytes_per_chip=byts,
                    coll_bytes_per_chip=float(sum(coll.values())),
                    coll_by_kind=coll)


def memory_stats(compiled) -> dict:
    """Per-chip memory analysis (argument/output/temp/peak), best-effort."""
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    if ma is None:
        return {}
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes",
            "alias_size_in_bytes", "peak_memory_in_bytes")
    out = {}
    for k in keys:
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out


# ---------------------------------------------------------------------------
# Analytic MODEL_FLOPS (the "useful work" numerator for the waste ratio).
# ---------------------------------------------------------------------------

def lm_model_flops(cfg, shape_name: str, n_tokens: int, kind: str) -> float:
    """6·N_active·D for training, 2·N_active·D for inference steps."""
    n_active = cfg.active_param_count()
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_active * n_tokens


def model_flops(arch, shape: str, smoke: bool = False) -> Optional[float]:
    """Best-effort analytic useful-FLOPs per step for any registered arch."""
    fam = getattr(arch, "family", "lm")
    if fam == "lm":
        from repro.configs.lm_common import LM_SHAPES
        seq, batch, kind = LM_SHAPES[shape]
        cfg = arch.smoke_config() if smoke else arch.full_config()
        n_tok = batch * (1 if kind == "decode" else seq)
        return lm_model_flops(cfg, shape, n_tok, kind)
    if fam == "recsys":
        from repro.configs.fm import FM_SHAPES, N_CANDIDATES
        batch, kind = FM_SHAPES[shape]
        cfg = arch.smoke_config() if smoke else arch.full_config()
        k, f = cfg.embed_dim, cfg.n_fields
        if kind == "retrieval":
            n_cand = 1024 if smoke else N_CANDIDATES
            return 2.0 * n_cand * k
        fwd = 4.0 * batch * f * k          # sum-square trick: 2 passes over (B,F,k)
        return (3.0 * fwd) if kind == "train" else fwd
    if fam == "gnn":
        from repro.configs.gnn_common import GNN_SHAPES, GNN_SMOKE_SHAPES
        sh = (GNN_SMOKE_SHAPES if smoke else GNN_SHAPES)[shape]
        cfg = arch.make_config(sh, smoke)
        b = sh.batch if sh.kind != "full" else 1
        n, e, d = sh.n_nodes, sh.n_edges, getattr(cfg, "d_hidden", 64)
        L = (getattr(cfg, "n_layers", None) or getattr(cfg, "n_blocks", 4))
        # per layer: node transform 2·N·d_in·d_out + edge gather/scatter ~ e·d
        node_flops = 2.0 * n * (sh.d_feat * d + (L - 1) * d * d) / max(L, 1)
        per_layer = node_flops + 2.0 * e * d
        if arch.arch_id == "dimenet":
            from repro.configs.gnn_common import triplet_cap
            t = triplet_cap(shape, sh)
            per_layer += 2.0 * t * cfg.n_bilinear * d * 2   # bilinear einsum
        if arch.arch_id == "equiformer-v2":
            n_coef = (cfg.l_max + 1) ** 2
            per_layer += 2.0 * e * n_coef * d * 4           # rotate+conv+rotate
        return 3.0 * b * L * per_layer                       # train: fwd+bwd
    return None
