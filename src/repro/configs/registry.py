"""Registry of the ten assigned architectures (plus the paper's own Louvain
graph configs live in repro.core / benchmarks).

Every entry exposes the uniform arch protocol:
    .arch_id  .family  .shapes  .skip_notes
    .input_specs(shape, smoke=False) -> pytree of ShapeDtypeStruct
    .build_step(shape, mesh, smoke=False) -> (fn, arg_specs, in_shardings)
"""

from __future__ import annotations

from typing import Dict, List, Tuple

from repro.configs import (deepseek_v2_236b, dimenet_cfg, equiformer_v2, fm,
                           gat_cora, gemma3_12b, gin_tu, internlm2_20b,
                           mixtral_8x22b, qwen2_1p5b)

ALL_ARCHS = {
    a.ARCH.arch_id: a.ARCH
    for a in (gemma3_12b, qwen2_1p5b, internlm2_20b, mixtral_8x22b,
              deepseek_v2_236b, equiformer_v2, gin_tu, gat_cora, dimenet_cfg,
              fm)
}

# The paper's own distributed phases as dry-run targets (not part of the 40
# assigned cells; --arch louvain in launch/dryrun.py).
from repro.configs import louvain_arch  # noqa: E402

EXTRA_ARCHS = {louvain_arch.ARCH.arch_id: louvain_arch.ARCH}


def get_arch(arch_id: str):
    if arch_id in ALL_ARCHS:
        return ALL_ARCHS[arch_id]
    if arch_id in EXTRA_ARCHS:
        return EXTRA_ARCHS[arch_id]
    raise KeyError(f"unknown arch {arch_id!r}; have "
                   f"{sorted(ALL_ARCHS) + sorted(EXTRA_ARCHS)}")


def all_cells() -> List[Tuple[str, str]]:
    """Every assigned (arch, shape) cell — 40 total."""
    cells = []
    for aid, arch in ALL_ARCHS.items():
        for shape in arch.shapes:
            cells.append((aid, shape))
    return cells


def skipped_cells() -> Dict[Tuple[str, str], str]:
    """Cells skipped per assignment rules (with the reason)."""
    out = {}
    for aid, arch in ALL_ARCHS.items():
        for shape, why in arch.skip_notes.items():
            out[(aid, shape)] = why
    return out
