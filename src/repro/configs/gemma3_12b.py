"""gemma3-12b [dense]: 48L d_model=3840 16H (GQA kv=8) d_ff=15360
vocab=262144 — 5:1 local:global interleaved attention, 1024-token sliding
window on local layers.  [hf:google/gemma-3-12b-pt; unverified]"""

from repro.configs.lm_common import LMArch
from repro.models.transformer import TransformerConfig

_WINDOW = 1024
_PATTERN = (_WINDOW,) * 5 + (None,)      # 5 local : 1 global


def full_config() -> TransformerConfig:
    return TransformerConfig(
        name="gemma3-12b", n_layers=48, d_model=3840, n_heads=16,
        n_kv_heads=8, d_head=256, d_ff=15360, vocab=262144,
        rope_theta=1_000_000.0, layer_windows=_PATTERN, tie_embeddings=True,
        dtype="bfloat16",
    )


def smoke_config() -> TransformerConfig:
    # A 1:1 local:global pattern keeps both attention kinds covered at 2
    # layers — the full 5:1 ratio is a full_config property, and 6 unrolled
    # windowed layers blew the tier-1 compile budget (see tests/conftest.py).
    return TransformerConfig(
        name="gemma3-12b-smoke", n_layers=2, d_model=48, n_heads=4,
        n_kv_heads=2, d_head=12, d_ff=96, vocab=256,
        layer_windows=(16, None), tie_embeddings=True,
        dtype="float32", remat=False,
    )


ARCH = LMArch(
    arch_id="gemma3-12b",
    full_config=full_config,
    smoke_config=smoke_config,
    # long_500k runs: the 5:1 sliding:global hybrid is sub-quadratic in the
    # sliding layers and decode is O(S) per token.
    shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
)
