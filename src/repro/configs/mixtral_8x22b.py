"""mixtral-8x22b [moe]: 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, 8 experts top-2, sliding-window attention.
[arXiv:2401.04088; hf]"""

from repro.configs.lm_common import LMArch
from repro.models.transformer import MoESpec, TransformerConfig

_WINDOW = 4096


def full_config() -> TransformerConfig:
    return TransformerConfig(
        name="mixtral-8x22b", n_layers=56, d_model=6144, n_heads=48,
        n_kv_heads=8, d_head=128, d_ff=16384, vocab=32768,
        rope_theta=1_000_000.0, layer_windows=(_WINDOW,),
        tie_embeddings=False, dtype="bfloat16",
        moe=MoESpec(n_experts=8, top_k=2, d_ff_expert=16384,
                    softmax_after_topk=True),
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name="mixtral-8x22b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_head=16, d_ff=128, vocab=512, layer_windows=(16,),
        tie_embeddings=False, dtype="float32", remat=False,
        moe=MoESpec(n_experts=4, top_k=2, d_ff_expert=96,
                    softmax_after_topk=True),
    )


ARCH = LMArch(
    arch_id="mixtral-8x22b",
    full_config=full_config,
    smoke_config=smoke_config,
    # SWA makes prefill sub-quadratic; decode is O(window) -> long_500k runs.
    shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
)
