"""qwen2-1.5b [dense]: 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936 — GQA with QKV bias.  [arXiv:2407.10671; hf]"""

from repro.configs.lm_common import LMArch
from repro.models.transformer import TransformerConfig


def full_config() -> TransformerConfig:
    return TransformerConfig(
        name="qwen2-1.5b", n_layers=28, d_model=1536, n_heads=12,
        n_kv_heads=2, d_head=128, d_ff=8960, vocab=151936,
        rope_theta=1_000_000.0, qkv_bias=True, tie_embeddings=True,
        dtype="bfloat16",
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name="qwen2-1.5b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_head=16, d_ff=160, vocab=512, qkv_bias=True,
        tie_embeddings=True, dtype="float32", remat=False,
    )


ARCH = LMArch(
    arch_id="qwen2-1.5b",
    full_config=full_config,
    smoke_config=smoke_config,
    # Pure full-attention GQA: long_500k skipped per assignment rule
    # ("needs sub-quadratic attention — skip for pure full-attention archs").
    shapes=("train_4k", "prefill_32k", "decode_32k"),
    skip_notes={"long_500k": "pure full-attention arch (assignment rule)"},
)
