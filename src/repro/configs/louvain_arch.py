"""The paper's own workload as dry-run cells: distributed GVE-Louvain
phases lowered at SuiteSparse scale on the production meshes.

Shapes (mirroring Table 1's largest graphs; |E| counts directed slots):
    web_3.8B_move        sk-2005 scale   one local-move round
    web_3.8B_aggregate   sk-2005 scale   aggregation phase
    road_108M_move       europe_osm scale

Variants:
    "a2a"  — aggregation routes partial coarse edges to their owner shard
             with a capacity-bounded all_to_all instead of the gather-based
             baseline (which materializes the FULL edge list per chip —
             45.6 GB at sk-2005 scale, infeasible on v5e; the all_to_all
             variant is the §Perf fix for the paper's own bottleneck phase).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map


from repro.core.distributed import (ShardedGraphSpec, _best_moves_shard,
                                    _round_body, _shard_index)
from repro.core.engine import round_gate

F32, I32 = jnp.float32, jnp.int32

# ---------------------------------------------------------------------------
# Scanner-backend policy (the ``LouvainConfig.scan_backend`` knob).
# ---------------------------------------------------------------------------

#: Accepted values of ``LouvainConfig.scan_backend``.
SCAN_BACKENDS = ("auto", "full", "compact", "ell", "ell_fused")

#: ``"auto"`` picks the frontier-compacted sort-reduce scanner when the seed
#: frontier covers at most this fraction of the vertices (the measured
#: crossover regime: compact beats the full e_cap scan comfortably at
#: |F|/n <= ~10%, and its overflow fallback makes larger frontiers merely
#: neutral, not wrong).
AUTO_COMPACT_MAX_FRONTIER_FRAC = 0.10

#: Compact work-buffer capacity as a fraction of ``e_cap``.  Frontier edge
#: slots beyond the cap trigger the in-program fallback to the full scan,
#: so this bounds compact-scan memory/compile shape, not correctness.
COMPACT_WORK_FRAC = 0.25

#: Work-buffer floor — tiny graphs keep a sortable minimum.
COMPACT_WORK_MIN = 64


def compact_work_cap(e_cap: int, frac: float = COMPACT_WORK_FRAC) -> int:
    """Static work-buffer capacity for the compacted scanner on ``e_cap``."""
    return max(1, min(int(e_cap), max(COMPACT_WORK_MIN, int(e_cap * frac))))


# ---------------------------------------------------------------------------
# Aggregation-backend policy (the ``LouvainConfig.agg_backend`` knob).
# ---------------------------------------------------------------------------

#: Accepted values of ``LouvainConfig.agg_backend``.
AGG_BACKENDS = ("auto", "sort", "pallas")


def resolve_agg_backend(backend: str) -> str:
    """Map the ``agg_backend`` knob to a concrete aggregation backend.

    ``"sort"`` is the XLA lexsort -> segment_sum -> scatter chain;
    ``"pallas"`` fuses the post-sort group-detect + weight-accumulate +
    emit into one carry-chained kernel sweep (``repro.kernels.aggregate``).
    ``"auto"`` picks the kernel on TPU and the XLA chain elsewhere (the
    interpreter is a correctness tool, not a fast path).
    """
    if backend not in AGG_BACKENDS:
        raise ValueError(f"agg_backend must be one of {AGG_BACKENDS}; "
                         f"got {backend!r}")
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "sort"
    return backend


# ---------------------------------------------------------------------------
# Communication-backend policy (the ``LouvainConfig.comm_backend`` knob).
#
# The sharded move round has two exchange implementations (both pinned
# bit-for-bit against the committed goldens on one shard):
#   "gather" — the Vite-style dense ghost exchange: all_gather the owned
#              membership slice + moved mask, psum the dense Sigma and
#              community-size arrays (2 x O(n_pad) collectives per round).
#   "delta"  — ship only the movers as bit-packed (index, label) lanes;
#              Sigma and community sizes are reconstructed locally from
#              the replicated vertex weights and membership; a measured-
#              overflow lax.cond falls back to the dense exchange when a
#              round's movers exceed the cap.
# ---------------------------------------------------------------------------

#: Accepted values of ``LouvainConfig.comm_backend``.
COMM_BACKENDS = ("auto", "gather", "delta")

#: Mover-buffer capacity as a fraction of ``v_per_shard``: a round moving
#: more than v_per / DELTA_MOVE_CAP_FRAC owned vertices (early cold rounds)
#: takes the dense fallback; warm/late rounds fit comfortably.
DELTA_MOVE_CAP_FRAC = 4

#: Mover-buffer floor — tiny shards keep a usable buffer.
DELTA_MOVE_CAP_MIN = 8


def delta_move_cap(v_per: int) -> int:
    """Static mover-buffer capacity for a shard owning ``v_per`` vertices.

    The one cap of the delta exchange: movers are all that travels (Sigma
    and community sizes are reconstructed from replicated state), so a
    round overflows exactly when its movers do.
    """
    return max(1, min(int(v_per),
                      max(int(v_per) // DELTA_MOVE_CAP_FRAC,
                          DELTA_MOVE_CAP_MIN)))


def resolve_comm_backend(backend: str, n_shards: int) -> str:
    """Map the ``comm_backend`` knob to a concrete exchange for a mesh.

    ``"auto"`` picks ``"delta"`` on real multi-shard meshes and
    ``"gather"`` on a single shard, where every collective is an identity
    move and the delta path's pack/compact work buys nothing.  Explicit
    values pass through (``"delta"`` on one shard is how the golden matrix
    pins the path bit-for-bit).
    """
    if backend not in COMM_BACKENDS:
        raise ValueError(f"comm_backend must be one of {COMM_BACKENDS}; "
                         f"got {backend!r}")
    if backend == "auto":
        return "delta" if n_shards > 1 else "gather"
    return backend


# ---------------------------------------------------------------------------
# State-layout policy (the ``LouvainConfig.state_layout`` knob).
#
# The sharded move round has two STATE layouts, orthogonal to the comm
# backend (both pinned bit-for-bit against the committed goldens):
#   "replicated" — every shard holds (and keeps fresh) the full replicated
#                  membership / Sigma / sizes / K arrays; reconstruction
#                  and per-lane memory traffic scale with n_pad.
#   "hybrid"     — the P3 hybrid-parallel layout: topology stays sharded,
#                  per-vertex working state is OWNER-PARTITIONED, and only
#                  the boundary/halo labels (owned vertices with a live
#                  remote neighbour, ``comm.boundary_mask``) plus
#                  aggregated touched-community (Sigma, size) deltas are
#                  exchanged per round, so per-round payload scales with
#                  |boundary movers| + |touched communities| instead of n.
#                  One owned-membership all_gather per PHASE re-replicates
#                  the output for the unchanged downstream consumers.
# ---------------------------------------------------------------------------

#: Accepted values of ``LouvainConfig.state_layout``.
STATE_LAYOUTS = ("auto", "replicated", "hybrid")

#: ``"auto"`` engages the hybrid layout only when the measured boundary
#: fraction (boundary vertices / live vertices, measured host-side at
#: partition time) is at most this threshold: a mostly-interior partition
#: is where shipping boundary labels beats shipping dense state.  Above
#: it, nearly every vertex publishes anyway and replicated reconstruction
#: is the simpler bargain.
HYBRID_BOUNDARY_FRAC_MAX = 0.5

#: Touched-community lane capacity as a multiple of the mover cap: each
#: mover touches at most two communities (the one it leaves and the one it
#: joins), so 2x the mover cap never under-provisions a within-cap round.
HYBRID_TOUCHED_CAP_FRAC = 2


def hybrid_touched_cap(v_per: int) -> int:
    """Static touched-community lane capacity for a hybrid-DELTA round.

    Sized off the same mover cap as the delta exchange (every mover touches
    <= 2 communities); a round whose touched set overflows it takes the
    dense resync fallback, exactly like a mover overflow.
    """
    return HYBRID_TOUCHED_CAP_FRAC * delta_move_cap(v_per)


def resolve_state_layout(layout: str, n_shards: int,
                         boundary_frac: Optional[float] = None) -> str:
    """Map the ``state_layout`` knob to a concrete layout for a mesh.

    ``"auto"`` engages ``"hybrid"`` on real multi-shard meshes whose
    MEASURED boundary fraction is at most ``HYBRID_BOUNDARY_FRAC_MAX``
    (``None`` — no measurement available — stays replicated), mirroring
    ``resolve_comm_backend``'s shape.  Explicit values pass through
    (``"hybrid"`` on one shard has an empty boundary and collapses to the
    shard-local arithmetic — that is how the golden matrix pins it).
    """
    if layout not in STATE_LAYOUTS:
        raise ValueError(f"state_layout must be one of {STATE_LAYOUTS}; "
                         f"got {layout!r}")
    if layout == "auto":
        if (n_shards > 1 and boundary_frac is not None
                and boundary_frac <= HYBRID_BOUNDARY_FRAC_MAX):
            return "hybrid"
        return "replicated"
    return layout


# ---------------------------------------------------------------------------
# Coarse-pass capacity ladder (the ``LouvainConfig.use_ladder`` knob).
#
# Aggregation shrinks the live graph 10-100x, but buffers keep their original
# capacity — so every later pass scans, renumbers and sorts e_cap slots that
# are almost all padding.  The ladder re-buckets the coarse graph down to the
# smallest power-of-two tier that fits ``(n_comms, e_valid)`` with slack, so
# pass cost follows |V'|, |E'|.  Power-of-two tiers bound the number of
# distinct compiled shapes at log2(e_cap) per phase (each tier's phases are
# jit-cached by shape, the same reuse trick as the PR 3 ELL runner).
# ---------------------------------------------------------------------------

#: Vertex-capacity floor — below this, shrinking buys dispatch overhead, not
#: scan time, so the ladder stops.
LADDER_MIN_N_CAP = 64

#: Edge-capacity floor (same rationale; keeps the sort non-trivial).
LADDER_MIN_E_CAP = 256

#: Headroom multiplier applied to the live counts before tier rounding, so a
#: tier is never an exact fit (renumber/scatter scratch slots stay cheap).
LADDER_SLACK = 1.25

#: Hysteresis: a pass only re-buckets when the candidate tier is at least
#: this factor below the current capacity.  A < 2x shrink would re-jit every
#: phase to save less than half the scan — not worth the compile.
LADDER_HYSTERESIS = 2


def _pow2_at_least(x: int) -> int:
    """Smallest power of two >= max(x, 1)."""
    return 1 << max(int(x) - 1, 0).bit_length()


def resolve_coarse_capacity(n_comms: int, e_valid: int,
                            n_cap: int, e_cap: int) -> Tuple[int, int]:
    """Ladder tier for the NEXT pass of a coarse graph.

    Returns ``(n_cap_new, e_cap_new)``: each dimension independently drops
    to the smallest power-of-two tier >= ``LADDER_SLACK`` x its live count
    (floored at ``LADDER_MIN_*``), but only when that tier undercuts the
    current capacity by at least ``LADDER_HYSTERESIS`` — otherwise the
    dimension keeps its current capacity (never grows).  ``(n_cap, e_cap)``
    back means "don't re-bucket".
    """
    n_tier = max(_pow2_at_least(int(n_comms * LADDER_SLACK)), LADDER_MIN_N_CAP)
    e_tier = max(_pow2_at_least(int(e_valid * LADDER_SLACK)), LADDER_MIN_E_CAP)
    n_new = n_tier if n_tier * LADDER_HYSTERESIS <= n_cap else n_cap
    e_new = e_tier if e_tier * LADDER_HYSTERESIS <= e_cap else e_cap
    return n_new, e_new


# ---------------------------------------------------------------------------
# Skew-aware coarse re-sharding (the ``LouvainConfig.reshard`` knob).
#
# The sharded pass loop keeps the SEED 1-D owner ranges after every
# aggregation, so community-ownership skew on the coarse graph lands on one
# hot shard and is absorbed by capacity doubling (AggregationOverflow
# retries) instead of being balanced away.  ``plan_reshard`` measures the
# skew host-side (the coarse graph is already on the host for the ladder
# re-bucket) and, when it exceeds ``RESHARD_IMBALANCE_THRESHOLD``, assigns
# contiguous owner ranges by a greedy prefix-sum split that equalizes edge
# slots per shard.  Ranges stay uniform-width on the device: a monotone
# relabel places range ``s`` at block ``[s * v_per, s * v_per + width_s)``,
# so every shard_map body keeps its ``owner = id // v_per`` arithmetic and
# only the id -> block mapping changes.  The ids between ``width_s`` and
# ``v_per`` are GAPS — invalid vertices carrying the sentinel community —
# which is why the pass loop threads a live-vertex mask instead of a dense
# ``idx < n_live`` prefix after a re-shard.
# ---------------------------------------------------------------------------

#: Accepted values of ``LouvainConfig.reshard``.
RESHARD_MODES = ("none", "auto")

#: A coarse pass re-shards only when the worst shard's edge-slot load
#: exceeds this multiple of the mean (max/mean ratio) under the uniform
#: layout — balanced graphs skip the shuffle entirely.
RESHARD_IMBALANCE_THRESHOLD = 1.5

#: Per-shard block-width cap as a multiple of the fair share
#: ceil(n_live / n_shards).  Bounds the replicated-state blowup of the
#: relabelled layout: n_pad_new <= slack * pow2(n_live) instead of one hot
#: range stretching toward n_live.
RESHARD_WIDTH_SLACK = 4


def resolve_reshard(mode: str) -> str:
    """Validate the ``reshard`` knob (``"none"`` | ``"auto"``)."""
    if mode not in RESHARD_MODES:
        raise ValueError(f"reshard must be one of {RESHARD_MODES}; "
                         f"got {mode!r}")
    return mode


class ReshardPlan(NamedTuple):
    """A balanced contiguous owner split of a coarse graph.

    ``bounds`` is ``(n_shards + 1,)``: shard ``s`` owns the dense coarse
    ids ``[bounds[s], bounds[s + 1])``, relabelled onto the uniform block
    ``[s * v_per_shard, ...)``.  ``e_per_shard`` is the power-of-two edge
    tier sized to the worst post-split shard load (with ``LADDER_SLACK``),
    and the ``load_frac_*`` pair records the worst shard's share of all
    edge slots before/after — the ``max_shard_load_frac`` bench columns.
    """

    bounds: np.ndarray
    v_per_shard: int
    e_per_shard: int
    load_frac_before: float
    load_frac_after: float


def owner_load_frac(counts: np.ndarray, v_per: int, n_shards: int) -> float:
    """Worst shard's share of total edge slots under uniform-width ranges.

    ``counts`` holds per-vertex owned edge slots for ids ``[0, n_live)``;
    ownership is ``id // v_per`` (clamped to the last shard).  Returns a
    fraction in ``[1 / n_shards, 1]``; a total of zero reports the
    balanced floor.
    """
    counts = np.asarray(counts, np.int64)
    total = int(counts.sum())
    n_shards = max(int(n_shards), 1)
    if total <= 0 or counts.shape[0] == 0:
        return 1.0 / n_shards
    owner = np.minimum(np.arange(counts.shape[0]) // max(int(v_per), 1),
                       n_shards - 1)
    loads = np.bincount(owner, weights=counts, minlength=n_shards)
    return float(loads.max() / total)


def plan_reshard(counts: np.ndarray, n_shards: int, v_per_uniform: int, *,
                 threshold: float | None = None,
                 width_slack: int | None = None) -> Optional[ReshardPlan]:
    """Plan a skew-aware owner split, or ``None`` when not worth it.

    ``counts`` are per-coarse-vertex edge slots (dense ids, host-side);
    ``v_per_uniform`` is the per-shard width the uniform (non-resharded)
    layout would use for the next pass — the baseline being priced against.
    Returns ``None`` when the mesh is trivial, the measured imbalance
    (max/mean) is at most ``threshold``, or the greedy split cannot beat
    the uniform layout's worst load (e.g. one super-vertex dominates).

    The split is a greedy prefix-sum walk: boundary ``s`` lands where the
    cumulative load first reaches ``s / n_shards`` of the total, clamped so
    no block exceeds ``width_slack`` fair shares (and so the remaining
    shards can still cover the tail).  Deterministic pure numpy — no mesh.
    """
    counts = np.asarray(counts, np.int64)
    n_live = int(counts.shape[0])
    total = int(counts.sum())
    n_shards = int(n_shards)
    if n_shards <= 1 or n_live == 0 or total <= 0:
        return None
    thr = RESHARD_IMBALANCE_THRESHOLD if threshold is None else threshold
    slack = RESHARD_WIDTH_SLACK if width_slack is None else width_slack
    frac_before = owner_load_frac(counts, v_per_uniform, n_shards)
    if frac_before * n_shards <= thr:
        return None

    v_cap = _pow2_at_least(-(-n_live // n_shards) * max(int(slack), 1))
    cum = np.cumsum(counts)
    bounds = np.zeros((n_shards + 1,), np.int64)
    bounds[n_shards] = n_live
    for s in range(1, n_shards):
        prev = int(bounds[s - 1])
        target = total * s / n_shards
        b = int(np.searchsorted(cum, target, side="left")) + 1
        lo = max(prev, n_live - (n_shards - s) * v_cap)
        hi = min(prev + v_cap, n_live)
        bounds[s] = min(max(b, lo), hi)

    widths = np.diff(bounds)
    v_per = max(_pow2_at_least(int(widths.max())),
                _pow2_at_least(-(-LADDER_MIN_N_CAP // n_shards)))
    csum = np.concatenate([np.zeros((1,), np.int64), cum])
    loads = csum[bounds[1:]] - csum[bounds[:-1]]
    frac_after = float(loads.max() / total)
    if frac_after >= frac_before:
        return None
    e_floor = -(-LADDER_MIN_E_CAP // n_shards)
    e_per = _pow2_at_least(max(int(loads.max() * LADDER_SLACK), e_floor))
    return ReshardPlan(bounds, int(v_per), int(e_per),
                       frac_before, frac_after)


# ---------------------------------------------------------------------------
# Multi-tenant fleet admission policy (the ``core.fleet`` serving layer).
#
# The fleet combines the two scaling axes: every tenant graph is sharded
# across the mesh (1-D vertex partition, like ``core.distributed``) AND
# tenants are batched per dispatch (vmap over a tenant lane, like
# ``core.multistream``).  A vmapped program needs ONE compiled shape per
# bucket, so tenants are admitted into power-of-two capacity envelopes
# ``(v_per_shard, e_per_shard, b_cap)`` — tenants sharing an envelope share
# a bucket (one ``jit(vmap(step))`` program); a whale tenant outgrowing its
# envelope MIGRATES to a bigger bucket (one recompile in the destination
# bucket) instead of forcing a fleet-wide recompile.
# ---------------------------------------------------------------------------

#: Headroom multiplier on the worst shard's owned edge slots at admission —
#: mirrors the sharded streaming driver's default 25% slack, so a tenant's
#: first growth event needs genuinely new volume, not admission jitter.
FLEET_E_SLACK = 1.25

#: Per-shard vertex-block floor (tiny tenants keep a usable block).
FLEET_MIN_V_PER = 8

#: Per-shard edge-slot floor (keeps the per-shard sort non-trivial).
FLEET_MIN_E_PER = 32

#: Migration doubles capacity at least this factor — the same geometric
#: growth the single-fleet ``multistream`` regrow and the sharded streaming
#: ``_grow_to`` use, so a whale cannot thrash the bucket ladder.
FLEET_GROW_FACTOR = 2


class FleetEnvelope(NamedTuple):
    """Power-of-two per-tenant capacity envelope of a fleet bucket.

    Tenants with equal envelopes ride one compiled ``jit(vmap(...))``
    program; the implied global capacities on an ``n_shards`` mesh are
    ``v_cap = n_shards * v_per_shard`` (the padded vertex count / sentinel)
    and ``e_cap = n_shards * e_per_shard`` directed edge slots.
    """

    v_per_shard: int
    e_per_shard: int
    b_cap: int           # per-step edge-batch capacity (stacked per lane)

    def v_cap(self, n_shards: int) -> int:
        return self.v_per_shard * n_shards

    def e_cap(self, n_shards: int) -> int:
        return self.e_per_shard * n_shards


def fleet_v_per_shard(n_cap: int, n_shards: int) -> int:
    """Power-of-two per-shard vertex block covering ``n_cap`` vertices."""
    return max(_pow2_at_least(-(-int(n_cap) // max(int(n_shards), 1))),
               FLEET_MIN_V_PER)


def fleet_envelope(n_cap: int, owned_max: int, b_cap: int,
                   n_shards: int) -> FleetEnvelope:
    """Admission envelope for one tenant.

    ``owned_max`` is the worst shard's owned live directed slots under the
    ``fleet_v_per_shard`` owner map (the caller measures it host-side with
    one bincount).  The edge tier reserves ``FLEET_E_SLACK`` headroom plus
    room for one worst-case batch (a batch adds at most ``2 * b_cap``
    directed slots to a single shard), then rounds up to a power of two —
    so organically-near tenants coalesce into the same bucket.
    """
    b_cap = max(_pow2_at_least(int(b_cap)), 1)
    e_need = int(int(owned_max) * FLEET_E_SLACK) + 2 * b_cap
    e_per = max(_pow2_at_least(e_need), FLEET_MIN_E_PER)
    return FleetEnvelope(fleet_v_per_shard(n_cap, n_shards), e_per, b_cap)


def plan_fleet(sizings, n_shards: int) -> Dict[FleetEnvelope, list]:
    """Group tenants into capacity buckets — the fleet admission policy.

    ``sizings`` is a sequence of ``(n_cap, owned_max, b_cap)`` tuples (one
    per tenant, in admission order); returns ``{envelope: [tenant_index]}``
    with deterministic per-envelope ordering.  Pure policy: the router owns
    the arrays, this owns the numbers.
    """
    buckets: Dict[FleetEnvelope, list] = {}
    for i, (n_cap, owned_max, b_cap) in enumerate(sizings):
        env = fleet_envelope(n_cap, owned_max, b_cap, n_shards)
        buckets.setdefault(env, []).append(i)
    return buckets


def migrate_envelope(env: FleetEnvelope, e_need: int) -> FleetEnvelope:
    """The envelope a whale tenant migrates into after an edge overflow.

    ``e_need`` is the measured worst-shard slot requirement of the
    overflowing step; growth is geometric (``FLEET_GROW_FACTOR``) and
    power-of-two quantized, mirroring the sharded streaming driver's
    ``_grow_to(max(2 * e_per, e_max))``.
    """
    e_per = _pow2_at_least(max(FLEET_GROW_FACTOR * env.e_per_shard,
                               int(e_need)))
    return env._replace(e_per_shard=e_per)


def resolve_scan_backend(backend: str, *, use_ell_kernel: bool = False,
                         frontier_frac: float | None = None) -> str:
    """Map the ``scan_backend`` knob to a concrete scanner for ONE pass.

    ``frontier_frac`` is the seed-frontier fraction |F|/n of the pass when a
    delta-screened / warm frontier is active, ``None`` for a cold full-
    frontier pass.  Returns one of ``"full" | "compact" | "ell" |
    "ell_fused"``:

      * explicit values pass through (``"compact"`` still only engages when
        a frontier is active — a cold pass re-scans everything anyway);
      * ``"auto"`` + ELL family -> the fused kernel (it replaces the
        scan-then-apply round-trip, bit-identically);
      * ``"auto"`` + active small frontier -> ``"compact"``;
      * otherwise the full sort-reduce scan.
    """
    if backend not in SCAN_BACKENDS:
        raise ValueError(f"scan_backend must be one of {SCAN_BACKENDS}; "
                         f"got {backend!r}")
    if use_ell_kernel or backend in ("ell", "ell_fused"):
        if backend == "compact":
            raise ValueError(
                "scan_backend='compact' contradicts use_ell_kernel=True — "
                "the compacted scanner is a sort-reduce backend; use "
                "scan_backend='auto'/'ell_fused' for the ELL family or "
                "drop use_ell_kernel")
        if backend in ("auto", "ell_fused"):
            return "ell_fused"
        return "ell"
    if backend == "compact":
        return "compact" if frontier_frac is not None else "full"
    if backend == "auto":
        if (frontier_frac is not None
                and frontier_frac <= AUTO_COMPACT_MAX_FRONTIER_FRAC):
            return "compact"
        return "full"
    return "full"

# name -> (|V|, |E| directed slots, phase)
LOUVAIN_SHAPES: Dict[str, Tuple[int, int, str]] = {
    "web_3.8B_move": (50_636_154, 3_800_000_000, "move"),
    "web_3.8B_aggregate": (50_636_154, 3_800_000_000, "aggregate"),
    "road_108M_move": (50_912_018, 108_109_320, "move"),
    "road_108M_aggregate": (50_912_018, 108_109_320, "aggregate"),
}


def _spec_for(mesh: Mesh, n: int, e: int) -> ShardedGraphSpec:
    n_shards = int(mesh.devices.size)
    v_per = -(-n // n_shards)
    e_per = -(-e // n_shards)
    return ShardedGraphSpec(n_shards, v_per, e_per, v_per * n_shards)


def _move_round_delta(axes, spec: ShardedGraphSpec, move_cap_frac: int,
                      src_l, dst_l, w_l, comm, sigma, comm_sizes, k, m):
    """One local-move round with DELTA-ENCODED state exchange.

    The baseline round all_gathers the full membership C (n_pad int32),
    psums the dense Σ (n_pad f32) and psums the dense community sizes —
    3 x O(n_pad) collectives per round.  Here only the (vertex, new_comm)
    pairs of vertices that actually MOVED are gathered (static cap =
    v_per / move_cap_frac per shard); every shard then reconstructs Σ,
    community sizes and the frontier locally from the replicated k and the
    gathered deltas — redundant O(moved) recompute in place of O(n_pad)
    collectives.  Returns (comm', sigma', sizes', frontier_l, dq, overflow).
    """
    v_per, sent = spec.v_per_shard, spec.sentinel
    frontier_l = jnp.ones((v_per,), bool)
    best_c, best_dq, v0 = _best_moves_shard(
        axes, spec, src_l, dst_l, w_l, comm, sigma, k, frontier_l, m)
    own_comm_l = jax.lax.dynamic_slice_in_dim(comm, v0, v_per)
    k_l = jax.lax.dynamic_slice_in_dim(k, v0, v_per)
    gidx = v0 + jnp.arange(v_per)

    # round-0 gate + singleton guard from the REPLICATED sizes input.
    gate = round_gate(gidx, jnp.int32(0), 2)
    own_single = comm_sizes[own_comm_l] == 1
    tgt_single = comm_sizes[jnp.minimum(best_c, sent)] == 1
    swap_blocked = own_single & tgt_single & (best_c > own_comm_l)
    do_move = ((best_dq > 0.0) & (best_c != own_comm_l) & (best_c < sent)
               & gate & ~swap_blocked)
    dq_round = jax.lax.psum(jnp.sum(jnp.where(do_move, best_dq, 0.0)), axes)

    # --- delta encoding: (global vertex id, new community) of movers -------
    cap = max(v_per // move_cap_frac, 1)
    rank = jnp.cumsum(do_move.astype(I32)) - 1
    keep = do_move & (rank < cap)
    slot = jnp.where(keep, rank, cap)
    idx_buf = jnp.full((cap + 1,), sent, I32).at[slot].set(
        jnp.where(keep, gidx, sent))[:cap]
    val_buf = jnp.full((cap + 1,), sent, I32).at[slot].set(
        jnp.where(keep, best_c, sent))[:cap]
    overflow = jax.lax.pmax(jnp.sum(do_move.astype(I32)) - cap, axes)

    g_idx = jax.lax.all_gather(idx_buf, axes, tiled=True)   # (S*cap,)
    g_val = jax.lax.all_gather(val_buf, axes, tiled=True)

    # --- replicated reconstruction from the deltas --------------------------
    g_live = g_idx < sent
    comm_new = comm.at[jnp.minimum(g_idx, sent)].set(
        jnp.where(g_live, g_val, comm[jnp.minimum(g_idx, sent)]))
    k_moved = jnp.where(g_live, k[jnp.minimum(g_idx, sent)], 0.0)
    old_c = comm[jnp.minimum(g_idx, sent)]
    sigma_new = (sigma
                 .at[jnp.where(g_live, g_val, sent)].add(k_moved)
                 .at[jnp.where(g_live, old_c, sent)].add(-k_moved))
    ones_m = jnp.where(g_live, 1, 0)
    sizes_new = (comm_sizes
                 .at[jnp.where(g_live, g_val, sent)].add(ones_m)
                 .at[jnp.where(g_live, old_c, sent)].add(-ones_m))

    # frontier: neighbors of movers, from the reconstructed moved mask.
    moved_mask = jnp.zeros((sent + 1,), bool).at[
        jnp.minimum(g_idx, sent)].set(g_live)
    src_loc = jnp.where(src_l >= sent, v_per, src_l - v0)
    marked = jax.ops.segment_max(
        moved_mask[dst_l].astype(I32), src_loc, num_segments=v_per + 1)[:v_per]
    frontier_new = (marked > 0) & (gidx < spec.n_pad)
    return comm_new, sigma_new, sizes_new, frontier_new, dq_round, overflow


def _aggregate_a2a_body(axes, spec: ShardedGraphSpec, cap_factor: int,
                        src_l, dst_l, w_l, comm):
    """Owner-routed aggregation: local sort-reduce partials, all_to_all the
    partial coarse edges to the shard owning their source community, local
    re-reduce.  Per-chip traffic = 3 arrays x P x cap x 4B ~ cap_factor x e_l
    x 12B, vs the gather baseline's n_shards x e_l x 12B."""
    v_per, sent = spec.v_per_shard, spec.sentinel
    n_shards = spec.n_shards
    e_l = src_l.shape[0]
    ci = comm[src_l]
    cj = comm[dst_l]

    # local partial reduce (identical to the baseline first stage)
    order = jnp.lexsort((cj, ci))
    s_ci, s_cj, s_w = ci[order], cj[order], w_l[order]
    prev_i = jnp.concatenate([jnp.full((1,), -1, I32), s_ci[:-1]])
    prev_j = jnp.concatenate([jnp.full((1,), -1, I32), s_cj[:-1]])
    new_group = (s_ci != prev_i) | (s_cj != prev_j)
    gid = jnp.cumsum(new_group.astype(I32)) - 1
    gw = jax.ops.segment_sum(s_w, gid, num_segments=e_l)[gid]
    live = new_group & (s_ci != sent)

    # route each live partial to owner shard = ci // v_per, with a static
    # per-destination capacity (cap_factor x fair share).
    cap = cap_factor * (e_l // n_shards)
    dest = jnp.where(live, s_ci // v_per, n_shards)
    d_order = jnp.argsort(dest)
    d_sorted = dest[d_order]
    ranks = jnp.arange(e_l) - jnp.searchsorted(d_sorted, d_sorted,
                                               side="left")
    keep = (d_sorted < n_shards) & (ranks < cap)
    slot = jnp.where(keep, d_sorted * cap + ranks, n_shards * cap)

    def scatter(vals, fill):
        buf = jnp.full((n_shards * cap + 1,), fill, vals.dtype)
        return buf.at[slot].set(jnp.where(keep, vals[d_order], fill))[:-1]

    b_ci = scatter(s_ci, jnp.int32(sent)).reshape(n_shards, cap)
    b_cj = scatter(s_cj, jnp.int32(sent)).reshape(n_shards, cap)
    b_w = scatter(gw, jnp.float32(0)).reshape(n_shards, cap)

    r_ci = jax.lax.all_to_all(b_ci, axes, 0, 0, tiled=True).reshape(-1)
    r_cj = jax.lax.all_to_all(b_cj, axes, 0, 0, tiled=True).reshape(-1)
    r_w = jax.lax.all_to_all(b_w, axes, 0, 0, tiled=True).reshape(-1)

    # local re-reduce of everything this shard owns
    order2 = jnp.lexsort((r_cj, r_ci))
    t_ci, t_cj, t_w = r_ci[order2], r_cj[order2], r_w[order2]
    prev_i = jnp.concatenate([jnp.full((1,), -1, I32), t_ci[:-1]])
    prev_j = jnp.concatenate([jnp.full((1,), -1, I32), t_cj[:-1]])
    ng2 = (t_ci != prev_i) | (t_cj != prev_j)
    gid2 = jnp.cumsum(ng2.astype(I32)) - 1
    gw2 = jax.ops.segment_sum(t_w, gid2, num_segments=t_w.shape[0])[gid2]
    live2 = ng2 & (t_ci != sent)
    n_out = t_w.shape[0]
    pos2 = jnp.where(live2, gid2, n_out)
    o_ci = jnp.full((n_out + 1,), sent, I32).at[pos2].set(t_ci)[:n_out]
    o_cj = jnp.full((n_out + 1,), sent, I32).at[pos2].set(t_cj)[:n_out]
    o_w = jnp.zeros((n_out + 1,), F32).at[pos2].set(
        jnp.where(live2, gw2, 0.0))[:n_out]
    e_valid = jax.lax.psum(jnp.sum(jnp.where(live2, 1, 0)), axes)
    # capacity diagnostic: partials dropped by the per-destination cap
    dropped = jax.lax.psum(
        jnp.sum(jnp.where(live, 1, 0)) - jnp.sum(jnp.where(keep, 1, 0)),
        axes)
    return o_ci, o_cj, o_w, e_valid, dropped


def _aggregate_gather_body(axes, spec: ShardedGraphSpec,
                           src_l, dst_l, w_l, comm):
    """Baseline (core.distributed.make_distributed_aggregate inner body)."""
    from repro.core import distributed as dmod
    # Reuse the library body by constructing it the same way.
    v_per, sent = spec.v_per_shard, spec.sentinel
    e_l = src_l.shape[0]
    ci = comm[src_l]
    cj = comm[dst_l]
    order = jnp.lexsort((cj, ci))
    s_ci, s_cj, s_w = ci[order], cj[order], w_l[order]
    prev_i = jnp.concatenate([jnp.full((1,), -1, I32), s_ci[:-1]])
    prev_j = jnp.concatenate([jnp.full((1,), -1, I32), s_cj[:-1]])
    new_group = (s_ci != prev_i) | (s_cj != prev_j)
    gidl = jnp.cumsum(new_group.astype(I32)) - 1
    gw = jax.ops.segment_sum(s_w, gidl, num_segments=e_l)[gidl]
    live = new_group & (s_ci != sent)
    pos = jnp.where(live, gidl, e_l)
    p_ci = jnp.full((e_l + 1,), sent, I32).at[pos].set(s_ci)[:e_l]
    p_cj = jnp.full((e_l + 1,), sent, I32).at[pos].set(s_cj)[:e_l]
    p_w = jnp.zeros((e_l + 1,), F32).at[pos].set(gw)[:e_l]

    g_ci = jax.lax.all_gather(p_ci, axes, tiled=True)
    g_cj = jax.lax.all_gather(p_cj, axes, tiled=True)
    g_w = jax.lax.all_gather(p_w, axes, tiled=True)

    shard_ix = _shard_index(axes)
    v0 = shard_ix * v_per
    mine = (g_ci >= v0) & (g_ci < v0 + v_per)
    m_ci = jnp.where(mine, g_ci, sent)
    m_cj = jnp.where(mine, g_cj, sent)
    m_w = jnp.where(mine, g_w, 0.0)
    order2 = jnp.lexsort((m_cj, m_ci))
    t_ci, t_cj, t_w = m_ci[order2], m_cj[order2], m_w[order2]
    prev_i = jnp.concatenate([jnp.full((1,), -1, I32), t_ci[:-1]])
    prev_j = jnp.concatenate([jnp.full((1,), -1, I32), t_cj[:-1]])
    ng2 = (t_ci != prev_i) | (t_cj != prev_j)
    gid2 = jnp.cumsum(ng2.astype(I32)) - 1
    gw2 = jax.ops.segment_sum(t_w, gid2, num_segments=t_w.shape[0])[gid2]
    live2 = ng2 & (t_ci != sent)
    pos2 = jnp.where(live2, gid2, e_l)
    o_ci = jnp.full((e_l + 1,), sent, I32).at[pos2].set(
        jnp.where(live2, t_ci, sent))[:e_l]
    o_cj = jnp.full((e_l + 1,), sent, I32).at[pos2].set(
        jnp.where(live2, t_cj, sent))[:e_l]
    o_w = jnp.zeros((e_l + 1,), F32).at[pos2].set(
        jnp.where(live2, gw2, 0.0))[:e_l]
    e_valid = jax.lax.psum(jnp.sum(jnp.where(live2, 1, 0)), axes)
    # overflow diagnostic (see core.distributed.make_distributed_aggregate)
    owned_max = jax.lax.pmax(jnp.sum(jnp.where(live2, 1, 0)), axes)
    return o_ci, o_cj, o_w, e_valid, owned_max


@dataclasses.dataclass(frozen=True)
class LouvainArch:
    """Dry-run protocol wrapper for the paper's own distributed phases."""

    arch_id: str = "louvain"
    family: str = "louvain"
    shapes: Tuple[str, ...] = tuple(LOUVAIN_SHAPES)
    skip_notes: Dict[str, str] = dataclasses.field(default_factory=dict)

    def input_specs(self, shape: str, smoke: bool = False) -> dict:
        n, e, phase = LOUVAIN_SHAPES[shape]
        if smoke:
            n, e = 4096, 32768
        S = jax.ShapeDtypeStruct
        # edge arrays are padded to shard-divisible lengths at build time
        return {"src": S((e,), I32), "dst": S((e,), I32),
                "w": S((e,), F32), "comm": S((n + 1,), I32),
                "sigma": S((n + 1,), F32), "k": S((n + 1,), F32),
                "m": S((), F32)}

    def build_step(self, shape: str, mesh: Mesh, smoke: bool = False,
                   variant: Tuple[str, ...] = ()):
        n, e, phase = LOUVAIN_SHAPES[shape]
        if smoke:
            n, e = 4096, 32768
        spec = _spec_for(mesh, n, e)
        axes = tuple(mesh.axis_names)
        n_pad, e_pad = spec.n_pad, spec.e_per_shard * spec.n_shards
        S = jax.ShapeDtypeStruct
        arg_specs = ({"src": S((e_pad,), I32), "dst": S((e_pad,), I32),
                      "w": S((e_pad,), F32), "comm": S((n_pad + 1,), I32),
                      "sigma": S((n_pad + 1,), F32),
                      "k": S((n_pad + 1,), F32), "m": S((), F32)},)
        edge = P(axes)
        rep = P()
        shardings = ({"src": NamedSharding(mesh, edge),
                      "dst": NamedSharding(mesh, edge),
                      "w": NamedSharding(mesh, edge),
                      "comm": NamedSharding(mesh, rep),
                      "sigma": NamedSharding(mesh, rep),
                      "k": NamedSharding(mesh, rep),
                      "m": NamedSharding(mesh, rep)},)

        if phase == "move" and "delta_c" in variant:
            arg_specs[0]["comm_sizes"] = S((n_pad + 1,), I32)
            shardings[0]["comm_sizes"] = NamedSharding(mesh, rep)
            body = functools.partial(_move_round_delta, axes, spec, 4)
            fn_s = shard_map(
                body, mesh=mesh,
                in_specs=(edge, edge, edge, rep, rep, rep, rep, rep),
                out_specs=(rep, rep, rep, edge, rep, rep),
                check_rep=False)

            def step(batch):
                return fn_s(batch["src"], batch["dst"], batch["w"],
                            batch["comm"], batch["sigma"],
                            batch["comm_sizes"], batch["k"], batch["m"])
            return step, arg_specs, shardings

        if phase == "move":
            def round_shard(src_l, dst_l, w_l, comm, sigma, k, m):
                frontier = jnp.ones((spec.v_per_shard,), bool)
                return _round_body(axes, spec, src_l, dst_l, w_l, comm,
                                   sigma, k, frontier, jnp.int32(0), 2, m)

            fn_s = shard_map(round_shard, mesh=mesh,
                             in_specs=(edge, edge, edge, rep, rep, rep, rep),
                             out_specs=(rep, rep, edge, rep),
                             check_rep=False)
        else:
            if "a2a" in variant:
                body = functools.partial(_aggregate_a2a_body, axes, spec, 4)
            else:
                body = functools.partial(_aggregate_gather_body, axes, spec)
            outs = (edge, edge, edge, rep, rep)

            fn_s = shard_map(body, mesh=mesh,
                             in_specs=(edge, edge, edge, rep),
                             out_specs=outs,
                             check_rep=False)

        if phase == "move":
            def step(batch):
                return fn_s(batch["src"], batch["dst"], batch["w"],
                            batch["comm"], batch["sigma"], batch["k"],
                            batch["m"])
        else:
            def step(batch):
                return fn_s(batch["src"], batch["dst"], batch["w"],
                            batch["comm"])
        return step, arg_specs, shardings


ARCH = LouvainArch()
