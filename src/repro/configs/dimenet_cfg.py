"""dimenet [gnn]: 6 blocks, d_hidden=128, n_bilinear=8, n_spherical=7,
n_radial=6 — directional message passing over triplets.  [arXiv:2003.03123]

Graph-level regression everywhere (DimeNet's native task).  Non-geometric
shapes use stub positions; triplet lists are capacity-capped on the web-scale
shapes (DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.gnn_common import GNNArch, GNNShape
from repro.models.gnn import dimenet
from repro.models.gnn.common import GraphBatch


def _config(sh: GNNShape, smoke: bool) -> dimenet.DimeNetConfig:
    if smoke:
        return dimenet.DimeNetConfig(
            name="dimenet-smoke", n_blocks=2, d_hidden=16, n_bilinear=4,
            n_spherical=3, n_radial=4, d_feat=sh.d_feat)
    return dimenet.DimeNetConfig(
        name="dimenet", n_blocks=6, d_hidden=128, n_bilinear=8,
        n_spherical=7, n_radial=6, d_feat=sh.d_feat)


def _loss(cfg: dimenet.DimeNetConfig, sh: GNNShape, shape_name: str):
    if sh.kind == "full":
        def loss(params, batch):
            n_pad = batch["node_feat"].shape[0]
            g = GraphBatch(
                node_feat=batch["node_feat"], edge_src=batch["edge_src"],
                edge_dst=batch["edge_dst"], n_nodes=jnp.int32(sh.n_nodes),
                labels=batch["labels"],
                graph_id=jnp.zeros((n_pad,), jnp.int32),
                n_graphs=jnp.int32(1), positions=batch["positions"])
            pred = dimenet.forward(cfg, params, g, batch["t_kj"],
                                   batch["t_ji"])        # (n_pad, 1)
            return jnp.mean(jnp.square(pred[0, 0] - batch["labels"][0]))
        return loss

    def one(params, nf, es, ed, pos, tkj, tji):
        g = GraphBatch(node_feat=nf, edge_src=es, edge_dst=ed,
                       n_nodes=jnp.int32(sh.n_nodes),
                       labels=jnp.zeros((sh.n_nodes,), jnp.float32),
                       graph_id=jnp.zeros((sh.n_nodes,), jnp.int32),
                       n_graphs=jnp.int32(1), positions=pos)
        return dimenet.forward(cfg, params, g, tkj, tji)[0, 0]

    def loss(params, batch):
        pred = jax.vmap(one, in_axes=(None, 0, 0, 0, 0, 0, 0))(
            params, batch["node_feat"], batch["edge_src"],
            batch["edge_dst"], batch["positions"], batch["t_kj"],
            batch["t_ji"])                                # (B,)
        return jnp.mean(jnp.square(pred - batch["labels"]))
    return loss


ARCH = GNNArch(
    arch_id="dimenet",
    needs_positions=True,
    needs_triplets=True,
    label_kind="graph",
    make_config=_config,
    make_loss=_loss,
    make_params=lambda cfg, key: dimenet.init_params(cfg, key),
    make_param_specs=lambda cfg: jax.eval_shape(
        functools.partial(dimenet.init_params, cfg), jax.random.PRNGKey(0)),
    skip_notes={},
)
