"""deepseek-v2-236b [moe]: 60L d_model=5120 128H MLA (kv_lora=512) d_ff=1536,
160 routed experts top-6 + 2 shared experts, vocab=102400.
[arXiv:2405.04434; hf]

Simplification noted in DESIGN.md: the reference model's first layer uses a
dense FFN (12288); here every layer is MoE (uniform scan pattern)."""

from repro.configs.lm_common import LMArch
from repro.models.mla import MLAConfig
from repro.models.transformer import MoESpec, TransformerConfig


def full_config() -> TransformerConfig:
    return TransformerConfig(
        name="deepseek-v2-236b", n_layers=60, d_model=5120, n_heads=128,
        n_kv_heads=128, d_head=128, d_ff=1536, vocab=102400,
        rope_theta=10000.0, tie_embeddings=False, dtype="bfloat16",
        mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                      qk_nope_head_dim=128, qk_rope_head_dim=64,
                      v_head_dim=128),
        moe=MoESpec(n_experts=160, top_k=6, d_ff_expert=1536,
                    n_shared=2, d_ff_shared=3072),
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name="deepseek-v2-236b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_head=16, d_ff=64, vocab=512, tie_embeddings=False,
        dtype="float32", remat=False,
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
                      qk_rope_head_dim=8, v_head_dim=16),
        moe=MoESpec(n_experts=8, top_k=2, d_ff_expert=32, n_shared=2,
                    d_ff_shared=64),
    )


ARCH = LMArch(
    arch_id="deepseek-v2-236b",
    full_config=full_config,
    smoke_config=smoke_config,
    # MLA decode reads a 576-float/token latent cache: long_500k runs.
    shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
)
