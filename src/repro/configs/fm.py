"""fm [recsys]: factorization machine, 39 sparse fields, embed_dim=10,
pairwise interactions via the O(nk) sum-square trick.  [ICDM'10 (Rendle)]

Shapes: train_batch (B=65,536 training), serve_p99 (B=512 online),
serve_bulk (B=262,144 offline scoring), retrieval_cand (1 query vs 10^6
candidates, single batched matvec).

Embedding tables (~33M rows x 10) are row-sharded over the `model` mesh axis;
the batch is data-parallel over the dp axes.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import recsys
from repro.optim import AdamWConfig, adamw_update
from repro.optim.adamw import AdamWState
from repro.sharding.rules import dp_axes, fm_param_pspecs

I32, F32 = jnp.int32, jnp.float32

# (batch, kind); retrieval_cand carries n_candidates.
FM_SHAPES: Dict[str, Tuple[int, str]] = {
    "train_batch": (65536, "train"),
    "serve_p99": (512, "serve"),
    "serve_bulk": (262144, "serve"),
    "retrieval_cand": (1, "retrieval"),
}
N_CANDIDATES = 1_000_000
# Candidate array padded to divide every mesh flattening (valid prefix = 1M).
N_CANDIDATES_PAD = -(-N_CANDIDATES // 512) * 512

SMOKE_VOCABS = tuple([64, 48, 32, 24, 16, 12, 8, 8] + [4] * 31)  # 39 fields


def full_config() -> recsys.FMConfig:
    return recsys.FMConfig(name="fm", n_fields=39, embed_dim=10)


def smoke_config() -> recsys.FMConfig:
    return recsys.FMConfig(name="fm-smoke", n_fields=39, embed_dim=10,
                           vocab_sizes=SMOKE_VOCABS)


def fm_input_specs(cfg: recsys.FMConfig, shape: str,
                   smoke: bool = False) -> dict:
    batch, kind = FM_SHAPES[shape]
    if smoke:
        batch = min(batch, 32)
    S = jax.ShapeDtypeStruct
    if kind == "train":
        return {"field_ids": S((batch, cfg.n_fields), I32),
                "labels": S((batch,), I32)}
    if kind == "serve":
        return {"field_ids": S((batch, cfg.n_fields), I32)}
    n_cand = 1024 if smoke else N_CANDIDATES_PAD
    return {"user_fields": S((1, cfg.n_fields), I32),
            "cand_rows": S((n_cand,), I32)}


def _opt_specs(param_specs_tree) -> AdamWState:
    f32 = lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32)
    return AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32),
                      mu=jax.tree.map(f32, param_specs_tree),
                      nu=jax.tree.map(f32, param_specs_tree))


def build_fm_step(cfg: recsys.FMConfig, shape: str, mesh: Mesh,
                  opt_cfg: AdamWConfig = AdamWConfig(),
                  smoke: bool = False):
    """Returns (fn, arg_specs, in_shardings) for jit(...).lower()."""
    batch, kind = FM_SHAPES[shape]
    p_shapes = recsys.param_shapes(cfg)
    p_specs = jax.tree.map(lambda s: jax.ShapeDtypeStruct(s, F32), p_shapes,
                           is_leaf=lambda x: isinstance(x, tuple))
    p_pspecs = fm_param_pspecs(mesh)
    ns = lambda tree: jax.tree.map(
        lambda spec: NamedSharding(mesh, spec), tree,
        is_leaf=lambda x: isinstance(x, P))
    dp = dp_axes(mesh)
    in_specs = fm_input_specs(cfg, shape, smoke=smoke)

    if kind == "train":
        o_specs = _opt_specs(p_specs)
        o_pspecs = AdamWState(step=P(),
                              mu=jax.tree.map(lambda p: p, p_pspecs),
                              nu=jax.tree.map(lambda p: p, p_pspecs))

        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(
                lambda p: recsys.loss_fn(cfg, p, batch))(params)
            params, opt_state, _ = adamw_update(opt_cfg, params, grads,
                                                opt_state)
            return params, opt_state, loss

        args = (p_specs, o_specs, in_specs)
        shardings = (ns(p_pspecs), ns(o_pspecs),
                     {"field_ids": NamedSharding(mesh, P(dp, None)),
                      "labels": NamedSharding(mesh, P(dp))})
        return train_step, args, shardings

    if kind == "serve":
        def serve_step(params, batch):
            return recsys.forward(cfg, params, batch["field_ids"])

        args = (p_specs, in_specs)
        shardings = (ns(p_pspecs),
                     {"field_ids": NamedSharding(mesh, P(dp, None))})
        return serve_step, args, shardings

    # retrieval: one user scored against every candidate — candidates are
    # sharded over the full mesh, the query is replicated.
    allax = tuple(mesh.axis_names)

    def retrieval_step(params, batch):
        return recsys.retrieval_scores(cfg, params, batch["user_fields"],
                                       batch["cand_rows"])

    args = (p_specs, in_specs)
    shardings = (ns(p_pspecs),
                 {"user_fields": NamedSharding(mesh, P(None, None)),
                  "cand_rows": NamedSharding(mesh, P(allax))})
    return retrieval_step, args, shardings


@dataclasses.dataclass(frozen=True)
class FMArch:
    arch_id: str = "fm"
    family: str = "recsys"
    shapes: Tuple[str, ...] = tuple(FM_SHAPES)
    skip_notes: Dict[str, str] = dataclasses.field(default_factory=dict)

    def full_config(self) -> recsys.FMConfig:
        return full_config()

    def smoke_config(self) -> recsys.FMConfig:
        return smoke_config()

    def input_specs(self, shape: str, smoke: bool = False) -> dict:
        cfg = smoke_config() if smoke else full_config()
        return fm_input_specs(cfg, shape, smoke=smoke)

    def build_step(self, shape: str, mesh: Mesh, smoke: bool = False):
        cfg = smoke_config() if smoke else full_config()
        return build_fm_step(cfg, shape, mesh, smoke=smoke)


ARCH = FMArch()
