"""Shared plumbing for the five LM architectures: shapes, input specs, and
step builders (train / prefill / decode) with production shardings."""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import transformer as tf
from repro.optim import AdamWConfig, adamw_update
from repro.optim.adamw import AdamWState
from repro.sharding.rules import (dp_axes, lm_batch_pspecs, lm_cache_pspecs,
                                  lm_param_pspecs)

# (seq_len, global_batch, kind)
LM_SHAPES: Dict[str, Tuple[int, int, str]] = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}


def _shape_dims(shape: str, smoke: bool):
    """(seq, batch, kind); smoke shrinks to CPU-executable sizes."""
    seq, batch, kind = LM_SHAPES[shape]
    if smoke:
        seq, batch = min(seq, 128), min(batch, 4)
    return seq, batch, kind


def lm_input_specs(cfg: tf.TransformerConfig, shape: str,
                   smoke: bool = False) -> dict:
    seq, batch, kind = _shape_dims(shape, smoke)
    tok = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    if kind == "train":
        return {"tokens": tok, "labels": tok}
    if kind == "prefill":
        return {"tokens": tok}
    # decode: one new token against a seq-long cache
    return {"tokens": jax.ShapeDtypeStruct((batch, 1), jnp.int32),
            "cache_len": jax.ShapeDtypeStruct((), jnp.int32)}


def opt_specs(param_specs_tree) -> AdamWState:
    f32 = lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32)
    return AdamWState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        mu=jax.tree.map(f32, param_specs_tree),
        nu=jax.tree.map(f32, param_specs_tree),
    )


def opt_pspecs(param_pspecs_tree) -> AdamWState:
    return AdamWState(step=P(),
                      mu=jax.tree.map(lambda p: p, param_pspecs_tree),
                      nu=jax.tree.map(lambda p: p, param_pspecs_tree))


def make_sharded_ce(cfg: tf.TransformerConfig, mesh: Mesh):
    """Vocab-sharded cross-entropy: the LM-head matmul + softmax reductions
    run per vocab shard inside shard_map; only O(B·S) max/sum scalars cross
    the `model` axis — the full (B, S, V) f32 logits are NEVER materialized
    or gathered (they peak at ~40 GB/chip on the train_4k cells otherwise).
    """
    from jax.experimental.shard_map import shard_map

    dp = dp_axes(mesh)
    axes = tuple(mesh.axis_names)
    n_model = mesh.shape["model"]

    def body(x_l, head_l, labels_l):
        # x_l: (b_l, S, d) — batch-sharded over dp, replicated over model.
        # head_l: (d_f, V/m) — vocab-sharded; d still FSDP-sharded: gather.
        if dp:
            head_l = jax.lax.all_gather(head_l, dp, axis=0, tiled=True)
        logits = (x_l @ head_l.astype(x_l.dtype)).astype(jnp.float32)
        # global max via all_gather of the (b_l, S) per-shard maxima (pmax
        # has no AD rule; the gathered stats are ~KBs).
        shard_max = jnp.max(logits, -1)                        # (b_l, S)
        gmax = jax.lax.stop_gradient(jnp.max(
            jax.lax.all_gather(shard_max, "model", axis=0), axis=0))
        sumexp = jnp.sum(jnp.exp(logits - gmax[..., None]), -1)
        lse = gmax + jnp.log(jax.lax.psum(sumexp, "model"))

        v_l = logits.shape[-1]
        col = labels_l - jax.lax.axis_index("model") * v_l
        in_shard = (col >= 0) & (col < v_l)
        ll_local = jnp.take_along_axis(
            logits, jnp.clip(col, 0, v_l - 1)[..., None], -1)[..., 0]
        ll = jax.lax.psum(jnp.where(in_shard, ll_local, 0.0), "model")

        total = jax.lax.psum(jnp.sum(lse - ll), axes)
        count = jax.lax.psum(jnp.float32(lse.size), axes)
        return total / count

    F = dp if dp else None

    def loss(params, batch):
        x = tf.forward(cfg, params, batch["tokens"], return_hidden=True)
        head = (params["embed"].T if cfg.tie_embeddings
                else params["lm_head"])
        head_spec = P(F, "model")   # embed.T of P('model', F) / lm_head
        fn = shard_map(body, mesh=mesh,
                       in_specs=(P(dp, None, None), head_spec, P(dp, None)),
                       out_specs=P(), check_rep=False)
        return fn(x, head, batch["labels"])

    return loss


def build_lm_step(cfg: tf.TransformerConfig, shape: str, mesh: Mesh,
                  opt_cfg: AdamWConfig = AdamWConfig(),
                  variant: Tuple[str, ...] = (),
                  smoke_shapes: bool = False):
    """Returns (fn, arg_specs, in_shardings) ready for jax.jit(...).lower().

    variant: perf A/B switches (see EXPERIMENTS.md §Perf).
      "naive_cache"     — decode caches head/dim-sharded instead of the
                          flash-decoding sequence-sharded layout (baseline).
      "tp_only_params"  — params replicated over dp (no FSDP gathers);
                          serving layout for models whose TP shard fits HBM.
      "sharded_ce"      — vocab-sharded distributed-softmax loss: never
                          materializes the (B, S, V) f32 logits.
      "int8_kv"         — decode caches stored int8 with per-(pos, head)
                          scales; dequantized in-register.
    """
    if "int8_kv" in variant and cfg.mla is None:
        cfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
    seq, batch, kind = _shape_dims(shape, smoke_shapes)
    p_specs = tf.param_specs(cfg)
    p_pspecs = lm_param_pspecs(cfg, mesh,
                               fsdp="tp_only_params" not in variant)
    ns = lambda tree: jax.tree.map(
        lambda spec: NamedSharding(mesh, spec), tree,
        is_leaf=lambda x: isinstance(x, P))
    batch_specs = lm_input_specs(cfg, shape, smoke=smoke_shapes)
    dp = dp_axes(mesh)

    if kind == "train":
        o_specs = opt_specs(p_specs)
        o_pspecs = opt_pspecs(p_pspecs)
        if "sharded_ce" in variant:
            loss_of = make_sharded_ce(cfg, mesh)
        else:
            loss_of = lambda p, b: tf.loss_fn(cfg, p, b)

        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(
                lambda p: loss_of(p, batch))(params)
            params, opt_state, metrics = adamw_update(
                opt_cfg, params, grads, opt_state)
            return params, opt_state, loss

        # params + opt_state are donated (aliased in-place) in production.
        train_step.donate_argnums = (0, 1)
        args = (p_specs, o_specs, batch_specs)
        shardings = (ns(p_pspecs), ns(o_pspecs),
                     ns(lm_batch_pspecs(mesh)))
        return train_step, args, shardings

    if kind == "prefill":
        def prefill_step(params, batch):
            logits = tf.forward(cfg, params, batch["tokens"])
            return logits[:, -1]

        args = (p_specs, {"tokens": batch_specs["tokens"]})
        shardings = (ns(p_pspecs), {"tokens": NamedSharding(mesh, P(dp, None))})
        return prefill_step, args, shardings

    # decode.  batch=1 (long_500k) seq-shards the cache: sequence parallelism.
    c_specs = tf.cache_specs(cfg, batch, seq)
    c_pspecs = lm_cache_pspecs(cfg, mesh, seq_shard=(batch == 1),
                               model_seq_shard="naive_cache" not in variant)

    def decode_fn(params, cache, batch):
        logits, new_cache = tf.decode_step(
            cfg, params, cache, batch["tokens"], batch["cache_len"])
        return logits, new_cache

    # The KV cache is donated — the decode loop updates it in place; without
    # donation every step would copy the full cache (+2x HBM traffic).
    if "no_donate" not in variant:
        decode_fn.donate_argnums = (1,)

    tok_spec = P(None, None) if batch == 1 else P(dp, None)
    args = (p_specs, c_specs, batch_specs)
    shardings = (ns(p_pspecs), ns(c_pspecs),
                 {"tokens": NamedSharding(mesh, tok_spec),
                  "cache_len": NamedSharding(mesh, P())})
    return decode_fn, args, shardings


@dataclasses.dataclass(frozen=True)
class LMArch:
    arch_id: str
    full_config: Callable[[], tf.TransformerConfig]
    smoke_config: Callable[[], tf.TransformerConfig]
    shapes: Tuple[str, ...]
    skip_notes: Dict[str, str] = dataclasses.field(default_factory=dict)
    family: str = "lm"

    def input_specs(self, shape: str, smoke: bool = False):
        cfg = self.smoke_config() if smoke else self.full_config()
        return lm_input_specs(cfg, shape, smoke=smoke)

    def config(self, smoke: bool = False, n_repeats: int | None = None,
               scan_layers: bool = True) -> tf.TransformerConfig:
        cfg = self.smoke_config() if smoke else self.full_config()
        repl = {}
        if n_repeats is not None:
            repl["n_layers"] = len(cfg.layer_windows) * n_repeats
        if not scan_layers:
            repl["scan_layers"] = False
        return dataclasses.replace(cfg, **repl) if repl else cfg

    def build_step(self, shape: str, mesh: Mesh, smoke: bool = False,
                   n_repeats: int | None = None, scan_layers: bool = True,
                   variant: Tuple[str, ...] = ()):
        """n_repeats + scan_layers=False are the dry-run cost-accounting
        variants: XLA cost_analysis counts while-loop bodies once, so the
        dry-run compiles UNROLLED r=1 and r=2 stacks and extrapolates
        linearly to the full depth."""
        return build_lm_step(self.config(smoke, n_repeats, scan_layers),
                             shape, mesh, variant=variant,
                             smoke_shapes=smoke)
