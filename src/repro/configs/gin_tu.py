"""gin-tu [gnn]: 5 layers, d_hidden=64, sum aggregator, learnable eps.
[arXiv:1810.00826; paper]

Node classification on the full-graph / sampled shapes; TU-style graph
classification on the `molecule` shape (its native benchmark setting).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.gnn_common import GNNArch, GNNShape
from repro.models.gnn import gin
from repro.models.gnn.common import GraphBatch, node_ce_loss


def _config(sh: GNNShape, smoke: bool) -> gin.GINConfig:
    if smoke:
        return gin.GINConfig(name="gin-tu-smoke", n_layers=2, d_hidden=16,
                             d_feat=sh.d_feat, n_classes=sh.n_classes)
    return gin.GINConfig(name="gin-tu", n_layers=5, d_hidden=64,
                         d_feat=sh.d_feat, n_classes=sh.n_classes)


def _graph_of(batch: dict, n_valid: int) -> GraphBatch:
    n_pad = batch["node_feat"].shape[0]
    return GraphBatch(
        node_feat=batch["node_feat"], edge_src=batch["edge_src"],
        edge_dst=batch["edge_dst"], n_nodes=jnp.int32(n_valid),
        labels=batch["labels"], graph_id=jnp.zeros((n_pad,), jnp.int32),
        n_graphs=jnp.int32(1), positions=batch.get("positions"))


def _loss(cfg: gin.GINConfig, sh: GNNShape, shape_name: str):
    if sh.kind == "full":
        def loss(params, batch):
            g = _graph_of(batch, sh.n_nodes)
            logits = gin.forward(cfg, params, g)
            n_pad = logits.shape[0]
            mask = (jnp.arange(n_pad) < sh.n_nodes).astype(jnp.float32)
            return node_ce_loss(logits, batch["labels"], mask)
        return loss

    if sh.kind == "blocks":
        def one(params, nf, es, ed, lab):
            g = GraphBatch(node_feat=nf, edge_src=es, edge_dst=ed,
                           n_nodes=jnp.int32(sh.n_nodes), labels=lab,
                           graph_id=jnp.zeros((sh.n_nodes,), jnp.int32),
                           n_graphs=jnp.int32(1))
            logits = gin.forward(cfg, params, g)
            mask = (jnp.arange(sh.n_nodes) < sh.n_seeds).astype(jnp.float32)
            return node_ce_loss(logits, lab, mask)

        def loss(params, batch):
            per = jax.vmap(one, in_axes=(None, 0, 0, 0, 0))(
                params, batch["node_feat"], batch["edge_src"],
                batch["edge_dst"], batch["labels"])
            return jnp.mean(per)
        return loss

    # molecule: graph classification (graph_level readout, label per graph).
    def one_g(params, nf, es, ed):
        g = GraphBatch(node_feat=nf, edge_src=es, edge_dst=ed,
                       n_nodes=jnp.int32(sh.n_nodes),
                       labels=jnp.zeros((sh.n_nodes,), jnp.int32),
                       graph_id=jnp.zeros((sh.n_nodes,), jnp.int32),
                       n_graphs=jnp.int32(1))
        cfg_g = gin.GINConfig(**{**cfg.__dict__, "graph_level": True})
        return gin.forward(cfg_g, params, g)[0]          # (n_classes,)

    def loss(params, batch):
        logits = jax.vmap(one_g, in_axes=(None, 0, 0, 0))(
            params, batch["node_feat"], batch["edge_src"],
            batch["edge_dst"])                            # (B, n_classes)
        mask = jnp.ones((sh.batch,), jnp.float32)
        return node_ce_loss(logits, batch["labels"], mask)
    return loss


ARCH = GNNArch(
    arch_id="gin-tu",
    needs_positions=False,
    needs_triplets=False,
    label_kind="node",
    label_kind_overrides={"molecule": "graph_class"},
    make_config=_config,
    make_loss=_loss,
    make_params=lambda cfg, key: gin.init_params(cfg, key),
    make_param_specs=lambda cfg: jax.eval_shape(
        functools.partial(gin.init_params, cfg), jax.random.PRNGKey(0)),
)
