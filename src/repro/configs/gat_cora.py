"""gat-cora [gnn]: 2 layers, d_hidden=8 per head, 8 heads, attention
aggregator.  [arXiv:1710.10903; paper]

Node classification on every shape (GAT is a node classifier; the `molecule`
shape runs node-level targets over the batched graphs).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.gnn_common import GNNArch, GNNShape
from repro.models.gnn import gat
from repro.models.gnn.common import GraphBatch, node_ce_loss


def _config(sh: GNNShape, smoke: bool) -> gat.GATConfig:
    if smoke:
        return gat.GATConfig(name="gat-cora-smoke", n_layers=2, d_hidden=4,
                             n_heads=2, d_feat=sh.d_feat,
                             n_classes=sh.n_classes)
    return gat.GATConfig(name="gat-cora", n_layers=2, d_hidden=8, n_heads=8,
                         d_feat=sh.d_feat, n_classes=sh.n_classes)


def _loss(cfg: gat.GATConfig, sh: GNNShape, shape_name: str):
    if sh.kind == "full":
        def loss(params, batch):
            n_pad = batch["node_feat"].shape[0]
            g = GraphBatch(
                node_feat=batch["node_feat"], edge_src=batch["edge_src"],
                edge_dst=batch["edge_dst"], n_nodes=jnp.int32(sh.n_nodes),
                labels=batch["labels"],
                graph_id=jnp.zeros((n_pad,), jnp.int32),
                n_graphs=jnp.int32(1))
            logits = gat.forward(cfg, params, g)
            mask = (jnp.arange(n_pad) < sh.n_nodes).astype(jnp.float32)
            return node_ce_loss(logits, batch["labels"], mask)
        return loss

    seed_masked = sh.kind == "blocks"

    def one(params, nf, es, ed, lab):
        g = GraphBatch(node_feat=nf, edge_src=es, edge_dst=ed,
                       n_nodes=jnp.int32(sh.n_nodes), labels=lab,
                       graph_id=jnp.zeros((sh.n_nodes,), jnp.int32),
                       n_graphs=jnp.int32(1))
        logits = gat.forward(cfg, params, g)
        if seed_masked:
            mask = (jnp.arange(sh.n_nodes) < sh.n_seeds).astype(jnp.float32)
        else:
            mask = jnp.ones((sh.n_nodes,), jnp.float32)
        return node_ce_loss(logits, lab, mask)

    def loss(params, batch):
        per = jax.vmap(one, in_axes=(None, 0, 0, 0, 0))(
            params, batch["node_feat"], batch["edge_src"],
            batch["edge_dst"], batch["labels"])
        return jnp.mean(per)
    return loss


ARCH = GNNArch(
    arch_id="gat-cora",
    needs_positions=False,
    needs_triplets=False,
    label_kind="node",
    make_config=_config,
    make_loss=_loss,
    make_params=lambda cfg, key: gat.init_params(cfg, key),
    make_param_specs=lambda cfg: jax.eval_shape(
        functools.partial(gat.init_params, cfg), jax.random.PRNGKey(0)),
)
