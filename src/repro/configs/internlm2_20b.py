"""internlm2-20b [dense]: 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92544 — GQA.  [arXiv:2403.17297; hf]"""

from repro.configs.lm_common import LMArch
from repro.models.transformer import TransformerConfig


def full_config() -> TransformerConfig:
    return TransformerConfig(
        name="internlm2-20b", n_layers=48, d_model=6144, n_heads=48,
        n_kv_heads=8, d_head=128, d_ff=16384, vocab=92544,
        rope_theta=1_000_000.0, tie_embeddings=False, dtype="bfloat16",
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name="internlm2-20b-smoke", n_layers=2, d_model=96, n_heads=6,
        n_kv_heads=2, d_head=16, d_ff=256, vocab=512, tie_embeddings=False,
        dtype="float32", remat=False,
    )


ARCH = LMArch(
    arch_id="internlm2-20b",
    full_config=full_config,
    smoke_config=smoke_config,
    shapes=("train_4k", "prefill_32k", "decode_32k"),
    skip_notes={"long_500k": "pure full-attention arch (assignment rule)"},
)
