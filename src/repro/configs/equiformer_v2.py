"""equiformer-v2 [gnn]: 12 layers, d_hidden=128 sphere channels, l_max=6,
m_max=2, 8 heads — SO(2)-eSCN equivariant graph attention.
[arXiv:2306.12059; unverified]

Node classification on full/sampled shapes (node head over the l=0 channel),
energy regression on `molecule`.  Positions are required (stubbed for the
non-geometric shapes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.gnn_common import GNNArch, GNNShape
from repro.models.gnn import equiformer
from repro.models.gnn.common import GraphBatch, node_ce_loss


def _config(sh: GNNShape, smoke: bool) -> equiformer.EquiformerConfig:
    node_level = sh.kind != "molecule"
    out = sh.n_classes if node_level else 1
    if smoke:
        # d_hidden=8 keeps the eSCN tensor-product compile inside the tier-1
        # wall-clock budget; l_max=2/m_max=1 still exercise the SO(2) path.
        return equiformer.EquiformerConfig(
            name="equiformer-v2-smoke", n_layers=2, d_hidden=8, l_max=2,
            m_max=1, n_heads=2, d_feat=sh.d_feat, out_dim=out,
            node_level=node_level)
    return equiformer.EquiformerConfig(
        name="equiformer-v2", n_layers=12, d_hidden=128, l_max=6, m_max=2,
        n_heads=8, d_feat=sh.d_feat, out_dim=out, node_level=node_level)


def _loss(cfg: equiformer.EquiformerConfig, sh: GNNShape, shape_name: str):
    if sh.kind == "full":
        def loss(params, batch):
            n_pad = batch["node_feat"].shape[0]
            g = GraphBatch(
                node_feat=batch["node_feat"], edge_src=batch["edge_src"],
                edge_dst=batch["edge_dst"], n_nodes=jnp.int32(sh.n_nodes),
                labels=batch["labels"],
                graph_id=jnp.zeros((n_pad,), jnp.int32),
                n_graphs=jnp.int32(1), positions=batch["positions"])
            logits = equiformer.forward(cfg, params, g)
            mask = (jnp.arange(n_pad) < sh.n_nodes).astype(jnp.float32)
            return node_ce_loss(logits, batch["labels"], mask)
        return loss

    if sh.kind == "blocks":
        def one(params, nf, es, ed, pos, lab):
            g = GraphBatch(node_feat=nf, edge_src=es, edge_dst=ed,
                           n_nodes=jnp.int32(sh.n_nodes), labels=lab,
                           graph_id=jnp.zeros((sh.n_nodes,), jnp.int32),
                           n_graphs=jnp.int32(1), positions=pos)
            logits = equiformer.forward(cfg, params, g)
            mask = (jnp.arange(sh.n_nodes) < sh.n_seeds).astype(jnp.float32)
            return node_ce_loss(logits, lab, mask)

        def loss(params, batch):
            per = jax.vmap(one, in_axes=(None, 0, 0, 0, 0, 0))(
                params, batch["node_feat"], batch["edge_src"],
                batch["edge_dst"], batch["positions"], batch["labels"])
            return jnp.mean(per)
        return loss

    # molecule: per-graph energy regression.
    def one_g(params, nf, es, ed, pos):
        g = GraphBatch(node_feat=nf, edge_src=es, edge_dst=ed,
                       n_nodes=jnp.int32(sh.n_nodes),
                       labels=jnp.zeros((sh.n_nodes,), jnp.float32),
                       graph_id=jnp.zeros((sh.n_nodes,), jnp.int32),
                       n_graphs=jnp.int32(1), positions=pos)
        return equiformer.forward(cfg, params, g)[0, 0]

    def loss(params, batch):
        pred = jax.vmap(one_g, in_axes=(None, 0, 0, 0, 0))(
            params, batch["node_feat"], batch["edge_src"],
            batch["edge_dst"], batch["positions"])
        return jnp.mean(jnp.square(pred - batch["labels"]))
    return loss


ARCH = GNNArch(
    arch_id="equiformer-v2",
    needs_positions=True,
    needs_triplets=False,
    label_kind="node",
    label_kind_overrides={"molecule": "graph"},
    make_config=_config,
    make_loss=_loss,
    make_params=lambda cfg, key: equiformer.init_params(cfg, key),
    make_param_specs=lambda cfg: jax.eval_shape(
        functools.partial(equiformer.init_params, cfg), jax.random.PRNGKey(0)),
)
