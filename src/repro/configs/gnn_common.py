"""Shared plumbing for the four GNN architectures: the assigned shape set,
input specs, and train-step builders with production shardings.

The four assigned GNN shapes all exercise *training*:

  full_graph_sm   Cora-scale full-batch        (N=2,708   E=10,556   F=1,433)
  minibatch_lg    Reddit-scale sampled blocks  (N=232,965 E=114.6M, 1,024 seeds,
                                                fanout 15-10)
  ogb_products    products-scale full-batch    (N=2,449,029 E=61.9M  F=100)
  molecule        batched small graphs         (30 nodes, 64 edges, batch 128)

Layouts (see DESIGN.md):
  - full graphs: node/edge arrays sharded over EVERY mesh axis flattened
    (graph parallelism; the paper's Louvain partitioner produces the
    device-local orderings used at runtime).
  - minibatch: a leading batch of 32 sampled blocks (32 seeds x fanout 15-10
    each = 1,024 global seeds), data-parallel over the dp axes, model vmapped
    over blocks.
  - molecule: a leading batch of 128 padded molecules, data-parallel.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.optim import AdamWConfig, adamw_update
from repro.optim.adamw import AdamWState
from repro.sharding.rules import dp_axes

F32 = jnp.float32
I32 = jnp.int32


@dataclasses.dataclass(frozen=True)
class GNNShape:
    kind: str                 # "full" | "blocks" | "molecule"
    n_nodes: int
    n_edges: int              # directed edge slots
    d_feat: int
    n_classes: int
    # blocks / molecule:
    batch: int = 1            # leading batch (blocks or molecules)
    n_seeds: int = 0          # seeds per block (blocks kind)
    # graph-level targets (dimenet / equiformer energy heads):
    note: str = ""


# block capacity for 32 seeds, fanout (15, 10):  nodes 32*(1+15+150)=5312,
# edges 32*(15+150)=5280 — 32 blocks x 32 seeds = 1,024 global seed nodes.
_BLOCK_SEEDS = 32
_BLOCK_N = _BLOCK_SEEDS * (1 + 15 + 15 * 10)
_BLOCK_E = _BLOCK_SEEDS * (15 + 15 * 10)

GNN_SHAPES: Dict[str, GNNShape] = {
    "full_graph_sm": GNNShape("full", 2708, 10556, 1433, 7,
                              note="Cora full-batch"),
    "minibatch_lg": GNNShape("blocks", _BLOCK_N, _BLOCK_E, 602, 41,
                             batch=32, n_seeds=_BLOCK_SEEDS,
                             note="Reddit-scale sampled; global graph "
                                  "N=232,965 E=114,615,892 lives host-side"),
    "ogb_products": GNNShape("full", 2449029, 61859140, 100, 47,
                             note="ogbn-products full-batch"),
    "molecule": GNNShape("molecule", 30, 64, 16, 8, batch=128,
                         note="batched small graphs"),
}

# Reduced shapes for smoke tests (same kinds, tiny sizes).
GNN_SMOKE_SHAPES: Dict[str, GNNShape] = {
    "full_graph_sm": GNNShape("full", 64, 256, 16, 4),
    "minibatch_lg": GNNShape("blocks", 2 * (1 + 3 + 6), 2 * (3 + 6), 16, 4,
                             batch=2, n_seeds=2),
    "ogb_products": GNNShape("full", 96, 384, 12, 5),
    "molecule": GNNShape("molecule", 10, 20, 8, 3, batch=4),
}


def pad512(x: int) -> int:
    """Pad a sharded-dim capacity to a multiple of 512 (= lcm of every mesh
    flattening: 256 single-pod, 512 multi-pod, 16/32 dp groups).  The valid
    prefix keeps the exact assigned size; pad slots carry sentinels — the
    same padded-buffer convention as the Louvain core."""
    return -(-x // 512) * 512


def triplet_cap(shape_name: str, shape: GNNShape) -> int:
    """Static triplet capacity for DimeNet per shape (k->j->i wedges).

    Molecular graphs get a comfortable 4x edges; the non-geometric stress
    shapes are capacity-capped (DimeNet's wedge count grows with sum(deg^2),
    which is unbounded on power-law graphs — noted in DESIGN.md).
    """
    if shape.kind == "full" and shape.n_edges > 1_000_000:
        return pad512(2 * shape.n_edges)
    if shape.kind == "full":
        return pad512(16 * shape.n_edges)
    return 4 * shape.n_edges


# ---------------------------------------------------------------------------
# Input specs
# ---------------------------------------------------------------------------

def gnn_input_specs(shape_name: str, *, needs_positions: bool,
                    needs_triplets: bool, label_kind: str,
                    smoke: bool = False) -> dict:
    """ShapeDtypeStruct stand-ins for one (arch x shape) batch.

    label_kind: "node" (int class per node), "graph" (float target per graph).
    """
    sh = (GNN_SMOKE_SHAPES if smoke else GNN_SHAPES)[shape_name]
    S = jax.ShapeDtypeStruct
    if sh.kind == "full":
        n_pad, e_pad = pad512(sh.n_nodes), pad512(sh.n_edges)
        specs = {
            "node_feat": S((n_pad, sh.d_feat), F32),
            "edge_src": S((e_pad,), I32),
            "edge_dst": S((e_pad,), I32),
        }
        specs["labels"] = (S((n_pad,), I32) if label_kind == "node"
                           else S((1,), F32))
        if needs_positions:
            specs["positions"] = S((n_pad, 3), F32)
        if needs_triplets:
            t = triplet_cap(shape_name, sh)
            specs["t_kj"] = S((t,), I32)
            specs["t_ji"] = S((t,), I32)
        return specs
    # blocks / molecule: leading batch dim.
    b, n, e = sh.batch, sh.n_nodes, sh.n_edges
    specs = {
        "node_feat": S((b, n, sh.d_feat), F32),
        "edge_src": S((b, e), I32),
        "edge_dst": S((b, e), I32),
    }
    specs["labels"] = {"node": S((b, n), I32),
                       "graph": S((b,), F32),
                       "graph_class": S((b,), I32)}[label_kind]
    if needs_positions:
        specs["positions"] = S((b, n, 3), F32)
    if needs_triplets:
        t = triplet_cap(shape_name, sh)
        specs["t_kj"] = S((b, t), I32)
        specs["t_ji"] = S((b, t), I32)
    return specs


def gnn_batch_pspecs(shape_name: str, mesh: Mesh, specs: dict) -> dict:
    """PartitionSpecs matching gnn_input_specs: full graphs shard dim 0 over
    every mesh axis; batched kinds shard the leading dim over the dp axes."""
    sh = GNN_SHAPES.get(shape_name) or GNN_SMOKE_SHAPES[shape_name]
    if sh.kind == "full":
        allax = tuple(mesh.axis_names)
        out = {}
        for k, s in specs.items():
            if k == "labels" and s.shape == (1,):
                out[k] = P(None)
            else:
                out[k] = P(*((allax,) + (None,) * (len(s.shape) - 1)))
        return out
    dp = dp_axes(mesh)
    return {k: P(*((dp,) + (None,) * (len(s.shape) - 1)))
            for k, s in specs.items()}


# ---------------------------------------------------------------------------
# Step builder
# ---------------------------------------------------------------------------

def _opt_specs(param_specs_tree) -> AdamWState:
    f32 = lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32)
    return AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32),
                      mu=jax.tree.map(f32, param_specs_tree),
                      nu=jax.tree.map(f32, param_specs_tree))


def build_gnn_step(
    *,
    shape_name: str,
    mesh: Mesh,
    param_specs: dict,
    loss_of_batch: Callable,     # (params, batch_dict) -> scalar loss
    input_specs: dict,
    opt_cfg: AdamWConfig = AdamWConfig(),
):
    """Returns (train_step, arg_specs, in_shardings) for jit(...).lower().

    GNN params are small relative to activations — replicated everywhere;
    gradients are implicitly all-reduced by GSPMD over the sharded batch.
    """
    o_specs = _opt_specs(param_specs)
    rep = lambda tree: jax.tree.map(
        lambda _: NamedSharding(mesh, P()), tree,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    b_pspecs = gnn_batch_pspecs(shape_name, mesh, input_specs)
    b_shard = {k: NamedSharding(mesh, p) for k, p in b_pspecs.items()}

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: loss_of_batch(p, batch))(params)
        params, opt_state, _ = adamw_update(opt_cfg, params, grads, opt_state)
        return params, opt_state, loss

    args = (param_specs, o_specs, input_specs)
    shardings = (rep(param_specs), rep(o_specs), b_shard)
    return train_step, args, shardings


@dataclasses.dataclass(frozen=True)
class GNNArch:
    """One assigned GNN architecture: configs + batch semantics per shape."""

    arch_id: str
    needs_positions: bool
    needs_triplets: bool
    label_kind: str                               # "node" | "graph" | "graph_class"
    make_config: Callable[[GNNShape, bool], object]   # (shape, smoke) -> cfg
    make_loss: Callable[[object, GNNShape, str], Callable]  # -> loss(params, batch)
    make_params: Callable[[object, jax.Array], dict]
    make_param_specs: Callable[[object], dict]
    shapes: Tuple[str, ...] = tuple(GNN_SHAPES)
    family: str = "gnn"
    skip_notes: Dict[str, str] = dataclasses.field(default_factory=dict)
    # Per-shape-kind override, e.g. GIN classifies graphs on `molecule`.
    label_kind_overrides: Dict[str, str] = dataclasses.field(
        default_factory=dict)

    def label_kind_for(self, shape: str) -> str:
        sh = GNN_SHAPES.get(shape) or GNN_SMOKE_SHAPES[shape]
        return self.label_kind_overrides.get(sh.kind, self.label_kind)

    def input_specs(self, shape: str, smoke: bool = False) -> dict:
        return gnn_input_specs(
            shape, needs_positions=self.needs_positions,
            needs_triplets=self.needs_triplets,
            label_kind=self.label_kind_for(shape), smoke=smoke)

    def build_step(self, shape: str, mesh: Mesh, smoke: bool = False,
                   variant: Tuple[str, ...] = ()):
        """variant "halo": the Louvain-partitioned halo-exchange layout
        (full-graph shapes of gin-tu / equiformer-v2) — see core/gnn_halo."""
        sh = (GNN_SMOKE_SHAPES if smoke else GNN_SHAPES)[shape]
        cfg = self.make_config(sh, smoke)
        if ("halo" in variant and sh.kind == "full"
                and self.arch_id in ("gin-tu", "equiformer-v2")):
            from repro.core.gnn_halo import build_halo_step
            return build_halo_step(
                self.arch_id, shape, mesh, n_valid=sh.n_nodes, cfg=cfg,
                param_specs=self.make_param_specs(cfg),
                m_truncate="no_mtrunc" not in variant,
                bf16_msgs="bf16_msgs" in variant,
                needs_positions=self.needs_positions)
        loss = self.make_loss(cfg, sh, shape)
        return build_gnn_step(
            shape_name=shape, mesh=mesh,
            param_specs=self.make_param_specs(cfg),
            loss_of_batch=loss,
            input_specs=self.input_specs(shape, smoke=smoke))

    def init_params(self, shape: str, key, smoke: bool = False) -> dict:
        sh = (GNN_SMOKE_SHAPES if smoke else GNN_SHAPES)[shape]
        return self.make_params(self.make_config(sh, smoke), key)

    def make_batch(self, shape: str, key, smoke: bool = False) -> dict:
        """Random concrete batch matching input_specs (for smoke tests)."""
        specs = self.input_specs(shape, smoke=smoke)
        sh = (GNN_SMOKE_SHAPES if smoke else GNN_SHAPES)[shape]
        rng = np.random.default_rng(int(jax.random.randint(key, (), 0, 2**31 - 1)))
        out = {}
        for k, s in specs.items():
            if k in ("edge_src", "edge_dst"):
                out[k] = jnp.asarray(
                    rng.integers(0, sh.n_nodes, s.shape), I32)
            elif k in ("t_kj", "t_ji"):
                out[k] = jnp.asarray(rng.integers(0, sh.n_edges, s.shape), I32)
            elif k == "labels":
                if s.dtype == I32:
                    out[k] = jnp.asarray(
                        rng.integers(0, sh.n_classes, s.shape), I32)
                else:
                    out[k] = jnp.asarray(rng.standard_normal(s.shape), F32)
            else:
                out[k] = jnp.asarray(rng.standard_normal(s.shape), F32)
        return out
