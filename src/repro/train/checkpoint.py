"""Checkpoint/restore for arbitrary pytrees (npz payload + msgpack treedef).

Fault-tolerance contract (designed for 1000+-node operation, exercised
single-host here):

  - atomic writes: payload lands in ``<dir>/tmp.<uuid>`` then is renamed, so
    a preempted writer never corrupts the latest checkpoint;
  - every checkpoint carries a content checksum, validated on restore;
  - ``latest_step`` scans for the newest *complete* checkpoint, skipping any
    partial/corrupt ones (crash-during-save recovery);
  - rolling retention (keep_n) bounds disk usage;
  - on a real cluster each host writes only the shards it owns (addressable
    devices) — here the process owns everything, the code path is the same.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import uuid
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Tuple[list, Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save_checkpoint(ckpt_dir: str, step: int, tree, *, keep_n: int = 3) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    leaves, treedef = _flatten(tree)
    arrays = {f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)}

    tmp = os.path.join(ckpt_dir, f"tmp.{uuid.uuid4().hex}")
    os.makedirs(tmp)
    payload = os.path.join(tmp, "arrays.npz")
    np.savez(payload, **arrays)
    digest = hashlib.sha256(open(payload, "rb").read()).hexdigest()
    meta = {"step": int(step), "treedef": str(treedef),
            "n_leaves": len(leaves), "sha256": digest}
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    final = os.path.join(ckpt_dir, f"step_{int(step):010d}")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)

    # Rolling retention.
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep_n]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:010d}"),
                      ignore_errors=True)
    return final


def all_steps(ckpt_dir: str) -> list:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and os.path.exists(
                os.path.join(ckpt_dir, name, "meta.json")):
            out.append(int(name.split("_")[1]))
    return out


def latest_step(ckpt_dir: str) -> Optional[int]:
    """Newest checkpoint that passes integrity validation."""
    for s in sorted(all_steps(ckpt_dir), reverse=True):
        path = os.path.join(ckpt_dir, f"step_{s:010d}")
        try:
            meta = json.load(open(os.path.join(path, "meta.json")))
            payload = os.path.join(path, "arrays.npz")
            digest = hashlib.sha256(open(payload, "rb").read()).hexdigest()
            if digest == meta["sha256"]:
                return s
        except Exception:
            continue
    return None


def restore_checkpoint(ckpt_dir: str, step: int, like_tree):
    """Restore into the structure of ``like_tree`` (shapes must match)."""
    path = os.path.join(ckpt_dir, f"step_{int(step):010d}")
    meta = json.load(open(os.path.join(path, "meta.json")))
    payload = os.path.join(path, "arrays.npz")
    digest = hashlib.sha256(open(payload, "rb").read()).hexdigest()
    if digest != meta["sha256"]:
        raise IOError(f"checkpoint {path} failed checksum validation")
    data = np.load(payload)
    leaves, treedef = _flatten(like_tree)
    assert meta["n_leaves"] == len(leaves), "tree structure changed"
    new_leaves = [data[f"leaf_{i}"] for i in range(len(leaves))]
    for old, new in zip(leaves, new_leaves):
        if tuple(np.shape(old)) != tuple(new.shape):
            raise ValueError(f"shape mismatch {np.shape(old)} vs {new.shape}")
    return jax.tree.unflatten(treedef, new_leaves)
