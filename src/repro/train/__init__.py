from repro.train.checkpoint import (latest_step, restore_checkpoint,
                                    save_checkpoint)
from repro.train.loop import ElasticController, TrainLoopConfig, train

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "TrainLoopConfig", "ElasticController", "train"]
