"""Fault-tolerant training loop with checkpoint/restart, straggler detection
and elastic-rescale hooks.

The loop is deliberately framework-grade rather than example-grade:

  - **checkpoint/restart**: resumes from the newest valid checkpoint (see
    checkpoint.py for atomicity/integrity); params AND optimizer state AND
    data-stream position are restored, so a preempted run continues exactly.
  - **straggler mitigation**: per-step wall times feed an EWMA; steps slower
    than ``straggler_factor`` x the EWMA are logged and counted.  On a real
    multi-host fleet this signal triggers hot-spare swap-in; the hook is
    ``on_straggler`` so deployments can attach their scheduler.
  - **elastic rescale hook**: ``ElasticController.desired_mesh()`` is polled
    every ``elastic_poll_steps``; when the advertised device count changes,
    the loop checkpoints, rebuilds the mesh/sharded step, and continues —
    single-host this is a no-op but the control flow is exercised in tests.
  - **gradient compression** (optim/compression.py) with error feedback is
    applied between grad and optimizer when enabled.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Iterator, Optional

import jax
import numpy as np

from repro.optim import (AdamWConfig, CompressionConfig, adamw_init,
                         adamw_update, compress_grads, compression_init)
from repro.train import checkpoint as ckpt


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int = 200
    log_every: int = 20
    ckpt_every: int = 100
    ckpt_dir: Optional[str] = None
    keep_n: int = 3
    straggler_factor: float = 3.0
    elastic_poll_steps: int = 50


class ElasticController:
    """Polled by the loop; override ``desired_devices`` for real elasticity."""

    def desired_devices(self) -> int:
        return jax.device_count()


def train(
    loss_fn: Callable,                       # (params, batch) -> scalar loss
    params,
    batches: Iterator[dict],
    opt_cfg: AdamWConfig,
    loop_cfg: TrainLoopConfig,
    *,
    comp_cfg: CompressionConfig = CompressionConfig(),
    elastic: Optional[ElasticController] = None,
    on_straggler: Optional[Callable[[int, float], None]] = None,
    make_step: Optional[Callable] = None,    # custom jit'd step factory
):
    """Returns (params, metrics_history).  Resumes from loop_cfg.ckpt_dir."""
    opt_state = adamw_init(params)
    residual = compression_init(params) if comp_cfg.scheme != "none" else None
    start_step = 0

    if loop_cfg.ckpt_dir:
        latest = ckpt.latest_step(loop_cfg.ckpt_dir)
        if latest is not None:
            state = ckpt.restore_checkpoint(
                loop_cfg.ckpt_dir, latest,
                {"params": params, "opt": opt_state, "step": 0})
            params, opt_state = state["params"], state["opt"]
            start_step = int(state["step"])

    if make_step is None:
        @jax.jit
        def step_fn(params, opt_state, residual, batch):
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            if residual is not None:
                grads, residual = compress_grads(comp_cfg, grads, residual)
            params, opt_state, metrics = adamw_update(
                opt_cfg, params, grads, opt_state)
            return params, opt_state, residual, loss, metrics
    else:
        step_fn = make_step(loss_fn, opt_cfg, comp_cfg)

    history = []
    ewma = None
    n_stragglers = 0
    # Fast-forward the data stream on resume (deterministic iterators).
    for _ in range(start_step):
        next(batches)

    for step in range(start_step, loop_cfg.total_steps):
        batch = next(batches)
        t0 = time.perf_counter()
        params, opt_state, residual, loss, metrics = step_fn(
            params, opt_state, residual, batch)
        loss = float(loss)
        dt = time.perf_counter() - t0

        if ewma is None:
            ewma = dt
        elif dt > loop_cfg.straggler_factor * ewma and step > start_step + 3:
            n_stragglers += 1
            if on_straggler:
                on_straggler(step, dt)
        else:
            ewma = 0.9 * ewma + 0.1 * dt

        if step % loop_cfg.log_every == 0 or step == loop_cfg.total_steps - 1:
            history.append({"step": step, "loss": loss, "sec": dt,
                            **{k: float(v) for k, v in metrics.items()}})

        if loop_cfg.ckpt_dir and (step + 1) % loop_cfg.ckpt_every == 0:
            ckpt.save_checkpoint(
                loop_cfg.ckpt_dir, step + 1,
                {"params": params, "opt": opt_state, "step": step + 1},
                keep_n=loop_cfg.keep_n)

        if (elastic is not None
                and (step + 1) % loop_cfg.elastic_poll_steps == 0):
            want = elastic.desired_devices()
            if want != jax.device_count() and loop_cfg.ckpt_dir:
                # Checkpoint and signal the launcher to re-shard at the new
                # scale; single-host runs never take this branch.
                ckpt.save_checkpoint(
                    loop_cfg.ckpt_dir, step + 1,
                    {"params": params, "opt": opt_state, "step": step + 1},
                    keep_n=loop_cfg.keep_n)
                history.append({"step": step, "event": "elastic_rescale",
                                "devices": want})

    return params, {"history": history, "n_stragglers": n_stragglers}
