"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

Prefill/train: the latent c_kv is up-projected to per-head K/V and fed to the
shared blockwise attention.  Decode: the *absorbed* form — W_UK folds into the
query and W_UV into the output — so the per-token cost is O(S * kv_lora) and
the cache stores only (kv_lora + rope_dim) floats per token (576 for V2), the
paper's headline memory saving.
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, blockwise_attention, rms_norm


class MLAConfig(NamedTuple):
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


def mla_qkv(p, cfg: MLAConfig, n_heads: int, x, positions, rope_theta):
    """Project to (q_nope, q_rope, c_kv, k_rope).  x: (B, S, d)."""
    b, s, _ = x.shape
    h, dn, dr = n_heads, cfg.qk_nope_head_dim, cfg.qk_rope_head_dim

    cq = rms_norm(x @ p["w_dq"], p["q_ln"])                    # (B, S, q_lora)
    q = (cq @ p["w_uq"]).reshape(b, s, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, rope_theta)

    c_kv = rms_norm(x @ p["w_dkv"], p["kv_ln"])                # (B, S, kv_lora)
    k_rope = apply_rope((x @ p["w_kr"])[:, :, None, :], positions, rope_theta)
    return q_nope, q_rope, c_kv, k_rope                        # k_rope: (B,S,1,dr)


def mla_attention_full(p, cfg: MLAConfig, n_heads: int, x, positions,
                       rope_theta: float, *, q_block: int = 512,
                       kv_block: int = 512) -> jax.Array:
    """Train/prefill MLA: materialize per-head K/V from the latent."""
    b, s, _ = x.shape
    h, dn, dr, dv = (n_heads, cfg.qk_nope_head_dim, cfg.qk_rope_head_dim,
                     cfg.v_head_dim)
    q_nope, q_rope, c_kv, k_rope = mla_qkv(p, cfg, h, x, positions, rope_theta)

    k_nope = (c_kv @ p["w_uk"]).reshape(b, s, h, dn)
    v = (c_kv @ p["w_uv"]).reshape(b, s, h, dv)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (b, s, h, dr))], -1)
    q = jnp.concatenate([q_nope, q_rope], -1)
    scale = 1.0 / math.sqrt(dn + dr)
    out = blockwise_attention(q, k, v, causal=True, q_block=q_block,
                              kv_block=kv_block, softmax_scale=scale)
    return out.reshape(b, s, h * dv) @ p["w_o"]


def mla_decode(p, cfg: MLAConfig, n_heads: int, x, position,
               c_cache, kr_cache, cache_len, rope_theta: float) -> jax.Array:
    """Absorbed-latent decode.  x: (B, 1, d); caches: (B, S, kv_lora)/(B, S, dr).

    score_h(t) = (W_UK_h^T q_nope_h) . c_t + q_rope_h . k_rope_t
    out_h      = W_UV_h^T (sum_t p_t c_t)
    """
    b = x.shape[0]
    h, dn, dr, dv = (n_heads, cfg.qk_nope_head_dim, cfg.qk_rope_head_dim,
                     cfg.v_head_dim)
    r = cfg.kv_lora_rank
    q_nope, q_rope, _, _ = mla_qkv(p, cfg, h, x, position, rope_theta)

    w_uk = p["w_uk"].reshape(r, h, dn)
    q_eff = jnp.einsum("bohd,rhd->bhr", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))               # (B, H, r)
    s_lat = jnp.einsum("bhr,bsr->bhs", q_eff, c_cache.astype(jnp.float32))
    s_rope = jnp.einsum("bohd,bsd->bhs", q_rope.astype(jnp.float32),
                        kr_cache.astype(jnp.float32))
    logits = (s_lat + s_rope) / math.sqrt(dn + dr)
    pos = jnp.arange(c_cache.shape[1])
    mask = pos[None, None, :] < jnp.asarray(cache_len).reshape(-1, 1, 1)
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    ctx = jnp.einsum("bhs,bsr->bhr", probs, c_cache.astype(jnp.float32))
    w_uv = p["w_uv"].reshape(r, h, dv)
    out = jnp.einsum("bhr,rhd->bhd", ctx, w_uv.astype(jnp.float32))
    return (out.reshape(b, 1, h * dv) @ p["w_o"]).astype(x.dtype)


def mla_init(key, cfg: MLAConfig, d_model: int, n_heads: int, dtype=jnp.float32):
    h, dn, dr, dv = (n_heads, cfg.qk_nope_head_dim, cfg.qk_rope_head_dim,
                     cfg.v_head_dim)
    ks = jax.random.split(key, 8)
    init = lambda k, *s: (jax.random.normal(k, s, dtype)
                          / math.sqrt(max(s[0], 1)))
    return {
        "w_dq": init(ks[0], d_model, cfg.q_lora_rank),
        "q_ln": jnp.ones((cfg.q_lora_rank,), dtype),
        "w_uq": init(ks[1], cfg.q_lora_rank, h * (dn + dr)),
        "w_dkv": init(ks[2], d_model, cfg.kv_lora_rank),
        "kv_ln": jnp.ones((cfg.kv_lora_rank,), dtype),
        "w_kr": init(ks[3], d_model, dr),
        "w_uk": init(ks[4], cfg.kv_lora_rank, h * dn),
        "w_uv": init(ks[5], cfg.kv_lora_rank, h * dv),
        "w_o": init(ks[6], h * dv, d_model),
    }
