"""Shared transformer building blocks (pure-JAX, functional, dry-run friendly).

Everything takes explicit param pytrees; initialization mirrors the shapes the
dry-run lowers with ShapeDtypeStructs.  Attention is blockwise (online softmax
over KV chunks, FlashAttention-style in XLA):

  - full-causal layers unroll a small number of query blocks, each scanning
    exactly the KV blocks at/below its diagonal — compiled FLOPs ~ S^2/2, not
    S^2, so cost_analysis() reflects the real causal work;
  - sliding-window layers visit only the KV blocks intersecting their window
    (O(S * W) FLOPs);
  - GQA never materializes repeated KV heads (grouped einsums).
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale


def rope_freqs(d_head: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, Dh); positions: (B, S) or (S,) int32."""
    d_head = x.shape[-1]
    freqs = rope_freqs(d_head, theta)                       # (Dh/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, Dh/2)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def _tile(q5, k_blk, v_blk, mask, scale):
    """One attention tile.  q5: (B, Qb, Hkv, G, Dh); k/v: (B, Kb, Hkv, Dh).

    Returns running-softmax pieces (m, l, o) with
    m, l: (B, Hkv, G, Qb, 1); o: (B, Qb, Hkv, G, Dh) — all f32.
    """
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q5, k_blk,
                   preferred_element_type=jnp.float32)
    s = s * scale + jnp.where(mask, 0.0, -1e30)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v_blk.dtype), v_blk,
                   preferred_element_type=jnp.float32)
    return m, l, o.astype(jnp.float32)


def _merge(carry, m_i, l_i, o_i):
    m_run, l_run, o_run = carry
    m_new = jnp.maximum(m_run, m_i)
    alpha = jnp.exp(m_run - m_new)
    beta = jnp.exp(m_i - m_new)
    l_new = l_run * alpha + l_i * beta
    # (B,H,G,Q,1) -> (B,Q,H,G,1) to scale o.
    tr = lambda t: jnp.transpose(t, (0, 3, 1, 2, 4))
    o_new = o_run * tr(alpha) + o_i * tr(beta)
    return m_new, l_new, o_new


def blockwise_attention(
    q: jax.Array,                  # (B, Sq, Hq, Dh)
    k: jax.Array,                  # (B, Sk, Hkv, Dh)
    v: jax.Array,                  # (B, Sk, Hkv, Dh)
    *,
    causal: bool = True,
    window: Optional[int] = None,  # sliding-window size (None = full)
    q_offset: int = 0,             # static absolute position of q[0]
    q_block: int = 512,
    kv_block: int = 512,
    softmax_scale: Optional[float] = None,
) -> jax.Array:
    b, sq, hq, dh = q.shape
    _, sk, hkv, _ = k.shape
    dv = v.shape[-1]
    g = hq // hkv
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(dh)

    q_block = min(q_block, sq)
    kv_block = min(kv_block, sk)
    windowed = window is not None and window < sk
    if not windowed:
        # The causal-exact path unrolls query blocks in Python — cap at 8
        # blocks so the HLO stays small at long sequence lengths.
        q_block = max(q_block, -(-sq // 8))
    assert sq % q_block == 0 and sk % kv_block == 0, (sq, q_block, sk, kv_block)
    nq = sq // q_block
    nk = sk // kv_block
    q5 = q.reshape(b, sq, hkv, g, dh)

    def run_q_block(qi_static: int):
        """Causal-exact path: static KV span per query block (unrolled)."""
        q_i = q5[:, qi_static * q_block:(qi_static + 1) * q_block]
        q_pos = q_offset + qi_static * q_block + jnp.arange(q_block)
        hi = nk if not causal else min(
            nk, -(-(q_offset + (qi_static + 1) * q_block) // kv_block))

        def kv_step(carry, kb):
            k_i = jax.lax.dynamic_slice_in_dim(k, kb * kv_block, kv_block, 1)
            v_i = jax.lax.dynamic_slice_in_dim(v, kb * kv_block, kv_block, 1)
            kv_pos = kb * kv_block + jnp.arange(kv_block)
            mask = jnp.ones((q_block, kv_block), bool)
            if causal:
                mask &= q_pos[:, None] >= kv_pos[None, :]
            m_i, l_i, o_i = _tile(q_i, k_i, v_i, mask[None, None, None], scale)
            return _merge(carry, m_i, l_i, o_i), None

        carry0 = (
            jnp.full((b, hkv, g, q_block, 1), -1e30, jnp.float32),
            jnp.zeros((b, hkv, g, q_block, 1), jnp.float32),
            jnp.zeros((b, q_block, hkv, g, dv), jnp.float32),
        )
        (m_f, l_f, o_f), _ = jax.lax.scan(kv_step, carry0, jnp.arange(hi))
        l_t = jnp.transpose(l_f, (0, 3, 1, 2, 4))
        return o_f / jnp.maximum(l_t, 1e-30)

    def run_q_block_windowed(qi):
        """Windowed path: fixed span of KV blocks around the diagonal."""
        span = min(nk, -(-(window + q_block) // kv_block) + 1)
        q_i = jax.lax.dynamic_slice_in_dim(q5, qi * q_block, q_block, 1)
        q_pos = q_offset + qi * q_block + jnp.arange(q_block)
        lo_pos = jnp.maximum(q_offset + qi * q_block - window + 1, 0)
        kv_lo = jnp.clip(lo_pos // kv_block, 0, nk - span)

        def kv_step(carry, ki):
            kb = kv_lo + ki
            k_i = jax.lax.dynamic_slice_in_dim(k, kb * kv_block, kv_block, 1)
            v_i = jax.lax.dynamic_slice_in_dim(v, kb * kv_block, kv_block, 1)
            kv_pos = kb * kv_block + jnp.arange(kv_block)
            mask = q_pos[:, None] - kv_pos[None, :] < window
            if causal:
                mask &= q_pos[:, None] >= kv_pos[None, :]
            m_i, l_i, o_i = _tile(q_i, k_i, v_i, mask[None, None, None], scale)
            return _merge(carry, m_i, l_i, o_i), None

        carry0 = (
            jnp.full((b, hkv, g, q_block, 1), -1e30, jnp.float32),
            jnp.zeros((b, hkv, g, q_block, 1), jnp.float32),
            jnp.zeros((b, q_block, hkv, g, dv), jnp.float32),
        )
        (m_f, l_f, o_f), _ = jax.lax.scan(kv_step, carry0, jnp.arange(span))
        l_t = jnp.transpose(l_f, (0, 3, 1, 2, 4))
        return o_f / jnp.maximum(l_t, 1e-30)

    if windowed:
        outs = jax.lax.map(run_q_block_windowed, jnp.arange(nq))   # (nq,B,qb,...)
        out = jnp.moveaxis(outs, 0, 1).reshape(b, sq, hkv, g, dv)
    else:
        parts = [run_q_block(qi) for qi in range(nq)]
        out = jnp.concatenate(parts, axis=1) if nq > 1 else parts[0]
    return out.reshape(b, sq, hq, dv).astype(q.dtype)


def decode_attention(
    q: jax.Array,            # (B, 1, Hq, Dh)
    k_cache: jax.Array,      # (B, S, Hkv, Dh) — bf16/f32 or int8 (quantized)
    v_cache: jax.Array,      # (B, S, Hkv, Dh)
    cache_len: jax.Array,    # (B,) or scalar — valid prefix length
    *,
    window: Optional[int] = None,
    softmax_scale: Optional[float] = None,
    k_scale: Optional[jax.Array] = None,   # (B, S, Hkv) int8 dequant scales
    v_scale: Optional[jax.Array] = None,
) -> jax.Array:
    """Single-token attention against a (padded) KV cache — O(S) per token.

    With k_scale/v_scale the caches hold int8 values; dequantization happens
    in-register (the per-row scale folds into the logits / the probabilities),
    so HBM reads stay at 1 byte/element."""
    b, s, hkv, dh = k_cache.shape
    hq = q.shape[2]
    g = hq // hkv
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(dh)
    qf = q[:, 0].astype(jnp.float32).reshape(b, hkv, g, dh)
    logits = jnp.einsum("bhgd,bshd->bhgs", qf, k_cache.astype(jnp.float32))
    if k_scale is not None:
        logits *= jnp.moveaxis(k_scale, 1, 2)[:, :, None, :]   # (B,H,1,S)
    logits *= scale
    pos = jnp.arange(s)
    clen = jnp.asarray(cache_len).reshape(-1, 1, 1, 1)
    mask = pos[None, None, None, :] < clen
    if window is not None and window < s:
        mask &= pos[None, None, None, :] >= clen - window
    logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    if v_scale is not None:
        p = p * jnp.moveaxis(v_scale, 1, 2)[:, :, None, :]     # fold dequant
    out = jnp.einsum("bhgs,bshd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, hq, dh).astype(q.dtype)


def swiglu_ffn(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
               w_down: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down


def cross_entropy_loss(logits: jax.Array, labels: jax.Array,
                       ignore_id: int = -1) -> jax.Array:
    """Mean token CE, numerically stable, ignoring ``ignore_id`` positions."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    mask = labels != ignore_id
    nll = (lse - ll) * mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1)
