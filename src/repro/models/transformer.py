"""Configurable decoder-only LM covering the five assigned architectures.

gemma3-12b (5:1 local:global GQA), qwen2-1.5b (GQA + QKV bias),
internlm2-20b (GQA), mixtral-8x22b (GQA + SWA + 8-expert top-2 MoE),
deepseek-v2-236b (MLA + 160-expert top-6 + 2 shared MoE).

Layers run under `lax.scan` over *pattern repeats*: a config declares a layer
pattern (e.g. gemma3: 5 sliding + 1 global) and the stack is that pattern
repeated; each pattern slot owns stacked params of shape (n_repeats, ...).
This keeps HLO size ~ O(pattern length), not O(n_layers), while letting layer
kinds differ.

Entry points:
  init_params(cfg, key)        — real weights for smoke-scale configs.
  param_specs(cfg)             — ShapeDtypeStructs for AOT dry-runs.
  forward(cfg, params, tokens) — logits.
  loss_fn / make_train_step    — training.
  init_cache / decode_step     — single-token serving against a KV cache.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import mla as mla_mod
from repro.models.layers import (apply_rope, blockwise_attention,
                                 cross_entropy_loss, decode_attention,
                                 rms_norm, swiglu_ffn)
from repro.models.moe import MoEParams, moe_ffn


@dataclasses.dataclass(frozen=True)
class MoESpec:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    d_ff_shared: int = 0
    capacity_factor: float = 1.25
    softmax_after_topk: bool = False  # Mixtral-style router


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    # Layer pattern: tuple of window sizes, None = full attention.  The stack
    # is the pattern repeated n_layers // len(pattern) times.
    layer_windows: Tuple[Optional[int], ...] = (None,)
    moe: Optional[MoESpec] = None
    mla: Optional[mla_mod.MLAConfig] = None
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    remat: bool = True
    # "int8": GQA KV cache stored quantized (per-(pos, head) absmax scale),
    # dequantized in-register during decode — halves cache HBM traffic and
    # residency vs bf16.  MLA latent caches stay bf16 (already compressed).
    kv_cache_dtype: str = "bf16"
    # scan-over-repeats keeps HLO O(pattern) — the production default.  The
    # dry-run's cost accounting unrolls (XLA cost_analysis counts while-loop
    # bodies once, so per-layer costs must appear inline to be counted).
    scan_layers: bool = True

    @property
    def n_repeats(self) -> int:
        assert self.n_layers % len(self.layer_windows) == 0
        return self.n_layers // len(self.layer_windows)

    @property
    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    def param_count(self) -> int:
        """Approximate parameter count (for MODEL_FLOPS accounting)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        if self.mla is not None:
            m = self.mla
            attn = (d * m.q_lora_rank
                    + m.q_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
                    + d * m.kv_lora_rank + d * m.qk_rope_head_dim
                    + m.kv_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
                    + self.n_heads * m.v_head_dim * d)
        else:
            attn = d * self.n_heads * self.d_head + 2 * d * self.n_kv_heads * self.d_head \
                + self.n_heads * self.d_head * d
        if self.moe is not None:
            ffn = (d * self.moe.n_experts
                   + 3 * d * self.moe.d_ff_expert * self.moe.n_experts
                   + 3 * d * self.moe.d_ff_shared * (1 if self.moe.n_shared else 0))
        else:
            ffn = 3 * d * f
        emb = v * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * (attn + ffn) + emb

    def active_param_count(self) -> int:
        """Active (per-token) params — MoE counts only routed top-k + shared."""
        if self.moe is None:
            return self.param_count()
        d, v = self.d_model, self.vocab
        if self.mla is not None:
            m = self.mla
            attn = (d * m.q_lora_rank
                    + m.q_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
                    + d * m.kv_lora_rank + d * m.qk_rope_head_dim
                    + m.kv_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
                    + self.n_heads * m.v_head_dim * d)
        else:
            attn = d * self.n_heads * self.d_head + 2 * d * self.n_kv_heads * self.d_head \
                + self.n_heads * self.d_head * d
        ffn = (3 * d * self.moe.d_ff_expert * self.moe.top_k
               + 3 * d * self.moe.d_ff_shared * (1 if self.moe.n_shared else 0)
               + d * self.moe.n_experts)
        emb = v * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * (attn + ffn) + emb


# ---------------------------------------------------------------------------
# Parameter construction
# ---------------------------------------------------------------------------

def _layer_param_shapes(cfg: TransformerConfig) -> dict:
    d, h, hk, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    shapes = {"ln1": (d,), "ln2": (d,)}
    if cfg.mla is not None:
        m = cfg.mla
        shapes.update({
            "w_dq": (d, m.q_lora_rank), "q_ln": (m.q_lora_rank,),
            "w_uq": (m.q_lora_rank, h * (m.qk_nope_head_dim + m.qk_rope_head_dim)),
            "w_dkv": (d, m.kv_lora_rank), "kv_ln": (m.kv_lora_rank,),
            "w_kr": (d, m.qk_rope_head_dim),
            "w_uk": (m.kv_lora_rank, h * m.qk_nope_head_dim),
            "w_uv": (m.kv_lora_rank, h * m.v_head_dim),
            "w_o": (h * m.v_head_dim, d),
        })
    else:
        shapes.update({
            "wq": (d, h * dh), "wk": (d, hk * dh), "wv": (d, hk * dh),
            "wo": (h * dh, d),
        })
        if cfg.qkv_bias:
            shapes.update({"bq": (h * dh,), "bk": (hk * dh,), "bv": (hk * dh,)})
    if cfg.moe is not None:
        mo = cfg.moe
        shapes.update({
            "router": (d, mo.n_experts),
            "w_gate_e": (mo.n_experts, d, mo.d_ff_expert),
            "w_up_e": (mo.n_experts, d, mo.d_ff_expert),
            "w_down_e": (mo.n_experts, mo.d_ff_expert, d),
        })
        if mo.n_shared:
            shapes.update({
                "w_gate_s": (d, mo.d_ff_shared), "w_up_s": (d, mo.d_ff_shared),
                "w_down_s": (mo.d_ff_shared, d),
            })
    else:
        shapes.update({"w_gate": (d, cfg.d_ff), "w_up": (d, cfg.d_ff),
                       "w_down": (cfg.d_ff, d)})
    return shapes


def param_shapes(cfg: TransformerConfig) -> dict:
    per_layer = _layer_param_shapes(cfg)
    n_slots = len(cfg.layer_windows)
    out = {
        "embed": (cfg.vocab, cfg.d_model),
        "final_ln": (cfg.d_model,),
        "layers": [
            {k: (cfg.n_repeats,) + v for k, v in per_layer.items()}
            for _ in range(n_slots)
        ],
    }
    if not cfg.tie_embeddings:
        out["lm_head"] = (cfg.d_model, cfg.vocab)
    return out


def param_specs(cfg: TransformerConfig) -> dict:
    dt = cfg.activation_dtype
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s, dt),
                        param_shapes(cfg),
                        is_leaf=lambda x: isinstance(x, tuple))


def init_params(cfg: TransformerConfig, key: jax.Array) -> dict:
    shapes = param_shapes(cfg)
    paths_leaves = jax.tree_util.tree_flatten_with_path(
        shapes, is_leaf=lambda x: isinstance(x, tuple))
    leaves = paths_leaves[0]
    treedef = paths_leaves[1]
    keys = jax.random.split(key, len(leaves))
    dt = cfg.activation_dtype

    def make(k, path, shape):
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        if "ln" in name or name == "final_ln":          # norm scales -> ones
            return jnp.ones(shape, dt)
        if name.startswith("b"):                        # biases -> zeros
            return jnp.zeros(shape, dt)
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
        return (jax.random.normal(k, shape, jnp.float32)
                / math.sqrt(fan_in)).astype(dt)

    inits = [make(k, path, s) for k, (path, s) in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, inits)


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _attention_block(cfg: TransformerConfig, p: dict, x: jax.Array,
                     positions, window: Optional[int]) -> jax.Array:
    b, s, d = x.shape
    h, hk, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    if cfg.mla is not None:
        return mla_mod.mla_attention_full(
            p, cfg.mla, h, x, positions, cfg.rope_theta)
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = apply_rope(q.reshape(b, s, h, dh), positions, cfg.rope_theta)
    k = apply_rope(k.reshape(b, s, hk, dh), positions, cfg.rope_theta)
    v = v.reshape(b, s, hk, dh)
    out = blockwise_attention(q, k, v, causal=True, window=window)
    return out.reshape(b, s, h * dh) @ p["wo"]


def _ffn_block(cfg: TransformerConfig, p: dict, x: jax.Array) -> jax.Array:
    b, s, d = x.shape
    if cfg.moe is None:
        return swiglu_ffn(x, p["w_gate"], p["w_up"], p["w_down"])
    mp = MoEParams(
        router=p["router"], w_gate=p["w_gate_e"], w_up=p["w_up_e"],
        w_down=p["w_down_e"],
        shared_w_gate=p.get("w_gate_s"), shared_w_up=p.get("w_up_s"),
        shared_w_down=p.get("w_down_s"),
    )
    out = moe_ffn(x.reshape(b * s, d), mp, top_k=cfg.moe.top_k,
                  capacity_factor=cfg.moe.capacity_factor,
                  router_softmax_after_topk=cfg.moe.softmax_after_topk)
    return out.reshape(b, s, d)


def _decoder_layer(cfg: TransformerConfig, window, p, x, positions):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    x = x + _attention_block(cfg, p, h, positions, window)
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    x = x + _ffn_block(cfg, p, h)
    return x


def forward(cfg: TransformerConfig, params: dict, tokens: jax.Array,
            return_hidden: bool = False) -> jax.Array:
    """tokens (B, S) -> logits (B, S, V); return_hidden skips the LM head
    (for the sharded-CE loss, which fuses head matmul + softmax per vocab
    shard)."""
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.activation_dtype)
    x = x * math.sqrt(cfg.d_model)
    positions = jnp.arange(s)[None, :]

    def repeat_body(x, slot_params):
        for slot, window in enumerate(cfg.layer_windows):
            p = slot_params[slot]
            fn = functools.partial(_decoder_layer, cfg, window)
            if cfg.remat:
                fn = jax.checkpoint(fn)
            x = fn(p, x, positions)
        return x, None

    if cfg.scan_layers:
        x, _ = jax.lax.scan(repeat_body, x, params["layers"])
    else:
        for r in range(cfg.n_repeats):
            slot_r = jax.tree.map(lambda a: a[r], params["layers"])
            x, _ = repeat_body(x, slot_r)
    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    if return_hidden:
        return x
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return (x @ head.astype(x.dtype)).astype(jnp.float32)


def loss_fn(cfg: TransformerConfig, params, batch) -> jax.Array:
    logits = forward(cfg, params, batch["tokens"])
    return cross_entropy_loss(logits, batch["labels"])


# ---------------------------------------------------------------------------
# Serving: KV cache + single-token decode
# ---------------------------------------------------------------------------

def cache_shapes(cfg: TransformerConfig, batch: int, max_len: int) -> dict:
    n_slots = len(cfg.layer_windows)
    r = cfg.n_repeats
    if cfg.mla is not None:
        m = cfg.mla
        per = {"c_kv": (r, batch, max_len, m.kv_lora_rank),
               "k_rope": (r, batch, max_len, m.qk_rope_head_dim)}
    elif cfg.kv_cache_dtype == "int8":
        per = {"k_q": (r, batch, max_len, cfg.n_kv_heads, cfg.d_head),
               "v_q": (r, batch, max_len, cfg.n_kv_heads, cfg.d_head),
               "k_s": (r, batch, max_len, cfg.n_kv_heads),
               "v_s": (r, batch, max_len, cfg.n_kv_heads)}
    else:
        per = {"k": (r, batch, max_len, cfg.n_kv_heads, cfg.d_head),
               "v": (r, batch, max_len, cfg.n_kv_heads, cfg.d_head)}
    return {"slots": [dict(per) for _ in range(n_slots)]}


def _cache_leaf_dtype(cfg: TransformerConfig, name: str):
    if name in ("k_q", "v_q"):
        return jnp.int8
    if name in ("k_s", "v_s"):
        return jnp.float32
    return cfg.activation_dtype


def _cache_tree_map(cfg, fn, tree):
    return {"slots": [{name: fn(shape, _cache_leaf_dtype(cfg, name))
                       for name, shape in slot.items()}
                      for slot in tree["slots"]]}


def init_cache(cfg: TransformerConfig, batch: int, max_len: int) -> dict:
    return _cache_tree_map(cfg, lambda s, dt: jnp.zeros(s, dt),
                           cache_shapes(cfg, batch, max_len))


def cache_specs(cfg: TransformerConfig, batch: int, max_len: int) -> dict:
    return _cache_tree_map(cfg, jax.ShapeDtypeStruct,
                           cache_shapes(cfg, batch, max_len))


def _quantize_kv(x: jax.Array):
    """(B, 1, H, Dh) -> (int8 values, f32 per-(b, 1, h) absmax scale)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def _decode_layer(cfg, window, p, x, pos, cache_slot, cache_len):
    """x: (B, 1, d); cache_slot: dict of (B, S, ...) arrays for THIS layer."""
    b = x.shape[0]
    h, hk, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    hcur = rms_norm(x, p["ln1"], cfg.norm_eps)
    if cfg.mla is not None:
        m = cfg.mla
        # Write the new latent into the cache, then absorbed-latent attention.
        _, _, c_kv, k_rope = mla_mod.mla_qkv(
            p, m, h, hcur, pos, cfg.rope_theta)
        c_cache = jax.lax.dynamic_update_slice(
            cache_slot["c_kv"], c_kv.astype(cache_slot["c_kv"].dtype),
            (0, cache_len, 0))
        kr_cache = jax.lax.dynamic_update_slice(
            cache_slot["k_rope"], k_rope[:, :, 0].astype(
                cache_slot["k_rope"].dtype), (0, cache_len, 0))
        attn = mla_mod.mla_decode(p, m, h, hcur, pos, c_cache, kr_cache,
                                  cache_len + 1, cfg.rope_theta)
        x = x + attn
        new_cache = {"c_kv": c_cache, "k_rope": kr_cache}
    else:
        q = hcur @ p["wq"]
        kx = hcur @ p["wk"]
        vx = hcur @ p["wv"]
        if cfg.qkv_bias:
            q, kx, vx = q + p["bq"], kx + p["bk"], vx + p["bv"]
        q = apply_rope(q.reshape(b, 1, h, dh), pos, cfg.rope_theta)
        kx = apply_rope(kx.reshape(b, 1, hk, dh), pos, cfg.rope_theta)
        vx = vx.reshape(b, 1, hk, dh)
        if cfg.kv_cache_dtype == "int8":
            kq, ks = _quantize_kv(kx)
            vq, vs = _quantize_kv(vx)
            upd = lambda buf, val, ix: jax.lax.dynamic_update_slice(
                buf, val.astype(buf.dtype), ix)
            k_q = upd(cache_slot["k_q"], kq, (0, cache_len, 0, 0))
            v_q = upd(cache_slot["v_q"], vq, (0, cache_len, 0, 0))
            k_s = upd(cache_slot["k_s"], ks, (0, cache_len, 0))
            v_s = upd(cache_slot["v_s"], vs, (0, cache_len, 0))
            attn = decode_attention(q, k_q, v_q, cache_len + 1,
                                    window=window, k_scale=k_s, v_scale=v_s)
            new_cache = {"k_q": k_q, "v_q": v_q, "k_s": k_s, "v_s": v_s}
        else:
            k_cache = jax.lax.dynamic_update_slice(
                cache_slot["k"], kx.astype(cache_slot["k"].dtype),
                (0, cache_len, 0, 0))
            v_cache = jax.lax.dynamic_update_slice(
                cache_slot["v"], vx.astype(cache_slot["v"].dtype),
                (0, cache_len, 0, 0))
            attn = decode_attention(q, k_cache, v_cache, cache_len + 1,
                                    window=window)
            new_cache = {"k": k_cache, "v": v_cache}
        x = x + attn.reshape(b, 1, h * dh) @ p["wo"]
    hcur = rms_norm(x, p["ln2"], cfg.norm_eps)
    x = x + _ffn_block(cfg, p, hcur)
    return x, new_cache


def decode_step(cfg: TransformerConfig, params, cache, tokens, cache_len):
    """One decode step.  tokens (B, 1) int32; cache_len scalar int32.

    Returns (logits (B, 1, V), new_cache).
    """
    b = tokens.shape[0]
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.activation_dtype)
    x = x * math.sqrt(cfg.d_model)
    pos = cache_len + jnp.zeros((b, 1), jnp.int32)

    def repeat_body(x, scan_in):
        slot_params, slot_caches = scan_in
        new_slots = []
        for slot, window in enumerate(cfg.layer_windows):
            x, nc = _decode_layer(cfg, window, slot_params[slot], x, pos,
                                  slot_caches[slot], cache_len)
            new_slots.append(nc)
        return x, new_slots

    # Scan over repeats; caches are scanned in/out along the repeat dim.
    if cfg.scan_layers:
        x, new_caches = jax.lax.scan(
            repeat_body, x, (params["layers"], cache["slots"]))
    else:
        outs = []
        for r in range(cfg.n_repeats):
            slot_p = jax.tree.map(lambda a: a[r], params["layers"])
            slot_c = jax.tree.map(lambda a: a[r], cache["slots"])
            x, nc = repeat_body(x, (slot_p, slot_c))
            outs.append(nc)
        new_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (x @ head.astype(x.dtype)).astype(jnp.float32)
    return logits, {"slots": new_caches}
