"""Mixture-of-Experts FFN with capacity-based sort dispatch (GShard lineage).

Dispatch avoids one-hot dispatch tensors: assignments are sorted by expert id,
ranked within expert by a cumulative count, dropped beyond capacity, and the
token features are gathered into a dense (E, capacity, d) buffer for a batched
expert matmul.  Compiled FLOPs ~ top_k * tokens * expert_ffn — the real MoE
cost, not the dense-all-experts upper bound.

Sharding: the (E, cap, d) buffer and the expert weights shard over the
``expert`` dimension for high-E models (DeepSeek: 160 experts / EP over the
`model` axis) or over ``d_ff`` for low-E models (Mixtral: 8 experts / TP) —
see configs/*.py for the per-arch rules.  Shared experts (DeepSeek) are plain
dense FFNs added to the routed output.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import swiglu_ffn


class MoEParams(NamedTuple):
    router: jax.Array            # (d, E)
    w_gate: jax.Array            # (E, d, f)
    w_up: jax.Array              # (E, d, f)
    w_down: jax.Array            # (E, f, d)
    shared_w_gate: Optional[jax.Array] = None   # (d, f_shared)
    shared_w_up: Optional[jax.Array] = None
    shared_w_down: Optional[jax.Array] = None


def moe_ffn(
    x: jax.Array,                # (T, d) — flattened tokens
    p: MoEParams,
    *,
    top_k: int,
    capacity_factor: float = 1.25,
    router_softmax_after_topk: bool = False,
) -> jax.Array:
    """Top-k routed expert FFN; returns (T, d)."""
    t, d = x.shape
    e = p.router.shape[1]
    logits = (x.astype(jnp.float32) @ p.router.astype(jnp.float32))  # (T, E)
    if router_softmax_after_topk:
        # Mixtral: softmax over the selected top-k logits only.
        top_logits, top_idx = jax.lax.top_k(logits, top_k)
        top_w = jax.nn.softmax(top_logits, axis=-1)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        top_w, top_idx = jax.lax.top_k(probs, top_k)
        top_w = top_w / jnp.maximum(jnp.sum(top_w, -1, keepdims=True), 1e-9)

    capacity = max(int(capacity_factor * t * top_k / e), 4)

    # Flatten (token, slot) assignments and rank them within each expert.
    flat_e = top_idx.reshape(-1)                    # (T*k,)
    flat_tok = jnp.repeat(jnp.arange(t), top_k)
    flat_w = top_w.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)        # group by expert
    sorted_e = flat_e[order]
    ranks = jnp.arange(t * top_k) - jnp.searchsorted(
        sorted_e, sorted_e, side="left")            # rank within expert group
    keep = ranks < capacity
    slot = jnp.where(keep, sorted_e * capacity + ranks, e * capacity)

    # Gather tokens into the (E*cap, d) dispatch buffer (scatter by slot).
    src_tok = flat_tok[order]
    buf = jnp.zeros((e * capacity + 1, d), x.dtype).at[slot].set(x[src_tok])
    buf = buf[:-1].reshape(e, capacity, d)

    # Batched expert FFN: (E, cap, d) x (E, d, f) -> (E, cap, d).
    h = jnp.einsum("ecd,edf->ecf", buf, p.w_gate)
    h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", buf, p.w_up)
    out_buf = jnp.einsum("ecf,efd->ecd", h, p.w_down).reshape(e * capacity, d)

    # Scatter-combine back to tokens, weighted by the router.
    gathered = jnp.where(
        keep[:, None], out_buf[jnp.minimum(slot, e * capacity - 1)], 0.0)
    out = jnp.zeros((t, d), out_buf.dtype).at[src_tok].add(
        gathered * flat_w[order][:, None])

    if p.shared_w_gate is not None:
        out = out + swiglu_ffn(x, p.shared_w_gate, p.shared_w_up,
                               p.shared_w_down)
    return out.astype(x.dtype)
