"""Real spherical-harmonic rotation (Wigner-D) matrices, batched over edges.

Implements the Ivanic–Ruedenberg recurrence (J. Phys. Chem. 1996, 100, 6342 +
errata): R^l is built from R^1 and R^{l-1} entirely with elementwise ops, so a
batch of edge rotations (E, 3, 3) turns into a list of (E, 2l+1, 2l+1) block
matrices with static Python loops (l <= l_max is small).

Convention: real SH basis ordered m = -l..l with the l=1 basis (y, z, x) —
R^1 is the cartesian rotation conjugated by that permutation.  ``rotation_to_z``
builds R with R @ n = z so that rotated edges point at +z, where real SH are
nonzero only at m = 0 — the eSCN trick's precondition.
"""

from __future__ import annotations

import functools
from typing import List

import jax
import jax.numpy as jnp
import numpy as np


def rotation_to_z(n: jax.Array) -> jax.Array:
    """(E, 3) unit vectors -> (E, 3, 3) rotations with R @ n = +z."""
    # Stable tangent: pick the reference axis least aligned with n.
    ref = jnp.where(
        (jnp.abs(n[:, 2:3]) < 0.9), jnp.array([[0.0, 0.0, 1.0]]),
        jnp.array([[1.0, 0.0, 0.0]]))
    u = jnp.cross(ref, n)
    u = u / jnp.maximum(jnp.linalg.norm(u, axis=-1, keepdims=True), 1e-12)
    v = jnp.cross(n, u)
    return jnp.stack([u, v, n], axis=1)      # rows: u, v, n  =>  R n = e_z


def _r1_from_cart(r: jax.Array) -> jax.Array:
    """Cartesian (E, 3, 3) -> l=1 real-SH block with (y, z, x) ordering."""
    perm = jnp.asarray([1, 2, 0])            # (x,y,z) -> (y,z,x)
    return r[:, perm][:, :, perm]


def wigner_d_stack(r_cart: jax.Array, l_max: int) -> List[jax.Array]:
    """Returns [D_0, D_1, ..., D_lmax], D_l: (E, 2l+1, 2l+1)."""
    e = r_cart.shape[0]
    ds = [jnp.ones((e, 1, 1), r_cart.dtype)]
    if l_max == 0:
        return ds
    r1 = _r1_from_cart(r_cart)
    ds.append(r1)

    def R1(i, j):          # i, j in [-1, 0, 1]
        return r1[:, i + 1, j + 1]

    for l in range(2, l_max + 1):
        prev = ds[l - 1]

        def Rp(a, b):      # R^{l-1} entries, a, b in [-(l-1) .. l-1]
            return prev[:, a + l - 1, b + l - 1]

        def P(i, a, b):
            # a: row of R^{l-1} (|a| <= l-1); b: column of R^l (|b| <= l).
            if b == -l:
                return R1(i, 1) * Rp(a, -l + 1) + R1(i, -1) * Rp(a, l - 1)
            if b == l:
                return R1(i, 1) * Rp(a, l - 1) - R1(i, -1) * Rp(a, -l + 1)
            return R1(i, 0) * Rp(a, b)

        rows = []
        for m in range(-l, l + 1):          # row index
            row = []
            am = abs(m)
            for n in range(-l, l + 1):      # column index
                denom = ((2 * l) * (2 * l - 1) if abs(n) == l
                         else (l + n) * (l - n))
                # u, v, w coefficients (Ivanic–Ruedenberg + errata): the
                # denominator depends on the COLUMN n, the numerators and the
                # case analysis on the ROW m.
                u_c = np.sqrt(max((l + m) * (l - m), 0) / denom)
                v_c = 0.5 * np.sqrt((1 + (m == 0)) * max((l + am - 1)
                                    * (l + am), 0) / denom) * (1 - 2 * (m == 0))
                w_c = -0.5 * np.sqrt(max((l - am - 1) * (l - am), 0) / denom) \
                    * (1 - (m == 0))

                term = 0.0
                if u_c:
                    term = term + u_c * P(0, m, n)
                if v_c:
                    if m == 0:
                        vv = P(1, 1, n) + P(-1, -1, n)
                    elif m > 0:
                        vv = P(1, m - 1, n) * np.sqrt(1 + (m == 1)) \
                            - P(-1, -m + 1, n) * (1 - (m == 1))
                    else:
                        vv = P(1, m + 1, n) * (1 - (m == -1)) \
                            + P(-1, -m - 1, n) * np.sqrt(1 + (m == -1))
                    term = term + v_c * vv
                if w_c:
                    if m > 0:
                        ww = P(1, m + 1, n) + P(-1, -m - 1, n)
                    else:
                        ww = P(1, m - 1, n) - P(-1, -m + 1, n)
                    term = term + w_c * ww
                row.append(term)
            rows.append(jnp.stack(row, axis=-1))
        ds.append(jnp.stack(rows, axis=1))
    return ds


def block_diag_apply(ds: List[jax.Array], x: jax.Array,
                     transpose: bool = False) -> jax.Array:
    """Apply the stacked Wigner blocks to irrep features.

    x: (E, (l_max+1)^2, C); returns same shape — each l block rotated.
    """
    outs = []
    off = 0
    for l, d in enumerate(ds):
        blk = x[:, off:off + 2 * l + 1]
        mat = jnp.swapaxes(d, 1, 2) if transpose else d
        outs.append(jnp.einsum("eij,ejc->eic", mat, blk))
        off += 2 * l + 1
    return jnp.concatenate(outs, axis=1)
