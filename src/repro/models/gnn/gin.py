"""GIN (Graph Isomorphism Network, arXiv:1810.00826): sum aggregation +
learnable epsilon + 2-layer MLP per layer.  gin-tu config: 5 layers, d=64."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.gnn.common import GraphBatch, mlp, mlp_init, node_ce_loss


@dataclasses.dataclass(frozen=True)
class GINConfig:
    name: str = "gin-tu"
    n_layers: int = 5
    d_hidden: int = 64
    d_feat: int = 64
    n_classes: int = 16
    graph_level: bool = False  # graph classification (TU datasets) vs node


def init_params(cfg: GINConfig, key: jax.Array) -> dict:
    ks = jax.random.split(key, cfg.n_layers + 2)
    layers = []
    d_in = cfg.d_feat
    for i in range(cfg.n_layers):
        layers.append({
            "mlp": mlp_init(ks[i], [d_in, cfg.d_hidden, cfg.d_hidden]),
            "eps": jnp.zeros(()),
        })
        d_in = cfg.d_hidden
    return {"layers": layers,
            "head": mlp_init(ks[-1], [cfg.d_hidden, cfg.n_classes])}


def forward(cfg: GINConfig, params: dict, g: GraphBatch) -> jax.Array:
    n_pad = g.node_feat.shape[0]
    x = g.node_feat
    for lp in params["layers"]:
        agg = jax.ops.segment_sum(x[g.edge_src], g.edge_dst,
                                  num_segments=n_pad + 1)[:n_pad]
        x = mlp((1.0 + lp["eps"]) * x + agg, lp["mlp"])
    if cfg.graph_level:
        pooled = jax.ops.segment_sum(
            x, g.graph_id, num_segments=int(g.graph_id.shape[0]))
        # Only the first n_graphs rows are meaningful.
        return mlp(pooled, params["head"])
    return mlp(x, params["head"])


def loss_fn(cfg: GINConfig, params: dict, g: GraphBatch) -> jax.Array:
    logits = forward(cfg, params, g)
    if cfg.graph_level:
        gmask = jnp.arange(logits.shape[0]) < g.n_graphs
        return node_ce_loss(logits, g.labels[: logits.shape[0]], gmask)
    mask = jnp.arange(logits.shape[0]) < g.n_nodes
    return node_ce_loss(logits, g.labels, mask)
