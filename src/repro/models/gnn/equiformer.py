"""EquiformerV2-style equivariant graph attention via eSCN SO(2) convolutions
(arXiv:2306.12059, eSCN trick from arXiv:2302.03655).

Node features are real-SH irreps x: (N, (l_max+1)^2, C).  Per edge, features
rotate into the edge-aligned frame (Wigner-D, edge -> +z), where the full
O(l^6) Clebsch-Gordan tensor product collapses to SO(2)-blockwise linear maps
over the m index; truncating to |m| <= m_max (= 2) gives the eSCN O(l^3) cost.
Attention weights come from the rotation-invariant m = 0 block, messages
rotate back and scatter-sum to destinations.

Simplifications vs. the reference implementation (documented in DESIGN.md):
the S2 grid pointwise activation is replaced by an equivariant gate
nonlinearity, and separable attention value/key projections are fused into the
SO(2) convolution output.  Equivariance is preserved exactly.
"""

from __future__ import annotations

import dataclasses
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.gnn.common import GraphBatch, mlp, mlp_init, segment_softmax
from repro.models.gnn.wigner import (block_diag_apply, rotation_to_z,
                                     wigner_d_stack)


@dataclasses.dataclass(frozen=True)
class EquiformerConfig:
    name: str = "equiformer-v2"
    n_layers: int = 12
    d_hidden: int = 128        # sphere channels C
    l_max: int = 6
    m_max: int = 2
    n_heads: int = 8
    n_radial: int = 8          # RBF size for distance embedding
    cutoff: float = 5.0
    d_feat: int = 16
    out_dim: int = 1
    node_level: bool = False   # node classification head instead of energy

    @property
    def n_coef(self) -> int:
        return (self.l_max + 1) ** 2


def _m_indices(l_max: int, m: int) -> tuple:
    """Flat irrep indices of the (+m, -m) coefficients for all l >= m."""
    pos = [l * l + l + m for l in range(m, l_max + 1)]
    neg = [l * l + l - m for l in range(m, l_max + 1)]
    return np.asarray(pos), np.asarray(neg)


def init_params(cfg: EquiformerConfig, key: jax.Array) -> dict:
    c, lm, mm = cfg.d_hidden, cfg.l_max, cfg.m_max
    ks = jax.random.split(key, cfg.n_layers + 3)
    layers = []
    for i in range(cfg.n_layers):
        kk = jax.random.split(ks[i], 10 + 2 * mm + lm)
        l0 = lm + 1
        lp = {
            # SO(2) conv, m = 0 (real): mixes (l, channel) jointly; input is
            # src||dst concatenated -> 2C channels.
            "w_m0": jax.random.normal(kk[0], (l0 * 2 * c, l0 * c)) / np.sqrt(l0 * 2 * c),
            "rbf_mlp": mlp_init(kk[1], [cfg.n_radial, c, c]),
            "attn_mlp": mlp_init(kk[2], [l0 * c, c, cfg.n_heads]),
            "ffn_gate": mlp_init(kk[3], [c, c, lm * c]),
            "ffn_l": [jax.random.normal(kk[8 + 2 * mm + l], (c, c)) / np.sqrt(c)
                      for l in range(lm + 1)],
            "ln_scale": jnp.ones((lm + 1, c)),
            "out_proj": jax.random.normal(kk[6], (c, c)) / np.sqrt(c),
        }
        for m in range(1, mm + 1):
            lmc = (lm + 1 - m) * 2 * c
            lout = (lm + 1 - m) * c
            lp[f"w1_m{m}"] = jax.random.normal(kk[6 + 2 * m - 1], (lmc, lout)) / np.sqrt(lmc)
            lp[f"w2_m{m}"] = jax.random.normal(kk[6 + 2 * m], (lmc, lout)) / np.sqrt(lmc)
        layers.append(lp)
    return {
        "embed": mlp_init(ks[-3], [cfg.d_feat, c]),
        "layers": layers,
        "head": mlp_init(ks[-2], [c, c, cfg.out_dim]),
    }


def _irrep_norm(x: jax.Array, scale: jax.Array, l_max: int) -> jax.Array:
    """Equivariant RMS norm: per-l, per-channel scaling."""
    outs = []
    for l in range(l_max + 1):
        blk = x[:, l * l:(l + 1) * (l + 1)]                  # (N, 2l+1, C)
        rms = jnp.sqrt(jnp.mean(jnp.sum(blk**2, axis=1), axis=-1,
                                keepdims=True) + 1e-8)       # (N, 1)
        outs.append(blk / rms[:, None] * scale[l])
    return jnp.concatenate(outs, axis=1)


def _so2_conv(cfg: EquiformerConfig, lp: dict, feat: jax.Array) -> jax.Array:
    """eSCN SO(2) convolution in the edge frame.

    feat: (E, n_coef, 2C) — rotated src||dst features.  Returns (E, n_coef, C)
    with |m| > m_max coefficients zeroed (the eSCN truncation).
    """
    e = feat.shape[0]
    c2 = feat.shape[-1]
    c = c2 // 2
    lm = cfg.l_max
    out = jnp.zeros((e, cfg.n_coef, c), feat.dtype)

    # m = 0: plain linear over (l, channel).
    idx0 = np.asarray([l * l + l for l in range(lm + 1)])
    x0 = feat[:, idx0].reshape(e, -1)                        # (E, (lm+1)*2C)
    y0 = (x0 @ lp["w_m0"]).reshape(e, lm + 1, c)
    out = out.at[:, idx0].set(y0)

    # m >= 1: SO(2)-equivariant pair mixing.
    for m in range(1, cfg.m_max + 1):
        pos, neg = _m_indices(lm, m)
        xp = feat[:, pos].reshape(e, -1)
        xn = feat[:, neg].reshape(e, -1)
        w1, w2 = lp[f"w1_m{m}"], lp[f"w2_m{m}"]
        yp = (xp @ w1 - xn @ w2).reshape(e, lm + 1 - m, c)
        yn = (xp @ w2 + xn @ w1).reshape(e, lm + 1 - m, c)
        out = out.at[:, pos].set(yp)
        out = out.at[:, neg].set(yn)
    return out, y0.reshape(e, -1)                            # messages, m0 flat


def forward(cfg: EquiformerConfig, params: dict, g: GraphBatch) -> jax.Array:
    n_pad = g.node_feat.shape[0]
    c, lm = cfg.d_hidden, cfg.l_max
    s = jnp.minimum(g.edge_src, n_pad - 1)
    t = jnp.minimum(g.edge_dst, n_pad - 1)
    live_e = (g.edge_src < n_pad)

    vec = g.positions[t] - g.positions[s]
    dist = jnp.linalg.norm(vec + 1e-12, axis=-1)
    nvec = vec / jnp.maximum(dist[:, None], 1e-8)
    rot = rotation_to_z(nvec)                                # (E, 3, 3)
    ds = wigner_d_stack(rot, lm)                             # list of blocks

    n_rbf = cfg.n_radial
    mu = jnp.linspace(0.0, cfg.cutoff, n_rbf)
    rbf = jnp.exp(-((dist[:, None] - mu) ** 2) * (n_rbf / cfg.cutoff))

    # Initialize irreps: scalar (l=0) channel from input features.
    x = jnp.zeros((n_pad, cfg.n_coef, c))
    x = x.at[:, 0].set(mlp(g.node_feat, params["embed"]))

    for lp in params["layers"]:
        h = _irrep_norm(x, lp["ln_scale"], lm)
        # Rotate src/dst into the edge frame and concatenate channels.
        f_src = block_diag_apply(ds, h[s])
        f_dst = block_diag_apply(ds, h[t])
        feat = jnp.concatenate([f_src, f_dst], axis=-1)      # (E, n_coef, 2C)
        msg, m0_flat = _so2_conv(cfg, lp, feat)

        # Distance modulation + head attention from the invariant part.
        gate_d = mlp(rbf, lp["rbf_mlp"])                     # (E, C)
        msg = msg * gate_d[:, None, :]
        logits = mlp(m0_flat, lp["attn_mlp"])                # (E, H)
        logits = jax.nn.leaky_relu(logits, 0.2)
        logits = jnp.where(live_e[:, None], logits, -jnp.inf)
        alpha = segment_softmax(logits, g.edge_dst, n_pad + 1)  # (E, H)
        msg = msg.reshape(*msg.shape[:2], cfg.n_heads, c // cfg.n_heads)
        msg = (msg * alpha[:, None, :, None]).reshape(msg.shape[0], cfg.n_coef, c)

        # Rotate back and aggregate.
        msg = block_diag_apply(ds, msg, transpose=True)
        msg = jnp.where(live_e[:, None, None], msg, 0.0)
        agg = jax.ops.segment_sum(msg, g.edge_dst, num_segments=n_pad + 1)[:n_pad]
        x = x + agg @ lp["out_proj"]

        # Equivariant gated FFN.
        h = _irrep_norm(x, lp["ln_scale"], lm)
        scalar = h[:, 0]                                     # (N, C)
        gates = jax.nn.sigmoid(mlp(scalar, lp["ffn_gate"]))  # (N, lm*C)
        outs = [jax.nn.silu(scalar @ lp["ffn_l"][0])]
        for l in range(1, lm + 1):
            blk = h[:, l * l:(l + 1) * (l + 1)] @ lp["ffn_l"][l]
            outs.append(blk * gates[:, None, (l - 1) * c:l * c])
        ffn = jnp.concatenate(
            [outs[0][:, None]] + outs[1:], axis=1)
        x = x + ffn

    scalar = x[:, 0]
    if cfg.node_level:
        return mlp(scalar, params["head"])                   # (N, out_dim)
    g_out = jax.ops.segment_sum(scalar, g.graph_id,
                                num_segments=int(g.graph_id.shape[0]))
    return mlp(g_out, params["head"])                        # (G, out_dim)


def loss_fn(cfg: EquiformerConfig, params: dict, g: GraphBatch) -> jax.Array:
    pred = forward(cfg, params, g)
    if cfg.node_level:
        from repro.models.gnn.common import node_ce_loss
        mask = jnp.arange(pred.shape[0]) < g.n_nodes
        return node_ce_loss(pred, g.labels, mask)
    gmask = (jnp.arange(pred.shape[0]) < g.n_graphs).astype(jnp.float32)
    target = g.labels[: pred.shape[0]].astype(jnp.float32)[:, None]
    err = jnp.square(pred - target).mean(-1) * gmask
    return jnp.sum(err) / jnp.maximum(jnp.sum(gmask), 1.0)
