"""Fanout neighbor sampler (GraphSAGE-style) for minibatch GNN training.

Host-side numpy: samples a k-hop block from a CSR graph with per-hop fanouts
(the assignment's ``minibatch_lg`` shape uses fanout 15-10 over 1024 seeds).
Returns a padded subgraph in GraphBatch layout with static shapes, suitable
for jit'd train steps: layer h edges connect hop-(h+1) sources to hop-h
destinations (all re-indexed into the block's local node space).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class SampledBlock:
    node_ids: np.ndarray      # (N_block,) global ids of all block nodes
    edge_src: np.ndarray      # (E_pad,) local ids
    edge_dst: np.ndarray      # (E_pad,) local ids
    n_nodes: int
    n_seeds: int              # first n_seeds nodes are the seed targets


def block_capacity(n_seeds: int, fanouts: Sequence[int]) -> Tuple[int, int]:
    """Static (node, edge) capacity of a sampled block."""
    n_cap, e_cap, frontier = n_seeds, 0, n_seeds
    for f in fanouts:
        e_cap += frontier * f
        frontier = frontier * f
        n_cap += frontier
    return n_cap, e_cap


def sample_block(
    indptr: np.ndarray,
    indices: np.ndarray,
    seeds: np.ndarray,
    fanouts: Sequence[int],
    rng: np.random.Generator,
) -> SampledBlock:
    """Uniform fanout sampling.  Capacity-padded; duplicate block nodes are
    deduplicated (memory layout stays static via padding)."""
    n_cap, e_cap = block_capacity(len(seeds), fanouts)
    nodes = list(seeds)
    local = {int(v): i for i, v in enumerate(seeds)}
    src_l, dst_l = [], []
    frontier = list(seeds)
    for f in fanouts:
        nxt = []
        for v in frontier:
            lo, hi = indptr[v], indptr[v + 1]
            deg = hi - lo
            if deg == 0:
                continue
            take = min(f, deg)
            picks = indices[lo + rng.choice(deg, size=take, replace=False)]
            for u in picks:
                u = int(u)
                if u not in local:
                    local[u] = len(nodes)
                    nodes.append(u)
                # message u -> v
                src_l.append(local[u])
                dst_l.append(local[v])
                nxt.append(u)
        frontier = nxt

    n_block = len(nodes)
    e_block = len(src_l)
    node_ids = np.full(n_cap, -1, np.int64)
    node_ids[:n_block] = nodes
    es = np.full(e_cap, n_cap, np.int32)
    ed = np.full(e_cap, n_cap, np.int32)
    es[:e_block] = src_l
    ed[:e_block] = dst_l
    return SampledBlock(node_ids=node_ids, edge_src=es, edge_dst=ed,
                        n_nodes=n_block, n_seeds=len(seeds))
