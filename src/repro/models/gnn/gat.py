"""GAT (arXiv:1710.10903): SDDMM edge scores -> segment softmax -> weighted
SpMM.  gat-cora config: 2 layers, 8 hidden per head, 8 heads."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.gnn.common import GraphBatch, node_ce_loss, segment_softmax


@dataclasses.dataclass(frozen=True)
class GATConfig:
    name: str = "gat-cora"
    n_layers: int = 2
    d_hidden: int = 8          # per head
    n_heads: int = 8
    d_feat: int = 1433
    n_classes: int = 7
    negative_slope: float = 0.2


def init_params(cfg: GATConfig, key: jax.Array) -> dict:
    layers = []
    d_in = cfg.d_feat
    ks = jax.random.split(key, cfg.n_layers)
    for i in range(cfg.n_layers):
        last = i == cfg.n_layers - 1
        d_out = cfg.n_classes if last else cfg.d_hidden
        heads = 1 if last else cfg.n_heads
        k1, k2, k3 = jax.random.split(ks[i], 3)
        layers.append({
            "w": jax.random.normal(k1, (d_in, heads, d_out)) / np.sqrt(d_in),
            "a_src": jax.random.normal(k2, (heads, d_out)) / np.sqrt(d_out),
            "a_dst": jax.random.normal(k3, (heads, d_out)) / np.sqrt(d_out),
        })
        d_in = heads * d_out
    return {"layers": layers}


def forward(cfg: GATConfig, params: dict, g: GraphBatch) -> jax.Array:
    n_pad = g.node_feat.shape[0]
    x = g.node_feat
    for i, lp in enumerate(params["layers"]):
        last = i == cfg.n_layers - 1
        h = jnp.einsum("nd,dho->nho", x, lp["w"])          # (N, H, O)
        s_src = jnp.einsum("nho,ho->nh", h, lp["a_src"])   # (N, H)
        s_dst = jnp.einsum("nho,ho->nh", h, lp["a_dst"])
        e = s_src[g.edge_src] + s_dst[g.edge_dst]          # (E, H) SDDMM
        e = jax.nn.leaky_relu(e, cfg.negative_slope)
        # Mask padding edges out of the softmax.
        e = jnp.where((g.edge_dst < n_pad)[:, None], e, -jnp.inf)
        alpha = segment_softmax(e, g.edge_dst, n_pad + 1)  # (E, H)
        msg = h[g.edge_src] * alpha[:, :, None]
        out = jax.ops.segment_sum(msg, g.edge_dst, num_segments=n_pad + 1)[:n_pad]
        x = out.reshape(n_pad, -1) if last else jax.nn.elu(out).reshape(n_pad, -1)
    return x  # (N, n_classes)


def loss_fn(cfg: GATConfig, params: dict, g: GraphBatch) -> jax.Array:
    logits = forward(cfg, params, g)
    mask = jnp.arange(logits.shape[0]) < g.n_nodes
    return node_ce_loss(logits, g.labels, mask)
