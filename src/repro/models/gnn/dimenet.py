"""DimeNet (arXiv:2003.03123): directional message passing with spherical
Bessel / spherical-harmonic bases and triplet (k->j->i) interactions.

Config (assigned): 6 blocks, d=128, n_bilinear=8, n_spherical=7, n_radial=6.

Bases:
  RBF(d)    = sqrt(2/c) * sin(n pi d / c) / d                       n=1..6
  SBF(d,a)  = j_l(z_{l,n} d / c) * Y_l^0(a)        l=0..6, n=1..6
with j_l the spherical Bessel functions (hardcoded closed forms) and z_{l,n}
their roots (computed once with scipy at module import).

Triplets: for every directed edge (j -> i), every incoming edge (k -> j),
k != i, contributes a message weighted by the angle between the two edge
vectors.  Triplet index lists are built host-side (numpy) and padded.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.gnn.common import GraphBatch, mlp, mlp_init


# --- spherical Bessel j_l, closed forms up to l = 6 -------------------------

def _sph_jl(l: int, x: jnp.ndarray) -> jnp.ndarray:
    """Numerically-safe j_l(x): closed forms for x >~ 0.5, Taylor series below
    (the closed forms carry 1/x^(l+1) terms that explode near 0)."""
    # The closed forms cancel catastrophically below x ~ l (terms of size
    # (2l-1)!!/x^(l+1) summing to O(x^l)); switch to the Taylor series there.
    thresh = max(0.5, 0.55 * l + 0.5)
    small = x < thresh
    xs = jnp.where(small, thresh + 1.0, x)   # safe arg for the closed form
    s, c = jnp.sin(xs), jnp.cos(xs)
    inv = 1.0 / xs
    if l == 0:
        big = s * inv
    elif l == 1:
        big = s * inv**2 - c * inv
    elif l == 2:
        big = (3 * inv**3 - inv) * s - 3 * inv**2 * c
    elif l == 3:
        big = (15 * inv**4 - 6 * inv**2) * s - (15 * inv**3 - inv) * c
    elif l == 4:
        big = (105 * inv**5 - 45 * inv**3 + inv) * s \
            - (105 * inv**4 - 10 * inv**2) * c
    elif l == 5:
        big = (945 * inv**6 - 420 * inv**4 + 15 * inv**2) * s \
            - (945 * inv**5 - 105 * inv**3 + inv) * c
    elif l == 6:
        big = (10395 * inv**7 - 4725 * inv**5 + 210 * inv**3 - inv) * s \
            - (10395 * inv**6 - 1260 * inv**4 + 21 * inv**2) * c
    else:
        raise ValueError(l)
    # Small-x series: x^l/(2l+1)!! * sum_k (-x^2/2)^k / (k! (2l+3)(2l+5)...).
    dfact = float(np.prod(np.arange(2 * l + 1, 0, -2))) if l > 0 else 1.0
    x2 = x * x
    term = jnp.ones_like(x)
    series = jnp.ones_like(x)
    for k in range(1, 6):
        term = term * (-x2 / 2.0) / (k * (2 * l + 2 * k + 1))
        series = series + term
    series = x**l / dfact * series
    return jnp.where(small, series, big)


@functools.lru_cache(maxsize=None)
def _bessel_zeros(n_l: int, n_n: int) -> np.ndarray:
    """Roots z_{l,n} of j_l, shape (n_l, n_n) — scipy once, host-side."""
    from scipy import optimize, special
    zeros = np.zeros((n_l, n_n))
    for l in range(n_l):
        f = lambda x: special.spherical_jn(l, x)
        found, x = [], l + 1e-3  # j_l's first zero is > l
        step = 0.1
        while len(found) < n_n:
            if f(x) * f(x + step) < 0:
                found.append(optimize.brentq(f, x, x + step))
            x += step
        zeros[l] = found
    return zeros


def _legendre_y_l0(l: int, cos_t: jnp.ndarray) -> jnp.ndarray:
    """Y_l^0 up to normalization constant sqrt((2l+1)/4pi) * P_l(cos t)."""
    p = [jnp.ones_like(cos_t), cos_t]
    for ll in range(2, l + 1):
        p.append(((2 * ll - 1) * cos_t * p[-1] - (ll - 1) * p[-2]) / ll)
    return np.sqrt((2 * l + 1) / (4 * np.pi)) * p[l]


@dataclasses.dataclass(frozen=True)
class DimeNetConfig:
    name: str = "dimenet"
    n_blocks: int = 6
    d_hidden: int = 128
    n_bilinear: int = 8
    n_spherical: int = 7
    n_radial: int = 6
    cutoff: float = 5.0
    d_feat: int = 16           # input node feature dim (atom embedding stub)
    out_dim: int = 1           # graph-level regression target


class TripletIndex(Tuple):
    pass


def build_triplets_host(edge_src: np.ndarray, edge_dst: np.ndarray,
                        n_edges: int, cap: int) -> Tuple[np.ndarray, np.ndarray]:
    """(t_kj, t_ji) edge-index pairs: edge kj feeds edge ji when dst(kj) ==
    src(ji) and src(kj) != dst(ji).  Padded to ``cap`` with n_edges."""
    by_dst = {}
    for e in range(n_edges):
        by_dst.setdefault(int(edge_dst[e]), []).append(e)
    t_kj, t_ji = [], []
    for e in range(n_edges):
        j, i = int(edge_src[e]), int(edge_dst[e])
        for e2 in by_dst.get(j, ()):               # e2: k -> j
            if int(edge_src[e2]) != i:
                t_kj.append(e2)
                t_ji.append(e)
    t_kj, t_ji = t_kj[:cap], t_ji[:cap]
    pad = cap - len(t_kj)
    return (np.asarray(t_kj + [n_edges] * pad, np.int32),
            np.asarray(t_ji + [n_edges] * pad, np.int32))


def rbf_basis(d: jax.Array, n_radial: int, cutoff: float) -> jax.Array:
    n = jnp.arange(1, n_radial + 1, dtype=jnp.float32)
    d = jnp.maximum(d, 1e-8)[:, None]
    env = jnp.where(d < cutoff, 1.0, 0.0)
    return env * np.sqrt(2.0 / cutoff) * jnp.sin(n * jnp.pi * d / cutoff) / d


def sbf_basis(d: jax.Array, cos_angle: jax.Array, n_spherical: int,
              n_radial: int, cutoff: float) -> jax.Array:
    """(T, n_spherical * n_radial) spherical basis over triplets."""
    zeros = _bessel_zeros(n_spherical, n_radial)       # (L, N)
    d = jnp.maximum(d, 1e-8)
    parts = []
    for l in range(n_spherical):
        ang = _legendre_y_l0(l, cos_angle)             # (T,)
        for n in range(n_radial):
            rad = _sph_jl(l, zeros[l, n] * d / cutoff)
            parts.append(rad * ang)
    env = jnp.where(d < cutoff, 1.0, 0.0)
    return jnp.stack(parts, axis=-1) * env[:, None]


def init_params(cfg: DimeNetConfig, key: jax.Array) -> dict:
    d, nb = cfg.d_hidden, cfg.n_bilinear
    n_sbf = cfg.n_spherical * cfg.n_radial
    ks = jax.random.split(key, 4 + cfg.n_blocks)
    blocks = []
    for i in range(cfg.n_blocks):
        k1, k2, k3, k4, k5 = jax.random.split(ks[i], 5)
        blocks.append({
            "w_sbf": jax.random.normal(k1, (n_sbf, nb)) / np.sqrt(n_sbf),
            "w_bil": jax.random.normal(k2, (nb, d, d)) * (2.0 / d),
            "mlp_kj": mlp_init(k3, [d, d]),
            "mlp_ji": mlp_init(k4, [d, d]),
            "mlp_out": mlp_init(k5, [d, d, d]),
        })
    return {
        "embed": mlp_init(ks[-4], [2 * cfg.d_feat + cfg.n_radial, cfg.d_hidden]),
        "rbf_w": jax.random.normal(ks[-3], (cfg.n_radial, d)) / np.sqrt(cfg.n_radial),
        "blocks": blocks,
        "out": mlp_init(ks[-2], [d, d, cfg.out_dim]),
    }


def forward(cfg: DimeNetConfig, params: dict, g: GraphBatch,
            t_kj: jax.Array, t_ji: jax.Array) -> jax.Array:
    """Graph-level prediction (G_pad, out_dim).  Requires g.positions."""
    n_pad = g.node_feat.shape[0]
    e_pad = g.edge_src.shape[0]
    pos = g.positions
    # Edge geometry (padding edges point sentinel->sentinel; clamp indices).
    s = jnp.minimum(g.edge_src, n_pad - 1)
    t = jnp.minimum(g.edge_dst, n_pad - 1)
    vec = pos[t] - pos[s]
    dist = jnp.linalg.norm(vec + 1e-12, axis=-1)
    rbf = rbf_basis(dist, cfg.n_radial, cfg.cutoff)        # (E, n_radial)

    live_e = (g.edge_src < n_pad)[:, None]
    x_e = mlp(jnp.concatenate(
        [g.node_feat[s], g.node_feat[t], rbf], axis=-1), params["embed"])
    x_e = x_e * live_e                                     # (E, d)

    # Triplet geometry: angle between edge ji and edge kj at node j.
    kj = jnp.minimum(t_kj, e_pad - 1)
    ji = jnp.minimum(t_ji, e_pad - 1)
    v1 = -vec[kj]                                           # j -> k
    v2 = vec[ji]                                            # j -> i  (vec is src->dst: j->i)
    cos_a = jnp.sum(v1 * v2, -1) / jnp.maximum(
        jnp.linalg.norm(v1, axis=-1) * jnp.linalg.norm(v2, axis=-1), 1e-8)
    sbf = sbf_basis(dist[kj], jnp.clip(cos_a, -1.0, 1.0),
                    cfg.n_spherical, cfg.n_radial, cfg.cutoff)  # (T, n_sbf)
    live_t = (t_kj < e_pad)[:, None]

    rbf_proj = rbf @ params["rbf_w"]                        # (E, d)
    for bp in params["blocks"]:
        m_kj = mlp(x_e, bp["mlp_kj"])                       # (E, d)
        sbf_p = (sbf @ bp["w_sbf"]) * live_t                # (T, nb)
        # Bilinear directional interaction (DimeNet eq. 9).
        tri = jnp.einsum("tb,bdo,td->to", sbf_p, bp["w_bil"], m_kj[kj])
        agg = jax.ops.segment_sum(tri, jnp.minimum(t_ji, e_pad),
                                  num_segments=e_pad + 1)[:e_pad]
        x_e = x_e + mlp(mlp(x_e, bp["mlp_ji"]) * rbf_proj + agg, bp["mlp_out"])
        x_e = x_e * live_e

    # Per-node then per-graph readout.
    node_out = jax.ops.segment_sum(
        x_e, jnp.minimum(g.edge_dst, n_pad), num_segments=n_pad + 1)[:n_pad]
    g_out = jax.ops.segment_sum(
        node_out, g.graph_id, num_segments=int(g.graph_id.shape[0]))
    return mlp(g_out, params["out"])


def loss_fn(cfg: DimeNetConfig, params: dict, g: GraphBatch,
            t_kj: jax.Array, t_ji: jax.Array) -> jax.Array:
    pred = forward(cfg, params, g, t_kj, t_ji)          # (G_pad, out)
    gmask = (jnp.arange(pred.shape[0]) < g.n_graphs).astype(jnp.float32)
    target = g.labels[: pred.shape[0]].astype(jnp.float32)[:, None]
    err = jnp.square(pred - target).mean(-1) * gmask
    return jnp.sum(err) / jnp.maximum(jnp.sum(gmask), 1.0)
