"""Shared GNN infrastructure: message passing on edge lists via segment ops.

JAX has no sparse SpMM beyond BCOO, so message passing is built directly on
``jax.ops.segment_sum`` / ``segment_max`` over an edge-index — gather source
features, transform, scatter-reduce to destinations.  Edge lists are padded to
static capacity with src = dst = n_nodes (a sentinel row).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np


class GraphBatch(NamedTuple):
    """Padded graph (or batch of merged graphs).

    node_feat : (N_pad, d_feat) float — input features.
    edge_src  : (E_pad,) int32 — source node per directed edge (pad = N_pad).
    edge_dst  : (E_pad,) int32 — destination node (pad = N_pad).
    n_nodes   : () int32 — valid node count.
    labels    : (N_pad,) int32 or (G,) — targets (node class / graph target).
    graph_id  : (N_pad,) int32 — for batched small graphs (else zeros).
    n_graphs  : () int32.
    positions : (N_pad, 3) float or None — 3D coordinates (geometric models).
    """

    node_feat: jax.Array
    edge_src: jax.Array
    edge_dst: jax.Array
    n_nodes: jax.Array
    labels: jax.Array
    graph_id: jax.Array
    n_graphs: jax.Array
    positions: Optional[jax.Array] = None


def segment_softmax(logits: jax.Array, segments: jax.Array,
                    num_segments: int) -> jax.Array:
    """Softmax over groups (e.g. incoming edges of each node)."""
    mx = jax.ops.segment_max(logits, segments, num_segments=num_segments)
    mx = jnp.where(jnp.isfinite(mx), mx, 0.0)
    ex = jnp.exp(logits - mx[segments])
    den = jax.ops.segment_sum(ex, segments, num_segments=num_segments)
    return ex / jnp.maximum(den[segments], 1e-16)


def scatter_mean(values: jax.Array, segments: jax.Array,
                 num_segments: int) -> jax.Array:
    s = jax.ops.segment_sum(values, segments, num_segments=num_segments)
    c = jax.ops.segment_sum(jnp.ones_like(segments, jnp.float32), segments,
                            num_segments=num_segments)
    return s / jnp.maximum(c, 1.0)[..., None] if values.ndim > 1 else \
        s / jnp.maximum(c, 1.0)


def mlp(x: jax.Array, params: list, act=jax.nn.relu) -> jax.Array:
    """params: list of (w, b) pairs; activation between layers."""
    for i, (w, b) in enumerate(params):
        x = x @ w + b
        if i + 1 < len(params):
            x = act(x)
    return x


def mlp_init(key, dims, dtype=jnp.float32) -> list:
    ks = jax.random.split(key, len(dims) - 1)
    out = []
    for i in range(len(dims) - 1):
        w = jax.random.normal(ks[i], (dims[i], dims[i + 1]), jnp.float32)
        out.append(((w / np.sqrt(dims[i])).astype(dtype),
                    jnp.zeros((dims[i + 1],), dtype)))
    return out


def node_ce_loss(logits: jax.Array, labels: jax.Array,
                 mask: jax.Array) -> jax.Array:
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[:, None], 1)[:, 0]
    nll = (lse - ll) * mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
