"""Factorization Machine (Rendle, ICDM'10) with JAX-native embedding bags.

y(x) = w0 + sum_i w_i + 1/2 [ (sum_i v_i)^2 - sum_i v_i^2 ]   (O(n k) trick)

over 39 sparse categorical fields (Criteo-style).  JAX has no native
EmbeddingBag — ``embedding_bag`` below builds it from ``jnp.take`` +
``jax.ops.segment_sum``, and the one-hot FM path is a plain sharded gather.
Embedding tables are concatenated into one (sum(vocab), k) matrix row-sharded
over the `model` mesh axis; per-field offsets turn field-local ids into rows.

Shapes served: train (B=65536), online (B=512), bulk scoring (B=262144) and
retrieval — one user query scored against 10^6 candidate items via a single
batched matvec (no loop).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# A realistic Criteo-like vocabulary mix for 39 fields (sums to ~33M rows).
DEFAULT_VOCABS = tuple(
    [int(v) for v in
     [10_000_000, 8_000_000, 4_000_000, 2_000_000, 1_500_000, 1_000_000,
      800_000, 600_000, 400_000, 300_000, 200_000, 150_000, 100_000,
      80_000, 60_000, 40_000, 30_000, 20_000, 15_000, 10_000,
      8_000, 6_000, 4_000, 3_000, 2_000, 1_500, 1_000, 800, 600, 400,
      300, 200, 150, 100, 80, 60, 40, 20, 10]]
)


@dataclasses.dataclass(frozen=True)
class FMConfig:
    name: str = "fm"
    n_fields: int = 39
    embed_dim: int = 10
    vocab_sizes: Tuple[int, ...] = DEFAULT_VOCABS

    @property
    def total_vocab(self) -> int:
        return int(sum(self.vocab_sizes))

    @property
    def padded_vocab(self) -> int:
        """Table rows padded to a multiple of 512 so the row-sharded tables
        divide every production mesh axis flattening; rows past total_vocab
        are never indexed."""
        return -(-self.total_vocab // 512) * 512

    @property
    def field_offsets(self) -> np.ndarray:
        return np.concatenate([[0], np.cumsum(self.vocab_sizes)[:-1]]).astype(np.int64)


def init_params(cfg: FMConfig, key: jax.Array, dtype=jnp.float32) -> dict:
    k1, k2 = jax.random.split(key)
    v_total = cfg.padded_vocab
    return {
        "w0": jnp.zeros((), dtype),
        "w": (jax.random.normal(k1, (v_total,), jnp.float32) * 0.01).astype(dtype),
        "v": (jax.random.normal(k2, (v_total, cfg.embed_dim), jnp.float32)
              * 0.01).astype(dtype),
    }


def param_shapes(cfg: FMConfig) -> dict:
    return {"w0": (), "w": (cfg.padded_vocab,),
            "v": (cfg.padded_vocab, cfg.embed_dim)}


def embedding_bag(table: jax.Array, ids: jax.Array, bag_ids: jax.Array,
                  n_bags: int, mode: str = "sum",
                  weights: jax.Array | None = None) -> jax.Array:
    """torch.nn.EmbeddingBag equivalent: ragged gather + segment reduce.

    table (V, k); ids (L,) row ids; bag_ids (L,) which bag each id belongs to.
    """
    rows = jnp.take(table, ids, axis=0)
    if weights is not None:
        rows = rows * weights[:, None]
    if mode == "sum":
        return jax.ops.segment_sum(rows, bag_ids, num_segments=n_bags)
    if mode == "mean":
        s = jax.ops.segment_sum(rows, bag_ids, num_segments=n_bags)
        c = jax.ops.segment_sum(jnp.ones_like(ids, jnp.float32), bag_ids,
                                num_segments=n_bags)
        return s / jnp.maximum(c, 1.0)[:, None]
    if mode == "max":
        return jax.ops.segment_max(rows, bag_ids, num_segments=n_bags)
    raise ValueError(mode)


def _rows_from_fields(cfg: FMConfig, field_ids: jax.Array) -> jax.Array:
    """(B, F) per-field ids -> (B, F) global rows via field offsets."""
    offs = jnp.asarray(cfg.field_offsets, jnp.int32)
    return field_ids + offs[None, :]


def forward(cfg: FMConfig, params: dict, field_ids: jax.Array) -> jax.Array:
    """field_ids (B, F) int32 -> logits (B,)."""
    rows = _rows_from_fields(cfg, field_ids)
    v = jnp.take(params["v"], rows, axis=0)          # (B, F, k)  gather
    w = jnp.take(params["w"], rows, axis=0)          # (B, F)
    lin = params["w0"] + jnp.sum(w, axis=1)
    sum_v = jnp.sum(v, axis=1)                        # (B, k)
    sum_sq = jnp.sum(v * v, axis=1)                   # (B, k)
    pair = 0.5 * jnp.sum(sum_v * sum_v - sum_sq, axis=1)
    return (lin + pair).astype(jnp.float32)


def loss_fn(cfg: FMConfig, params: dict, batch: dict) -> jax.Array:
    """Binary cross-entropy on click labels."""
    logits = forward(cfg, params, batch["field_ids"])
    y = batch["labels"].astype(jnp.float32)
    return jnp.mean(jnp.maximum(logits, 0) - logits * y
                    + jnp.log1p(jnp.exp(-jnp.abs(logits))))


def retrieval_scores(cfg: FMConfig, params: dict, user_fields: jax.Array,
                     cand_rows: jax.Array) -> jax.Array:
    """Score ONE user (1, F) against N candidate rows (N,) in one matvec.

    FM restricted to user-item cross terms: s(u, c) = <sum_f v_f(u), v_c> +
    w_c + user-internal terms (constant over candidates, dropped for ranking).
    """
    rows = _rows_from_fields(cfg, user_fields)        # (1, F)
    v_u = jnp.sum(jnp.take(params["v"], rows[0], axis=0), axis=0)   # (k,)
    v_c = jnp.take(params["v"], cand_rows, axis=0)    # (N, k)
    w_c = jnp.take(params["w"], cand_rows, axis=0)    # (N,)
    return (v_c @ v_u + w_c).astype(jnp.float32)
