"""Version-portability shims for JAX API skew.

Two skews currently bite:

  * mesh construction — newer JAX exposes ``jax.sharding.AxisType`` and
    ``jax.make_mesh(..., axis_types=...)``; older releases (e.g. 0.4.x) have
    ``jax.make_mesh`` without ``axis_types``, and the oldest only have
    ``jax.sharding.Mesh``.  Every mesh in this repo is built with Auto axis
    semantics, so the portable spelling is just ``make_mesh`` below.
  * ``jax.lax.axis_size`` — absent on 0.4.x; ``axis_size`` below falls back
    to ``psum(1, axis)`` (a constant inside shard_map bodies).

Keep ALL version probing in this module — call sites must not touch
``jax.sharding.AxisType`` / ``jax.lax.axis_size`` directly.
"""

from __future__ import annotations

import jax


def _auto_axis_types(n_axes: int):
    """(AxisType.Auto,) * n_axes on JAX versions that have it, else None."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return None
    return (axis_type.Auto,) * n_axes


def make_mesh(axis_shapes, axis_names):
    """``jax.make_mesh`` with Auto axis types, across JAX versions."""
    axis_shapes = tuple(axis_shapes)
    axis_names = tuple(axis_names)
    axis_types = _auto_axis_types(len(axis_names))
    if hasattr(jax, "make_mesh"):
        if axis_types is not None:
            try:
                return jax.make_mesh(axis_shapes, axis_names,
                                     axis_types=axis_types)
            except TypeError:  # make_mesh exists but predates axis_types
                pass
        return jax.make_mesh(axis_shapes, axis_names)
    from jax.experimental import mesh_utils

    devices = mesh_utils.create_device_mesh(axis_shapes)
    return jax.sharding.Mesh(devices, axis_names)


def axis_size(axis_name):
    """Size of a named mesh axis, usable inside shard_map/pmap bodies."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)
