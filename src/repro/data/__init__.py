from repro.data.graphs import (lfr_graph, powerlaw_cluster, rmat_graph,
                               sbm_graph, sbm_holdout_stream)
from repro.data.tokens import synthetic_token_batches
from repro.data.recsys import synthetic_click_batches

__all__ = ["rmat_graph", "sbm_graph", "sbm_holdout_stream", "lfr_graph",
           "powerlaw_cluster",
           "synthetic_token_batches", "synthetic_click_batches"]
