"""Synthetic graph generators (the paper's datasets — SuiteSparse web/social/
road/k-mer graphs — are not available offline; these generators match the
paper's graph *families*): R-MAT (web-like power-law), SBM (planted
communities), LFR (community benchmark), powerlaw-cluster (social-like)."""

from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.core.graph import CSRGraph, build_csr


def rmat_graph(scale: int, edge_factor: int = 8,
               a: float = 0.57, b: float = 0.19, c: float = 0.19,
               seed: int = 0, n_cap: int | None = None,
               e_cap: int | None = None) -> CSRGraph:
    """R-MAT generator (Graph500-style): 2^scale vertices, power-law degrees."""
    n = 1 << scale
    m = n * edge_factor
    rng = np.random.default_rng(seed)
    src = np.zeros(m, np.int64)
    dst = np.zeros(m, np.int64)
    for bit in range(scale):
        r = rng.random(m)
        go_right = (r > a + b) & (r <= a + b + c)
        go_down = r > a + b + c
        pick_b = (r > a) & (r <= a + b)
        src += ((go_right | go_down).astype(np.int64)) << bit
        dst += ((pick_b | go_down).astype(np.int64)) << bit
    keep = src != dst
    src, dst = src[keep], dst[keep]
    w = np.ones(len(src), np.float32)
    return build_csr(src, dst, w, n, symmetrize=True, dedup=True,
                     n_cap=n_cap, e_cap=e_cap)


def sbm_graph(n_communities: int, size: int, p_in: float, p_out: float,
              seed: int = 0) -> Tuple[CSRGraph, np.ndarray]:
    """Stochastic block model; returns (graph, true_membership)."""
    rng = np.random.default_rng(seed)
    n = n_communities * size
    labels = np.repeat(np.arange(n_communities), size)
    src_l, dst_l = [], []
    # Within-community edges.
    for cix in range(n_communities):
        base = cix * size
        tri = rng.random((size, size)) < p_in
        iu = np.triu_indices(size, 1)
        sel = tri[iu]
        src_l.append(base + iu[0][sel])
        dst_l.append(base + iu[1][sel])
    # Cross edges (sparse sampling).
    n_cross = rng.binomial(n * (n - 1) // 2, p_out)
    cs = rng.integers(0, n, n_cross)
    cd = rng.integers(0, n, n_cross)
    off = (labels[cs] != labels[cd]) & (cs != cd)
    src_l.append(cs[off])
    dst_l.append(cd[off])
    src = np.concatenate(src_l)
    dst = np.concatenate(dst_l)
    w = np.ones(len(src), np.float32)
    return build_csr(src, dst, w, n, symmetrize=True, dedup=True), labels


def sbm_holdout_stream(seed: int, *, n_communities: int = 8, size: int = 16,
                       p_in: float = 0.4, p_out: float = 0.01,
                       n_cap: int | None = None, e_cap: int | None = None,
                       n_hold: int = 32, n_steps: int = 4, b_cap: int = 8):
    """One streaming-Louvain scenario: an SBM with held-out edges fed back
    as ``n_steps`` edge batches (round-robin striding over the holdout).

    Returns (initial_graph, batches, full_graph).  The shared builder of
    the dynamic/multistream tests, benchmarks and examples — the holdout
    logic exists ONCE so they all measure the same stream.
    """
    from repro.core.delta import make_edge_batch

    full, _ = sbm_graph(n_communities, size, p_in, p_out, seed=seed)
    e = int(full.e_valid)
    src = np.asarray(full.src)[:e]
    dst = np.asarray(full.indices)[:e]
    w = np.asarray(full.weights)[:e]
    und = src < dst
    us, ud, uw = src[und], dst[und], w[und]
    rng = np.random.default_rng(seed)
    hold = rng.choice(len(us), n_hold, replace=False)
    keep = np.ones(len(us), bool)
    keep[hold] = False
    init = build_csr(np.concatenate([us[keep], ud[keep]]),
                     np.concatenate([ud[keep], us[keep]]),
                     np.concatenate([uw[keep], uw[keep]]),
                     int(full.n_valid), n_cap=n_cap,
                     e_cap=e_cap if e_cap is not None else e + 8)
    batches = [make_edge_batch(us[hold[i::n_steps]], ud[hold[i::n_steps]],
                               uw[hold[i::n_steps]], init.n_cap, b_cap=b_cap)
               for i in range(n_steps)]
    return init, batches, full


def lfr_graph(n: int = 1000, seed: int = 42):
    """LFR benchmark via networkx; returns (CSRGraph, networkx graph)."""
    import networkx as nx
    from repro.core.graph import from_networkx
    g = nx.LFR_benchmark_graph(
        n, 3, 1.5, 0.1, average_degree=10, max_degree=max(50, n // 20),
        min_community=20, seed=seed)
    g = nx.Graph(g)
    g.remove_edges_from(nx.selfloop_edges(g))
    return from_networkx(g), g


def powerlaw_cluster(n: int, m: int = 10, p: float = 0.3, seed: int = 7):
    import networkx as nx
    from repro.core.graph import from_networkx
    g = nx.powerlaw_cluster_graph(n, m, p, seed=seed)
    return from_networkx(g), g
