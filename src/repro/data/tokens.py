"""Synthetic LM token pipeline: deterministic shardable batches with a
Zipfian unigram distribution plus short-range structure (so loss decreases
measurably during the example training runs)."""

from __future__ import annotations

from typing import Iterator

import numpy as np


def synthetic_token_batches(vocab: int, batch: int, seq_len: int,
                            seed: int = 0, structured: bool = True
                            ) -> Iterator[dict]:
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab + 1)
    probs = 1.0 / ranks ** 1.1
    probs /= probs.sum()
    while True:
        toks = rng.choice(vocab, size=(batch, seq_len + 1), p=probs)
        if structured:
            # Deterministic successor rule for 1/2 of positions: makes the
            # sequence partially learnable (tok[t+1] = (tok[t]*7+3) % vocab).
            mask = rng.random((batch, seq_len)) < 0.5
            nxt = (toks[:, :-1] * 7 + 3) % vocab
            toks[:, 1:][mask] = nxt[mask]
        yield {"tokens": toks[:, :-1].astype(np.int32),
               "labels": toks[:, 1:].astype(np.int32)}
