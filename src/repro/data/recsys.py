"""Synthetic Criteo-like click batches for the FM architecture."""

from __future__ import annotations

from typing import Iterator, Sequence

import numpy as np


def synthetic_click_batches(vocab_sizes: Sequence[int], batch: int,
                            seed: int = 0) -> Iterator[dict]:
    rng = np.random.default_rng(seed)
    vs = np.asarray(vocab_sizes)
    # Hidden linear model over a few hash features -> learnable CTR signal.
    w_true = rng.normal(size=len(vs)) * 0.5
    while True:
        ids = (rng.pareto(1.2, size=(batch, len(vs))) * vs / 20).astype(np.int64)
        ids = np.minimum(ids, vs - 1).astype(np.int32)
        logit = ((ids % 7 - 3) * w_true).sum(1) * 0.3
        y = (rng.random(batch) < 1 / (1 + np.exp(-logit))).astype(np.int32)
        yield {"field_ids": ids, "labels": y}
