"""Pallas TPU kernel: group-resolve scan for the edge-batch sort-reduce.

``repro.core.delta.sort_reduce_apply_slots`` — the single shared core of
both the single-device CSR batch apply and the per-shard sharded apply —
resolves a (src, dst)-sorted unified slot list into per-edge groups:
each group's last slot wins (batch slots outrank existing ones), live
groups compact into the output capacity, and groups whose resolved weight
changed report their endpoints.  The XLA reference expresses this with
five segment_* reductions plus two global cumsums over the full slot list;
this kernel fuses the whole post-sort resolve into ONE forward scan:

    tile t:   is_first  = key != shifted(key)           (group boundaries)
              open-first = segmented copy-scan of (w, is_batch)
              finalize   = at each boundary, emit the group that just ended
              pos        = running kept-group prefix (carried in SMEM)

The TPU grid is sequential, so cross-tile state (previous slot, open-group
first values, kept-count prefix) rides in SMEM scratch between programs —
the same pattern as a carry-chained prefix sum.  All emitted weights are
*selected*, never summed, so the kernel output is bit-for-bit identical to
the XLA path (asserted by tests/test_batch_apply_kernel.py).

The scatter into compacted output slots and the preceding lexsort remain
XLA's job (dynamic scatter is not a TPU-kernel-friendly primitive); the
kernel returns per-slot (keep, pos, src, dst, w, changed) records at each
group-finalization point.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # scratch memory-space types live in the TPU namespace
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover - CPU-only wheels
    pltpu = None

_BLOCK = 512  # lanes per program (multiple of 128)


def _shift_right(x: jax.Array, d: int, fill) -> jax.Array:
    """(1, T) lane shift by ``d`` with constant fill on the left."""
    return jnp.concatenate(
        [jnp.full((1, d), fill, x.dtype), x[:, :-d]], axis=1)


def _resolve_kernel(sent: int, src_ref, dst_ref, w_ref, batch_ref,
                    keep_ref, pos_ref, fsrc_ref, fdst_ref, fw_ref, chg_ref,
                    ckey_ref, clastw_ref, clastb_ref, copenw_ref, copenb_ref,
                    ckept_ref):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _():
        # -2 differs from every real key (keys are in [0, sent]), so the
        # very first slot always opens a group; the phantom "previous
        # group" it finalizes has w = 0 / batch = 0 -> never kept/changed.
        ckey_ref[0] = -2
        ckey_ref[1] = -2
        clastw_ref[0] = 0.0
        clastb_ref[0] = 0
        copenw_ref[0] = 0.0
        copenb_ref[0] = 0
        ckept_ref[0] = 0

    src = src_ref[...]                     # (1, T) int32
    dst = dst_ref[...]
    w = w_ref[...].astype(jnp.float32)
    b = batch_ref[...]                     # (1, T) int32 0/1

    # Lane 0's "previous slot" is the carry from the preceding tile.
    lane0 = jax.lax.broadcasted_iota(jnp.int32, src.shape, 1) == 0
    prev_src = jnp.where(lane0, ckey_ref[0], _shift_right(src, 1, 0))
    prev_dst = jnp.where(lane0, ckey_ref[1], _shift_right(dst, 1, 0))
    prev_w = jnp.where(lane0, clastw_ref[0], _shift_right(w, 1, 0.0))
    prev_b = jnp.where(lane0, clastb_ref[0], _shift_right(b, 1, 0))
    is_first = (src != prev_src) | (dst != prev_dst)

    # Segmented copy-scan (Hillis-Steele): per slot, the (w, batch) of the
    # first slot of the group CONTAINING it; unanchored slots (group opened
    # in an earlier tile) fall back to the carried open-group state.
    fw, fb, anch = w, b, is_first
    d = 1
    while d < src.shape[1]:
        pfw = _shift_right(fw, d, 0.0)
        pfb = _shift_right(fb, d, 0)
        panch = _shift_right(anch, d, False)
        fw = jnp.where(anch, fw, pfw)
        fb = jnp.where(anch, fb, pfb)
        anch = anch | panch
        d *= 2
    open_fw = jnp.where(anch, fw, copenw_ref[0])
    open_fb = jnp.where(anch, fb, copenb_ref[0])

    # Group finalized at slot i = the group open at slot i - 1.
    prev_open_fw = jnp.where(lane0, copenw_ref[0],
                             _shift_right(open_fw, 1, 0.0))
    prev_open_fb = jnp.where(lane0, copenb_ref[0],
                             _shift_right(open_fb, 1, 0))

    new_w = prev_w                                   # last slot wins
    old_w = jnp.where(prev_open_fb == 1, 0.0, prev_open_fw)
    live = prev_src != sent
    keep = is_first & live & (new_w > 0.0)
    # Batch slots outrank existing, so "group contains a batch slot" is
    # exactly "its last slot is a batch slot".
    changed = is_first & live & (prev_b == 1) & (old_w != new_w)

    kp = keep.astype(jnp.int32)
    incl = jnp.cumsum(kp, axis=1)
    keep_ref[...] = kp
    pos_ref[...] = ckept_ref[0] + incl - kp
    fsrc_ref[...] = prev_src
    fdst_ref[...] = prev_dst
    fw_ref[...] = new_w
    chg_ref[...] = changed.astype(jnp.int32)

    last = src.shape[1] - 1
    ckey_ref[0] = src[0, last]
    ckey_ref[1] = dst[0, last]
    clastw_ref[0] = w[0, last]
    clastb_ref[0] = b[0, last]
    copenw_ref[0] = open_fw[0, last]
    copenb_ref[0] = open_fb[0, last]
    ckept_ref[0] = ckept_ref[0] + incl[0, last]


@functools.partial(jax.jit, static_argnames=("sent", "block", "interpret"))
def resolve_groups_pallas(
    s_src: jax.Array,      # (total,) int32 — (src, dst)-sorted keys
    s_dst: jax.Array,      # (total,) int32
    s_w: jax.Array,        # (total,) f32 — slot weights in sorted order
    s_batch: jax.Array,    # (total,) bool — batch-slot flags
    *,
    sent: int,
    block: int = _BLOCK,
    interpret: bool | None = None,
) -> Tuple[jax.Array, ...]:
    """Per-slot group-finalization records over a sorted slot list.

    Returns (keep, pos, src, dst, w, changed), each of padded length
    >= total + 1 (at least one sentinel pad slot guarantees the last real
    group finalizes).  ``keep`` marks one slot per surviving group; ``pos``
    is its compaction position; ``changed`` marks one slot per group whose
    resolved weight differs from its pre-batch weight.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    total = s_src.shape[0]
    tiles = total // block + 1             # >= 1 trailing pad slot, always
    padded = tiles * block

    def pad(x, fill, dtype):
        return jnp.concatenate(
            [x.astype(dtype), jnp.full((padded - total,), fill, dtype)]
        ).reshape(tiles, block)

    ins = (pad(s_src, sent, jnp.int32), pad(s_dst, sent, jnp.int32),
           pad(s_w, 0.0, jnp.float32), pad(s_batch, 0, jnp.int32))

    row = pl.BlockSpec((1, block), lambda i: (i, 0))
    out_shape = (
        jax.ShapeDtypeStruct((tiles, block), jnp.int32),    # keep
        jax.ShapeDtypeStruct((tiles, block), jnp.int32),    # pos
        jax.ShapeDtypeStruct((tiles, block), jnp.int32),    # src
        jax.ShapeDtypeStruct((tiles, block), jnp.int32),    # dst
        jax.ShapeDtypeStruct((tiles, block), jnp.float32),  # w
        jax.ShapeDtypeStruct((tiles, block), jnp.int32),    # changed
    )
    if pltpu is not None:
        scratch = [pltpu.SMEM((2,), jnp.int32),     # prev slot key
                   pltpu.SMEM((1,), jnp.float32),   # prev slot w
                   pltpu.SMEM((1,), jnp.int32),     # prev slot batch
                   pltpu.SMEM((1,), jnp.float32),   # open-group first w
                   pltpu.SMEM((1,), jnp.int32),     # open-group first batch
                   pltpu.SMEM((1,), jnp.int32)]     # kept-count prefix
    else:  # pragma: no cover - interpret-only environments
        scratch = [jax.ShapeDtypeStruct((2,), jnp.int32),
                   jax.ShapeDtypeStruct((1,), jnp.float32),
                   jax.ShapeDtypeStruct((1,), jnp.int32),
                   jax.ShapeDtypeStruct((1,), jnp.float32),
                   jax.ShapeDtypeStruct((1,), jnp.int32),
                   jax.ShapeDtypeStruct((1,), jnp.int32)]

    outs = pl.pallas_call(
        functools.partial(_resolve_kernel, sent),
        grid=(tiles,),
        in_specs=[row, row, row, row],
        out_specs=[row] * 6,
        out_shape=out_shape,
        scratch_shapes=scratch,
        interpret=interpret,
    )(*ins)
    keep, pos, fsrc, fdst, fw, chg = (o.reshape(-1) for o in outs)
    return keep > 0, pos, fsrc, fdst, fw, chg > 0
