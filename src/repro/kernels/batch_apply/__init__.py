"""Pallas kernel for the edge-batch sort-reduce group-resolve."""

from repro.kernels.batch_apply.resolve import resolve_groups_pallas

__all__ = ["resolve_groups_pallas"]
