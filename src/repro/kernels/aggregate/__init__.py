"""Pallas kernel for the aggregation-phase group-detect + accumulate."""

from repro.kernels.aggregate.coarsen import coarsen_groups_pallas

__all__ = ["coarsen_groups_pallas"]
