"""Pallas TPU kernel: group-detect + weight-accumulate for aggregation.

``repro.core.aggregate.aggregate_graph`` coarsens a relabeled edge-slot list
``(C[i], C[j], w)`` into the community graph.  The XLA reference resolves the
post-sort slots with a global cumsum (group ids), a ``segment_sum`` (group
weights) and three scatters (coarse src/dst/w).  This kernel fuses the whole
post-sort reduce into ONE forward sweep over the sorted slots:

    tile t:   is_first   = (ci, cj) != shifted(ci, cj)     (group boundaries)
              open-sum   = segmented inclusive sum-scan of w
              finalize   = at each boundary, emit the group that just ended
                           (its key, its accumulated weight, its position)

The TPU grid is sequential, so cross-tile state (previous slot key, the open
group's partial weight sum, the emitted-group count) rides in SMEM scratch
between programs — the same carry-chain as ``repro.kernels.batch_apply``.
The preceding lexsort and the final scatter into the coarse CSR buffers
remain XLA's job (sorting and dynamic scatter are not TPU-kernel-friendly
primitives); the kernel returns per-slot (emit, pos, src, dst, w) group
records at each finalization point.

Exactness: group positions and keys are integers (always exact).  Group
weights are float32 sums; the in-tile segmented scan accumulates with a
balanced-tree association while XLA's ``segment_sum`` order is
implementation-defined, so the two backends agree bit-for-bit whenever the
sums are exact (integer-valued weights < 2^24 — all golden corpora) and to
float32 rounding otherwise.  ``tests/test_aggregate_kernel.py`` asserts
both regimes.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # scratch memory-space types live in the TPU namespace
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover - CPU-only wheels
    pltpu = None

_BLOCK = 512  # lanes per program (multiple of 128)


def _shift_right(x: jax.Array, d: int, fill) -> jax.Array:
    """(1, T) lane shift by ``d`` with constant fill on the left."""
    return jnp.concatenate(
        [jnp.full((1, d), fill, x.dtype), x[:, :-d]], axis=1)


def _coarsen_kernel(sent: int, ci_ref, cj_ref, w_ref,
                    emit_ref, pos_ref, gsrc_ref, gdst_ref, gw_ref,
                    ckey_ref, copen_ref, ccnt_ref):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _():
        # -2 differs from every real key (keys are in [0, sent]), so the
        # very first slot always opens a group; the phantom "previous
        # group" it finalizes is never emitted (prev_ci == -2).
        ckey_ref[0] = -2
        ckey_ref[1] = -2
        copen_ref[0] = 0.0
        ccnt_ref[0] = 0

    ci = ci_ref[...]                       # (1, T) int32
    cj = cj_ref[...]
    w = w_ref[...].astype(jnp.float32)

    # Lane 0's "previous slot" is the carry from the preceding tile.
    lane0 = jax.lax.broadcasted_iota(jnp.int32, ci.shape, 1) == 0
    prev_ci = jnp.where(lane0, ckey_ref[0], _shift_right(ci, 1, 0))
    prev_cj = jnp.where(lane0, ckey_ref[1], _shift_right(cj, 1, 0))
    is_first = (ci != prev_ci) | (cj != prev_cj)

    # Segmented inclusive sum-scan (Hillis-Steele): per slot, the weight sum
    # of its group FROM the group's first in-tile slot; slots whose group
    # opened in an earlier tile (no boundary anywhere left of them) add the
    # carried open-group partial sum.
    s, f = w, is_first
    d = 1
    while d < ci.shape[1]:
        ps = _shift_right(s, d, 0.0)
        pf = _shift_right(f, d, False)
        s = jnp.where(f, s, s + ps)
        f = f | pf
        d *= 2
    open_sum = jnp.where(f, s, s + copen_ref[0])

    # Group finalized at slot i = the group open at slot i - 1.
    prev_open = jnp.where(lane0, copen_ref[0], _shift_right(open_sum, 1, 0.0))
    emit = is_first & (prev_ci != sent) & (prev_ci >= 0)

    em = emit.astype(jnp.int32)
    incl = jnp.cumsum(em, axis=1)
    emit_ref[...] = em
    pos_ref[...] = ccnt_ref[0] + incl - em
    gsrc_ref[...] = prev_ci
    gdst_ref[...] = prev_cj
    gw_ref[...] = prev_open

    last = ci.shape[1] - 1
    ckey_ref[0] = ci[0, last]
    ckey_ref[1] = cj[0, last]
    copen_ref[0] = open_sum[0, last]
    ccnt_ref[0] = ccnt_ref[0] + incl[0, last]


@functools.partial(jax.jit, static_argnames=("sent", "block", "interpret"))
def coarsen_groups_pallas(
    s_ci: jax.Array,       # (total,) int32 — (ci, cj)-lexsorted src labels
    s_cj: jax.Array,       # (total,) int32 — dst labels in the same order
    s_w: jax.Array,        # (total,) f32 — slot weights in sorted order
    *,
    sent: int,
    block: int = _BLOCK,
    interpret: bool | None = None,
) -> Tuple[jax.Array, ...]:
    """Per-slot group-finalization records over a sorted relabeled slot list.

    Returns (emit, pos, g_src, g_dst, g_w), each of padded length >=
    total + 1 (at least one trailing sentinel pad slot guarantees the last
    live group finalizes).  ``emit`` marks one slot per live group; ``pos``
    is its dense group index (== the sort path's ``gid``, since live groups
    sort before sentinel padding); ``g_w`` its accumulated weight.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    total = s_ci.shape[0]
    tiles = total // block + 1             # >= 1 trailing pad slot, always
    padded = tiles * block

    def pad(x, fill, dtype):
        return jnp.concatenate(
            [x.astype(dtype), jnp.full((padded - total,), fill, dtype)]
        ).reshape(tiles, block)

    ins = (pad(s_ci, sent, jnp.int32), pad(s_cj, sent, jnp.int32),
           pad(s_w, 0.0, jnp.float32))

    row = pl.BlockSpec((1, block), lambda i: (i, 0))
    out_shape = (
        jax.ShapeDtypeStruct((tiles, block), jnp.int32),    # emit
        jax.ShapeDtypeStruct((tiles, block), jnp.int32),    # pos
        jax.ShapeDtypeStruct((tiles, block), jnp.int32),    # group src
        jax.ShapeDtypeStruct((tiles, block), jnp.int32),    # group dst
        jax.ShapeDtypeStruct((tiles, block), jnp.float32),  # group weight
    )
    if pltpu is not None:
        scratch = [pltpu.SMEM((2,), jnp.int32),     # prev slot key (ci, cj)
                   pltpu.SMEM((1,), jnp.float32),   # open-group partial sum
                   pltpu.SMEM((1,), jnp.int32)]     # emitted-group count
    else:  # pragma: no cover - interpret-only environments
        scratch = [jax.ShapeDtypeStruct((2,), jnp.int32),
                   jax.ShapeDtypeStruct((1,), jnp.float32),
                   jax.ShapeDtypeStruct((1,), jnp.int32)]

    outs = pl.pallas_call(
        functools.partial(_coarsen_kernel, sent),
        grid=(tiles,),
        in_specs=[row, row, row],
        out_specs=[row] * 5,
        out_shape=out_shape,
        scratch_shapes=scratch,
        interpret=interpret,
    )(*ins)
    emit, pos, gsrc, gdst, gw = (o.reshape(-1) for o in outs)
    return emit > 0, pos, gsrc, gdst, gw
