"""Fused Pallas TPU kernel: Louvain ELL scan + gated move decision.

The scan-only kernel (``louvain_scan.py``) returns per-row (best_c, best_dq)
and leaves the move *decision* — improvement test, round gate, singleton-swap
guard, frontier/validity masks — to the engine, which re-reads the tile
results from HBM to compute it.  This kernel fuses the whole Algorithm-2 row
body into the tile's single VMEM residency: each row leaves the kernel with
its decision made (``do_move``) and its target chosen, so the engine's apply
collapses to two cheap segment-sums (Sigma) and a scatter (C) with no second
pass over the scan output.

Decision inputs that are per-community lookups (|community| for the guard)
are pre-gathered per slot outside the kernel, like Sigma — XLA owns gathers,
the kernel stays dense.  The round gate is computed IN-kernel from the
vertex ids via the engine's own ``round_gate`` (pure jnp, one home for the
Weyl constants), so the fused decision is bit-identical to the engine's
generic path by construction — and pinned to it by tests.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import engine
from repro.kernels.louvain_scan.louvain_scan import dense_scan_tile

# The Weyl gate constants live in engine.py (their ONE home); Pallas kernels
# cannot close over device arrays, so rebind them as Python ints here — the
# in-kernel gate hash inlines them as literals and stays bit-identical to
# ``engine.round_gate``.
_GATE_MUL = int(engine.GATE_MUL)
_GATE_INC = int(engine.GATE_INC)


def fused_decision_tile(c, size_nbr, size_own, best_c, best_dq, c_own,
                        rows, front, round_ix, *, gate_fraction: int,
                        sentinel: int):
    """The gated move decision on one tile — pure jnp, shared kernel/ref.

    Mirrors ``repro.core.engine.gated_move_mask`` exactly, with the
    community-size lookups replaced by the pre-gathered per-slot ``size_nbr``
    / per-row ``size_own`` (``sizes[best_c]`` becomes a masked row-min over
    the slots holding the best community).  Returns (best_c mapped to
    ``sentinel`` when none, best_dq masked to -inf off-frontier, do_move).
    """
    found = best_c >= 0
    bc = jnp.where(found, best_c, jnp.int32(sentinel))

    # sizes[best_c] without a gather: every live slot in the best community
    # carries that community's size — min over them (big when none found).
    valid = (c >= 0) & (c != c_own)
    big = jnp.int32(jnp.iinfo(jnp.int32).max)
    size_best = jnp.min(
        jnp.where((c == bc) & valid, size_nbr, big), axis=1, keepdims=True)

    own_single = size_own == 1
    tgt_single = size_best == 1
    swap_blocked = own_single & tgt_single & (bc > c_own)
    do_move = ((best_dq > 0.0) & (bc != c_own) & (bc < sentinel)
               & front & ~swap_blocked)
    if gate_fraction > 1:
        # engine.round_gate, inlined with the int-rebound Weyl constants.
        h = (rows.astype(jnp.int32) * jnp.int32(_GATE_MUL)
             + round_ix.astype(jnp.int32) * jnp.int32(_GATE_INC))
        do_move = do_move & (jnp.abs(h >> 13) % gate_fraction == 0)
    best_dq = jnp.where(front, best_dq, jnp.float32(-jnp.inf))
    return bc, best_dq, do_move


def _make_fused_kernel(gate_fraction: int, sentinel: int):
    def kernel(
        c_ref,        # (B, D) int32 — neighbor communities, -1 dead
        w_ref,        # (B, D) f32  — neighbor edge weights, 0 dead
        sig_ref,      # (B, D) f32  — Sigma[target community]
        size_ref,     # (B, D) int32 — |target community|, 0 dead
        ki_ref,       # (B, 1) f32  — K_i
        cown_ref,     # (B, 1) int32
        sigown_ref,   # (B, 1) f32
        sizeown_ref,  # (B, 1) int32 — |own community|
        rows_ref,     # (B, 1) int32 — global vertex id (pad = sentinel)
        front_ref,    # (B, 1) int32 — frontier & move-valid (0/1)
        m_ref,        # (1, 1) f32  — total weight (broadcast)
        round_ref,    # (1, 1) int32 — round index (broadcast)
        bestc_ref,    # out (B, 1) int32 — sentinel-mapped best community
        bestdq_ref,   # out (B, 1) f32
        domove_ref,   # out (B, 1) int32 (0/1)
    ):
        c = c_ref[...]
        best_c, best_dq = dense_scan_tile(
            c, w_ref[...], sig_ref[...], ki_ref[...], cown_ref[...],
            sigown_ref[...], m_ref[0, 0])
        bc, bdq, do_move = fused_decision_tile(
            c, size_ref[...], sizeown_ref[...], best_c, best_dq,
            cown_ref[...], rows_ref[...], front_ref[...] > 0,
            round_ref[0, 0], gate_fraction=gate_fraction, sentinel=sentinel)
        bestc_ref[...] = bc
        bestdq_ref[...] = bdq
        domove_ref[...] = do_move.astype(jnp.int32)

    return kernel


@functools.partial(
    jax.jit,
    static_argnames=("gate_fraction", "sentinel", "block_rows", "interpret"))
def louvain_fused_pallas(
    c_nbr: jax.Array,      # (R, D) int32
    w_nbr: jax.Array,      # (R, D) f32
    sigma_nbr: jax.Array,  # (R, D) f32
    size_nbr: jax.Array,   # (R, D) int32
    k_i: jax.Array,        # (R, 1) f32
    c_own: jax.Array,      # (R, 1) int32
    sigma_own: jax.Array,  # (R, 1) f32
    size_own: jax.Array,   # (R, 1) int32
    rows: jax.Array,       # (R, 1) int32
    front: jax.Array,      # (R, 1) int32
    m: jax.Array,          # () or (1, 1) f32
    round_ix: jax.Array,   # () or (1, 1) int32
    *,
    gate_fraction: int,
    sentinel: int,
    block_rows: int = 8,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    r, d = c_nbr.shape
    assert r % block_rows == 0, (r, block_rows)
    m2d = jnp.reshape(m.astype(jnp.float32), (1, 1))
    r2d = jnp.reshape(round_ix.astype(jnp.int32), (1, 1))

    grid = (r // block_rows,)
    row_spec = lambda width: pl.BlockSpec((block_rows, width), lambda i: (i, 0))
    bcast = pl.BlockSpec((1, 1), lambda i: (0, 0))
    out_shape = (
        jax.ShapeDtypeStruct((r, 1), jnp.int32),
        jax.ShapeDtypeStruct((r, 1), jnp.float32),
        jax.ShapeDtypeStruct((r, 1), jnp.int32),
    )
    return pl.pallas_call(
        _make_fused_kernel(gate_fraction, sentinel),
        grid=grid,
        in_specs=[
            row_spec(d),                                   # c_nbr
            row_spec(d),                                   # w_nbr
            row_spec(d),                                   # sigma_nbr
            row_spec(d),                                   # size_nbr
            row_spec(1),                                   # k_i
            row_spec(1),                                   # c_own
            row_spec(1),                                   # sigma_own
            row_spec(1),                                   # size_own
            row_spec(1),                                   # rows
            row_spec(1),                                   # front
            bcast,                                         # m
            bcast,                                         # round_ix
        ],
        out_specs=[row_spec(1), row_spec(1), row_spec(1)],
        out_shape=out_shape,
        interpret=interpret,
    )(c_nbr, w_nbr, sigma_nbr, size_nbr, k_i, c_own, sigma_own, size_own,
      rows, front, m2d, r2d)


def louvain_fused_ref(
    c_nbr, w_nbr, sigma_nbr, size_nbr, k_i, c_own, sigma_own, size_own,
    rows, front, m, round_ix, *, gate_fraction: int, sentinel: int,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Pure-jnp oracle of the fused kernel (same tile math, no grid)."""
    best_c, best_dq = dense_scan_tile(c_nbr, w_nbr, sigma_nbr, k_i, c_own,
                                      sigma_own, jnp.asarray(m, jnp.float32))
    bc, bdq, do_move = fused_decision_tile(
        c_nbr, size_nbr, size_own, best_c, best_dq, c_own, rows, front > 0,
        jnp.asarray(round_ix, jnp.int32), gate_fraction=gate_fraction,
        sentinel=sentinel)
    return bc[:, 0], bdq[:, 0], do_move[:, 0].astype(jnp.int32)
