"""Jit'd public wrapper for the Louvain ELL scan kernel.

`louvain_scan` dispatches to the Pallas kernel (TPU target; interpret=True on
CPU) or the pure-jnp reference, choosing VMEM-safe block shapes per ELL width.
`prepare_ell_inputs` builds the pre-gathered per-slot arrays from graph state
(the gathers are XLA's job — Pallas TPU kernels keep to dense tiles).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.graph import ELLBlock
from repro.kernels.louvain_scan.fused import (louvain_fused_pallas,
                                              louvain_fused_ref)
from repro.kernels.louvain_scan.louvain_scan import louvain_scan_pallas
from repro.kernels.louvain_scan.ref import louvain_scan_ref

# width -> rows per program, keeping the (B, D, D) compare tile + operands
# comfortably inside ~4 MB of VMEM (paper-analogue of Far-KV sizing).
_BLOCK_ROWS = {16: 256, 64: 64, 256: 8, 1024: 1}


def block_rows_for_width(width: int) -> int:
    best = 8
    for w_key, rows in _BLOCK_ROWS.items():
        if width <= w_key:
            return rows
    return 1


def prepare_ell_inputs(
    block: ELLBlock,
    comm: jax.Array,       # (n_cap + 1,) int32
    sigma: jax.Array,      # (n_cap + 1,) f32
    k: jax.Array,          # (n_cap + 1,) f32
    n_cap: int,
) -> Tuple[jax.Array, ...]:
    """Gather per-slot community state for one ELL block (outside the kernel)."""
    rows, cols, w = block.rows, block.cols, block.w
    dead = (cols == n_cap) | (cols == rows[:, None])   # padding or self-loop
    c_nbr = jnp.where(dead, -1, comm[cols])
    w_nbr = jnp.where(dead, 0.0, w).astype(jnp.float32)
    sigma_nbr = jnp.where(dead, 0.0, sigma[jnp.maximum(c_nbr, 0)]).astype(jnp.float32)
    k_i = k[rows][:, None].astype(jnp.float32)
    c_own = comm[rows][:, None]
    sigma_own = sigma[c_own[:, 0]][:, None].astype(jnp.float32)
    return c_nbr, w_nbr, sigma_nbr, k_i, c_own, sigma_own


def prepare_fused_inputs(
    block: ELLBlock,
    comm: jax.Array,       # (n_cap + 1,) int32
    sigma: jax.Array,      # (n_cap + 1,) f32
    sizes: jax.Array,      # (n_cap + 1,) int32 — |community| per id
    k: jax.Array,          # (n_cap + 1,) f32
    front: jax.Array,      # (n_cap + 1,) bool — frontier & move-valid
    n_cap: int,
) -> Tuple[jax.Array, ...]:
    """Per-slot state for the fused scan+apply kernel (gathers stay in XLA).

    Extends ``prepare_ell_inputs`` with the decision inputs: per-slot and
    per-row community sizes (the singleton-swap guard), the row's global
    vertex id (the in-kernel round gate) and its frontier/validity bit.
    """
    c_nbr, w_nbr, sigma_nbr, k_i, c_own, sigma_own = prepare_ell_inputs(
        block, comm, sigma, k, n_cap)
    dead = c_nbr < 0
    size_nbr = jnp.where(dead, 0,
                         sizes[jnp.maximum(c_nbr, 0)]).astype(jnp.int32)
    size_own = sizes[c_own[:, 0]][:, None].astype(jnp.int32)
    rows = block.rows[:, None].astype(jnp.int32)
    front_rows = front[block.rows][:, None].astype(jnp.int32)
    return (c_nbr, w_nbr, sigma_nbr, size_nbr, k_i, c_own, sigma_own,
            size_own, rows, front_rows)


def louvain_fused(
    c_nbr: jax.Array,
    w_nbr: jax.Array,
    sigma_nbr: jax.Array,
    size_nbr: jax.Array,
    k_i: jax.Array,
    c_own: jax.Array,
    sigma_own: jax.Array,
    size_own: jax.Array,
    rows: jax.Array,
    front: jax.Array,
    m: jax.Array,
    round_ix: jax.Array,
    *,
    gate_fraction: int,
    sentinel: int,
    use_pallas: bool = True,
    interpret: bool | None = None,
    block_rows: int | None = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Fused (best_c, best_dq, do_move) per ELL row.  See fused.py."""
    if not use_pallas:
        return louvain_fused_ref(
            c_nbr, w_nbr, sigma_nbr, size_nbr, k_i, c_own, sigma_own,
            size_own, rows, front, m, round_ix,
            gate_fraction=gate_fraction, sentinel=sentinel)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    r, d = c_nbr.shape
    rows_per = block_rows or block_rows_for_width(d)
    rows_per = max(1, min(rows_per, r))
    while r % rows_per:  # shrink to a divisor of R (rows are align-padded)
        rows_per -= 1
    out_c, out_dq, out_mv = louvain_fused_pallas(
        c_nbr, w_nbr, sigma_nbr, size_nbr, k_i, c_own, sigma_own, size_own,
        rows, front, m, round_ix, gate_fraction=gate_fraction,
        sentinel=sentinel, block_rows=rows_per, interpret=interpret)
    return out_c[:, 0], out_dq[:, 0], out_mv[:, 0]


def louvain_scan(
    c_nbr: jax.Array,
    w_nbr: jax.Array,
    sigma_nbr: jax.Array,
    k_i: jax.Array,
    c_own: jax.Array,
    sigma_own: jax.Array,
    m: jax.Array,
    *,
    use_pallas: bool = True,
    interpret: bool | None = None,
    block_rows: int | None = None,
) -> Tuple[jax.Array, jax.Array]:
    """Best (community, dQ) per ELL row.  See ref.py for exact semantics."""
    if not use_pallas:
        return louvain_scan_ref(c_nbr, w_nbr, sigma_nbr, k_i, c_own, sigma_own, m)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    r, d = c_nbr.shape
    rows = block_rows or block_rows_for_width(d)
    rows = max(1, min(rows, r))
    while r % rows:  # shrink to a divisor of R (rows are align-padded anyway)
        rows -= 1
    out_c, out_dq = louvain_scan_pallas(
        c_nbr, w_nbr, sigma_nbr, k_i, c_own, sigma_own, m,
        block_rows=rows, interpret=interpret,
    )
    return out_c[:, 0], out_dq[:, 0]
