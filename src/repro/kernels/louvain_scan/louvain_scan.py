"""Pallas TPU kernel: Louvain best-community scan over ELL adjacency tiles.

This is the TPU-native replacement for the paper's Far-KV collision-free
per-thread hashtable (§4.1.9).  On a CPU, scanCommunities() accumulates
K_{i->c} into a values array indexed by community id; on a TPU the idiomatic
form is a dense all-pairs equality compare inside VMEM: for a tile of vertices
whose (padded) neighbor lists sit in registers, the per-community sums are

    K[r, d] = sum_e w[r, e] * [c[r, e] == c[r, d]]

i.e. one masked (D x D) matvec per row — MXU/VPU work instead of scattered
memory traffic, collision-free by construction.  The best-move selection
(Alg. 2 lines 8-9) is fused into the same kernel, so each tile makes exactly
one trip HBM -> VMEM -> HBM.

Grid: one program per tile of ``block_rows`` vertices.  VMEM per program
is ~ block_rows * D * (3 inputs * 4B) + block_rows * D * D transient, bounded
by the (block_rows, width)-tuned table in ops.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def dense_scan_tile(c, w, sig, k_i, c_own, sig_own, m):
    """The dense best-move scan of one (B, D) tile — pure jnp.

    Shared by the scan-only kernel below and the fused scan+apply kernel
    (``fused.py``): both must produce bit-identical (best_c, best_dq), so
    the math lives exactly once.  Returns ((B, 1) int32 best community with
    -1 = none, (B, 1) f32 best dQ with -inf = none).
    """
    w = w.astype(jnp.float32)
    sig = sig.astype(jnp.float32)
    k_i = k_i.astype(jnp.float32)                   # (B, 1)
    sig_own = sig_own.astype(jnp.float32)

    # Collision-free community scan: dense pairwise equality, then a batched
    # matvec against the weights (MXU-friendly: (B*D, D) x (D,) contractions).
    eq = (c[:, :, None] == c[:, None, :]) & (c[:, None, :] >= 0)
    k_to = jax.lax.dot_general(
        eq.astype(jnp.float32),
        w[:, :, None],
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )[:, :, 0]                                      # (B, D)

    k_own = jnp.sum(jnp.where(c == c_own, w, 0.0), axis=1, keepdims=True)

    dq = (k_to - k_own) / m - k_i * (k_i + sig - sig_own) / (2.0 * m * m)

    valid = (c >= 0) & (c != c_own)
    neg_inf = jnp.float32(-jnp.inf)
    dq = jnp.where(valid, dq, neg_inf)
    best_dq = jnp.max(dq, axis=1, keepdims=True)    # (B, 1)
    is_best = (dq == best_dq) & valid
    big = jnp.int32(jnp.iinfo(jnp.int32).max)
    best_c = jnp.min(jnp.where(is_best, c, big), axis=1, keepdims=True)
    found = jnp.isfinite(best_dq)
    return (jnp.where(found, best_c, jnp.int32(-1)),
            jnp.where(found, best_dq, neg_inf))


def _scan_kernel(
    c_ref,          # (B, D) int32 — neighbor communities, -1 dead
    w_ref,          # (B, D) f32  — neighbor edge weights, 0 dead
    sig_ref,        # (B, D) f32  — Sigma[target community]
    ki_ref,         # (B, 1) f32  — K_i
    cown_ref,       # (B, 1) int32
    sigown_ref,     # (B, 1) f32
    m_ref,          # (1, 1) f32  — total weight (broadcast to every program)
    bestc_ref,      # out (B, 1) int32
    bestdq_ref,     # out (B, 1) f32
):
    best_c, best_dq = dense_scan_tile(
        c_ref[...], w_ref[...], sig_ref[...], ki_ref[...], cown_ref[...],
        sigown_ref[...], m_ref[0, 0])
    bestc_ref[...] = best_c
    bestdq_ref[...] = best_dq


@functools.partial(
    jax.jit, static_argnames=("block_rows", "interpret")
)
def louvain_scan_pallas(
    c_nbr: jax.Array,      # (R, D) int32
    w_nbr: jax.Array,      # (R, D) f32 (or bf16)
    sigma_nbr: jax.Array,  # (R, D) f32
    k_i: jax.Array,        # (R, 1) f32
    c_own: jax.Array,      # (R, 1) int32
    sigma_own: jax.Array,  # (R, 1) f32
    m: jax.Array,          # () or (1, 1) f32
    *,
    block_rows: int = 8,
    interpret: bool = False,
):
    r, d = c_nbr.shape
    assert r % block_rows == 0, (r, block_rows)
    m2d = jnp.reshape(m.astype(jnp.float32), (1, 1))

    grid = (r // block_rows,)
    row_spec = lambda width: pl.BlockSpec((block_rows, width), lambda i: (i, 0))
    out_shape = (
        jax.ShapeDtypeStruct((r, 1), jnp.int32),
        jax.ShapeDtypeStruct((r, 1), jnp.float32),
    )
    return pl.pallas_call(
        _scan_kernel,
        grid=grid,
        in_specs=[
            row_spec(d),                                   # c_nbr
            row_spec(d),                                   # w_nbr
            row_spec(d),                                   # sigma_nbr
            row_spec(1),                                   # k_i
            row_spec(1),                                   # c_own
            row_spec(1),                                   # sigma_own
            pl.BlockSpec((1, 1), lambda i: (0, 0)),        # m (broadcast)
        ],
        out_specs=[row_spec(1), row_spec(1)],
        out_shape=out_shape,
        interpret=interpret,
    )(c_nbr, w_nbr, sigma_nbr, k_i, c_own, sigma_own, m2d)
