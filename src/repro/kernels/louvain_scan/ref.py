"""Pure-jnp oracle for the Louvain ELL best-community scan.

Semantics (per ELL row r = one vertex i):
  K_{i->c_d} = sum_e w[r,e] * [c[r,e] == c[r,d]]           (collision-free scan)
  K_{i->own} = sum_e w[r,e] * [c[r,e] == c_own[r]]
  dQ_d       = (K_d - K_own)/m - k_i*(k_i + Sigma_{c_d} - Sigma_own)/(2 m^2)
  best slot  = argmax_d dQ_d over valid slots (c_d >= 0, c_d != c_own),
               ties broken to the smallest community id.
Outputs per row: (best_c int32 — or -1 if no valid slot, best_dq f32).

Inputs are pre-masked: padding/self-loop slots carry w == 0 and c == -1.
"""

from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp


def louvain_scan_ref(
    c_nbr: jnp.ndarray,      # (R, D) int32, -1 for dead slots
    w_nbr: jnp.ndarray,      # (R, D) float, 0 for dead slots
    sigma_nbr: jnp.ndarray,  # (R, D) float — Sigma[c_nbr], any value at dead slots
    k_i: jnp.ndarray,        # (R, 1) float — vertex weighted degree
    c_own: jnp.ndarray,      # (R, 1) int32 — current community
    sigma_own: jnp.ndarray,  # (R, 1) float — Sigma[c_own]
    m: jnp.ndarray,          # () float — total graph weight
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    w = w_nbr.astype(jnp.float32)
    eq = (c_nbr[:, :, None] == c_nbr[:, None, :]) & (c_nbr[:, None, :] >= 0)
    k_to = jnp.einsum("rde,re->rd", eq.astype(jnp.float32), w)  # (R, D)
    k_own = jnp.sum(jnp.where(c_nbr == c_own, w, 0.0), axis=1, keepdims=True)

    k_i = k_i.astype(jnp.float32)
    dq = (k_to - k_own) / m - k_i * (
        k_i + sigma_nbr.astype(jnp.float32) - sigma_own.astype(jnp.float32)
    ) / (2.0 * m * m)

    valid = (c_nbr >= 0) & (c_nbr != c_own)
    dq = jnp.where(valid, dq, -jnp.inf)
    best_dq = jnp.max(dq, axis=1)
    is_best = (dq == best_dq[:, None]) & valid
    big = jnp.iinfo(jnp.int32).max
    best_c = jnp.min(jnp.where(is_best, c_nbr, big), axis=1)
    best_c = jnp.where(jnp.isfinite(best_dq), best_c, -1)
    best_dq = jnp.where(jnp.isfinite(best_dq), best_dq, -jnp.inf)
    return best_c.astype(jnp.int32), best_dq.astype(jnp.float32)
