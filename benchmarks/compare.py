"""Perf-regression gate over the committed ``BENCH_*.json`` artifacts.

    PYTHONPATH=src python -m benchmarks.compare --baseline . --fresh out/

Loads each ``BENCH_<name>.json`` present in BOTH directories, matches rows
by their identity fields (graph / backend / batch shape — everything that
names a configuration rather than measures it), and fails when a fresh
throughput metric regresses beyond the threshold:

  * ``updates_per_s_*``  — higher is better; fail when fresh drops more
    than ``threshold`` (default 25%) below the baseline.
  * ``bytes_per_round``  — lower is better; fail when fresh grows more
    than ``threshold`` above the baseline.
  * ``bytes_per_dispatch`` — lower is better (the fleet analogue: fleet
    rows have no ``bytes_per_round``, so without this a PR could inflate
    the batched dispatch wire unnoticed).

Rows or files present on only one side are reported but never fail the
gate (PRs add new benchmarks; deletions show up in review) — UNLESS the
gate was pointed at them by name: a ``--names`` entry missing from either
directory (or unreadable) exits 2, so a typo'd or silently-skipped gate
can never compare nothing and pass.  Exit status: 0 = no regressions,
1 = at least one regression, 2 = usage error / named artifact missing.
CI runs this non-blocking on pull requests (timing noise on shared
runners) and blocking on pushes to main.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys
from typing import Dict, List, Optional, Tuple

# Fields that NAME a row (a configuration) rather than measure it; the
# match key is the subset present in the row, in this order.
IDENTITY_FIELDS = (
    "graph", "kind", "metric", "artifact", "config", "comm_backend",
    "state_layout", "agg_backend", "ladder", "reshard", "batch_size",
    "n_batches", "n_streams", "n_steps", "n_tenants", "pass", "work_cap",
)

# (prefix-match?, field, higher_is_better)
HIGHER_BETTER_PREFIX = "updates_per_s_"
LOWER_BETTER_FIELDS = ("bytes_per_round", "bytes_per_dispatch")


def row_key(row: dict) -> Tuple:
    return tuple((f, row[f]) for f in IDENTITY_FIELDS if f in row)


def tracked_metrics(row: dict) -> List[Tuple[str, bool]]:
    """(field, higher_is_better) for every gated metric in the row."""
    out = [(k, True) for k in row if k.startswith(HIGHER_BETTER_PREFIX)]
    out += [(k, False) for k in LOWER_BETTER_FIELDS if k in row]
    return sorted(out)


def _num(v) -> Optional[float]:
    if isinstance(v, bool) or not isinstance(v, (int, float)):
        return None
    return float(v)


def compare_rows(base_rows: List[dict], fresh_rows: List[dict],
                 threshold: float, bench: str) -> List[dict]:
    """Regressions between two row lists of the same benchmark.

    Rows pair up by identity key; duplicate keys pair positionally within
    the key group (e.g. repeated passes of one configuration).
    """
    def grouped(rows):
        g: Dict[Tuple, List[dict]] = {}
        for r in rows:
            g.setdefault(row_key(r), []).append(r)
        return g

    base_g, fresh_g = grouped(base_rows), grouped(fresh_rows)
    regressions = []
    for key, brows in base_g.items():
        for b, f in zip(brows, fresh_g.get(key, [])):
            for field, higher in tracked_metrics(b):
                bv, fv = _num(b.get(field)), _num(f.get(field))
                if bv is None or fv is None or bv <= 0:
                    continue
                ratio = fv / bv
                bad = ratio < 1 - threshold if higher else ratio > 1 + threshold
                if bad:
                    regressions.append({
                        "bench": bench, "field": field,
                        "key": dict(key), "baseline": bv, "fresh": fv,
                        "ratio": ratio, "higher_is_better": higher,
                    })
    return regressions


def load_bench(path: str) -> Optional[List[dict]]:
    try:
        with open(path) as fh:
            doc = json.load(fh)
    except (OSError, json.JSONDecodeError):
        return None
    rows = doc.get("rows")
    return rows if isinstance(rows, list) else None


def compare_dirs(baseline: str, fresh: str, threshold: float,
                 names: Optional[List[str]] = None):
    """(regressions, compared_names, notes, errors) across two artifact dirs.

    Without ``names``, files present on only one side are notes (PRs add
    benchmarks, deletions show up in review).  WITH ``names`` the caller
    asked for those gates specifically, so a named artifact missing from
    either side — or unreadable — is an ERROR, not a note: a typo'd or
    skipped gate must never silently compare nothing and pass.
    """
    def found(d):
        return {os.path.basename(p)[len("BENCH_"):-len(".json")]: p
                for p in sorted(glob.glob(os.path.join(d, "BENCH_*.json")))}

    base_f, fresh_f = found(baseline), found(fresh)
    errors = []
    if names:
        for name in names:
            if name not in base_f:
                errors.append(f"{name}: named but no BENCH_{name}.json "
                              f"under baseline {baseline!r}")
            if name not in fresh_f:
                errors.append(f"{name}: named but no BENCH_{name}.json "
                              f"under fresh {fresh!r}")
        base_f = {k: v for k, v in base_f.items() if k in names}
        fresh_f = {k: v for k, v in fresh_f.items() if k in names}
    regressions, compared, notes = [], [], []
    for name in sorted(set(base_f) | set(fresh_f)):
        if name not in base_f:
            notes.append(f"{name}: only in fresh (new benchmark, not gated)")
            continue
        if name not in fresh_f:
            notes.append(f"{name}: only in baseline (fresh run skipped it)")
            continue
        b, f = load_bench(base_f[name]), load_bench(fresh_f[name])
        if b is None or f is None:
            msg = f"{name}: unreadable artifact"
            if names:
                errors.append(msg)
            else:
                notes.append(msg + ", skipped")
            continue
        compared.append(name)
        regressions += compare_rows(b, f, threshold, name)
    return regressions, compared, notes, errors


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", default=".",
                    help="directory holding the committed BENCH_*.json")
    ap.add_argument("--fresh", required=True,
                    help="directory holding the freshly produced BENCH_*.json")
    ap.add_argument("--names", default=None,
                    help="comma-separated benchmark names to gate "
                         "(default: every artifact present in both dirs)")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="relative slack before a metric counts as a "
                         "regression (default 0.25 = 25%%)")
    args = ap.parse_args()
    if not (0 < args.threshold < 10):
        print(f"error: --threshold {args.threshold} out of range (0, 10)",
              file=sys.stderr)
        sys.exit(2)
    names = ([s.strip() for s in args.names.split(",") if s.strip()]
             if args.names else None)
    regressions, compared, notes, errors = compare_dirs(
        args.baseline, args.fresh, args.threshold, names)
    for note in notes:
        print(f"note: {note}")
    if errors:
        for err in errors:
            print(f"error: {err}", file=sys.stderr)
        sys.exit(2)
    print(f"compared {len(compared)} benchmark(s): "
          f"{', '.join(compared) or '(none)'}")
    if not regressions:
        print(f"no regressions beyond {args.threshold:.0%}")
        return
    print(f"\n{len(regressions)} regression(s) beyond {args.threshold:.0%}:")
    for r in regressions:
        arrow = "fell" if r["higher_is_better"] else "grew"
        key = ", ".join(f"{k}={v}" for k, v in r["key"].items()) or "(row)"
        print(f"  {r['bench']}[{key}] {r['field']}: "
              f"{r['baseline']:g} -> {r['fresh']:g} "
              f"({arrow} to {r['ratio']:.2f}x baseline)")
    sys.exit(1)


if __name__ == "__main__":
    main()
