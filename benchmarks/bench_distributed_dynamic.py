"""Distributed streaming Louvain: updates/sec vs cold sharded recompute.

The sharded analogue of ``bench_dynamic``: an SBM graph is streamed as
edge-batch inserts through ``louvain_dynamic_sharded`` (partition once, then
per batch: in-layout shard_map apply + delta-screened warm restart) and
compared against the batch-only baseline — a cold ``distributed_louvain``
(fresh partition, singleton start) after every batch.  Each batch size runs
under BOTH communication backends (replicated ``gather`` round-trips vs the
delta exchange of packed moved labels + top-k Sigma deltas), so the rows
report edge updates/sec, speedup over cold recompute, mean delta-screened
frontier fraction, the modularity gap on the final graph, and the measured
bytes-on-wire per engine round per backend.  A third configuration per
batch size runs gather under ``state_layout="hybrid"`` (owner-partitioned
working state; rows carry ``state_layout`` / ``halo_bytes_per_round`` /
``boundary_frac`` and measured ``pass_seconds_total``) — the acceptance
contrast is its ``bytes_per_round`` against the replicated gather row.

Every row also carries the skew-aware re-shard counters (``reshard_passes``,
``reshard_bytes``, ``max_shard_load_frac_before`` / ``_after`` — None when no
pass re-sharded) and the worst coarse-pass edge tier ``coarse_e_per_max``.
A second section streams a skew-OWNED corpus (hot interconnected cliques on
a sparse ring, so aggregation concentrates the coarse edges onto shard 0's
uniform owner range) head-to-head under ``reshard="none"`` vs ``"auto"``:
the auto row must run its coarse passes at a strictly lower capacity tier,
which is the win the one-time priced ``reshard_bytes`` shuffle buys.

Executed as a script it forces 8 host devices (it must own the process
before JAX initializes, which is why ``benchmarks.run`` launches it as a
subprocess); inside an existing JAX process it degrades to however many
devices are visible.

    PYTHONPATH=src python -m benchmarks.bench_distributed_dynamic [--full]
"""

from __future__ import annotations

import os

if __name__ == "__main__":  # must precede the first jax import
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                               + os.environ.get("XLA_FLAGS", ""))

import numpy as np

from benchmarks.common import emit_csv, time_fn
from repro.core.delta import apply_edge_batch, make_edge_batch
from repro.core.distributed import distributed_louvain
from repro.core.distributed_dynamic import louvain_dynamic_sharded
from repro.core.graph import build_csr
from repro.core.louvain import LouvainConfig, membership_modularity
from repro.data import sbm_graph


def _mesh_axes():
    import jax

    from repro.compat import make_mesh

    if jax.device_count() >= 8:
        return make_mesh((2, 4), ("data", "model")), ("data", "model")
    n = jax.device_count()
    return make_mesh((n,), ("shard",)), ("shard",)


def _holdout_stream(small: bool, seed: int = 0):
    n_comms, size = (32, 16) if small else (96, 24)
    full, _ = sbm_graph(n_communities=n_comms, size=size, p_in=0.4,
                        p_out=0.002, seed=seed)
    e = int(full.e_valid)
    src = np.asarray(full.src)[:e]
    dst = np.asarray(full.indices)[:e]
    w = np.asarray(full.weights)[:e]
    und = src < dst
    us, ud, uw = src[und], dst[und], w[und]
    rng = np.random.default_rng(seed)
    n_hold = min(len(us) // 4, 240 if small else 2000)
    hold = rng.choice(len(us), n_hold, replace=False)
    keep = np.ones(len(us), bool)
    keep[hold] = False
    init = build_csr(np.concatenate([us[keep], ud[keep]]),
                     np.concatenate([ud[keep], us[keep]]),
                     np.concatenate([uw[keep], uw[keep]]),
                     int(full.n_valid), e_cap=e + 8)
    return init, (us[hold], ud[hold], uw[hold]), e


def _skewed_stream(n_cliques: int = 64, hot: int = 8, csize: int = 5,
                   holdout: int = 8):
    """Skew-owned corpus: cliques coarsen to a contiguous id prefix whose
    first ``hot`` members are all-pairs interconnected — the uniform owner
    split overloads shard 0 after aggregation.  The ring's first ``holdout``
    edges form the insert stream."""
    edges = []

    def vid(c, i):
        return c * csize + i

    for c in range(n_cliques):
        for i in range(csize):
            for j in range(i + 1, csize):
                edges.append((vid(c, i), vid(c, j), 1.0))
    for a in range(hot):
        for b in range(a + 1, hot):
            edges.append((vid(a, a % csize), vid(b, b % csize), 0.25))
    ring = [(vid(c, 0), vid((c + 1) % n_cliques, 1), 0.25)
            for c in range(n_cliques)]
    held, kept = ring[:holdout], ring[holdout:]
    n = n_cliques * csize

    def arr(es):
        return (np.array([e[0] for e in es]), np.array([e[1] for e in es]),
                np.array([e[2] for e in es], np.float32))

    s, d, w = arr(edges + kept)
    init = build_csr(s, d, w, n, symmetrize=True,
                     e_cap=2 * (len(edges) + len(ring)) + 64)
    hs, hd, hw = arr(held)
    return init, (hs.astype(np.int32), hd.astype(np.int32), hw)


def _reshard_cols(dyn) -> dict:
    return {
        "reshard_passes": int(dyn.reshard_passes),
        "reshard_bytes": int(dyn.reshard_bytes),
        "max_shard_load_frac_before": dyn.max_shard_load_frac_before,
        "max_shard_load_frac_after": dyn.max_shard_load_frac_after,
        "coarse_e_per_max": int(dyn.coarse_e_per_max),
    }


def run(small: bool = True, repeats: int = 3,
        batch_sizes=(4, 16)) -> None:
    mesh, axes = _mesh_axes()
    init, (us, ud, uw), e = _holdout_stream(small)
    # Cold runs re-partition per batch with skew headroom (aggregation can
    # concentrate the SBM's coarse edges onto one shard).
    prev, _, _ = distributed_louvain(init, mesh, axes, e_per_shard=e)
    rows = []
    for bs in batch_sizes:
        n_batches = max(1, min(len(us) // bs, 12))
        used = n_batches * bs
        batches = [make_edge_batch(us[i * bs:(i + 1) * bs],
                                   ud[i * bs:(i + 1) * bs],
                                   uw[i * bs:(i + 1) * bs],
                                   init.n_cap, b_cap=bs)
                   for i in range(n_batches)]

        # Batch-only baseline: apply the delta, then a cold sharded run
        # (fresh partition + singleton start) after every batch.  Timed
        # once per batch size — it has no streaming exchange, so it is
        # independent of the comm backend under test.
        def recompute():
            g = init
            mem = None
            for b in batches:
                g, _ = apply_edge_batch(g, b)
                mem, _, _ = distributed_louvain(g, mesh, axes,
                                                e_per_shard=e)
            return g, mem

        t_cold, (g_end, mem_cold) = time_fn(recompute, repeats=repeats)
        q_cold = membership_modularity(g_end, mem_cold)

        # Both comm backends under the replicated layout, plus the hybrid
        # owner-partitioned layout under gather — the combination where
        # partitioning the working state pays (the delta wire's Sigma f32
        # lanes make delta x hybrid a premium, documented in the README).
        for backend, layout in (("gather", "replicated"),
                                ("delta", "replicated"),
                                ("gather", "hybrid")):
            t_dyn, dyn = time_fn(louvain_dynamic_sharded, init, mesh, axes,
                                 batches, prev=prev,
                                 config=LouvainConfig(comm_backend=backend,
                                                      state_layout=layout),
                                 repeats=repeats)
            q_dyn = membership_modularity(g_end, dyn.membership)
            fr = [s.frontier_fraction for s in dyn.batch_stats]
            rows.append({
                "graph": "sbm_holdout", "reshard": "none",
                "batch_size": bs, "n_batches": n_batches,
                "comm_backend": dyn.comm_backend,
                "state_layout": dyn.state_layout,
                "updates_per_s_dynamic": round(used / t_dyn, 1),
                "updates_per_s_recompute": round(used / t_cold, 1),
                "speedup": round(t_cold / t_dyn, 2),
                "bytes_per_round": round(dyn.bytes_per_round, 1),
                "bytes_on_wire": int(dyn.bytes_on_wire),
                "halo_bytes_per_round": round(dyn.halo_bytes_per_round, 1),
                "boundary_frac": (None if dyn.boundary_frac is None
                                  else round(dyn.boundary_frac, 4)),
                "comm_rounds": int(dyn.comm_rounds),
                "comm_fallback_rounds": int(dyn.comm_fallback_rounds),
                "pass_seconds_total": round(dyn.pass_seconds_total, 4),
                "frontier_frac_mean": round(float(np.mean(fr)), 4),
                "q_dynamic": round(q_dyn, 4),
                "q_recompute": round(q_cold, 4),
                **_reshard_cols(dyn),
            })

    # Skew-owned head-to-head: same stream, reshard off vs on (the auto
    # row also exercises the pipelined convergence fetch).  No cold
    # baseline — the contrast under test is the coarse capacity tier.
    sk_init, (ss, sd, sw) = _skewed_stream()
    sbs = 4
    sk_batches = [make_edge_batch(ss[i:i + sbs], sd[i:i + sbs],
                                  sw[i:i + sbs], sk_init.n_cap, b_cap=sbs)
                  for i in range(0, len(ss), sbs)]
    sk_end = sk_init
    for b in sk_batches:
        sk_end, _ = apply_edge_batch(sk_end, b)
    for mode in ("none", "auto"):
        cfg = LouvainConfig(comm_backend="delta", reshard=mode,
                            pipeline_fetch=(mode == "auto"))
        t_dyn, dyn = time_fn(louvain_dynamic_sharded, sk_init, mesh, axes,
                             sk_batches, config=cfg, repeats=repeats)
        rows.append({
            "graph": "skewed_clique", "reshard": mode,
            "batch_size": sbs, "n_batches": len(sk_batches),
            "comm_backend": dyn.comm_backend,
            "state_layout": dyn.state_layout,
            "updates_per_s_dynamic": round(len(ss) / t_dyn, 1),
            "bytes_per_round": round(dyn.bytes_per_round, 1),
            "bytes_on_wire": int(dyn.bytes_on_wire),
            "halo_bytes_per_round": round(dyn.halo_bytes_per_round, 1),
            "boundary_frac": (None if dyn.boundary_frac is None
                              else round(dyn.boundary_frac, 4)),
            "comm_rounds": int(dyn.comm_rounds),
            "comm_fallback_rounds": int(dyn.comm_fallback_rounds),
            # Measured pass wall-clock: the number reshard="auto"'s priced
            # tier win must actually show up in (none vs auto row).
            "pass_seconds_total": round(dyn.pass_seconds_total, 4),
            "q_dynamic": round(membership_modularity(
                sk_end, dyn.membership), 4),
            **_reshard_cols(dyn),
        })
    e_none = next(r["coarse_e_per_max"] for r in rows
                  if r["graph"] == "skewed_clique" and r["reshard"] == "none")
    e_auto = next(r["coarse_e_per_max"] for r in rows
                  if r["graph"] == "skewed_clique" and r["reshard"] == "auto")
    print(f"skewed_clique coarse tier: none={e_none} auto={e_auto} "
          f"({'LOWER' if e_auto < e_none else 'not lower'})")
    emit_csv(rows, ["graph", "reshard", "batch_size", "n_batches",
                    "comm_backend", "state_layout",
                    "updates_per_s_dynamic", "updates_per_s_recompute",
                    "speedup", "bytes_per_round", "bytes_on_wire",
                    "halo_bytes_per_round", "boundary_frac", "comm_rounds",
                    "comm_fallback_rounds", "pass_seconds_total",
                    "frontier_frac_mean", "q_dynamic", "q_recompute",
                    "reshard_passes", "reshard_bytes",
                    "max_shard_load_frac_before", "max_shard_load_frac_after",
                    "coarse_e_per_max"])
    return rows


if __name__ == "__main__":
    import argparse
    import time

    import jax

    from benchmarks.common import emit_json

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    print(f"devices: {jax.device_count()}")
    t0 = time.perf_counter()
    rows = run(small=not args.full, repeats=3)
    # This module runs as its own process (forced device count), so it
    # emits its BENCH json here rather than via benchmarks/run.py.
    emit_json("distdyn", rows, seconds=time.perf_counter() - t0,
              small=not args.full)
