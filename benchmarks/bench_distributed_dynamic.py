"""Distributed streaming Louvain: updates/sec vs cold sharded recompute.

The sharded analogue of ``bench_dynamic``: an SBM graph is streamed as
edge-batch inserts through ``louvain_dynamic_sharded`` (partition once, then
per batch: in-layout shard_map apply + delta-screened warm restart) and
compared against the batch-only baseline — a cold ``distributed_louvain``
(fresh partition, singleton start) after every batch.  Each batch size runs
under BOTH communication backends (replicated ``gather`` round-trips vs the
delta exchange of packed moved labels + top-k Sigma deltas), so the rows
report edge updates/sec, speedup over cold recompute, mean delta-screened
frontier fraction, the modularity gap on the final graph, and the measured
bytes-on-wire per engine round per backend.

Executed as a script it forces 8 host devices (it must own the process
before JAX initializes, which is why ``benchmarks.run`` launches it as a
subprocess); inside an existing JAX process it degrades to however many
devices are visible.

    PYTHONPATH=src python -m benchmarks.bench_distributed_dynamic [--full]
"""

from __future__ import annotations

import os

if __name__ == "__main__":  # must precede the first jax import
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                               + os.environ.get("XLA_FLAGS", ""))

import numpy as np

from benchmarks.common import emit_csv, time_fn
from repro.core.delta import apply_edge_batch, make_edge_batch
from repro.core.distributed import distributed_louvain
from repro.core.distributed_dynamic import louvain_dynamic_sharded
from repro.core.graph import build_csr
from repro.core.louvain import LouvainConfig, membership_modularity
from repro.data import sbm_graph


def _mesh_axes():
    import jax

    from repro.compat import make_mesh

    if jax.device_count() >= 8:
        return make_mesh((2, 4), ("data", "model")), ("data", "model")
    n = jax.device_count()
    return make_mesh((n,), ("shard",)), ("shard",)


def _holdout_stream(small: bool, seed: int = 0):
    n_comms, size = (32, 16) if small else (96, 24)
    full, _ = sbm_graph(n_communities=n_comms, size=size, p_in=0.4,
                        p_out=0.002, seed=seed)
    e = int(full.e_valid)
    src = np.asarray(full.src)[:e]
    dst = np.asarray(full.indices)[:e]
    w = np.asarray(full.weights)[:e]
    und = src < dst
    us, ud, uw = src[und], dst[und], w[und]
    rng = np.random.default_rng(seed)
    n_hold = min(len(us) // 4, 240 if small else 2000)
    hold = rng.choice(len(us), n_hold, replace=False)
    keep = np.ones(len(us), bool)
    keep[hold] = False
    init = build_csr(np.concatenate([us[keep], ud[keep]]),
                     np.concatenate([ud[keep], us[keep]]),
                     np.concatenate([uw[keep], uw[keep]]),
                     int(full.n_valid), e_cap=e + 8)
    return init, (us[hold], ud[hold], uw[hold]), e


def run(small: bool = True, repeats: int = 3,
        batch_sizes=(4, 16)) -> None:
    mesh, axes = _mesh_axes()
    init, (us, ud, uw), e = _holdout_stream(small)
    # Cold runs re-partition per batch with skew headroom (aggregation can
    # concentrate the SBM's coarse edges onto one shard).
    prev, _, _ = distributed_louvain(init, mesh, axes, e_per_shard=e)
    rows = []
    for bs in batch_sizes:
        n_batches = max(1, min(len(us) // bs, 12))
        used = n_batches * bs
        batches = [make_edge_batch(us[i * bs:(i + 1) * bs],
                                   ud[i * bs:(i + 1) * bs],
                                   uw[i * bs:(i + 1) * bs],
                                   init.n_cap, b_cap=bs)
                   for i in range(n_batches)]

        # Batch-only baseline: apply the delta, then a cold sharded run
        # (fresh partition + singleton start) after every batch.  Timed
        # once per batch size — it has no streaming exchange, so it is
        # independent of the comm backend under test.
        def recompute():
            g = init
            mem = None
            for b in batches:
                g, _ = apply_edge_batch(g, b)
                mem, _, _ = distributed_louvain(g, mesh, axes,
                                                e_per_shard=e)
            return g, mem

        t_cold, (g_end, mem_cold) = time_fn(recompute, repeats=repeats)
        q_cold = membership_modularity(g_end, mem_cold)

        for backend in ("gather", "delta"):
            t_dyn, dyn = time_fn(louvain_dynamic_sharded, init, mesh, axes,
                                 batches, prev=prev,
                                 config=LouvainConfig(comm_backend=backend),
                                 repeats=repeats)
            q_dyn = membership_modularity(g_end, dyn.membership)
            fr = [s.frontier_fraction for s in dyn.batch_stats]
            rows.append({
                "batch_size": bs, "n_batches": n_batches,
                "comm_backend": dyn.comm_backend,
                "updates_per_s_dynamic": round(used / t_dyn, 1),
                "updates_per_s_recompute": round(used / t_cold, 1),
                "speedup": round(t_cold / t_dyn, 2),
                "bytes_per_round": round(dyn.bytes_per_round, 1),
                "bytes_on_wire": int(dyn.bytes_on_wire),
                "comm_rounds": int(dyn.comm_rounds),
                "comm_fallback_rounds": int(dyn.comm_fallback_rounds),
                "frontier_frac_mean": round(float(np.mean(fr)), 4),
                "q_dynamic": round(q_dyn, 4),
                "q_recompute": round(q_cold, 4),
            })
    emit_csv(rows, ["batch_size", "n_batches", "comm_backend",
                    "updates_per_s_dynamic", "updates_per_s_recompute",
                    "speedup", "bytes_per_round", "bytes_on_wire",
                    "comm_rounds", "comm_fallback_rounds",
                    "frontier_frac_mean", "q_dynamic", "q_recompute"])
    return rows


if __name__ == "__main__":
    import argparse
    import time

    import jax

    from benchmarks.common import emit_json

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    print(f"devices: {jax.device_count()}")
    t0 = time.perf_counter()
    rows = run(small=not args.full, repeats=3)
    # This module runs as its own process (forced device count), so it
    # emits its BENCH json here rather than via benchmarks/run.py.
    emit_json("distdyn", rows, seconds=time.perf_counter() - t0,
              small=not args.full)
