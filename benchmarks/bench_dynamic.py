"""Dynamic streaming Louvain: updates/sec vs full static recompute.

An SBM graph is streamed as edge-batch inserts of varying size; for each
batch size we measure

  * ``dynamic``  — ``louvain_dynamic`` (warm start + delta screening),
  * ``recompute`` — a cold static ``louvain`` after every batch

and report edge-updates/sec, speedup, the mean delta-screened frontier
fraction, and the modularity gap vs the cold recompute on the final graph.
This is the streaming-serving scenario of the ROADMAP: small deltas between
queries, membership always fresh.

The ``pallas`` column re-runs the dynamic stream with the Pallas batch-apply
kernel (``apply_backend="pallas"``, interpret mode on CPU) and asserts its
final membership is BIT-IDENTICAL to the sort-reduce apply — the kernel
acceptance gate, recorded per row as ``pallas_match``.

Scan-backend coverage (``BENCH_dynamic.json``):

  * stream rows compare the full-scan and frontier-compacted scanners end
    to end (``updates_per_s_compact`` / ``compact_speedup`` /
    ``compact_match`` — the compacted backend must be bit-identical);
  * ``kind="scan"`` rows time ONE move-round scan per backend at swept
    frontier fractions — the acceptance artifact that per-round scan time
    scales DOWN with |F| (compact beats the full e_cap scan at
    |F|/n <= ~10%; past the work cap it falls back and merely matches).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit_csv, time_fn
from repro.configs.louvain_arch import compact_work_cap
from repro.core.delta import make_edge_batch
from repro.core.dynamic import louvain_dynamic
from repro.core.graph import build_csr
from repro.core.local_move import best_moves, compact_best_moves
from repro.core.louvain import (LouvainConfig, louvain, louvain_modularity,
                                membership_modularity as _q, pad_membership)
from repro.core.modularity import community_weights
from repro.data import sbm_graph


def _holdout_stream(small: bool, seed: int = 0):
    """(initial graph, (us, ud, uw) held-out undirected edges, full e)."""
    n_comms, size = (32, 16) if small else (96, 24)
    full, truth = sbm_graph(n_communities=n_comms, size=size, p_in=0.4,
                            p_out=0.002, seed=seed)
    e = int(full.e_valid)
    src = np.asarray(full.src)[:e]
    dst = np.asarray(full.indices)[:e]
    w = np.asarray(full.weights)[:e]
    und = src < dst
    us, ud, uw = src[und], dst[und], w[und]
    rng = np.random.default_rng(seed)
    n_hold = min(len(us) // 4, 480 if small else 4000)
    hold = rng.choice(len(us), n_hold, replace=False)
    keep = np.ones(len(us), bool)
    keep[hold] = False
    init = build_csr(np.concatenate([us[keep], ud[keep]]),
                     np.concatenate([ud[keep], us[keep]]),
                     np.concatenate([uw[keep], uw[keep]]),
                     int(full.n_valid), e_cap=e + 8)
    return init, (us[hold], ud[hold], uw[hold]), e


def scan_round_timings(graph, prev, fracs=(0.02, 0.05, 0.10, 0.25, 1.0),
                       repeats: int = 5):
    """Time ONE best-move scan per backend at swept frontier fractions.

    This isolates exactly what the compacted scanner changes — the
    per-round scan — from pass-loop effects.  Uses the converged membership
    as the (C, Sigma) snapshot (the streaming regime's actual state).
    """
    n_cap = graph.n_cap
    n = int(graph.n_valid)
    k = graph.vertex_weights()
    m = graph.total_weight()
    comm = jnp.asarray(pad_membership(prev, n_cap))
    sigma = community_weights(graph, comm)
    work_cap = compact_work_cap(graph.e_cap)

    full = jax.jit(lambda fr: best_moves(graph, comm, sigma, k, fr, m))
    comp = jax.jit(lambda fr: compact_best_moves(graph, comm, sigma, k, fr,
                                                 m, work_cap))

    def best_ms(fn, fr):
        jax.block_until_ready(fn(fr))          # warm / compile
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(fr))
            best = min(best, time.perf_counter() - t0)
        return best * 1e3

    rng = np.random.default_rng(0)
    rows = []
    for frac in fracs:
        fr = np.zeros(n_cap + 1, bool)
        fr[rng.choice(n, max(1, int(frac * n)), replace=False)] = True
        fr = jnp.asarray(fr)
        t_full = best_ms(full, fr)
        t_comp = best_ms(comp, fr)
        overflow = bool(comp(fr)[2])
        rows.append({
            "kind": "scan",
            "frontier_frac": frac,
            "frontier_size": int(jnp.sum(fr)),
            "t_scan_full_ms": round(t_full, 4),
            "t_scan_compact_ms": round(t_comp, 4),
            "compact_speedup": round(t_full / max(t_comp, 1e-9), 2),
            "work_cap": work_cap,
            "overflow_fallback": overflow,
        })
    return rows


def run(small: bool = True, repeats: int = 2,
        batch_sizes=(1, 4, 16, 64)) -> list:
    init, (us, ud, uw), _ = _holdout_stream(small)
    prev = louvain(init).membership
    rows = []
    for bs in batch_sizes:
        n_batches = max(1, min(len(us) // bs, 24))
        used = n_batches * bs
        batches = [make_edge_batch(us[i * bs:(i + 1) * bs],
                                   ud[i * bs:(i + 1) * bs],
                                   uw[i * bs:(i + 1) * bs],
                                   init.n_cap, b_cap=bs)
                   for i in range(n_batches)]

        t_dyn, dyn = time_fn(louvain_dynamic, init, batches, prev=prev,
                             config=LouvainConfig(scan_backend="full"),
                             repeats=repeats)
        q_dyn = _q(dyn.graph, dyn.membership)

        # Frontier-compacted scanner: the same stream, scan work
        # proportional to |F|.  Must be bit-identical (compact_match) —
        # the hard gate lives in tests/test_engine_equiv.py.
        t_cmp, dyn_cmp = time_fn(louvain_dynamic, init, batches, prev=prev,
                                 config=LouvainConfig(scan_backend="compact"),
                                 repeats=repeats)
        compact_match = bool(np.array_equal(dyn.membership,
                                            dyn_cmp.membership))
        if not compact_match:
            print(f"WARNING: compact scan backend diverged from full scan "
                  f"at batch_size={bs}")

        # Pallas batch-apply: must reproduce the stream bit-for-bit.  A
        # divergence is recorded (pallas_match=False survives into the
        # BENCH json) rather than aborting the suite — the hard gate lives
        # in tests/test_batch_apply_kernel.py / test_engine_equiv.py.
        t_pal, dyn_pal = time_fn(louvain_dynamic, init, batches, prev=prev,
                                 apply_backend="pallas", repeats=repeats)
        pallas_match = bool(np.array_equal(dyn.membership,
                                           dyn_pal.membership))
        if not pallas_match:
            print(f"WARNING: pallas batch-apply diverged from sort-reduce "
                  f"at batch_size={bs}")

        # Full recompute baseline: same stream, cold louvain per batch.
        def recompute():
            from repro.core.delta import apply_edge_batch
            g = init
            res = None
            for b in batches:
                g, _ = apply_edge_batch(g, b)
                res = louvain(g)
            return g, res

        t_cold, (g_end, res_cold) = time_fn(recompute, repeats=repeats)
        q_cold = louvain_modularity(g_end, res_cold)

        fr = [s.frontier_fraction for s in dyn.batch_stats]
        rows.append({
            "kind": "stream",
            "batch_size": bs, "n_batches": n_batches,
            "updates_per_s_dynamic": round(used / t_dyn, 1),
            "updates_per_s_recompute": round(used / t_cold, 1),
            "updates_per_s_pallas_apply": round(used / t_pal, 1),
            "updates_per_s_compact": round(used / t_cmp, 1),
            "speedup": round(t_cold / t_dyn, 2),
            "compact_speedup": round(t_dyn / t_cmp, 2),
            "pallas_match": pallas_match,
            "compact_match": compact_match,
            "frontier_frac_mean": round(float(np.mean(fr)), 4),
            "q_dynamic": round(q_dyn, 4),
            "q_recompute": round(q_cold, 4),
        })
    emit_csv(rows, ["batch_size", "n_batches", "updates_per_s_dynamic",
                    "updates_per_s_recompute", "updates_per_s_pallas_apply",
                    "updates_per_s_compact", "speedup", "compact_speedup",
                    "pallas_match", "compact_match",
                    "frontier_frac_mean", "q_dynamic", "q_recompute"])

    # Per-round scan timings per backend (the |F|-scaling acceptance rows).
    scan_rows = scan_round_timings(init, prev,
                                   repeats=5 if small else 7)
    emit_csv(scan_rows, ["frontier_frac", "frontier_size", "t_scan_full_ms",
                         "t_scan_compact_ms", "compact_speedup", "work_cap",
                         "overflow_fallback"])
    return rows + scan_rows


if __name__ == "__main__":
    import argparse

    from benchmarks.common import emit_json

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    t0 = time.perf_counter()
    all_rows = run(small=not args.full, repeats=3 if args.full else 2)
    emit_json("dynamic", all_rows, seconds=time.perf_counter() - t0,
              small=not args.full)
