"""Refinement benchmark: Leiden-style constrained sweep vs plain Louvain.

For every suite graph (plus the committed pathology corpus, where plain
parallel Louvain demonstrably leaves a disconnected community), run both
``refine="none"`` and ``refine="leiden"`` and report wall time, reported-
partition modularity, community counts, and the number of communities whose
induced subgraph is NOT connected.  The headline guarantees enforced here:
``q_leiden >= q_none`` on every graph, and refinement never INCREASES the
disconnected count (it is exactly zero on the golden corpora — that stricter
audit lives in tests/test_louvain.py; on adversarial power-law graphs the
synchronous coarse-level sweep can still leave a straggler).

Both variants run at convergence-quality settings (``initial_tolerance=1e-4``,
``gate_fraction=3`` — same config on both sides, so the comparison is fair):
at the looser paper-default tolerance, warm-started refined passes bail a
round early and the Q comparison measures convergence wobble (~1e-3) instead
of the refinement effect.  The run is fully deterministic, so the committed
artifact is reproducible bit-for-bit.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit_csv, graph_suite, time_fn
from repro.core.louvain import LouvainConfig, louvain, louvain_modularity


def _disconnected(src, dst, membership):
    """Number of communities whose induced subgraph is disconnected
    (NumPy BFS — mirrors tests/_oracle.disconnected_communities, inlined
    so the benchmark stays importable without the test tree)."""
    membership = np.asarray(membership)
    bad = 0
    for c in np.unique(membership):
        members = np.where(membership == c)[0]
        if len(members) <= 1:
            continue
        inside = (membership[src] == c) & (membership[dst] == c)
        adj = {}
        for s, d in zip(src[inside], dst[inside]):
            adj.setdefault(int(s), []).append(int(d))
        seen = {int(members[0])}
        stack = [int(members[0])]
        while stack:
            for nb in adj.get(stack.pop(), []):
                if nb not in seen:
                    seen.add(nb)
                    stack.append(nb)
        if len(seen) < len(members):
            bad += 1
    return bad


def _graph_slots(g):
    src = np.asarray(g.src)
    dst = np.asarray(g.indices)
    w = np.asarray(g.weights)
    live = (src < g.n_cap) & (w > 0)
    return src[live], dst[live], w[live]


def run(small: bool = True, repeats: int = 2):
    import networkx as nx
    from repro.core.graph import from_networkx

    graphs = dict(graph_suite(small=small))
    # The corpus the refinement phase exists for (see tests/golden).
    graphs["gnp_pathology"] = from_networkx(
        nx.gnp_random_graph(120, 0.05, seed=21))

    kw = dict(initial_tolerance=1e-4, gate_fraction=3)
    cfg_none = LouvainConfig(**kw)
    cfg_ref = LouvainConfig(refine="leiden", **kw)
    rows = []
    for name, g in graphs.items():
        t_none, r_none = time_fn(louvain, g, cfg_none, repeats=repeats)
        t_ref, r_ref = time_fn(louvain, g, cfg_ref, repeats=repeats)
        src, dst, _w = _graph_slots(g)
        row = {
            "graph": name,
            "n": int(g.n_valid),
            "seconds_none": round(t_none, 4),
            "seconds_leiden": round(t_ref, 4),
            "q_none": round(float(louvain_modularity(g, r_none)), 6),
            "q_leiden": round(float(louvain_modularity(g, r_ref)), 6),
            "n_comms_none": int(r_none.n_communities),
            "n_comms_leiden": int(r_ref.n_communities),
            "disconnected_none": _disconnected(src, dst, r_none.membership),
            "disconnected_leiden": _disconnected(src, dst, r_ref.membership),
        }
        assert row["q_leiden"] >= row["q_none"] - 1e-9, row
        assert row["disconnected_leiden"] <= row["disconnected_none"], row
        rows.append(row)
    emit_csv(rows, ["graph", "n", "seconds_none", "seconds_leiden",
                    "q_none", "q_leiden", "n_comms_none", "n_comms_leiden",
                    "disconnected_none", "disconnected_leiden"])
    return rows
