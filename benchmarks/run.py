"""Benchmark driver — one section per paper figure/table.

    PYTHONPATH=src python -m benchmarks.run           # small suite (CI)
    PYTHONPATH=src python -m benchmarks.run --full    # paper-scale suite

Sections:
    fig3  optimization ablations (rel. runtime / rel. modularity)
    fig5  runtime + speedup + modularity vs networkx Louvain
    fig6  phase split / pass split
    fig7  runtime per edge
    fig8  strong scaling (device-count structural scaling)
    dynamic  streaming edge-batch updates/sec vs full recompute
             (+ Pallas batch-apply bit-for-bit gate)
    multistream  batched multi-stream serving vs sequential dynamic
    refine  Leiden-style refinement vs plain Louvain (Q, wall time,
            disconnected-community audit)
    distdyn  sharded streaming updates/sec vs cold sharded recompute
             (forced-8-device subprocess)
    fleet  multi-tenant serving fleet (sharded x batched) vs sequential
           per-tenant sharded serving (forced-8-device subprocess)
    roofline  achieved rates from the committed BENCH_*.json artifacts vs
              the paper's 560M edges/s headline

Every section also writes a machine-readable ``BENCH_<name>.json`` (rows +
wall seconds + backend), so the perf trajectory is diffable across PRs;
``BENCH_OUT_DIR`` redirects the artifacts.
"""

from __future__ import annotations

import argparse
import os
import subprocess
import sys
import time

# Every section the driver knows, in run order; ``--only`` names must come
# from this list (a typo'd section silently running NOTHING is how perf
# gates rot, so unknown names are a hard error).
SECTIONS = ("fig3", "fig5", "fig6", "fig7", "fig8", "dynamic", "multistream",
            "refine", "distdyn", "fleet", "roofline")


def parse_only(spec: str | None) -> set[str] | None:
    """Validate a ``--only`` spec against ``SECTIONS``.

    Returns the requested subset (None = everything).  Raises ValueError
    naming the unknown entries and the valid set, so the CLI can exit
    non-zero instead of skipping every section.
    """
    if spec is None:
        return None
    names = {s.strip() for s in spec.split(",") if s.strip()}
    unknown = sorted(names - set(SECTIONS))
    if unknown:
        raise ValueError(
            f"unknown section(s) {', '.join(unknown)}; "
            f"valid sections: {', '.join(SECTIONS)}")
    if not names:
        raise ValueError(
            f"--only got no section names; valid sections: "
            f"{', '.join(SECTIONS)}")
    return names


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true",
                    help="paper-scale graphs + 3 repeats (slow)")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: " + ",".join(SECTIONS))
    args = ap.parse_args()
    small = not args.full
    repeats = 3 if args.full else 2
    try:
        only = parse_only(args.only)
    except ValueError as exc:
        print(f"error: {exc}", file=sys.stderr)
        sys.exit(2)

    def want(name: str) -> bool:
        return only is None or name in only

    from benchmarks.common import emit_json

    t0 = time.perf_counter()
    failed = False

    def section(name: str, title: str, fn) -> None:
        """Run one in-process section and persist its BENCH json."""
        print(f"== {name}: {title} ==")
        t = time.perf_counter()
        rows = fn()
        emit_json(name, rows, seconds=time.perf_counter() - t, small=small)
        print()

    if want("fig3"):
        from benchmarks import bench_fig3_ablations
        section("fig3", "optimization ablations "
                "(relative to the paper's defaults)",
                lambda: bench_fig3_ablations.run(small=small,
                                                 repeats=repeats))
    if want("fig5"):
        from benchmarks import bench_fig5_runtime
        section("fig5", "runtime / speedup / modularity vs networkx",
                lambda: bench_fig5_runtime.run(small=small, repeats=repeats))
    if want("fig6"):
        from benchmarks import bench_fig6_phase_split
        section("fig6", "phase and pass split "
                "(per agg backend x capacity ladder)",
                lambda: bench_fig6_phase_split.run(small=small,
                                                   repeats=repeats))
    if want("fig7"):
        from benchmarks import bench_fig7_edge_factor
        section("fig7", "runtime per edge",
                lambda: bench_fig7_edge_factor.run(small=small,
                                                   repeats=repeats))
    if want("fig8"):
        from benchmarks import bench_fig8_scaling
        section("fig8", "strong scaling (structural, 1..8 host devices)",
                lambda: bench_fig8_scaling.run(max_devices=8))
    if want("dynamic"):
        from benchmarks import bench_dynamic
        section("dynamic", "streaming updates/sec vs full recompute "
                "(+ Pallas batch-apply)",
                lambda: bench_dynamic.run(small=small, repeats=repeats))
    if want("multistream"):
        from benchmarks import bench_multistream
        section("multistream",
                "batched multi-stream serving vs sequential dynamic",
                # best-of-5 minimum: the head-to-head is tight enough that
                # 2-vCPU runner noise can flip a low-repeat row.
                lambda: bench_multistream.run(small=small,
                                              repeats=max(repeats, 5)))
    if want("refine"):
        from benchmarks import bench_refine
        section("refine", "Leiden refinement vs plain Louvain "
                "(Q / wall time / connectivity audit)",
                lambda: bench_refine.run(small=small, repeats=repeats))
    if want("distdyn"):
        print("== distdyn: sharded streaming vs cold sharded recompute "
              "(8 forced host devices, subprocess) ==")
        # The benchmark must force the device count before JAX initializes,
        # so it runs as its own process (it emits BENCH_distdyn.json itself).
        env = dict(os.environ)
        env["PYTHONPATH"] = "src" + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
        cmd = [sys.executable, "-m", "benchmarks.bench_distributed_dynamic"]
        if not small:
            cmd.append("--full")
        proc = subprocess.run(cmd, env=env)
        if proc.returncode != 0:
            print(f"(distdyn subprocess failed with code {proc.returncode})")
            failed = True
        print()
    if want("fleet"):
        print("== fleet: multi-tenant serving fleet vs sequential "
              "per-tenant sharded serving (8 forced host devices, "
              "subprocess) ==")
        # Forces the device count before JAX initializes, like distdyn
        # (it emits BENCH_fleet.json itself).
        env = dict(os.environ)
        env["PYTHONPATH"] = "src" + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
        cmd = [sys.executable, "-m", "benchmarks.bench_fleet"]
        if not small:
            cmd.append("--full")
        proc = subprocess.run(cmd, env=env)
        if proc.returncode != 0:
            print(f"(fleet subprocess failed with code {proc.returncode})")
            failed = True
        print()
    if want("roofline"):
        # Reads the committed BENCH_*.json artifacts (including any the
        # sections above just refreshed) — raises instead of emitting an
        # empty table when none are found.
        print("== roofline: achieved rates vs the paper's 560M edges/s ==")
        from benchmarks import roofline
        t = time.perf_counter()
        try:
            rows = roofline.run(
                out_dir=os.environ.get("BENCH_OUT_DIR", "."))
            emit_json("roofline", rows, seconds=time.perf_counter() - t)
        except RuntimeError as exc:
            print(f"(roofline failed: {exc})")
            failed = True
        print()
    print(f"benchmarks done in {time.perf_counter() - t0:.1f}s")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
