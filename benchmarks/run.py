"""Benchmark driver — one section per paper figure/table.

    PYTHONPATH=src python -m benchmarks.run           # small suite (CI)
    PYTHONPATH=src python -m benchmarks.run --full    # paper-scale suite

Sections:
    fig3  optimization ablations (rel. runtime / rel. modularity)
    fig5  runtime + speedup + modularity vs networkx Louvain
    fig6  phase split / pass split
    fig7  runtime per edge
    fig8  strong scaling (device-count structural scaling)
    dynamic  streaming edge-batch updates/sec vs full recompute
    distdyn  sharded streaming updates/sec vs cold sharded recompute
             (forced-8-device subprocess)
    roofline  per-(arch x shape) table from the dry-run artifacts (if present)
"""

from __future__ import annotations

import argparse
import os
import subprocess
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true",
                    help="paper-scale graphs + 3 repeats (slow)")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: fig3,fig5,fig6,fig7,fig8,"
                         "dynamic,distdyn,roofline")
    args = ap.parse_args()
    small = not args.full
    repeats = 3 if args.full else 2
    only = set(args.only.split(",")) if args.only else None

    def want(name: str) -> bool:
        return only is None or name in only

    t0 = time.perf_counter()
    failed = False
    if want("fig3"):
        print("== fig3: optimization ablations "
              "(relative to the paper's defaults) ==")
        from benchmarks import bench_fig3_ablations
        bench_fig3_ablations.run(small=small, repeats=repeats)
        print()
    if want("fig5"):
        print("== fig5: runtime / speedup / modularity vs networkx ==")
        from benchmarks import bench_fig5_runtime
        bench_fig5_runtime.run(small=small, repeats=repeats)
        print()
    if want("fig6"):
        print("== fig6: phase and pass split ==")
        from benchmarks import bench_fig6_phase_split
        bench_fig6_phase_split.run(small=small)
        print()
    if want("fig7"):
        print("== fig7: runtime per edge ==")
        from benchmarks import bench_fig7_edge_factor
        bench_fig7_edge_factor.run(small=small, repeats=repeats)
        print()
    if want("fig8"):
        print("== fig8: strong scaling (structural, 1..8 host devices) ==")
        from benchmarks import bench_fig8_scaling
        bench_fig8_scaling.run(max_devices=8)
        print()
    if want("dynamic"):
        print("== dynamic: streaming updates/sec vs full recompute ==")
        from benchmarks import bench_dynamic
        bench_dynamic.run(small=small, repeats=repeats)
        print()
    if want("distdyn"):
        print("== distdyn: sharded streaming vs cold sharded recompute "
              "(8 forced host devices, subprocess) ==")
        # The benchmark must force the device count before JAX initializes,
        # so it runs as its own process.
        env = dict(os.environ)
        env["PYTHONPATH"] = "src" + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
        cmd = [sys.executable, "-m", "benchmarks.bench_distributed_dynamic"]
        if not small:
            cmd.append("--full")
        proc = subprocess.run(cmd, env=env)
        if proc.returncode != 0:
            print(f"(distdyn subprocess failed with code {proc.returncode})")
            failed = True
        print()
    if want("roofline"):
        print("== roofline: dry-run artifacts (single-pod) ==")
        if os.path.isdir("results/dryrun"):
            from benchmarks import roofline
            roofline.run()
        else:
            print("(results/dryrun not found — run "
                  "`python -m repro.launch.dryrun --all` first)")
        print()
    print(f"benchmarks done in {time.perf_counter() - t0:.1f}s")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
