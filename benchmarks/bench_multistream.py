"""Batched multi-stream serving vs sequential per-stream dynamic updates.

S independent SBM edge streams (one tenant each) are served two ways:

  * ``sequential`` — S separate ``louvain_dynamic`` calls, one per stream
    (they share compiled phases — equal capacities — so this baseline is
    already dispatch-amortized across streams);
  * ``batched``    — ONE ``louvain_dynamic_batched`` call: the engine's
    move rounds are vmapped over the stream axis, so every pass/apply is a
    single program for the whole fleet.

Reported per stream count: end-to-end wall time, edge-updates/sec, speedup,
and the worst per-stream modularity gap (the batched path must not trade
quality for throughput; per-stream results are asserted equal to the
sequential ones by tests/test_multistream.py).  The acceptance row is
``n_streams >= 4``: batched must beat sequential (``speedup > 1``) —
recorded machine-readably in ``BENCH_multistream.json`` by benchmarks/run.py
(or by running this module directly).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit_csv, emit_json, time_fn
from repro.core.dynamic import louvain_dynamic
from repro.core.louvain import (LouvainConfig, louvain,
                                membership_modularity)
from repro.core.multistream import louvain_dynamic_batched
from repro.data import sbm_holdout_stream


def _stream_case(seed, n_comms, size, n_cap, e_cap, n_hold, n_steps, b_cap):
    init, batches, _ = sbm_holdout_stream(
        seed, n_communities=n_comms, size=size, n_cap=n_cap, e_cap=e_cap,
        n_hold=n_hold, n_steps=n_steps, b_cap=b_cap)
    return init, batches


def run(small: bool = True, repeats: int = 5,
        stream_counts=(2, 4, 8)):
    n_comms, size = (8, 16) if small else (16, 24)
    n_cap = n_comms * size
    e_cap = (4600 if small else 22000)
    # Serving regime: many small deltas per stream (the batched win comes
    # from amortizing per-update dispatch + host control flow fleet-wide).
    # Enough steps that the fleet-level win clears 2-vCPU runner noise.
    n_hold, n_steps, b_cap = (48, 16, 3) if small else (96, 24, 4)

    rows = []
    for S in stream_counts:
        cases = [_stream_case(100 + s, n_comms, size, n_cap, e_cap,
                              n_hold, n_steps, b_cap) for s in range(S)]
        graphs = [c[0] for c in cases]
        streams = [c[1] for c in cases]
        prevs = [louvain(g).membership for g in graphs]
        edges = S * n_steps * b_cap

        def sequential():
            return [louvain_dynamic(graphs[s], streams[s], prev=prevs[s])
                    for s in range(S)]

        t_seq, seq = time_fn(sequential, repeats=repeats)
        t_bat, bat = time_fn(louvain_dynamic_batched, graphs, streams,
                             prevs=prevs, repeats=repeats)
        # Compacted scanner through the batched driver: a correctness gate
        # per row, not a speedup claim — under vmap the overflow cond
        # lowers to a both-branches select (see core.multistream), so the
        # win case stays the sequential driver (BENCH_dynamic scan rows).
        t_bc, bat_c = time_fn(louvain_dynamic_batched, graphs, streams,
                              prevs=prevs,
                              config=LouvainConfig(scan_backend="compact"),
                              repeats=repeats)
        compact_match = all(
            np.array_equal(bat.stream_membership(s),
                           bat_c.stream_membership(s)) for s in range(S))
        if not compact_match:
            print(f"WARNING: batched compact backend diverged at S={S}")

        q_gap = max(
            abs(membership_modularity(seq[s].graph, seq[s].membership)
                - membership_modularity(seq[s].graph,
                                        bat.stream_membership(s)))
            for s in range(S))
        rows.append({
            "n_streams": S,
            "n_steps": n_steps,
            "edges_streamed": edges,
            "t_sequential_s": round(t_seq, 4),
            "t_batched_s": round(t_bat, 4),
            "t_batched_compact_s": round(t_bc, 4),
            "updates_per_s_sequential": round(edges / t_seq, 1),
            "updates_per_s_batched": round(edges / t_bat, 1),
            "speedup": round(t_seq / t_bat, 2),
            "compact_match": compact_match,
            "q_gap_max": round(float(q_gap), 6),
        })
    emit_csv(rows, ["n_streams", "n_steps", "edges_streamed",
                    "t_sequential_s", "t_batched_s", "t_batched_compact_s",
                    "updates_per_s_sequential", "updates_per_s_batched",
                    "speedup", "compact_match", "q_gap_max"])
    return rows


if __name__ == "__main__":
    import argparse
    import time

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    t0 = time.perf_counter()
    # best-of-5 even in small mode — a low-repeat row can be flipped by
    # 2-vCPU runner noise (this json is the acceptance artifact).
    rows = run(small=not args.full, repeats=5)
    emit_json("multistream", rows, seconds=time.perf_counter() - t0,
              small=not args.full)
