"""Figure 3 analogue: per-optimization ablations.

For each paper optimization (§4.1.2-4.1.6 are hardware-independent and ported
verbatim; §4.1.1/4.1.9's TPU analogues are the ELL widths / kernel path), run
the alternatives over the graph suite and report geometric-mean relative
runtime and arithmetic-mean relative modularity — the paper's exact protocol
(5 runs, geomean runtime / mean modularity, expressed vs the default)."""

from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import emit_csv, geomean, graph_suite, time_fn
from repro.core.louvain import LouvainConfig, louvain, louvain_modularity

ABLATIONS = {
    # paper default                        alternative(s)
    "max_iterations": [("20 (paper)", {"max_iterations": 20}),
                       ("100", {"max_iterations": 100})],
    "tolerance_drop": [("10 (paper)", {"tolerance_drop": 10.0}),
                       ("1 (disabled)", {"tolerance_drop": 1.0})],
    "initial_tolerance": [("0.01 (paper)", {"initial_tolerance": 0.01}),
                          ("1e-6", {"initial_tolerance": 1e-6})],
    "aggregation_tolerance": [("0.8 (paper)", {"aggregation_tolerance": 0.8}),
                              ("1.0 (disabled)",
                               {"aggregation_tolerance": 1.0})],
    "vertex_pruning": [("on (paper)", {"use_pruning": True}),
                       ("off", {"use_pruning": False})],
    "scan_path": [("sort-reduce", {"use_ell_kernel": False}),
                  ("ELL kernel (Far-KV analogue)", {"use_ell_kernel": True})],
}


def run(small: bool = True, repeats: int = 2):
    graphs = graph_suite(small=small)
    rows = []
    for opt_name, variants in ABLATIONS.items():
        base_times, base_qs = None, None
        for label, overrides in variants:
            cfg = LouvainConfig(**overrides)
            times, qs = [], []
            for gname, g in graphs.items():
                dt, res = time_fn(louvain, g, cfg, repeats=repeats)
                times.append(dt)
                qs.append(louvain_modularity(g, res))
            if base_times is None:
                base_times, base_qs = times, qs
            rel_t = geomean(t / b for t, b in zip(times, base_times))
            rel_q = float(np.mean([q / max(b, 1e-9)
                                   for q, b in zip(qs, base_qs)]))
            rows.append({"optimization": opt_name, "variant": label,
                         "rel_runtime": round(rel_t, 3),
                         "rel_modularity": round(rel_q, 4)})
    emit_csv(rows, ["optimization", "variant", "rel_runtime",
                    "rel_modularity"])
    return rows


if __name__ == "__main__":
    run(small=False, repeats=3)
