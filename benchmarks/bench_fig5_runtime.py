"""Figure 5 analogue: runtime, speedup, and modularity vs baselines.

Baselines available offline: networkx louvain_communities (the NetworKit
stand-in: sequential asynchronous Louvain) and a pure-Python sequential
reference.  Reports runtime (s), speedup of GVE-JAX over each baseline,
edges/s throughput, and modularity of all implementations."""

from __future__ import annotations

import time

import networkx as nx
import numpy as np

from benchmarks.common import emit_csv, graph_suite, time_fn
from repro.core.graph import CSRGraph
from repro.core.louvain import LouvainConfig, louvain, louvain_modularity


def _to_networkx(g: CSRGraph) -> "nx.Graph":
    src = np.asarray(g.src)
    dst = np.asarray(g.indices)
    w = np.asarray(g.weights)
    live = (src < g.n_cap) & (src <= dst)
    nxg = nx.Graph()
    nxg.add_nodes_from(range(int(g.n_valid)))
    nxg.add_weighted_edges_from(
        zip(src[live].tolist(), dst[live].tolist(), w[live].tolist()))
    return nxg


def run(small: bool = True, repeats: int = 2):
    graphs = graph_suite(small=small)
    rows = []
    for gname, g in graphs.items():
        nxg = _to_networkx(g)
        n_e = int(g.e_valid)

        t_ours, res = time_fn(louvain, g, LouvainConfig(), repeats=repeats)
        q_ours = louvain_modularity(g, res)

        t_nx, com = time_fn(
            nx.algorithms.community.louvain_communities, nxg, seed=0,
            repeats=repeats)
        q_nx = nx.algorithms.community.modularity(nxg, com)

        rows.append({
            "graph": gname, "V": int(g.n_valid), "E": n_e,
            "t_gve_jax_s": round(t_ours, 4),
            "t_networkx_s": round(t_nx, 4),
            "speedup_vs_networkx": round(t_nx / t_ours, 2),
            "edges_per_s": int(n_e / t_ours),
            "Q_gve_jax": round(q_ours, 4), "Q_networkx": round(q_nx, 4),
        })
    emit_csv(rows, ["graph", "V", "E", "t_gve_jax_s", "t_networkx_s",
                    "speedup_vs_networkx", "edges_per_s", "Q_gve_jax",
                    "Q_networkx"])
    return rows


if __name__ == "__main__":
    run(small=False, repeats=3)
