"""Figure 7 analogue: runtime / |E| factor per graph (the paper's observation:
low-degree and poorly-clustered graphs cost more per edge)."""

from __future__ import annotations

from benchmarks.common import emit_csv, graph_suite, time_fn
from repro.core.louvain import LouvainConfig, louvain


def run(small: bool = True, repeats: int = 2):
    graphs = graph_suite(small=small)
    rows = []
    for gname, g in graphs.items():
        dt, res = time_fn(louvain, g, LouvainConfig(), repeats=repeats)
        e = int(g.e_valid)
        deg = e / max(int(g.n_valid), 1)
        rows.append({"graph": gname, "E": e, "avg_degree": round(deg, 2),
                     "runtime_s": round(dt, 4),
                     "ns_per_edge": round(1e9 * dt / e, 1)})
    emit_csv(rows, ["graph", "E", "avg_degree", "runtime_s", "ns_per_edge"])
    return rows


if __name__ == "__main__":
    run(small=False, repeats=3)
