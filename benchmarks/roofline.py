"""§Roofline table builder: reads the dry-run JSONs from results/dryrun and
emits the per-(arch x shape) roofline terms as CSV + markdown."""

from __future__ import annotations

import glob
import json
import os
from typing import List

from benchmarks.common import emit_csv


def load_records(out_dir: str = "results/dryrun",
                 mesh: str = "16x16") -> List[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(out_dir, f"*_{mesh}.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def run(out_dir: str = "results/dryrun", mesh: str = "16x16",
        markdown: bool = False):
    rows = []
    for rec in load_records(out_dir, mesh):
        if not rec.get("ok"):
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "bottleneck": "FAILED: " + rec.get("error", "?")})
            continue
        r = rec["roofline"]
        mf = rec.get("model_flops") or 0
        rows.append({
            "arch": rec["arch"], "shape": rec["shape"],
            "t_compute_s": f"{r['t_compute_s']:.3e}",
            "t_memory_s": f"{r['t_memory_s']:.3e}",
            "t_collective_s": f"{r['t_collective_s']:.3e}",
            "bottleneck": r["bottleneck"],
            "model_flops": f"{mf:.3e}" if mf else "",
            "useful_ratio": (f"{rec['useful_flops_ratio']:.3f}"
                             if rec.get("useful_flops_ratio") else ""),
            "hbm_per_chip_gb": (
                f"{rec['memory'].get('temp_size_in_bytes', 0) / 1e9:.2f}"
                if rec.get("memory") else ""),
        })
    header = ["arch", "shape", "t_compute_s", "t_memory_s", "t_collective_s",
              "bottleneck", "model_flops", "useful_ratio", "hbm_per_chip_gb"]
    if markdown:
        print("| " + " | ".join(header) + " |")
        print("|" + "---|" * len(header))
        for r in rows:
            print("| " + " | ".join(str(r.get(h, "")) for h in header) + " |")
    else:
        emit_csv(rows, header)
    return rows


if __name__ == "__main__":
    import sys
    run(markdown="--md" in sys.argv,
        mesh="2x16x16" if "--multipod" in sys.argv else "16x16")
