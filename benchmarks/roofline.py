"""§Roofline table: achieved processing rates from the committed BENCH
artifacts vs the paper's headline.

GVE-Louvain's headline is **560M edges/s** (64-core shared memory, Table 2);
this section reads the machine-readable ``BENCH_*.json`` artifacts the other
sections emit (committed at the repo root, so the perf trajectory is
diffable across PRs) and reports every achieved rate against that target:

  * ``BENCH_phase_split`` — static pass loop: directed edge slots of the
    fine graph over the summed pass wall time, per (graph x agg backend x
    ladder) — the closest analogue of the paper's edges/s metric.
  * ``BENCH_dynamic`` / ``BENCH_multistream`` / ``BENCH_distdyn`` —
    streaming paths: edge updates/s per driver variant (plus, for distdyn,
    the measured bytes-on-wire per engine round per comm backend).

The old dry-run reader (``results/dryrun/*_16x16.json``) is gone — nothing
produces those files since the launch refactor, and the empty table it
silently emitted hid the regression this section exists to catch: loading
NO artifacts is now an error.
"""

from __future__ import annotations

import glob
import json
import os
from typing import List

from benchmarks.common import emit_csv

#: Paper headline: 560M edges/s (Table 2, 64-core Xeon).  Laptop-scale CI
#: artifacts land far below it; the point is a diffable trajectory.
PAPER_EDGES_PER_S = 560e6

HEADER = ["artifact", "config", "metric", "rate_per_s", "pct_of_paper"]


def _pct(rate: float) -> str:
    return f"{100.0 * rate / PAPER_EDGES_PER_S:.2e}"


def _phase_split_rows(payload: dict) -> List[dict]:
    """Static edges/s: per (graph, agg_backend, ladder), the fine graph's
    directed slot count over the summed pass time."""
    groups = {}
    for r in payload.get("rows", []):
        key = (r["graph"], r.get("agg_backend", "?"), r.get("ladder"))
        g = groups.setdefault(key, {"edges": 0, "seconds": 0.0})
        if r.get("pass") == 0:
            g["edges"] = int(r.get("e_cap", 0))
        g["seconds"] += float(r.get("seconds", 0.0))
    out = []
    for (graph, agg, ladder), g in sorted(groups.items()):
        if g["edges"] and g["seconds"] > 0:
            rate = g["edges"] / g["seconds"]
            out.append({"artifact": "phase_split",
                        "config": f"{graph}/agg={agg}/ladder={ladder}",
                        "metric": "edges_per_s",
                        "rate_per_s": f"{rate:.3e}",
                        "pct_of_paper": _pct(rate)})
    return out


def _rate_rows(name: str, payload: dict) -> List[dict]:
    """Streaming updates/s: every ``updates_per_s*`` column of every row."""
    out = []
    for r in payload.get("rows", []):
        tags = []
        for k in ("batch_size", "n_streams", "comm_backend"):
            if k in r:
                tags.append(f"{k}={r[k]}")
        cfg = "/".join(tags) or "-"
        for k, v in r.items():
            if not k.startswith("updates_per_s") or not v:
                continue
            rate = float(v)
            out.append({"artifact": name,
                        "config": cfg,
                        "metric": k,
                        "rate_per_s": f"{rate:.3e}",
                        "pct_of_paper": _pct(rate)})
    return out


def load_artifacts(out_dir: str = ".") -> dict:
    arts = {}
    for path in sorted(glob.glob(os.path.join(out_dir, "BENCH_*.json"))):
        name = os.path.basename(path)[len("BENCH_"):-len(".json")]
        if name == "roofline":
            continue              # never read our own output
        with open(path) as fh:
            arts[name] = json.load(fh)
    return arts


def run(out_dir: str = ".", markdown: bool = False) -> List[dict]:
    arts = load_artifacts(out_dir)
    if not arts:
        raise RuntimeError(
            f"no BENCH_*.json artifacts under {out_dir!r} — run the other "
            "benchmark sections first (PYTHONPATH=src python -m "
            "benchmarks.run); an empty roofline table is a bug, not a "
            "result")
    rows: List[dict] = []
    for name, payload in sorted(arts.items()):
        if name == "phase_split":
            rows.extend(_phase_split_rows(payload))
        else:
            rows.extend(_rate_rows(name, payload))
    if not rows:
        raise RuntimeError(
            f"BENCH artifacts {sorted(arts)} contained no rate columns "
            "(updates_per_s* / phase timings) — schema drift?")
    best = max(rows, key=lambda r: float(r["rate_per_s"]))
    summary = (f"best achieved: {best['rate_per_s']} /s "
               f"({best['artifact']}:{best['metric']} @ {best['config']}) "
               f"= {best['pct_of_paper']}% of the paper's "
               f"{PAPER_EDGES_PER_S:.0e} edges/s")
    if markdown:
        print("| " + " | ".join(HEADER) + " |")
        print("|" + "---|" * len(HEADER))
        for r in rows:
            print("| " + " | ".join(str(r.get(h, "")) for h in HEADER) + " |")
    else:
        emit_csv(rows, HEADER)
    print(summary)
    return rows


if __name__ == "__main__":
    import sys
    run(markdown="--md" in sys.argv)
