"""Figure 6 analogue: phase split (local-moving / aggregation / others) and
pass split (first pass vs rest) per graph."""

from __future__ import annotations

from benchmarks.common import emit_csv, graph_suite
from repro.core.louvain import LouvainConfig, louvain


def run(small: bool = True):
    graphs = graph_suite(small=small)
    rows = []
    for gname, g in graphs.items():
        res = louvain(g, LouvainConfig())
        lm = sum(p.phase_seconds["local_move"] for p in res.passes)
        ag = sum(p.phase_seconds["aggregate"] for p in res.passes)
        ot = sum(p.phase_seconds["other"] for p in res.passes)
        tot = max(lm + ag + ot, 1e-12)
        first = res.passes[0].seconds
        all_p = max(sum(p.seconds for p in res.passes), 1e-12)
        rows.append({
            "graph": gname, "passes": res.n_passes,
            "local_move_frac": round(lm / tot, 3),
            "aggregate_frac": round(ag / tot, 3),
            "other_frac": round(ot / tot, 3),
            "first_pass_frac": round(first / all_p, 3),
        })
    emit_csv(rows, ["graph", "passes", "local_move_frac", "aggregate_frac",
                    "other_frac", "first_pass_frac"])
    return rows


if __name__ == "__main__":
    run(small=False)
