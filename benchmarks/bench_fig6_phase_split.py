"""Figure 6 analogue: phase split (local-moving / aggregation / others) and
pass split (first pass vs rest) per graph — now per aggregation backend and
per capacity-ladder setting, with per-pass timings as the committed
machine-readable artifact.

``BENCH_phase_split.json`` carries one row per (graph, agg_backend, ladder,
pass) with ``local_move``/``aggregate``/``other`` seconds and the capacities
the pass ran at, plus summary rows with the coarse-pass (pass >= 1) totals
and the ladder's coarse-pass speedup — the before/after of the
capacity-ladder PR is diffable straight from the artifact.
"""

from __future__ import annotations

import time

from benchmarks.common import emit_csv, emit_json, graph_suite
from repro.core.louvain import LouvainConfig, louvain


def _timed_run(g, cfg, repeats: int):
    """Warm every tier's compiled phases, then best-of-N by total pass time
    (per-pass timings are taken from the best run, so compiles never
    pollute the phase split)."""
    louvain(g, cfg)
    best = None
    for _ in range(max(repeats, 1)):
        res = louvain(g, cfg)
        tot = sum(p.seconds for p in res.passes)
        if best is None or tot < best[0]:
            best = (tot, res)
    return best[1]


def run(small: bool = True, repeats: int = 2):
    graphs = graph_suite(small=small)
    pass_rows, summary = [], []
    t0 = time.perf_counter()
    for gname, g in graphs.items():
        coarse_by_cfg = {}
        for backend in ("sort", "pallas"):
            for ladder in (False, True):
                cfg = LouvainConfig(use_ladder=ladder, agg_backend=backend)
                res = _timed_run(g, cfg, repeats)
                lm = sum(p.phase_seconds["local_move"] for p in res.passes)
                ag = sum(p.phase_seconds["aggregate"] for p in res.passes)
                ot = sum(p.phase_seconds["other"] for p in res.passes)
                tot = max(lm + ag + ot, 1e-12)
                all_p = max(sum(p.seconds for p in res.passes), 1e-12)
                coarse = sum(p.seconds for p in res.passes[1:])
                coarse_by_cfg[(backend, ladder)] = coarse
                for i, p in enumerate(res.passes):
                    pass_rows.append({
                        "graph": gname, "agg_backend": backend,
                        "ladder": ladder, "pass": i,
                        "local_move_s": round(p.phase_seconds["local_move"], 6),
                        "aggregate_s": round(p.phase_seconds["aggregate"], 6),
                        "other_s": round(p.phase_seconds["other"], 6),
                        "seconds": round(p.seconds, 6),
                        "n_cap": p.n_cap, "e_cap": p.e_cap,
                        "n_vertices": p.n_vertices,
                        "n_communities": p.n_communities,
                    })
                summary.append({
                    "graph": gname, "agg_backend": backend, "ladder": ladder,
                    "passes": res.n_passes,
                    "local_move_frac": round(lm / tot, 3),
                    "aggregate_frac": round(ag / tot, 3),
                    "other_frac": round(ot / tot, 3),
                    "first_pass_frac": round(res.passes[0].seconds / all_p, 3),
                    "coarse_pass_s": round(coarse, 6),
                })
        for backend in ("sort", "pallas"):
            off = coarse_by_cfg[(backend, False)]
            on = coarse_by_cfg[(backend, True)]
            for row in summary:
                if (row["graph"] == gname and row["agg_backend"] == backend
                        and row["ladder"]):
                    row["coarse_speedup_vs_no_ladder"] = round(
                        off / max(on, 1e-12), 2)
    emit_csv(summary, ["graph", "agg_backend", "ladder", "passes",
                       "local_move_frac", "aggregate_frac", "other_frac",
                       "first_pass_frac", "coarse_pass_s",
                       "coarse_speedup_vs_no_ladder"])
    emit_json("phase_split", pass_rows,
              seconds=time.perf_counter() - t0, small=small, summary=summary)
    return summary


if __name__ == "__main__":
    run(small=False)
