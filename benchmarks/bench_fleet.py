"""Multi-tenant serving fleet vs sequential per-tenant sharded serving.

T independent tenant graphs, each with its own edge stream, are served two
ways on the same device mesh:

  * ``sequential`` — T separate ``louvain_dynamic_sharded`` calls, one per
    tenant (each shards its graph across every device; they share compiled
    phases when layouts match, so the baseline is compile-amortized);
  * ``fleet``      — ONE ``serve_fleet`` call: tenants are bucketed into
    power-of-two capacity envelopes, each bucket's step is a single
    ``jit(vmap(shard_map ...))`` dispatch over its tenant lanes, and every
    dispatch's convergence fetch is deferred one step so device work
    overlaps host control.

Reported per tenant count: end-to-end wall time, edge-updates/sec, speedup,
bucket/dispatch/fallback/migration counters, plan-priced bytes per
dispatch, and a bit-for-bit parity flag against the sequential results
(the fleet must never trade correctness for throughput; the same contract
is pinned by tests/test_fleet.py and the golden rows in
tests/test_engine_equiv.py).  The acceptance row is ``n_tenants >= 4``:
fleet must beat sequential (``speedup > 1``) — recorded machine-readably
in ``BENCH_fleet.json``.  A trailing ``kind="layout_head_to_head"`` pair
serves the same T=4 tenants under the gather backend with the replicated
vs hybrid state layout: identical memberships, lower ``bytes_per_dispatch``
on the hybrid row (rows carry ``state_layout`` / ``halo_bytes_per_round``
/ ``boundary_frac``).

Executed as a script it forces 8 host devices (it must own the process
before JAX initializes, which is why ``benchmarks.run`` launches it as a
subprocess); inside an existing JAX process it degrades to however many
devices are visible.

    PYTHONPATH=src python -m benchmarks.bench_fleet [--full]
"""

from __future__ import annotations

import os

if __name__ == "__main__":  # must precede the first jax import
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                               + os.environ.get("XLA_FLAGS", ""))

import numpy as np

from benchmarks.common import emit_csv, time_fn
from repro.core.distributed_dynamic import louvain_dynamic_sharded
from repro.core.fleet import serve_fleet
from repro.core.louvain import LouvainConfig, louvain
from repro.data import sbm_holdout_stream


def _mesh_axes():
    import jax

    from repro.compat import make_mesh

    if jax.device_count() >= 8:
        return make_mesh((2, 4), ("data", "model")), ("data", "model")
    n = jax.device_count()
    return make_mesh((n,), ("shard",)), ("shard",)


def _tenant(seed: int, small: bool):
    n_comms, size = (8, 16) if small else (16, 24)
    n_hold, n_steps, b_cap = (48, 16, 3) if small else (96, 24, 4)
    # p_in=0.3 keeps every tenant's measured owned-edge count comfortably
    # inside ONE power-of-two envelope bin, so the fleet serves all T
    # tenants from a single bucket (the head-to-head is about batching,
    # not about where the bucket ladder happens to split a corpus).
    init, batches, _ = sbm_holdout_stream(
        seed, n_communities=n_comms, size=size, p_in=0.3,
        n_cap=n_comms * size, e_cap=(4600 if small else 22000),
        n_hold=n_hold, n_steps=n_steps, b_cap=b_cap)
    return init, batches, n_steps * b_cap


def run(small: bool = True, repeats: int = 3,
        tenant_counts=(2, 4, 8)):
    mesh, axes = _mesh_axes()
    rows = []
    for T in tenant_counts:
        cases = [_tenant(200 + t, small) for t in range(T)]
        graphs = {f"t{t}": cases[t][0] for t in range(T)}
        streams = {f"t{t}": cases[t][1] for t in range(T)}
        prevs = {tid: louvain(g).membership for tid, g in graphs.items()}
        edges = sum(c[2] for c in cases)

        def sequential():
            return {tid: louvain_dynamic_sharded(
                        graphs[tid], mesh, axes, streams[tid],
                        prev=prevs[tid], screening="community")
                    for tid in graphs}

        t_seq, seq = time_fn(sequential, repeats=repeats)
        t_flt, flt = time_fn(serve_fleet, graphs, streams, mesh, axes,
                             prevs=prevs, screening="community",
                             repeats=repeats)

        parity = all(np.array_equal(flt.membership[tid],
                                    seq[tid].membership) for tid in graphs)
        if not parity:
            print(f"WARNING: fleet diverged from sequential at T={T}")
        rows.append({
            "n_tenants": T,
            "n_steps": max(len(s) for s in streams.values()),
            "edges_streamed": edges,
            "t_sequential_s": round(t_seq, 4),
            "t_fleet_s": round(t_flt, 4),
            "updates_per_s_sequential": round(edges / t_seq, 1),
            "updates_per_s_fleet": round(edges / t_flt, 1),
            "speedup": round(t_seq / t_flt, 2),
            "n_buckets": len(flt.buckets),
            "n_dispatches": int(flt.n_dispatches),
            "n_fallbacks": int(flt.n_fallbacks),
            "n_migrations": int(flt.n_migrations),
            "bytes_per_dispatch": round(flt.bytes_per_dispatch, 1),
            "bytes_on_wire": int(flt.bytes_on_wire),
            "halo_bytes_per_round": round(flt.halo_bytes_per_round, 1),
            "boundary_frac": (None if flt.boundary_frac is None
                              else round(flt.boundary_frac, 4)),
            "comm_backend": flt.comm_backend,
            "state_layout": flt.state_layout,
            "parity": parity,
        })

    # State-layout head-to-head under the gather backend (hybrid's winning
    # combination — the delta wire already ships labels sparse, so hybrid's
    # per-community Sigma lanes only pay off against gather's dense psums).
    # Same T=4 tenant set both ways: memberships must agree bit-for-bit and
    # the hybrid dispatch wire must be the smaller one.
    T = 4
    cases = [_tenant(200 + t, small) for t in range(T)]
    graphs = {f"t{t}": cases[t][0] for t in range(T)}
    streams = {f"t{t}": cases[t][1] for t in range(T)}
    prevs = {tid: louvain(g).membership for tid, g in graphs.items()}
    edges = sum(c[2] for c in cases)
    lay_out = {}
    for layout in ("replicated", "hybrid"):
        cfg = LouvainConfig(comm_backend="gather", state_layout=layout)
        t_flt, flt = time_fn(serve_fleet, graphs, streams, mesh, axes,
                             prevs=prevs, config=cfg,
                             screening="community", repeats=repeats)
        lay_out[layout] = flt
        rows.append({
            "n_tenants": T, "kind": "layout_head_to_head",
            "n_steps": max(len(s) for s in streams.values()),
            "edges_streamed": edges,
            "t_fleet_s": round(t_flt, 4),
            "updates_per_s_fleet": round(edges / t_flt, 1),
            "n_buckets": len(flt.buckets),
            "n_dispatches": int(flt.n_dispatches),
            "n_fallbacks": int(flt.n_fallbacks),
            "n_migrations": int(flt.n_migrations),
            "bytes_per_dispatch": round(flt.bytes_per_dispatch, 1),
            "bytes_on_wire": int(flt.bytes_on_wire),
            "halo_bytes_per_round": round(flt.halo_bytes_per_round, 1),
            "boundary_frac": (None if flt.boundary_frac is None
                              else round(flt.boundary_frac, 4)),
            "comm_backend": flt.comm_backend,
            "state_layout": flt.state_layout,
            "parity": all(np.array_equal(flt.membership[t],
                                         lay_out["replicated"].membership[t])
                          for t in graphs),
        })
    hb = rows[-1]["bytes_per_dispatch"]
    rb = rows[-2]["bytes_per_dispatch"]
    print(f"gather layout head-to-head bytes/dispatch: replicated={rb} "
          f"hybrid={hb} ({'LOWER' if hb < rb else 'not lower'})")
    emit_csv(rows, ["n_tenants", "kind", "n_steps", "edges_streamed",
                    "t_sequential_s", "t_fleet_s",
                    "updates_per_s_sequential", "updates_per_s_fleet",
                    "speedup", "n_buckets", "n_dispatches", "n_fallbacks",
                    "n_migrations", "bytes_per_dispatch", "bytes_on_wire",
                    "halo_bytes_per_round", "boundary_frac", "comm_backend",
                    "state_layout", "parity"])
    return rows


if __name__ == "__main__":
    import argparse
    import time

    from benchmarks.common import emit_json

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    t0 = time.perf_counter()
    # best-of-5 even in small mode: the head-to-head is the acceptance
    # artifact and a low-repeat row can be flipped by runner noise.
    rows = run(small=not args.full, repeats=5)
    emit_json("fleet", rows, seconds=time.perf_counter() - t0,
              small=not args.full)
