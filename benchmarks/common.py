"""Shared benchmark infrastructure: the graph suite (the paper's dataset
*families* at laptop scale — SuiteSparse itself is not available offline),
timing helpers, and CSV emission."""

from __future__ import annotations

import time
from typing import Callable, Dict, List

import numpy as np

from repro.core.graph import CSRGraph
from repro.data import powerlaw_cluster, rmat_graph, sbm_graph


def graph_suite(small: bool = False) -> Dict[str, CSRGraph]:
    """Five graphs mirroring Table 1's families: web (R-MAT power-law),
    social (powerlaw-cluster), community-structured (SBM), road (2D grid),
    k-mer (low-degree chains)."""
    import networkx as nx
    from repro.core.graph import from_networkx

    scale = 9 if small else 11
    n_grid = 24 if small else 48
    n_sbm = (8, 24) if small else (16, 48)

    web = rmat_graph(scale, edge_factor=8, seed=0)
    social, _ = powerlaw_cluster(300 if small else 1500, 6, 0.5, seed=1)
    sbm, _ = sbm_graph(*n_sbm, p_in=0.25, p_out=0.004, seed=2)
    road = from_networkx(nx.grid_2d_graph(n_grid, n_grid))
    # k-mer-like: union of long paths (avg degree ~2)
    kmer_nx = nx.Graph()
    rng = np.random.default_rng(3)
    base = 0
    for _ in range(20 if small else 60):
        ln = int(rng.integers(20, 60))
        kmer_nx.add_edges_from((base + i, base + i + 1) for i in range(ln))
        base += ln + 1
    kmer = from_networkx(kmer_nx)
    return {"rmat_web": web, "powerlaw_social": social, "sbm": sbm,
            "grid_road": road, "kmer_paths": kmer}


def time_fn(fn: Callable, *args, repeats: int = 3, **kw):
    """(best_seconds, last_result) — best-of-N like the paper's 5-run mean."""
    best, out = float("inf"), None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return best, out


def emit_csv(rows: List[dict], header: List[str]) -> None:
    print(",".join(header))
    for r in rows:
        print(",".join(str(r.get(h, "")) for h in header))


def geomean(xs) -> float:
    xs = np.asarray(list(xs), dtype=float)
    return float(np.exp(np.log(np.maximum(xs, 1e-12)).mean()))
