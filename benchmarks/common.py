"""Shared benchmark infrastructure: the graph suite (the paper's dataset
*families* at laptop scale — SuiteSparse itself is not available offline),
timing helpers, and CSV/JSON emission.

Every benchmark section also lands as a machine-readable ``BENCH_<name>.json``
(rows + wall time + environment), so the perf trajectory is diffable across
PRs — see ``benchmarks/run.py``.  ``BENCH_OUT_DIR`` overrides the output
directory (default: the current working directory)."""

from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core.graph import CSRGraph
from repro.data import powerlaw_cluster, rmat_graph, sbm_graph


def graph_suite(small: bool = False) -> Dict[str, CSRGraph]:
    """Five graphs mirroring Table 1's families: web (R-MAT power-law),
    social (powerlaw-cluster), community-structured (SBM), road (2D grid),
    k-mer (low-degree chains)."""
    import networkx as nx
    from repro.core.graph import from_networkx

    scale = 9 if small else 11
    n_grid = 24 if small else 48
    n_sbm = (8, 24) if small else (16, 48)

    web = rmat_graph(scale, edge_factor=8, seed=0)
    social, _ = powerlaw_cluster(300 if small else 1500, 6, 0.5, seed=1)
    sbm, _ = sbm_graph(*n_sbm, p_in=0.25, p_out=0.004, seed=2)
    road = from_networkx(nx.grid_2d_graph(n_grid, n_grid))
    # k-mer-like: union of long paths (avg degree ~2)
    kmer_nx = nx.Graph()
    rng = np.random.default_rng(3)
    base = 0
    for _ in range(20 if small else 60):
        ln = int(rng.integers(20, 60))
        kmer_nx.add_edges_from((base + i, base + i + 1) for i in range(ln))
        base += ln + 1
    kmer = from_networkx(kmer_nx)
    return {"rmat_web": web, "powerlaw_social": social, "sbm": sbm,
            "grid_road": road, "kmer_paths": kmer}


def time_fn(fn: Callable, *args, repeats: int = 3, **kw):
    """(best_seconds, last_result) — best-of-N like the paper's 5-run mean."""
    best, out = float("inf"), None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return best, out


def emit_csv(rows: List[dict], header: List[str]) -> None:
    print(",".join(header))
    for r in rows:
        print(",".join(str(r.get(h, "")) for h in header))


def _git_sha() -> Optional[str]:
    """Short commit hash of the tree the artifact was produced from, or
    None outside a git checkout — ties each BENCH json to a revision."""
    import subprocess

    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__)))
        return out.stdout.strip() or None if out.returncode == 0 else None
    except OSError:
        return None


def emit_json(name: str, rows: Optional[List[dict]],
              seconds: Optional[float] = None, **extra) -> str:
    """Write ``BENCH_<name>.json`` — the machine-readable perf artifact.

    ``rows`` is whatever the section measured (each bench keeps its own
    schema: wall times, edges/s / updates/s, modularity where applicable);
    ``seconds`` the section's wall time; ``extra`` free-form metadata.
    Every payload carries the producing tree's ``git_sha``.
    Returns the path written.
    """
    import jax

    payload = {
        "bench": name,
        "seconds": None if seconds is None else round(float(seconds), 3),
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "git_sha": _git_sha(),
        "rows": rows if rows is not None else [],
    }
    payload.update(extra)
    out_dir = os.environ.get("BENCH_OUT_DIR", ".")
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"BENCH_{name}.json")
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=2, default=str)
        fh.write("\n")
    print(f"[bench] wrote {path}")
    return path


def geomean(xs) -> float:
    xs = np.asarray(list(xs), dtype=float)
    return float(np.exp(np.log(np.maximum(xs, 1e-12)).mean()))
