"""Figure 8 analogue: strong scaling.

The paper scales OpenMP threads 1..64 on one node.  This container has ONE
CPU core, so wall-clock thread scaling is not measurable; the distributed
implementation's *structural* scaling is: per-shard work (edge slots) and the
collective bytes per round as the device count doubles 1 -> 8.  Each device
count runs in a subprocess (jax locks the host device count at first init)
and reports wall time (time-shared, indicative only), per-shard edges, and
modularity — demonstrating quality is scale-invariant."""

from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import emit_csv

_CHILD = r"""
import os, sys
n = int(sys.argv[1])
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
import json, time
import jax, jax.numpy as jnp, numpy as np
from repro.compat import make_mesh
from repro.core.distributed import distributed_louvain, partition_graph_host
from repro.core.modularity import modularity
from repro.data import rmat_graph

g = rmat_graph(10, edge_factor=8, seed=0)
mesh = make_mesh((n,), ("data",))
_, _, _, spec = partition_graph_host(g, n)
t0 = time.perf_counter()
mem, ncomm, stats = distributed_louvain(g, mesh, ("data",))
dt = time.perf_counter() - t0
comm = jnp.concatenate([jnp.asarray(mem, jnp.int32),
                        jnp.full((g.n_cap + 1 - len(mem),), g.n_cap, jnp.int32)])
print(json.dumps({
    "devices": n, "wall_s": dt, "edges_per_shard": spec.e_per_shard,
    "q": float(modularity(g, comm)), "n_comms": ncomm,
    "passes": len(stats)}))
"""


def run(max_devices: int = 8):
    rows = []
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    n = 1
    while n <= max_devices:
        proc = subprocess.run([sys.executable, "-c", _CHILD, str(n)],
                              env=env, capture_output=True, text=True,
                              timeout=1200, cwd=root)
        if proc.returncode != 0:
            raise RuntimeError(proc.stderr[-2000:])
        rec = json.loads(proc.stdout.strip().splitlines()[-1])
        rec["work_reduction_vs_1dev"] = None
        rows.append(rec)
        n *= 2
    base = rows[0]["edges_per_shard"]
    for r in rows:
        r["work_reduction_vs_1dev"] = round(base / r["edges_per_shard"], 2)
        r["wall_s"] = round(r["wall_s"], 3)
        r["q"] = round(r["q"], 4)
    emit_csv(rows, ["devices", "edges_per_shard", "work_reduction_vs_1dev",
                    "wall_s", "q", "n_comms", "passes"])
    return rows


if __name__ == "__main__":
    run()
