"""Golden tests: every execution path reaches NumPy-oracle-level modularity.

``tests/_oracle.py`` is an independent pure-NumPy sequential Louvain; on
small deterministic graphs each of the repo's four execution paths —
single-device sort-reduce, ELL (Pallas interpret on CPU), sharded static,
and sharded dynamic — must land within ``TOL`` of the oracle's modularity.
The sharded paths run tier-1 on a 1-shard mesh (same shard_map code on the
default device); the forced-8-device variants live in
``tests/test_distributed_dynamic.py`` behind ``--runslow``.
"""

import numpy as np
import pytest

from _oracle import louvain_oracle, modularity_np, oracle_graph_slots

from repro.compat import make_mesh
from repro.core.delta import make_edge_batch
from repro.core.distributed import distributed_louvain
from repro.core.distributed_dynamic import louvain_dynamic_sharded
from repro.core.dynamic import louvain_dynamic
from repro.core.graph import build_csr, from_networkx
from repro.core.louvain import (LouvainConfig, louvain, louvain_modularity,
                                membership_modularity)
from repro.core.multistream import louvain_dynamic_batched
from repro.data import sbm_graph

TOL = 0.02  # absolute modularity gap allowed vs the sequential oracle


def _graphs():
    import networkx as nx

    lesmis = from_networkx(nx.les_miserables_graph())
    sbm, _ = sbm_graph(n_communities=8, size=16, p_in=0.4, p_out=0.01, seed=2)
    ring = from_networkx(nx.ring_of_cliques(8, 6))

    # Weighted corpus: the SBM topology with deterministic non-uniform
    # weights (intra-community edges heavier on average, so the planted
    # structure survives reweighting).
    e = int(sbm.e_valid)
    s_src = np.asarray(sbm.src)[:e]
    s_dst = np.asarray(sbm.indices)[:e]
    und = s_src < s_dst
    us, ud = s_src[und], s_dst[und]
    rng = np.random.default_rng(7)
    uw = rng.uniform(0.5, 3.0, len(us)).astype(np.float32)
    weighted = build_csr(np.concatenate([us, ud]), np.concatenate([ud, us]),
                         np.concatenate([uw, uw]), int(sbm.n_valid))

    # Self-loop-heavy corpus: ring of cliques with a weighted self loop on
    # every other vertex (self loops stress the K_i / 2m conventions: one
    # directed slot, excluded from K_{i->c}).
    e = int(ring.e_valid)
    r_src = np.asarray(ring.src)[:e]
    r_dst = np.asarray(ring.indices)[:e]
    r_w = np.asarray(ring.weights)[:e]
    loops = np.arange(0, int(ring.n_valid), 2, dtype=np.int64)
    selfloops = build_csr(np.concatenate([r_src, loops]),
                          np.concatenate([r_dst, loops]),
                          np.concatenate([r_w, np.full(len(loops), 2.0,
                                                       np.float32)]),
                          int(ring.n_valid))

    return {"lesmis": lesmis, "sbm": sbm, "ring_of_cliques": ring,
            "sbm_weighted": weighted, "ring_selfloops": selfloops}


@pytest.fixture(scope="module", params=list(_graphs()))
def golden_case(request):
    g = _graphs()[request.param]
    src, dst, w, n = oracle_graph_slots(g)
    q_oracle = modularity_np(src, dst, w, louvain_oracle(src, dst, w, n))
    assert q_oracle > 0.3, f"oracle degenerate on {request.param}"
    return request.param, g, q_oracle


def test_oracle_golden_single_device(golden_case):
    name, g, q_oracle = golden_case
    q = louvain_modularity(g, louvain(g))
    assert q >= q_oracle - TOL, (name, q, q_oracle)


def test_oracle_golden_ell_kernel(golden_case):
    name, g, q_oracle = golden_case
    q = louvain_modularity(g, louvain(g, LouvainConfig(use_ell_kernel=True)))
    assert q >= q_oracle - TOL, (name, q, q_oracle)


def test_oracle_golden_sharded_static(golden_case):
    name, g, q_oracle = golden_case
    mesh = make_mesh((1,), ("shard",))
    mem, _, _ = distributed_louvain(g, mesh, ("shard",))
    q = membership_modularity(g, mem)
    assert q >= q_oracle - TOL, (name, q, q_oracle)


# ---------------------------------------------------------------------------
# Streaming corpora beyond inserts: deletion-only and reweight-heavy batch
# streams, pinned to the oracle across the CSR, sharded and batched applies.
# (The insert-dominated stream is covered above and by test_engine_equiv.)
# ---------------------------------------------------------------------------


def _sbm_undirected(seed=2):
    full, truth = sbm_graph(n_communities=8, size=16, p_in=0.4, p_out=0.01,
                            seed=seed)
    e = int(full.e_valid)
    src = np.asarray(full.src)[:e]
    dst = np.asarray(full.indices)[:e]
    w = np.asarray(full.weights)[:e]
    und = src < dst
    return full, truth, src[und], dst[und], w[und]


def _deletion_stream(n_batches: int = 8):
    """Start from the full SBM; stream deletions of 40 inter-community
    edges (w=0 assignments).  The final graph is the SBM with most noise
    edges removed — cleaner structure, higher oracle Q."""
    full, truth, us, ud, uw = _sbm_undirected()
    inter = np.where(truth[us] != truth[ud])[0]
    rng = np.random.default_rng(3)
    kill = rng.choice(inter, min(40, len(inter)), replace=False)
    batches = [make_edge_batch(us[kill[i::n_batches]], ud[kill[i::n_batches]],
                               np.zeros(len(kill[i::n_batches]), np.float32),
                               full.n_cap, b_cap=8)
               for i in range(n_batches)]
    keep = np.ones(len(us), bool)
    keep[kill] = False
    final = build_csr(np.concatenate([us[keep], ud[keep]]),
                      np.concatenate([ud[keep], us[keep]]),
                      np.concatenate([uw[keep], uw[keep]]),
                      int(full.n_valid))
    return full, batches, final


def _reweight_stream():
    """Start from the full SBM; stream reweights only — 40 intra-community
    edges up to 3x, 24 inter-community edges down to 0.25 — no topology
    change at all (the apply path's set-not-add semantics under load)."""
    full, truth, us, ud, uw = _sbm_undirected()
    intra = np.where(truth[us] == truth[ud])[0]
    inter = np.where(truth[us] != truth[ud])[0]
    rng = np.random.default_rng(4)
    up = rng.choice(intra, 40, replace=False)
    down = rng.choice(inter, min(24, len(inter)), replace=False)
    edges = np.concatenate([up, down])
    new_w = np.concatenate([np.full(len(up), 3.0, np.float32),
                            np.full(len(down), 0.25, np.float32)])
    order = rng.permutation(len(edges))
    edges, new_w = edges[order], new_w[order]
    batches = [make_edge_batch(us[edges[i::8]], ud[edges[i::8]],
                               new_w[i::8], full.n_cap, b_cap=8)
               for i in range(8)]
    w_final = uw.copy()
    w_final[edges] = new_w
    final = build_csr(np.concatenate([us, ud]), np.concatenate([ud, us]),
                      np.concatenate([w_final, w_final]),
                      int(full.n_valid))
    return full, batches, final


# Reweight batches touch endpoints across every community, so the
# community-granular frontier legitimately covers all n — the DF-style
# per-vertex screening is the one with a meaningful smallness invariant
# there (and gets real-stream coverage this way).
_STREAM_SCREENING = {"deletion_only": True, "reweight_heavy": "vertex"}


@pytest.fixture(scope="module", params=["deletion_only", "reweight_heavy"])
def stream_case(request):
    init, batches, final = (_deletion_stream() if request.param ==
                            "deletion_only" else _reweight_stream())
    fs, fd, fw, fn = oracle_graph_slots(final)
    q_oracle = modularity_np(fs, fd, fw, louvain_oracle(fs, fd, fw, fn))
    assert q_oracle > 0.3, f"oracle degenerate on {request.param}"
    return (request.param, init, batches, final, q_oracle,
            _STREAM_SCREENING[request.param])


def test_oracle_golden_stream_csr_apply(stream_case):
    name, init, batches, final, q_oracle, screening = stream_case
    dyn = louvain_dynamic(init, batches, screening=screening)
    assert int(dyn.graph.e_valid) == int(final.e_valid), name
    q = membership_modularity(final, dyn.membership)
    assert q >= q_oracle - TOL, (name, q, q_oracle)
    # Delta screening engaged on every batch.
    assert all(s.frontier_size < s.n_vertices for s in dyn.batch_stats), name


def test_oracle_golden_stream_sharded_apply(stream_case):
    name, init, batches, final, q_oracle, screening = stream_case
    mesh = make_mesh((1,), ("shard",))
    dyn = louvain_dynamic_sharded(init, mesh, ("shard",), batches,
                                  screening=screening)
    q = membership_modularity(final, dyn.membership)
    assert q >= q_oracle - TOL, (name, q, q_oracle)
    assert all(s.frontier_size < s.n_vertices for s in dyn.batch_stats), name


def test_oracle_golden_stream_batched_apply(stream_case):
    name, init, batches, final, q_oracle, screening = stream_case
    bat = louvain_dynamic_batched([init], [batches], screening=screening)
    q = membership_modularity(final, bat.stream_membership(0))
    assert q >= q_oracle - TOL, (name, q, q_oracle)
    n = int(np.asarray(bat.graphs.n_valid)[0])
    assert np.all(bat.frontier_sizes < n), name


def test_oracle_golden_stream_auto_screening_matches_quality():
    """screening="auto" through a real deletion stream: same oracle-level
    quality, and every batch's seed frontier is consistent with the auto
    policy — vertex-granular (frontier == touched set) when the touched
    set is small, community-granular (>= touched) above the threshold.
    Self-consistent within one run, so membership-trajectory divergence
    between screening modes cannot flip it."""
    from repro.core.engine import AUTO_SCREEN_TOUCHED_DENOM as DENOM

    # 20 batches of ~2 deletions: small enough (<= 4 endpoints vs the
    # n/16 = 8 threshold) that auto actually reaches vertex granularity.
    init, batches, final = _deletion_stream(n_batches=20)
    fs, fd, fw, fn = oracle_graph_slots(final)
    q_oracle = modularity_np(fs, fd, fw, louvain_oracle(fs, fd, fw, fn))
    dyn = louvain_dynamic(init, batches, screening="auto")
    q = membership_modularity(final, dyn.membership)
    assert q >= q_oracle - TOL, (q, q_oracle)
    saw_vertex = False
    for s in dyn.batch_stats:
        if s.n_touched * DENOM <= s.n_vertices:
            assert s.frontier_size == s.n_touched, vars(s)
            saw_vertex = True
        else:
            assert s.frontier_size >= s.n_touched, vars(s)
    assert saw_vertex, "no batch small enough to exercise vertex mode"


def test_oracle_golden_sharded_dynamic():
    """Stream half of an SBM's held-out intra-community edges back through
    ``louvain_dynamic_sharded``; final membership must be oracle-level on
    the final graph."""
    full, truth = sbm_graph(n_communities=8, size=16, p_in=0.4, p_out=0.01,
                            seed=2)
    e = int(full.e_valid)
    src = np.asarray(full.src)[:e]
    dst = np.asarray(full.indices)[:e]
    w = np.asarray(full.weights)[:e]
    und = src < dst
    us, ud, uw = src[und], dst[und], w[und]
    rng = np.random.default_rng(0)
    hold = rng.choice(len(us), 40, replace=False)
    keep = np.ones(len(us), bool)
    keep[hold] = False
    init = build_csr(np.concatenate([us[keep], ud[keep]]),
                     np.concatenate([ud[keep], us[keep]]),
                     np.concatenate([uw[keep], uw[keep]]),
                     int(full.n_valid), e_cap=e + 8)
    batches = [make_edge_batch(us[hold[i::8]], ud[hold[i::8]],
                               uw[hold[i::8]], init.n_cap, b_cap=8)
               for i in range(8)]

    mesh = make_mesh((1,), ("shard",))
    dyn = louvain_dynamic_sharded(init, mesh, ("shard",), batches)
    assert len(dyn.batch_stats) == 8

    fs, fd, fw, fn = oracle_graph_slots(full)
    q_oracle = modularity_np(fs, fd, fw, louvain_oracle(fs, fd, fw, fn))
    q = membership_modularity(full, dyn.membership)
    assert q >= q_oracle - TOL, (q, q_oracle)
    # Delta screening really screened (strict minority bounds need a graph
    # much larger than each batch's community spread — covered by the
    # forced-8-device acceptance test in test_distributed_dynamic.py).
    assert all(s.frontier_size < s.n_vertices for s in dyn.batch_stats)
