"""Golden tests: every execution path reaches NumPy-oracle-level modularity.

``tests/_oracle.py`` is an independent pure-NumPy sequential Louvain; on
small deterministic graphs each of the repo's four execution paths —
single-device sort-reduce, ELL (Pallas interpret on CPU), sharded static,
and sharded dynamic — must land within ``TOL`` of the oracle's modularity.
The sharded paths run tier-1 on a 1-shard mesh (same shard_map code on the
default device); the forced-8-device variants live in
``tests/test_distributed_dynamic.py`` behind ``--runslow``.
"""

import numpy as np
import pytest

from _oracle import louvain_oracle, modularity_np, oracle_graph_slots

from repro.compat import make_mesh
from repro.core.delta import make_edge_batch
from repro.core.distributed import distributed_louvain
from repro.core.distributed_dynamic import louvain_dynamic_sharded
from repro.core.graph import build_csr, from_networkx
from repro.core.louvain import (LouvainConfig, louvain, louvain_modularity,
                                membership_modularity)
from repro.data import sbm_graph

TOL = 0.02  # absolute modularity gap allowed vs the sequential oracle


def _graphs():
    import networkx as nx

    lesmis = from_networkx(nx.les_miserables_graph())
    sbm, _ = sbm_graph(n_communities=8, size=16, p_in=0.4, p_out=0.01, seed=2)
    ring = from_networkx(nx.ring_of_cliques(8, 6))

    # Weighted corpus: the SBM topology with deterministic non-uniform
    # weights (intra-community edges heavier on average, so the planted
    # structure survives reweighting).
    e = int(sbm.e_valid)
    s_src = np.asarray(sbm.src)[:e]
    s_dst = np.asarray(sbm.indices)[:e]
    und = s_src < s_dst
    us, ud = s_src[und], s_dst[und]
    rng = np.random.default_rng(7)
    uw = rng.uniform(0.5, 3.0, len(us)).astype(np.float32)
    weighted = build_csr(np.concatenate([us, ud]), np.concatenate([ud, us]),
                         np.concatenate([uw, uw]), int(sbm.n_valid))

    # Self-loop-heavy corpus: ring of cliques with a weighted self loop on
    # every other vertex (self loops stress the K_i / 2m conventions: one
    # directed slot, excluded from K_{i->c}).
    e = int(ring.e_valid)
    r_src = np.asarray(ring.src)[:e]
    r_dst = np.asarray(ring.indices)[:e]
    r_w = np.asarray(ring.weights)[:e]
    loops = np.arange(0, int(ring.n_valid), 2, dtype=np.int64)
    selfloops = build_csr(np.concatenate([r_src, loops]),
                          np.concatenate([r_dst, loops]),
                          np.concatenate([r_w, np.full(len(loops), 2.0,
                                                       np.float32)]),
                          int(ring.n_valid))

    return {"lesmis": lesmis, "sbm": sbm, "ring_of_cliques": ring,
            "sbm_weighted": weighted, "ring_selfloops": selfloops}


@pytest.fixture(scope="module", params=list(_graphs()))
def golden_case(request):
    g = _graphs()[request.param]
    src, dst, w, n = oracle_graph_slots(g)
    q_oracle = modularity_np(src, dst, w, louvain_oracle(src, dst, w, n))
    assert q_oracle > 0.3, f"oracle degenerate on {request.param}"
    return request.param, g, q_oracle


def test_oracle_golden_single_device(golden_case):
    name, g, q_oracle = golden_case
    q = louvain_modularity(g, louvain(g))
    assert q >= q_oracle - TOL, (name, q, q_oracle)


def test_oracle_golden_ell_kernel(golden_case):
    name, g, q_oracle = golden_case
    q = louvain_modularity(g, louvain(g, LouvainConfig(use_ell_kernel=True)))
    assert q >= q_oracle - TOL, (name, q, q_oracle)


def test_oracle_golden_sharded_static(golden_case):
    name, g, q_oracle = golden_case
    mesh = make_mesh((1,), ("shard",))
    mem, _, _ = distributed_louvain(g, mesh, ("shard",))
    q = membership_modularity(g, mem)
    assert q >= q_oracle - TOL, (name, q, q_oracle)


def test_oracle_golden_sharded_dynamic():
    """Stream half of an SBM's held-out intra-community edges back through
    ``louvain_dynamic_sharded``; final membership must be oracle-level on
    the final graph."""
    full, truth = sbm_graph(n_communities=8, size=16, p_in=0.4, p_out=0.01,
                            seed=2)
    e = int(full.e_valid)
    src = np.asarray(full.src)[:e]
    dst = np.asarray(full.indices)[:e]
    w = np.asarray(full.weights)[:e]
    und = src < dst
    us, ud, uw = src[und], dst[und], w[und]
    rng = np.random.default_rng(0)
    hold = rng.choice(len(us), 40, replace=False)
    keep = np.ones(len(us), bool)
    keep[hold] = False
    init = build_csr(np.concatenate([us[keep], ud[keep]]),
                     np.concatenate([ud[keep], us[keep]]),
                     np.concatenate([uw[keep], uw[keep]]),
                     int(full.n_valid), e_cap=e + 8)
    batches = [make_edge_batch(us[hold[i::8]], ud[hold[i::8]],
                               uw[hold[i::8]], init.n_cap, b_cap=8)
               for i in range(8)]

    mesh = make_mesh((1,), ("shard",))
    dyn = louvain_dynamic_sharded(init, mesh, ("shard",), batches)
    assert len(dyn.batch_stats) == 8

    fs, fd, fw, fn = oracle_graph_slots(full)
    q_oracle = modularity_np(fs, fd, fw, louvain_oracle(fs, fd, fw, fn))
    q = membership_modularity(full, dyn.membership)
    assert q >= q_oracle - TOL, (q, q_oracle)
    # Delta screening really screened (strict minority bounds need a graph
    # much larger than each batch's community spread — covered by the
    # forced-8-device acceptance test in test_distributed_dynamic.py).
    assert all(s.frontier_size < s.n_vertices for s in dyn.batch_stats)
