"""Fused Pallas ELL scan+apply kernel vs its pure-jnp oracle and vs the
engine's generic scan-then-decide path (interpret=True executes the kernel
body on CPU).  The fused round is pinned BIT-FOR-BIT: same best moves, same
gated decision, same memberships after full engine rounds."""

import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:         # optional dev dep — see tests/_hypothesis_fallback
    from _hypothesis_fallback import given, settings, st

from repro.core import ell_move
from repro.core.engine import (EngineConfig, MoveEngine, round_gate)
from repro.core.graph import to_ell_blocks
from repro.core.louvain import singleton_init
from repro.data import sbm_graph
from repro.kernels.louvain_scan import ops
from repro.kernels.louvain_scan.fused import louvain_fused_ref


def _random_fused_inputs(rng, r, d, n_comms=8, sentinel=64):
    c = rng.integers(-1, n_comms, (r, d)).astype(np.int32)
    w = (rng.random((r, d)) + 0.1).astype(np.float32)
    w = np.where(c >= 0, w, 0).astype(np.float32)
    sig = (rng.random((r, d)) * 5).astype(np.float32)
    # Community sizes must be CONSISTENT per community id (the kernel takes
    # a row-min over slots of the best community).
    comm_sizes = rng.integers(1, 5, sentinel + 1).astype(np.int32)
    size = np.where(c >= 0, comm_sizes[np.maximum(c, 0)], 0).astype(np.int32)
    ki = (rng.random((r, 1)) * 3 + 0.1).astype(np.float32)
    cown = rng.integers(0, n_comms, (r, 1)).astype(np.int32)
    sigown = (rng.random((r, 1)) * 5).astype(np.float32)
    sizeown = comm_sizes[cown[:, 0]][:, None].astype(np.int32)
    rows = rng.permutation(sentinel)[:r].astype(np.int32)[:, None]
    front = rng.integers(0, 2, (r, 1)).astype(np.int32)
    m = np.float32(10.0)
    return tuple(jnp.asarray(x) for x in
                 (c, w, sig, size, ki, cown, sigown, sizeown, rows, front,
                  m))


@pytest.mark.parametrize("r,d", [(8, 4), (8, 16), (16, 16), (32, 64)])
@pytest.mark.parametrize("gate_fraction", [1, 2])
def test_fused_pallas_matches_ref(r, d, gate_fraction):
    rng = np.random.default_rng(r * 1000 + d + gate_fraction)
    ins = _random_fused_inputs(rng, r, d)
    round_ix = jnp.int32(3)
    out_p = ops.louvain_fused(*ins, round_ix, gate_fraction=gate_fraction,
                              sentinel=64, use_pallas=True, interpret=True)
    out_r = louvain_fused_ref(*ins, round_ix, gate_fraction=gate_fraction,
                              sentinel=64)
    for a, b, what in zip(out_p, out_r, ("best_c", "best_dq", "do_move")):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b), what)


@pytest.mark.parametrize("block_rows", [1, 2, 4, 8])
def test_fused_block_rows_invariant(block_rows):
    """Grid tiling must not change the fused decision."""
    rng = np.random.default_rng(11)
    ins = _random_fused_inputs(rng, 16, 8)
    round_ix = jnp.int32(1)
    ref = louvain_fused_ref(*ins, round_ix, gate_fraction=2, sentinel=64)
    out = ops.louvain_fused(*ins, round_ix, gate_fraction=2, sentinel=64,
                            use_pallas=True, interpret=True,
                            block_rows=block_rows)
    for a, b in zip(out, ref):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([4, 8, 16]),
       st.sampled_from([4, 8, 32]), st.integers(0, 7))
def test_fused_pallas_matches_ref_property(seed, r, d, round_ix):
    rng = np.random.default_rng(seed)
    ins = _random_fused_inputs(rng, r, d, n_comms=max(2, d // 2))
    out_p = ops.louvain_fused(*ins, jnp.int32(round_ix), gate_fraction=2,
                              sentinel=64, use_pallas=True, interpret=True)
    out_r = louvain_fused_ref(*ins, jnp.int32(round_ix), gate_fraction=2,
                              sentinel=64)
    for a, b in zip(out_p, out_r):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_fused_in_kernel_gate_matches_engine_round_gate():
    """The kernel's inlined Weyl gate equals engine.round_gate for the same
    (vertex id, round) — the constants have ONE home and one behavior."""
    rng = np.random.default_rng(5)
    r, d = 32, 8
    ins = list(_random_fused_inputs(rng, r, d))
    # Rig every row to an unambiguous improving move with no guard blocks:
    # all moves pass except where the gate says no.
    ins[3] = jnp.full((r, d), 3, jnp.int32)           # sizes > 1
    ins[7] = jnp.full((r, 1), 3, jnp.int32)           # own size > 1
    ins[9] = jnp.ones((r, 1), jnp.int32)              # frontier on
    rows = ins[8]
    for round_ix in range(6):
        _, _, mv = ops.louvain_fused(
            *ins, jnp.int32(round_ix), gate_fraction=2, sentinel=64,
            use_pallas=True, interpret=True)
        _, ref_dq, ref_mv = louvain_fused_ref(
            *ins, jnp.int32(round_ix), gate_fraction=2, sentinel=64)
        gate = np.asarray(round_gate(rows[:, 0], jnp.int32(round_ix), 2))
        moved = np.asarray(mv) > 0
        np.testing.assert_array_equal(moved, np.asarray(ref_mv) > 0)
        # every mover passed the engine's gate — no kernel-side drift
        assert not np.any(moved & ~gate)
        # and on gated-off rows with a found improving move, the gate is
        # the ONLY thing that blocked (dq > 0, frontier on, guard off)
        blocked_only_by_gate = (~gate) & (np.asarray(ref_dq) > 0)
        assert not np.any(moved[blocked_only_by_gate])


def _engine_rounds(g, fused):
    """One full engine move phase over SBM, via the requested scanner.

    Narrow ELL widths on purpose: every vertex of degree > 16 must land in
    the leftover set so the sort-reduce/gated_move_mask composition path of
    ``FusedELLScanner.decide_moves`` actually runs.
    """
    blocks, leftover_np = to_ell_blocks(g, (16,))      # force a leftover set
    leftover = jnp.asarray(leftover_np)
    k = g.vertex_weights()
    m = g.total_weight()
    comm0, sigma0, frontier0 = singleton_init(g)
    if fused:
        scanner = ell_move.FusedELLScanner(
            g, tuple(blocks), leftover, k, m, use_pallas=True,
            interpret=True, gate_fraction=2)
    else:
        scanner = ell_move.ELLScanner(
            g, tuple(blocks), leftover, k, m, use_pallas=True,
            interpret=True)
    st = MoveEngine(scanner, EngineConfig()).run(
        comm0, sigma0, frontier0, jnp.float32(0.01))
    return st


def test_fused_engine_rounds_bit_for_bit_with_hub_leftovers():
    """Full engine phase, fused vs scan-only, on a graph whose hubs exceed
    the widest ELL tile (the leftover/sort-reduce composition path)."""
    g, _ = sbm_graph(n_communities=4, size=24, p_in=0.5, p_out=0.02, seed=7)
    _, leftover_np = to_ell_blocks(g, (16,))           # same widths as below
    assert len(leftover_np) > 0, "corpus has no hub leftovers; widen test"
    st_ell = _engine_rounds(g, fused=False)
    st_fused = _engine_rounds(g, fused=True)
    np.testing.assert_array_equal(np.asarray(st_ell.comm),
                                  np.asarray(st_fused.comm))
    assert int(st_ell.iters) == int(st_fused.iters)
    assert float(st_ell.dq_sum) == float(st_fused.dq_sum)


def test_fused_move_phase_warm_start_bit_for_bit():
    """Warm start + seed frontier through move_phase_ell(fused=True) equals
    the scan-only phase (the streaming entry into the fused round)."""
    g, _ = sbm_graph(n_communities=8, size=16, p_in=0.4, p_out=0.01, seed=2)
    n_cap = g.n_cap
    rng = np.random.default_rng(0)
    comm0 = jnp.asarray(np.concatenate(
        [rng.integers(0, 16, n_cap), [n_cap]]).astype(np.int32))
    fr = np.zeros(n_cap + 1, bool)
    fr[:24] = True
    fr = jnp.asarray(fr)
    c0, i0, d0 = ell_move.move_phase_ell(g, jnp.float32(0.01), comm0=comm0,
                                         frontier0=fr)
    c1, i1, d1 = ell_move.move_phase_ell(g, jnp.float32(0.01), comm0=comm0,
                                         frontier0=fr, fused=True)
    np.testing.assert_array_equal(np.asarray(c0), np.asarray(c1))
    assert (int(i0), float(d0)) == (int(i1), float(d1))


def test_fused_refine_constrained_sweep_bit_for_bit():
    """Refinement (constrained singleton sweep) through the ELL kernels in
    interpret mode: the cross-outer slot masking + ConstrainedScanner wrap
    must leave scan-only and fused Pallas paths bit-identical, and both must
    genuinely refine the outer partition (no community crosses an outer
    boundary, movers only merged as singletons)."""
    from repro.core.louvain import louvain

    g, _ = sbm_graph(n_communities=4, size=24, p_in=0.5, p_out=0.02, seed=7)
    n = int(g.n_valid)
    outer_mem = louvain(g).membership
    outer = jnp.asarray(np.concatenate(
        [outer_mem, np.full(g.n_cap + 1 - n, g.n_cap)]).astype(np.int32))
    out = {}
    for fused in (False, True):
        out[fused] = ell_move.move_phase_ell(
            g, jnp.float32(0.01), fused=fused, interpret=True,
            refine_outer=outer)
    c0, i0, d0 = out[False]
    c1, i1, d1 = out[True]
    np.testing.assert_array_equal(np.asarray(c0), np.asarray(c1))
    assert (int(i0), float(d0)) == (int(i1), float(d1))
    refined = np.asarray(c0)[:n]
    for r in np.unique(refined):
        assert len(np.unique(np.asarray(outer_mem)[refined == r])) == 1
