"""End-to-end system behaviour: the full GVE-Louvain pipeline on generated
graph families (the paper's dataset categories), plus determinism."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.louvain import LouvainConfig, louvain, louvain_modularity
from repro.data import powerlaw_cluster, rmat_graph, sbm_graph


def test_rmat_web_like_end_to_end():
    """R-MAT (web-graph family): converges, sane community count, Q > 0."""
    g = rmat_graph(10, edge_factor=6, seed=0)
    res = louvain(g)
    assert res.n_passes <= 10
    assert 1 <= res.n_communities < int(g.n_valid)
    q = louvain_modularity(g, res)
    assert q > 0.1


def test_powerlaw_social_like_end_to_end():
    g, _ = powerlaw_cluster(600, 4, 0.6, seed=1)
    res = louvain(g)
    q = louvain_modularity(g, res)
    assert q > 0.2
    assert res.n_communities >= 2


def test_sbm_quality_tracks_planted_q():
    g, truth = sbm_graph(n_communities=10, size=30, p_in=0.25, p_out=0.004,
                         seed=2)
    res = louvain(g)
    q_found = louvain_modularity(g, res)
    comm = jnp.concatenate([jnp.asarray(truth, jnp.int32),
                            jnp.full((g.n_cap + 1 - len(truth),), g.n_cap,
                                     jnp.int32)])
    from repro.core.modularity import modularity
    q_planted = float(modularity(g, comm))
    assert q_found >= 0.9 * q_planted


def test_pass_stats_structure():
    g = rmat_graph(8, edge_factor=4, seed=3)
    res = louvain(g, LouvainConfig(track_modularity=True))
    assert res.passes
    for p in res.passes:
        assert p.iterations >= 1
        assert p.n_communities <= p.n_vertices
        assert set(p.phase_seconds) == {"local_move", "other", "aggregate"}
        assert p.modularity is None or np.isfinite(p.modularity)
    # monotone coarsening
    sizes = [p.n_vertices for p in res.passes]
    assert sizes == sorted(sizes, reverse=True)


def test_deterministic_across_runs():
    """Same graph + same config -> identical membership (the deterministic
    tie-breaking requirement)."""
    g = rmat_graph(8, edge_factor=4, seed=4)
    r1 = louvain(g)
    r2 = louvain(g)
    np.testing.assert_array_equal(r1.membership, r2.membership)
