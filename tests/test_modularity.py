"""Eq. 1 / Eq. 2 against networkx and against each other (property);
zero-edge graphs (m == 0) must yield Q = 0 / dQ = 0, never NaN."""

import jax.numpy as jnp
import networkx as nx
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:         # optional dev dep — see tests/_hypothesis_fallback
    from _hypothesis_fallback import given, settings, st

from repro.core.graph import from_networkx
from repro.core.local_move import best_moves
from repro.core.modularity import community_weights, delta_modularity, modularity


def _comm_array(g, membership):
    n_cap = g.n_cap
    return jnp.asarray(list(membership) + [n_cap], jnp.int32)


def test_modularity_matches_networkx_karate():
    nxg = nx.karate_club_graph()
    g = from_networkx(nxg)
    # ground-truth club split
    clubs = [0 if nxg.nodes[v]["club"] == "Mr. Hi" else 1 for v in nxg]
    q_nx = nx.algorithms.community.modularity(
        nxg, [{v for v in nxg if clubs[v] == c} for c in (0, 1)])
    q = float(modularity(g, _comm_array(g, clubs)))
    assert np.isclose(q, q_nx, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000))
def test_modularity_matches_networkx_random(seed):
    rng = np.random.default_rng(seed)
    nxg = nx.gnp_random_graph(24, 0.2, seed=int(seed))
    if nxg.number_of_edges() == 0:
        return
    # fixed capacities: every example reuses one compiled modularity()
    g = from_networkx(nxg, n_cap=24, e_cap=2 * 276)
    comm = rng.integers(0, 4, 24)
    parts = [{v for v in range(24) if comm[v] == c} for c in range(4)]
    parts = [p for p in parts if p]
    q_nx = nx.algorithms.community.modularity(nxg, parts)
    q = float(modularity(g, _comm_array(g, comm)))
    assert np.isclose(q, q_nx, atol=1e-5)


def test_singleton_modularity_formula():
    """Q of the singleton partition = -sum (K_i/2m)^2 (no internal edges
    besides self-loops)."""
    nxg = nx.les_miserables_graph()
    g = from_networkx(nxg)
    n = int(g.n_valid)
    comm = _comm_array(g, range(n))
    k = np.asarray(g.vertex_weights())[:n]
    m = float(g.total_weight())
    expect = -np.sum((k / (2 * m)) ** 2)
    assert np.isclose(float(modularity(g, comm)), expect, rtol=1e-5)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_delta_modularity_consistent_with_q(seed):
    """Moving one vertex: Q(after) - Q(before) == dQ from Eq. 2 (property —
    the identity the local-moving phase relies on)."""
    rng = np.random.default_rng(seed)
    nxg = nx.gnp_random_graph(16, 0.3, seed=int(seed))
    if nxg.number_of_edges() < 4:
        return
    g = from_networkx(nxg, n_cap=16, e_cap=2 * 120)  # fixed caps: one jit
    n = int(g.n_valid)
    comm = rng.integers(0, 3, n)
    i = int(rng.integers(0, n))
    # target community c among neighbors
    nbrs = list(nxg.neighbors(i))
    if not nbrs:
        return
    c = int(comm[nbrs[0]])
    d = int(comm[i])
    if c == d:
        return

    comm_j = _comm_array(g, comm)
    m = g.total_weight()
    k = g.vertex_weights()
    sigma = community_weights(g, comm_j)

    # K_{i->c}, K_{i->d} by hand
    k_ic = sum(1.0 for j in nbrs if comm[j] == c and j != i)
    k_id = sum(1.0 for j in nbrs if comm[j] == d and j != i)
    dq = float(delta_modularity(
        jnp.float32(k_ic), jnp.float32(k_id), k[i],
        sigma[c], sigma[d], m))

    q_before = float(modularity(g, comm_j))
    comm2 = comm.copy()
    comm2[i] = c
    q_after = float(modularity(g, _comm_array(g, comm2)))
    assert np.isclose(q_after - q_before, dq, atol=1e-5)


def test_best_moves_agree_with_bruteforce():
    """best_moves() (sort-reduce path) equals brute-force dQ maximization."""
    nxg = nx.gnp_random_graph(40, 0.15, seed=5)   # unweighted, int nodes
    g = from_networkx(nxg)
    n = int(g.n_valid)
    rng = np.random.default_rng(1)
    comm = rng.integers(0, 5, n)
    comm_j = _comm_array(g, comm)
    m = g.total_weight()
    k = g.vertex_weights()
    sigma = community_weights(g, comm_j)
    frontier = jnp.ones((g.n_cap + 1,), bool)
    bc, bdq = best_moves(g, comm_j, sigma, k, frontier, m)
    bc, bdq = np.asarray(bc), np.asarray(bdq)

    for i in range(n):
        nbr_comms = {int(comm[j]) for j in nxg.neighbors(i) if j != i}
        nbr_comms.discard(int(comm[i]))
        if not nbr_comms:
            assert not np.isfinite(bdq[i])
            continue
        best = None
        for c in sorted(nbr_comms):
            k_ic = sum(1.0 for j in nxg.neighbors(i)
                       if comm[j] == c and j != i)
            k_id = sum(1.0 for j in nxg.neighbors(i)
                       if comm[j] == comm[i] and j != i)
            dq = float(delta_modularity(
                jnp.float32(k_ic), jnp.float32(k_id), k[i],
                sigma[c], sigma[int(comm[i])], m))
            if best is None or dq > best[1] + 1e-9:
                best = (c, dq)
        assert np.isclose(bdq[i], best[1], atol=1e-5), i


# -- zero-edge graphs: Q and dQ are 0, never NaN ------------------------------


def test_modularity_zero_edge_graph_is_zero_not_nan():
    """m == 0 (vertices, no edges): Eq. 1's 1/(2m) terms must not produce
    NaN — the guarded form returns exactly 0."""
    nxg = nx.Graph()
    nxg.add_nodes_from(range(4))
    g = from_networkx(nxg)
    q = float(modularity(g, _comm_array(g, [0, 1, 2, 3])))
    assert q == 0.0 and np.isfinite(q)


def test_modularity_single_vertex_graph():
    nxg = nx.Graph()
    nxg.add_node(0)
    g = from_networkx(nxg)
    assert float(modularity(g, _comm_array(g, [0]))) == 0.0


def test_delta_modularity_zero_m_is_zero_not_nan():
    dq = float(delta_modularity(jnp.float32(0.0), jnp.float32(0.0),
                                jnp.float32(0.0), jnp.float32(0.0),
                                jnp.float32(0.0), jnp.float32(0.0)))
    assert dq == 0.0 and np.isfinite(dq)


def test_louvain_zero_edge_graph_no_nan():
    """End to end: Louvain (refined and not) on an edgeless graph stays
    finite and keeps every vertex a singleton."""
    from repro.core.louvain import LouvainConfig, louvain, louvain_modularity

    nxg = nx.Graph()
    nxg.add_nodes_from(range(5))
    g = from_networkx(nxg)
    for cfg in (LouvainConfig(), LouvainConfig(refine="leiden")):
        res = louvain(g, cfg)
        assert np.isfinite(louvain_modularity(g, res))
        assert res.n_communities == 5


def test_deletion_only_stream_drains_to_zero_edges_no_nan():
    """A deletion-only stream that removes EVERY edge: the final update
    runs Louvain at m == 0 — Q must come back 0, not NaN (the original
    zero-edge bug), on both the plain and refined configs."""
    from repro.core.delta import make_edge_batch
    from repro.core.dynamic import louvain_dynamic
    from repro.core.graph import from_networkx as _fnx
    from repro.core.louvain import LouvainConfig

    nxg = nx.karate_club_graph()
    g = _fnx(nxg)
    edges = np.asarray(sorted(nxg.edges()))
    batches = [make_edge_batch(edges[i::4, 0], edges[i::4, 1],
                               np.zeros(len(edges[i::4])), g.n_cap,
                               b_cap=32)
               for i in range(4)]
    for cfg in (LouvainConfig(), LouvainConfig(refine="leiden")):
        res = louvain_dynamic(g, batches, config=cfg,
                              track_modularity=True)
        assert int(res.graph.e_valid) == 0
        qs = [s.modularity for s in res.batch_stats]
        assert all(np.isfinite(q) for q in qs), qs
        assert qs[-1] == 0.0
