"""Aggregation + capacity-ladder properties.

``aggregate_graph`` is pinned against the pure-NumPy coarsening oracle
(``tests/_oracle.py::_aggregate``): self-loop creation from intra-community
edges, duplicate-edge merge, sentinel padding, exact weight conservation.
The capacity ladder is tested as a pure policy (``resolve_coarse_capacity``:
tiers, floors, hysteresis), as a graph transform (re-bucket down -> up
round-trips bit-for-bit), and end-to-end (laddered ``louvain`` reproduces
un-laddered memberships with a BOUNDED number of compiles — the trace
counters in ``repro.core.graph.TRACE_COUNTS``).

Uses ``hypothesis`` when installed, ``tests/_hypothesis_fallback`` otherwise.
"""

import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:         # optional dev dep — see tests/_hypothesis_fallback
    from _hypothesis_fallback import given, settings, st

from _oracle import aggregate_oracle

from repro.configs.louvain_arch import (LADDER_HYSTERESIS, LADDER_MIN_E_CAP,
                                        LADDER_MIN_N_CAP,
                                        resolve_agg_backend,
                                        resolve_coarse_capacity)
from repro.core.aggregate import aggregate_graph, renumber_communities
from repro.core.graph import (TRACE_COUNTS, build_csr, rebucket_graph)
from repro.core.louvain import LouvainConfig, louvain
from repro.data import sbm_graph

N_CAP, E_CAP = 24, 256


def _random_graph(rng, n, e0, *, integer_w=True):
    src = rng.integers(0, n, e0)
    dst = rng.integers(0, n, e0)
    w = (rng.integers(1, 5, e0).astype(np.float32) if integer_w
         else (rng.random(e0) + 0.1).astype(np.float32))
    # Fixed capacities across draws: one compiled aggregate per shape.
    return build_csr(src, dst, w, n, symmetrize=True, dedup=True,
                     n_cap=N_CAP, e_cap=E_CAP)


def _random_renumbered(rng, g, n_groups):
    n = int(g.n_valid)
    comm = np.full(g.n_cap + 1, g.n_cap, np.int32)
    comm[:n] = rng.integers(0, n_groups, n)
    comm_ren, n_comms = renumber_communities(
        jnp.asarray(comm), g.n_valid, g.n_cap)
    return comm_ren, n_comms


def _coarse_dict(g):
    """Live coarse slots of a CSRGraph as {(ci, cj): w}."""
    e = int(g.e_valid)
    src = np.asarray(g.src)
    dst = np.asarray(g.indices)
    w = np.asarray(g.weights)
    return {(int(src[i]), int(dst[i])): float(w[i]) for i in range(e)}


@settings(max_examples=10)
@given(st.integers(min_value=0, max_value=10_000),
       st.integers(min_value=2, max_value=8))
def test_aggregate_matches_numpy_oracle(seed, n_groups):
    """Coarse slot set == the oracle's: duplicate coarse edges merged, intra-
    community edges collapsed to (c, c) self loops, weights summed exactly."""
    rng = np.random.default_rng(seed)
    g = _random_graph(rng, 16, 40)
    comm_ren, n_comms = _random_renumbered(rng, g, n_groups)
    coarse = aggregate_graph(g, comm_ren, n_comms)

    e = int(g.e_valid)
    cs, cd, cw = aggregate_oracle(
        np.asarray(g.src)[:e], np.asarray(g.indices)[:e],
        np.asarray(g.weights)[:e],
        np.asarray(comm_ren)[: g.n_cap], int(n_comms))
    want = {(int(a), int(b)): float(x) for a, b, x in zip(cs, cd, cw)}
    got = _coarse_dict(coarse)
    assert set(got) == set(want)
    for key in want:      # integer weights -> float32 sums are exact
        assert got[key] == pytest.approx(want[key], abs=0.0)
    # Intra-community mass appears as (c, c) self loops.
    src_np = np.asarray(g.src)[:e]
    dst_np = np.asarray(g.indices)[:e]
    comm_np = np.asarray(comm_ren)
    if np.any(comm_np[src_np] == comm_np[dst_np]):
        assert any(a == b for a, b in got)


@settings(max_examples=6)
@given(st.integers(min_value=0, max_value=10_000))
def test_aggregate_padding_and_conservation(seed):
    """Beyond e_valid every slot is sentinel/0; sum(w') == sum(w) exactly;
    rows are grouped (CSR indptr consistent with src)."""
    rng = np.random.default_rng(seed)
    g = _random_graph(rng, 16, 40)
    comm_ren, n_comms = _random_renumbered(rng, g, 4)
    coarse = aggregate_graph(g, comm_ren, n_comms)

    e = int(coarse.e_valid)
    src = np.asarray(coarse.src)
    dst = np.asarray(coarse.indices)
    w = np.asarray(coarse.weights)
    assert np.all(src[e:] == coarse.n_cap)
    assert np.all(dst[e:] == coarse.n_cap)
    assert np.all(w[e:] == 0.0)
    assert float(w.sum()) == pytest.approx(
        float(np.asarray(g.weights).sum()), abs=0.0)
    # indptr rebuild matches the live rows.
    indptr = np.asarray(coarse.indptr)
    counts = np.zeros(coarse.n_cap, np.int64)
    np.add.at(counts, src[:e], 1)
    np.testing.assert_array_equal(np.diff(indptr), counts)
    assert int(coarse.n_valid) == int(n_comms)


def test_aggregate_pallas_backend_matches_sort():
    """Both group-resolve backends produce the same coarse graph — equal
    bits on integer weights, float32-close otherwise."""
    rng = np.random.default_rng(7)
    for integer_w, exact in ((True, True), (False, False)):
        g = _random_graph(rng, 16, 48, integer_w=integer_w)
        comm_ren, n_comms = _random_renumbered(rng, g, 5)
        a = aggregate_graph(g, comm_ren, n_comms, backend="sort")
        b = aggregate_graph(g, comm_ren, n_comms, backend="pallas")
        np.testing.assert_array_equal(np.asarray(a.src), np.asarray(b.src))
        np.testing.assert_array_equal(np.asarray(a.indices),
                                      np.asarray(b.indices))
        np.testing.assert_array_equal(np.asarray(a.indptr),
                                      np.asarray(b.indptr))
        assert int(a.e_valid) == int(b.e_valid)
        if exact:
            np.testing.assert_array_equal(np.asarray(a.weights),
                                          np.asarray(b.weights))
        else:
            np.testing.assert_allclose(np.asarray(a.weights),
                                       np.asarray(b.weights), rtol=1e-6)


def test_aggregate_unknown_backend_raises():
    rng = np.random.default_rng(0)
    g = _random_graph(rng, 8, 12)
    comm_ren, n_comms = _random_renumbered(rng, g, 2)
    with pytest.raises(ValueError, match="aggregation backend"):
        aggregate_graph(g, comm_ren, n_comms, backend="nope")
    with pytest.raises(ValueError, match="agg_backend"):
        resolve_agg_backend("nope")
    assert resolve_agg_backend("sort") == "sort"
    assert resolve_agg_backend("pallas") == "pallas"
    assert resolve_agg_backend("auto") in ("sort", "pallas")


# ---------------------------------------------------------------------------
# Capacity-ladder policy + re-bucketing.
# ---------------------------------------------------------------------------


def test_resolve_coarse_capacity_policy():
    # Far below current caps -> power-of-two tier with slack.
    n_new, e_new = resolve_coarse_capacity(100, 1000, 4096, 65536)
    assert n_new == 128 and e_new == 2048
    # Floors: tiny coarse graphs stop at the min tier.
    n_new, e_new = resolve_coarse_capacity(3, 10, 4096, 65536)
    assert n_new == LADDER_MIN_N_CAP and e_new == LADDER_MIN_E_CAP
    # Hysteresis: a < LADDER_HYSTERESIS shrink keeps the current capacity.
    n_new, e_new = resolve_coarse_capacity(300, 40_000, 700, 70_000)
    assert (n_new, e_new) == (700, 70_000)
    # Never grows.
    n_new, e_new = resolve_coarse_capacity(60, 200, 64, 256)
    assert (n_new, e_new) == (64, 256)
    # Result always fits the live counts.
    for n_c, e_v in ((1, 1), (63, 255), (65, 257), (1000, 12345)):
        n_new, e_new = resolve_coarse_capacity(n_c, e_v, 1 << 20, 1 << 24)
        assert n_new >= n_c and e_new >= e_v
        assert n_new & (n_new - 1) == 0 and e_new & (e_new - 1) == 0


@settings(max_examples=6)
@given(st.integers(min_value=0, max_value=10_000))
def test_ladder_rebucket_round_trip(seed):
    """Re-bucket a coarse graph down to its tier and back up: every buffer
    reproduces the original bit-for-bit (sentinels rewritten both ways)."""
    rng = np.random.default_rng(seed)
    g = _random_graph(rng, 16, 40)
    comm_ren, n_comms = _random_renumbered(rng, g, 4)
    coarse = aggregate_graph(g, comm_ren, n_comms)

    n_new, e_new = resolve_coarse_capacity(
        int(n_comms), int(coarse.e_valid), coarse.n_cap, coarse.e_cap)
    n_new = min(n_new, max(int(n_comms), 8))   # force a real shrink
    e_new = min(e_new, max(int(coarse.e_valid), 8))
    down = rebucket_graph(coarse, n_new, e_new)
    assert down.n_cap == n_new and down.e_cap == e_new
    up = rebucket_graph(down, coarse.n_cap, coarse.e_cap)
    for a, b in zip(coarse, up):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_rebucket_rejects_overflow():
    rng = np.random.default_rng(1)
    g = _random_graph(rng, 16, 40)
    with pytest.raises(ValueError, match="does not fit"):
        rebucket_graph(g, 4, E_CAP)
    with pytest.raises(ValueError, match="does not fit"):
        rebucket_graph(g, N_CAP, 2)


def test_laddered_louvain_matches_and_bounds_compiles():
    """The regression pin for the ladder's whole point: laddered passes
    reproduce un-laddered memberships EXACTLY, the capacities actually
    drop, and the number of phase compiles is bounded by the distinct
    tiers (re-running adds ZERO traces — the per-tier jit cache holds)."""
    # Unique capacities so this test owns its jit cache entries.
    g, _ = sbm_graph(12, 40, p_in=0.3, p_out=0.004, seed=5)
    base = louvain(g, LouvainConfig(use_ladder=False))
    TRACE_COUNTS.clear()
    lad = louvain(g, LouvainConfig(use_ladder=True))
    first = dict(TRACE_COUNTS)

    np.testing.assert_array_equal(base.membership, lad.membership)
    caps = [(p.n_cap, p.e_cap) for p in lad.passes]
    assert caps[0] == (g.n_cap, g.e_cap)
    assert len(lad.passes) >= 2, "test vacuous — need a coarse pass"
    assert caps[1][1] < caps[0][1], f"ladder never engaged: {caps}"

    n_tiers = len(set(caps))
    assert first.get("move_phase", 0) <= n_tiers
    assert first.get("aggregate_phase", 0) <= n_tiers
    assert first.get("rebucket_capacity", 0) <= n_tiers
    # Tier reuse: the same run again re-jits NOTHING.
    louvain(g, LouvainConfig(use_ladder=True))
    assert dict(TRACE_COUNTS) == first
