"""Frontier compaction property tests: the compacted sort-reduce scan must
equal the full-scan backend per vertex — bit for bit — for ANY frontier
(empty, full, random, and frontiers overflowing the static work cap), and
the measured-overflow fallback must actually trigger when it should.

Uses ``hypothesis`` when installed, ``tests/_hypothesis_fallback`` otherwise.
"""

import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:         # optional dev dep — see tests/_hypothesis_fallback
    from _hypothesis_fallback import given, settings, st

from repro.configs.louvain_arch import (AUTO_COMPACT_MAX_FRONTIER_FRAC,
                                        SCAN_BACKENDS, compact_work_cap,
                                        resolve_scan_backend)
from repro.core.graph import build_csr
from repro.core.local_move import (CompactSortReduceScanner,
                                   SortReduceScanner, best_moves,
                                   compact_best_moves, gather_frontier_slots)
from repro.core.modularity import community_weights
from repro.data import sbm_graph


def _random_graph(rng, n, e0):
    src = rng.integers(0, n, e0)
    dst = rng.integers(0, n, e0)
    w = (rng.random(e0) + 0.1).astype(np.float32)
    # Fixed capacities across draws: one compiled scan per shape.
    return build_csr(src, dst, w, n, symmetrize=True, dedup=True,
                     n_cap=24, e_cap=256)


def _snapshot(rng, g, n_comms):
    n_cap = g.n_cap
    comm = np.full(n_cap + 1, n_cap, np.int32)
    comm[: int(g.n_valid)] = rng.integers(0, n_comms, int(g.n_valid))
    comm = jnp.asarray(comm)
    return comm, community_weights(g, comm)


def _assert_scan_equal(g, comm, sigma, frontier, work_cap):
    k = g.vertex_weights()
    m = g.total_weight()
    bc_full, bdq_full = best_moves(g, comm, sigma, k, frontier, m)
    bc_c, bdq_c, overflow = compact_best_moves(g, comm, sigma, k, frontier,
                                               m, work_cap)
    np.testing.assert_array_equal(np.asarray(bc_full), np.asarray(bc_c))
    # -inf == -inf under array_equal; bit-for-bit incl. the dead slots.
    np.testing.assert_array_equal(np.asarray(bdq_full), np.asarray(bdq_c))
    # The overflow flag is exact, not conservative.
    n_slots = int(np.asarray(frontier)[np.asarray(g.src)].sum())
    assert bool(overflow) == (n_slots > work_cap)
    return bool(overflow)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([0.0, 0.05, 0.3, 1.0]),
       st.sampled_from([16, 64, 256]))
def test_compact_matches_full_scan_property(seed, frac, work_cap):
    """Random graphs x random frontiers x caps: per-vertex (best_c, best_dq)
    must be bit-identical to the full scan, overflowing or not."""
    rng = np.random.default_rng(seed)
    g = _random_graph(rng, int(rng.integers(8, 24)), int(rng.integers(8, 64)))
    comm, sigma = _snapshot(rng, g, n_comms=6)
    fr = np.zeros(g.n_cap + 1, bool)
    n = int(g.n_valid)
    fr[:n] = rng.random(n) < frac
    _assert_scan_equal(g, comm, sigma, jnp.asarray(fr), work_cap)


def test_compact_empty_frontier():
    rng = np.random.default_rng(0)
    g = _random_graph(rng, 16, 40)
    comm, sigma = _snapshot(rng, g, 4)
    fr = jnp.zeros(g.n_cap + 1, bool)
    overflow = _assert_scan_equal(g, comm, sigma, fr, 32)
    assert not overflow


def test_compact_full_frontier_overflows_and_falls_back():
    """A full frontier over a graph with more live slots than the cap MUST
    take the fallback branch — and still match the full scan exactly."""
    rng = np.random.default_rng(1)
    g = _random_graph(rng, 20, 60)
    assert int(g.e_valid) > 16
    comm, sigma = _snapshot(rng, g, 4)
    fr = np.zeros(g.n_cap + 1, bool)
    fr[: int(g.n_valid)] = True
    overflow = _assert_scan_equal(g, comm, sigma, jnp.asarray(fr), 16)
    assert overflow, "fallback path was not exercised"


def test_compact_sub_cap_frontier_stays_compact():
    """A frontier whose slots fit the cap must NOT take the fallback."""
    rng = np.random.default_rng(2)
    g = _random_graph(rng, 16, 30)
    comm, sigma = _snapshot(rng, g, 4)
    fr = np.zeros(g.n_cap + 1, bool)
    fr[0] = True          # one vertex; degree < e_cap cap for sure
    overflow = _assert_scan_equal(g, comm, sigma, jnp.asarray(fr), 64)
    assert not overflow


def test_gather_frontier_slots_order_preserving():
    """Compaction keeps CSR slot order (the bit-for-bit precondition) and
    pads with sentinels."""
    rng = np.random.default_rng(3)
    g = _random_graph(rng, 12, 30)
    fr = np.zeros(g.n_cap + 1, bool)
    fr[[1, 5, 9]] = True
    src_c, dst_c, w_c, overflow = gather_frontier_slots(g, jnp.asarray(fr),
                                                        64)
    src_np = np.asarray(g.src)
    sel = fr[src_np]
    exp_src = src_np[sel]
    n_live = len(exp_src)
    np.testing.assert_array_equal(np.asarray(src_c)[:n_live], exp_src)
    np.testing.assert_array_equal(np.asarray(dst_c)[:n_live],
                                  np.asarray(g.indices)[sel])
    np.testing.assert_array_equal(np.asarray(w_c)[:n_live],
                                  np.asarray(g.weights)[sel])
    assert np.all(np.asarray(src_c)[n_live:] == g.n_cap)
    assert np.all(np.asarray(w_c)[n_live:] == 0)
    assert not bool(overflow)


def test_compact_scanner_through_engine_rounds():
    """End-to-end: the compact scanner's full move phase equals the full-scan
    scanner's on a delta-screened frontier (engine semantics preserved, not
    just one scan call)."""
    from repro.core.local_move import louvain_move

    g, _ = sbm_graph(n_communities=8, size=16, p_in=0.4, p_out=0.01, seed=3)
    n_cap = g.n_cap
    k = g.vertex_weights()
    m = g.total_weight()
    comm0 = jnp.arange(n_cap + 1, dtype=jnp.int32)
    sigma0 = k
    fr = np.zeros(n_cap + 1, bool)
    fr[:16] = True
    fr = jnp.asarray(fr)
    st_full = louvain_move(g, comm0, sigma0, k, m,
                           tolerance=jnp.float32(0.01), frontier0=fr)
    st_comp = louvain_move(g, comm0, sigma0, k, m,
                           tolerance=jnp.float32(0.01), frontier0=fr,
                           work_cap=compact_work_cap(g.e_cap))
    np.testing.assert_array_equal(np.asarray(st_full.comm),
                                  np.asarray(st_comp.comm))
    assert int(st_full.iters) == int(st_comp.iters)
    assert float(st_full.dq_sum) == float(st_comp.dq_sum)


def test_compact_scanner_caps_work_buffer_at_e_cap():
    rng = np.random.default_rng(4)
    g = _random_graph(rng, 10, 20)
    sc = CompactSortReduceScanner(g, g.vertex_weights(), g.total_weight(),
                                  work_cap=10 * g.e_cap)
    assert sc.work_cap == g.e_cap
    with pytest.raises(ValueError):
        CompactSortReduceScanner(g, g.vertex_weights(), g.total_weight(),
                                 work_cap=0)


def test_resolve_scan_backend_policy():
    """The configs.louvain_arch routing table, pinned."""
    assert resolve_scan_backend("full") == "full"
    assert resolve_scan_backend("compact") == "full"          # no frontier
    assert resolve_scan_backend("compact", frontier_frac=0.9) == "compact"
    assert resolve_scan_backend("auto") == "full"
    assert resolve_scan_backend(
        "auto", frontier_frac=AUTO_COMPACT_MAX_FRONTIER_FRAC) == "compact"
    assert resolve_scan_backend(
        "auto", frontier_frac=AUTO_COMPACT_MAX_FRONTIER_FRAC + 0.01) == "full"
    assert resolve_scan_backend("auto", use_ell_kernel=True) == "ell_fused"
    assert resolve_scan_backend("full", use_ell_kernel=True) == "ell"
    with pytest.raises(ValueError):                    # contradictory ask
        resolve_scan_backend("compact", use_ell_kernel=True)
    assert resolve_scan_backend("ell") == "ell"
    assert resolve_scan_backend("ell_fused") == "ell_fused"
    with pytest.raises(ValueError):
        resolve_scan_backend("bogus")
    assert set(SCAN_BACKENDS) == {"auto", "full", "compact", "ell",
                                  "ell_fused"}
    assert compact_work_cap(1000, 0.25) == 250
    assert compact_work_cap(100, 0.25) == 64    # COMPACT_WORK_MIN floor
    assert compact_work_cap(40, 0.25) == 40     # ... clamped to e_cap
