"""Skew-aware coarse re-sharding: the pure-numpy policy (plan_reshard /
owner_load_frac), the monotone relabel + host re-bucket helpers, the comm
plan's re-shard pricing, the bench driver's ``--only`` validation, the
``benchmarks/compare.py`` regression gate, and the forced-8-device
acceptance subprocess (``--runslow``) where ``reshard="auto"`` must beat
``reshard="none"`` on a skew-owned corpus at identical memberships."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from conftest import multi_device as _multi_device

from repro.configs.louvain_arch import (RESHARD_IMBALANCE_THRESHOLD,
                                        RESHARD_WIDTH_SLACK, _pow2_at_least,
                                        owner_load_frac, plan_reshard,
                                        resolve_reshard)
from repro.core.comm import comm_plan, phase_bytes, reshard_bytes
from repro.core.distributed import (ShardedGraphSpec, _reshard_coarse_host,
                                    _reshard_relabel, bucket_slots_host)

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _REPO)   # benchmarks/ is a plain directory, not on path

from benchmarks.compare import compare_dirs, compare_rows  # noqa: E402
from benchmarks.run import SECTIONS, parse_only  # noqa: E402


# ---------------------------------------------------------------- policy

def test_resolve_reshard():
    assert resolve_reshard("none") == "none"
    assert resolve_reshard("auto") == "auto"
    with pytest.raises(ValueError, match="reshard"):
        resolve_reshard("always")


def test_owner_load_frac_balanced():
    # 4 shards x 4 vertices, one slot each -> every shard holds 1/4.
    counts = np.ones(16, np.int64)
    assert owner_load_frac(counts, 4, 4) == pytest.approx(0.25)


def test_owner_load_frac_skewed_and_empty():
    counts = np.zeros(16, np.int64)
    counts[:4] = 100          # all mass on shard 0's uniform range
    assert owner_load_frac(counts, 4, 4) == pytest.approx(1.0)
    # zero total -> the 1/S floor, never a division by zero
    assert owner_load_frac(np.zeros(8, np.int64), 2, 4) == pytest.approx(0.25)


def test_plan_reshard_balanced_returns_none():
    assert plan_reshard(np.ones(64, np.int64), 4, 16) is None


def test_plan_reshard_trivial_returns_none():
    assert plan_reshard(np.ones(16, np.int64), 1, 16) is None
    assert plan_reshard(np.zeros(0, np.int64), 4, 4) is None
    assert plan_reshard(np.zeros(16, np.int64), 4, 4) is None


def test_plan_reshard_skewed_balances():
    """Hot prefix (the skewed-ownership shape aggregation produces when hub
    communities renumber first): imbalanced before, balanced after."""
    counts = np.full(64, 1, np.int64)
    counts[:8] = 200
    plan = plan_reshard(counts, 4, 16)
    assert plan is not None
    n_shards = 4
    assert plan.load_frac_before * n_shards > RESHARD_IMBALANCE_THRESHOLD
    assert plan.load_frac_after < plan.load_frac_before
    # bounds: monotone cover of the dense ids
    b = np.asarray(plan.bounds)
    assert b[0] == 0 and b[-1] == 64
    assert (np.diff(b) >= 0).all()
    # every block fits the uniform device width and the slack cap
    widths = np.diff(b)
    v_cap = _pow2_at_least(-(-64 // n_shards) * RESHARD_WIDTH_SLACK)
    assert widths.max() <= min(plan.v_per_shard, v_cap)
    # static shapes are pow2 (the jit-signature ladder contract)
    assert plan.v_per_shard & (plan.v_per_shard - 1) == 0
    assert plan.e_per_shard & (plan.e_per_shard - 1) == 0
    # the split's worst shard holds what the plan priced
    csum = np.concatenate([[0], np.cumsum(counts)])
    loads = csum[b[1:]] - csum[b[:-1]]
    assert loads.max() / counts.sum() == pytest.approx(plan.load_frac_after)


def test_plan_reshard_threshold_gate():
    """Mild skew under the threshold keeps the uniform layout (no shuffle)."""
    counts = np.full(64, 10, np.int64)
    counts[:16] += 3          # max/mean ~1.23 < 1.5
    assert plan_reshard(counts, 4, 16) is None
    assert plan_reshard(counts, 4, 16, threshold=1.1) is not None


# ------------------------------------------------------------- relabel

def test_reshard_relabel_monotone_block_law():
    bounds = np.array([0, 3, 5, 11, 12])
    v_per = 8
    n_pad_new = 32
    lut = _reshard_relabel(bounds, v_per, n_pad_new, old_cap=16)
    assert lut.shape == (17,)
    live = lut[:12]
    # strictly increasing -> ordered reductions downstream are preserved
    assert (np.diff(live) > 0).all()
    # the layout law: owner = new_id // v_per matches the bounds ranges
    owner = np.searchsorted(bounds, np.arange(12), side="right") - 1
    assert (live // v_per == owner).all()
    assert (live - owner * v_per == np.arange(12) - bounds[owner]).all()
    # everything past the live ids (incl. the old sentinel) -> new sentinel
    assert (lut[12:] == n_pad_new).all()


def test_reshard_coarse_host_roundtrip():
    """Re-bucketing through the LUT preserves the live slot multiset."""
    spec_old = ShardedGraphSpec(4, 4, 16, 16)
    rng = np.random.default_rng(7)
    # skewed coarse graph on 6 dense ids: id 0 is a hub
    src = np.concatenate([np.zeros(10, np.int64), rng.integers(1, 6, 8)])
    dst = rng.integers(0, 6, 18)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    w = (rng.random(len(src)) + 0.5).astype(np.float32)
    src_g, dst_g, w_g = bucket_slots_host(src, dst, w, spec_old)

    counts = np.bincount(src, minlength=6)
    plan = plan_reshard(counts, 4, spec_old.v_per_shard, threshold=1.0)
    assert plan is not None
    s2, d2, w2, spec_new, lut, live_mask = _reshard_coarse_host(
        src_g, dst_g, w_g, spec_old.sentinel, plan)
    assert spec_new.n_pad == 4 * plan.v_per_shard
    # live mask marks exactly the relabelled dense ids
    assert live_mask.sum() == 6
    assert live_mask[lut[:6]].all() and not live_mask[spec_new.sentinel]
    # per-shard ownership of the new slots obeys the uniform block law
    s2, d2, w2 = np.asarray(s2), np.asarray(d2), np.asarray(w2)
    for sh in range(4):
        blk = s2[sh * spec_new.e_per_shard:(sh + 1) * spec_new.e_per_shard]
        lv = blk < spec_new.sentinel
        assert (blk[lv] // spec_new.v_per_shard == sh).all()
    # inverse relabel reproduces the original slot multiset
    inv = np.full(spec_new.n_pad + 1, -1, np.int64)
    inv[lut[:6]] = np.arange(6)
    lv = s2 < spec_new.sentinel
    got = sorted(zip(inv[s2[lv]], inv[d2[lv]], w2[lv].round(5)))
    want = sorted(zip(src, dst, w.round(5)))
    assert got == want


# ------------------------------------------------------------- pricing

def test_reshard_bytes_pricing():
    # 12 B per slot (src+dst int32 + weight f32), both layouts priced once
    assert reshard_bytes(128, 64) == 12 * 192
    plan = comm_plan("delta", 4, 16, 64, move_cap=8)
    base = phase_bytes(plan, rounds=5, fallback_rounds=1)
    assert phase_bytes(plan, 5, 1, reshard_cost=reshard_bytes(128, 64)) \
        == base + 12 * 192
    assert phase_bytes(plan, 5, 1, reshard_cost=0) == base


# ------------------------------------------- bench driver --only guard

def test_run_only_validation_unit():
    assert parse_only(None) is None
    assert parse_only("fig5, distdyn") == {"fig5", "distdyn"}
    with pytest.raises(ValueError, match="bogus"):
        parse_only("fig5,bogus")
    with pytest.raises(ValueError, match="valid sections"):
        parse_only(",")
    assert "distdyn" in SECTIONS and "roofline" in SECTIONS


def test_run_only_unknown_exits_nonzero():
    """The CLI must fail fast on a typo'd section, not silently run nothing
    (validation happens before any heavy import, so this is instant)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--only", "figg5"],
        env=env, cwd=_REPO, capture_output=True, text=True, timeout=120)
    assert proc.returncode == 2, (proc.stdout, proc.stderr)
    assert "figg5" in proc.stderr and "valid sections" in proc.stderr


# ----------------------------------------------- compare.py perf gate

def _rows(ups, bpr):
    return [{"comm_backend": "delta", "batch_size": 4,
             "updates_per_s_dynamic": ups, "bytes_per_round": bpr}]


def test_compare_rows_within_threshold_passes():
    assert compare_rows(_rows(100, 1000), _rows(80, 1200), 0.25, "b") == []


def test_compare_rows_flags_both_directions():
    regs = compare_rows(_rows(100, 1000), _rows(50, 1000), 0.25, "b")
    assert [r["field"] for r in regs] == ["updates_per_s_dynamic"]
    assert regs[0]["ratio"] == pytest.approx(0.5)
    regs = compare_rows(_rows(100, 1000), _rows(100, 1600), 0.25, "b")
    assert [r["field"] for r in regs] == ["bytes_per_round"]
    # a FASTER fresh run is never a regression, in either metric direction
    assert compare_rows(_rows(100, 1000), _rows(500, 10), 0.25, "b") == []


def test_compare_rows_matches_by_identity_not_position():
    base = _rows(100, 1000) + [{"comm_backend": "gather", "batch_size": 4,
                                "updates_per_s_dynamic": 10}]
    fresh = list(reversed(base))
    assert compare_rows(base, fresh, 0.25, "b") == []


def test_compare_dirs_end_to_end(tmp_path):
    basedir, freshdir = tmp_path / "base", tmp_path / "fresh"
    basedir.mkdir(), freshdir.mkdir()
    doc = {"bench": "x", "rows": _rows(100, 1000)}
    (basedir / "BENCH_x.json").write_text(json.dumps(doc))
    bad = {"bench": "x", "rows": _rows(50, 1000)}
    (freshdir / "BENCH_x.json").write_text(json.dumps(bad))
    (freshdir / "BENCH_new.json").write_text(json.dumps(doc))  # new: ungated
    regs, compared, notes, errors = compare_dirs(str(basedir),
                                                 str(freshdir), 0.25)
    assert compared == ["x"] and len(regs) == 1
    assert any("new" in n for n in notes)
    assert errors == []


def test_compare_dirs_named_but_missing_is_an_error(tmp_path):
    """A --names entry with no artifact on either side must surface as an
    error (exit 2 in main), never compare nothing and pass."""
    basedir, freshdir = tmp_path / "base", tmp_path / "fresh"
    basedir.mkdir(), freshdir.mkdir()
    doc = {"bench": "x", "rows": _rows(100, 1000)}
    (basedir / "BENCH_x.json").write_text(json.dumps(doc))
    (freshdir / "BENCH_x.json").write_text(json.dumps(doc))
    regs, compared, notes, errors = compare_dirs(
        str(basedir), str(freshdir), 0.25, names=["x", "fleeet"])
    assert compared == ["x"] and regs == []
    assert len(errors) == 2 and all("fleeet" in e for e in errors)
    # Unreadable named artifacts are errors too.
    (freshdir / "BENCH_x.json").write_text("not json")
    _, _, _, errors = compare_dirs(str(basedir), str(freshdir), 0.25,
                                   names=["x"])
    assert any("unreadable" in e for e in errors)


# ----------------------------- forced-8-device acceptance (subprocess)

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np
import jax

from repro.compat import make_mesh
from repro.core.distributed import distributed_louvain
from repro.core.graph import build_csr
from repro.core.louvain import membership_modularity


def skewed_clique_graph(n_cliques=64, hot=8, csize=5):
    # cliques renumber to a contiguous coarse-id prefix; all-pairs links
    # among the first ``hot`` cliques concentrate the coarse edges there,
    # so the uniform owner split overloads shard 0 after aggregation.
    edges = []
    def vid(c, i):
        return c * csize + i
    for c in range(n_cliques):
        for i in range(csize):
            for j in range(i + 1, csize):
                edges.append((vid(c, i), vid(c, j), 1.0))
    for a in range(hot):
        for b in range(a + 1, hot):
            edges.append((vid(a, a % csize), vid(b, b % csize), 0.25))
    for c in range(n_cliques):
        d = (c + 1) % n_cliques
        edges.append((vid(c, 0), vid(d, 1), 0.25))
    n = n_cliques * csize
    src = np.array([e[0] for e in edges])
    dst = np.array([e[1] for e in edges])
    w = np.array([e[2] for e in edges], np.float32)
    return build_csr(src, dst, w, n, symmetrize=True,
                     e_cap=2 * len(edges) + 64)


g = skewed_clique_graph()
mesh = make_mesh((8,), ("shard",))
out = {}
runs = {}
for mode in ("none", "auto"):
    mem, _, stats = distributed_louvain(g, mesh, ("shard",), reshard=mode,
                                        use_ladder=True)
    runs[mode] = np.asarray(mem)
    out[mode] = {
        "q": membership_modularity(g, mem),
        "coarse_e_per": [r["e_per_shard"] for r in stats[1:]],
        "reshard_rows": [
            {k: r[k] for k in ("reshard", "reshard_bytes",
                               "max_shard_load_frac_before",
                               "max_shard_load_frac_after", "comm_bytes")}
            for r in stats if r.get("reshard")],
    }
mem_p, _, _ = distributed_louvain(g, mesh, ("shard",), reshard="auto",
                                  use_ladder=True, pipeline_fetch=True)
out["n_comms"] = {m: int(len(np.unique(runs[m]))) for m in runs}
out["pipeline_equal"] = bool(np.array_equal(runs["auto"], np.asarray(mem_p)))
print(json.dumps(out))
"""


@pytest.fixture(scope="module")
def reshard_8dev():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=1200,
                          cwd=_REPO)
    assert proc.returncode == 0, proc.stderr[-3000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


@pytest.mark.slow
@_multi_device
def test_reshard_fires_and_balances_8dev(reshard_8dev):
    """On the skew-owned corpus the auto policy re-shards at least once,
    the measured worst-shard load drops, and the one-time cost is priced
    into the pass's comm bytes."""
    rows = reshard_8dev["auto"]["reshard_rows"]
    assert len(rows) >= 1
    for r in rows:
        assert r["max_shard_load_frac_after"] < r["max_shard_load_frac_before"]
        assert r["reshard_bytes"] > 0
        assert r["comm_bytes"] >= r["reshard_bytes"]
    assert reshard_8dev["none"]["reshard_rows"] == []


@pytest.mark.slow
@_multi_device
def test_reshard_lower_coarse_tier_8dev(reshard_8dev):
    """The ISSUE acceptance: balanced ownership lets the coarse pass run at
    a strictly lower capacity tier than the uniform split needs."""
    e_auto = min(reshard_8dev["auto"]["coarse_e_per"])
    e_none = min(reshard_8dev["none"]["coarse_e_per"])
    assert e_auto < e_none, (e_auto, e_none)


@pytest.mark.slow
@_multi_device
def test_reshard_quality_parity_8dev(reshard_8dev):
    """Re-sharding changes the summation layout, so exact modularity ties
    (this corpus's symmetric hot block is full of them) may resolve to a
    different — equally good — partition: the contract is quality parity
    (repo precedent for multi-shard layout changes, e.g. the capacity
    ladder; bit-for-bit is pinned on the 1-shard goldens in
    test_engine_equiv.py).  The pipelined convergence fetch reorders host
    syncs only, never arithmetic, so against the SAME layout it must stay
    bit-identical."""
    q_none = reshard_8dev["none"]["q"]
    assert reshard_8dev["auto"]["q"] >= q_none - 0.01 * abs(q_none)
    assert reshard_8dev["n_comms"]["auto"] == reshard_8dev["n_comms"]["none"]
    assert reshard_8dev["pipeline_equal"]
