"""Shared fixtures + the tier-1/slow split.  NOTE: no XLA_FLAGS here — smoke
tests must see the real single CPU device; only launch/dryrun.py forces 512
placeholder devices.

Tier-1 (default) excludes tests marked ``slow`` — the multi-device subprocess
suites and the heaviest smoke compiles — so `pytest -q` stays under ~2 min on
a laptop CPU.  Run everything with ``pytest --runslow``.
"""

import jax
import numpy as np
import pytest

# Shared by the subprocess multi-device suites (test_distributed, test_halo,
# test_louvain_arch, test_sharded_ce).  Those tests run their workload in a
# subprocess that forces N host CPU devices via XLA_FLAGS, so a single-CPU
# machine can execute them; skip only when neither real devices nor a CPU
# backend that can fake them exists.
N_SUBPROCESS_DEVICES = 8
multi_device = pytest.mark.skipif(
    jax.device_count() < N_SUBPROCESS_DEVICES
    and jax.default_backend() != "cpu",
    reason=f"needs {N_SUBPROCESS_DEVICES} devices or a CPU backend able to "
           "force host devices")


def pytest_addoption(parser):
    parser.addoption(
        "--runslow", action="store_true", default=False,
        help="also run tests marked slow (subprocess/multi-device suites)")


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: excluded from tier-1; run with --runslow")


def pytest_collection_modifyitems(config, items):
    if config.getoption("--runslow"):
        return
    skip_slow = pytest.mark.skip(reason="slow: needs --runslow")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip_slow)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
