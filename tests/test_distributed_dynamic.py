"""Sharded streaming Louvain: per-shard batch-apply invariants (property),
quality parity with the single-device dynamic path, capacity growth, and the
forced-8-device acceptance suite (subprocess, ``--runslow``)."""

import json
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:         # optional dev dep — see tests/_hypothesis_fallback
    from _hypothesis_fallback import given, settings, st

from conftest import multi_device as _multi_device

from repro.compat import make_mesh
from repro.core.delta import apply_edge_batch, make_edge_batch
from repro.core.distributed import partition_graph_host
from repro.core.distributed_dynamic import (apply_batch_shard,
                                            louvain_dynamic_sharded)
from repro.core.dynamic import louvain_dynamic
from repro.core.graph import build_csr
from repro.core.louvain import louvain, membership_modularity
from repro.data import sbm_graph


def _slot_dict(src, dst, w, sent):
    src, dst, w = np.asarray(src), np.asarray(dst), np.asarray(w)
    live = src < sent
    return {(int(s), int(d)): float(x)
            for s, d, x in zip(src[live], dst[live], w[live])}


def _apply_all_shards(spec, src_g, dst_g, w_g, batch):
    """Drive the pure per-shard kernel shard-by-shard (no mesh needed)."""
    e_per = spec.e_per_shard
    outs, touched, e_news = [], [], []
    for s in range(spec.n_shards):
        sl = slice(s * e_per, (s + 1) * e_per)
        o = apply_batch_shard(spec, jnp.asarray(s, jnp.int32),
                              src_g[sl], dst_g[sl], w_g[sl],
                              batch.src, batch.dst, batch.weight,
                              batch.b_valid)
        outs.append(o[:3])
        touched.append(np.asarray(o[3]))
        e_news.append(int(o[4]))
    src2 = jnp.concatenate([o[0] for o in outs])
    dst2 = jnp.concatenate([o[1] for o in outs])
    w2 = jnp.concatenate([o[2] for o in outs])
    return src2, dst2, w2, np.concatenate(touched), e_news


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 10_000))
def test_sharded_batch_apply_matches_single_device(seed):
    """Property: after random insert/delete/reweight streams, the union of
    all shards' slots equals the single-device ``apply_edge_batch`` result,
    per-shard padding/ordering/ownership invariants hold, and the gathered
    touched-owned slices reproduce the single-device touched mask."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(6, 16))
    e0 = int(rng.integers(2, 3 * n))
    src = rng.integers(0, n, e0)
    dst = rng.integers(0, n, e0)
    w = (rng.random(e0) + 0.1).astype(np.float32)
    # Fixed capacities across examples -> ONE compiled kernel per shape.
    g = build_csr(src, dst, w, n, symmetrize=True, dedup=True,
                  n_cap=16, e_cap=192)
    n_shards = 4
    src_g, dst_g, w_g, spec = partition_graph_host(
        g, n_shards, n_target=g.n_cap, e_per_shard=64)
    assert spec.n_pad == g.n_cap  # sentinel spaces coincide -> comparable
    sent = spec.sentinel

    for _ in range(3):
        b = int(rng.integers(1, 8))
        us = rng.integers(0, n, b)
        vs = rng.integers(0, n, b)
        ws = np.where(rng.random(b) < 0.3, 0.0,
                      (rng.random(b) * 2 + 0.1)).astype(np.float32)
        batch = make_edge_batch(us, vs, ws, g.n_cap, b_cap=8)

        g, touched_ref = apply_edge_batch(g, batch)
        src_g, dst_g, w_g, touched_sh, e_news = _apply_all_shards(
            spec, src_g, dst_g, w_g, batch)

        # Union of shard slots == single-device CSR slots (exact).
        ref = _slot_dict(g.src, g.indices, g.weights, g.n_cap)
        sh = _slot_dict(src_g, dst_g, w_g, sent)
        assert sh == pytest.approx(ref)

        # Per-shard invariants: live prefix, sentinel padding, ownership,
        # strict (src, dst) order, e_new == live count.
        for s in range(n_shards):
            sl = slice(s * spec.e_per_shard, (s + 1) * spec.e_per_shard)
            ss = np.asarray(src_g[sl])
            sd = np.asarray(dst_g[sl])
            sw = np.asarray(w_g[sl])
            live = ss < sent
            cnt = int(live.sum())
            assert e_news[s] == cnt
            assert np.all(ss[:cnt] < sent) and np.all(ss[cnt:] == sent)
            assert np.all(sd[cnt:] == sent) and np.all(sw[cnt:] == 0)
            assert np.all(ss[:cnt] // spec.v_per_shard == s)
            order = ss[:cnt].astype(np.int64) * (sent + 1) + sd[:cnt]
            assert np.all(np.diff(order) > 0)

        # K_i / 2m conservation across the partition.
        k_sh = np.zeros(n)
        for (u, _), x in sh.items():
            k_sh[u] += x
        np.testing.assert_allclose(
            k_sh, np.asarray(g.vertex_weights())[:n], rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(float(jnp.sum(w_g)),
                                   2 * float(g.total_weight()), rtol=1e-5)

        # Touched: gathered owned slices == single-device mask.
        np.testing.assert_array_equal(
            touched_sh[: g.n_cap], np.asarray(touched_ref)[: g.n_cap])


def test_sharded_batch_apply_drops_out_of_capacity_endpoints():
    """n_pad > n_cap when n_cap % n_shards != 0; entries touching the
    phantom ids in [n_cap, n_pad) must be dropped exactly like the
    single-device apply drops them (n_limit plumbing)."""
    g = build_csr(np.array([0, 1]), np.array([1, 0]),
                  np.ones(2, np.float32), 10, n_cap=10, e_cap=32)
    src_g, dst_g, w_g, spec = partition_graph_host(
        g, 4, n_target=g.n_cap, e_per_shard=16)
    assert spec.n_pad > g.n_cap  # 4 * ceil(10/4) = 12
    batch = make_edge_batch([10, 2], [3, 3], [1.0, 1.0], g.n_cap, b_cap=4)

    g2, touched_ref = apply_edge_batch(g, batch)
    outs = []
    for s in range(spec.n_shards):
        sl = slice(s * spec.e_per_shard, (s + 1) * spec.e_per_shard)
        outs.append(apply_batch_shard(
            spec, jnp.asarray(s, jnp.int32), src_g[sl], dst_g[sl], w_g[sl],
            batch.src, batch.dst, batch.weight, batch.b_valid,
            n_limit=g.n_cap))
    sh = {}
    for o in outs:
        sh.update(_slot_dict(o[0], o[1], o[2], spec.sentinel))
    ref = _slot_dict(g2.src, g2.indices, g2.weights, g2.n_cap)
    assert sh == pytest.approx(ref)         # the (10, 3) entry was dropped
    assert (2, 3) in sh and not any(u >= g.n_cap or v >= g.n_cap
                                    for u, v in sh)


def _holdout_stream(n_comms, size, n_hold, n_batches, seed):
    full, _ = sbm_graph(n_communities=n_comms, size=size, p_in=0.4,
                        p_out=0.005, seed=seed)
    e = int(full.e_valid)
    src = np.asarray(full.src)[:e]
    dst = np.asarray(full.indices)[:e]
    w = np.asarray(full.weights)[:e]
    und = src < dst
    us, ud, uw = src[und], dst[und], w[und]
    rng = np.random.default_rng(seed)
    hold = rng.choice(len(us), n_hold, replace=False)
    keep = np.ones(len(us), bool)
    keep[hold] = False
    init = build_csr(np.concatenate([us[keep], ud[keep]]),
                     np.concatenate([ud[keep], us[keep]]),
                     np.concatenate([uw[keep], uw[keep]]),
                     int(full.n_valid), e_cap=e + 8)
    batches = [make_edge_batch(us[hold[i::n_batches]], ud[hold[i::n_batches]],
                               uw[hold[i::n_batches]], init.n_cap, b_cap=8)
               for i in range(n_batches)]
    return full, init, batches


def test_sharded_dynamic_matches_single_device_dynamic():
    """Same stream through ``louvain_dynamic`` and the sharded driver
    (1-shard mesh, tier-1): matching modularity and final edge sets."""
    full, init, batches = _holdout_stream(16, 16, 60, 10, seed=7)
    prev = louvain(init).membership
    mesh = make_mesh((1,), ("shard",))

    dyn_sh = louvain_dynamic_sharded(init, mesh, ("shard",), batches,
                                     prev=prev)
    dyn_sd = louvain_dynamic(init, batches, prev=prev)

    q_sh = membership_modularity(dyn_sd.graph, dyn_sh.membership)
    q_sd = membership_modularity(dyn_sd.graph, dyn_sd.membership)
    assert q_sh >= q_sd - 0.02, (q_sh, q_sd)
    assert dyn_sh.n_regrows == 0
    # Both drivers applied the same stream: final graph == the full SBM.
    assert all(s.frontier_size < s.n_vertices for s in dyn_sh.batch_stats)
    assert all(s.n_touched == t.n_touched
               for s, t in zip(dyn_sh.batch_stats, dyn_sd.batch_stats))


def test_sharded_capacity_growth_rebuckets_and_matches():
    """A stream engineered to overflow e_per_shard re-buckets into doubled
    capacity (results unchanged) instead of raising; grow_capacity=False
    raises."""
    full, init, batches = _holdout_stream(16, 16, 60, 10, seed=7)
    prev = louvain(init).membership
    mesh = make_mesh((1,), ("shard",))

    ample = louvain_dynamic_sharded(init, mesh, ("shard",), batches,
                                    prev=prev)
    tight = louvain_dynamic_sharded(init, mesh, ("shard",), batches,
                                    prev=prev, e_per_shard=1)
    assert tight.n_regrows >= 1
    assert tight.spec.e_per_shard > 1  # capacity actually doubled up
    q_tight = membership_modularity(full, tight.membership)
    q_ample = membership_modularity(full, ample.membership)
    # Grown arrays have different padding shapes, so reduction order (and
    # with it ULP-level dQ ties) may differ — quality equivalence, not
    # bitwise equality, is the contract.
    assert abs(q_tight - q_ample) < 0.02, (q_tight, q_ample)

    with pytest.raises(ValueError, match="overflow"):
        louvain_dynamic_sharded(init, mesh, ("shard",), batches, prev=prev,
                                e_per_shard=1, grow_capacity=False)


# ---------------------------------------------------------------------------
# Forced-8-device acceptance suite (subprocess so XLA_FLAGS does not leak).
# ---------------------------------------------------------------------------

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import sys
import numpy as np

sys.path.insert(0, "tests")   # subprocess cwd is the repo root
from _oracle import louvain_oracle, modularity_np, oracle_graph_slots

from repro.compat import make_mesh
from repro.core.delta import make_edge_batch
from repro.core.distributed import distributed_louvain
from repro.core.distributed_dynamic import louvain_dynamic_sharded
from repro.core.graph import build_csr
from repro.core.louvain import membership_modularity
from repro.data import sbm_graph

full, _ = sbm_graph(n_communities=64, size=16, p_in=0.4, p_out=0.002, seed=11)
e = int(full.e_valid)
src = np.asarray(full.src)[:e]
dst = np.asarray(full.indices)[:e]
w = np.asarray(full.weights)[:e]
und = src < dst
us, ud, uw = src[und], dst[und], w[und]
rng = np.random.default_rng(0)
hold = rng.choice(len(us), 100, replace=False)
keep = np.ones(len(us), bool)
keep[hold] = False
init = build_csr(np.concatenate([us[keep], ud[keep]]),
                 np.concatenate([ud[keep], us[keep]]),
                 np.concatenate([uw[keep], uw[keep]]),
                 int(full.n_valid), e_cap=e + 8)
batches = [make_edge_batch(us[hold[i::20]], ud[hold[i::20]],
                           uw[hold[i::20]], init.n_cap, b_cap=8)
           for i in range(20)]

mesh = make_mesh((2, 4), ("data", "model"))
axes = ("data", "model")
# Cold static runs need per-shard headroom: aggregation concentrates this
# SBM's coarse edges onto one shard (community skew).  e covers any skew.
prev, _, _ = distributed_louvain(init, mesh, axes, e_per_shard=e)

out = {}
# Default config: comm_backend="auto" resolves to the DELTA exchange on a
# multi-shard mesh — the stream acceptance numbers below exercise it.
dyn = louvain_dynamic_sharded(init, mesh, axes, batches, prev=prev)
cold_mem, _, _ = distributed_louvain(full, mesh, axes, e_per_shard=e)
q_dyn = membership_modularity(full, dyn.membership)
q_cold = membership_modularity(full, cold_mem)
fr = [s.frontier_size / max(s.n_vertices, 1) for s in dyn.batch_stats]
out["stream"] = {"q_dyn": q_dyn, "q_cold": q_cold,
                 "frontier_max": max(fr), "n_batches": len(dyn.batch_stats),
                 "regrows": dyn.n_regrows}

fs, fd, fw, fn = oracle_graph_slots(full)
out["oracle"] = {"q": modularity_np(fs, fd, fw,
                                    louvain_oracle(fs, fd, fw, fn))}

# Communication backends head-to-head on the SAME stream: the delta
# exchange must match gather's quality while shipping far fewer bytes.
from repro.core.louvain import LouvainConfig
gat = louvain_dynamic_sharded(init, mesh, axes, batches, prev=prev,
                              config=LouvainConfig(comm_backend="gather"))
out["comm"] = {
    "backend_delta": dyn.comm_backend, "backend_gather": gat.comm_backend,
    "q_delta": membership_modularity(full, dyn.membership),
    "q_gather": membership_modularity(full, gat.membership),
    "bpr_delta": dyn.bytes_per_round, "bpr_gather": gat.bytes_per_round,
    "fallback_rounds": dyn.comm_fallback_rounds,
    "rounds": dyn.comm_rounds,
}

# State layouts head-to-head under the gather backend on the SAME stream:
# the hybrid owner-partitioned layout must reproduce the replicated
# memberships BIT-FOR-BIT (data placement, not semantics) while shipping
# strictly fewer total bytes on the wire — boundary movers + touched-
# community deltas instead of dense O(n_pad) psums every round.
hyb = louvain_dynamic_sharded(init, mesh, axes, batches, prev=prev,
                              config=LouvainConfig(comm_backend="gather",
                                                   state_layout="hybrid"))
out["layout"] = {
    "layout": hyb.state_layout,
    "identical": bool(np.array_equal(np.asarray(hyb.membership),
                                     np.asarray(gat.membership))),
    "bytes_hybrid": int(hyb.bytes_on_wire),
    "bytes_replicated": int(gat.bytes_on_wire),
    "halo_bytes": int(hyb.halo_bytes),
    "boundary_frac": hyb.boundary_frac,
    "pass_seconds": hyb.pass_seconds_total,
    "rounds": int(hyb.comm_rounds),
}

tight = louvain_dynamic_sharded(init, mesh, axes, batches, prev=prev,
                                e_per_shard=1)
out["growth"] = {"regrows": tight.n_regrows,
                 "q": membership_modularity(full, tight.membership),
                 "e_per": tight.spec.e_per_shard}
print(json.dumps(out))
"""


@pytest.fixture(scope="module")
def dist_dyn_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=1200,
                          cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__))))
    assert proc.returncode == 0, proc.stderr[-3000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


@pytest.mark.slow
@_multi_device
def test_sharded_dynamic_acceptance_8dev(dist_dyn_results):
    """Acceptance: within 1% modularity of a cold sharded recompute while
    re-processing a minority of vertices per batch."""
    r = dist_dyn_results["stream"]
    assert r["n_batches"] == 20
    assert r["q_dyn"] >= r["q_cold"] - 0.01 * abs(r["q_cold"]), r
    assert r["frontier_max"] < 0.5, r


@pytest.mark.slow
@_multi_device
def test_sharded_dynamic_oracle_level_8dev(dist_dyn_results):
    r = dist_dyn_results["stream"]
    assert r["q_dyn"] >= dist_dyn_results["oracle"]["q"] - 0.02, r


@pytest.mark.slow
@_multi_device
def test_sharded_capacity_growth_8dev(dist_dyn_results):
    r = dist_dyn_results["growth"]
    assert r["regrows"] >= 1
    assert r["q"] >= dist_dyn_results["stream"]["q_dyn"] - 0.02, r


@pytest.mark.slow
@_multi_device
def test_sharded_delta_comm_8dev(dist_dyn_results):
    """The delta exchange on 8 real shards: "auto" routes to it, quality
    matches the gather backend, and bytes-on-wire per round drop >= 2x
    (the ISSUE acceptance ratio, measured end to end on the stream)."""
    r = dist_dyn_results["comm"]
    assert r["backend_delta"] == "delta" and r["backend_gather"] == "gather"
    assert r["q_delta"] >= r["q_gather"] - 0.01 * abs(r["q_gather"]), r
    assert r["bpr_gather"] >= 2 * r["bpr_delta"], r
    assert r["fallback_rounds"] <= r["rounds"], r


@pytest.mark.slow
@_multi_device
def test_sharded_hybrid_layout_8dev(dist_dyn_results):
    """The hybrid state layout on 8 real shards: bit-identical memberships
    to the replicated layout under the same (gather) backend, STRICTLY
    fewer total bytes on the wire end to end (the ISSUE acceptance), and a
    sane halo share (boundary-mover lanes are a fraction of the wire, the
    measured boundary fraction a genuine (0, 1] ratio)."""
    r = dist_dyn_results["layout"]
    assert r["layout"] == "hybrid"
    assert r["identical"], r
    assert 0 < r["bytes_hybrid"] < r["bytes_replicated"], r
    assert 0 < r["halo_bytes"] < r["bytes_hybrid"], r
    assert 0.0 < r["boundary_frac"] <= 1.0, r
    assert r["rounds"] > 0 and r["pass_seconds"] > 0.0, r
