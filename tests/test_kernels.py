"""Pallas louvain_scan kernel vs pure-jnp oracle: shape/dtype sweep +
hypothesis property sweep (interpret=True executes the kernel body on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:         # optional dev dep — see tests/_hypothesis_fallback
    from _hypothesis_fallback import given, settings, st

from repro.kernels.louvain_scan import ops
from repro.kernels.louvain_scan.ref import louvain_scan_ref


def _random_inputs(rng, r, d, n_comms=8, w_dtype=np.float32):
    c = rng.integers(-1, n_comms, (r, d)).astype(np.int32)
    w = (rng.random((r, d)) + 0.1).astype(w_dtype)
    w = np.where(c >= 0, w, 0).astype(w_dtype)
    sig = (rng.random((r, d)) * 5).astype(np.float32)
    ki = (rng.random((r, 1)) * 3 + 0.1).astype(np.float32)
    cown = rng.integers(0, n_comms, (r, 1)).astype(np.int32)
    sigown = (rng.random((r, 1)) * 5).astype(np.float32)
    m = np.float32(10.0)
    return (jnp.asarray(c), jnp.asarray(w), jnp.asarray(sig),
            jnp.asarray(ki), jnp.asarray(cown), jnp.asarray(sigown),
            jnp.asarray(m))


@pytest.mark.parametrize("r,d", [(8, 4), (8, 16), (16, 16), (32, 64),
                                 (8, 128), (64, 8)])
def test_pallas_matches_ref_shapes(r, d):
    rng = np.random.default_rng(r * 1000 + d)
    ins = _random_inputs(rng, r, d)
    bc_p, bdq_p = ops.louvain_scan(*ins, use_pallas=True, interpret=True)
    bc_r, bdq_r = louvain_scan_ref(*ins)
    np.testing.assert_array_equal(np.asarray(bc_p), np.asarray(bc_r))
    finite = np.isfinite(np.asarray(bdq_r))
    np.testing.assert_allclose(np.asarray(bdq_p)[finite],
                               np.asarray(bdq_r)[finite], rtol=1e-5)
    assert np.array_equal(np.isfinite(np.asarray(bdq_p)), finite)


@pytest.mark.parametrize("w_dtype", [np.float32, np.float16])
def test_pallas_weight_dtypes(w_dtype):
    rng = np.random.default_rng(7)
    ins = _random_inputs(rng, 16, 16, w_dtype=w_dtype)
    bc_p, bdq_p = ops.louvain_scan(*ins, use_pallas=True, interpret=True)
    bc_r, bdq_r = louvain_scan_ref(*ins)
    np.testing.assert_array_equal(np.asarray(bc_p), np.asarray(bc_r))


@pytest.mark.parametrize("block_rows", [1, 2, 4, 8])
def test_pallas_block_rows_invariant(block_rows):
    """Grid tiling must not change results."""
    rng = np.random.default_rng(11)
    ins = _random_inputs(rng, 16, 8)
    bc_ref, bdq_ref = louvain_scan_ref(*ins)
    bc, bdq = ops.louvain_scan(*ins, use_pallas=True, interpret=True,
                               block_rows=block_rows)
    np.testing.assert_array_equal(np.asarray(bc), np.asarray(bc_ref))


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([4, 8, 16]),
       st.sampled_from([4, 8, 32]))
def test_pallas_matches_ref_property(seed, r, d):
    rng = np.random.default_rng(seed)
    ins = _random_inputs(rng, r, d, n_comms=max(2, d // 2))
    bc_p, bdq_p = ops.louvain_scan(*ins, use_pallas=True, interpret=True)
    bc_r, bdq_r = louvain_scan_ref(*ins)
    np.testing.assert_array_equal(np.asarray(bc_p), np.asarray(bc_r))
    finite = np.isfinite(np.asarray(bdq_r))
    np.testing.assert_allclose(np.asarray(bdq_p)[finite],
                               np.asarray(bdq_r)[finite], rtol=1e-4)


def test_ref_semantics_dead_rows():
    """All-dead rows return (-1, -inf)."""
    c = jnp.full((8, 4), -1, jnp.int32)
    w = jnp.zeros((8, 4), jnp.float32)
    sig = jnp.zeros((8, 4), jnp.float32)
    ki = jnp.ones((8, 1), jnp.float32)
    cown = jnp.zeros((8, 1), jnp.int32)
    sigown = jnp.ones((8, 1), jnp.float32)
    bc, bdq = ops.louvain_scan(c, w, sig, ki, cown, sigown,
                               jnp.float32(5.0), use_pallas=True,
                               interpret=True)
    assert np.all(np.asarray(bc) == -1)
    assert np.all(np.isneginf(np.asarray(bdq)))


def test_ref_tie_breaks_to_lowest_community():
    """Two communities with identical dQ -> the smaller id wins
    (determinism requirement of the synchronous rounds)."""
    # One row, two neighbors in different communities, symmetric weights.
    c = jnp.asarray([[2, 1]], jnp.int32)
    w = jnp.asarray([[1.0, 1.0]], jnp.float32)
    sig = jnp.asarray([[3.0, 3.0]], jnp.float32)
    ki = jnp.asarray([[1.0]], jnp.float32)
    cown = jnp.asarray([[0]], jnp.int32)
    sigown = jnp.asarray([[1.0]], jnp.float32)
    bc, _ = ops.louvain_scan(c, w, sig, ki, cown, sigown, jnp.float32(8.0),
                             use_pallas=True, interpret=True)
    assert int(bc[0]) == 1
