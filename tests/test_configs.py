"""Registry coverage: all ten assigned archs expose the uniform protocol,
input specs match the assigned shapes, and every (arch x shape) smoke step
builds + runs one real step on the local device."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ALL_ARCHS, all_cells, get_arch, skipped_cells

ASSIGNED = {
    "gemma3-12b", "qwen2-1.5b", "internlm2-20b", "mixtral-8x22b",
    "deepseek-v2-236b", "equiformer-v2", "gin-tu", "gat-cora", "dimenet",
    "fm",
}


def test_all_ten_archs_registered():
    assert set(ALL_ARCHS) == ASSIGNED


def test_cell_count_and_skips():
    cells = all_cells()
    # 40 assigned minus the 2 assignment-sanctioned long_500k skips
    # (qwen2 / internlm2 are pure full-attention).
    assert len(cells) == 38
    lm_long = [(a, s) for a, s in cells if s == "long_500k"]
    assert {a for a, _ in lm_long} == {"gemma3-12b", "mixtral-8x22b",
                                       "deepseek-v2-236b"}


def test_assigned_lm_shapes_exact():
    from repro.configs.lm_common import LM_SHAPES
    assert LM_SHAPES["train_4k"] == (4096, 256, "train")
    assert LM_SHAPES["prefill_32k"] == (32768, 32, "prefill")
    assert LM_SHAPES["decode_32k"] == (32768, 128, "decode")
    assert LM_SHAPES["long_500k"] == (524288, 1, "decode")


def test_assigned_lm_configs_exact():
    cfg = get_arch("gemma3-12b").full_config()
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.d_ff, cfg.vocab) == (48, 3840, 16, 8, 15360, 262144)
    cfg = get_arch("qwen2-1.5b").full_config()
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.d_ff, cfg.vocab) == (28, 1536, 12, 2, 8960, 151936)
    assert cfg.qkv_bias
    cfg = get_arch("internlm2-20b").full_config()
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.d_ff, cfg.vocab) == (48, 6144, 48, 8, 16384, 92544)
    cfg = get_arch("mixtral-8x22b").full_config()
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.vocab) == (56, 6144, 48, 8, 32768)
    assert cfg.moe.n_experts == 8 and cfg.moe.top_k == 2
    cfg = get_arch("deepseek-v2-236b").full_config()
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads,
            cfg.vocab) == (60, 5120, 128, 102400)
    assert cfg.moe.n_experts == 160 and cfg.moe.top_k == 6
    assert cfg.moe.n_shared == 2
    assert cfg.mla.kv_lora_rank == 512


def test_assigned_gnn_configs_exact():
    from repro.configs.gnn_common import GNN_SHAPES
    assert GNN_SHAPES["full_graph_sm"].n_nodes == 2708
    assert GNN_SHAPES["full_graph_sm"].n_edges == 10556
    assert GNN_SHAPES["full_graph_sm"].d_feat == 1433
    assert GNN_SHAPES["ogb_products"].n_nodes == 2449029
    assert GNN_SHAPES["ogb_products"].n_edges == 61859140
    assert GNN_SHAPES["ogb_products"].d_feat == 100
    assert GNN_SHAPES["molecule"].n_nodes == 30
    assert GNN_SHAPES["molecule"].n_edges == 64
    assert GNN_SHAPES["molecule"].batch == 128
    # minibatch_lg: 1,024 global seeds, fanout 15-10.
    sh = GNN_SHAPES["minibatch_lg"]
    assert sh.batch * sh.n_seeds == 1024

    gin_cfg = get_arch("gin-tu").make_config(GNN_SHAPES["molecule"], False)
    assert gin_cfg.n_layers == 5 and gin_cfg.d_hidden == 64
    gat_cfg = get_arch("gat-cora").make_config(GNN_SHAPES["full_graph_sm"],
                                               False)
    assert gat_cfg.n_layers == 2 and gat_cfg.d_hidden == 8
    assert gat_cfg.n_heads == 8
    dn = get_arch("dimenet").make_config(GNN_SHAPES["molecule"], False)
    assert (dn.n_blocks, dn.d_hidden, dn.n_bilinear, dn.n_spherical,
            dn.n_radial) == (6, 128, 8, 7, 6)
    eq = get_arch("equiformer-v2").make_config(GNN_SHAPES["molecule"], False)
    assert (eq.n_layers, eq.d_hidden, eq.l_max, eq.m_max,
            eq.n_heads) == (12, 128, 6, 2, 8)


def test_assigned_fm_config_exact():
    from repro.configs.fm import FM_SHAPES, N_CANDIDATES
    cfg = get_arch("fm").full_config()
    assert cfg.n_fields == 39 and cfg.embed_dim == 10
    assert FM_SHAPES["train_batch"][0] == 65536
    assert FM_SHAPES["serve_p99"][0] == 512
    assert FM_SHAPES["serve_bulk"][0] == 262144
    assert N_CANDIDATES == 1_000_000


@pytest.mark.parametrize("arch_id,shape", all_cells(),
                         ids=[f"{a}-{s}" for a, s in all_cells()])
def test_input_specs_exist(arch_id, shape):
    arch = get_arch(arch_id)
    specs = arch.input_specs(shape)
    leaves = jax.tree.leaves(
        specs, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    assert leaves, (arch_id, shape)
    for leaf in leaves:
        assert isinstance(leaf, jax.ShapeDtypeStruct)
        assert all(d > 0 for d in leaf.shape)


def _local_mesh():
    from repro.compat import make_mesh

    return make_mesh((1, 1), ("data", "model"))


# The heaviest smoke compiles are tier-2 (slow): the same archs are already
# exercised by tests/test_models_lm.py / test_models_gnn.py every run, and
# the full registry sweep runs under --runslow (and in CI's full job).
_HEAVY_SMOKE = {"gemma3-12b", "equiformer-v2", "deepseek-v2-236b",
                "mixtral-8x22b", "internlm2-20b", "dimenet"}


@pytest.mark.parametrize(
    "arch_id",
    [pytest.param(a, marks=pytest.mark.slow) if a in _HEAVY_SMOKE
     else a for a in sorted(ASSIGNED)])
def test_smoke_step_builds_and_runs(arch_id):
    """build_step(smoke=True) lowers AND executes with real (tiny) inputs."""
    arch = get_arch(arch_id)
    shape = arch.shapes[0]
    mesh = _local_mesh()
    fn, arg_specs, in_shardings = arch.build_step(shape, mesh, smoke=True)
    with mesh:
        jitted = jax.jit(fn, in_shardings=in_shardings)
        lowered = jitted.lower(*arg_specs)
        compiled = lowered.compile()

        # Execute with concrete zeros matching the specs (zeros keep the
        # optimizer second moments valid; every model is zero-input safe).
        def concrete(spec):
            return jnp.zeros(spec.shape, spec.dtype)

        args = jax.tree.map(
            concrete, arg_specs,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
        out = compiled(*args)
        finite = all(bool(jnp.all(jnp.isfinite(x)))
                     for x in jax.tree.leaves(out)
                     if jnp.issubdtype(x.dtype, jnp.floating))
        assert finite, (arch_id, shape)
