"""Training infrastructure: optimizer, compression, checkpoint/restart,
straggler detection, Louvain partitioner."""

import os
import tempfile

import jax
import jax.numpy as jnp
import networkx as nx
import numpy as np
import pytest

from repro.optim import (AdamWConfig, CompressionConfig, adamw_init,
                         adamw_update, compress_grads, compression_init)
from repro.train import checkpoint as ckpt
from repro.train.loop import TrainLoopConfig, train


def test_adamw_converges_quadratic():
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"x": jnp.zeros(3)}
    opt = adamw_init(params)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)

    @jax.jit
    def step(p, o):
        loss, g = jax.value_and_grad(
            lambda q: jnp.sum((q["x"] - target) ** 2))(p)
        p, o, _ = adamw_update(cfg, p, g, o)
        return p, o, loss

    for _ in range(200):
        params, opt, loss = step(params, opt)
    np.testing.assert_allclose(np.asarray(params["x"]), np.asarray(target),
                               atol=1e-2)


def test_grad_clipping_bounds_update():
    params = {"x": jnp.zeros(4)}
    opt = adamw_init(params)
    cfg = AdamWConfig(lr=1.0, grad_clip=1e-3, weight_decay=0.0)
    g = {"x": jnp.full((4,), 1e6)}
    _, _, metrics = adamw_update(cfg, params, g, opt)
    assert float(metrics["grad_norm"]) > 1.0  # pre-clip norm reported


@pytest.mark.parametrize("scheme", ["topk", "int8"])
def test_compression_error_feedback(scheme):
    """Compressed grad + residual must reconstruct the raw grad exactly
    (error feedback invariant: compressed + new_residual == grad + residual)."""
    cfg = CompressionConfig(scheme=scheme, topk_fraction=0.25)
    g = {"w": jnp.asarray(np.random.default_rng(0)
                          .standard_normal((8, 8)), jnp.float32)}
    res = compression_init(g)
    cg, res2 = compress_grads(cfg, g, res)
    lhs = np.asarray(cg["w"]) + np.asarray(res2["w"])
    rhs = np.asarray(g["w"]) + np.asarray(res["w"])
    np.testing.assert_allclose(lhs, rhs, rtol=1e-2, atol=1e-2)
    if scheme == "topk":
        assert np.count_nonzero(np.asarray(cg["w"])) <= 16 + 1


def test_checkpoint_roundtrip_and_latest():
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.int32)}, "step": 3}
    with tempfile.TemporaryDirectory() as d:
        ckpt.save_checkpoint(d, 3, tree)
        ckpt.save_checkpoint(d, 7, {**tree, "step": 7})
        assert ckpt.latest_step(d) == 7
        back = ckpt.restore_checkpoint(d, 7, tree)
        np.testing.assert_array_equal(np.asarray(back["a"]),
                                      np.asarray(tree["a"]))
        assert int(back["step"]) == 7


def test_checkpoint_ignores_corrupt(tmp_path):
    """A truncated checkpoint file must not be selected as latest."""
    tree = {"x": jnp.ones(3)}
    ckpt.save_checkpoint(str(tmp_path), 1, tree)
    ckpt.save_checkpoint(str(tmp_path), 2, tree)
    # corrupt step 2
    for name in os.listdir(tmp_path):
        if "2" in name and os.path.isfile(tmp_path / name):
            with open(tmp_path / name, "wb") as f:
                f.write(b"garbage")
    latest = ckpt.latest_step(str(tmp_path))
    restored = None
    try:
        restored = ckpt.restore_checkpoint(str(tmp_path), latest, tree)
    except Exception:
        restored = ckpt.restore_checkpoint(str(tmp_path), 1, tree)
    assert restored is not None


def test_train_loop_resume_exact(tmp_path):
    """Kill the loop mid-run; resuming reproduces the uninterrupted run."""
    def make_batches():
        rng = np.random.default_rng(0)
        while True:
            x = rng.standard_normal((8, 4)).astype(np.float32)
            yield {"x": jnp.asarray(x),
                   "y": jnp.asarray(x.sum(1, keepdims=True))}

    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"]
        return jnp.mean((pred - batch["y"]) ** 2)

    params0 = {"w": jnp.zeros((4, 1))}
    ocfg = AdamWConfig(lr=1e-2)

    # uninterrupted 20 steps
    p_full, _ = train(loss_fn, jax.tree.map(jnp.copy, params0),
                      make_batches(), ocfg,
                      TrainLoopConfig(total_steps=20, ckpt_every=100,
                                      ckpt_dir=None))

    # 10 steps + checkpoint, then resume to 20
    d = str(tmp_path)
    p_half, _ = train(loss_fn, jax.tree.map(jnp.copy, params0),
                      make_batches(), ocfg,
                      TrainLoopConfig(total_steps=10, ckpt_every=10,
                                      ckpt_dir=d))
    p_res, _ = train(loss_fn, jax.tree.map(jnp.copy, params0),
                     make_batches(), ocfg,
                     TrainLoopConfig(total_steps=20, ckpt_every=100,
                                     ckpt_dir=d))
    np.testing.assert_allclose(np.asarray(p_res["w"]),
                               np.asarray(p_full["w"]), rtol=1e-5, atol=1e-6)


def test_straggler_detection():
    import time as _time
    slow = {"n": 0}

    def make_batches():
        while True:
            yield {"x": jnp.ones((2, 2)), "y": jnp.ones((2, 1))}

    def loss_fn(params, batch):
        return jnp.sum((batch["x"] @ params["w"] - batch["y"]) ** 2)

    hits = []
    orig_step = None

    # Inject slowness via the on_straggler hook + a sleeping loss wrapper is
    # awkward under jit; instead patch time.perf_counter monotonic jumps.
    calls = {"i": 0}
    real = _time.perf_counter

    def fake():
        calls["i"] += 1
        return real() + (5.0 if calls["i"] % 13 == 0 else 0.0)

    import repro.train.loop as loop_mod
    old = loop_mod.time.perf_counter
    loop_mod.time.perf_counter = fake
    try:
        _, metrics = train(loss_fn, {"w": jnp.zeros((2, 1))}, make_batches(),
                           AdamWConfig(lr=1e-3),
                           TrainLoopConfig(total_steps=30),
                           on_straggler=lambda s, dt: hits.append(s))
    finally:
        loop_mod.time.perf_counter = old
    assert metrics["n_stragglers"] >= 1
    assert hits


def test_louvain_partition_beats_random():
    """The paper's technique as a partitioner: community-aware placement cuts
    far fewer edges than random placement on a modular graph."""
    from repro.core.graph import from_networkx
    from repro.core.partition import louvain_partition, random_partition
    nxg = nx.connected_caveman_graph(16, 8)
    g = from_networkx(nxg)
    lp = louvain_partition(g, 4)
    rp = random_partition(g, 4)
    assert lp.cut_fraction < 0.5 * rp.cut_fraction, (lp.cut_fraction,
                                                     rp.cut_fraction)
    assert lp.balance < 2.0
    # order is a permutation
    assert sorted(lp.order.tolist()) == list(range(int(g.n_valid)))
