"""FM model: sum-square trick vs brute-force pairwise oracle, EmbeddingBag
semantics, retrieval scoring consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:         # optional dev dep — see tests/_hypothesis_fallback
    from _hypothesis_fallback import given, settings, st

from repro.configs.fm import smoke_config
from repro.models import recsys


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_config()
    params = recsys.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _brute_force_fm(cfg, params, field_ids):
    """O(F^2) pairwise-interaction oracle."""
    offs = cfg.field_offsets
    rows = np.asarray(field_ids) + offs[None, :]
    v = np.asarray(params["v"])[rows]            # (B, F, k)
    w = np.asarray(params["w"])[rows]            # (B, F)
    out = float(np.asarray(params["w0"])) + w.sum(1)
    b, f, k = v.shape
    pair = np.zeros(b)
    for i in range(f):
        for j in range(i + 1, f):
            pair += (v[:, i] * v[:, j]).sum(-1)
    return out + pair


def test_fm_matches_bruteforce(setup):
    cfg, params = setup
    rng = np.random.default_rng(0)
    ids = np.stack([rng.integers(0, v, 16) for v in cfg.vocab_sizes], 1)
    got = np.asarray(recsys.forward(cfg, params, jnp.asarray(ids, jnp.int32)))
    want = _brute_force_fm(cfg, params, ids)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_fm_matches_bruteforce_property(seed):
    cfg = smoke_config()
    params = recsys.init_params(cfg, jax.random.PRNGKey(seed % 17))
    rng = np.random.default_rng(seed)
    ids = np.stack([rng.integers(0, v, 4) for v in cfg.vocab_sizes], 1)
    got = np.asarray(recsys.forward(cfg, params, jnp.asarray(ids, jnp.int32)))
    want = _brute_force_fm(cfg, params, ids)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)


def test_embedding_bag_modes():
    table = jnp.asarray(np.arange(20, dtype=np.float32).reshape(10, 2))
    ids = jnp.asarray([0, 1, 2, 5], jnp.int32)
    bags = jnp.asarray([0, 0, 1, 1], jnp.int32)
    s = recsys.embedding_bag(table, ids, bags, 2, "sum")
    np.testing.assert_allclose(np.asarray(s),
                               [[2.0, 4.0], [14.0, 16.0]])
    m = recsys.embedding_bag(table, ids, bags, 2, "mean")
    np.testing.assert_allclose(np.asarray(m), [[1.0, 2.0], [7.0, 8.0]])
    mx = recsys.embedding_bag(table, ids, bags, 2, "max")
    np.testing.assert_allclose(np.asarray(mx), [[2.0, 3.0], [10.0, 11.0]])
    # per-sample weights
    ws = recsys.embedding_bag(table, ids, bags, 2, "sum",
                              weights=jnp.asarray([1.0, 2.0, 0.5, 0.5]))
    np.testing.assert_allclose(np.asarray(ws), [[4.0, 7.0], [7.0, 8.0]])


def test_retrieval_ranking_matches_full_fm_cross_terms(setup):
    """retrieval_scores ranks candidates identically to scoring the full FM
    on (user, candidate) pairs (user-internal terms are rank-constant)."""
    cfg, params = setup
    rng = np.random.default_rng(1)
    user = np.stack([rng.integers(0, v, 1) for v in cfg.vocab_sizes], 1)
    n_cand = 50
    cand_rows = rng.integers(0, cfg.total_vocab, n_cand).astype(np.int32)

    fast = np.asarray(recsys.retrieval_scores(
        cfg, params, jnp.asarray(user, jnp.int32), jnp.asarray(cand_rows)))

    # slow: score = <sum_f v_f(user), v_c> + w_c
    offs = cfg.field_offsets
    v_user = np.asarray(params["v"])[np.asarray(user)[0] + offs].sum(0)
    slow = (np.asarray(params["v"])[cand_rows] @ v_user
            + np.asarray(params["w"])[cand_rows])
    np.testing.assert_allclose(fast, slow, rtol=1e-4)
    np.testing.assert_array_equal(np.argsort(fast), np.argsort(slow))


def test_fm_training_reduces_loss(setup):
    from repro.data.recsys import synthetic_click_batches
    from repro.optim import AdamWConfig, adamw_init, adamw_update
    cfg, params = setup
    params = jax.tree.map(jnp.copy, params)
    opt = adamw_init(params)
    ocfg = AdamWConfig(lr=5e-2)

    @jax.jit
    def step(p, o, batch):
        loss, g = jax.value_and_grad(
            lambda q: recsys.loss_fn(cfg, q, batch))(p)
        p, o, _ = adamw_update(ocfg, p, g, o)
        return p, o, loss

    batches = synthetic_click_batches(cfg.vocab_sizes, batch=512, seed=0)
    losses = []
    for i, b in zip(range(25), batches):
        jb = {"field_ids": jnp.asarray(b["field_ids"]),
              "labels": jnp.asarray(b["labels"])}
        params, opt, loss = step(params, opt, jb)
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses[:3] + losses[-3:]
