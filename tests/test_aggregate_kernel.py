"""Pallas aggregation kernel (interpret mode on CPU).

The carry-chained group-detect + accumulate sweep
(``repro.kernels.aggregate.coarsen_groups_pallas``) must reproduce the XLA
sort path's group records: identical keys/positions always, identical
weights for integer-valued inputs (exact float32 sums), float32-close for
arbitrary weights.  Small blocks force multi-tile carries so the SMEM
chain (previous key, open-group partial sum, emitted count) is exercised,
including groups spanning tile boundaries.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aggregate import aggregate_graph, renumber_communities
from repro.core.graph import build_csr
from repro.kernels.aggregate import coarsen_groups_pallas


def _sorted_labeled_slots(rng, n, e0, n_groups, *, integer_w, n_cap, e_cap):
    src = rng.integers(0, n, e0)
    dst = rng.integers(0, n, e0)
    w = (rng.integers(1, 5, e0).astype(np.float32) if integer_w
         else (rng.random(e0) + 0.1).astype(np.float32))
    g = build_csr(src, dst, w, n, symmetrize=True, dedup=True,
                  n_cap=n_cap, e_cap=e_cap)
    comm = np.full(n_cap + 1, n_cap, np.int32)
    comm[: int(g.n_valid)] = rng.integers(0, n_groups, int(g.n_valid))
    comm_ren, n_comms = renumber_communities(
        jnp.asarray(comm), g.n_valid, n_cap)
    ci = np.asarray(comm_ren)[np.asarray(g.src)]
    cj = np.asarray(comm_ren)[np.asarray(g.indices)]
    wv = np.asarray(g.weights)
    order = np.lexsort((cj, ci))
    return (jnp.asarray(ci[order]), jnp.asarray(cj[order]),
            jnp.asarray(wv[order]), g, comm_ren, n_comms)


def _oracle_groups(s_ci, s_cj, s_w, sent):
    """Group records straight from the sorted slot list (NumPy)."""
    ci = np.asarray(s_ci)
    cj = np.asarray(s_cj)
    w = np.asarray(s_w, np.float64)
    recs = []
    i = 0
    while i < len(ci):
        j = i
        tot = 0.0
        while j < len(ci) and ci[j] == ci[i] and cj[j] == cj[i]:
            tot += w[j]
            j += 1
        if ci[i] != sent:
            recs.append((int(ci[i]), int(cj[i]), tot))
        i = j
    return recs


@pytest.mark.parametrize("block", [128, 512])
@pytest.mark.parametrize("integer_w", [True, False])
def test_kernel_groups_match_oracle(block, integer_w):
    rng = np.random.default_rng(3)
    s_ci, s_cj, s_w, g, _, _ = _sorted_labeled_slots(
        rng, 24, 80, 5, integer_w=integer_w, n_cap=24, e_cap=300)
    sent = g.n_cap
    emit, pos, gsrc, gdst, gw = coarsen_groups_pallas(
        s_ci, s_cj, s_w, sent=sent, block=block, interpret=True)
    emit = np.asarray(emit)
    recs = [(int(np.asarray(gsrc)[i]), int(np.asarray(gdst)[i]),
             float(np.asarray(gw)[i]))
            for i in np.flatnonzero(emit)]
    want = _oracle_groups(s_ci, s_cj, s_w, sent)
    assert len(recs) == len(want)
    # Positions are the dense 0..L-1 group order.
    np.testing.assert_array_equal(np.asarray(pos)[emit > 0],
                                  np.arange(len(want)))
    for (a, b, x), (aw, bw, xw) in zip(recs, want):
        assert (a, b) == (aw, bw)
        if integer_w:
            assert x == xw          # exact float32 sums
        else:
            assert x == pytest.approx(xw, rel=1e-6)


def test_kernel_group_spanning_many_tiles():
    """One giant group crossing every tile boundary: the open-sum carry must
    chain exactly (integer weights -> exact equality)."""
    total = 700                       # > 5 tiles at block=128
    s_ci = jnp.zeros((total,), jnp.int32)
    s_cj = jnp.zeros((total,), jnp.int32)
    s_w = jnp.asarray(np.arange(1, total + 1) % 7 + 1, jnp.float32)
    emit, pos, gsrc, gdst, gw = coarsen_groups_pallas(
        s_ci, s_cj, s_w, sent=5, block=128, interpret=True)
    idx = np.flatnonzero(np.asarray(emit))
    assert len(idx) == 1
    assert float(np.asarray(gw)[idx[0]]) == float(np.asarray(s_w).sum())
    assert int(np.asarray(pos)[idx[0]]) == 0


def test_kernel_all_padding_emits_nothing():
    sent = 9
    s_ci = jnp.full((130,), sent, jnp.int32)
    s_cj = jnp.full((130,), sent, jnp.int32)
    s_w = jnp.zeros((130,), jnp.float32)
    emit, *_ = coarsen_groups_pallas(s_ci, s_cj, s_w, sent=sent,
                                     block=128, interpret=True)
    assert int(np.asarray(emit).sum()) == 0


def test_aggregate_graph_pallas_end_to_end_exact():
    """Through ``aggregate_graph(backend="pallas")``: identical coarse CSR
    to the sort backend on integer weights (the golden-corpus regime)."""
    rng = np.random.default_rng(11)
    _, _, _, g, comm_ren, n_comms = _sorted_labeled_slots(
        rng, 32, 120, 6, integer_w=True, n_cap=32, e_cap=400)
    a = aggregate_graph(g, comm_ren, n_comms, backend="sort")
    b = aggregate_graph(g, comm_ren, n_comms, backend="pallas")
    for fa, fb in zip(a, b):
        np.testing.assert_array_equal(np.asarray(fa), np.asarray(fb))
