"""End-to-end GVE-Louvain behaviour: quality, invariants, paper parameters."""

import jax.numpy as jnp
import networkx as nx
import numpy as np
import pytest

from repro.core.aggregate import (aggregate_graph, community_vertices_csr,
                                  renumber_communities)
from repro.core.graph import from_networkx
from repro.core.louvain import LouvainConfig, louvain, louvain_modularity
from repro.core.modularity import modularity
from repro.data import sbm_graph


def _nx_louvain_q(nxg, seed=0):
    com = nx.algorithms.community.louvain_communities(nxg, seed=seed)
    return nx.algorithms.community.modularity(nxg, com)


@pytest.mark.parametrize("make", [
    nx.karate_club_graph,
    nx.les_miserables_graph,
    lambda: nx.connected_caveman_graph(8, 6),
])
def test_quality_close_to_networkx(make):
    """Q within 5% of networkx's sequential Louvain (the paper reports GVE
    within ~1% of Grappolo/NetworKit; synchronous rounds wobble slightly)."""
    nxg = make()
    g = from_networkx(nxg)
    res = louvain(g)
    q = louvain_modularity(g, res)
    q_nx = _nx_louvain_q(nxg)
    assert q >= 0.95 * q_nx, (q, q_nx)


def test_sbm_planted_communities_recovered():
    g, truth = sbm_graph(n_communities=8, size=32, p_in=0.3, p_out=0.005,
                         seed=1)
    res = louvain(g)
    # Every planted block should map (almost) 1:1 onto a found community.
    n = int(g.n_valid)
    mem = res.membership
    agree = 0
    for b in range(8):
        ids, counts = np.unique(mem[truth == b], return_counts=True)
        agree += counts.max()
    assert agree / n > 0.95
    assert 4 <= res.n_communities <= 16


def test_aggregation_conserves_weight():
    nxg = nx.les_miserables_graph()
    g = from_networkx(nxg)
    n = int(g.n_valid)
    rng = np.random.default_rng(0)
    comm = rng.integers(0, 6, n)
    comm_j = jnp.asarray(np.concatenate([comm, [g.n_cap]]), jnp.int32)
    comm_ren, n_comms = renumber_communities(comm_j, g.n_valid, g.n_cap)
    coarse = aggregate_graph(g, comm_ren, n_comms)
    assert float(coarse.total_weight()) == pytest.approx(
        float(g.total_weight()), rel=1e-6)
    assert int(coarse.n_valid) == int(n_comms)
    # Q of the coarse singleton partition == Q of comm on the fine graph.
    idx = jnp.arange(coarse.n_cap + 1, dtype=jnp.int32)
    q_coarse = float(modularity(coarse, idx))
    q_fine = float(modularity(g, comm_j))
    assert np.isclose(q_coarse, q_fine, atol=1e-5)


def test_aggregation_matches_networkx_quotient():
    nxg = nx.les_miserables_graph()
    g = from_networkx(nxg)
    n = int(g.n_valid)
    rng = np.random.default_rng(3)
    comm = rng.integers(0, 5, n)
    comm_j = jnp.asarray(np.concatenate([comm, [g.n_cap]]), jnp.int32)
    comm_ren, n_comms = renumber_communities(comm_j, g.n_valid, g.n_cap)
    coarse = aggregate_graph(g, comm_ren, n_comms)

    # Build the same quotient in numpy from the original slot list.
    ren = np.asarray(comm_ren)[:n]
    src = np.asarray(g.src)
    dst = np.asarray(g.indices)
    w = np.asarray(g.weights)
    live = src < g.n_cap
    agg = {}
    for s, d, ww in zip(ren[src[live]], ren[dst[live]], w[live]):
        agg[(int(s), int(d))] = agg.get((int(s), int(d)), 0.0) + float(ww)

    c_src = np.asarray(coarse.src)
    c_dst = np.asarray(coarse.indices)
    c_w = np.asarray(coarse.weights)
    got = {}
    for s, d, ww in zip(c_src, c_dst, c_w):
        if s < coarse.n_cap:
            got[(int(s), int(d))] = got.get((int(s), int(d)), 0.0) + float(ww)
    assert set(got) == set(agg)
    for key in agg:
        assert np.isclose(got[key], agg[key], rtol=1e-5), key


def test_renumber_dense_and_stable():
    # community ids live in vertex-id space [0, n_cap); sentinel = n_cap.
    comm = jnp.asarray([5, 5, 4, 2, 4, 2, 6], jnp.int32)
    out, n = renumber_communities(comm, jnp.int32(6), 6)
    out = np.asarray(out)
    assert int(n) == 3
    assert out[-1] == 6                       # sentinel fixed
    # dense ids, order-preserving (2 -> 0, 4 -> 1, 5 -> 2)
    np.testing.assert_array_equal(out[:6], [2, 2, 1, 0, 1, 0])


def test_community_vertices_csr_groups():
    comm = jnp.asarray([1, 0, 1, 0, 2, 999], jnp.int32)
    offsets, order = community_vertices_csr(comm, jnp.int32(5), 5)
    offsets, order = np.asarray(offsets), np.asarray(order)
    # communities 0: {1,3}, 1: {0,2}, 2: {4}
    assert offsets[0] == 0 and offsets[1] == 2 and offsets[2] == 4
    assert set(order[0:2]) == {1, 3}
    assert set(order[2:4]) == {0, 2}
    assert order[4] == 4


def test_max_passes_and_threshold_scaling_respected():
    nxg = nx.les_miserables_graph()
    g = from_networkx(nxg)
    res = louvain(g, LouvainConfig(max_passes=1))
    assert res.n_passes == 1
    res2 = louvain(g, LouvainConfig(max_iterations=2))
    assert all(p.iterations <= 2 for p in res2.passes)


def test_aggregation_tolerance_stops_early():
    # On a graph with weak structure, |G'|/|G| stays high -> stop pass 1.
    nxg = nx.gnp_random_graph(60, 0.5, seed=0)
    g = from_networkx(nxg)
    res = louvain(g, LouvainConfig(aggregation_tolerance=0.01))
    assert res.n_passes <= 2


def test_pruning_matches_unpruned_quality():
    nxg = nx.les_miserables_graph()
    g = from_networkx(nxg)
    q_on = louvain_modularity(g, louvain(g, LouvainConfig(use_pruning=True)))
    q_off = louvain_modularity(g, louvain(g, LouvainConfig(use_pruning=False)))
    assert abs(q_on - q_off) < 0.05


def test_ell_kernel_path_equivalent_quality():
    nxg = nx.les_miserables_graph()
    g = from_networkx(nxg)
    q_sort = louvain_modularity(g, louvain(g, LouvainConfig()))
    q_ell = louvain_modularity(
        g, louvain(g, LouvainConfig(use_ell_kernel=True)))
    assert abs(q_sort - q_ell) < 0.05
    assert q_ell > 0.4


def test_isolated_vertices_stay_put():
    nxg = nx.Graph()
    nxg.add_edges_from([(0, 1), (1, 2)])
    nxg.add_nodes_from([3, 4])               # isolated
    g = from_networkx(nxg)
    res = louvain(g)
    assert len(res.membership) == 5
    assert np.isfinite(louvain_modularity(g, res))


# -- Leiden-style refinement --------------------------------------------------

from _oracle import (disconnected_communities, modularity_np,  # noqa: E402
                     oracle_graph_slots, refine_oracle)


def _badly_connected_graph():
    """The committed pathology corpus: plain parallel Louvain leaves a
    disconnected community here (see tests/golden/capture_engine_golden)."""
    return from_networkx(nx.gnp_random_graph(120, 0.05, seed=21))


def test_unrefined_louvain_leaves_disconnected_community():
    """The regression the refinement phase exists for: with refine="none"
    the audit finds at least one community whose induced subgraph is NOT
    connected on the pathology corpus."""
    g = _badly_connected_graph()
    src, dst, w, _ = oracle_graph_slots(g)
    mem = louvain(g).membership
    assert len(disconnected_communities(src, dst, mem)) >= 1


def test_leiden_communities_all_connected():
    """refine="leiden" yields ZERO disconnected communities on every golden
    corpus (including the pathology one).  Tier-1 runs the sort-reduce
    family everywhere plus the ELL kernel on the pathology corpus; the full
    ELL-family matrix is the slow test below."""
    from golden.capture_engine_golden import corpora

    for name, g in corpora().items():
        src, dst, w, _ = oracle_graph_slots(g)
        mem = louvain(g, LouvainConfig(refine="leiden")).membership
        assert disconnected_communities(src, dst, mem) == [], name
    g = _badly_connected_graph()
    src, dst, w, _ = oracle_graph_slots(g)
    mem = louvain(g, LouvainConfig(refine="leiden",
                                   use_ell_kernel=True)).membership
    assert disconnected_communities(src, dst, mem) == []


@pytest.mark.slow
def test_leiden_communities_all_connected_ell_full():
    """Full-matrix ELL-kernel variant of the connectivity audit."""
    from golden.capture_engine_golden import corpora

    cfg = LouvainConfig(refine="leiden", use_ell_kernel=True)
    for name, g in corpora().items():
        src, dst, w, _ = oracle_graph_slots(g)
        mem = louvain(g, cfg).membership
        assert disconnected_communities(src, dst, mem) == [], name


def test_leiden_modularity_not_worse():
    """The reported (outer) partition under refinement never loses Q vs the
    unrefined run on the golden corpora."""
    from golden.capture_engine_golden import corpora

    for name, g in corpora().items():
        src, dst, w, _ = oracle_graph_slots(g)
        q_none = modularity_np(src, dst, w, louvain(g).membership)
        q_ref = modularity_np(
            src, dst, w, louvain(g, LouvainConfig(refine="leiden")).membership)
        assert q_ref >= q_none - 1e-9, (name, q_none, q_ref)


def test_refine_rejects_unknown_mode():
    g = from_networkx(nx.karate_club_graph())
    with pytest.raises(ValueError, match="refine"):
        louvain(g, LouvainConfig(refine="bogus"))


def test_refine_oracle_properties():
    """The NumPy reference refinement: refines the outer partition and
    every refined community is connected."""
    g = _badly_connected_graph()
    src, dst, w, n = oracle_graph_slots(g)
    outer = louvain(g).membership
    refined = refine_oracle(src, dst, w, n, outer)
    # Refinement: each refined community lies inside ONE outer community.
    for r in np.unique(refined):
        assert len(np.unique(outer[refined == r])) == 1
    assert disconnected_communities(src, dst, refined) == []
    # It genuinely splits the disconnected community (strict refinement).
    assert len(np.unique(refined)) > len(np.unique(outer))


def test_refine_pass_stats_populated():
    g = _badly_connected_graph()
    res = louvain(g, LouvainConfig(refine="leiden"))
    assert all(p.refine_iterations is not None for p in res.passes)
    assert all(p.n_refined is not None and p.n_refined >= p.n_communities
               for p in res.passes)
    assert all("refine" in p.phase_seconds for p in res.passes)
    res_none = louvain(g)
    assert all(p.refine_iterations is None and p.n_refined is None
               for p in res_none.passes)


# -- per-level memberships (LouvainResult.levels) -----------------------------


def _is_coarsening(fine, coarse):
    """coarse is a coarsening of fine: fine-equal pairs stay coarse-equal
    (checked via a single-valued fine -> coarse label map)."""
    m = {}
    for f, c in zip(fine.tolist(), coarse.tolist()):
        if m.setdefault(f, c) != c:
            return False
    return True


def test_levels_nest_and_fold_in_order():
    """refine="none": each level coarsens the previous (the dendrogram fold
    order), the last level IS the membership, and every level's labeling
    matches the recorded per-pass community count."""
    g = from_networkx(nx.les_miserables_graph())
    res = louvain(g)
    assert len(res.levels) == res.n_passes
    np.testing.assert_array_equal(res.levels[-1], res.membership)
    for a, b in zip(res.levels, res.levels[1:]):
        assert _is_coarsening(a, b)
    for lvl, p in zip(res.levels, res.passes):
        assert len(np.unique(lvl)) == p.n_communities


def test_levels_leiden_reports_outer_per_pass():
    """refine="leiden": levels are the OUTER partitions (reported per pass);
    the last one is the membership and per-pass counts line up.  Outer
    levels need not nest — but Q must not decrease across them."""
    g = _badly_connected_graph()
    src, dst, w, _ = oracle_graph_slots(g)
    res = louvain(g, LouvainConfig(refine="leiden"))
    assert len(res.levels) == res.n_passes
    np.testing.assert_array_equal(res.levels[-1], res.membership)
    for lvl, p in zip(res.levels, res.passes):
        assert len(np.unique(lvl)) == p.n_communities
    qs = [modularity_np(src, dst, w, lvl) for lvl in res.levels]
    assert all(b >= a - 1e-9 for a, b in zip(qs, qs[1:])), qs
