"""Capture per-path golden memberships for the engine-equivalence tests.

Run ONCE against a known-good tree (it was run against the pre-engine-refactor
tree to freeze its exact outputs) and commit the resulting
``tests/golden/engine_memberships.npz``:

    PYTHONPATH=src JAX_PLATFORMS=cpu python tests/golden/capture_engine_golden.py

``tests/test_engine_equiv.py`` then asserts every execution path still
reproduces these memberships BIT-FOR-BIT on CPU.  Regenerating the file is a
deliberate act (a semantics change), not part of the test run.
"""

import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from _oracle import oracle_graph_slots  # noqa: E402

from repro.compat import make_mesh  # noqa: E402
from repro.core.delta import make_edge_batch  # noqa: E402
from repro.core.distributed import distributed_louvain  # noqa: E402
from repro.core.dynamic import louvain_dynamic  # noqa: E402
from repro.core.graph import build_csr  # noqa: E402
from repro.core.louvain import LouvainConfig, louvain  # noqa: E402
from repro.data import sbm_graph  # noqa: E402


def corpora():
    import networkx as nx
    from repro.core.graph import from_networkx

    lesmis = from_networkx(nx.les_miserables_graph())
    sbm, _ = sbm_graph(n_communities=8, size=16, p_in=0.4, p_out=0.01, seed=2)
    ring = from_networkx(nx.ring_of_cliques(8, 6))
    # The badly-connected corpus: plain parallel Louvain leaves a
    # DISCONNECTED community on this graph (pinned by the connectivity
    # audit in tests/test_louvain.py); refine="leiden" fixes it.
    gnp = from_networkx(nx.gnp_random_graph(120, 0.05, seed=21))
    return {"lesmis": lesmis, "sbm": sbm, "ring_of_cliques": ring,
            "gnp": gnp}


def dynamic_stream():
    """The deterministic held-out SBM stream of test_oracle_golden."""
    full, _ = sbm_graph(n_communities=8, size=16, p_in=0.4, p_out=0.01, seed=2)
    e = int(full.e_valid)
    src, dst, w, _ = oracle_graph_slots(full)
    und = src < dst
    us, ud, uw = src[und], dst[und], w[und]
    rng = np.random.default_rng(0)
    hold = rng.choice(len(us), 40, replace=False)
    keep = np.ones(len(us), bool)
    keep[hold] = False
    init = build_csr(np.concatenate([us[keep], ud[keep]]),
                     np.concatenate([ud[keep], us[keep]]),
                     np.concatenate([uw[keep], uw[keep]]),
                     int(full.n_valid), e_cap=e + 8)
    batches = [make_edge_batch(us[hold[i::8]], ud[hold[i::8]],
                               uw[hold[i::8]], init.n_cap, b_cap=8)
               for i in range(8)]
    return init, batches


def main():
    out = {}
    mesh = make_mesh((1,), ("shard",))
    for name, g in corpora().items():
        out[f"single__{name}"] = louvain(g).membership
        out[f"ell__{name}"] = louvain(
            g, LouvainConfig(use_ell_kernel=True)).membership
        mem, _, _ = distributed_louvain(g, mesh, ("shard",))
        out[f"sharded__{name}"] = mem
        # Leiden-refined goldens: same corpora through the constrained
        # refinement sweep (reported membership = outer partition).
        out[f"single_leiden__{name}"] = louvain(
            g, LouvainConfig(refine="leiden")).membership
        out[f"ell_leiden__{name}"] = louvain(
            g, LouvainConfig(use_ell_kernel=True,
                             refine="leiden")).membership
        mem, _, _ = distributed_louvain(g, mesh, ("shard",),
                                        refine="leiden")
        out[f"sharded_leiden__{name}"] = mem
    init, batches = dynamic_stream()
    out["dynamic__sbm_stream"] = louvain_dynamic(init, batches).membership
    init, batches = dynamic_stream()
    out["dynamic_leiden__sbm_stream"] = louvain_dynamic(
        init, batches, config=LouvainConfig(refine="leiden")).membership
    from repro.core.distributed_dynamic import louvain_dynamic_sharded
    init, batches = dynamic_stream()
    out["sharded_dynamic__sbm_stream"] = louvain_dynamic_sharded(
        init, mesh, ("shard",), batches).membership
    init, batches = dynamic_stream()
    out["sharded_dynamic_leiden__sbm_stream"] = louvain_dynamic_sharded(
        init, mesh, ("shard",), batches,
        config=LouvainConfig(refine="leiden")).membership

    path = os.path.join(os.path.dirname(__file__), "engine_memberships.npz")
    np.savez_compressed(path, **out)
    for k, v in sorted(out.items()):
        print(f"{k}: n={len(v)} n_comms={len(np.unique(v))}")
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
