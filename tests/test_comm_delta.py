"""Property tests for the delta-exchange primitives (repro.core.comm).

Everything in the module is pure jnp on one shard's arrays, so the whole
layer is testable without a mesh: bit-pack/unpack round-trips at every lane
width, the mover compaction and top-k touched-community selection (empty,
full, overflowing, and skewed inputs), and the bytes-on-wire plan that the
pass-loop stats and the distdyn benchmark report.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.comm import (comm_plan, compact_movers, label_bits,
                             pack_bits, packed_lanes, phase_bytes,
                             topk_touched_deltas, unpack_bits)
from repro.core.distributed import ShardedGraphSpec, sharded_comm_plan


# -- bit packing ------------------------------------------------------------


def test_label_bits_edges():
    assert label_bits(0) == 1
    assert label_bits(1) == 1
    assert label_bits(2) == 1
    assert label_bits(3) == 2
    assert label_bits(256) == 8
    assert label_bits(257) == 9


def test_packed_lanes_is_ceil_division():
    assert packed_lanes(0, 7) == 0
    assert packed_lanes(1, 7) == 1
    assert packed_lanes(32, 1) == 1
    assert packed_lanes(33, 1) == 2
    assert packed_lanes(37, 4) == 5   # 148 bits -> 5 lanes, not 4


@pytest.mark.parametrize("width", [1, 3, 4, 7, 13, 17, 31, 32])
@pytest.mark.parametrize("count", [0, 1, 5, 37, 64, 100])
def test_pack_unpack_round_trip(width, count):
    rng = np.random.default_rng(width * 1000 + count)
    mask = np.uint32((1 << width) - 1)
    vals = jnp.asarray(
        rng.integers(0, 2 ** min(width, 31), size=count), jnp.int32)
    lanes = pack_bits(vals, width)
    assert lanes.shape == (packed_lanes(count, width),)
    assert lanes.dtype == jnp.uint32
    out = unpack_bits(lanes, width, count)
    assert np.array_equal(np.asarray(out).astype(np.uint32) & mask,
                          np.asarray(vals).astype(np.uint32) & mask)


def test_pack_unpack_straddling_values():
    """Width 13 straddles lane boundaries constantly; max values exercise
    every bit of the straddle arithmetic."""
    width, count = 13, 50
    vals = jnp.full((count,), (1 << width) - 1, jnp.int32)
    out = unpack_bits(pack_bits(vals, width), width, count)
    assert np.array_equal(np.asarray(out), np.asarray(vals))


def test_pack_bits_rejects_bad_width():
    v = jnp.zeros((4,), jnp.int32)
    with pytest.raises(ValueError):
        pack_bits(v, 0)
    with pytest.raises(ValueError):
        unpack_bits(jnp.zeros((1,), jnp.uint32), 33, 4)


# -- mover compaction -------------------------------------------------------


def test_compact_movers_empty():
    flag = jnp.zeros((8,), bool)
    vals = jnp.arange(8, dtype=jnp.int32)
    idx, val, n = compact_movers(flag, vals, 4, jnp.int32(99))
    assert int(n) == 0
    assert np.all(np.asarray(idx) == 8)      # local sentinel = L
    assert np.all(np.asarray(val) == 99)     # fill


def test_compact_movers_full_exact():
    flag = jnp.asarray([1, 0, 1, 1, 0, 1], bool)
    vals = jnp.asarray([10, 11, 12, 13, 14, 15], jnp.int32)
    idx, val, n = compact_movers(flag, vals, 4, jnp.int32(-1))
    assert int(n) == 4
    assert np.array_equal(np.asarray(idx), [0, 2, 3, 5])
    assert np.array_equal(np.asarray(val), [10, 12, 13, 15])


def test_compact_movers_overflow_reports_true_count():
    flag = jnp.ones((10,), bool)
    vals = jnp.arange(10, dtype=jnp.int32)
    idx, val, n = compact_movers(flag, vals, 3, jnp.int32(0))
    assert int(n) == 10                      # uncapped count -> fallback
    assert np.array_equal(np.asarray(idx), [0, 1, 2])
    assert np.array_equal(np.asarray(val), [0, 1, 2])


# -- top-k touched communities ----------------------------------------------


def _mask(sent, ids):
    m = np.zeros(sent + 1, bool)
    m[list(ids)] = True
    return jnp.asarray(m)


def test_topk_touched_empty():
    sent = 16
    delta = jnp.arange(sent + 1, dtype=jnp.float32)
    c, d, n = topk_touched_deltas(delta, _mask(sent, []), 4, sent)
    assert int(n) == 0
    assert np.all(np.asarray(c) == sent)
    assert np.all(np.asarray(d) == 0.0)


def test_topk_touched_ascending_and_ignores_sentinel_slot():
    sent = 10
    delta = jnp.arange(sent + 1, dtype=jnp.float32)
    c, d, n = topk_touched_deltas(delta, _mask(sent, [7, 3, 2, sent]),
                                  4, sent)
    assert int(n) == 3                       # the sent slot never ships
    assert np.array_equal(np.asarray(c), [2, 3, 7, 10])
    assert np.array_equal(np.asarray(d), [2.0, 3.0, 7.0, 0.0])


def test_topk_touched_full_capacity():
    sent = 8
    delta = -jnp.arange(sent + 1, dtype=jnp.float32)
    c, d, n = topk_touched_deltas(delta, _mask(sent, [0, 1, 2, 3]), 4, sent)
    assert int(n) == 4
    assert np.array_equal(np.asarray(c), [0, 1, 2, 3])
    assert np.array_equal(np.asarray(d), [0.0, -1.0, -2.0, -3.0])


def test_topk_touched_skewed_overflow_flags_fallback():
    """A skewed round touching more communities than the cap must report
    the TRUE count (the overflow signal) while still shipping the first
    cap ids."""
    sent = 32
    delta = jnp.ones((sent + 1,), jnp.float32)
    c, d, n = topk_touched_deltas(delta, _mask(sent, range(10)), 4, sent)
    assert int(n) == 10 > 4
    assert np.array_equal(np.asarray(c), [0, 1, 2, 3])


# -- bytes-on-wire plan -----------------------------------------------------


def test_comm_plan_delta_beats_gather_at_8_shards():
    """The acceptance ratio, at the plan level: with the policy caps, a
    regular delta round ships >= 2x fewer bytes than a gather round on an
    8-shard layout — and even an all-fallback delta stream stays cheaper
    (the dense fallback still skips the sizes psum)."""
    spec = ShardedGraphSpec(8, 64, 256, 512)
    g = sharded_comm_plan(spec, "gather")
    d = sharded_comm_plan(spec, "delta")
    assert g.round_bytes >= 2 * d.round_bytes
    assert d.fallback_bytes < g.round_bytes


def test_comm_plan_gather_has_no_fallback_discount():
    p = comm_plan("gather", 4, 32, 128)
    assert p.round_bytes == p.fallback_bytes
    assert phase_bytes(p, 10, 3) == 10 * p.round_bytes


def test_phase_bytes_clamps_fallbacks():
    p = comm_plan("delta", 2, 16, 32, move_cap=4)
    assert phase_bytes(p, 2, 5) == 2 * p.fallback_bytes
    assert phase_bytes(p, 3, 1) == 2 * p.round_bytes + p.fallback_bytes


def test_comm_plan_rejects_unknown_backend():
    with pytest.raises(ValueError):
        comm_plan("carrier-pigeon", 2, 16, 32)
