"""Pure-NumPy sequential Louvain oracle — no JAX anywhere.

An independent reference implementation of classic sequential Louvain
(Blondel et al.), used by the golden tests to pin the quality of every
execution path in the repo (single-device sort-reduce, ELL kernel, sharded
static, sharded dynamic).  It deliberately shares NO code with ``src/``:
adjacency is a plain dict-of-dicts, the move phase is the textbook
sequential sweep (vertices in id order, best community by modularity gain,
lowest-id tie-break), and aggregation rebuilds the coarse slot list with
``np.add.at``.

Slot conventions match the repo's CSR (see the ``repro.core.graph`` module
docstring): an undirected edge
{i, j}, i != j, appears as two directed slots; a self loop as one.  So
``modularity_np`` on the same slot list is directly comparable with
``repro.core.modularity.modularity``.
"""

from __future__ import annotations

import numpy as np


def modularity_np(src, dst, w, membership) -> float:
    """Q over directed slot lists (undirected edges as two slots)."""
    src = np.asarray(src)
    dst = np.asarray(dst)
    w = np.asarray(w, dtype=np.float64)
    membership = np.asarray(membership)
    m = w.sum() / 2.0
    if m <= 0:
        return 0.0
    internal = w[membership[src] == membership[dst]].sum()
    k = np.zeros(len(membership), np.float64)
    np.add.at(k, src, w)
    sigma = np.zeros(int(membership.max()) + 1, np.float64)
    np.add.at(sigma, membership, k)
    return float(internal / (2 * m) - np.sum((sigma / (2 * m)) ** 2))


def _move_phase(adj, n, m, max_sweeps=100):
    """Sequential local-moving: sweep vertices in id order until no vertex
    moves.  ``adj`` is {u: {v: w}}; returns the membership array."""
    comm = np.arange(n)
    k = np.zeros(n, np.float64)
    for u, nbrs in adj.items():
        k[u] = sum(nbrs.values())
    sigma = k.copy()

    for _ in range(max_sweeps):
        moved = False
        for u in range(n):
            nbrs = adj.get(u, {})
            # K_{u -> c} over neighbor communities (self loops excluded).
            k_to = {}
            for v, wv in nbrs.items():
                if v == u:
                    continue
                c = int(comm[v])
                k_to[c] = k_to.get(c, 0.0) + wv
            d = int(comm[u])
            sigma[d] -= k[u]  # remove u from its community
            # Best community by gain: k_uc - k_u * sigma_c / (2m); staying
            # in d scores its own gain too.  Lowest id breaks ties.
            best_c, best_gain = d, k_to.get(d, 0.0) - k[u] * sigma[d] / (2 * m)
            for c in sorted(k_to):
                gain = k_to[c] - k[u] * sigma[c] / (2 * m)
                if gain > best_gain + 1e-12:
                    best_c, best_gain = c, gain
            sigma[best_c] += k[u]
            if best_c != d:
                comm[u] = best_c
                moved = True
        if not moved:
            break
    return comm


def _aggregate(src, dst, w, comm_dense, n_comms):
    """Coarse directed slot list: communities become vertices, parallel
    slots merge by weight sum (self loops collapse community-internal
    weight, appearing once per (c, c) key as in the repo's aggregation)."""
    cs, cd = comm_dense[src], comm_dense[dst]
    key = cs.astype(np.int64) * n_comms + cd
    order = np.argsort(key, kind="stable")
    key, cs, cd, w = key[order], cs[order], cd[order], np.asarray(w)[order]
    first = np.ones(len(key), bool)
    first[1:] = key[1:] != key[:-1]
    gid = np.cumsum(first) - 1
    wsum = np.zeros(int(gid[-1]) + 1, np.float64)
    np.add.at(wsum, gid, w)
    return cs[first], cd[first], wsum


# Public alias: the coarsening oracle is also pinned directly against
# ``repro.core.aggregate.aggregate_graph`` (tests/test_aggregate.py), not
# just through the end-to-end Louvain goldens.
aggregate_oracle = _aggregate


def louvain_oracle(src, dst, w, n, *, max_passes=10):
    """Full sequential Louvain; returns the flat (n,) membership.

    ``src``/``dst``/``w`` are directed slot lists in the repo convention.
    Deterministic: in-order sweeps, lowest-id tie-break, aggregation keyed
    by dense community ids.
    """
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    w = np.asarray(w, np.float64)
    m = w.sum() / 2.0
    flat = np.arange(n)
    cur_src, cur_dst, cur_w, cur_n = src, dst, w, n
    for _ in range(max_passes):
        adj = {}
        for s, d, x in zip(cur_src, cur_dst, cur_w):
            adj.setdefault(int(s), {})
            adj[int(s)][int(d)] = adj[int(s)].get(int(d), 0.0) + x
        comm = _move_phase(adj, cur_n, m)
        uniq, comm_dense = np.unique(comm, return_inverse=True)
        flat = comm_dense[flat]
        if len(uniq) == cur_n:  # no compression -> converged
            break
        cur_src, cur_dst, cur_w = _aggregate(
            cur_src, cur_dst, cur_w, comm_dense, len(uniq))
        cur_n = len(uniq)
    return flat


def oracle_graph_slots(graph):
    """Live directed slot lists (np arrays) of a repro ``CSRGraph``."""
    e = int(graph.e_valid)
    return (np.asarray(graph.src)[:e], np.asarray(graph.indices)[:e],
            np.asarray(graph.weights)[:e], int(graph.n_valid))


def refine_oracle(src, dst, w, n, outer, *, max_sweeps=100):
    """Sequential Leiden-style refinement: the NumPy reference of the
    constrained sweep (``repro.core.louvain._refine_phase``).

    Every vertex re-seeds as its own singleton community; a sweep in id
    order may merge a STILL-SINGLETON vertex into a neighboring refined
    community, but only one inside its ``outer`` community and only for a
    strictly positive modularity gain.  Because a singleton's gain against
    a community it has no edge to is never positive (the degree term of the
    gain is non-positive when sigma_d == k_u), every refined community is
    connected by construction — the property the auditor below checks.

    Returns the (n,) refined membership (a refinement of ``outer``: each
    refined community lies inside one outer community).
    """
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    w = np.asarray(w, np.float64)
    outer = np.asarray(outer)
    m = w.sum() / 2.0
    adj = {}
    for s, d, x in zip(src, dst, w):
        adj.setdefault(int(s), {})
        adj[int(s)][int(d)] = adj[int(s)].get(int(d), 0.0) + x

    comm = np.arange(n)
    k = np.zeros(n, np.float64)
    for u, nbrs in adj.items():
        k[u] = sum(nbrs.values())
    sigma = k.copy()
    size = np.ones(n, np.int64)
    if m <= 0:
        return comm

    for _ in range(max_sweeps):
        moved = False
        for u in range(n):
            if size[int(comm[u])] != 1:    # only still-singleton movers
                continue
            k_to = {}
            for v, wv in adj.get(u, {}).items():
                if v == u or outer[v] != outer[u]:
                    continue               # constrained: intra-outer only
                c = int(comm[v])
                k_to[c] = k_to.get(c, 0.0) + wv
            d = int(comm[u])
            sigma[d] -= k[u]
            best_c = d
            best_gain = k_to.get(d, 0.0) - k[u] * sigma[d] / (2 * m)
            for c in sorted(k_to):
                gain = k_to[c] - k[u] * sigma[c] / (2 * m)
                if gain > best_gain + 1e-12:
                    best_c, best_gain = c, gain
            sigma[best_c] += k[u]
            if best_c != d:
                size[d] -= 1
                size[best_c] += 1
                comm[u] = best_c
                moved = True
        if not moved:
            break
    return comm


def disconnected_communities(src, dst, membership):
    """Community ids whose induced subgraph is NOT connected (BFS audit).

    ``src``/``dst`` are directed slot lists; ``membership`` a flat (n,)
    labeling.  A community is connected when a BFS over its intra-community
    edges from any member reaches every member; singletons are trivially
    connected.  Returns the sorted list of offending community ids.
    """
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    membership = np.asarray(membership)
    members = {}
    for v, c in enumerate(membership):
        members.setdefault(int(c), []).append(v)
    intra = membership[src] == membership[dst]
    adj = {}
    for s, d in zip(src[intra], dst[intra]):
        if s != d:
            adj.setdefault(int(s), []).append(int(d))
    bad = []
    for c, vs in members.items():
        if len(vs) <= 1:
            continue
        seen = {vs[0]}
        queue = [vs[0]]
        while queue:
            u = queue.pop()
            for v in adj.get(u, ()):
                if v not in seen:
                    seen.add(v)
                    queue.append(v)
        if len(seen) != len(vs):
            bad.append(c)
    return sorted(bad)
