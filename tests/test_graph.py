"""CSR container invariants (paper opts 7/8 rely on exact conservation)."""

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:         # optional dev dep — see tests/_hypothesis_fallback
    from _hypothesis_fallback import given, settings, st

from repro.core.graph import CSRGraph, build_csr, from_networkx, to_ell_blocks

import networkx as nx


def test_build_csr_basic():
    src = np.array([0, 1, 1, 2])
    dst = np.array([1, 0, 2, 1])
    w = np.ones(4, np.float32)
    g = build_csr(src, dst, w, 3)
    assert int(g.n_valid) == 3 and int(g.e_valid) == 4
    assert float(g.total_weight()) == 2.0          # m = sum(w)/2
    np.testing.assert_array_equal(np.asarray(g.degrees())[:3], [1, 2, 1])


def test_symmetrize_adds_reverse_slots():
    src = np.array([0, 1])
    dst = np.array([1, 2])
    g = build_csr(src, dst, np.ones(2, np.float32), 3, symmetrize=True)
    assert int(g.e_valid) == 4
    k = np.asarray(g.vertex_weights())
    np.testing.assert_allclose(k[:3], [1.0, 2.0, 1.0])


def test_dedup_sums_parallel_edges():
    src = np.array([0, 0, 1, 1])
    dst = np.array([1, 1, 0, 0])
    g = build_csr(src, dst, np.full(4, 2.0, np.float32), 2)
    assert int(g.e_valid) == 2
    assert float(g.total_weight()) == 4.0


def test_self_loop_single_slot():
    g = build_csr(np.array([0]), np.array([0]), np.array([3.0]), 2,
                  symmetrize=True)
    assert int(g.e_valid) == 1
    assert float(g.total_weight()) == 1.5


@settings(max_examples=25, deadline=None)
@given(st.integers(5, 40), st.integers(0, 10_000))
def test_weight_conservation_random(n, seed):
    """sum(K_i) == 2m on arbitrary random graphs (property)."""
    rng = np.random.default_rng(seed)
    e = rng.integers(1, 4 * n)
    src = rng.integers(0, n, e)
    dst = rng.integers(0, n, e)
    w = rng.random(e).astype(np.float32) + 0.1
    # fixed capacities: every example reuses one compiled vertex_weights()
    g = build_csr(src, dst, w, n, symmetrize=True, n_cap=40, e_cap=2 * 160)
    k = np.asarray(g.vertex_weights())
    assert np.isclose(k.sum(), 2 * float(g.total_weight()), rtol=1e-5)
    # padding slots carry zero weight and sentinel indices
    e_valid = int(g.e_valid)
    assert np.all(np.asarray(g.weights)[e_valid:] == 0)
    assert np.all(np.asarray(g.indices)[e_valid:] == g.n_cap)


def test_from_networkx_karate():
    g = from_networkx(nx.karate_club_graph())
    assert int(g.n_valid) == 34
    assert int(g.e_valid) == 2 * 78
    # karate_club_graph is weighted (interaction counts, sum = 231)
    assert float(g.total_weight()) == 231.0


def test_ell_blocks_cover_all_vertices():
    g = from_networkx(nx.les_miserables_graph())
    blocks, leftover = to_ell_blocks(g, widths=(4, 16, 64))
    seen = set(leftover.tolist())
    n_cap = g.n_cap
    for b in blocks:
        rows = np.asarray(b.rows)
        seen.update(rows[rows < n_cap].tolist())
        # every row's neighbor slots either live or sentinel-padded
        cols = np.asarray(b.cols)
        w = np.asarray(b.w)
        assert np.all(w[cols == n_cap] == 0)
    assert seen == set(range(int(g.n_valid)))


def test_ell_blocks_degree_bounds():
    g = from_networkx(nx.les_miserables_graph())
    widths = (4, 16, 64)
    blocks, leftover = to_ell_blocks(g, widths=widths)
    deg = np.asarray(g.degrees())
    for width, b in zip(widths, blocks):
        rows = np.asarray(b.rows)
        live = rows[rows < g.n_cap]
        assert np.all(deg[live] <= width)
    assert all(deg[v] > widths[-1] for v in leftover)
