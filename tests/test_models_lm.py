"""LM architecture smoke tests: reduced configs, forward/train/decode on CPU,
shape + finiteness assertions, and prefill/decode consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (deepseek_v2_236b, gemma3_12b, internlm2_20b,
                           mixtral_8x22b, qwen2_1p5b)
from repro.models import transformer as tf

LM_MODS = [gemma3_12b, qwen2_1p5b, internlm2_20b, mixtral_8x22b,
           deepseek_v2_236b]


@pytest.fixture(scope="module")
def lm_setups():
    out = {}
    for mod in LM_MODS:
        cfg = mod.ARCH.smoke_config()
        params = tf.init_params(cfg, jax.random.PRNGKey(0))
        out[mod.ARCH.arch_id] = (cfg, params)
    return out


@pytest.mark.parametrize("mod", LM_MODS, ids=lambda m: m.ARCH.arch_id)
def test_forward_shapes_finite(mod, lm_setups):
    cfg, params = lm_setups[mod.ARCH.arch_id]
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0, cfg.vocab)
    logits = tf.forward(cfg, params, toks)
    assert logits.shape == (2, 24, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("mod", LM_MODS, ids=lambda m: m.ARCH.arch_id)
def test_train_step_decreases_loss(mod, lm_setups):
    from repro.optim import AdamWConfig, adamw_init, adamw_update
    cfg, params = lm_setups[mod.ARCH.arch_id]
    toks = jax.random.randint(jax.random.PRNGKey(2), (4, 32), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    opt = adamw_init(params)
    ocfg = AdamWConfig(lr=1e-3)

    @jax.jit
    def step(p, o):
        loss, g = jax.value_and_grad(lambda q: tf.loss_fn(cfg, q, batch))(p)
        p, o, _ = adamw_update(ocfg, p, g, o)
        return p, o, loss

    losses = []
    for _ in range(8):
        params, opt, loss = step(params, opt)
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses


@pytest.mark.parametrize("mod", LM_MODS, ids=lambda m: m.ARCH.arch_id)
def test_decode_matches_forward(mod, lm_setups):
    """Token-by-token decode must reproduce the teacher-forced logits."""
    cfg, params = lm_setups[mod.ARCH.arch_id]
    if cfg.moe is not None:
        pytest.skip("MoE capacity-dropping differs between the (B*S)-token "
                    "prefill router and the B-token decode router")
    b, s = 2, 8
    toks = jax.random.randint(jax.random.PRNGKey(3), (b, s), 0, cfg.vocab)
    full = tf.forward(cfg, params, toks)              # (b, s, v)
    cache = tf.init_cache(cfg, b, s)
    got = []
    for t in range(s):
        logits, cache = tf.decode_step(cfg, params, cache, toks[:, t:t + 1],
                                       jnp.int32(t))
        got.append(logits[:, 0])
    got = jnp.stack(got, axis=1)                       # (b, s, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                               rtol=2e-2, atol=2e-2)


# One representative arch in tier-1 (the variant equivalence is per-layer
# machinery shared by all five archs); the full sweep runs under --runslow.
@pytest.mark.parametrize(
    "mod",
    [m if m is qwen2_1p5b else pytest.param(m, marks=pytest.mark.slow)
     for m in LM_MODS], ids=lambda m: m.ARCH.arch_id)
def test_scan_vs_unrolled_forward(mod, lm_setups):
    """The dry-run's unrolled variant computes the same function as scan."""
    import dataclasses
    cfg, params = lm_setups[mod.ARCH.arch_id]
    cfg_u = dataclasses.replace(cfg, scan_layers=False)
    toks = jax.random.randint(jax.random.PRNGKey(4), (2, 16), 0, cfg.vocab)
    a = tf.forward(cfg, params, toks)
    b = tf.forward(cfg_u, params, toks)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-4, atol=1e-4)


def test_int8_kv_cache_decode_close_to_bf16(lm_setups):
    """int8-quantized KV cache decode tracks the full-precision decode
    (absmax per-(pos, head) quantization: ~1% logit error budget)."""
    import dataclasses
    cfg, params = lm_setups["qwen2-1.5b"]
    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
    b, s = 2, 8
    toks = jax.random.randint(jax.random.PRNGKey(5), (b, s), 0, cfg.vocab)
    cache = tf.init_cache(cfg, b, s)
    cache8 = tf.init_cache(cfg8, b, s)
    assert cache8["slots"][0]["k_q"].dtype == jnp.int8
    outs, outs8 = [], []
    for t in range(s):
        lg, cache = tf.decode_step(cfg, params, cache, toks[:, t:t + 1],
                                   jnp.int32(t))
        lg8, cache8 = tf.decode_step(cfg8, params, cache8, toks[:, t:t + 1],
                                     jnp.int32(t))
        outs.append(lg)
        outs8.append(lg8)
    full = jnp.stack(outs, 1)[:, :, 0]
    quant = jnp.stack(outs8, 1)[:, :, 0]
    # same argmax token nearly everywhere + bounded logit drift
    agree = jnp.mean((jnp.argmax(full, -1) == jnp.argmax(quant, -1))
                     .astype(jnp.float32))
    assert float(agree) >= 0.9, float(agree)
    denom = jnp.maximum(jnp.max(jnp.abs(full)), 1.0)
    assert float(jnp.max(jnp.abs(full - quant)) / denom) < 0.08


def test_gemma3_sliding_window_masks_long_range():
    """A local-attention layer must not see past its window."""
    from repro.models.layers import blockwise_attention
    b, s, h, dh = 1, 32, 2, 8
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (b, s, h, dh))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, h, dh))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, h, dh))
    out_w = blockwise_attention(q, k, v, causal=True, window=4)
    # Perturb k/v at position 0; outputs at position >= 5 must not change.
    k2 = k.at[:, 0].set(100.0)
    v2 = v.at[:, 0].set(-100.0)
    out_w2 = blockwise_attention(q, k2, v2, causal=True, window=4)
    np.testing.assert_allclose(np.asarray(out_w[:, 6:]),
                               np.asarray(out_w2[:, 6:]), atol=1e-5)
    # ...but full attention does change.
    out_f = blockwise_attention(q, k, v, causal=True, window=None)
    out_f2 = blockwise_attention(q, k2, v2, causal=True, window=None)
    assert not np.allclose(np.asarray(out_f[:, 6:]), np.asarray(out_f2[:, 6:]))


def test_param_counts_match_assigned_sizes():
    """Full configs land near their nameplate parameter counts."""
    expect = {"gemma3-12b": (10e9, 14e9),
              "qwen2-1.5b": (1.2e9, 2.0e9),
              "internlm2-20b": (17e9, 23e9),
              "mixtral-8x22b": (120e9, 150e9),
              "deepseek-v2-236b": (200e9, 260e9)}
    for mod in LM_MODS:
        cfg = mod.ARCH.full_config()
        lo, hi = expect[mod.ARCH.arch_id]
        n = cfg.param_count()
        assert lo <= n <= hi, (mod.ARCH.arch_id, n)


def test_moe_identical_experts_equals_dense():
    """With identical expert weights and no capacity drops, the routed MoE
    must equal the dense SwiGLU FFN (router weights sum to 1)."""
    from repro.models.layers import swiglu_ffn
    from repro.models.moe import MoEParams, moe_ffn
    d, e, f, t = 16, 4, 32, 12
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (t, d))
    wg = jax.random.normal(jax.random.PRNGKey(1), (d, f)) / 4
    wu = jax.random.normal(jax.random.PRNGKey(2), (d, f)) / 4
    wd = jax.random.normal(jax.random.PRNGKey(3), (f, d)) / 6
    p = MoEParams(
        router=jax.random.normal(jax.random.PRNGKey(4), (d, e)),
        w_gate=jnp.broadcast_to(wg, (e, d, f)),
        w_up=jnp.broadcast_to(wu, (e, d, f)),
        w_down=jnp.broadcast_to(wd, (e, f, d)))
    out = moe_ffn(x, p, top_k=2, capacity_factor=float(e))  # no drops
    dense = swiglu_ffn(x, wg, wu, wd)
    np.testing.assert_allclose(np.asarray(out), np.asarray(dense),
                               rtol=1e-4, atol=1e-4)
