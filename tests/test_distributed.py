"""Distributed (shard_map) Louvain on forced host devices.

Runs in a subprocess so the 8-device XLA_FLAGS does not leak into the other
tests (jax locks device count at first init)."""

import json
import os
import subprocess
import sys

import pytest

from conftest import multi_device as _multi_device

pytestmark = [pytest.mark.slow, _multi_device]

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
import networkx as nx
import numpy as np

from repro.core.distributed import (distributed_louvain, partition_graph_host,
                                    replicated_renumber)
from repro.core.graph import from_networkx
from repro.core.louvain import louvain, louvain_modularity
from repro.core.modularity import modularity
from repro.data import sbm_graph

out = {}

from repro.compat import make_mesh
mesh = make_mesh((2, 4), ("data", "model"))

# --- quality matches single-device on les miserables -----------------------
nxg = nx.les_miserables_graph()
g = from_networkx(nxg)
mem, ncomm, stats = distributed_louvain(g, mesh, ("data", "model"))
comm = jnp.concatenate([jnp.asarray(mem, jnp.int32),
                        jnp.full((g.n_cap + 1 - len(mem),), g.n_cap, jnp.int32)])
q_dist = float(modularity(g, comm))
q_single = louvain_modularity(g, louvain(g))
out["lesmis"] = {"q_dist": q_dist, "q_single": q_single, "ncomm": ncomm}

# --- SBM recovery ------------------------------------------------------------
g2, truth = sbm_graph(n_communities=6, size=24, p_in=0.35, p_out=0.01, seed=3)
mem2, ncomm2, _ = distributed_louvain(g2, mesh, ("data", "model"))
agree = 0
for b in range(6):
    ids, counts = np.unique(mem2[truth == b], return_counts=True)
    agree += counts.max()
out["sbm"] = {"recovery": float(agree / len(mem2)), "ncomm": ncomm2}

# --- Leiden refinement on 8 shards: bit-for-bit vs the committed golden ------
g3 = from_networkx(nx.gnp_random_graph(120, 0.05, seed=21))
mem3, ncomm3, _ = distributed_louvain(g3, mesh, ("data", "model"),
                                      refine="leiden")
out["leiden_gnp"] = {"membership": np.asarray(mem3).tolist(),
                     "ncomm": int(ncomm3)}

# --- partition layout invariants ---------------------------------------------
src_g, dst_g, w_g, spec = partition_graph_host(g, 8)
out["partition"] = {
    "w_sum_ok": bool(np.isclose(float(jnp.sum(w_g)),
                                float(jnp.sum(g.weights)), rtol=1e-6)),
    "shards": spec.n_shards,
}
print(json.dumps(out))
"""


@pytest.fixture(scope="module")
def dist_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900,
                          cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__))))
    assert proc.returncode == 0, proc.stderr[-3000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


def test_distributed_quality_close_to_single(dist_results):
    r = dist_results["lesmis"]
    assert r["q_dist"] >= 0.95 * r["q_single"], r


def test_distributed_sbm_recovery(dist_results):
    assert dist_results["sbm"]["recovery"] > 0.9


def test_partition_conserves_weight(dist_results):
    assert dist_results["partition"]["w_sum_ok"]
    assert dist_results["partition"]["shards"] == 8


def test_distributed_leiden_8dev_matches_golden_and_connected(dist_results):
    """refine="leiden" on 8 forced shards reproduces the committed golden
    bit-for-bit (captured single-shard — sharding must not change a single
    label) and the audit finds zero disconnected communities."""
    import networkx as nx
    import numpy as np

    from _oracle import disconnected_communities, oracle_graph_slots
    from repro.core.graph import from_networkx

    got = np.asarray(dist_results["leiden_gnp"]["membership"], np.int32)
    here = os.path.dirname(os.path.abspath(__file__))
    golden = np.load(os.path.join(here, "golden", "engine_memberships.npz"))
    np.testing.assert_array_equal(got, golden["sharded_leiden__gnp"])
    g = from_networkx(nx.gnp_random_graph(120, 0.05, seed=21))
    src, dst, _w, _n = oracle_graph_slots(g)
    assert disconnected_communities(src, dst, got) == []
