"""Halo-exchange distribution (core/gnn_halo) must compute EXACTLY the same
loss as the single-device model on a real Louvain-partitioned graph — for
both GIN and Equiformer (the latter also validates the m-truncated rotation
is exact).  Runs on 8 forced host devices in a subprocess."""

import json
import os
import subprocess
import sys

import pytest

from conftest import multi_device as _multi_device

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
import networkx as nx
import numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.gnn_halo import (HaloSpec, build_halo_inputs,
                                 equiformer_halo_loss_shard,
                                 gin_halo_loss_shard)
from repro.core.graph import from_networkx
from repro.core.partition import louvain_partition
from repro.models.gnn import equiformer, gin
from repro.models.gnn.common import GraphBatch, node_ce_loss

N_SHARDS = 8
out = {}

# --- a modular graph + its Louvain order ------------------------------------
nxg = nx.connected_caveman_graph(8, 8)          # 64 nodes
g = from_networkx(nxg)
n = int(g.n_valid)
lp = louvain_partition(g, N_SHARDS)
order = lp.order                                # community-contiguous perm

src = np.asarray(g.src)[: int(g.e_valid)]
dst = np.asarray(g.indices)[: int(g.e_valid)]

v_l = n // N_SHARDS
spec = HaloSpec(N_SHARDS, v_l, e_per_shard=len(src), send_cap=v_l)
halo = build_halo_inputs(src, dst, order, N_SHARDS, n, len(src) * N_SHARDS,
                         spec)

rng = np.random.default_rng(0)
feat = rng.standard_normal((n, 8)).astype(np.float32)
pos = rng.standard_normal((n, 3)).astype(np.float32)
labels = rng.integers(0, 4, n).astype(np.int32)

# permuted (Louvain-order) arrays — the layout the halo step consumes
perm = halo["perm"]
feat_p, pos_p, labels_p = feat[perm], pos[perm], labels[perm]
inv = np.argsort(perm)
src_p, dst_p = inv[src], inv[dst]

from repro.compat import make_mesh
mesh = make_mesh((N_SHARDS,), ("i",))
axes = ("i",)
shard1, rep = P("i"), P()

def run_halo(loss_shard, params, arrays, in_specs):
    fn = shard_map(loss_shard, mesh=mesh, in_specs=in_specs, out_specs=rep,
                   check_rep=False)
    with mesh:
        return float(jax.jit(fn)(params, *arrays))

# --- GIN ---------------------------------------------------------------------
cfg = gin.GINConfig(n_layers=2, d_hidden=16, d_feat=8, n_classes=4)
params = gin.init_params(cfg, jax.random.PRNGKey(0))

loss_halo = run_halo(
    lambda p, nf, es, ed, lab, sidx: gin_halo_loss_shard(
        cfg, p, nf, es, ed, lab, sidx, n, spec, axes),
    params,
    (jnp.asarray(feat_p), jnp.asarray(halo["edge_src"]),
     jnp.asarray(halo["edge_dst"]), jnp.asarray(labels_p),
     jnp.asarray(halo["send_idx"])),
    (jax.tree.map(lambda _: rep, params), P("i", None), shard1, shard1,
     shard1, P("i", None)))

gref = GraphBatch(node_feat=jnp.asarray(feat_p),
                  edge_src=jnp.asarray(src_p, jnp.int32),
                  edge_dst=jnp.asarray(dst_p, jnp.int32),
                  n_nodes=jnp.int32(n), labels=jnp.asarray(labels_p),
                  graph_id=jnp.zeros((n,), jnp.int32),
                  n_graphs=jnp.int32(1))
logits = gin.forward(cfg, params, gref)
loss_ref = float(node_ce_loss(logits, jnp.asarray(labels_p),
                              jnp.ones((n,), jnp.float32)))
out["gin"] = {"halo": loss_halo, "ref": loss_ref}

# --- Equiformer (validates m-truncation exactness too) -----------------------
ecfg = equiformer.EquiformerConfig(n_layers=2, d_hidden=8, l_max=3, m_max=1,
                                   n_heads=2, d_feat=8, out_dim=4,
                                   node_level=True)
eparams = equiformer.init_params(ecfg, jax.random.PRNGKey(1))

for trunc in (True, False):
    out[f"equi_trunc_{trunc}"] = run_halo(
        lambda p, nf, po, es, ed, lab, sidx: equiformer_halo_loss_shard(
            ecfg, p, nf, po, es, ed, lab, sidx, n, spec, axes,
            m_truncate=trunc),
        eparams,
        (jnp.asarray(feat_p), jnp.asarray(pos_p),
         jnp.asarray(halo["edge_src"]), jnp.asarray(halo["edge_dst"]),
         jnp.asarray(labels_p), jnp.asarray(halo["send_idx"])),
        (jax.tree.map(lambda _: rep, eparams), P("i", None), P("i", None),
         shard1, shard1, shard1, P("i", None)))

egref = GraphBatch(node_feat=jnp.asarray(feat_p),
                   edge_src=jnp.asarray(src_p, jnp.int32),
                   edge_dst=jnp.asarray(dst_p, jnp.int32),
                   n_nodes=jnp.int32(n), labels=jnp.asarray(labels_p),
                   graph_id=jnp.zeros((n,), jnp.int32),
                   n_graphs=jnp.int32(1),
                   positions=jnp.asarray(pos_p))
elogits = equiformer.forward(ecfg, eparams, egref)
out["equi_ref"] = float(node_ce_loss(elogits, jnp.asarray(labels_p),
                                     jnp.ones((n,), jnp.float32)))
print(json.dumps(out))
"""


@pytest.fixture(scope="module")
def halo_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=1200,
                          cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__))))
    assert proc.returncode == 0, proc.stderr[-3000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


@pytest.mark.slow
@_multi_device
def test_gin_halo_matches_reference(halo_results):
    r = halo_results["gin"]
    assert abs(r["halo"] - r["ref"]) < 1e-4 * max(abs(r["ref"]), 1), r


@pytest.mark.slow
@_multi_device
def test_equiformer_halo_matches_reference(halo_results):
    ref = halo_results["equi_ref"]
    got = halo_results["equi_trunc_False"]
    assert abs(got - ref) < 1e-3 * max(abs(ref), 1), (got, ref)


@pytest.mark.slow
@_multi_device
def test_equiformer_m_truncation_exact(halo_results):
    """Truncated-rotation path == full-rotation path (the |m|>m_max
    coefficients it skips are provably unused)."""
    a = halo_results["equi_trunc_True"]
    b = halo_results["equi_trunc_False"]
    assert abs(a - b) < 1e-4 * max(abs(b), 1), (a, b)


def test_halo_step_lowers_locally():
    """build_halo_step (the --variant halo dry-run path) lowers + compiles
    on a local mesh for the small full-graph shape."""
    import jax
    from repro.configs.registry import get_arch

    from repro.compat import make_mesh

    mesh = make_mesh((1, 1), ("data", "model"))
    arch = get_arch("gin-tu")
    fn, args, shardings = arch.build_step("full_graph_sm", mesh,
                                          variant=("halo",))
    donate = getattr(fn, "donate_argnums", ())
    with mesh:
        jax.jit(fn, in_shardings=shardings,
                donate_argnums=donate).lower(*args).compile()
