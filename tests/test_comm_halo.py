"""Property tests for the hybrid state-layout halo primitives.

The hybrid layout (state_layout="hybrid") keeps per-vertex working state
owner-partitioned and exchanges only boundary-mover labels plus aggregated
touched-community deltas per round.  Everything it stands on is pure jnp /
numpy on one shard's arrays, so — like tests/test_comm_delta.py — the whole
layer is testable without a mesh: the boundary (halo) mask over empty, full
and padded layouts; the symmetric-placement freshness invariant the
exchange's soundness rests on; invariance of the boundary structure under
the monotone re-shard relabel; and exact byte accounting of the hybrid
CommPlan against phase_bytes.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.comm import (boundary_mask, comm_plan, label_bits,
                             packed_lanes, phase_bytes, size_delta_width)
from repro.core.distributed import (ShardedGraphSpec, _reshard_relabel,
                                    measure_boundary_frac,
                                    sharded_comm_plan)


def _shard_slots(src, dst, s, v_per, sent, e_per):
    """One shard's directed slot arrays under symmetric placement: every
    edge (u, v) yields slot (u, v) on owner(u) AND (v, u) on owner(v)."""
    su = np.concatenate([src, dst])
    sv = np.concatenate([dst, src])
    own = su // v_per == s
    sl_s = np.full(e_per, sent, np.int32)
    sl_d = np.full(e_per, sent, np.int32)
    k = int(own.sum())
    sl_s[:k], sl_d[:k] = su[own], sv[own]
    return jnp.asarray(sl_s), jnp.asarray(sl_d)


# -- boundary mask: empty / full / padded ------------------------------------


def test_boundary_mask_empty_all_local():
    """A shard whose every live slot stays inside its owner range has an
    empty halo — nothing to publish, zero per-round label bytes."""
    v_per, sent = 8, 32
    src = jnp.asarray([0, 1, 2, 5], jnp.int32)
    dst = jnp.asarray([1, 0, 5, 2], jnp.int32)
    m = boundary_mask(src, dst, 0, v_per, sent)
    assert m.shape == (v_per,)
    assert not bool(m.any())


def test_boundary_mask_empty_all_dead():
    """All-sentinel slots (a fully padded shard) publish nothing."""
    v_per, sent = 8, 32
    s = jnp.full((6,), sent, jnp.int32)
    assert not bool(boundary_mask(s, s, 8, v_per, sent).any())


def test_boundary_mask_full():
    """Every owned vertex with a live remote slot is boundary — a shard
    whose every vertex talks across the cut replicates its whole slice."""
    v_per, sent = 4, 16
    v0 = 4
    src = jnp.asarray([4, 5, 6, 7], jnp.int32)
    dst = jnp.asarray([0, 6, 12, 1], jnp.int32)   # 6 is local; rest remote
    m = np.asarray(boundary_mask(src, dst, v0, v_per, sent))
    assert np.array_equal(m, [True, False, True, True])


def test_boundary_mask_excludes_padding_and_dead_slots():
    """Vertices at or beyond the sentinel never enter the halo, and a dead
    slot (src or dst == sent) never flags its vertex."""
    v_per, sent = 4, 6                      # owned range [4, 8) but sent=6
    src = jnp.asarray([4, 5, 5, sent], jnp.int32)
    dst = jnp.asarray([0, sent, 1, 0], jnp.int32)
    m = np.asarray(boundary_mask(src, dst, 4, v_per, sent))
    # 4 remote-live -> True; 5's only live slot is remote -> True; 6, 7 are
    # padding (>= sent) -> False regardless.
    assert np.array_equal(m, [True, True, False, False])


def test_boundary_mask_matches_measured_fraction():
    """boundary_mask (device, per shard) and measure_boundary_frac (host,
    global) count the same vertices on a random symmetric layout."""
    rng = np.random.default_rng(5)
    S, v_per = 4, 16
    n = S * v_per
    spec = ShardedGraphSpec(S, v_per, 256, n)
    src = rng.integers(0, n, 80).astype(np.int32)
    dst = ((src + 1 + rng.integers(0, n - 1, 80)) % n).astype(np.int32)
    n_bnd = 0
    for s in range(S):
        sl_s, sl_d = _shard_slots(src, dst, s, v_per, spec.sentinel, 256)
        n_bnd += int(np.asarray(
            boundary_mask(sl_s, sl_d, s * v_per, v_per,
                          spec.sentinel)).sum())
    su = np.concatenate([src, dst])
    n_live = int(np.unique(su).size)
    got = measure_boundary_frac(
        jnp.concatenate([jnp.asarray(src), jnp.asarray(dst)]),
        jnp.concatenate([jnp.asarray(dst), jnp.asarray(src)]), spec)
    assert got == pytest.approx(n_bnd / n_live)


def test_symmetric_placement_freshness_invariant():
    """The soundness keystone of the hybrid exchange: any remote dst some
    shard reads is flagged boundary by its OWNER's mask — so publishing
    only boundary movers keeps every cross-shard read fresh."""
    rng = np.random.default_rng(11)
    S, v_per = 4, 16
    n = S * v_per
    sent = n
    src = rng.integers(0, n, 120).astype(np.int32)
    dst = ((src + 1 + rng.integers(0, n - 1, 120)) % n).astype(np.int32)
    masks = [np.asarray(boundary_mask(
        *_shard_slots(src, dst, s, v_per, sent, 300), s * v_per, v_per,
        sent)) for s in range(S)]
    for s in range(S):
        sl_s, sl_d = (np.asarray(a) for a in
                      _shard_slots(src, dst, s, v_per, sent, 300))
        live = (sl_s < sent) & (sl_d < sent)
        for d in np.unique(sl_d[live & (sl_d // v_per != s)]):
            o = d // v_per
            assert masks[o][d - o * v_per], (s, int(d))


# -- invariance under the monotone re-shard relabel --------------------------


def test_reshard_relabel_identity_bounds_preserve_boundary():
    """Uniform bounds (the layout the pass already has) produce the
    identity LUT on live ids — the halo mask is bit-identical through it."""
    rng = np.random.default_rng(3)
    S, v_per = 4, 8
    n = S * v_per
    bounds = np.arange(S + 1) * v_per
    lut = _reshard_relabel(bounds, v_per, n, n)
    assert np.array_equal(lut[:n], np.arange(n))
    src = rng.integers(0, n, 40).astype(np.int32)
    dst = ((src + 1 + rng.integers(0, n - 1, 40)) % n).astype(np.int32)
    for s in range(S):
        sl_s, sl_d = _shard_slots(src, dst, s, v_per, n, 100)
        a = boundary_mask(sl_s, sl_d, s * v_per, v_per, n)
        b = boundary_mask(jnp.asarray(lut)[sl_s], jnp.asarray(lut)[sl_d],
                          s * v_per, v_per, n)
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_reshard_relabel_boundary_consistent_with_plan_owners():
    """A skewed split moves ids, never edges: after relabelling both
    endpoints through the monotone LUT, the per-shard halo masks flag
    EXACTLY the vertices whose plan owner differs from some neighbour's —
    the boundary structure is derivable from the bounds alone."""
    rng = np.random.default_rng(7)
    n_live, v_per = 24, 16
    bounds = np.asarray([0, 5, 14, 24])           # 3 skewed owner ranges
    S = len(bounds) - 1
    n_pad = S * v_per
    lut = _reshard_relabel(bounds, v_per, n_pad, n_live)
    assert np.all(np.diff(lut[:n_live]) > 0)      # strictly increasing
    src = rng.integers(0, n_live, 60).astype(np.int32)
    dst = ((src + 1 + rng.integers(0, n_live - 1, 60)) % n_live
           ).astype(np.int32)
    owner = np.searchsorted(bounds, np.arange(n_live), side="right") - 1
    expect = set()
    for u, v in zip(src, dst):
        if owner[u] != owner[v]:
            expect.add(int(lut[u]))
            expect.add(int(lut[v]))
    rs, rd = lut[src].astype(np.int32), lut[dst].astype(np.int32)
    got = set()
    for s in range(S):
        sl_s, sl_d = _shard_slots(rs, rd, s, v_per, n_pad, 200)
        m = np.asarray(boundary_mask(sl_s, sl_d, s * v_per, v_per, n_pad))
        got |= {s * v_per + i for i in np.flatnonzero(m)}
    assert got == expect


# -- exact byte accounting ---------------------------------------------------


def _hybrid_lanes(v_per, n_pad, move_cap, touched_cap):
    iw, lw = label_bits(v_per + 1), label_bits(n_pad + 1)
    if iw + lw <= 31:
        mover = packed_lanes(move_cap, iw + lw)
    else:
        mover = packed_lanes(move_cap, iw) + packed_lanes(move_cap, lw)
    tid = packed_lanes(touched_cap, lw)
    siz = packed_lanes(touched_cap, size_delta_width(v_per))
    return mover, tid, siz


def test_hybrid_plan_prices_exact_wire_lanes():
    """The hybrid round price is EXACTLY the wire the scanner builds:
    a 12-byte header + 4 bytes per packed mover/tid/Sigma/size lane,
    summed over shards — recomputed here lane by lane from the public
    packing primitives."""
    S, v_per, n_pad, mcap, tcap = 8, 64, 512, 16, 32
    p = comm_plan("delta", S, v_per, n_pad, mcap, state_layout="hybrid",
                  touched_cap=tcap)
    mover, tid, siz = _hybrid_lanes(v_per, n_pad, mcap, tcap)
    assert p.round_bytes == S * (12 + 4 * (mover + tid + tcap + siz))
    assert p.halo_round_bytes == S * 4 * mover
    assert p.phase_fixed_bytes == S * v_per * 4
    # delta-flavor fallback: the wire has travelled, then the dense resync
    # (owned comm slice + moved mask + two dense psums) rides on top.
    assert p.fallback_bytes == (p.round_bytes
                                + S * (v_per * 4 + v_per
                                       + 2 * (n_pad + 1) * 4))


def test_hybrid_gather_flavor_is_overflow_free():
    """Under the gather backend the caps are the worst case (v_per /
    2*v_per): no round can overflow, so fallback == round."""
    S, v_per, n_pad = 4, 32, 128
    p = comm_plan("gather", S, v_per, n_pad, 5, state_layout="hybrid",
                  touched_cap=7)                  # caps are overridden
    assert (p.move_cap, p.touched_cap) == (v_per, 2 * v_per)
    assert p.fallback_bytes == p.round_bytes
    mover, tid, siz = _hybrid_lanes(v_per, n_pad, v_per, 2 * v_per)
    assert p.round_bytes == S * (12 + 4 * (mover + tid + 2 * v_per + siz))


def test_phase_bytes_adds_hybrid_resync_once_per_phase():
    """The end-of-phase membership resync is priced ONCE per phase that
    ran at least one round — never per round, never on an empty phase."""
    p = comm_plan("delta", 2, 16, 32, 4, state_layout="hybrid",
                  touched_cap=8)
    assert p.phase_fixed_bytes > 0
    assert phase_bytes(p, 0) == 0
    assert phase_bytes(p, 1) == p.round_bytes + p.phase_fixed_bytes
    assert (phase_bytes(p, 5, 2)
            == 3 * p.round_bytes + 2 * p.fallback_bytes
            + p.phase_fixed_bytes)
    # replicated plans have no fixed term — the accounting is unchanged.
    r = comm_plan("delta", 2, 16, 32, 4)
    assert r.phase_fixed_bytes == 0
    assert phase_bytes(r, 5, 2) == 3 * r.round_bytes + 2 * r.fallback_bytes


def test_sharded_hybrid_plan_beats_replicated_gather_at_8_shards():
    """The acceptance ratio at plan level, mirroring the delta-vs-gather
    pin in test_comm_delta.py: on an 8-shard layout a hybrid-gather round
    (worst-case caps!) plus its amortised resync is still far below a
    replicated gather round's dense O(n_pad) psums."""
    spec = ShardedGraphSpec(8, 64, 256, 512)
    rep = sharded_comm_plan(spec, "gather")
    hyb = sharded_comm_plan(spec, "gather", "hybrid")
    assert hyb.state_layout == "hybrid" and rep.state_layout == "replicated"
    assert rep.round_bytes >= 2 * hyb.round_bytes
    # even a one-round phase (fixed resync fully unamortised) wins.
    assert phase_bytes(rep, 1) > phase_bytes(hyb, 1)


def test_comm_plan_rejects_unknown_layout():
    with pytest.raises(ValueError):
        comm_plan("gather", 2, 16, 32, state_layout="partitioned")
