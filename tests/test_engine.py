"""Unit tests for the unified move engine (repro.core.engine).

Covers the satellite asks of the engine refactor: the Weyl gate hash lives
in ONE place and selects ~1/gate_fraction of vertices per round, and the
engine-level delta screening (community vs DF-style per-vertex granularity)
behaves as documented.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import engine
from repro.core.engine import (affected_frontier, gate_hash,
                               normalize_screening, round_gate)


def test_gate_constants_single_home():
    """The magic constants exist only in engine.py (the dedup satellite)."""
    import pathlib
    root = pathlib.Path(engine.__file__).parents[1]   # src/repro
    offenders = []
    for py in root.rglob("*.py"):
        if py.name == "engine.py":
            continue
        text = py.read_text()
        if "-1640531535" in text or "40503" in text:
            offenders.append(py.name)
    assert not offenders, f"gate constants pasted outside engine.py: {offenders}"


@pytest.mark.parametrize("gate_fraction", [2, 3, 4])
def test_round_gate_selects_expected_fraction(gate_fraction):
    """Each round selects ~1/gate_fraction of vertices (+-25% relative)."""
    ids = jnp.arange(1 << 14)
    for r in range(6):
        frac = float(jnp.mean(round_gate(ids, jnp.int32(r), gate_fraction)))
        expect = 1.0 / gate_fraction
        assert abs(frac - expect) < 0.25 * expect, (r, frac, expect)


def test_round_gate_covers_vertices_across_rounds():
    """Over a few rounds nearly every vertex gets selected at least once."""
    ids = jnp.arange(4096)
    seen = np.zeros(4096, bool)
    for r in range(8):
        seen |= np.asarray(round_gate(ids, jnp.int32(r), 2))
    assert seen.mean() > 0.95


def test_round_gate_decorrelated_across_rounds():
    """Adjacent rounds select materially different vertex sets: the round
    increment rotates the Weyl sequence, so round r+1 mostly picks vertices
    round r skipped (low overlap, near-complete union — a sweep of
    gate_fraction rounds processes nearly everyone)."""
    ids = jnp.arange(1 << 14)
    g0 = np.asarray(round_gate(ids, jnp.int32(0), 2))
    g1 = np.asarray(round_gate(ids, jnp.int32(1), 2))
    overlap = (g0 & g1).mean() / max(g0.mean(), 1e-9)
    assert overlap < 0.5, overlap         # not the same set again
    assert (g0 | g1).mean() > 0.85        # a sweep covers nearly everyone


def test_gate_hash_matches_weyl_form():
    ids = jnp.asarray([0, 1, 17], jnp.int32)
    h = np.asarray(gate_hash(ids, jnp.int32(3)))
    expect = (np.asarray(ids, np.int32) * np.int32(-1640531535)
              + np.int32(3) * np.int32(40503))
    assert np.array_equal(h, expect)


def test_affected_frontier_vertex_subset_of_community():
    n_cap = 16
    membership = jnp.asarray(
        [0, 0, 0, 1, 1, 2, 2, 2, 3, 3, 4, 4, 4, 4, 5, 5, n_cap], jnp.int32)
    touched = jnp.zeros(n_cap + 1, bool).at[jnp.asarray([1, 8])].set(True)
    fv = affected_frontier(touched, membership, jnp.int32(16), "vertex")
    fc = affected_frontier(touched, membership, jnp.int32(16), "community")
    fv, fc = np.asarray(fv), np.asarray(fc)
    # vertex mode: exactly the touched endpoints
    assert np.array_equal(np.where(fv)[0], [1, 8])
    # community mode: all members of communities 0 and 3
    assert np.array_equal(np.where(fc)[0], [0, 1, 2, 8, 9])
    assert not fv[-1] and not fc[-1]          # sentinel never seeds
    assert np.all(fc[fv])                     # vertex ⊆ community


def test_affected_frontier_respects_n_valid():
    n_cap = 8
    membership = jnp.zeros(n_cap + 1, jnp.int32)
    touched = jnp.ones(n_cap + 1, bool)
    for mode in ("vertex", "community"):
        f = np.asarray(affected_frontier(touched, membership, jnp.int32(5),
                                         mode))
        assert np.array_equal(np.where(f)[0], np.arange(5)), mode


def test_normalize_screening():
    assert normalize_screening(True) == "community"
    assert normalize_screening(False) is None
    assert normalize_screening(None) is None
    assert normalize_screening("vertex") == "vertex"
    assert normalize_screening("community") == "community"
    assert normalize_screening("auto") == "auto"
    with pytest.raises(ValueError):
        normalize_screening("bogus")


def test_affected_frontier_auto_picks_granularity_by_touched_size():
    """screening="auto": a small touched set yields the per-vertex frontier,
    a bulky one the community-granular frontier — selected on device from
    |touched| vs n_valid / AUTO_SCREEN_TOUCHED_DENOM."""
    n_cap = 64
    n_valid = jnp.int32(64)
    membership = jnp.asarray(
        np.concatenate([np.repeat(np.arange(8) * 8, 8), [n_cap]])
        .astype(np.int32))

    # 2 touched of 64 valid: 2 * 16 <= 64 -> vertex granularity.
    touched = jnp.zeros(n_cap + 1, bool).at[jnp.asarray([3, 40])].set(True)
    fa = affected_frontier(touched, membership, n_valid, "auto")
    fv = affected_frontier(touched, membership, n_valid, "vertex")
    fc = affected_frontier(touched, membership, n_valid, "community")
    np.testing.assert_array_equal(np.asarray(fa), np.asarray(fv))
    assert np.asarray(fc).sum() > np.asarray(fa).sum()

    # 8 touched of 64 valid: 8 * 16 > 64 -> community granularity.
    touched = jnp.zeros(n_cap + 1, bool).at[jnp.arange(0, 64, 8)].set(True)
    fa = affected_frontier(touched, membership, n_valid, "auto")
    fc = affected_frontier(touched, membership, n_valid, "community")
    np.testing.assert_array_equal(np.asarray(fa), np.asarray(fc))


def test_affected_frontier_auto_threshold_boundary():
    """Exactly n_valid / DENOM touched vertices still selects vertex mode
    (the policy is <=), one more tips it to community."""
    from repro.core.engine import AUTO_SCREEN_TOUCHED_DENOM as DENOM
    n_cap = DENOM * 4
    n_valid = jnp.int32(n_cap)
    membership = jnp.zeros(n_cap + 1, jnp.int32).at[n_cap].set(n_cap)

    at_limit = jnp.zeros(n_cap + 1, bool).at[jnp.arange(4)].set(True)
    fa = affected_frontier(at_limit, membership, n_valid, "auto")
    fv = affected_frontier(at_limit, membership, n_valid, "vertex")
    np.testing.assert_array_equal(np.asarray(fa), np.asarray(fv))

    over = jnp.zeros(n_cap + 1, bool).at[jnp.arange(5)].set(True)
    fa = affected_frontier(over, membership, n_valid, "auto")
    fc = affected_frontier(over, membership, n_valid, "community")
    np.testing.assert_array_equal(np.asarray(fa), np.asarray(fc))


# -- refinement warm-start sanitation (ConstrainedScanner) --------------------


def test_sanitize_outer_maps_stale_labels_to_singletons():
    """A stale out-of-range outer label (e.g. a previous pass's coarse id
    surviving a layout change) must NOT leak into the constrained sweep:
    sanitize_outer re-seeds that slot as its own singleton and forces
    invalid slots to the sentinel."""
    from repro.core.engine import sanitize_outer

    outer = jnp.asarray([2, 2, 99, -1, 7, 0], jnp.int32)   # n_valid = 4
    out = np.asarray(sanitize_outer(outer, jnp.int32(4), 5))
    # valid+in-range keep their label; stale (99, -1) become singletons;
    # slots >= n_valid (incl. the 0 at index 5) become the sentinel.
    np.testing.assert_array_equal(out, [2, 2, 2, 3, 5, 5])


def test_assert_outer_sane_raises_eagerly_on_stale_label():
    from repro.core.engine import assert_outer_sane

    good = jnp.asarray([0, 0, 1, 5, 5, 5], jnp.int32)
    assert_outer_sane(good, jnp.int32(3), 5)     # no raise
    bad = jnp.asarray([0, 42, 1, 5, 5, 5], jnp.int32)
    with pytest.raises(ValueError, match="outer"):
        assert_outer_sane(bad, jnp.int32(3), 5)


def test_refine_phase_sanitizes_stale_outer_end_to_end():
    """_refine_phase with a stale outer id: the polluted slot refines as a
    singleton instead of constraining against a phantom community, and the
    result still refines the SANITIZED outer partition."""
    import networkx as nx
    from repro.core.graph import from_networkx
    from repro.core.louvain import _refine_phase, louvain

    g = from_networkx(nx.karate_club_graph())
    n = int(g.n_valid)
    outer = louvain(g).membership
    # Pollute a vertex whose own id is NOT in use as a community label, so
    # its sanitized singleton {v} cannot collide with a real community.
    v = next(i for i in range(n) if i not in np.unique(outer))
    stale = np.concatenate([outer, np.full(g.n_cap + 1 - n, g.n_cap)])
    stale[v] = g.n_cap + 7           # out-of-range: stale coarse id
    refined, iters, _ = _refine_phase(
        g, jnp.asarray(stale, jnp.int32), jnp.float32(0.01),
        max_iterations=20, use_pruning=True)
    refined = np.asarray(refined)[:n]
    # v's sanitized outer community is the singleton {v}: the constrained
    # sweep cannot merge it anywhere.
    assert np.sum(refined == refined[v]) == 1
    # everyone else still refines the real outer partition.
    rest = np.arange(n) != v
    for r in np.unique(refined[rest]):
        members = (refined == r) & rest
        assert len(np.unique(outer[members])) == 1


def test_mask_cross_outer_slots_masks_dst_and_weight():
    """Cross-outer slots must lose BOTH endpoints-as-candidates and weight:
    dst -> sentinel kills the candidate group in every backend's validity
    check (weight-zero alone would leave a positive degree-term dQ)."""
    from repro.core.engine import mask_cross_outer_slots

    outer = jnp.asarray([0, 0, 1, 1, 4], jnp.int32)   # sentinel slot = 4
    src = jnp.asarray([0, 1, 2], jnp.int32)
    dst = jnp.asarray([1, 2, 3], jnp.int32)
    w = jnp.asarray([1.0, 2.0, 3.0], jnp.float32)
    dst2, w2 = mask_cross_outer_slots(src, dst, w, outer, 4)
    np.testing.assert_array_equal(np.asarray(dst2), [1, 4, 3])
    np.testing.assert_array_equal(np.asarray(w2), [1.0, 0.0, 3.0])
