"""Minimal stand-in for ``hypothesis`` when it isn't installed.

``hypothesis`` is an OPTIONAL dev dependency (see README): when present, the
property tests use it unchanged.  This fallback keeps the same
``@settings(...) @given(st...)`` surface but degrades to a deterministic
fixed-example sweep — each strategy draws from a seeded RNG keyed on the test
name and example index, with example 0 pinned to the strategy's minimal value
(the analogue of hypothesis shrinking: failures reproduce on the simplest
draw first).  No shrinking, no database, no deadlines — just N examples.

Import pattern used by the test modules:

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_fallback import given, settings, st
"""

from __future__ import annotations

import zlib

import numpy as np

DEFAULT_MAX_EXAMPLES = 10


class _Strategy:
    def __init__(self, minimal, draw):
        self._minimal = minimal
        self._draw = draw

    def example_at(self, rng, index):
        if index == 0:
            return self._minimal
        return self._draw(rng)


class _Strategies:
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(min_value,
                         lambda rng: int(rng.integers(min_value,
                                                      max_value + 1)))

    @staticmethod
    def sampled_from(elements):
        elements = list(elements)
        return _Strategy(elements[0],
                         lambda rng: elements[int(rng.integers(len(elements)))])

    @staticmethod
    def booleans():
        return _Strategy(False, lambda rng: bool(rng.integers(2)))

    @staticmethod
    def floats(min_value=0.0, max_value=1.0):
        return _Strategy(min_value,
                         lambda rng: float(rng.uniform(min_value, max_value)))


st = _Strategies()


def given(*strategies):
    def decorate(fn):
        def wrapper():
            n = getattr(wrapper, "_max_examples", DEFAULT_MAX_EXAMPLES)
            base = zlib.crc32(fn.__qualname__.encode())
            for i in range(n):
                rng = np.random.default_rng((base + i) & 0xFFFFFFFF)
                args = [s.example_at(rng, i) for s in strategies]
                try:
                    fn(*args)
                except Exception as err:
                    raise AssertionError(
                        f"falsifying example #{i}: "
                        f"{fn.__name__}({', '.join(map(repr, args))})"
                    ) from err

        # NOTE: no functools.wraps — pytest must see the ZERO-arg signature
        # (the given-bound parameters are not fixtures).
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper

    return decorate


class settings:
    """Accepts (and mostly ignores) hypothesis settings kwargs."""

    def __init__(self, max_examples=DEFAULT_MAX_EXAMPLES, **_ignored):
        self.max_examples = max_examples

    def __call__(self, fn):
        fn._max_examples = self.max_examples
        return fn
