"""Engine-equivalence: the refactored adapters reproduce the PRE-refactor
memberships BIT-FOR-BIT on the seed corpora.

``tests/golden/engine_memberships.npz`` was captured by running
``tests/golden/capture_engine_golden.py`` against the tree as it stood
before the three divergent round loops were unified behind
``repro.core.engine.MoveEngine``.  These tests assert that every execution
path — single-device sort-reduce, ELL (Pallas interpret on CPU), sharded
static, single-device dynamic stream, and sharded dynamic stream — still
produces exactly those memberships, element for element.

If an INTENTIONAL semantics change lands (new tie-break, different gating),
regenerate the goldens with the capture script and say so in the commit.
All comparisons are CPU-deterministic: fixed corpora, fixed seeds, one
device (the sharded paths run on a 1-shard mesh).
"""

import os

import numpy as np
import pytest

from golden import capture_engine_golden as capture

from repro.compat import make_mesh
from repro.core.distributed import distributed_louvain
from repro.core.distributed_dynamic import louvain_dynamic_sharded
from repro.core.dynamic import louvain_dynamic
from repro.core.louvain import LouvainConfig, louvain

_GOLD_PATH = os.path.join(os.path.dirname(__file__), "golden",
                          "engine_memberships.npz")


@pytest.fixture(scope="module")
def gold():
    return np.load(_GOLD_PATH)


@pytest.fixture(scope="module")
def corpora():
    return capture.corpora()


# Tier-1 pins every path on ONE corpus each (compiles dominate the cost);
# the remaining corpora run with --runslow.
_slow = pytest.mark.slow


@pytest.mark.parametrize("name", ["lesmis", "sbm", "ring_of_cliques",
                                  "gnp"])
def test_single_device_bit_for_bit(gold, corpora, name):
    mem = louvain(corpora[name]).membership
    assert np.array_equal(mem, gold[f"single__{name}"])


@pytest.mark.parametrize("name", [
    "sbm", pytest.param("lesmis", marks=_slow),
    pytest.param("ring_of_cliques", marks=_slow)])
def test_ell_kernel_bit_for_bit(gold, corpora, name):
    mem = louvain(corpora[name],
                  LouvainConfig(use_ell_kernel=True)).membership
    assert np.array_equal(mem, gold[f"ell__{name}"])


@pytest.mark.parametrize("name", [
    "sbm", pytest.param("lesmis", marks=_slow),
    pytest.param("ring_of_cliques", marks=_slow)])
def test_sharded_static_bit_for_bit(gold, corpora, name):
    mesh = make_mesh((1,), ("shard",))
    mem, _, _ = distributed_louvain(corpora[name], mesh, ("shard",))
    assert np.array_equal(mem, gold[f"sharded__{name}"])


def test_dynamic_stream_bit_for_bit(gold):
    init, batches = capture.dynamic_stream()
    mem = louvain_dynamic(init, batches).membership
    assert np.array_equal(mem, gold["dynamic__sbm_stream"])


def test_sharded_dynamic_stream_bit_for_bit(gold):
    init, batches = capture.dynamic_stream()
    mesh = make_mesh((1,), ("shard",))
    mem = louvain_dynamic_sharded(init, mesh, ("shard",), batches).membership
    assert np.array_equal(mem, gold["sharded_dynamic__sbm_stream"])


def test_pallas_apply_backend_bit_for_bit_through_stream(gold):
    """The Pallas batch-apply backend leaves the whole dynamic stream's
    final membership unchanged (apply is bit-identical, so everything
    downstream is too)."""
    init, batches = capture.dynamic_stream()
    mem = louvain_dynamic(init, batches, apply_backend="pallas").membership
    assert np.array_equal(mem, gold["dynamic__sbm_stream"])


# -- the scan-backend matrix: every new scanner reproduces the SAME goldens.
#
# The frontier-compacted sort-reduce scanner and the fused Pallas ELL round
# are work optimizations, not semantics changes — each must land on the
# committed pre-refactor memberships element for element, on both the cold
# static paths and the streaming path where the compaction actually engages.


@pytest.mark.parametrize("name", [
    "sbm", pytest.param("lesmis", marks=_slow),
    pytest.param("ring_of_cliques", marks=_slow)])
def test_compact_backend_static_bit_for_bit(gold, corpora, name):
    """Cold start: no seed frontier, so "compact" resolves to the full scan
    — the knob must be a no-op on the static path."""
    mem = louvain(corpora[name],
                  LouvainConfig(scan_backend="compact")).membership
    assert np.array_equal(mem, gold[f"single__{name}"])


@pytest.mark.parametrize("name", [
    "sbm", pytest.param("lesmis", marks=_slow),
    pytest.param("ring_of_cliques", marks=_slow)])
def test_fused_ell_backend_bit_for_bit(gold, corpora, name):
    """The fused scan+apply kernel reproduces the scan-only ELL goldens."""
    mem = louvain(corpora[name],
                  LouvainConfig(scan_backend="ell_fused")).membership
    assert np.array_equal(mem, gold[f"ell__{name}"])


@pytest.mark.parametrize("name", [
    "sbm", pytest.param("lesmis", marks=_slow),
    pytest.param("ring_of_cliques", marks=_slow)])
def test_ell_default_auto_routes_fused_bit_for_bit(gold, corpora, name):
    """use_ell_kernel under the default scan_backend="auto" now runs the
    FUSED round — and must still land on the scan-only goldens."""
    mem = louvain(corpora[name],
                  LouvainConfig(use_ell_kernel=True)).membership
    assert np.array_equal(mem, gold[f"ell__{name}"])


@pytest.mark.parametrize("backend", [
    "compact", pytest.param("auto", marks=_slow), "full"])
def test_dynamic_stream_scan_backends_bit_for_bit(gold, backend):
    """The streaming path — where the compacted scanner actually engages
    (delta-screened frontiers) — is pinned for every backend value."""
    init, batches = capture.dynamic_stream()
    mem = louvain_dynamic(init, batches,
                          config=LouvainConfig(scan_backend=backend)
                          ).membership
    assert np.array_equal(mem, gold["dynamic__sbm_stream"])


# -- the capacity-ladder / aggregation-backend matrix: pass-loop work
# optimizations, not semantics changes.
#
# Memberships are invariant to buffer capacity (sentinel slots carry no
# weight; the gate hash keys on vertex ids, not capacity), so the laddered
# pass loop and both aggregation backends must land on the SAME committed
# goldens element for element.  The sbm corpus is the one whose coarse
# passes actually drop tiers (the others stay above the min-tier floor /
# hysteresis — which is itself worth pinning: "no shrink" must also be a
# no-op).


@pytest.mark.parametrize("ladder", [True, False])
@pytest.mark.parametrize("name", [
    "sbm", pytest.param("lesmis", marks=_slow),
    pytest.param("ring_of_cliques", marks=_slow)])
def test_ladder_matrix_bit_for_bit(gold, corpora, name, ladder):
    mem = louvain(corpora[name],
                  LouvainConfig(use_ladder=ladder)).membership
    assert np.array_equal(mem, gold[f"single__{name}"])


@pytest.mark.parametrize("ladder", [True, pytest.param(False, marks=_slow)])
@pytest.mark.parametrize("name", [
    "sbm", pytest.param("lesmis", marks=_slow),
    pytest.param("ring_of_cliques", marks=_slow)])
def test_agg_backend_pallas_bit_for_bit(gold, corpora, name, ladder):
    """The fused Pallas aggregation kernel, with and without laddered
    coarse capacities (golden corpora have integer weights, so the kernel's
    sums are exact and the whole run is bit-identical)."""
    mem = louvain(corpora[name],
                  LouvainConfig(agg_backend="pallas",
                                use_ladder=ladder)).membership
    assert np.array_equal(mem, gold[f"single__{name}"])


def test_ladder_tiers_cover_shrink(corpora):
    """Guard against the matrix above going vacuous: the sbm corpus's pass
    loop must actually ladder down at least one tier."""
    res = louvain(corpora["sbm"], LouvainConfig(use_ladder=True))
    caps = [(p.n_cap, p.e_cap) for p in res.passes]
    assert any(c != caps[0] for c in caps[1:]), caps


@pytest.mark.parametrize("kw", [
    dict(config=LouvainConfig(use_ladder=False)),
    dict(config=LouvainConfig(agg_backend="pallas")),
])
def test_dynamic_stream_ladder_agg_matrix_bit_for_bit(gold, kw):
    init, batches = capture.dynamic_stream()
    mem = louvain_dynamic(init, batches, **kw).membership
    assert np.array_equal(mem, gold["dynamic__sbm_stream"])


@pytest.mark.parametrize("ladder", [True, False])
def test_sharded_static_ladder_bit_for_bit(gold, corpora, ladder):
    """The sharded pass loop re-buckets coarse layouts through
    bucket_slots_host when laddering — both settings must reproduce the
    goldens (the default path already covers ladder=True; this pins the
    knob itself)."""
    mesh = make_mesh((1,), ("shard",))
    mem, _, _ = distributed_louvain(corpora["sbm"], mesh, ("shard",),
                                    use_ladder=ladder)
    assert np.array_equal(mem, gold["sharded__sbm"])


# -- the communication-backend matrix: the delta exchange is a data-movement
# optimization, not a semantics change.
#
# On one shard the delta branch's scatters reduce to exactly the gather
# backend's arithmetic (identical segment sums, unique scatter indices), so
# every committed sharded golden must be reproduced element for element —
# static, laddered, and streaming.  The multi-shard quality/bytes contract
# lives in tests/test_distributed_dynamic.py (forced-8-device subprocess).


@pytest.mark.parametrize("backend", ["delta", "gather"])
def test_sharded_comm_backend_static_bit_for_bit(gold, corpora, backend):
    mesh = make_mesh((1,), ("shard",))
    mem, _, stats = distributed_louvain(corpora["sbm"], mesh, ("shard",),
                                        comm_backend=backend)
    assert np.array_equal(mem, gold["sharded__sbm"])
    assert all(r["comm_backend"] == backend for r in stats)


@pytest.mark.parametrize("ladder", [True, False])
def test_sharded_delta_ladder_bit_for_bit(gold, corpora, ladder):
    """The delta exchange composes with the coarse-pass capacity ladder:
    per-tier caps and lane widths change, memberships must not."""
    mesh = make_mesh((1,), ("shard",))
    mem, _, _ = distributed_louvain(corpora["sbm"], mesh, ("shard",),
                                    use_ladder=ladder, comm_backend="delta")
    assert np.array_equal(mem, gold["sharded__sbm"])


def test_sharded_dynamic_stream_delta_bit_for_bit(gold):
    init, batches = capture.dynamic_stream()
    mesh = make_mesh((1,), ("shard",))
    res = louvain_dynamic_sharded(
        init, mesh, ("shard",), batches,
        config=LouvainConfig(comm_backend="delta"))
    assert np.array_equal(res.membership,
                          gold["sharded_dynamic__sbm_stream"])
    assert res.comm_backend == "delta" and res.comm_rounds > 0
    assert res.bytes_on_wire > 0


# -- the state-layout matrix: the hybrid owner-partitioned layout is a
# data-placement optimization, not a semantics change.
#
# On one shard every vertex is owned, the boundary set is empty, and the
# hybrid exchange reduces to the shard-local arithmetic of the replicated
# path (identical segment sums at touched communities, untouched slots
# unchanged), so every committed sharded golden must be reproduced element
# for element under BOTH comm backends — static, laddered, streaming, and
# refined.  The multi-shard parity/bytes contract lives in
# tests/test_distributed_dynamic.py (forced-8-device subprocess).


@pytest.mark.parametrize("backend", ["gather", "delta"])
def test_sharded_hybrid_static_bit_for_bit(gold, corpora, backend):
    mesh = make_mesh((1,), ("shard",))
    mem, _, stats = distributed_louvain(corpora["sbm"], mesh, ("shard",),
                                        comm_backend=backend,
                                        state_layout="hybrid")
    assert np.array_equal(mem, gold["sharded__sbm"])
    assert all(r["state_layout"] == "hybrid" for r in stats)


@pytest.mark.parametrize("ladder", [True, pytest.param(False, marks=_slow)])
def test_sharded_hybrid_ladder_bit_for_bit(gold, corpora, ladder):
    """The hybrid exchange composes with the coarse-pass capacity ladder:
    per-tier caps, lane widths and boundary masks change, memberships must
    not."""
    mesh = make_mesh((1,), ("shard",))
    mem, _, _ = distributed_louvain(corpora["sbm"], mesh, ("shard",),
                                    use_ladder=ladder, comm_backend="gather",
                                    state_layout="hybrid")
    assert np.array_equal(mem, gold["sharded__sbm"])


def test_sharded_static_auto_layout_bit_for_bit(gold, corpora):
    """state_layout="auto" on one shard must resolve to replicated (no
    boundary measurement can justify partitioning a 1-shard mesh) and stay
    on the goldens."""
    mesh = make_mesh((1,), ("shard",))
    mem, _, stats = distributed_louvain(corpora["sbm"], mesh, ("shard",),
                                        state_layout="auto")
    assert np.array_equal(mem, gold["sharded__sbm"])
    assert all(r["state_layout"] == "replicated" for r in stats)


@pytest.mark.parametrize("backend", ["gather", pytest.param(
    "delta", marks=_slow)])
def test_sharded_dynamic_stream_hybrid_bit_for_bit(gold, backend):
    init, batches = capture.dynamic_stream()
    mesh = make_mesh((1,), ("shard",))
    res = louvain_dynamic_sharded(
        init, mesh, ("shard",), batches,
        config=LouvainConfig(comm_backend=backend, state_layout="hybrid"))
    assert np.array_equal(res.membership,
                          gold["sharded_dynamic__sbm_stream"])
    assert res.state_layout == "hybrid"
    assert res.halo_bytes > 0 and res.comm_rounds > 0


def test_sharded_hybrid_leiden_bit_for_bit(gold, corpora):
    """Refinement composes with the hybrid layout — the constrained sweep
    mirrors resync_comm through the same scanner protocol."""
    mesh = make_mesh((1,), ("shard",))
    mem, _, _ = distributed_louvain(corpora["sbm"], mesh, ("shard",),
                                    refine="leiden", state_layout="hybrid")
    assert np.array_equal(mem, gold["sharded_leiden__sbm"])


def test_fleet_hybrid_tenants_bit_for_bit(gold):
    """Fleet tenants served under the hybrid layout land on the committed
    sharded-dynamic golden — the per-bucket layout changes data placement,
    never results."""
    from repro.core.fleet import serve_fleet

    init, batches = capture.dynamic_stream()
    mesh = make_mesh((1,), ("shard",))
    res = serve_fleet({"a": init, "b": init}, {"a": batches, "b": batches},
                      mesh, ("shard",), screening="community",
                      config=LouvainConfig(state_layout="hybrid"))
    for tid in ("a", "b"):
        assert np.array_equal(res.membership[tid],
                              gold["sharded_dynamic__sbm_stream"]), tid
    assert res.state_layout == "hybrid" and res.halo_bytes > 0


# -- the re-shard / pipelined-fetch matrix: skew-aware re-sharding moves
# data, never labels, and the pipelined convergence fetch reorders host
# syncs, never arithmetic — every combination must reproduce the committed
# goldens element for element.  (A 1-shard mesh can never be imbalanced, so
# reshard="auto" must also NEVER fire here; the multi-shard firing contract
# lives in tests/test_reshard.py's forced-8-device subprocess.)


@pytest.mark.parametrize("kw", [
    dict(reshard="auto"),
    dict(reshard="none", pipeline_fetch=True),
    dict(reshard="auto", pipeline_fetch=True),
    dict(reshard="auto", pipeline_fetch=True, comm_backend="delta"),
])
def test_sharded_reshard_pipeline_static_bit_for_bit(gold, corpora, kw):
    mesh = make_mesh((1,), ("shard",))
    mem, _, stats = distributed_louvain(corpora["sbm"], mesh, ("shard",),
                                        **kw)
    assert np.array_equal(mem, gold["sharded__sbm"])
    assert not any(r.get("reshard") for r in stats)


def test_sharded_dynamic_stream_reshard_bit_for_bit(gold):
    init, batches = capture.dynamic_stream()
    mesh = make_mesh((1,), ("shard",))
    res = louvain_dynamic_sharded(
        init, mesh, ("shard",), batches,
        config=LouvainConfig(comm_backend="delta", reshard="auto",
                             pipeline_fetch=True))
    assert np.array_equal(res.membership,
                          gold["sharded_dynamic__sbm_stream"])
    assert res.reshard_passes == 0 and res.reshard_bytes == 0


# -- the refinement matrix: refine="leiden" runs the constrained sweep
# between local-moving and aggregation on EVERY backend through the one
# ConstrainedScanner wrapper — each path is pinned to its own committed
# refined goldens bit-for-bit (captured on this tree; the unrefined keys
# above are untouched).  The "gnp" corpus is the badly-connected one:
# plain Louvain leaves a disconnected community there (audited in
# tests/test_louvain.py), so the refined keys genuinely differ.


@pytest.mark.parametrize("name", [
    "gnp", "sbm", pytest.param("lesmis", marks=_slow),
    pytest.param("ring_of_cliques", marks=_slow)])
def test_single_leiden_bit_for_bit(gold, corpora, name):
    mem = louvain(corpora[name],
                  LouvainConfig(refine="leiden")).membership
    assert np.array_equal(mem, gold[f"single_leiden__{name}"])


@pytest.mark.parametrize("name", [
    "sbm", pytest.param("gnp", marks=_slow),
    pytest.param("lesmis", marks=_slow),
    pytest.param("ring_of_cliques", marks=_slow)])
def test_ell_leiden_bit_for_bit(gold, corpora, name):
    mem = louvain(corpora[name],
                  LouvainConfig(use_ell_kernel=True,
                                refine="leiden")).membership
    assert np.array_equal(mem, gold[f"ell_leiden__{name}"])


@_slow
@pytest.mark.parametrize("backend", ["ell", "ell_fused"])
def test_ell_scan_vs_fused_leiden_bit_for_bit(gold, corpora, backend):
    """Scan-only and fused ELL rounds agree under the refinement constraint
    (the on-device block masking composes with both kernels).  Slow-only:
    tier-1 already pins scan-vs-fused refine parity through the Pallas
    interpreter in tests/test_fused_ell_kernel.py."""
    mem = louvain(corpora["sbm"],
                  LouvainConfig(scan_backend=backend,
                                refine="leiden")).membership
    assert np.array_equal(mem, gold["ell_leiden__sbm"])


@pytest.mark.parametrize("name", [
    "sbm", pytest.param("gnp", marks=_slow),
    pytest.param("lesmis", marks=_slow),
    pytest.param("ring_of_cliques", marks=_slow)])
def test_sharded_leiden_bit_for_bit(gold, corpora, name):
    mesh = make_mesh((1,), ("shard",))
    mem, _, _ = distributed_louvain(corpora[name], mesh, ("shard",),
                                    refine="leiden")
    assert np.array_equal(mem, gold[f"sharded_leiden__{name}"])


@pytest.mark.parametrize("kw", [
    dict(comm_backend="delta"),
    pytest.param(dict(use_ladder=False), marks=_slow),
    pytest.param(dict(comm_backend="delta", use_ladder=False), marks=_slow)])
def test_sharded_leiden_comm_ladder_matrix_bit_for_bit(gold, corpora, kw):
    """Refinement composes with the delta exchange and the capacity ladder
    — the constrained sweep rides the same scanner protocol."""
    mesh = make_mesh((1,), ("shard",))
    mem, _, _ = distributed_louvain(corpora["sbm"], mesh, ("shard",),
                                    refine="leiden", **kw)
    assert np.array_equal(mem, gold["sharded_leiden__sbm"])


def test_dynamic_stream_leiden_bit_for_bit(gold):
    init, batches = capture.dynamic_stream()
    mem = louvain_dynamic(init, batches,
                          config=LouvainConfig(refine="leiden")).membership
    assert np.array_equal(mem, gold["dynamic_leiden__sbm_stream"])


def test_sharded_dynamic_stream_leiden_bit_for_bit(gold):
    init, batches = capture.dynamic_stream()
    mesh = make_mesh((1,), ("shard",))
    mem = louvain_dynamic_sharded(
        init, mesh, ("shard",), batches,
        config=LouvainConfig(refine="leiden")).membership
    assert np.array_equal(mem, gold["sharded_dynamic_leiden__sbm_stream"])


def test_batched_leiden_bit_for_bit(gold, corpora):
    """The vmapped fleet pass loop under refinement lands on the
    single-device refined golden (one stream, identical semantics)."""
    from repro.core.multistream import louvain_batched, stack_graphs

    g = corpora["gnp"]
    res = louvain_batched(stack_graphs([g]),
                          LouvainConfig(refine="leiden"))
    n = int(np.asarray(g.n_valid))
    assert np.array_equal(np.asarray(res.membership[0, :n]),
                          gold["single_leiden__gnp"])


def test_batched_stream_compact_bit_for_bit(gold):
    """One-stream batched serving with the compacted scanner equals the
    sequential compact driver exactly (vmapped cond/select semantics must
    not perturb results)."""
    from repro.core.multistream import louvain_dynamic_batched

    init, batches = capture.dynamic_stream()
    prev = louvain(init).membership
    bat = louvain_dynamic_batched(
        [init], [batches], prevs=[prev],
        config=LouvainConfig(scan_backend="compact"))
    seq = louvain_dynamic(init, batches, prev=prev,
                          config=LouvainConfig(scan_backend="compact"))
    assert np.array_equal(bat.stream_membership(0), seq.membership)


# -- the serving-fleet matrix: the multi-tenant fleet's fused per-lane step
# IS the solo sharded dynamic path (same apply, same move phase, same
# renumber), so every tenant served through the fleet must land on the
# committed sharded-dynamic golden element for element — alone AND batched
# with a neighbor lane.


def test_fleet_single_tenant_stream_bit_for_bit(gold):
    from repro.core.fleet import serve_fleet

    init, batches = capture.dynamic_stream()
    mesh = make_mesh((1,), ("shard",))
    res = serve_fleet({"t0": init}, {"t0": batches}, mesh, ("shard",),
                      screening="community")
    assert np.array_equal(res.membership["t0"],
                          gold["sharded_dynamic__sbm_stream"])


def test_fleet_batched_tenants_bit_for_bit(gold):
    init, batches = capture.dynamic_stream()
    from repro.core.fleet import serve_fleet

    mesh = make_mesh((1,), ("shard",))
    res = serve_fleet({"a": init, "b": init}, {"a": batches, "b": batches},
                      mesh, ("shard",), screening="community")
    for tid in ("a", "b"):
        assert np.array_equal(res.membership[tid],
                              gold["sharded_dynamic__sbm_stream"]), tid
