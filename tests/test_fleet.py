"""Multi-tenant serving fleet (repro.core.fleet).

The correctness bar: every tenant served through the fleet — batched with
other tenants per dispatch, sharded per lane, convergence fetches deferred
one dispatch — gets BIT-FOR-BIT the membership it would get from
``louvain_dynamic_sharded`` alone, through every control path (fused
accept, non-converged fallback replay, whale bucket migration).  Admission
edge cases (zero tenants, one tenant, uneven streams, frozen source
buckets) must degrade to the obvious behavior, never crash.

All on a 1-shard mesh: the vmap-over-shard_map composition itself is what
is under test; the multi-device contract rides the same sharded pass loop
pinned by tests/test_distributed_dynamic.py.
"""

import numpy as np
import pytest

from repro.compat import make_mesh
from repro.configs.louvain_arch import (FleetEnvelope, fleet_envelope,
                                        migrate_envelope, plan_fleet)
from repro.core.delta import make_edge_batch
from repro.core.distributed_dynamic import louvain_dynamic_sharded
from repro.core.fleet import FleetRouter, serve_fleet
from repro.core.graph import build_csr
from repro.core.louvain import LouvainConfig, louvain
from repro.data import sbm_graph, sbm_holdout_stream

AXES = ("shard",)


@pytest.fixture(scope="module")
def mesh():
    return make_mesh((1,), AXES)


def _case(seed, n_steps=3, b_cap=8):
    init, batches, _ = sbm_holdout_stream(seed, n_cap=128, e_cap=1400,
                                          n_hold=24, n_steps=n_steps,
                                          b_cap=b_cap)
    return init, batches


def _ring_whale(n=64, n_batches=8, k=12):
    """A sparse ring whose envelope is tight, plus dense insert batches
    that blow through it: forces bucket migration mid-stream."""
    s = np.arange(n, dtype=np.int64)
    d = (s + 1) % n
    g = build_csr(np.concatenate([s, d]), np.concatenate([d, s]),
                  np.ones(2 * n, np.float32), n, e_cap=2 * n + 4 * k)
    rng = np.random.default_rng(7)
    batches = []
    for _ in range(n_batches):
        bs = rng.integers(0, n, k)
        bd = (bs + 2 + rng.integers(0, n - 3, k)) % n
        batches.append(make_edge_batch(bs, bd, np.ones(k, np.float32),
                                       g.n_cap, b_cap=k))
    return g, batches


def _solo(graph, batches, mesh, config=LouvainConfig(), screening=True):
    return louvain_dynamic_sharded(graph, mesh, AXES, batches,
                                   config=config, screening=screening)


# -- envelope policy units ---------------------------------------------------


def test_fleet_envelope_is_power_of_two():
    env = fleet_envelope(100, 300, 5, 2)
    assert env.v_per_shard & (env.v_per_shard - 1) == 0
    assert env.e_per_shard & (env.e_per_shard - 1) == 0
    assert env.b_cap & (env.b_cap - 1) == 0
    assert env.v_per_shard * 2 >= 100
    assert env.e_per_shard >= 300 and env.b_cap >= 5


def test_plan_fleet_buckets_same_size_tenants_together():
    plan = plan_fleet([(100, 300, 4), (100, 290, 3), (100, 5000, 4)], 2)
    pair = [env for env, idx in plan.items() if 0 in idx]
    whale = [env for env, idx in plan.items() if 2 in idx]
    assert plan[pair[0]] == [0, 1]         # one compile for the pair
    assert whale[0].e_per_shard > pair[0].e_per_shard
    assert whale[0].v_per_shard == pair[0].v_per_shard


def test_migrate_envelope_doubles_edges_only():
    env = FleetEnvelope(64, 256, 8)
    big = migrate_envelope(env, 300)
    assert big.e_per_shard == 512
    assert big.v_per_shard == 64 and big.b_cap == 8
    assert migrate_envelope(env, 2000).e_per_shard == 2048


# -- admission edge cases ----------------------------------------------------


def test_serve_zero_tenants(mesh):
    res = FleetRouter(mesh, AXES).serve({})
    assert res.membership == {} and res.n_dispatches == 0
    assert res.bytes_on_wire == 0 and res.buckets == {}


def test_refine_rejected(mesh):
    with pytest.raises(ValueError, match="refine"):
        FleetRouter(mesh, AXES, LouvainConfig(refine="leiden"))


def test_double_admission_rejected(mesh):
    init, batches = _case(20)
    router = FleetRouter(mesh, AXES)
    router.admit("a", init, b_cap=8)
    with pytest.raises(ValueError, match="already admitted"):
        router.admit("a", init, b_cap=8)


def test_unadmitted_tenant_rejected(mesh):
    with pytest.raises(ValueError, match="not admitted"):
        FleetRouter(mesh, AXES).serve({"ghost": []})


def test_oversized_batch_rejected(mesh):
    init, batches = _case(21)
    router = FleetRouter(mesh, AXES)
    env = router.admit("a", init, b_cap=1)
    big = make_edge_batch(np.array([0, 1]), np.array([2, 3]),
                          np.ones(2, np.float32), init.n_cap,
                          b_cap=4 * env.b_cap)
    with pytest.raises(ValueError, match="exceeds"):
        router.serve({"a": [big]})


def test_single_tenant_empty_stream_keeps_admission_state(mesh):
    init, _ = _case(22)
    prev = louvain(init).membership
    router = FleetRouter(mesh, AXES)
    router.admit("a", init, prev=prev, b_cap=8)
    res = router.serve({"a": []})
    n = int(init.n_valid)
    assert np.array_equal(res.membership["a"], np.asarray(prev)[:n])
    assert res.n_dispatches == 0 and res.pass_stats["a"] == []


# -- parity: fleet == solo sharded serving, per tenant -----------------------


@pytest.mark.slow
def test_fleet_parity_four_tenants(mesh):
    cases = [_case(seed) for seed in (30, 31, 32, 33)]
    res = serve_fleet({f"t{i}": c[0] for i, c in enumerate(cases)},
                      {f"t{i}": c[1] for i, c in enumerate(cases)},
                      mesh, AXES, screening="community")
    # One fused dispatch per bucket per step — NOT per tenant per step.
    assert res.n_dispatches == 3 * len(res.buckets) < 3 * len(cases)
    assert res.bytes_on_wire > 0
    for i, (init, batches) in enumerate(cases):
        solo = _solo(init, batches, mesh, screening="community")
        assert np.array_equal(res.membership[f"t{i}"], solo.membership), i
        stats = res.pass_stats[f"t{i}"]
        assert len(stats) == len(batches)
        assert all(s.screening == "community" for s in stats)


def test_fleet_parity_uneven_streams(mesh):
    """Lanes whose stream already ended ride along as idle (b_valid=0)
    without perturbing their resident state."""
    a = _case(34, n_steps=3)
    b = _case(35, n_steps=3)
    res = serve_fleet({"a": a[0], "b": b[0]},
                      {"a": a[1], "b": b[1][:1]},
                      mesh, AXES, screening="community")
    solo_a = _solo(a[0], a[1], mesh, screening="community")
    solo_b = _solo(b[0], b[1][:1], mesh, screening="community")
    assert np.array_equal(res.membership["a"], solo_a.membership)
    assert np.array_equal(res.membership["b"], solo_b.membership)
    assert len(res.pass_stats["b"]) == 1


def test_fleet_fallback_replay_parity(mesh):
    """A config whose lanes never satisfy the fused accept predicate
    (aggregation always proceeds) exercises the solo-replay fallback; the
    replay must be invisible in the results."""
    cfg = LouvainConfig(aggregation_tolerance=1.0, initial_tolerance=0.0)
    cases = [_case(36), _case(37)]
    router = FleetRouter(mesh, AXES, cfg, screening="community")
    for tid, (init, _) in zip("ab", cases):
        # Singleton warm start: the first step cannot converge in one
        # sweep, so its lane misses the fused accept predicate.
        router.admit(tid, init, prev=np.arange(init.n_cap, dtype=np.int32),
                     b_cap=8)
    res = router.serve({"a": cases[0][1], "b": cases[1][1]})
    assert res.n_fallbacks > 0
    for tid, (init, batches) in zip("ab", cases):
        solo = louvain_dynamic_sharded(
            init, mesh, AXES, batches,
            prev=np.arange(init.n_cap, dtype=np.int32),
            config=cfg, screening="community")
        assert np.array_equal(res.membership[tid], solo.membership), tid


@pytest.mark.slow
def test_fleet_auto_screening_parity_and_stats(mesh):
    init, batches = _case(38)
    res = serve_fleet({"a": init}, {"a": batches}, mesh, AXES,
                      screening="auto")
    stats = res.pass_stats["a"]
    assert stats[0].screening == "community" and stats[0].downgraded
    assert all(s.screening in ("community", "vertex") for s in stats)
    # Replaying the recorded modes through the solo path reproduces it:
    # "auto" is host-side routing over concrete modes, never new semantics.
    from repro.core.delta import apply_edge_batch

    g = init
    cur = louvain_dynamic_sharded(g, mesh, AXES, []).membership
    for t, s in enumerate(stats):
        solo = louvain_dynamic_sharded(
            g, mesh, AXES, batches[t:t + 1], prev=cur,
            screening=s.screening if s.screening else False)
        cur = solo.membership
        g, _ = apply_edge_batch(g, batches[t])
    assert np.array_equal(res.membership["a"], cur)


# -- whale migration ---------------------------------------------------------


def test_whale_migrates_without_perturbing_buddy(mesh):
    """The whale's insert stream overflows its envelope mid-stream: it must
    migrate to a bigger bucket (its old lane freezes — possibly leaving an
    all-frozen source bucket) and finish correctly, while a buddy tenant in
    a DIFFERENT bucket sails through bit-for-bit untouched."""
    whale_g, whale_b = _ring_whale()
    buddy_g, buddy_b = _case(39, n_steps=len(whale_b), b_cap=8)
    res = serve_fleet({"whale": whale_g, "buddy": buddy_g},
                      {"whale": whale_b, "buddy": buddy_b},
                      mesh, AXES, screening="community")
    assert res.n_migrations >= 1
    solo_w = _solo(whale_g, whale_b, mesh, screening="community")
    solo_b = _solo(buddy_g, buddy_b, mesh, screening="community")
    assert np.array_equal(res.membership["whale"], solo_w.membership)
    assert np.array_equal(res.membership["buddy"], solo_b.membership)
    # The whale landed in exactly one live bucket, in a bigger envelope.
    homes = [env for env, tids in res.buckets.items() if "whale" in tids]
    assert len(homes) == 1
    assert homes[0].e_per_shard > 2 * whale_g.n_valid


def test_whale_alone_migrates(mesh):
    """One tenant, migrating mid-stream: the source bucket goes all-frozen
    and later dispatches must still drain the remaining steps."""
    whale_g, whale_b = _ring_whale()
    res = serve_fleet({"w": whale_g}, {"w": whale_b}, mesh, AXES,
                      screening="community")
    assert res.n_migrations >= 1
    solo = _solo(whale_g, whale_b, mesh, screening="community")
    assert np.array_equal(res.membership["w"], solo.membership)
