"""launch/analysis.py: HLO collective parsing, roofline terms, model flops."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import analysis

_FAKE_HLO = """
HloModule jit_step

ENTRY %main {
  %p0 = f32[128,256]{1,0} parameter(0)
  %ag = f32[2048,256]{1,0} all-gather(%p0), replica_groups={}, dimensions={0}
  %ar.1 = bf16[1024]{0} all-reduce(%x), to_apply=%add
  %a2a = f32[16,32]{1,0} all-to-all(%y), dimensions={0}
  %cp = u8[64]{0} collective-permute(%z), source_target_pairs={{0,1}}
  %rs = f32[128]{0} reduce-scatter(%w), dimensions={0}, to_apply=%add
  %ar2.s = (f32[256]{0}, f32[64]{0}) all-reduce-start(%q, %r), to_apply=%add
  %ar2.d = (f32[256]{0}, f32[64]{0}) all-reduce-done(%ar2.s)
  %not_a_coll = f32[999]{0} add(%a, %b)
}
"""


def test_collective_bytes_parser():
    got = analysis.collective_bytes(_FAKE_HLO)
    assert got["all-gather"] == 2048 * 256 * 4
    # plain all-reduce + the tuple-shaped async start (done NOT re-counted)
    assert got["all-reduce"] == 1024 * 2 + (256 + 64) * 4
    assert got["all-to-all"] == 16 * 32 * 4
    assert got["collective-permute"] == 64 * 1
    assert got["reduce-scatter"] == 128 * 4


def test_roofline_terms_and_bottleneck():
    r = analysis.Roofline(flops_per_chip=197e12, bytes_per_chip=819e9,
                          coll_bytes_per_chip=0.0, coll_by_kind={})
    assert r.t_compute == pytest.approx(1.0)
    assert r.t_memory == pytest.approx(1.0)
    assert r.t_collective == 0.0
    assert r.bound_time == pytest.approx(1.0)
    r2 = analysis.Roofline(1e12, 1e9, 500e9, {})
    assert r2.bottleneck == "collective"
    assert r2.t_collective == pytest.approx(10.0)


def test_roofline_from_real_compiled():
    """End-to-end on a genuinely compiled function (1 device)."""
    fn = jax.jit(lambda x: jnp.tanh(x @ x))
    compiled = fn.lower(jax.ShapeDtypeStruct((256, 256), jnp.float32)).compile()
    roof = analysis.roofline_from_compiled(compiled)
    # matmul flops dominate: 2*256^3 = 33.6 MFLOP
    assert roof.flops_per_chip >= 2 * 256**3
    assert roof.bytes_per_chip > 0
    assert roof.coll_bytes_per_chip == 0  # single device, no collectives
    ms = analysis.memory_stats(compiled)
    assert ms.get("argument_size_in_bytes", 0) >= 256 * 256 * 4


def test_model_flops_lm():
    from repro.configs.registry import get_arch
    arch = get_arch("qwen2-1.5b")
    mf_train = analysis.model_flops(arch, "train_4k")
    # 6 * ~1.5e9 params * (256*4096 tokens) ~ 9.4e15, embed-heavy +/- 20%
    assert 6e15 < mf_train < 1.5e16
    mf_dec = analysis.model_flops(arch, "decode_32k")
    assert mf_dec < mf_train / 1e3     # one token vs 4096


def test_model_flops_all_cells_positive():
    from repro.configs.registry import all_cells, get_arch
    for arch_id, shape in all_cells():
        mf = analysis.model_flops(get_arch(arch_id), shape)
        assert mf is not None and mf > 0, (arch_id, shape)


def test_shape_bytes_dtypes():
    assert analysis._shape_bytes("bf16", "2,3") == 12
    assert analysis._shape_bytes("f32", "") == 4      # scalar
    assert analysis._shape_bytes("pred", "8") == 8
    assert analysis._shape_bytes("s64", "4") == 32
