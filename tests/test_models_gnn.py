"""GNN architecture tests: per-arch x per-shape smoke steps, model
invariances (GIN permutation equivariance, Equiformer rotation invariance,
GAT attention normalization), DimeNet triplet builder, neighbor sampler."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import dimenet_cfg, equiformer_v2, gat_cora, gin_tu
from repro.configs.gnn_common import GNN_SMOKE_SHAPES
from repro.models.gnn.common import GraphBatch, segment_softmax

GNN_MODS = [gin_tu, gat_cora, dimenet_cfg, equiformer_v2]
SHAPES = list(GNN_SMOKE_SHAPES)

# DimeNet/Equiformer pay several seconds of tensor-product compile per
# (arch, shape) cell; tier-1 keeps one representative shape ("molecule")
# and the full sweep runs under --runslow.
_HEAVY_GNN = {"dimenet", "equiformer-v2"}


def _cell(shape, mod):
    if mod.ARCH.arch_id in _HEAVY_GNN and shape != "molecule":
        return pytest.param(shape, mod, marks=pytest.mark.slow,
                            id=f"{shape}-{mod.ARCH.arch_id}")
    return pytest.param(shape, mod, id=f"{shape}-{mod.ARCH.arch_id}")


@pytest.mark.parametrize(
    "shape,mod", [_cell(s, m) for s in SHAPES for m in GNN_MODS])
def test_smoke_train_step(shape, mod):
    """One optimizer step on a reduced config: loss finite and decreasing
    over a few steps."""
    from repro.optim import AdamWConfig, adamw_init, adamw_update
    arch = mod.ARCH
    sh = GNN_SMOKE_SHAPES[shape]
    cfg = arch.make_config(sh, True)
    loss_fn = arch.make_loss(cfg, sh, shape)
    key = jax.random.PRNGKey(0)
    params = arch.init_params(shape, key, smoke=True)
    batch = arch.make_batch(shape, key, smoke=True)
    opt = adamw_init(params)
    ocfg = AdamWConfig(lr=3e-3)

    @jax.jit
    def step(p, o):
        loss, g = jax.value_and_grad(lambda q: loss_fn(q, batch))(p)
        p, o, _ = adamw_update(ocfg, p, g, o)
        return p, o, loss

    losses = []
    for _ in range(6):
        params, opt, loss = step(params, opt)
        assert np.isfinite(float(loss)), (arch.arch_id, shape)
        losses.append(float(loss))
    assert losses[-1] < losses[0] + 1e-6, (arch.arch_id, shape, losses)


def _rand_graph(key, n=20, e=60, f=8, n_classes=3):
    ks = jax.random.split(key, 4)
    return GraphBatch(
        node_feat=jax.random.normal(ks[0], (n, f)),
        edge_src=jax.random.randint(ks[1], (e,), 0, n),
        edge_dst=jax.random.randint(ks[2], (e,), 0, n),
        n_nodes=jnp.int32(n),
        labels=jax.random.randint(ks[3], (n,), 0, n_classes),
        graph_id=jnp.zeros((n,), jnp.int32), n_graphs=jnp.int32(1),
        positions=jax.random.normal(jax.random.PRNGKey(9), (n, 3)))


def test_gin_permutation_equivariance():
    """Relabeling vertices permutes GIN outputs identically."""
    from repro.models.gnn import gin
    cfg = gin.GINConfig(n_layers=2, d_hidden=16, d_feat=8, n_classes=3)
    params = gin.init_params(cfg, jax.random.PRNGKey(0))
    g = _rand_graph(jax.random.PRNGKey(1))
    out = gin.forward(cfg, params, g)

    n = 20
    perm = np.random.default_rng(0).permutation(n)
    inv = np.argsort(perm)
    g2 = g._replace(
        node_feat=g.node_feat[perm],
        edge_src=jnp.asarray(inv)[g.edge_src],
        edge_dst=jnp.asarray(inv)[g.edge_dst],
        labels=g.labels[perm])
    out2 = gin.forward(cfg, params, g2)
    np.testing.assert_allclose(np.asarray(out2), np.asarray(out)[perm],
                               rtol=2e-4, atol=2e-4)


def test_gat_attention_normalized():
    """Segment softmax over incoming edges sums to 1 per destination."""
    e, n = 40, 10
    key = jax.random.PRNGKey(0)
    logits = jax.random.normal(key, (e,))
    dst = jax.random.randint(jax.random.PRNGKey(1), (e,), 0, n)
    alpha = segment_softmax(logits, dst, n)
    sums = jax.ops.segment_sum(alpha, dst, num_segments=n)
    present = np.asarray(jax.ops.segment_sum(jnp.ones(e), dst,
                                             num_segments=n)) > 0
    np.testing.assert_allclose(np.asarray(sums)[present], 1.0, rtol=1e-5)


def test_equiformer_rotation_invariance():
    """Scalar (energy) output must be invariant under global rotation of the
    positions — the core eSCN equivariance property."""
    from scipy.spatial.transform import Rotation
    from repro.models.gnn import equiformer
    cfg = equiformer.EquiformerConfig(n_layers=2, d_hidden=8, l_max=2,
                                      m_max=1, n_heads=2, d_feat=8,
                                      out_dim=1, node_level=False)
    params = equiformer.init_params(cfg, jax.random.PRNGKey(0))
    g = _rand_graph(jax.random.PRNGKey(1))
    e1 = float(equiformer.forward(cfg, params, g)[0, 0])

    rot = Rotation.from_euler("xyz", [0.3, -1.1, 2.0]).as_matrix()
    g2 = g._replace(positions=g.positions @ jnp.asarray(rot, jnp.float32).T)
    e2 = float(equiformer.forward(cfg, params, g2)[0, 0])
    assert np.isclose(e1, e2, rtol=1e-3, atol=1e-4), (e1, e2)


def test_dimenet_triplet_builder():
    """Triplets are exactly the (k->j, j->i) wedges with k != i."""
    from repro.models.gnn.dimenet import build_triplets_host
    src = np.array([0, 1, 2, 1], np.int32)   # edges: 0->1, 1->2, 2->0, 1->0
    dst = np.array([1, 2, 0, 0], np.int32)
    t_kj, t_ji = build_triplets_host(src, dst, 4, cap=16)
    live = t_kj < 4
    wedges = {(int(a), int(b)) for a, b in zip(t_kj[live], t_ji[live])}
    # e1: j=1,i=2; edges into j=1: e0 (0->1). k=0 != i=2 -> (e0, e1)
    # e2: j=2,i=0; edges into 2: e1 (1->2). k=1 != 0 -> (e1, e2)
    # e0: j=0,i=1; edges into 0: e2 (2->0), e3 (1->0). k=2 ok, k=1 == i dropped.
    # e3: j=1,i=0; edges into 1: e0 (0->1). k=0 == i dropped.
    assert wedges == {(0, 1), (1, 2), (2, 0)}


def test_dimenet_distance_basis_bounds():
    from repro.models.gnn.dimenet import rbf_basis, sbf_basis
    d = jnp.linspace(0.1, 6.0, 50)
    rbf = rbf_basis(d, 6, 5.0)
    assert rbf.shape == (50, 6)
    # envelope: zero beyond cutoff
    assert np.all(np.asarray(rbf)[np.asarray(d) >= 5.0] == 0)
    cos_a = jnp.linspace(-1, 1, 50)
    sbf = sbf_basis(d, cos_a, 3, 4, 5.0)
    assert sbf.shape == (50, 12)
    assert bool(jnp.all(jnp.isfinite(sbf)))


def test_neighbor_sampler_block():
    from repro.models.gnn.sampler import block_capacity, sample_block
    rng = np.random.default_rng(0)
    n = 200
    # random regular-ish graph in CSR
    deg = 8
    indptr = np.arange(0, deg * n + 1, deg)
    indices = rng.integers(0, n, deg * n)
    seeds = rng.choice(n, 16, replace=False)
    blk = sample_block(indptr, indices, seeds, (4, 3), rng)
    n_cap, e_cap = block_capacity(16, (4, 3))
    assert blk.edge_src.shape == (e_cap,)
    assert blk.node_ids.shape == (n_cap,)
    assert blk.n_seeds == 16
    live = blk.edge_src < n_cap
    # every live edge references a node inside the block
    assert np.all(blk.edge_src[live] < blk.n_nodes)
    assert np.all(blk.edge_dst[live] < blk.n_nodes)
    # seeds are the first n_seeds nodes
    np.testing.assert_array_equal(blk.node_ids[:16], seeds)


def test_wigner_d_orthogonality_and_rotation_to_z():
    """Wigner-D blocks used by the eSCN rotation are orthogonal, and the
    rotation_to_z frame actually sends each edge vector to +z."""
    from repro.models.gnn.wigner import rotation_to_z, wigner_d_stack
    rng = np.random.default_rng(0)
    vecs = rng.standard_normal((5, 3)).astype(np.float32)
    vecs /= np.linalg.norm(vecs, axis=1, keepdims=True)
    R = rotation_to_z(jnp.asarray(vecs))
    z = np.einsum("eij,ej->ei", np.asarray(R), vecs)
    np.testing.assert_allclose(z, np.tile([0, 0, 1.0], (5, 1)), atol=1e-5)
    ds = wigner_d_stack(R, 3)
    for l, d in enumerate(ds):
        d = np.asarray(d)
        for e in range(5):
            np.testing.assert_allclose(d[e] @ d[e].T, np.eye(2 * l + 1),
                                       atol=2e-4)
